//! Scripted fault injection: the perturbation engine (ROADMAP item 5).
//!
//! Every scenario before this module priced a *static* cluster: clean
//! links, healthy devices, a stationary gate distribution. The adaptive
//! stack — EWMA gate tracking, the drift-tolerant [`PlanCache`], the
//! placement engine, the overlap autotuner — exists precisely for
//! networks that change under the job, so this module makes them change,
//! deterministically and reproducibly, at step granularity through the
//! [`Workload`] seam shared by training and serving.
//!
//! Four perturbation classes ([`PerturbKind`]):
//!
//! * **stragglers** — a per-device compute slowdown factor, constant over
//!   a step window or *flapping* (alternating on/off every `flap_period`
//!   steps, the classic intermittently-throttled host);
//! * **degraded links** — a physical link's α and β scale by a factor at
//!   the window start and scale back at its end. Per-pair costs re-derive
//!   through the stored routing paths ([`Topology::scale_link`]) and the
//!   mutation bumps the shared *topology epoch*, so the [`PlanCache`]
//!   drops schedules and tuned chunk counts synthesised for the old
//!   fabric and the step loop re-enters BvN synthesis + overlap
//!   autotuning;
//! * **node loss** — a device drops dead ([`Topology::mark_dead`]). The
//!   world elastically shrinks: the corpse's sender row is dropped, the
//!   tokens every surviving sender routed toward corpse-hosted experts
//!   are re-gated onto live-hosted experts, and the placement engine
//!   runs an *emergency evacuation* (amortisation gate bypassed, cost
//!   still charged to the clock) that swaps loaded experts off the dead
//!   host;
//! * **gate drift** — a cyclic shift of the expert columns over a step
//!   window: a regime change in the gate distribution that stresses
//!   `GateLoadEwma` smoothing and the plan-cache tolerance band without
//!   touching the fabric.
//!
//! Recovery is the observable: [`recovery_steps`] reports how many steps
//! after a fault's onset the step clock returns within [`RECOVERY_TOL`]
//! of the pre-fault steady state (the mean of the [`RECOVERY_WINDOW`]
//! steps before onset). The schedule itself is pure data — parsing a
//! [`ChaosSpec`] and replaying it produce the same faults on every run,
//! and an empty spec (`off`) leaves every code path bit-identical to a
//! run without the engine.
//!
//! [`PlanCache`]: crate::coordinator::PlanCache
//! [`Workload`]: crate::coordinator::Workload
//! [`Topology::scale_link`]: crate::topology::Topology::scale_link
//! [`Topology::mark_dead`]: crate::topology::Topology::mark_dead

use crate::placement::Placement;
use crate::topology::Topology;
use crate::util::Mat;

/// Steps of pre-fault history averaged into the recovery baseline.
pub const RECOVERY_WINDOW: usize = 8;
/// Relative band around the baseline that counts as "recovered".
pub const RECOVERY_TOL: f64 = 0.05;
/// `end_step` sentinel for a window that never closes.
pub const OPEN_END: usize = usize::MAX;

/// What a perturbation does while its window is active.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PerturbKind {
    /// Device `dev` computes `factor`× slower. `flap_period == 0` means
    /// constant over the window; otherwise the slowdown alternates
    /// on/off every `flap_period` steps from the window start.
    Straggler { dev: usize, factor: f64, flap_period: usize },
    /// Physical link `edge` degrades: α and β scale by `factor` at the
    /// window start and scale back (×1/factor) at the window end.
    LinkDegrade { edge: usize, factor: f64 },
    /// Device `dev` drops dead at the window start (one-shot; the end is
    /// meaningless — a corpse stays a corpse).
    NodeLoss { dev: usize },
    /// Gate regime shift: expert columns of the dispatch counts rotate
    /// left by `shift` while the window is active.
    GateDrift { shift: usize },
}

/// One scripted fault: a kind plus its step window `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Perturbation {
    pub kind: PerturbKind,
    /// First step (0-based) the fault is active / fires.
    pub start_step: usize,
    /// First step past the window ([`OPEN_END`] = never closes).
    pub end_step: usize,
}

/// Is a straggler with window `[start, end)` and `flap_period` slowing
/// its device down at `step`? With a zero period the slowdown holds over
/// the whole window; otherwise it alternates on/off in `flap_period`
/// blocks, starting on. (Mirrored in `python/mirrors/perturb_recovery.py`.)
pub fn straggler_active(step: usize, start: usize, end: usize, flap_period: usize) -> bool {
    if step < start || step >= end {
        return false;
    }
    flap_period == 0 || ((step - start) / flap_period) % 2 == 0
}

/// Steps from fault onset until the step clock first returns within
/// `tol` of the pre-onset steady state: baseline = mean of the `window`
/// steps before `onset`, recovered at the first `t >= onset` with
/// `step_s[t] <= baseline * (1 + tol)`. `None` when there is no
/// pre-onset history or the clock never comes back.
/// (Mirrored in `python/mirrors/perturb_recovery.py`.)
pub fn recovery_steps(step_s: &[f64], onset: usize, window: usize, tol: f64) -> Option<usize> {
    if onset == 0 || onset > step_s.len() || window == 0 {
        return None;
    }
    let lo = onset.saturating_sub(window);
    let base = &step_s[lo..onset];
    let baseline = base.iter().sum::<f64>() / base.len() as f64;
    (onset..step_s.len())
        .find(|&t| step_s[t] <= baseline * (1.0 + tol))
        .map(|t| t - onset)
}

/// A parsed `--chaos` schedule: zero or more [`Perturbation`]s. The
/// grammar (one event, `+`-join for several; `off` for none):
///
/// ```text
/// straggler:<dev>x<factor>@<start>[-<end>][:flap=<period>]
/// link:<edge>x<factor>@<start>[-<end>]
/// nodeloss:<dev>@<step>
/// drift:<shift>@<start>[-<end>]
/// ```
///
/// Windows are `[start, end)`; an omitted end never closes. `Display`
/// emits the canonical spelling, so parse → format round-trips.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ChaosSpec {
    pub events: Vec<Perturbation>,
}

impl ChaosSpec {
    /// The empty schedule (`off`).
    pub fn off() -> ChaosSpec {
        ChaosSpec::default()
    }

    /// No events scheduled?
    pub fn is_off(&self) -> bool {
        self.events.is_empty()
    }

    /// Check every referenced device/link exists on a `p`-device fabric
    /// with `n_links` physical links.
    pub fn validate(&self, p: usize, n_links: usize) -> Result<(), String> {
        for ev in &self.events {
            match ev.kind {
                PerturbKind::Straggler { dev, factor, .. } => {
                    if dev >= p {
                        return Err(format!("straggler device {dev} >= P={p}"));
                    }
                    if factor < 1.0 {
                        return Err(format!("straggler factor {factor} < 1 speeds a device up"));
                    }
                }
                PerturbKind::LinkDegrade { edge, factor } => {
                    if edge >= n_links {
                        return Err(format!("link {edge} out of range ({n_links} links)"));
                    }
                    if factor <= 0.0 {
                        return Err(format!("link factor {factor} must be positive"));
                    }
                }
                PerturbKind::NodeLoss { dev } => {
                    if dev >= p {
                        return Err(format!("nodeloss device {dev} >= P={p}"));
                    }
                }
                PerturbKind::GateDrift { .. } => {}
            }
        }
        let dead = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, PerturbKind::NodeLoss { .. }))
            .count();
        if dead >= p {
            return Err(format!("{dead} node losses would kill all {p} devices"));
        }
        Ok(())
    }
}

/// `"<start>[-<end>]"` → `(start, end_exclusive)`.
fn parse_window(s: &str) -> Result<(usize, usize), String> {
    let bad = |e: &dyn std::fmt::Display| format!("bad step window {s:?}: {e}");
    match s.split_once('-') {
        None => {
            let start = s.parse::<usize>().map_err(|e| bad(&e))?;
            Ok((start, OPEN_END))
        }
        Some((a, b)) => {
            let start = a.parse::<usize>().map_err(|e| bad(&e))?;
            let end = b.parse::<usize>().map_err(|e| bad(&e))?;
            if end <= start {
                return Err(format!("empty step window {s:?} (end <= start)"));
            }
            Ok((start, end))
        }
    }
}

/// `"<id>x<factor>@<window>"` → `(id, factor, start, end)`.
fn parse_target(s: &str) -> Result<(usize, f64, usize, usize), String> {
    let (head, window) = s
        .split_once('@')
        .ok_or_else(|| format!("missing @<step window> in {s:?}"))?;
    let (id, factor) = head
        .split_once('x')
        .ok_or_else(|| format!("missing x<factor> in {head:?}"))?;
    let id = id.parse::<usize>().map_err(|e| format!("bad id {id:?}: {e}"))?;
    let factor =
        factor.parse::<f64>().map_err(|e| format!("bad factor {factor:?}: {e}"))?;
    let (start, end) = parse_window(window)?;
    Ok((id, factor, start, end))
}

impl std::str::FromStr for ChaosSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<ChaosSpec, String> {
        let s = s.trim();
        if s.is_empty() || s == "off" {
            return Ok(ChaosSpec::off());
        }
        let mut events = Vec::new();
        for part in s.split('+') {
            let part = part.trim();
            let (family, rest) = part
                .split_once(':')
                .ok_or_else(|| format!("chaos event {part:?} has no <family>: prefix"))?;
            let ev = match family {
                "straggler" => {
                    let (body, flap_period) = match rest.rsplit_once(":flap=") {
                        Some((body, n)) => {
                            let period = n
                                .parse::<usize>()
                                .map_err(|e| format!("bad flap period {n:?}: {e}"))?;
                            if period == 0 {
                                return Err("flap period must be >= 1".into());
                            }
                            (body, period)
                        }
                        None => (rest, 0),
                    };
                    let (dev, factor, start_step, end_step) = parse_target(body)?;
                    Perturbation {
                        kind: PerturbKind::Straggler { dev, factor, flap_period },
                        start_step,
                        end_step,
                    }
                }
                "link" => {
                    let (edge, factor, start_step, end_step) = parse_target(rest)?;
                    Perturbation {
                        kind: PerturbKind::LinkDegrade { edge, factor },
                        start_step,
                        end_step,
                    }
                }
                "nodeloss" => {
                    let (dev, step) = rest
                        .split_once('@')
                        .ok_or_else(|| format!("missing @<step> in {rest:?}"))?;
                    let dev =
                        dev.parse::<usize>().map_err(|e| format!("bad device {dev:?}: {e}"))?;
                    let step = step
                        .parse::<usize>()
                        .map_err(|e| format!("bad step {step:?}: {e}"))?;
                    Perturbation {
                        kind: PerturbKind::NodeLoss { dev },
                        start_step: step,
                        end_step: OPEN_END,
                    }
                }
                "drift" => {
                    let (shift, window) = rest
                        .split_once('@')
                        .ok_or_else(|| format!("missing @<step window> in {rest:?}"))?;
                    let shift = shift
                        .parse::<usize>()
                        .map_err(|e| format!("bad shift {shift:?}: {e}"))?;
                    let (start_step, end_step) = parse_window(window)?;
                    Perturbation {
                        kind: PerturbKind::GateDrift { shift },
                        start_step,
                        end_step,
                    }
                }
                other => {
                    return Err(format!(
                        "unknown chaos family {other:?} (known: straggler, link, nodeloss, drift)"
                    ))
                }
            };
            events.push(ev);
        }
        Ok(ChaosSpec { events })
    }
}

impl std::fmt::Display for ChaosSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.events.is_empty() {
            return write!(f, "off");
        }
        let window = |start: usize, end: usize| {
            if end == OPEN_END {
                format!("{start}")
            } else {
                format!("{start}-{end}")
            }
        };
        let parts: Vec<String> = self
            .events
            .iter()
            .map(|ev| match ev.kind {
                PerturbKind::Straggler { dev, factor, flap_period } => {
                    let flap = if flap_period > 0 {
                        format!(":flap={flap_period}")
                    } else {
                        String::new()
                    };
                    format!(
                        "straggler:{dev}x{factor}@{}{flap}",
                        window(ev.start_step, ev.end_step)
                    )
                }
                PerturbKind::LinkDegrade { edge, factor } => {
                    format!("link:{edge}x{factor}@{}", window(ev.start_step, ev.end_step))
                }
                PerturbKind::NodeLoss { dev } => {
                    format!("nodeloss:{dev}@{}", ev.start_step)
                }
                PerturbKind::GateDrift { shift } => {
                    format!("drift:{shift}@{}", window(ev.start_step, ev.end_step))
                }
            })
            .collect();
        write!(f, "{}", parts.join("+"))
    }
}

/// A topology-or-log action firing at one step, returned by
/// [`ChaosEngine::fired`] for the step loop to execute and record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FiredEvent {
    /// Scale link `edge`'s α/β by `factor` (a degradation onset, or its
    /// restore with the reciprocal factor). Bumps the topology epoch.
    LinkScale { edge: usize, factor: f64 },
    /// Device `dev` dies now. Bumps the topology epoch and triggers the
    /// emergency evacuation.
    NodeLoss { dev: usize },
    /// A straggler window opens (log-only: the slowdown itself flows
    /// through [`ChaosEngine::slowdown`] every step).
    StragglerOn { dev: usize, factor: f64 },
    /// A gate-drift window opens (log-only: the shift flows through
    /// [`ChaosEngine::transform_counts`] every step).
    DriftOn { shift: usize },
}

impl std::fmt::Display for FiredEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            FiredEvent::LinkScale { edge, factor } => write!(f, "link:{edge}x{factor}"),
            FiredEvent::NodeLoss { dev } => write!(f, "nodeloss:{dev}"),
            FiredEvent::StragglerOn { dev, factor } => write!(f, "straggler:{dev}x{factor}"),
            FiredEvent::DriftOn { shift } => write!(f, "drift:{shift}"),
        }
    }
}

/// Replays a [`ChaosSpec`] against a step counter. The engine itself is
/// pure bookkeeping — the step loop asks what [`fired`](Self::fired)
/// this step (and executes the topology mutations), pushes the dispatch
/// counts through [`transform_counts`](Self::transform_counts), prices
/// compute under [`slowdown`](Self::slowdown), then
/// [`advance`](Self::advance)s the clock.
#[derive(Clone, Debug)]
pub struct ChaosEngine {
    spec: ChaosSpec,
    step: usize,
}

impl ChaosEngine {
    pub fn new(spec: ChaosSpec) -> ChaosEngine {
        ChaosEngine { spec, step: 0 }
    }

    /// The schedule being replayed.
    pub fn spec(&self) -> &ChaosSpec {
        &self.spec
    }

    /// The current (0-based) step the next queries answer for.
    pub fn step(&self) -> usize {
        self.step
    }

    /// Move to the next step. Call once per priced step, after the
    /// queries.
    pub fn advance(&mut self) {
        self.step += 1;
    }

    /// Actions firing at the current step, in spec order: link scalings
    /// at window boundaries (restore uses the reciprocal factor), node
    /// deaths, and log-only window-open markers for stragglers and
    /// drift.
    pub fn fired(&self) -> Vec<FiredEvent> {
        let mut out = Vec::new();
        for ev in &self.spec.events {
            match ev.kind {
                PerturbKind::LinkDegrade { edge, factor } => {
                    if self.step == ev.start_step {
                        out.push(FiredEvent::LinkScale { edge, factor });
                    }
                    if self.step == ev.end_step {
                        out.push(FiredEvent::LinkScale { edge, factor: 1.0 / factor });
                    }
                }
                PerturbKind::NodeLoss { dev } => {
                    if self.step == ev.start_step {
                        out.push(FiredEvent::NodeLoss { dev });
                    }
                }
                PerturbKind::Straggler { dev, factor, .. } => {
                    if self.step == ev.start_step {
                        out.push(FiredEvent::StragglerOn { dev, factor });
                    }
                }
                PerturbKind::GateDrift { shift } => {
                    if self.step == ev.start_step {
                        out.push(FiredEvent::DriftOn { shift });
                    }
                }
            }
        }
        out
    }

    /// Per-device compute slowdown factors for the current step, or
    /// `None` when every factor is 1 (the clean-path guarantee: a step
    /// with no active straggler prices bit-identically to a run without
    /// the engine). Concurrent stragglers on one device compose
    /// multiplicatively; dead devices are clamped back to 1 (a corpse's
    /// idle dense clock must not become the compute bound).
    pub fn slowdown(&self, topo: &Topology) -> Option<Vec<f64>> {
        let mut s = vec![1.0; topo.p()];
        let mut any = false;
        for ev in &self.spec.events {
            if let PerturbKind::Straggler { dev, factor, flap_period } = ev.kind {
                if straggler_active(self.step, ev.start_step, ev.end_step, flap_period)
                    && topo.is_alive(dev)
                {
                    s[dev] *= factor;
                    any = any || factor != 1.0;
                }
            }
        }
        if any {
            Some(s)
        } else {
            None
        }
    }

    /// Rewrite one step's dispatch counts (tokens, P×N) for the current
    /// step: active gate-drift windows rotate the expert columns, then —
    /// when any device is dead — the elastic re-scale applies: dead
    /// senders' rows drop to zero (the world shrank; survivors keep
    /// their own batch) and each live sender's tokens aimed at
    /// corpse-hosted experts re-gate onto its live-hosted experts,
    /// proportionally to its existing distribution (uniform when it sent
    /// them nothing). Live senders' row sums are conserved. With no
    /// active drift and no corpse the counts are untouched (bit-identity
    /// for the clean path).
    pub fn transform_counts(
        &self,
        counts: &mut Mat,
        topo: &Topology,
        placement: Option<&Placement>,
    ) {
        let p = topo.p();
        let n = counts.cols();
        assert_eq!(counts.rows(), p, "counts rows");
        let shift: usize = self
            .spec
            .events
            .iter()
            .filter_map(|ev| match ev.kind {
                PerturbKind::GateDrift { shift }
                    if self.step >= ev.start_step && self.step < ev.end_step =>
                {
                    Some(shift)
                }
                _ => None,
            })
            .sum();
        if shift % n != 0 {
            let shift = shift % n;
            let old = counts.clone();
            for i in 0..p {
                for e in 0..n {
                    counts.set(i, e, old.get(i, (e + shift) % n));
                }
            }
        }
        if topo.n_alive() == p {
            return;
        }
        let e_per_dev = n / p;
        let host = |e: usize| placement.map_or(e / e_per_dev, |pl| pl.device_of(e));
        let live_cols: Vec<usize> = (0..n).filter(|&e| topo.is_alive(host(e))).collect();
        let dead_cols: Vec<usize> = (0..n).filter(|&e| !topo.is_alive(host(e))).collect();
        assert!(!live_cols.is_empty(), "no live expert host left");
        for i in 0..p {
            if !topo.is_alive(i) {
                for e in 0..n {
                    counts.set(i, e, 0.0);
                }
                continue;
            }
            let stranded: f64 = dead_cols.iter().map(|&e| counts.get(i, e)).sum();
            if stranded > 0.0 {
                let live_sum: f64 = live_cols.iter().map(|&e| counts.get(i, e)).sum();
                if live_sum > 0.0 {
                    for &e in &live_cols {
                        let v = counts.get(i, e);
                        counts.set(i, e, v + stranded * (v / live_sum));
                    }
                } else {
                    let share = stranded / live_cols.len() as f64;
                    for &e in &live_cols {
                        counts.set(i, e, share);
                    }
                }
            }
            for &e in &dead_cols {
                counts.set(i, e, 0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn parse(s: &str) -> ChaosSpec {
        s.parse().unwrap()
    }

    #[test]
    fn spec_parse_display_round_trips() {
        for s in [
            "off",
            "straggler:0x2@10",
            "straggler:3x1.5@10-50",
            "straggler:1x4@0-64:flap=8",
            "link:2x4@16-48",
            "link:0x0.5@5",
            "nodeloss:2@32",
            "drift:1@8-40",
            "straggler:0x2@4-20+link:1x8@10-30+nodeloss:3@16+drift:2@24",
        ] {
            let spec = parse(s);
            assert_eq!(spec.to_string(), s, "canonical display");
            assert_eq!(parse(&spec.to_string()), spec, "round-trip");
        }
        assert_eq!(parse(""), ChaosSpec::off());
        assert!(parse("off").is_off());
        assert!(!parse("nodeloss:0@1").is_off());
    }

    #[test]
    fn spec_rejects_malformed_events() {
        for s in [
            "straggler:0@10",          // missing factor
            "straggler:0x2",           // missing window
            "straggler:0x2@9:flap=0",  // zero flap period
            "link:ax2@3",              // non-numeric edge
            "link:0x2@8-8",            // empty window
            "link:0x2@9-3",            // inverted window
            "nodeloss:1",              // missing step
            "drift:@4",                // missing shift
            "meteor:0@3",              // unknown family
            "straggler",               // no payload
        ] {
            assert!(s.parse::<ChaosSpec>().is_err(), "{s:?} must not parse");
        }
    }

    #[test]
    fn validate_checks_world_bounds() {
        // table1 tree: P=4, 4 device downlinks + 1 uplink = 5 links
        let topo = presets::table1();
        let (p, n_links) = (topo.p(), topo.links().len());
        assert!(parse("straggler:3x2@0").validate(p, n_links).is_ok());
        assert!(parse("straggler:4x2@0").validate(p, n_links).is_err());
        assert!(parse("straggler:0x0.5@0").validate(p, n_links).is_err());
        assert!(parse("link:4x2@0").validate(p, n_links).is_ok());
        assert!(parse("link:5x2@0").validate(p, n_links).is_err());
        assert!(parse("nodeloss:3@1").validate(p, n_links).is_ok());
        assert!(parse("nodeloss:4@1").validate(p, n_links).is_err());
        let all_dead = "nodeloss:0@1+nodeloss:1@2+nodeloss:2@3+nodeloss:3@4";
        assert!(parse(all_dead).validate(p, n_links).is_err());
    }

    #[test]
    fn straggler_active_windows_and_flaps() {
        // constant window [4, 8)
        assert!(!straggler_active(3, 4, 8, 0));
        assert!(straggler_active(4, 4, 8, 0));
        assert!(straggler_active(7, 4, 8, 0));
        assert!(!straggler_active(8, 4, 8, 0));
        // open end
        assert!(straggler_active(1_000_000, 4, OPEN_END, 0));
        // flap period 2 from step 10: on 10-11, off 12-13, on 14-15, …
        for (step, on) in [(10, true), (11, true), (12, false), (13, false), (14, true)] {
            assert_eq!(straggler_active(step, 10, OPEN_END, 2), on, "step {step}");
        }
        // the window still clips the flapping
        assert!(!straggler_active(14, 10, 14, 2));
    }

    #[test]
    fn recovery_steps_finds_the_first_return() {
        let clock = [1.0, 1.0, 1.0, 1.0, 3.0, 2.0, 1.04, 1.0];
        // baseline = 1.0; tol 5% → recovered at t=6 (1.04 <= 1.05)
        assert_eq!(recovery_steps(&clock, 4, 8, 0.05), Some(2));
        // tighter band: only t=7 qualifies
        assert_eq!(recovery_steps(&clock, 4, 8, 0.01), Some(3));
        // instant recovery: onset step already inside the band
        assert_eq!(recovery_steps(&[1.0, 1.0, 1.0], 2, 8, 0.05), Some(0));
        // never returns
        assert_eq!(recovery_steps(&[1.0, 1.0, 5.0, 5.0], 2, 8, 0.05), None);
        // no pre-onset history
        assert_eq!(recovery_steps(&clock, 0, 8, 0.05), None);
        assert_eq!(recovery_steps(&[], 1, 8, 0.05), None);
    }

    #[test]
    fn fired_marks_window_boundaries() {
        let spec = parse("link:1x4@2-5+nodeloss:3@2+straggler:0x2@3+drift:1@4-6");
        let mut eng = ChaosEngine::new(spec);
        assert_eq!(eng.step(), 0);
        assert!(eng.fired().is_empty());
        eng.advance();
        eng.advance();
        assert_eq!(
            eng.fired(),
            vec![
                FiredEvent::LinkScale { edge: 1, factor: 4.0 },
                FiredEvent::NodeLoss { dev: 3 },
            ]
        );
        eng.advance();
        assert_eq!(eng.fired(), vec![FiredEvent::StragglerOn { dev: 0, factor: 2.0 }]);
        eng.advance();
        assert_eq!(eng.fired(), vec![FiredEvent::DriftOn { shift: 1 }]);
        eng.advance();
        // link restore fires at the window end with the reciprocal factor
        assert_eq!(eng.fired(), vec![FiredEvent::LinkScale { edge: 1, factor: 0.25 }]);
        assert_eq!(eng.fired()[0].to_string(), "link:1x0.25");
        eng.advance();
        assert!(eng.fired().is_empty(), "drift close is silent");
    }

    #[test]
    fn slowdown_composes_and_respects_liveness() {
        let mut topo = presets::table1();
        let spec = parse("straggler:1x2@0+straggler:1x3@0-4+straggler:2x1.5@8");
        let mut eng = ChaosEngine::new(spec);
        assert_eq!(eng.slowdown(&topo), Some(vec![1.0, 6.0, 1.0, 1.0]));
        for _ in 0..4 {
            eng.advance();
        }
        assert_eq!(eng.slowdown(&topo), Some(vec![1.0, 2.0, 1.0, 1.0]));
        for _ in 0..4 {
            eng.advance();
        }
        assert_eq!(eng.slowdown(&topo), Some(vec![1.0, 2.0, 1.5, 1.0]));
        // a dead straggler is no straggler
        topo.mark_dead(1);
        assert_eq!(eng.slowdown(&topo), Some(vec![1.0, 1.0, 1.5, 1.0]));
        // no active straggler at all → None, the clean-path guarantee
        let eng = ChaosEngine::new(parse("link:0x2@0"));
        assert_eq!(eng.slowdown(&topo), None);
    }

    #[test]
    fn transform_counts_is_identity_on_the_clean_path() {
        let topo = presets::table1();
        let eng = ChaosEngine::new(parse("straggler:0x2@0+link:0x2@0"));
        let counts = Mat::from_fn(4, 4, |i, e| (i * 4 + e) as f64);
        let mut got = counts.clone();
        eng.transform_counts(&mut got, &topo, None);
        assert_eq!(got.data(), counts.data(), "bit-identical");
    }

    #[test]
    fn drift_rotates_expert_columns_inside_the_window() {
        let topo = presets::table1();
        let mut eng = ChaosEngine::new(parse("drift:1@1-3"));
        let counts = Mat::from_fn(4, 4, |_, e| e as f64);
        let mut got = counts.clone();
        eng.transform_counts(&mut got, &topo, None);
        assert_eq!(got.data(), counts.data(), "inactive before the window");
        eng.advance();
        let mut got = counts.clone();
        eng.transform_counts(&mut got, &topo, None);
        for e in 0..4 {
            assert_eq!(got.get(0, e), ((e + 1) % 4) as f64, "rotated left by 1");
        }
        eng.advance();
        eng.advance();
        let mut got = counts.clone();
        eng.transform_counts(&mut got, &topo, None);
        assert_eq!(got.data(), counts.data(), "inactive after the window");
    }

    #[test]
    fn node_loss_drops_the_corpse_and_conserves_live_rows() {
        let mut topo = presets::table1();
        topo.mark_dead(3);
        let eng = ChaosEngine::new(parse("nodeloss:3@0"));
        let mut counts = Mat::from_fn(4, 4, |_, _| 8.0);
        eng.transform_counts(&mut counts, &topo, None);
        for e in 0..4 {
            assert_eq!(counts.get(3, e), 0.0, "dead sender row dropped");
        }
        for i in 0..3 {
            assert_eq!(counts.get(i, 3), 0.0, "dead-hosted column emptied");
        }
        for i in 0..3 {
            let row: f64 = (0..4).map(|e| counts.get(i, e)).sum();
            assert!((row - 32.0).abs() < 1e-12, "live row {i} conserved: {row}");
            // proportional re-gate of a uniform row stays uniform
            for e in 0..3 {
                assert!((counts.get(i, e) - 32.0 / 3.0).abs() < 1e-12);
            }
        }
        // a sender with zero live-hosted tokens re-gates uniformly
        let mut counts = Mat::zeros(4, 4);
        counts.set(0, 3, 9.0);
        eng.transform_counts(&mut counts, &topo, None);
        for e in 0..3 {
            assert!((counts.get(0, e) - 3.0).abs() < 1e-12);
        }
        assert_eq!(counts.get(0, 3), 0.0);
    }

    #[test]
    fn node_loss_follows_the_placement_map() {
        // expert 3 was evacuated to device 0, expert 0 parked on corpse 3:
        // the dead-hosted column is 0, not 3
        let mut topo = presets::table1();
        topo.mark_dead(3);
        let pl = Placement::from_device_of(vec![3, 1, 2, 0], 4, 1).unwrap();
        let eng = ChaosEngine::new(parse("nodeloss:3@0"));
        let mut counts = Mat::from_fn(4, 4, |_, _| 4.0);
        eng.transform_counts(&mut counts, &topo, Some(&pl));
        assert_eq!(counts.get(0, 0), 0.0, "expert 0 now corpse-hosted");
        assert!(counts.get(0, 3) > 4.0, "expert 3 absorbs re-gated share");
    }
}
