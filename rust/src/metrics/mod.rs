//! Metrics: per-step records, run logs, CSV/JSON emission.
//!
//! Every training/benchmark run accumulates [`StepRecord`]s; [`RunLog`]
//! derives the aggregates the paper reports (throughput in tokens/s on the
//! simulated cluster clock, loss-vs-step and loss-vs-time curves) and
//! writes CSV files the benches print / EXPERIMENTS.md references.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// CSV column header emitted by [`RunLog::write_csv`]: one column per
/// [`StepRecord`] field in declaration order — `wall_s` is skipped (host
/// wall-clock, not reproducible) — plus the derived `sim_t` time axis.
pub const CSV_HEADER: &str = "step,loss,ce,aux,dropped,sim_comm_s,sim_compute_s,\
    a2a_local_s,a2a_intra_s,a2a_inter_s,a2a_exposed_s,serial_s,chunks,\
    plan_hit,migration_s,inflight,admitted,finished,cache_hits,\
    cache_misses,fetch_s,sim_t";

/// Column → [`StepRecord`] field map behind [`CSV_HEADER`], in emission
/// order. `plan_hit` ⇒ `plan_cached` and `sim_t` ⇒ the derived time axis
/// are the two declared aliases; every other column is the field name or
/// the field minus its `sim_` prefix. pallas-lint (units rule) and
/// `csv_schema_matches_struct` cross-check header, schema, struct order,
/// and the actual `write_csv` emission against each other.
pub const CSV_SCHEMA: &[(&str, &str)] = &[
    ("step", "step"),
    ("loss", "loss"),
    ("ce", "ce"),
    ("aux", "aux"),
    ("dropped", "dropped"),
    ("sim_comm_s", "sim_comm_s"),
    ("sim_compute_s", "sim_compute_s"),
    ("a2a_local_s", "sim_a2a_local_s"),
    ("a2a_intra_s", "sim_a2a_intra_s"),
    ("a2a_inter_s", "sim_a2a_inter_s"),
    ("a2a_exposed_s", "sim_a2a_exposed_s"),
    ("serial_s", "sim_serial_s"),
    ("chunks", "chunks"),
    ("plan_hit", "plan_cached"),
    ("migration_s", "sim_migration_s"),
    ("inflight", "inflight"),
    ("admitted", "admitted"),
    ("finished", "finished"),
    ("cache_hits", "cache_hits"),
    ("cache_misses", "cache_misses"),
    ("fetch_s", "sim_fetch_s"),
    ("sim_t", "t"),
];

/// One training step's observables.
#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub ce: f64,
    pub aux: f64,
    pub dropped: f64,
    /// Simulated communication time for the step (α-β model).
    pub sim_comm_s: f64,
    /// Simulated compute time for the step (FLOPs / device_flops).
    pub sim_compute_s: f64,
    /// A2a time in exposed local copies (part of `sim_comm_s`).
    pub sim_a2a_local_s: f64,
    /// A2a time in intra-node phases/rounds (part of `sim_comm_s`).
    pub sim_a2a_intra_s: f64,
    /// A2a time in phases/rounds crossing a node boundary (part of
    /// `sim_comm_s`).
    pub sim_a2a_inter_s: f64,
    /// A2a time not hidden under compute on the overlap timeline
    /// (the whole a2a time for serially-priced steps).
    pub sim_a2a_exposed_s: f64,
    /// The serial upper bound of this step (phases back to back). Equals
    /// `sim_comm_s + sim_compute_s` on serially-priced steps; with
    /// `--overlap` the charged clock is smaller and
    /// `(serial - charged) / serial` is the step's overlap efficiency.
    pub sim_serial_s: f64,
    /// Token chunks the step was pipelined into (1 = serial clock).
    pub chunks: usize,
    /// Whether this step's a2a schedule came from the session's
    /// `PlanCache` (true = hit) rather than a fresh synthesis.
    pub plan_cached: bool,
    /// Simulated time spent migrating expert weights this step (0 for the
    /// overwhelming majority of steps; charged by the placement engine).
    pub sim_migration_s: f64,
    /// Host wall-clock spent executing the XLA step (not simulated).
    pub wall_s: f64,
    /// Sequences in flight this iteration (serving runs; 0 in training).
    pub inflight: usize,
    /// Sequences admitted from the arrival queue this iteration.
    pub admitted: usize,
    /// Sequences that emitted their last token this iteration.
    pub finished: usize,
    /// Expert-weight cache hits this iteration (serving runs).
    pub cache_hits: usize,
    /// Expert-weight cache misses this iteration (serving runs).
    pub cache_misses: usize,
    /// Simulated time fetching missed expert weights over the links,
    /// charged to this iteration's clock (serving runs).
    pub sim_fetch_s: f64,
}

impl StepRecord {
    pub fn sim_total_s(&self) -> f64 {
        self.sim_comm_s + self.sim_compute_s + self.sim_migration_s + self.sim_fetch_s
    }
}

/// One served request's lifecycle on the simulated clock (serving runs).
#[derive(Clone, Debug, Default)]
pub struct RequestRecord {
    /// Arrival order (the trace index).
    pub id: usize,
    /// Arrival time on the simulated clock.
    pub arrival_s: f64,
    /// When the first output token was emitted (end of the prefill
    /// iteration).
    pub first_token_s: f64,
    /// When the last output token was emitted.
    pub finish_s: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

impl RequestRecord {
    /// Time to first token: queueing + prefill.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// Mean time per output token after the first.
    pub fn tpot_s(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish_s - self.first_token_s) / (self.output_tokens - 1) as f64
    }
}

/// Exact nearest-rank percentile via quickselect (no full sort): the
/// `ceil(q/100 · n)`-th smallest sample, `q` clamped to [0, 100]. `None`
/// on an empty slice. Property-tested against the naive sort oracle in
/// `rust/tests/prop_serve.rs`.
pub fn percentile(xs: &[f64], q: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len();
    let q = q.clamp(0.0, 100.0);
    let rank = ((q / 100.0 * n as f64).ceil() as usize).clamp(1, n);
    let mut v = xs.to_vec();
    Some(quickselect(&mut v, rank - 1))
}

/// In-place quickselect of the `k`-th smallest (0-based), median-of-three
/// pivot (deterministic — no RNG in the metrics path).
fn quickselect(v: &mut [f64], k: usize) -> f64 {
    let (mut lo, mut hi) = (0usize, v.len() - 1);
    loop {
        if lo == hi {
            return v[lo];
        }
        // median-of-three pivot, moved to hi
        let mid = lo + (hi - lo) / 2;
        if v[mid] < v[lo] {
            v.swap(mid, lo);
        }
        if v[hi] < v[lo] {
            v.swap(hi, lo);
        }
        if v[hi] < v[mid] {
            v.swap(hi, mid);
        }
        v.swap(mid, hi);
        let pivot = v[hi];
        let mut store = lo;
        for i in lo..hi {
            if v[i] < pivot {
                v.swap(i, store);
                store += 1;
            }
        }
        v.swap(store, hi);
        match k.cmp(&store) {
            std::cmp::Ordering::Equal => return v[store],
            std::cmp::Ordering::Less => hi = store - 1,
            std::cmp::Ordering::Greater => lo = store + 1,
        }
    }
}

/// One accepted expert migration, as the run log records it: what moved,
/// what the move cost on the cluster clock, and the per-step savings the
/// amortisation decision predicted vs what the live counts realised.
#[derive(Clone, Debug, Default)]
pub struct MigrationRecord {
    /// Training step the migration happened on.
    pub step: usize,
    /// Number of experts whose host changed.
    pub moved: usize,
    /// Expert-weight bytes moved over the links.
    pub bytes: f64,
    /// One-off migration time charged to the step clock.
    pub cost_s: f64,
    /// Predicted per-step a2a saving (on the EWMA load estimate).
    pub predicted_saving_s: f64,
    /// Per-step saving re-priced on the deciding step's live counts.
    pub realized_saving_s: f64,
}

/// One fault-stream event as the run log records it: which step it fired
/// on and its canonical spec spelling (see [`crate::perturb`]).
#[derive(Clone, Debug, Default)]
pub struct PerturbationRecord {
    /// Step (0-based record index) the event fired on.
    pub step: usize,
    /// Canonical event string, e.g. `straggler:1x2` or `nodeloss:3`.
    pub event: String,
}

/// A labelled sequence of step records (+ optional eval points).
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub label: String,
    pub records: Vec<StepRecord>,
    /// (completed training steps at eval time, validation loss) points.
    /// 0 completed steps = an eval before any training.
    pub evals: Vec<(usize, f64)>,
    /// Tokens processed per step across the whole cluster.
    pub tokens_per_step: usize,
    /// `PlanCache` schedule re-uses over the run (see `coordinator::cost`).
    pub plan_hits: u64,
    /// `PlanCache` cold schedule syntheses over the run.
    pub plan_misses: u64,
    /// Accepted expert migrations, in step order (placement engine).
    pub migrations: Vec<MigrationRecord>,
    /// Fault-stream events that fired, in step order (perturbation
    /// engine; empty on clean runs).
    pub perturbations: Vec<PerturbationRecord>,
    /// Completed requests, in finish order (serving runs).
    pub requests: Vec<RequestRecord>,
    /// Expert-weight cache hits over the run (serving runs).
    pub cache_hits: u64,
    /// Expert-weight cache misses over the run (serving runs).
    pub cache_misses: u64,
}

impl RunLog {
    pub fn new(label: &str, tokens_per_step: usize) -> RunLog {
        RunLog { label: label.to_string(), tokens_per_step, ..Default::default() }
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    /// Record a validation loss measured after `steps_done` completed
    /// training steps (0 = before any training).
    pub fn push_eval(&mut self, steps_done: usize, loss: f64) {
        self.evals.push((steps_done, loss));
    }

    /// Simulated cluster time elapsed up to (and including) each step.
    pub fn sim_time_axis(&self) -> Vec<f64> {
        let mut t = 0.0;
        self.records
            .iter()
            .map(|r| {
                t += r.sim_total_s();
                t
            })
            .collect()
    }

    /// Mean simulated throughput (tokens/s) over the run.
    pub fn sim_throughput(&self) -> f64 {
        let total: f64 = self.records.iter().map(|r| r.sim_total_s()).sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.tokens_per_step as f64 * self.records.len() as f64 / total
    }

    /// Simulated time to first reach a validation loss ≤ `target`.
    /// Linear scan over eval points against the sim clock; an eval before
    /// any training sits at t = 0.
    pub fn sim_time_to_loss(&self, target: f64) -> Option<f64> {
        let axis = self.sim_time_axis();
        for &(steps_done, loss) in &self.evals {
            if loss <= target {
                if steps_done == 0 || axis.is_empty() {
                    return Some(0.0);
                }
                return Some(axis[(steps_done - 1).min(axis.len() - 1)]);
            }
        }
        None
    }

    /// Mean of the last `n` training losses (converged-loss estimate).
    /// 0 on an empty log, so `summary_json` never emits NaN (and the
    /// slice below never underflows).
    pub fn tail_loss(&self, n: usize) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let k = self.records.len().min(n).max(1);
        let s: f64 = self.records[self.records.len() - k..]
            .iter()
            .map(|r| r.ce)
            .sum();
        s / k as f64
    }

    /// Record an accepted expert migration.
    pub fn push_migration(&mut self, m: MigrationRecord) {
        self.migrations.push(m);
    }

    /// Record one fault-stream event.
    pub fn push_perturbation(&mut self, p: PerturbationRecord) {
        self.perturbations.push(p);
    }

    /// Step the first fault fired on (`None` on clean runs).
    pub fn first_perturbation_step(&self) -> Option<usize> {
        self.perturbations.first().map(|p| p.step)
    }

    /// Devices the fault stream killed (`nodeloss:<dev>` perturbation
    /// records), in firing order, deduplicated — the exclusion list
    /// [`crate::trace::utilization`] takes so corpses don't poison the
    /// straggler skew.
    pub fn dead_devices(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for p in &self.perturbations {
            if let Some(dev) = p.event.strip_prefix("nodeloss:").and_then(|d| d.parse().ok()) {
                if !out.contains(&dev) {
                    out.push(dev);
                }
            }
        }
        out
    }

    /// Steps from the first fault's onset until the per-step clock
    /// (including migration/fetch spikes) first returns within
    /// [`crate::perturb::RECOVERY_TOL`] of the mean of the
    /// [`crate::perturb::RECOVERY_WINDOW`] pre-onset steps. `None` on a
    /// clean run, when the fault fired on step 0 (no baseline), or when
    /// the clock never comes back inside the band.
    pub fn recovery_steps(&self) -> Option<usize> {
        let onset = self.first_perturbation_step()?;
        let step_s: Vec<f64> = self.records.iter().map(|r| r.sim_total_s()).collect();
        crate::perturb::recovery_steps(
            &step_s,
            onset,
            crate::perturb::RECOVERY_WINDOW,
            crate::perturb::RECOVERY_TOL,
        )
    }

    /// Total expert-weight bytes moved by migrations over the run.
    pub fn migration_bytes(&self) -> f64 {
        self.migrations.iter().map(|m| m.bytes).sum()
    }

    /// Summed per-step savings accounting over all migrations:
    /// `(predicted_s, realized_s)`.
    pub fn migration_savings(&self) -> (f64, f64) {
        self.migrations.iter().fold((0.0, 0.0), |(p, r), m| {
            (p + m.predicted_saving_s, r + m.realized_saving_s)
        })
    }

    /// Total serial upper bound over the run (the clock the run would
    /// have been charged without overlap; migration time excluded).
    pub fn sim_serial_total(&self) -> f64 {
        self.records.iter().map(|r| r.sim_serial_s).sum()
    }

    /// Fraction of the serial clock the overlap engine hid over the run:
    /// `(serial − charged) / serial`, with the charged clock being
    /// `sim_comm_s + sim_compute_s` per step (migration time excluded
    /// from both sides). ~0 for serial runs; negative when a forced
    /// chunk count re-pays more latency than it overlaps.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.sim_serial_total();
        if serial <= 0.0 {
            return 0.0;
        }
        let charged: f64 =
            self.records.iter().map(|r| r.sim_comm_s + r.sim_compute_s).sum();
        (serial - charged) / serial
    }

    /// Total a2a time left exposed (not hidden under compute) over the
    /// run.
    pub fn a2a_exposed_total(&self) -> f64 {
        self.records.iter().map(|r| r.sim_a2a_exposed_s).sum()
    }

    /// Accumulated per-phase a2a split over the run:
    /// `(local_s, intra_s, inter_s)` — the fig6-style "where does the
    /// communication time go" series.
    pub fn a2a_phase_totals(&self) -> (f64, f64, f64) {
        self.records.iter().fold((0.0, 0.0, 0.0), |(l, a, e), r| {
            (
                l + r.sim_a2a_local_s,
                a + r.sim_a2a_intra_s,
                e + r.sim_a2a_inter_s,
            )
        })
    }

    /// Record one completed request (serving runs).
    pub fn push_request(&mut self, r: RequestRecord) {
        self.requests.push(r);
    }

    /// Nearest-rank percentile of time-to-first-token over completed
    /// requests (`None` before any completed).
    pub fn ttft_percentile(&self, q: f64) -> Option<f64> {
        let xs: Vec<f64> = self.requests.iter().map(|r| r.ttft_s()).collect();
        percentile(&xs, q)
    }

    /// Nearest-rank percentile of mean per-output-token latency over
    /// completed requests with ≥ 2 output tokens.
    pub fn tpot_percentile(&self, q: f64) -> Option<f64> {
        let xs: Vec<f64> =
            self.requests.iter().filter(|r| r.output_tokens > 1).map(|r| r.tpot_s()).collect();
        percentile(&xs, q)
    }

    /// Goodput under a TTFT deadline: output tokens/s counting only
    /// completed requests whose first token met `slo_s`, over the
    /// simulated clock.
    pub fn goodput(&self, slo_s: f64) -> f64 {
        let total = self.sim_time_axis().last().copied().unwrap_or(0.0);
        if total <= 0.0 {
            return 0.0;
        }
        let good: usize = self
            .requests
            .iter()
            .filter(|r| r.ttft_s() <= slo_s)
            .map(|r| r.output_tokens)
            .sum();
        good as f64 / total
    }

    /// Expert-weight cache hit rate over the run (0 when the run never
    /// touched a cache, i.e. every training run).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / total as f64
    }

    /// Write the [`CSV_HEADER`] columns (the serve columns are zero on
    /// training runs). The column↔field map is pinned by [`CSV_SCHEMA`]
    /// and cross-checked by pallas-lint and `csv_schema_matches_struct`.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{CSV_HEADER}")?;
        let axis = self.sim_time_axis();
        for (r, t) in self.records.iter().zip(axis) {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{:.4},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{},{},{:.6e},{},{},{},{},{},{:.6e},{:.6e}",
                r.step,
                r.loss,
                r.ce,
                r.aux,
                r.dropped,
                r.sim_comm_s,
                r.sim_compute_s,
                r.sim_a2a_local_s,
                r.sim_a2a_intra_s,
                r.sim_a2a_inter_s,
                r.sim_a2a_exposed_s,
                r.sim_serial_s,
                r.chunks,
                r.plan_cached as u8,
                r.sim_migration_s,
                r.inflight,
                r.admitted,
                r.finished,
                r.cache_hits,
                r.cache_misses,
                r.sim_fetch_s,
                t
            )?;
        }
        Ok(())
    }

    /// JSON summary used by benches.
    pub fn summary_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("steps".into(), Json::Num(self.records.len() as f64));
        m.insert("throughput_tok_s".into(), Json::Num(self.sim_throughput()));
        m.insert("tail_ce".into(), Json::Num(self.tail_loss(20)));
        let comm: f64 = self.records.iter().map(|r| r.sim_comm_s).sum();
        let comp: f64 = self.records.iter().map(|r| r.sim_compute_s).sum();
        m.insert("sim_comm_s".into(), Json::Num(comm));
        m.insert("sim_compute_s".into(), Json::Num(comp));
        let (local, intra, inter) = self.a2a_phase_totals();
        m.insert("sim_a2a_local_s".into(), Json::Num(local));
        m.insert("sim_a2a_intra_s".into(), Json::Num(intra));
        m.insert("sim_a2a_inter_s".into(), Json::Num(inter));
        m.insert("sim_serial_s".into(), Json::Num(self.sim_serial_total()));
        m.insert("sim_a2a_exposed_s".into(), Json::Num(self.a2a_exposed_total()));
        m.insert("overlap_efficiency".into(), Json::Num(self.overlap_efficiency()));
        let max_chunks = self.records.iter().map(|r| r.chunks).max().unwrap_or(0);
        m.insert("chunks_max".into(), Json::Num(max_chunks as f64));
        m.insert("plan_hits".into(), Json::Num(self.plan_hits as f64));
        m.insert("plan_misses".into(), Json::Num(self.plan_misses as f64));
        m.insert("migrations".into(), Json::Num(self.migrations.len() as f64));
        m.insert("migration_bytes".into(), Json::Num(self.migration_bytes()));
        let mig_s: f64 = self.records.iter().map(|r| r.sim_migration_s).sum();
        m.insert("migration_s".into(), Json::Num(mig_s));
        let (pred, real) = self.migration_savings();
        m.insert("migration_predicted_saving_s".into(), Json::Num(pred));
        m.insert("migration_realized_saving_s".into(), Json::Num(real));
        // chaos keys only when faults actually fired: a `--chaos off` run
        // stays byte-identical to one without the engine at all
        if !self.perturbations.is_empty() {
            m.insert("perturbations".into(), Json::Num(self.perturbations.len() as f64));
            m.insert(
                "first_perturb_step".into(),
                Json::Num(self.first_perturbation_step().unwrap_or(0) as f64),
            );
            // -1 encodes "never recovered" (and "no pre-fault baseline")
            let recovery = self.recovery_steps().map_or(-1.0, |r| r as f64);
            m.insert("recovery_steps".into(), Json::Num(recovery));
        }
        if !self.requests.is_empty() || self.cache_hits + self.cache_misses > 0 {
            m.insert("requests".into(), Json::Num(self.requests.len() as f64));
            m.insert("ttft_p50_s".into(), Json::Num(self.ttft_percentile(50.0).unwrap_or(0.0)));
            m.insert("ttft_p99_s".into(), Json::Num(self.ttft_percentile(99.0).unwrap_or(0.0)));
            m.insert("tpot_p50_s".into(), Json::Num(self.tpot_percentile(50.0).unwrap_or(0.0)));
            m.insert("tpot_p99_s".into(), Json::Num(self.tpot_percentile(99.0).unwrap_or(0.0)));
            m.insert("cache_hits".into(), Json::Num(self.cache_hits as f64));
            m.insert("cache_misses".into(), Json::Num(self.cache_misses as f64));
            m.insert("cache_hit_rate".into(), Json::Num(self.cache_hit_rate()));
            let fetch: f64 = self.records.iter().map(|r| r.sim_fetch_s).sum();
            m.insert("fetch_s".into(), Json::Num(fetch));
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, ce: f64, comm: f64, comp: f64) -> StepRecord {
        StepRecord {
            step,
            loss: ce,
            ce,
            sim_comm_s: comm,
            sim_compute_s: comp,
            ..Default::default()
        }
    }

    #[test]
    fn throughput_uses_sim_clock() {
        let mut log = RunLog::new("x", 1000);
        log.push(rec(0, 5.0, 0.5, 0.5));
        log.push(rec(1, 4.0, 0.5, 0.5));
        assert!((log.sim_throughput() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn time_axis_accumulates() {
        let mut log = RunLog::new("x", 10);
        log.push(rec(0, 5.0, 1.0, 0.0));
        log.push(rec(1, 4.0, 2.0, 0.0));
        assert_eq!(log.sim_time_axis(), vec![1.0, 3.0]);
    }

    #[test]
    fn time_to_loss_finds_first_crossing() {
        let mut log = RunLog::new("x", 10);
        for i in 0..10 {
            log.push(rec(i, 5.0 - i as f64 * 0.5, 1.0, 0.0));
        }
        log.push_eval(3, 4.2);
        log.push_eval(6, 3.0);
        log.push_eval(9, 2.0);
        let t = log.sim_time_to_loss(3.0).unwrap();
        assert_eq!(t, 6.0); // after 6 completed steps → 6 s of sim time
        assert!(log.sim_time_to_loss(0.1).is_none());
    }

    #[test]
    fn eval_before_training_sits_at_time_zero() {
        let mut log = RunLog::new("x", 10);
        log.push_eval(0, 1.0); // before any training step
        log.push(rec(0, 5.0, 1.0, 0.0));
        assert_eq!(log.sim_time_to_loss(1.5), Some(0.0));
    }

    #[test]
    fn a2a_phase_totals_accumulate() {
        let mut log = RunLog::new("x", 10);
        for i in 0..3 {
            log.push(StepRecord {
                step: i,
                sim_a2a_local_s: 0.1,
                sim_a2a_intra_s: 0.2,
                sim_a2a_inter_s: 0.7,
                ..Default::default()
            });
        }
        let (l, a, e) = log.a2a_phase_totals();
        assert!((l - 0.3).abs() < 1e-12);
        assert!((a - 0.6).abs() < 1e-12);
        assert!((e - 2.1).abs() < 1e-12);
    }

    #[test]
    fn tail_loss_averages_last_n() {
        let mut log = RunLog::new("x", 10);
        for i in 0..10 {
            log.push(rec(i, i as f64, 0.0, 1.0));
        }
        assert!((log.tail_loss(2) - 8.5).abs() < 1e-12);
        assert!((log.tail_loss(100) - 4.5).abs() < 1e-12);
    }

    #[test]
    fn empty_run_summary_emits_finite_numbers() {
        // zero-step runs (e.g. a serve trace whose first arrival never
        // fits the horizon) must not panic or divide by zero anywhere
        let log = RunLog::new("empty", 0);
        assert_eq!(log.tail_loss(20), 0.0);
        assert_eq!(log.sim_throughput(), 0.0);
        assert_eq!(log.overlap_efficiency(), 0.0);
        assert_eq!(log.cache_hit_rate(), 0.0);
        assert_eq!(log.goodput(1.0), 0.0);
        let json = log.summary_json().to_string_compact();
        assert!(!json.to_ascii_lowercase().contains("nan"), "{json}");
        assert!(!json.to_ascii_lowercase().contains("inf"), "{json}");
    }

    #[test]
    fn csv_round_trip_smoke() {
        let mut log = RunLog::new("x", 10);
        log.push(rec(0, 1.0, 0.1, 0.2));
        let path = std::env::temp_dir().join("ta_moe_test_metrics.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,loss"));
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn migration_accounting_surfaces_everywhere() {
        let mut log = RunLog::new("x", 10);
        log.push(StepRecord {
            step: 0,
            sim_comm_s: 1.0,
            sim_compute_s: 1.0,
            sim_migration_s: 0.5,
            ..Default::default()
        });
        log.push(StepRecord { step: 1, sim_comm_s: 1.0, sim_compute_s: 1.0, ..Default::default() });
        log.push_migration(MigrationRecord {
            step: 0,
            moved: 2,
            bytes: 2048.0,
            cost_s: 0.5,
            predicted_saving_s: 0.1,
            realized_saving_s: 0.08,
        });
        // the migration is charged to the step clock
        assert_eq!(log.records[0].sim_total_s(), 2.5);
        assert_eq!(log.sim_time_axis(), vec![2.5, 4.5]);
        assert_eq!(log.migration_bytes(), 2048.0);
        let (p, r) = log.migration_savings();
        assert!((p - 0.1).abs() < 1e-12 && (r - 0.08).abs() < 1e-12);
        let json = log.summary_json().to_string_compact();
        assert!(json.contains("\"migrations\":1"), "{json}");
        assert!(json.contains("\"migration_bytes\":2048"), "{json}");
        let path = std::env::temp_dir().join("ta_moe_test_metrics_migration.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        let col = header.split(',').position(|c| c == "migration_s").unwrap();
        let row0: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row0[col], "5.000000e-1");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_schema_matches_struct() {
        // Sentinel record: every emitted field carries a distinct value,
        // so each CSV cell can be traced back to the exact field
        // CSV_SCHEMA claims that column prints. Catches silent column ↔
        // field drift that format-string reordering would introduce.
        let rec = StepRecord {
            step: 1,
            loss: 2.0,
            ce: 3.0,
            aux: 4.0,
            dropped: 5.0,
            sim_comm_s: 6.0,
            sim_compute_s: 7.0,
            sim_a2a_local_s: 8.0,
            sim_a2a_intra_s: 9.0,
            sim_a2a_inter_s: 10.0,
            sim_a2a_exposed_s: 11.0,
            sim_serial_s: 12.0,
            chunks: 13,
            plan_cached: true,
            sim_migration_s: 15.0,
            wall_s: 99.0, // host wall-clock: deliberately absent from the CSV
            inflight: 16,
            admitted: 17,
            finished: 18,
            cache_hits: 19,
            cache_misses: 20,
            sim_fetch_s: 21.0,
        };
        let sim_t = rec.sim_total_s(); // 6 + 7 + 15 + 21
        let mut log = RunLog::new("schema", 0);
        log.push(rec);
        let path = std::env::temp_dir().join("ta_moe_test_metrics_schema.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header: Vec<&str> = text.lines().next().unwrap().split(',').collect();
        let row: Vec<f64> = text
            .lines()
            .nth(1)
            .unwrap()
            .split(',')
            .map(|v| v.parse().unwrap())
            .collect();
        let _ = std::fs::remove_file(&path);

        let schema_cols: Vec<&str> = CSV_SCHEMA.iter().map(|(c, _)| *c).collect();
        assert_eq!(header, schema_cols, "header must be CSV_SCHEMA's columns");
        assert_eq!(row.len(), header.len());
        let want = [
            ("step", 1.0),
            ("loss", 2.0),
            ("ce", 3.0),
            ("aux", 4.0),
            ("dropped", 5.0),
            ("sim_comm_s", 6.0),
            ("sim_compute_s", 7.0),
            ("a2a_local_s", 8.0),
            ("a2a_intra_s", 9.0),
            ("a2a_inter_s", 10.0),
            ("a2a_exposed_s", 11.0),
            ("serial_s", 12.0),
            ("chunks", 13.0),
            ("plan_hit", 1.0),
            ("migration_s", 15.0),
            ("inflight", 16.0),
            ("admitted", 17.0),
            ("finished", 18.0),
            ("cache_hits", 19.0),
            ("cache_misses", 20.0),
            ("fetch_s", 21.0),
            ("sim_t", sim_t),
        ];
        assert_eq!(want.len(), header.len());
        for (col, v) in want {
            let i = header.iter().position(|c| *c == col).unwrap();
            assert!(
                (row[i] - v).abs() < 1e-9,
                "column {col}: csv {} != field sentinel {v}",
                row[i]
            );
        }
        // unit suffixes: every seconds column says so
        for (col, field) in CSV_SCHEMA {
            if field.ends_with("_s") && *col != "sim_t" {
                assert!(col.ends_with("_s"), "{col} drops the _s suffix");
            }
        }
    }

    #[test]
    fn overlap_accounting_surfaces_in_summary_and_csv() {
        let mut log = RunLog::new("x", 10);
        // an overlapped step: serial bound 3.0, charged clock 2.0
        log.push(StepRecord {
            step: 0,
            sim_comm_s: 1.0, // exposed comm on the timeline
            sim_compute_s: 1.0,
            sim_serial_s: 3.0,
            sim_a2a_exposed_s: 0.6,
            chunks: 4,
            ..Default::default()
        });
        // and a serially-priced one: no hiding
        log.push(StepRecord {
            step: 1,
            sim_comm_s: 2.0,
            sim_compute_s: 1.0,
            sim_serial_s: 3.0,
            sim_a2a_exposed_s: 1.5,
            chunks: 1,
            ..Default::default()
        });
        assert_eq!(log.sim_serial_total(), 6.0);
        // charged 2 + 3 = 5 of a 6 s serial bound → 1/6 hidden
        assert!((log.overlap_efficiency() - 1.0 / 6.0).abs() < 1e-12);
        assert!((log.a2a_exposed_total() - 2.1).abs() < 1e-12);
        let json = log.summary_json().to_string_compact();
        assert!(json.contains("\"sim_serial_s\":6"), "{json}");
        assert!(json.contains("\"chunks_max\":4"), "{json}");
        assert!(json.contains("\"overlap_efficiency\":"), "{json}");
        let path = std::env::temp_dir().join("ta_moe_test_metrics_overlap.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        for col in ["a2a_exposed_s", "serial_s", "chunks"] {
            assert!(header.split(',').any(|c| c == col), "{header}");
        }
        let chunks_col = header.split(',').position(|c| c == "chunks").unwrap();
        let row0: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row0[chunks_col], "4");
        let serial_col = header.split(',').position(|c| c == "serial_s").unwrap();
        assert_eq!(row0[serial_col], "3.000000e0");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn percentile_matches_sort_oracle_on_small_samples() {
        let xs = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6, 5.0];
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let rank = ((q / 100.0 * xs.len() as f64).ceil() as usize).clamp(1, xs.len());
            assert_eq!(percentile(&xs, q), Some(sorted[rank - 1]), "q={q}");
        }
        assert_eq!(percentile(&[], 50.0), None);
        assert_eq!(percentile(&[7.0], 99.0), Some(7.0));
    }

    #[test]
    fn request_latency_accounting() {
        let r = RequestRecord {
            id: 0,
            arrival_s: 1.0,
            first_token_s: 1.5,
            finish_s: 3.5,
            prompt_tokens: 8,
            output_tokens: 5,
        };
        assert!((r.ttft_s() - 0.5).abs() < 1e-12);
        assert!((r.tpot_s() - 0.5).abs() < 1e-12); // 2.0 s / 4 tokens
    }

    #[test]
    fn goodput_counts_only_requests_meeting_the_slo() {
        let mut log = RunLog::new("serve", 0);
        log.push(StepRecord { step: 0, sim_compute_s: 10.0, ..Default::default() });
        log.push_request(RequestRecord {
            id: 0,
            arrival_s: 0.0,
            first_token_s: 0.1,
            finish_s: 1.0,
            prompt_tokens: 4,
            output_tokens: 20,
        });
        log.push_request(RequestRecord {
            id: 1,
            arrival_s: 0.0,
            first_token_s: 5.0, // misses a 1 s TTFT deadline
            finish_s: 9.0,
            prompt_tokens: 4,
            output_tokens: 30,
        });
        assert!((log.goodput(1.0) - 2.0).abs() < 1e-12); // 20 tokens / 10 s
        assert!((log.goodput(10.0) - 5.0).abs() < 1e-12); // all 50 tokens
        assert_eq!(log.ttft_percentile(50.0), Some(0.1));
        assert_eq!(log.ttft_percentile(99.0), Some(5.0));
    }

    #[test]
    fn serve_columns_and_summary_keys_surface() {
        let mut log = RunLog::new("serve", 0);
        log.cache_hits = 9;
        log.cache_misses = 1;
        log.push(StepRecord {
            step: 0,
            inflight: 3,
            admitted: 2,
            finished: 1,
            cache_hits: 9,
            cache_misses: 1,
            sim_fetch_s: 0.25,
            sim_compute_s: 1.0,
            ..Default::default()
        });
        // fetch time is charged to the step clock
        assert!((log.records[0].sim_total_s() - 1.25).abs() < 1e-12);
        assert!((log.cache_hit_rate() - 0.9).abs() < 1e-12);
        let json = log.summary_json().to_string_compact();
        for key in ["cache_hit_rate", "ttft_p99_s", "tpot_p50_s", "fetch_s", "requests"] {
            assert!(json.contains(&format!("\"{key}\":")), "{key} missing: {json}");
        }
        let path = std::env::temp_dir().join("ta_moe_test_metrics_serve.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        let row0: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        for (col, want) in
            [("inflight", "3"), ("admitted", "2"), ("finished", "1"), ("cache_hits", "9")]
        {
            let i = header.split(',').position(|c| c == col).unwrap();
            assert_eq!(row0[i], want, "{col}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn training_summaries_omit_serve_keys() {
        let mut log = RunLog::new("train", 10);
        log.push(rec(0, 1.0, 0.1, 0.2));
        let json = log.summary_json().to_string_compact();
        assert!(!json.contains("cache_hit_rate"), "{json}");
        assert!(!json.contains("ttft_p99_s"), "{json}");
    }

    #[test]
    fn clean_summaries_omit_chaos_keys() {
        let mut log = RunLog::new("clean", 10);
        log.push(rec(0, 1.0, 0.1, 0.2));
        let json = log.summary_json().to_string_compact();
        assert!(!json.contains("perturbations"), "{json}");
        assert!(!json.contains("recovery_steps"), "{json}");
    }

    #[test]
    fn perturbation_accounting_and_recovery_surface() {
        let mut log = RunLog::new("chaos", 10);
        // 4 steady steps at 1.0 s, a fault spikes steps 4-5, back by 6
        for (i, s) in [1.0, 1.0, 1.0, 1.0, 3.0, 2.0, 1.02, 1.0].iter().enumerate() {
            log.push(StepRecord { step: i, sim_compute_s: *s, ..Default::default() });
        }
        log.push_perturbation(PerturbationRecord { step: 4, event: "straggler:1x3".into() });
        log.push_perturbation(PerturbationRecord { step: 9, event: "link:0x2".into() });
        assert_eq!(log.first_perturbation_step(), Some(4));
        assert_eq!(log.recovery_steps(), Some(2));
        let json = log.summary_json().to_string_compact();
        assert!(json.contains("\"perturbations\":2"), "{json}");
        assert!(json.contains("\"first_perturb_step\":4"), "{json}");
        assert!(json.contains("\"recovery_steps\":2"), "{json}");
        // an unrecovered run reports -1
        let mut stuck = RunLog::new("stuck", 10);
        for (i, s) in [1.0, 1.0, 5.0, 5.0].iter().enumerate() {
            stuck.push(StepRecord { step: i, sim_compute_s: *s, ..Default::default() });
        }
        stuck.push_perturbation(PerturbationRecord { step: 2, event: "nodeloss:1".into() });
        assert_eq!(stuck.recovery_steps(), None);
        let json = stuck.summary_json().to_string_compact();
        assert!(json.contains("\"recovery_steps\":-1"), "{json}");
        // the CSV schema is untouched: no chaos columns
        let path = std::env::temp_dir().join("ta_moe_test_metrics_chaos.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.lines().next().unwrap().contains("perturb"), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn plan_cache_counters_surface_in_summary_and_csv() {
        let mut log = RunLog::new("x", 10);
        log.plan_hits = 7;
        log.plan_misses = 3;
        log.push(StepRecord { step: 0, plan_cached: true, ..Default::default() });
        log.push(StepRecord { step: 1, plan_cached: false, ..Default::default() });
        let json = log.summary_json().to_string_compact();
        assert!(json.contains("\"plan_hits\":7"), "{json}");
        assert!(json.contains("\"plan_misses\":3"), "{json}");
        let path = std::env::temp_dir().join("ta_moe_test_metrics_cache.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        assert!(header.contains("plan_hit"), "{header}");
        let hit_col = header.split(',').position(|c| c == "plan_hit").unwrap();
        let cols: Vec<&str> = text.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(cols[hit_col], "1");
        let cols: Vec<&str> = text.lines().nth(2).unwrap().split(',').collect();
        assert_eq!(cols[hit_col], "0");
        let _ = std::fs::remove_file(&path);
    }
}
