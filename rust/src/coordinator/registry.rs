//! The dispatch-policy registry: name → policy factory.
//!
//! `Strategy::parse` used to be a closed `match`; the registry makes the
//! name space open. A spec string is `key[:arg[:arg...]]` — the key picks
//! a factory, the remaining `:`-separated parts are passed to it, and the
//! factory must consume *all* of them (trailing garbage like
//! `ta-moe:softmax:2.0:junk` or `fastermoe:notanumber` is an error, not a
//! silent default). The four paper systems are pre-registered; downstream
//! code adds its own with [`register_policy`] and can then select it by
//! name everywhere a builtin works — configs, the CLI, bench arms:
//!
//! ```
//! use ta_moe::coordinator::{register_policy, parse_policy, DispatchPolicy, PolicyInputs};
//! # use ta_moe::runtime::ModelCfg;
//! # use ta_moe::topology::Topology;
//! # use ta_moe::util::Mat;
//! #[derive(Debug)]
//! struct Everywhere;
//! impl DispatchPolicy for Everywhere {
//!     fn name(&self) -> String { "everywhere".into() }
//!     fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
//!         ta_moe::coordinator::FastMoeEven.runtime_inputs(topo, cfg)
//!     }
//!     fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> Mat {
//!         ta_moe::coordinator::FastMoeEven.converged_counts(topo, cfg)
//!     }
//! }
//! fn make(args: &[&str]) -> Result<Box<dyn DispatchPolicy>, String> {
//!     if !args.is_empty() { return Err("everywhere takes no arguments".into()); }
//!     Ok(Box::new(Everywhere))
//! }
//! register_policy(&["everywhere"], "uniform demo policy", make);
//! assert_eq!(parse_policy("everywhere").unwrap().name(), "everywhere");
//! ```

use super::policy::{DeepSpeedEven, DispatchPolicy, FastMoeEven, FasterMoeHir, TaMoe};
use crate::dispatch::Norm;
use std::sync::{Mutex, OnceLock};

/// Builds a policy from the `:`-separated arguments after the key.
/// Must reject unconsumed arguments.
pub type PolicyFactory = fn(args: &[&str]) -> Result<Box<dyn DispatchPolicy>, String>;

struct Entry {
    names: &'static [&'static str],
    help: &'static str,
    factory: PolicyFactory,
}

fn registry() -> &'static Mutex<Vec<Entry>> {
    static REGISTRY: OnceLock<Mutex<Vec<Entry>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(builtin_entries()))
}

/// Register a policy factory under one or more names (the first is
/// canonical). Later registrations shadow earlier ones with the same name,
/// so a downstream crate may also *override* a builtin.
pub fn register_policy(
    names: &'static [&'static str],
    help: &'static str,
    factory: PolicyFactory,
) {
    assert!(!names.is_empty(), "policy needs at least one name");
    registry().lock().unwrap().push(Entry { names, help, factory });
}

/// Parse a policy spec `key[:arg...]` via the registry.
pub fn parse_policy(spec: &str) -> Result<Box<dyn DispatchPolicy>, String> {
    let parts: Vec<&str> = spec.split(':').collect();
    let key = parts[0];
    if key.is_empty() {
        return Err("empty policy name".into());
    }
    let factory = {
        let reg = registry().lock().unwrap();
        reg.iter()
            .rev()
            .find(|e| e.names.iter().any(|n| *n == key))
            .map(|e| e.factory)
    };
    match factory {
        Some(f) => f(&parts[1..]).map_err(|e| format!("policy {spec:?}: {e}")),
        None => {
            let known: Vec<&str> = {
                let reg = registry().lock().unwrap();
                reg.iter().map(|e| e.names[0]).collect()
            };
            Err(format!("unknown policy {key:?} (known: {})", known.join(", ")))
        }
    }
}

/// All registered policies as `(names-joined-by-|, help)` rows, in
/// registration order — the `--list-strategies` table.
pub fn list_policies() -> Vec<(String, String)> {
    registry()
        .lock()
        .unwrap()
        .iter()
        .map(|e| (e.names.join("|"), e.help.to_string()))
        .collect()
}

// ---------------------------------------------------------------------------
// builtin factories
// ---------------------------------------------------------------------------

fn reject_extra(args: &[&str], name: &str) -> Result<(), String> {
    if args.is_empty() {
        Ok(())
    } else {
        Err(format!("{name} takes no arguments, got {:?}", args.join(":")))
    }
}

fn make_deepspeed(args: &[&str]) -> Result<Box<dyn DispatchPolicy>, String> {
    reject_extra(args, "deepspeed")?;
    Ok(Box::new(DeepSpeedEven))
}

fn make_fastmoe(args: &[&str]) -> Result<Box<dyn DispatchPolicy>, String> {
    reject_extra(args, "fastmoe")?;
    Ok(Box::new(FastMoeEven))
}

fn make_fastermoe(args: &[&str]) -> Result<Box<dyn DispatchPolicy>, String> {
    let remote_frac = match args {
        [] => FasterMoeHir::default().remote_frac,
        [f] => {
            let v: f64 =
                f.parse().map_err(|e| format!("remote_frac {f:?}: {e}"))?;
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("remote_frac {v} outside [0, 1]"));
            }
            v
        }
        _ => return Err(format!("at most one remote_frac argument, got {:?}", args.join(":"))),
    };
    Ok(Box::new(FasterMoeHir { remote_frac }))
}

fn make_tamoe(args: &[&str]) -> Result<Box<dyn DispatchPolicy>, String> {
    let norm = match args {
        [] => Norm::L1,
        ["softmax"] => Norm::Softmax { temp: 2.0 },
        ["softmax", t] => {
            let temp: f64 = t.parse().map_err(|e| format!("temp {t:?}: {e}"))?;
            if !temp.is_finite() || temp <= 0.0 {
                return Err(format!("temp must be positive, got {temp}"));
            }
            Norm::Softmax { temp }
        }
        ["softmax", _, ..] => {
            return Err(format!("unexpected trailing arguments {:?}", args[2..].join(":")))
        }
        [other, ..] => return Err(format!("unknown variant {other:?} (expected `softmax`)")),
    };
    Ok(Box::new(TaMoe { norm }))
}

fn builtin_entries() -> Vec<Entry> {
    vec![
        Entry {
            names: &["deepspeed", "deepspeed-moe"],
            help: "DeepSpeed-MoE: even local capacities, load-balance loss, hierarchical a2a",
            factory: make_deepspeed,
        },
        Entry {
            names: &["fastmoe"],
            help: "FastMoE: global capacity with size exchange, load-balance loss, direct a2a",
            factory: make_fastmoe,
        },
        Entry {
            names: &["fastermoe", "fastermoe-hir", "hir"],
            help: "FasterMoE Hir gate: compulsory intra-node ratio; optional `:remote_frac` (default 0.25)",
            factory: make_fastermoe,
        },
        Entry {
            names: &["ta-moe", "tamoe"],
            help: "TA-MoE (this paper): topology-aware loss + proportional caps; optional `:softmax[:temp]`",
            factory: make_tamoe,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_parse() {
        for (spec, want) in [
            ("deepspeed", "deepspeed"),
            ("deepspeed-moe", "deepspeed"),
            ("fastmoe", "fastmoe"),
            ("fastermoe", "fastermoe:0.25"),
            ("fastermoe-hir:0.1", "fastermoe:0.1"),
            ("hir:0.5", "fastermoe:0.5"),
            ("ta-moe", "ta-moe"),
            ("tamoe", "ta-moe"),
            ("ta-moe:softmax", "ta-moe:softmax:2"),
            ("ta-moe:softmax:3.5", "ta-moe:softmax:3.5"),
        ] {
            assert_eq!(parse_policy(spec).unwrap().name(), want, "{spec}");
        }
    }

    #[test]
    fn every_builtin_name_round_trips() {
        let policies: Vec<Box<dyn DispatchPolicy>> = vec![
            Box::new(DeepSpeedEven),
            Box::new(FastMoeEven),
            Box::new(FasterMoeHir { remote_frac: 0.3 }),
            Box::new(FasterMoeHir::default()),
            Box::new(TaMoe { norm: Norm::L1 }),
            Box::new(TaMoe { norm: Norm::Softmax { temp: 2.0 } }),
            Box::new(TaMoe { norm: Norm::Softmax { temp: 0.75 } }),
        ];
        for p in &policies {
            let name = p.name();
            let parsed = parse_policy(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(parsed.name(), name, "parse(name()) must round-trip");
            assert_eq!(parsed.is_topology_aware(), p.is_topology_aware());
            assert_eq!(parsed.preferred_a2a(), p.preferred_a2a());
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        for bad in [
            "ta-moe:softmax:2.0:junk",
            "ta-moe:blah",
            "fastermoe:notanumber",
            "fastermoe:0.2:x",
            "fastermoe:1.5",
            "fastermoe:-0.1",
            "deepspeed:junk",
            "fastmoe:0.5",
            "ta-moe:softmax:-1",
            "ta-moe:softmax:nan",
            "",
            "whatever",
        ] {
            assert!(parse_policy(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn listing_names_the_builtins() {
        let rows = list_policies();
        assert!(rows.len() >= 4);
        let names: Vec<&str> = rows.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.iter().any(|n| n.starts_with("ta-moe")), "{names:?}");
        assert!(names.iter().any(|n| n.starts_with("deepspeed")), "{names:?}");
    }
}
