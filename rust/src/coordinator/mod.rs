//! Layer-3 coordinator: the paper's systems contribution in rust, behind
//! the crate's three public seams (DESIGN.md §api).
//!
//! * [`policy`] — the [`DispatchPolicy`] trait and the four systems under
//!   comparison (DeepSpeed-MoE, FastMoE, FasterMoE-Hir, TA-MoE) expressed
//!   as runtime inputs to one model, plus their converged dispatch
//!   patterns for the analytic sweeps.
//! * [`registry`] — open name → policy lookup ([`parse_policy`]);
//!   downstream crates plug in new policies with [`register_policy`].
//! * [`session`] — [`Session`]/[`SessionBuilder`]: topology + policy +
//!   backend + data + metrics composed into one training run.
//! * [`cost`] — the simulated cluster clock: FLOP model + α-β all-to-all +
//!   allreduce, priced on measured `c_ie` (training and decode
//!   [`StepProfile`]s).
//! * [`workload`] — [`Workload`]/[`WorkloadCore`]: the pricing state a
//!   run of any kind (training session, serving simulator) drives its
//!   steps through.

pub mod cost;
pub mod policy;
pub mod registry;
pub mod session;
pub mod workload;

pub use cost::{
    device_flops, step_cost, step_cost_blamed, step_cost_cached, step_cost_overlapped,
    step_cost_perturbed, step_cost_placed, step_cost_profiled, step_cost_traced, throughput,
    ModelShape, PlanCache, StepCost, StepProfile, PLAN_CACHE_TOL,
};
pub use policy::{
    converged_counts, DeepSpeedEven, DispatchPolicy, FastMoeEven, FasterMoeHir,
    PolicyInputs, TaMoe,
};
pub use registry::{list_policies, parse_policy, register_policy, PolicyFactory};
pub use session::{DataSource, Session, SessionBuilder, SessionOptions};
pub use workload::{ChaosReport, Workload, WorkloadCore};
