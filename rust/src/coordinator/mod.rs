//! Layer-3 coordinator: the paper's systems contribution in rust.
//!
//! * [`strategy`] — the MoE systems under comparison (DeepSpeed-MoE,
//!   FastMoE, FasterMoE-Hir, TA-MoE) expressed as runtime inputs to the
//!   one compiled model, plus their converged dispatch patterns for the
//!   analytic sweeps.
//! * [`cost`] — the simulated cluster clock: FLOP model + α-β all-to-all +
//!   allreduce, priced on measured `c_ie`.
//! * [`trainer`] — the step loop over the AOT-compiled cluster program.

pub mod cost;
pub mod strategy;
pub mod trainer;

pub use cost::{device_flops, step_cost, throughput, ModelShape, StepCost};
pub use strategy::{converged_counts, Strategy, StrategyInputs};
pub use trainer::{Trainer, TrainerOptions};
