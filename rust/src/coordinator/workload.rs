//! [`Workload`] + [`WorkloadCore`]: the pricing infrastructure a run of
//! *any* kind — training ([`super::Session`]) or continuous-batching
//! inference serving (`crate::serve::ServeSession`) — drives its steps
//! through.
//!
//! A workload owns three things the step loop varies (what produces the
//! per-step dispatch counts, what a "step" means, what gets logged) and
//! shares everything that prices them: the topology, the model shape, the
//! a2a plan, the epoch-aware [`PlanCache`], the optional live
//! [`PlacementEngine`], and the overlap clock. [`WorkloadCore`] bundles
//! the shared half so `Session::train_step` and the serve iteration loop
//! are the same four moves: observe loads → maybe migrate → price counts
//! under the workload's [`StepProfile`] → log.

use super::cost::{
    step_cost_perturbed, step_cost_profiled, step_cost_traced, ModelShape, PlanCache, StepCost,
    StepProfile,
};
use crate::comm::A2aAlgo;
use crate::metrics::{RunLog, StepRecord};
use crate::overlap::OverlapMode;
use crate::perturb::{ChaosEngine, ChaosSpec, FiredEvent};
use crate::placement::{
    Migration, OverlapPricing, Placement, PlacementConfig, PlacementEngine,
};
use crate::topology::Topology;
use crate::trace::{TraceLevel, Tracer};
use crate::util::Mat;
use anyhow::Result;

/// The shared pricing state of one run: everything between "here are this
/// step's dispatch counts" and "here is what the step cost on the cluster
/// clock", independent of whether the counts came from a training batch
/// or an inference micro-batch.
pub struct WorkloadCore {
    topo: Topology,
    shape: ModelShape,
    a2a: A2aAlgo,
    overlap: OverlapMode,
    flops_per_dev: f64,
    e_per_dev: usize,
    profile: StepProfile,
    plan_cache: PlanCache,
    placement: Option<PlacementEngine>,
    /// The scripted fault stream, if any (`None` and an attached-but-off
    /// spec both leave every priced path bit-identical to a clean run).
    chaos: Option<ChaosEngine>,
    /// Monotone counter bumped by every topology mutation (link scaling,
    /// node death); forwarded to [`PlanCache::set_topo_epoch`].
    topo_epoch: u64,
    /// Per-device compute slowdown of the step being priced (set by
    /// [`Self::chaos_step`], consumed by [`Self::price_with_shape`];
    /// `None` = every device at full speed, the clean fast path).
    slowdown: Option<Vec<f64>>,
    /// The structured event sink, attached by the sessions' trace
    /// builders. `None` (the default) keeps every priced path
    /// allocation-free and byte-identical to a build without tracing.
    tracer: Option<Tracer>,
}

/// What the fault stream did to one step, returned by
/// [`WorkloadCore::chaos_step`] for the session to log and charge.
#[derive(Clone, Debug, Default)]
pub struct ChaosReport {
    /// Events that fired at this step (onsets, restores, deaths), in
    /// their canonical spec spelling — the strings the run log records.
    pub events: Vec<String>,
    /// Devices that died this step — the serve session must drain their
    /// in-flight sequences (`ContinuousBatcher::fail_device`).
    pub dead_devices: Vec<usize>,
    /// The emergency evacuation a node death triggered, if any. Its
    /// `cost_s` must be charged to the step clock by the caller, like an
    /// ordinary accepted migration.
    pub migration: Option<Migration>,
}

impl WorkloadCore {
    /// Assemble the core. `placement_cfg` enables the live placement
    /// engine; its amortisation gate prices candidate hostings on the
    /// overlapped clock for training profiles (the historic behaviour)
    /// and on the serial exchange clock for forward-only profiles (the
    /// training pipeline DAG does not model a decode step, and a serial
    /// gate is conservative: it never overstates a candidate's saving
    /// relative to the charged clock by more than the overlap win).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        topo: Topology,
        shape: ModelShape,
        a2a: A2aAlgo,
        overlap: OverlapMode,
        flops_per_dev: f64,
        e_per_dev: usize,
        profile: StepProfile,
        plan_cache_tol: f64,
        placement_cfg: Option<PlacementConfig>,
    ) -> WorkloadCore {
        let placement = placement_cfg.map(|pcfg| {
            let engine = PlacementEngine::new(
                pcfg,
                topo.p(),
                e_per_dev,
                shape.token_bytes(),
                shape.expert_param_bytes(),
                profile.exchanges_per_layer * shape.n_moe_layers as f64,
                a2a,
            );
            if overlap == OverlapMode::Serial || profile.is_forward_only() {
                engine
            } else {
                // the run charges the overlapped clock, so the
                // amortisation gate must predict savings on it too (same
                // ModelShape derivation as step_cost_profiled)
                let dense_fwd_s = shape.dense_fwd_s(flops_per_dev);
                engine.with_overlap(OverlapPricing {
                    mode: overlap,
                    dense_fwd_s,
                    dense_bwd_s: (profile.compute_mult - 1.0).max(0.0) * dense_fwd_s,
                    expert_s_per_token: profile.compute_mult
                        * shape.expert_flops_per_token()
                        * shape.n_moe_layers as f64
                        / flops_per_dev,
                    n_moe: shape.n_moe_layers,
                    dense_param_bytes: shape.dense_param_bytes(),
                })
            }
        });
        WorkloadCore {
            topo,
            shape,
            a2a,
            overlap,
            flops_per_dev,
            e_per_dev,
            profile,
            plan_cache: PlanCache::new(plan_cache_tol),
            placement,
            chaos: None,
            topo_epoch: 0,
            slowdown: None,
            tracer: None,
        }
    }

    /// Attach a structured event sink at the requested level. Pricing
    /// routes through the traced path from the next step on; traced
    /// prices are bit-identical to untraced ones.
    pub fn attach_tracer(&mut self, level: TraceLevel) {
        self.tracer = Some(Tracer::new(level));
    }

    /// Attach a scripted fault stream. An `off` spec attaches nothing at
    /// all, so the clean path stays structurally identical to a core
    /// built without chaos.
    pub fn with_chaos(mut self, spec: ChaosSpec) -> Result<WorkloadCore> {
        spec.validate(self.topo.p(), self.topo.links().len())
            .map_err(anyhow::Error::msg)?;
        if !spec.is_off() {
            self.chaos = Some(ChaosEngine::new(spec));
        }
        Ok(self)
    }

    /// Advance the fault stream by one step: execute the topology
    /// mutations firing now (link α/β scaling, node death — each bumps
    /// the topology epoch so the plan cache drops schedules synthesised
    /// for the old fabric), run the emergency evacuation on a death,
    /// rewrite `counts` (gate drift, elastic re-scale), and latch the
    /// per-device compute slowdown for the pricing call that follows.
    /// Returns `None` when no fault stream is attached (and leaves
    /// `counts` untouched).
    pub fn chaos_step(&mut self, counts: &mut Mat) -> Option<ChaosReport> {
        let fired = self.chaos.as_ref()?.fired();
        let mut report = ChaosReport::default();
        for ev in &fired {
            report.events.push(ev.to_string());
            match *ev {
                FiredEvent::LinkScale { edge, factor } => {
                    self.topo.scale_link(edge, factor);
                    self.topo_epoch += 1;
                    self.plan_cache.set_topo_epoch(self.topo_epoch);
                }
                FiredEvent::NodeLoss { dev } => {
                    self.topo.mark_dead(dev);
                    report.dead_devices.push(dev);
                    self.topo_epoch += 1;
                    self.plan_cache.set_topo_epoch(self.topo_epoch);
                    if let Some(eng) = self.placement.as_mut() {
                        if let Some(m) = eng.evacuate(&self.topo, dev) {
                            self.plan_cache.set_epoch(eng.epoch());
                            report.migration = Some(m);
                        }
                    }
                }
                // window-open markers: logged above, nothing to execute
                FiredEvent::StragglerOn { .. } | FiredEvent::DriftOn { .. } => {}
            }
        }
        let chaos = self.chaos.as_ref().expect("chaos present");
        chaos.transform_counts(
            counts,
            &self.topo,
            self.placement.as_ref().map(|e| e.placement()),
        );
        self.slowdown = chaos.slowdown(&self.topo);
        self.chaos.as_mut().expect("chaos present").advance();
        Some(report)
    }

    /// Price one step's dispatch counts on the cluster clock under the
    /// core's profile, routing through the live placement and the plan
    /// cache.
    pub fn price(&mut self, counts: &Mat) -> StepCost {
        let shape = self.shape.clone();
        self.price_with_shape(&shape, counts)
    }

    /// [`Self::price`] with a per-step shape override. Serving iterations
    /// vary `tokens_per_dev` with the live batch (prefills vs decodes),
    /// so the continuous batcher prices each iteration under a shape
    /// cloned from the core's with only the token dimension rewritten.
    pub fn price_with_shape(&mut self, shape: &ModelShape, counts: &Mat) -> StepCost {
        if let Some(tracer) = self.tracer.as_mut() {
            // the traced path takes slowdown unconditionally; a unit
            // vector reproduces the profiled price exactly (pinned by
            // `unit_slowdown_reproduces_profiled_price_exactly`)
            let s = self
                .slowdown
                .clone()
                .unwrap_or_else(|| vec![1.0; self.topo.p()]);
            return step_cost_traced(
                shape,
                &self.topo,
                counts,
                self.e_per_dev,
                self.flops_per_dev,
                self.a2a,
                self.overlap,
                self.profile,
                Some(&mut self.plan_cache),
                self.placement.as_ref().map(|e| e.placement()),
                &s,
                tracer,
            );
        }
        match self.slowdown.clone() {
            // active stragglers: price compute per device under the
            // latched slowdown factors
            Some(s) => step_cost_perturbed(
                shape,
                &self.topo,
                counts,
                self.e_per_dev,
                self.flops_per_dev,
                self.a2a,
                self.overlap,
                self.profile,
                Some(&mut self.plan_cache),
                self.placement.as_ref().map(|e| e.placement()),
                &s,
            ),
            None => step_cost_profiled(
                shape,
                &self.topo,
                counts,
                self.e_per_dev,
                self.flops_per_dev,
                self.a2a,
                self.overlap,
                self.profile,
                Some(&mut self.plan_cache),
                self.placement.as_ref().map(|e| e.placement()),
            ),
        }
    }

    /// Fold one step's measured loads into the placement engine's EWMA
    /// (no-op when placement is disabled).
    pub fn observe(&mut self, counts: &Mat) {
        if let Some(eng) = self.placement.as_mut() {
            eng.observe(counts);
        }
    }

    /// At the placement engine's cadence, re-solve the hosting and accept
    /// the move when it amortises. On acceptance the plan cache's epoch
    /// is bumped (cached schedules were synthesised for the old byte
    /// routing); the *caller* re-points whatever else depends on the
    /// hosting — the gate inputs for training, the expert-weight caches
    /// for serving.
    pub fn maybe_migrate(&mut self, live_counts: &Mat) -> Option<Migration> {
        let m = self.placement.as_mut()?.maybe_replace(&self.topo, live_counts)?;
        let epoch = self.placement.as_ref().expect("placement present").epoch();
        self.plan_cache.set_epoch(epoch);
        Some(m)
    }

    // -- accessors ----------------------------------------------------------

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn shape(&self) -> &ModelShape {
        &self.shape
    }

    pub fn a2a_algo(&self) -> A2aAlgo {
        self.a2a
    }

    pub fn overlap_mode(&self) -> OverlapMode {
        self.overlap
    }

    pub fn flops_per_dev(&self) -> f64 {
        self.flops_per_dev
    }

    pub fn e_per_dev(&self) -> usize {
        self.e_per_dev
    }

    pub fn profile(&self) -> StepProfile {
        self.profile
    }

    pub fn plan_cache(&self) -> &PlanCache {
        &self.plan_cache
    }

    /// The live expert→device map (None when placement is disabled).
    pub fn placement(&self) -> Option<&Placement> {
        self.placement.as_ref().map(|e| e.placement())
    }

    /// Accepted migrations so far (0 when placement is disabled).
    pub fn placement_epoch(&self) -> u64 {
        self.placement.as_ref().map_or(0, |e| e.epoch())
    }

    /// Topology mutations executed so far (0 on a clean fabric).
    pub fn topo_epoch(&self) -> u64 {
        self.topo_epoch
    }

    /// The attached fault stream, if any.
    pub fn chaos(&self) -> Option<&ChaosEngine> {
        self.chaos.as_ref()
    }

    /// The per-device compute slowdown latched for the next priced step
    /// (`None` = every device at full speed).
    pub fn slowdown(&self) -> Option<&[f64]> {
        self.slowdown.as_deref()
    }

    /// The attached event sink, if any.
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Mutable access to the attached event sink, for the sessions to
    /// emit step-scope spans, instants and counters.
    pub fn tracer_mut(&mut self) -> Option<&mut Tracer> {
        self.tracer.as_mut()
    }
}

/// Record one accepted migration on the tracer: a span on the dedicated
/// `migrate` track (the stall the step clock is charged), the counters,
/// and the clock advance that pushes this step's exchanges after it.
/// Shared by the training session and the serving simulator so both
/// trace migrations identically.
pub(crate) fn trace_migration(tr: &mut Tracer, bytes: f64, cost_s: f64) {
    let t = tr.clock_s();
    tr.span("migrate", "migration", "placement", t, cost_s, &[("bytes", bytes)]);
    tr.registry_mut().inc("migrations_total", 1);
    tr.registry_mut().gauge_add("migration_bytes", bytes);
    tr.registry_mut().gauge_add("migration_s", cost_s);
    tr.advance(cost_s);
}

/// One run that prices its steps through a [`WorkloadCore`] — the seam
/// that lets benches/CLI drive a training `Session` and a serving
/// `ServeSession` identically.
pub trait Workload {
    /// Advance by one priced step (a training batch, a decode iteration)
    /// and return its record.
    fn step(&mut self) -> Result<StepRecord>;

    /// The accumulated run log.
    fn log(&self) -> &RunLog;

    /// The shared pricing state.
    fn core(&self) -> &WorkloadCore;

    /// Drive `steps` steps back to back.
    fn run_steps(&mut self, steps: usize) -> Result<()> {
        for _ in 0..steps {
            self.step()?;
        }
        Ok(())
    }
}
