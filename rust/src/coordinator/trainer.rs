//! The training orchestrator: drives the compiled cluster-step program.
//!
//! One [`Trainer`] owns the PJRT runtime, the artifact (init/step/eval
//! executables + manifest), the topology, and a [`Strategy`]. Per step it
//! feeds the model state + batch + the strategy's runtime matrices into
//! the compiled step, reads back the new state and the gate statistics
//! `c_ie`, and charges the step to the simulated cluster clock via
//! [`super::cost::step_cost`] using the *measured* dispatch counts — the
//! simulated time axis therefore reflects what the gate actually learned,
//! not what the strategy hoped for.

use super::cost::{step_cost, ModelShape};
use super::strategy::{Strategy, StrategyInputs};
use crate::metrics::{RunLog, StepRecord};
use crate::runtime::{Artifact, HostTensor, Runtime};
use crate::topology::Topology;
use crate::util::Mat;
use anyhow::{Context, Result};
use std::path::Path;
use std::time::Instant;

/// Options for constructing a [`Trainer`].
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    pub lr: f32,
    pub seed: i32,
    /// Effective device FLOP/s for the simulated clock.
    pub flops_per_dev: f64,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions { lr: 1e-3, seed: 0, flops_per_dev: 45e12 }
    }
}

/// Orchestrates training of one compiled artifact under one strategy.
pub struct Trainer {
    #[allow(dead_code)]
    runtime: Runtime,
    artifact: Artifact,
    topo: Topology,
    strategy: Strategy,
    inputs: StrategyInputs,
    input_lits: Vec<xla::Literal>, // penalty, caps, local_mask, hir_frac
    /// params ++ m ++ v as literals (kept as XLA literals between steps).
    state: Vec<xla::Literal>,
    t: f32,
    lr: f32,
    shape: ModelShape,
    flops_per_dev: f64,
    log: RunLog,
    last_counts: Option<Mat>,
}

impl Trainer {
    /// Load an artifact directory and initialise model state from `seed`.
    pub fn new(
        artifact_dir: &Path,
        topo: Topology,
        strategy: Strategy,
        opts: TrainerOptions,
    ) -> Result<Trainer> {
        let runtime = Runtime::cpu()?;
        let artifact = runtime.load_artifact(artifact_dir)?;
        let cfg = &artifact.manifest.config;
        anyhow::ensure!(
            topo.p() == cfg.p,
            "topology has {} devices, artifact {} wants {}",
            topo.p(),
            artifact.manifest.name,
            cfg.p
        );

        let inputs = strategy.runtime_inputs(&topo, cfg);
        let input_lits = vec![
            HostTensor::from_mat(&inputs.penalty).to_literal()?,
            HostTensor::from_mat(&inputs.caps).to_literal()?,
            HostTensor::from_mat(&inputs.local_mask).to_literal()?,
            HostTensor::scalar_f32(inputs.hir_remote_frac).to_literal()?,
        ];

        // init: seed → params; optimizer state starts at zero.
        let seed_lit = HostTensor::scalar_i32(opts.seed).to_literal()?;
        let params = artifact
            .init
            .run(&[seed_lit])
            .context("running init program")?;
        let mut state = params;
        for desc in artifact.manifest.params.iter().chain(&artifact.manifest.params) {
            state.push(HostTensor::f32(vec![0.0; desc.numel()], &desc.shape).to_literal()?);
        }

        let shape = ModelShape::from_cfg(cfg);
        let tokens_per_step = cfg.p * cfg.tokens_per_dev;
        let label = format!("{}/{}", artifact.manifest.name, strategy.name());
        Ok(Trainer {
            runtime,
            artifact,
            topo,
            strategy,
            inputs,
            input_lits,
            state,
            t: 0.0,
            lr: opts.lr,
            shape,
            flops_per_dev: opts.flops_per_dev,
            log: RunLog::new(&label, tokens_per_step),
            last_counts: None,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.artifact.manifest
    }

    pub fn strategy(&self) -> &Strategy {
        &self.strategy
    }

    pub fn strategy_inputs(&self) -> &StrategyInputs {
        &self.inputs
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn log(&self) -> &RunLog {
        &self.log
    }

    pub fn log_mut(&mut self) -> &mut RunLog {
        &mut self.log
    }

    /// Mean per-MoE-layer dispatch counts of the most recent step.
    pub fn last_counts(&self) -> Option<&Mat> {
        self.last_counts.as_ref()
    }

    fn batch_literals(&self, tokens: &[i32], targets: &[i32]) -> Result<(xla::Literal, xla::Literal)> {
        let cfg = &self.artifact.manifest.config;
        let shape = [cfg.p, cfg.batch, cfg.seq];
        Ok((
            HostTensor::i32(tokens.to_vec(), &shape).to_literal()?,
            HostTensor::i32(targets.to_vec(), &shape).to_literal()?,
        ))
    }

    /// Run one training step; returns the step's record (also logged).
    pub fn train_step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<StepRecord> {
        let n = self.artifact.manifest.n_param_tensors;
        let (tok_lit, tgt_lit) = self.batch_literals(tokens, targets)?;
        let t_lit = HostTensor::scalar_f32(self.t).to_literal()?;
        let lr_lit = HostTensor::scalar_f32(self.lr).to_literal()?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 8);
        args.extend(self.state.iter());
        args.push(&t_lit);
        args.push(&lr_lit);
        args.push(&tok_lit);
        args.push(&tgt_lit);
        for lit in &self.input_lits {
            args.push(lit);
        }

        let wall0 = Instant::now();
        let mut outs = self.artifact.step.run(&args)?;
        let wall_s = wall0.elapsed().as_secs_f64();

        // split outputs: 3n state, then t, loss, ce, aux, counts, dropped
        let tail = outs.split_off(3 * n);
        self.state = outs;
        let cfg = &self.artifact.manifest.config;
        let scalars: Vec<f64> = [0usize, 1, 2, 3, 5]
            .iter()
            .map(|&i| {
                HostTensor::from_literal(&tail[i], &[], crate::runtime::DType::F32)
                    .map(|t| t.item())
            })
            .collect::<Result<_>>()?;
        let counts = HostTensor::from_literal(
            &tail[4],
            &[cfg.p, cfg.n_experts],
            crate::runtime::DType::F32,
        )?
        .to_mat()?;
        self.t = scalars[0] as f32;

        let cost = step_cost(
            &self.shape,
            &self.topo,
            &counts,
            cfg.e_per_dev,
            self.flops_per_dev,
            self.strategy.hierarchical_a2a(),
        );
        let record = StepRecord {
            step: self.log.records.len(),
            loss: scalars[1],
            ce: scalars[2],
            aux: scalars[3],
            dropped: scalars[4],
            sim_comm_s: cost.a2a_s + cost.allreduce_s,
            sim_compute_s: cost.compute_s,
            wall_s,
        };
        self.last_counts = Some(counts);
        self.log.push(record.clone());
        Ok(record)
    }

    /// Validation pass on a held-out batch; logs (step, loss) and returns
    /// (ce_loss, counts).
    pub fn eval(&mut self, tokens: &[i32], targets: &[i32]) -> Result<(f64, Mat)> {
        let n = self.artifact.manifest.n_param_tensors;
        let (tok_lit, tgt_lit) = self.batch_literals(tokens, targets)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(n + 6);
        args.extend(self.state.iter().take(n));
        args.push(&tok_lit);
        args.push(&tgt_lit);
        for lit in &self.input_lits {
            args.push(lit);
        }
        let outs = self.artifact.eval.run(&args)?;
        let cfg = &self.artifact.manifest.config;
        let ce = HostTensor::from_literal(&outs[1], &[], crate::runtime::DType::F32)?.item();
        let counts = HostTensor::from_literal(
            &outs[3],
            &[cfg.p, cfg.n_experts],
            crate::runtime::DType::F32,
        )?
        .to_mat()?;
        let step = self.log.records.len().saturating_sub(1);
        self.log.push_eval(step, ce);
        Ok((ce, counts))
    }
}
