//! Simulated step-time model: FLOPs + α-β communication on the cluster
//! clock.
//!
//! This is the clock behind every throughput/speedup figure (DESIGN.md §2):
//! compute comes from a FLOP count over the model shape divided by an
//! effective per-device rate, communication from the [`crate::comm`]
//! engine priced on the *actual* per-step dispatch counts `c_ie` (either
//! measured from a real training run or taken from
//! [`super::policy::converged_counts`] for paper-scale sweeps).
//!
//! Per training step we charge:
//! * forward + backward compute: 3× the forward FLOPs (standard estimate);
//! * per MoE layer: dispatch + combine all-to-all in forward and their
//!   mirror images in backward → 4 exchanges of the `c_ie` byte matrix;
//! * a ring allreduce of the dense (replicated) gradients.
//!
//! Expert compute is bottlenecked by the most-loaded device (the paper's
//! load-imbalance effect): `max_j Σ_{e on j} Σ_i c_ie`.
//!
//! The back-to-back sum of those charges ([`StepCost::serial_total`]) is
//! a *serial upper bound*: real MoE runtimes pipeline token chunks
//! through dispatch → expert → combine and hide the allreduce under the
//! backward pass. [`step_cost_overlapped`] prices that regime on the
//! [`crate::overlap`] timeline, with the chunk-count autotuner's winners
//! memoised through the (epoch-aware) [`PlanCache`].

use crate::comm::{
    census_add, census_sub, contended_time, price_rounds, ring_allreduce_time, A2aAlgo,
    A2aBreakdown, CommPlan, Round,
};
use crate::overlap::{
    autotune_k, autotune_k_forward, pipeline_cost, pipeline_cost_forward,
    pipeline_cost_forward_retained, pipeline_cost_retained, EventClass, OverlapInputs,
    OverlapMode,
};
use crate::placement::Placement;
use crate::runtime::ModelCfg;
use crate::topology::Topology;
use crate::trace::{TraceLevel, Tracer};
use crate::util::Mat;

/// Shape of the model whose step is being priced. Decoupled from the
/// compiled artifacts so paper-scale configs (GPT-Medium) can be priced on
/// the cost model while the trained artifacts stay CPU-sized.
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    pub layers: usize,
    pub d: usize,
    pub f: usize,
    pub vocab: usize,
    pub seq: usize,
    /// Tokens per device per step (S).
    pub tokens_per_dev: usize,
    pub k: usize,
    pub n_moe_layers: usize,
    /// Bytes per element on the wire (2 = fp16, 4 = fp32).
    pub elem_bytes: usize,
}

impl ModelShape {
    /// The paper's GPT-Medium MoE configs (Table 3).
    pub fn gpt_medium(gate_gshard: bool, batch: usize, seq: usize) -> ModelShape {
        ModelShape {
            layers: 12,
            d: 1024,
            f: if gate_gshard { 2048 } else { 4096 },
            vocab: 50_000,
            seq,
            tokens_per_dev: batch * seq,
            k: if gate_gshard { 2 } else { 1 },
            n_moe_layers: 6, // MoE every other layer
            elem_bytes: 2,   // FP16 on clusters A; B/C use 4 (see Table 3)
        }
    }

    /// From a compiled artifact's config (fp32 on this CPU testbed).
    pub fn from_cfg(cfg: &ModelCfg) -> ModelShape {
        ModelShape {
            layers: cfg.layers,
            d: cfg.d,
            f: cfg.f,
            vocab: cfg.vocab,
            seq: cfg.seq,
            tokens_per_dev: cfg.tokens_per_dev,
            k: cfg.k,
            n_moe_layers: cfg.n_moe_layers(),
            elem_bytes: 4,
        }
    }

    /// Forward FLOPs per token, dense portion (attention + embeddings +
    /// the dense FFN layers).
    pub fn dense_flops_per_token(&self) -> f64 {
        let d = self.d as f64;
        let f = self.f as f64;
        let t = self.seq as f64;
        let attn = 8.0 * d * d + 4.0 * t * d; // qkvo projections + scores/apply
        let dense_ffn = 4.0 * d * f; // the non-MoE layers
        let n_dense = (self.layers - self.n_moe_layers) as f64;
        let logits = 2.0 * self.vocab as f64 * d;
        self.layers as f64 * attn + n_dense * dense_ffn + logits
    }

    /// Forward FLOPs per *dispatched* token inside one expert.
    pub fn expert_flops_per_token(&self) -> f64 {
        4.0 * self.d as f64 * self.f as f64
    }

    /// Wire bytes of one dispatched token (`d · elem_bytes`).
    pub fn token_bytes(&self) -> f64 {
        (self.d * self.elem_bytes) as f64
    }

    /// Weight bytes of one expert (its two FFN matrices) — the payload a
    /// live migration moves over the links.
    pub fn expert_param_bytes(&self) -> f64 {
        (2 * self.d * self.f * self.elem_bytes) as f64
    }

    /// Forward dense compute seconds per step at `flops_per_dev` —
    /// the single source of the overlap engine's dense timing (backward
    /// dense is 2× this, matching the 3×-forward step estimate).
    pub fn dense_fwd_s(&self, flops_per_dev: f64) -> f64 {
        self.dense_flops_per_token() * self.tokens_per_dev as f64 / flops_per_dev
    }

    /// Expert compute seconds per *received* token, totalled over all MoE
    /// layers, forward + backward (3× forward).
    pub fn expert_s_per_token(&self, flops_per_dev: f64) -> f64 {
        3.0 * self.expert_flops_per_token() * self.n_moe_layers as f64 / flops_per_dev
    }

    /// The overlap engine's view of one step under per-device received
    /// token loads `recv` — shared by [`step_cost_overlapped`], the
    /// placement gate's `OverlapPricing`, and the overlap property tests,
    /// so the timing derivation has one source of truth.
    pub fn overlap_inputs(&self, flops_per_dev: f64, recv: &[f64]) -> OverlapInputs {
        self.overlap_inputs_profiled(flops_per_dev, recv, StepProfile::train())
    }

    /// [`ModelShape::overlap_inputs`] under an explicit [`StepProfile`]:
    /// backward dense is whatever the profile's compute multiple adds on
    /// top of forward (zero for decode), and per-device expert seconds
    /// scale by the same multiple.
    pub fn overlap_inputs_profiled(
        &self,
        flops_per_dev: f64,
        recv: &[f64],
        profile: StepProfile,
    ) -> OverlapInputs {
        let dense_fwd_s = self.dense_fwd_s(flops_per_dev);
        let per_tok = profile.compute_mult * self.expert_flops_per_token()
            * self.n_moe_layers as f64
            / flops_per_dev;
        OverlapInputs {
            dense_fwd_s,
            dense_bwd_s: (profile.compute_mult - 1.0).max(0.0) * dense_fwd_s,
            expert_s_per_dev: recv.iter().map(|&r| r * per_tok).collect(),
            n_moe: self.n_moe_layers,
        }
    }

    /// Bytes of the replicated (dense) parameters, for the allreduce.
    pub fn dense_param_bytes(&self) -> f64 {
        let d = self.d as f64;
        let f = self.f as f64;
        let attn = 4.0 * d * d;
        let n_dense = (self.layers - self.n_moe_layers) as f64;
        let embed = self.vocab as f64 * d;
        (self.layers as f64 * attn + n_dense * 2.0 * d * f + embed) * self.elem_bytes as f64
    }
}

/// Effective sustained FLOP/s per device for the paper's clusters
/// (roofline × a realistic MFU for MoE training).
pub fn device_flops(cluster: char) -> f64 {
    match cluster.to_ascii_uppercase() {
        'A' => 120e12, // A100 fp16 (312 peak × ~0.38 MFU)
        _ => 45e12,    // V100 (125 peak fp16 × ~0.36; paper runs fp32 on B/C,
                       // absorbed into the same effective rate)
    }
}

/// What one priced step physically runs — the knob that lets training and
/// inference decode share [`priced_step`]'s α-β/contention machinery:
///
/// * **train** — forward + backward (compute ≈ 3× forward), dispatch and
///   combine in both directions (4 exchanges of the `c_ie` bytes per MoE
///   layer), plus the dense-gradient ring allreduce;
/// * **decode** — forward only (1× compute, 2 exchanges per layer, no
///   allreduce), the per-iteration clock of the continuous-batching
///   serving simulator (`crate::serve`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepProfile {
    /// Total compute as a multiple of the forward pass (3.0 train, 1.0
    /// decode).
    pub compute_mult: f64,
    /// Dispatch/combine exchanges of the `c_ie` byte matrix per MoE layer
    /// (4.0 train: dispatch/combine × fwd/bwd; 2.0 decode).
    pub exchanges_per_layer: f64,
    /// Whether the dense-gradient ring allreduce is charged.
    pub allreduce: bool,
}

impl StepProfile {
    /// The historic training clock; every pre-existing `step_cost*` path
    /// prices with this profile, bit-identically to before it existed.
    pub fn train() -> StepProfile {
        StepProfile { compute_mult: 3.0, exchanges_per_layer: 4.0, allreduce: true }
    }

    /// One decode iteration of an inference batch: forward only.
    pub fn decode() -> StepProfile {
        StepProfile { compute_mult: 1.0, exchanges_per_layer: 2.0, allreduce: false }
    }

    /// Forward-only profiles (no backward mirror, no allreduce) pipeline
    /// through `n_moe` blocks instead of the training DAG's `2 · n_moe`.
    pub fn is_forward_only(&self) -> bool {
        !self.allreduce && self.compute_mult <= 1.0
    }
}

/// Default relative drift tolerance of a [`PlanCache`]: re-synthesise the
/// schedule only once the byte matrix has moved more than this fraction of
/// the per-sender exchange volume since the cached plan was made. With the
/// sim gate's τ ≈ 24-step relaxation this yields ~5–6 syntheses over a
/// 200-step run (see `rust/tests/session_sim.rs`).
pub const PLAN_CACHE_TOL: f64 = 0.10;

/// A step-level cache of synthesised [`CommPlan`] round schedules.
///
/// `sched:bvn` synthesis is the expensive part of pricing a step; once the
/// gate's dispatch pattern converges, the synthesized schedule stops
/// changing, so [`PlanCache::plan`] keys cached schedules on a quantized
/// byte-matrix fingerprint and reuses them until the pattern drifts more
/// than `tol × (total bytes / P)` from the matrix the plan was made for.
/// Cached *rounds* are always re-priced on the live byte matrix
/// ([`price_rounds`]), so a hit never serves stale times — only the
/// schedule structure is reused. Entries are additionally bound to the
/// topology's link-graph identity (`topo_key`: P, link parameters, path
/// shapes), so one cache can safely serve calls that alternate
/// topologies: a schedule built for another link graph is never returned.
/// `direct`/`hier` plans have no synthesis step and bypass the cache
/// (neither counter moves).
///
/// The cache additionally carries a *placement epoch*
/// ([`PlanCache::set_epoch`]): expert migration re-routes the byte matrix
/// through a new expert→device map, so schedules synthesised before the
/// migration describe traffic that no longer exists — bumping the epoch
/// drops every cached entry, regardless of how small the fingerprint
/// drift looks.
#[derive(Debug, Default)]
pub struct PlanCache {
    tol: f64,
    entries: Vec<PlanEntry>,
    /// Memoised overlap-autotuner winners (see [`PlanCache::tuned_k`]).
    tuned: Vec<TuneEntry>,
    /// Placement epoch the cached entries were synthesised under.
    epoch: u64,
    /// Topology epoch the cached entries were synthesised under (bumped
    /// by the perturbation layer whenever it mutates link or per-pair
    /// α/β state in place — see [`PlanCache::set_topo_epoch`]).
    topo_epoch: u64,
    hits: u64,
    misses: u64,
}

#[derive(Debug)]
struct PlanEntry {
    algo: A2aAlgo,
    /// Link-graph identity of the topology the schedule was built for.
    topo_key: u64,
    fingerprint: u64,
    /// The byte matrix the cached schedule was synthesised from.
    bytes: Mat,
    rounds: Vec<Round>,
}

/// One memoised chunk-count autotune result: the winning `k` for a
/// (topology, plan, byte-pattern) triple, reused under the same drift
/// tolerance (and the same placement epoch) as cached schedules.
#[derive(Debug)]
struct TuneEntry {
    algo: A2aAlgo,
    topo_key: u64,
    fingerprint: u64,
    bytes: Mat,
    k: usize,
}

impl PlanCache {
    /// A cache with the given relative drift tolerance; `tol <= 0`
    /// disables caching (every plan is cold — the uncached baseline).
    pub fn new(tol: f64) -> PlanCache {
        PlanCache { tol, ..Default::default() }
    }

    /// A disabled cache: every [`PlanCache::plan`] call re-synthesises.
    pub fn disabled() -> PlanCache {
        Self::new(0.0)
    }

    /// Schedule re-uses since construction.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cold syntheses since construction.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn tol(&self) -> f64 {
        self.tol
    }

    /// The placement epoch the cache currently serves.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Align the cache with a placement epoch: a change invalidates every
    /// cached schedule (they were synthesised for byte matrices routed
    /// through the old expert→device map). Idempotent for an unchanged
    /// epoch — hits keep flowing between migrations.
    pub fn set_epoch(&mut self, epoch: u64) {
        if epoch != self.epoch {
            self.epoch = epoch;
            self.entries.clear();
            self.tuned.clear();
        }
    }

    /// The topology epoch the cache currently serves.
    pub fn topo_epoch(&self) -> u64 {
        self.topo_epoch
    }

    /// Align the cache with a *topology* epoch. `topo_key` already makes
    /// link-graph mutations (e.g. [`Topology::scale_link`]) miss
    /// naturally, but per-pair-only α/β mutations share a `topo_key` with
    /// the clean topology — that is exactly the staleness this explicit
    /// epoch closes: the perturbation layer bumps it on *every* in-place
    /// topology mutation, dropping cached BvN schedules and tuned-`k`
    /// memos alike. (The comm engine's flow-census scratch needs no
    /// epoch: `CostEngine` borrows the topology, so any `&mut` mutation
    /// invalidates it at compile time.) Idempotent for an unchanged
    /// epoch, like [`PlanCache::set_epoch`].
    pub fn set_topo_epoch(&mut self, epoch: u64) {
        if epoch != self.topo_epoch {
            self.topo_epoch = epoch;
            self.entries.clear();
            self.tuned.clear();
        }
    }

    /// Per-sender exchange volume — the drift/quantization scale.
    fn scale(bytes: &Mat) -> f64 {
        bytes.sum() / bytes.rows().max(1) as f64
    }

    /// FNV-1a over the byte matrix quantized to `tol·scale` buckets. The
    /// bucket width itself is mixed into the hash, so uniformly scaling
    /// the whole matrix (same buckets, different volume regime) changes
    /// the fingerprint and falls through to the drift check rather than
    /// silently hitting forever. Equal fingerprints ⇒ same bucket width
    /// and every entry in the same bucket ⇒ within tolerance.
    fn fingerprint(&self, bytes: &Mat) -> u64 {
        let q = self.tol * Self::scale(bytes);
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x0100_0000_01b3);
        };
        mix(bytes.rows() as u64);
        mix(q.to_bits());
        for &b in bytes.data() {
            let bucket = if q > 0.0 { (b / q).round() as i64 } else { 0 };
            mix(bucket as u64);
        }
        h
    }

    /// Identity of the topology's link graph — the inputs schedule
    /// synthesis actually depends on (P, link parameters, path shapes).
    /// Topologies with identical link graphs (e.g. a `with_noise` clone,
    /// which perturbs only the per-pair α/β matrices) may safely share a
    /// cached schedule; anything else misses.
    fn topo_key(topo: &Topology) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x0100_0000_01b3);
        };
        mix(topo.p() as u64);
        mix(topo.links().len() as u64);
        for (e, l) in topo.links().iter().enumerate() {
            mix(l.alpha.to_bits());
            mix(l.beta.to_bits());
            mix(topo.link_contended(e) as u64);
        }
        // path shapes: per-pair hop counts pin the wiring without hashing
        // every slot (O(P²), not O(P²·hops))
        for i in 0..topo.p() {
            for j in 0..topo.p() {
                mix(topo.path(i, j).len() as u64);
            }
        }
        h
    }

    /// Price one exchange, reusing the cached schedule while the byte
    /// matrix stays within tolerance of the one it was synthesised from
    /// (on the same link graph). Hit plans carry the breakdown only
    /// (`rounds: None`) — the rounds stay inside the cache, so the common
    /// path does not deep-copy the schedule it just reused.
    pub fn plan(&mut self, topo: &Topology, bytes: &Mat, algo: A2aAlgo) -> CommPlan {
        if !matches!(algo, A2aAlgo::Scheduled(_)) || self.tol <= 0.0 {
            return algo.plan(topo, bytes); // nothing synthesised to reuse
        }
        let fp = self.fingerprint(bytes);
        let tkey = Self::topo_key(topo);
        if let Some(e) = self.entries.iter().find(|e| e.algo == algo) {
            let hit =
                e.topo_key == tkey && self.pattern_hit(&e.bytes, e.fingerprint, bytes, fp);
            if hit {
                self.hits += 1;
                return CommPlan {
                    algo,
                    breakdown: price_rounds(topo, bytes, &e.rounds),
                    rounds: None,
                };
            }
        }
        self.misses += 1;
        let plan = algo.plan(topo, bytes);
        let rounds = plan.rounds.clone().expect("scheduled plans carry rounds");
        let entry =
            PlanEntry { algo, topo_key: tkey, fingerprint: fp, bytes: bytes.clone(), rounds };
        match self.entries.iter_mut().find(|e| e.algo == algo) {
            Some(e) => *e = entry,
            None => self.entries.push(entry),
        }
        plan
    }

    /// Is a cached pattern within drift tolerance of the live one?
    fn pattern_hit(&self, cached: &Mat, cached_fp: u64, bytes: &Mat, fp: u64) -> bool {
        cached.rows() == bytes.rows()
            && cached.cols() == bytes.cols()
            && (cached_fp == fp || {
                let scale = Self::scale(bytes).max(Self::scale(cached));
                cached.linf_dist(bytes) <= self.tol * scale
            })
    }

    /// Price one `1/k` chunk of an exchange, reusing the cached round
    /// schedule where one is within tolerance of the live byte matrix
    /// (synthesis runs on the *full* matrix — an even `1/k` split leaves
    /// the optimal round structure unchanged, so chunks re-price the same
    /// rounds on `bytes/k`). Direct/hierarchical plans, cache misses, and
    /// disabled caches price the chunk matrix from scratch; counters are
    /// untouched (the serial pricing of the same step already accounted
    /// the hit or synthesis).
    pub fn chunk_breakdown(
        &self,
        topo: &Topology,
        bytes: &Mat,
        algo: A2aAlgo,
        k: usize,
    ) -> A2aBreakdown {
        assert!(k >= 1, "chunk count must be >= 1");
        let chunk = bytes.scale(1.0 / k as f64);
        if matches!(algo, A2aAlgo::Scheduled(_)) && self.tol > 0.0 {
            let fp = self.fingerprint(bytes);
            let tkey = Self::topo_key(topo);
            if let Some(e) = self.entries.iter().find(|e| e.algo == algo) {
                if e.topo_key == tkey && self.pattern_hit(&e.bytes, e.fingerprint, bytes, fp)
                {
                    return price_rounds(topo, &chunk, &e.rounds);
                }
            }
        }
        algo.plan(topo, &chunk).breakdown
    }

    /// The cached round schedule that would serve this (topology,
    /// pattern) — the schedule [`PlanCache::plan`] just hit on (or
    /// synthesised), exposed side-effect-free so the tracer can attribute
    /// per-link round times without touching the hit/miss counters.
    pub(crate) fn cached_rounds(
        &self,
        topo: &Topology,
        bytes: &Mat,
        algo: A2aAlgo,
    ) -> Option<&[Round]> {
        if !matches!(algo, A2aAlgo::Scheduled(_)) || self.tol <= 0.0 {
            return None;
        }
        let fp = self.fingerprint(bytes);
        let tkey = Self::topo_key(topo);
        self.entries
            .iter()
            .find(|e| {
                e.algo == algo
                    && e.topo_key == tkey
                    && self.pattern_hit(&e.bytes, e.fingerprint, bytes, fp)
            })
            .map(|e| e.rounds.as_slice())
    }

    /// The memoised autotuned chunk count for this (topology, plan,
    /// pattern), if one is cached within the drift tolerance. A disabled
    /// cache never memoises (the autotuner sweeps every step — the
    /// uncached baseline).
    pub fn tuned_k(&self, topo: &Topology, bytes: &Mat, algo: A2aAlgo) -> Option<usize> {
        if self.tol <= 0.0 {
            return None;
        }
        let fp = self.fingerprint(bytes);
        let tkey = Self::topo_key(topo);
        self.tuned
            .iter()
            .find(|e| {
                e.algo == algo
                    && e.topo_key == tkey
                    && self.pattern_hit(&e.bytes, e.fingerprint, bytes, fp)
            })
            .map(|e| e.k)
    }

    /// Memoise an autotuned chunk count for this (topology, plan,
    /// pattern). Entries follow the same drift/topology/epoch
    /// invalidation rules as cached schedules.
    pub fn remember_k(&mut self, topo: &Topology, bytes: &Mat, algo: A2aAlgo, k: usize) {
        if self.tol <= 0.0 {
            return;
        }
        let entry = TuneEntry {
            algo,
            topo_key: Self::topo_key(topo),
            fingerprint: self.fingerprint(bytes),
            bytes: bytes.clone(),
            k,
        };
        match self.tuned.iter_mut().find(|e| e.algo == algo) {
            Some(e) => *e = entry,
            None => self.tuned.push(entry),
        }
    }
}

/// Per-step cost breakdown on the simulated cluster clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCost {
    pub compute_s: f64,
    /// Total all-to-all time; equals `a2a.total()`.
    pub a2a_s: f64,
    pub allreduce_s: f64,
    /// Per-phase all-to-all split (local / intra-node / inter-node).
    pub a2a: A2aBreakdown,
    /// Step time on the chunked overlap timeline
    /// ([`step_cost_overlapped`]); equals [`StepCost::serial_total`] for
    /// serially-priced steps and at `k = 1`.
    pub overlapped_s: f64,
    /// A2a time not hidden under compute on the timeline (the whole
    /// `a2a_s` when priced serially).
    pub exposed_a2a_s: f64,
    /// Token chunks the step was pipelined into (1 = serial).
    pub chunks: usize,
}

impl StepCost {
    /// The serial upper bound: compute, a2a, and allreduce executed back
    /// to back with nothing overlapping — the clock every pre-overlap
    /// comparison in this repo was priced on.
    pub fn serial_total(&self) -> f64 {
        self.compute_s + self.a2a_s + self.allreduce_s
    }

    /// Alias of [`StepCost::serial_total`], kept for callers that price
    /// analytic (non-overlapped) sweeps. Prefer `serial_total` where the
    /// serial-vs-overlapped distinction matters, and [`StepCost::step_s`]
    /// for "how long did this step take".
    pub fn total(&self) -> f64 {
        self.serial_total()
    }

    /// The step clock. Every pricing path fills `overlapped_s` — serial
    /// pricing copies its serial total in, overlap pricing the timeline
    /// makespan — so this is always the time the step is charged.
    pub fn step_s(&self) -> f64 {
        self.overlapped_s
    }

    /// Fraction of the serial clock the overlap engine hides:
    /// `(serial - overlapped) / serial`. Zero for serially-priced steps;
    /// negative when a forced chunk count re-pays more latency than it
    /// overlaps.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.serial_total();
        if serial <= 0.0 {
            0.0
        } else {
            (serial - self.step_s()) / serial
        }
    }
}

/// Price one training step.
///
/// `counts` is the per-MoE-layer dispatch matrix `c_ie` in tokens
/// (P×N). `a2a` selects how the dispatch/combine exchanges execute on
/// the wire (see [`A2aAlgo`]).
pub fn step_cost(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
) -> StepCost {
    step_cost_with(shape, topo, counts, e_per_dev, flops_per_dev, a2a, None, None)
}

/// [`step_cost`] under an explicit expert placement: the exchange's byte
/// matrix and the per-device expert-compute loads are both routed through
/// the expert→device map instead of the canonical `e / e_per_dev`
/// hosting. With the identity placement this reproduces [`step_cost`]
/// exactly.
pub fn step_cost_placed(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    placement: &Placement,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    cache: Option<&mut PlanCache>,
) -> StepCost {
    step_cost_with(
        shape,
        topo,
        counts,
        placement.e_per_dev(),
        flops_per_dev,
        a2a,
        cache,
        Some(placement),
    )
}

/// [`step_cost`] with a reusable [`PlanCache`]: the schedule synthesised
/// for the dispatch/combine exchange is reused across steps while the byte
/// matrix stays within the cache's tolerance. Prices are always computed
/// from the live `counts`, so a cache hit on an unchanged pattern
/// reproduces the cold-path [`StepCost`] exactly.
pub fn step_cost_cached(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    cache: &mut PlanCache,
) -> StepCost {
    step_cost_with(shape, topo, counts, e_per_dev, flops_per_dev, a2a, Some(cache), None)
}

/// [`step_cost`] priced on the chunked overlap timeline instead of the
/// serial phase sum (DESIGN.md §overlap). `mode` selects the clock:
///
/// * [`OverlapMode::Serial`] — identical to the serial paths above
///   (`overlapped_s` set to the serial total, `chunks = 1`);
/// * [`OverlapMode::Fixed`]`(k)` — the dispatch byte matrix and expert
///   FLOPs split into `k` token chunks pipelined through
///   dispatch → expert → combine (per-chunk exchanges priced on
///   `bytes/k` through the cache's round schedules);
/// * [`OverlapMode::Auto`] — the chunk-count autotuner sweeps
///   `k ∈ {1, 2, 4, 8, 16}` and memoises the winner through the cache
///   (epoch-aware, drift-invalidated). Since `k = 1` is in the sweep the
///   tuned clock never exceeds the serial one.
///
/// The serial fields (`compute_s`, `a2a_s`, `allreduce_s`, the phase
/// split) are always the serial attribution, so the serial-vs-overlapped
/// comparison is carried by every priced step.
#[allow(clippy::too_many_arguments)]
pub fn step_cost_overlapped(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    mode: OverlapMode,
    cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
) -> StepCost {
    step_cost_profiled(
        shape,
        topo,
        counts,
        e_per_dev,
        flops_per_dev,
        a2a,
        mode,
        StepProfile::train(),
        cache,
        placement,
    )
}

/// [`step_cost_overlapped`] under an explicit [`StepProfile`] — the entry
/// point the serving simulator prices decode iterations through
/// ([`StepProfile::decode`]: forward-only compute, 2 exchanges per MoE
/// layer, no allreduce). With [`StepProfile::train`] this *is*
/// [`step_cost_overlapped`]. Forward-only profiles pipeline through the
/// `n_moe`-block forward DAG ([`pipeline_cost_forward`]); everything else
/// (plan cache, tuned-`k` memo, placement routing) is shared.
#[allow(clippy::too_many_arguments)]
pub fn step_cost_profiled(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    mode: OverlapMode,
    profile: StepProfile,
    cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
) -> StepCost {
    step_cost_inner(
        shape, topo, counts, e_per_dev, flops_per_dev, a2a, mode, profile, cache, placement,
        None, None, None,
    )
}

/// [`step_cost_profiled`] under per-device compute slowdown factors — the
/// straggler model of the perturbation layer (`crate::perturb`). Factor
/// `s_i ≥ 1` multiplies device `i`'s compute time: the serial compute
/// bound becomes `max_i s_i · t_i` over per-device forward loads, and on
/// the overlap timeline each device's expert seconds scale by `s_i` while
/// the dense phases scale by `max_i s_i` (a synchronous step runs at the
/// slowest replica's pace). A slowdown of all-ones reproduces
/// [`step_cost_profiled`] exactly; communication is never touched (link
/// faults go through [`Topology::scale_link`] instead).
#[allow(clippy::too_many_arguments)]
pub fn step_cost_perturbed(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    mode: OverlapMode,
    profile: StepProfile,
    cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
    slowdown: &[f64],
) -> StepCost {
    step_cost_inner(
        shape, topo, counts, e_per_dev, flops_per_dev, a2a, mode, profile, cache, placement,
        Some(slowdown), None, None,
    )
}

/// [`step_cost_perturbed`] with a [`Tracer`] attached: prices
/// bit-identically to the untraced path (every emission is behind the
/// tracer, and re-derivations are side-effect-free) while recording, by
/// [`TraceLevel`]: plan-cache hit/miss instants and registry counters
/// (`Step`), serial phase spans on the `serial` track (`Phase`), and —
/// at `Chunk` — per-directed-link a2a round spans (`link:<slot>` tracks,
/// serially-priced steps of scheduled plans) or the retained pipeline
/// timeline (`dev:<i>` / `chan:<name>` tracks, overlapped steps) with
/// its independent `Timeline::busy()` totals fed to
/// [`Tracer::note_busy`].
#[allow(clippy::too_many_arguments)]
pub fn step_cost_traced(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    mode: OverlapMode,
    profile: StepProfile,
    cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
    slowdown: &[f64],
    tracer: &mut Tracer,
) -> StepCost {
    step_cost_inner(
        shape, topo, counts, e_per_dev, flops_per_dev, a2a, mode, profile, cache, placement,
        Some(slowdown), Some(tracer), None,
    )
}

/// [`step_cost_profiled`] (optionally under straggler slowdowns) that
/// additionally returns per-resource critical-path *blame* rows
/// `(track, seconds)` — the analyze subsystem's attribution primitive
/// (`crate::analyze`). Unlike busy time, blame partitions the step
/// clock: the returned seconds sum to [`StepCost::step_s`] (to fp
/// addition error), so `blame / step_s` fractions answer "which
/// resource gates this step". Serially-priced steps attribute the
/// slowest device's compute (`dev:<i>`), per-round bottleneck directed
/// links of scheduled plans (`link:<slot>`, with the round-free
/// residual — local copies, or the whole phase split for
/// direct/hierarchical plans — on `chan:a2a-*` rows), and the
/// allreduce; overlapped steps back-walk the retained pipeline
/// timeline ([`crate::overlap::Timeline::critical_blame`]) onto the
/// same `dev:<i>` / `chan:<name>` tracks the tracer uses. The
/// [`StepCost`] itself is priced through the identical code path as
/// the blame-free entry points, bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn step_cost_blamed(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    mode: OverlapMode,
    profile: StepProfile,
    cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
    slowdown: Option<&[f64]>,
) -> (StepCost, Vec<(String, f64)>) {
    let mut rows: Vec<(String, f64)> = Vec::new();
    let cost = step_cost_inner(
        shape, topo, counts, e_per_dev, flops_per_dev, a2a, mode, profile, cache, placement,
        slowdown, None, Some(&mut rows),
    );
    (cost, rows)
}

#[allow(clippy::too_many_arguments)]
fn step_cost_inner(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    mode: OverlapMode,
    profile: StepProfile,
    mut cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
    slowdown: Option<&[f64]>,
    mut tracer: Option<&mut Tracer>,
    blame: Option<&mut Vec<(String, f64)>>,
) -> StepCost {
    let counters_before = cache.as_deref().map(|c| (c.hits(), c.misses()));
    let (serial, bytes, recv) = priced_step(
        shape,
        topo,
        counts,
        e_per_dev,
        flops_per_dev,
        a2a,
        profile,
        cache.as_deref_mut(),
        placement,
        slowdown,
    );
    if let (Some(tr), Some(c), Some((h0, m0))) =
        (tracer.as_deref_mut(), cache.as_deref(), counters_before)
    {
        trace_plan_events(tr, c.hits() - h0, c.misses() - m0);
    }
    if mode == OverlapMode::Serial {
        if let Some(tr) = tracer {
            trace_serial_step(
                tr,
                topo,
                &bytes,
                &serial,
                a2a,
                profile,
                shape.n_moe_layers,
                cache.as_deref(),
            );
        }
        if let Some(out) = blame {
            serial_blame_rows(
                out,
                topo,
                &bytes,
                &serial,
                a2a,
                profile,
                shape.n_moe_layers,
                cache.as_deref(),
                shape,
                flops_per_dev,
                &recv,
                slowdown,
            );
        }
        return serial;
    }

    let mut inputs = shape.overlap_inputs_profiled(flops_per_dev, &recv, profile);
    if let Some(s) = slowdown {
        let max_slow = s.iter().copied().fold(1.0, f64::max);
        inputs.dense_fwd_s *= max_slow;
        inputs.dense_bwd_s *= max_slow;
        for (t, &sl) in inputs.expert_s_per_dev.iter_mut().zip(s) {
            *t *= sl;
        }
    }
    let forward_only = profile.is_forward_only();
    let chunk_of = |k: usize| {
        let breakdown = match cache.as_deref() {
            Some(c) => c.chunk_breakdown(topo, &bytes, a2a, k),
            None => a2a.plan(topo, &bytes.scale(1.0 / k as f64)).breakdown,
        };
        let ar_chunk = if profile.allreduce {
            ring_allreduce_time(topo, shape.dense_param_bytes() / k as f64)
        } else {
            0.0
        };
        (breakdown, ar_chunk)
    };
    let price = |inputs: &OverlapInputs, chunk: &A2aBreakdown, ar: f64, k: usize| {
        if forward_only {
            pipeline_cost_forward(inputs, chunk, k)
        } else {
            pipeline_cost(inputs, chunk, ar, k)
        }
    };
    let (k, pipe) = match mode {
        OverlapMode::Serial => unreachable!("handled above"),
        OverlapMode::Fixed(k) => {
            let (chunk, ar_chunk) = chunk_of(k);
            (k, price(&inputs, &chunk, ar_chunk, k))
        }
        OverlapMode::Auto => match cache.as_deref().and_then(|c| c.tuned_k(topo, &bytes, a2a))
        {
            Some(k) => {
                let (chunk, ar_chunk) = chunk_of(k);
                (k, price(&inputs, &chunk, ar_chunk, k))
            }
            None => {
                let (k, pipe) = if forward_only {
                    autotune_k_forward(&inputs, chunk_of)
                } else {
                    autotune_k(&inputs, chunk_of)
                };
                if let Some(c) = cache.as_deref_mut() {
                    c.remember_k(topo, &bytes, a2a, k);
                }
                if let Some(tr) = tracer.as_deref_mut() {
                    tr.registry_mut().inc("tuned_k_picks_total", 1);
                }
                (k, pipe)
            }
        },
    };
    if let Some(tr) = tracer {
        if tr.enabled(TraceLevel::Chunk) {
            // re-derive the winning chunk configuration (side-effect-free:
            // `chunk_breakdown` never touches the hit/miss counters) and
            // re-run the pipeline with event retention — bit-identical to
            // the schedule just priced, per the retention contract
            let chunk = match cache.as_deref() {
                Some(c) => c.chunk_breakdown(topo, &bytes, a2a, k),
                None => a2a.plan(topo, &bytes.scale(1.0 / k as f64)).breakdown,
            };
            let ar_chunk = if profile.allreduce {
                ring_allreduce_time(topo, shape.dense_param_bytes() / k as f64)
            } else {
                0.0
            };
            let (re, tl) = if forward_only {
                pipeline_cost_forward_retained(&inputs, &chunk, k, true)
            } else {
                pipeline_cost_retained(&inputs, &chunk, ar_chunk, k, true)
            };
            debug_assert_eq!(re.makespan_s, pipe.makespan_s, "retained re-run must agree");
            let t0 = tr.clock_s();
            let p = inputs.expert_s_per_dev.len();
            for e in tl.events() {
                let track = pipeline_track(p, e.resource);
                let cat = class_cat(e.class);
                tr.span(&track, cat, cat, t0 + e.start_s, e.end_s - e.start_s, &[]);
            }
            for (r, &b) in tl.busy().iter().enumerate() {
                tr.note_busy(&pipeline_track(p, r), b);
            }
        }
    }
    if let Some(out) = blame {
        // re-derive the winning chunk configuration and re-run the
        // pipeline with event retention — side-effect-free and
        // bit-identical to the schedule just priced, exactly like the
        // tracer's Chunk-level re-run above — then back-walk the
        // retained DAG for per-resource critical-path blame
        let chunk = match cache.as_deref() {
            Some(c) => c.chunk_breakdown(topo, &bytes, a2a, k),
            None => a2a.plan(topo, &bytes.scale(1.0 / k as f64)).breakdown,
        };
        let ar_chunk = if profile.allreduce {
            ring_allreduce_time(topo, shape.dense_param_bytes() / k as f64)
        } else {
            0.0
        };
        let (re, tl) = if forward_only {
            pipeline_cost_forward_retained(&inputs, &chunk, k, true)
        } else {
            pipeline_cost_retained(&inputs, &chunk, ar_chunk, k, true)
        };
        debug_assert_eq!(re.makespan_s, pipe.makespan_s, "retained re-run must agree");
        let p = inputs.expert_s_per_dev.len();
        let per_resource = tl.critical_blame();
        for (r, &b) in per_resource.iter().enumerate() {
            if b > 0.0 {
                out.push((pipeline_track(p, r), b));
            }
        }
    }
    StepCost {
        overlapped_s: pipe.makespan_s,
        exposed_a2a_s: pipe.exposed_a2a_s,
        chunks: k,
        ..serial
    }
}

/// Track name of a pipeline timeline resource under the chunk DAG's
/// resource map (P compute streams, 4 directional link channels, the
/// allreduce channel — forward pipelines simply never use the last).
fn pipeline_track(p: usize, resource: usize) -> String {
    match resource.checked_sub(p) {
        None => format!("dev:{resource}"),
        Some(0) => "chan:dispatch-intra".to_string(),
        Some(1) => "chan:dispatch-inter".to_string(),
        Some(2) => "chan:combine-intra".to_string(),
        Some(3) => "chan:combine-inter".to_string(),
        Some(_) => "chan:allreduce".to_string(),
    }
}

fn class_cat(class: EventClass) -> &'static str {
    match class {
        EventClass::Compute => "compute",
        EventClass::A2a => "a2a",
        EventClass::Allreduce => "allreduce",
    }
}

/// Registry counters + (at `Phase` and above) instants for the plan
/// cache's activity on this step. `dh`/`dm` are the hit/miss counter
/// deltas the step's serial pricing produced (0/0 for uncached plans).
fn trace_plan_events(tr: &mut Tracer, dh: u64, dm: u64) {
    if dh > 0 {
        tr.registry_mut().inc("plan_hits_total", dh);
    }
    if dm > 0 {
        tr.registry_mut().inc("plan_misses_total", dm);
    }
    if tr.enabled(TraceLevel::Phase) {
        let at = tr.clock_s();
        if dh > 0 {
            tr.instant("step", "plan:hit", "plan", at, &[]);
        }
        if dm > 0 {
            tr.instant("step", "plan:miss", "plan", at, &[]);
        }
    }
}

/// Phase spans (and, at `Chunk`, per-directed-link round spans) for one
/// serially-priced step. The serial layout is the clock's own
/// attribution: compute, then the a2a phase split, then the allreduce,
/// back to back — their sum is exactly the step's advance, so spans of
/// consecutive steps never overlap. Link spans attribute ONE
/// representative exchange's rounds (scaled by the step's exchange
/// count) inside the a2a window: per round, each directed-link slot on a
/// live delivery's path is busy until the slowest flow through it
/// finishes, priced by the same contended-census model as
/// `CostEngine::round_time`.
#[allow(clippy::too_many_arguments)]
fn trace_serial_step(
    tr: &mut Tracer,
    topo: &Topology,
    bytes: &Mat,
    serial: &StepCost,
    a2a: A2aAlgo,
    profile: StepProfile,
    n_moe_layers: usize,
    cache: Option<&PlanCache>,
) {
    if !tr.enabled(TraceLevel::Phase) {
        return;
    }
    let t0 = tr.clock_s();
    tr.span("serial", "compute", "compute", t0, serial.compute_s, &[]);
    let a2a_start = t0 + serial.compute_s;
    let mut cur = a2a_start;
    for (name, dur) in [
        ("a2a:local", serial.a2a.local_s),
        ("a2a:intra", serial.a2a.intra_s),
        ("a2a:inter", serial.a2a.inter_s),
    ] {
        tr.span("serial", name, "a2a", cur, dur, &[]);
        cur += dur;
    }
    if profile.allreduce {
        tr.span("serial", "allreduce", "allreduce", cur, serial.allreduce_s, &[]);
    }
    if !tr.enabled(TraceLevel::Chunk) {
        return;
    }

    // only scheduled plans have a round structure to attribute; reuse the
    // cache's schedule when one serves this pattern (the one just priced),
    // else synthesise the same schedule the cold path would have
    let fresh;
    let rounds: &[Round] = match cache.and_then(|c| c.cached_rounds(topo, bytes, a2a)) {
        Some(r) => r,
        None if matches!(a2a, A2aAlgo::Scheduled(_)) => {
            fresh = a2a.plan(topo, bytes).rounds;
            match &fresh {
                Some(r) => r.as_slice(),
                None => return,
            }
        }
        None => return,
    };

    let n_ex = profile.exchanges_per_layer * n_moe_layers as f64;
    let mut census = vec![0u32; topo.n_slots()];
    let mut slot_busy = vec![0.0f64; topo.n_slots()];
    let mut live: Vec<(usize, usize)> = Vec::new();
    let mut cur = a2a_start;
    for (r, round) in rounds.iter().enumerate() {
        live.clear();
        live.extend(round.iter().copied().filter(|&(i, j)| i != j && bytes.get(i, j) > 0.0));
        if live.is_empty() {
            continue;
        }
        for v in &mut slot_busy {
            *v = 0.0;
        }
        for &(i, j) in &live {
            census_add(topo, &mut census, i, j);
        }
        let mut round_dur = 0.0f64;
        for &(i, j) in &live {
            let t = contended_time(topo, &census, i, j, bytes.get(i, j));
            round_dur = round_dur.max(t);
            for &s in topo.pair_slots(i, j) {
                let s = s as usize;
                slot_busy[s] = slot_busy[s].max(t);
            }
        }
        for &(i, j) in &live {
            census_sub(topo, &mut census, i, j);
        }
        let name = format!("round {r}");
        for (s, &busy) in slot_busy.iter().enumerate() {
            if busy > 0.0 {
                tr.span(&format!("link:{s}"), &name, "a2a", cur, busy * n_ex, &[]);
            }
        }
        cur += round_dur * n_ex;
    }
}

/// Critical-path blame rows for one serially-priced step. A serial step
/// *is* its own critical path — compute, a2a, allreduce back to back —
/// so the phase times are the blame, refined to the gating resource:
/// the compute bound is charged to the slowest device, each scheduled
/// a2a round to the directed-link slot whose contended flow set the
/// round's duration (the same census model [`trace_serial_step`]
/// renders as spans), the round-free residual (local copies; the whole
/// phase split for plans without a round structure) to `chan:a2a-*`
/// rows, and the allreduce to its channel. Rows sum to
/// [`StepCost::serial_total`] by construction.
#[allow(clippy::too_many_arguments)]
fn serial_blame_rows(
    out: &mut Vec<(String, f64)>,
    topo: &Topology,
    bytes: &Mat,
    serial: &StepCost,
    a2a: A2aAlgo,
    profile: StepProfile,
    n_moe_layers: usize,
    cache: Option<&PlanCache>,
    shape: &ModelShape,
    flops_per_dev: f64,
    recv: &[f64],
    slowdown: Option<&[f64]>,
) {
    // compute: the serial bound waits on the slowest device
    if serial.compute_s > 0.0 {
        let dense = shape.dense_flops_per_token() * shape.tokens_per_dev as f64;
        let mut dev = 0usize;
        let mut worst = f64::NEG_INFINITY;
        for (i, &r) in recv.iter().enumerate() {
            let fwd = dense + shape.expert_flops_per_token() * r * n_moe_layers as f64;
            let t = profile.compute_mult * fwd / flops_per_dev
                * slowdown.map_or(1.0, |s| s[i]);
            if t > worst {
                worst = t;
                dev = i;
            }
        }
        out.push((format!("dev:{dev}"), serial.compute_s));
    }

    // a2a: per-round gating slots where a round structure exists, the
    // breakdown's phase split otherwise
    let n_ex = profile.exchanges_per_layer * n_moe_layers as f64;
    let fresh;
    let rounds: Option<&[Round]> = match cache.and_then(|c| c.cached_rounds(topo, bytes, a2a))
    {
        Some(r) => Some(r),
        None if matches!(a2a, A2aAlgo::Scheduled(_)) => {
            fresh = a2a.plan(topo, bytes).rounds;
            fresh.as_deref()
        }
        None => None,
    };
    match rounds {
        Some(rounds) => {
            let mut census = vec![0u32; topo.n_slots()];
            let mut slot_busy = vec![0.0f64; topo.n_slots()];
            let mut slot_blame = vec![0.0f64; topo.n_slots()];
            let mut live: Vec<(usize, usize)> = Vec::new();
            let mut linked = 0.0f64;
            for round in rounds {
                live.clear();
                live.extend(
                    round.iter().copied().filter(|&(i, j)| i != j && bytes.get(i, j) > 0.0),
                );
                if live.is_empty() {
                    continue;
                }
                for v in &mut slot_busy {
                    *v = 0.0;
                }
                for &(i, j) in &live {
                    census_add(topo, &mut census, i, j);
                }
                let mut round_dur = 0.0f64;
                for &(i, j) in &live {
                    let t = contended_time(topo, &census, i, j, bytes.get(i, j));
                    round_dur = round_dur.max(t);
                    for &s in topo.pair_slots(i, j) {
                        let s = s as usize;
                        slot_busy[s] = slot_busy[s].max(t);
                    }
                }
                for &(i, j) in &live {
                    census_sub(topo, &mut census, i, j);
                }
                if round_dur > 0.0 {
                    // the gating slot: lowest-indexed slot whose busiest
                    // flow set the round duration
                    let mut gate = 0usize;
                    let mut best = f64::NEG_INFINITY;
                    for (s, &b) in slot_busy.iter().enumerate() {
                        if b > best {
                            best = b;
                            gate = s;
                        }
                    }
                    slot_blame[gate] += round_dur * n_ex;
                    linked += round_dur * n_ex;
                }
            }
            let link_start = out.len();
            for (s, &b) in slot_blame.iter().enumerate() {
                if b > 0.0 {
                    out.push((format!("link:{s}"), b));
                }
            }
            // the round-free remainder of the a2a phase is the local
            // copies; clamp fp overshoot into the largest link row so
            // blame stays non-negative and still sums to the phase
            let residual = serial.a2a_s - linked;
            if residual > 0.0 {
                out.push(("chan:a2a-local".to_string(), residual));
            } else if residual < 0.0 {
                if let Some(row) =
                    out[link_start..].iter_mut().max_by(|a, b| a.1.total_cmp(&b.1))
                {
                    row.1 += residual;
                }
            }
        }
        None => {
            for (name, dur) in [
                ("chan:a2a-local", serial.a2a.local_s),
                ("chan:a2a-intra", serial.a2a.intra_s),
                ("chan:a2a-inter", serial.a2a.inter_s),
            ] {
                if dur > 0.0 {
                    out.push((name.to_string(), dur));
                }
            }
        }
    }

    if profile.allreduce && serial.allreduce_s > 0.0 {
        out.push(("chan:allreduce".to_string(), serial.allreduce_s));
    }
}

#[allow(clippy::too_many_arguments)]
fn step_cost_with(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
) -> StepCost {
    priced_step(
        shape,
        topo,
        counts,
        e_per_dev,
        flops_per_dev,
        a2a,
        StepProfile::train(),
        cache,
        placement,
        None,
    )
    .0
}

/// The shared serial pricing: the [`StepCost`] plus the routed dispatch
/// byte matrix and per-device received-token loads the overlap engine
/// reuses.
#[allow(clippy::too_many_arguments)]
fn priced_step(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
    profile: StepProfile,
    cache: Option<&mut PlanCache>,
    placement: Option<&Placement>,
    slowdown: Option<&[f64]>,
) -> (StepCost, Mat, Vec<f64>) {
    let p = topo.p();
    assert_eq!(counts.rows(), p);
    let n = counts.cols();
    assert_eq!(n, p * e_per_dev);
    if let Some(pl) = placement {
        assert_eq!((pl.p(), pl.e_per_dev()), (p, e_per_dev), "placement shape");
    }

    // --- compute: slowest device bounds the step ---------------------------
    let dense = shape.dense_flops_per_token() * shape.tokens_per_dev as f64;
    let recv: Vec<f64> = match placement {
        Some(pl) => pl.recv_per_device(counts),
        None => (0..p)
            .map(|j| {
                (0..e_per_dev)
                    .map(|le| counts.col_sum(j * e_per_dev + le))
                    .sum::<f64>()
            })
            .collect(),
    };
    let max_recv: f64 = recv.iter().copied().fold(0.0, f64::max);
    let expert = shape.expert_flops_per_token() * max_recv * shape.n_moe_layers as f64;
    let fwd_flops = dense + expert;
    // train: fwd + bwd ≈ 3× fwd; decode: forward only (1×)
    let compute_s = match slowdown {
        None => profile.compute_mult * fwd_flops / flops_per_dev,
        // stragglers: the synchronous step waits on the slowest device's
        // slowed compute, which is no longer necessarily the max-recv one
        Some(s) => {
            assert_eq!(s.len(), p, "slowdown length");
            recv.iter()
                .zip(s)
                .map(|(&r, &sl)| {
                    let fwd =
                        dense + shape.expert_flops_per_token() * r * shape.n_moe_layers as f64;
                    profile.compute_mult * fwd / flops_per_dev * sl
                })
                .fold(0.0, f64::max)
        }
    };

    // --- all-to-all: the profile's exchanges of the c_ie bytes per layer ---
    let bytes = match placement {
        Some(pl) => pl.bytes_matrix(counts, shape.token_bytes()),
        None => Mat::from_fn(p, p, |i, j| {
            let mut tok = 0.0;
            for le in 0..e_per_dev {
                tok += counts.get(i, j * e_per_dev + le);
            }
            tok * shape.token_bytes()
        }),
    };
    let plan = match cache {
        Some(c) => c.plan(topo, &bytes, a2a),
        None => a2a.plan(topo, &bytes),
    };
    let breakdown = plan
        .breakdown
        .scale(profile.exchanges_per_layer * shape.n_moe_layers as f64);
    let a2a_s = breakdown.total();

    // --- dense gradient allreduce (training profiles only) -----------------
    let allreduce_s = if profile.allreduce {
        ring_allreduce_time(topo, shape.dense_param_bytes())
    } else {
        0.0
    };

    let cost = StepCost {
        compute_s,
        a2a_s,
        allreduce_s,
        a2a: breakdown,
        overlapped_s: compute_s + a2a_s + allreduce_s,
        exposed_a2a_s: a2a_s,
        chunks: 1,
    };
    (cost, bytes, recv)
}

/// Throughput in tokens/s for a converged dispatch pattern, on the
/// serial clock (the analytic-sweep convention; overlapped runs report
/// throughput through `RunLog::sim_throughput` instead).
pub fn throughput(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
) -> f64 {
    let cost = step_cost(shape, topo, counts, e_per_dev, flops_per_dev, a2a);
    topo.p() as f64 * shape.tokens_per_dev as f64 / cost.serial_total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{converged_counts, FastMoeEven, TaMoe};
    use crate::dispatch::Norm;
    use crate::topology::presets;

    fn cfg16() -> ModelCfg {
        ModelCfg {
            p: 16,
            e_per_dev: 1,
            layers: 12,
            d: 1024,
            f: 4096,
            heads: 16,
            vocab: 50_000,
            batch: 6,
            seq: 1024,
            k: 1,
            cap_factor: 1.0,
            gate: "switch".into(),
            dispatch: "local".into(),
            n_experts: 16,
            capacity: 6 * 1024,
            tokens_per_dev: 6 * 1024,
            moe_layer_ids: (0..6).map(|i| i * 2 + 1).collect(),
        }
    }

    #[test]
    fn tamoe_throughput_beats_even_on_cluster_c() {
        // The fig4 headline direction, at GPT-Medium scale on 2 nodes.
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let t_even = throughput(&shape, &topo, &even, 1, device_flops('C'), A2aAlgo::Direct);
        let t_ta = throughput(&shape, &topo, &ta, 1, device_flops('C'), A2aAlgo::Direct);
        let speedup = t_ta / t_even;
        assert!(speedup > 1.02, "speedup {speedup}");
        assert!(speedup < 6.0, "speedup {speedup} implausibly large");
    }

    #[test]
    fn compute_dominates_on_single_node() {
        let topo = presets::cluster_a(1);
        let cfg = ModelCfg { p: 8, n_experts: 8, ..cfg16() };
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let c = step_cost(&shape, &topo, &even, 1, device_flops('A'), A2aAlgo::Direct);
        assert!(c.compute_s > c.a2a_s, "{c:?}");
    }

    #[test]
    fn imbalanced_experts_slow_compute() {
        let topo = presets::cluster_b(1);
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = Mat::filled(8, 8, 768.0);
        // all tokens crowd expert 0
        let mut skew = Mat::zeros(8, 8);
        for i in 0..8 {
            skew.set(i, 0, 6144.0);
        }
        let c_even = step_cost(&shape, &topo, &even, 1, device_flops('B'), A2aAlgo::Direct);
        let c_skew = step_cost(&shape, &topo, &skew, 1, device_flops('B'), A2aAlgo::Direct);
        assert!(c_skew.compute_s > c_even.compute_s * 2.0);
    }

    #[test]
    fn a2a_algo_changes_a2a_only() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let dir = step_cost(&shape, &topo, &even, 1, device_flops('C'), A2aAlgo::Direct);
        for algo in [
            A2aAlgo::Hierarchical,
            A2aAlgo::Scheduled(crate::comm::ScheduleKind::Rotation),
            A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn),
        ] {
            let c = step_cost(&shape, &topo, &even, 1, device_flops('C'), algo);
            assert_eq!(dir.compute_s, c.compute_s, "{algo}");
            assert_eq!(dir.allreduce_s, c.allreduce_s, "{algo}");
            assert_ne!(dir.a2a_s, c.a2a_s, "{algo}");
            assert!((c.a2a.total() - c.a2a_s).abs() < 1e-15, "{algo}");
        }
    }

    #[test]
    fn plan_cache_hit_reproduces_cold_step_cost_exactly() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let flops = device_flops('C');
        let cold = step_cost(&shape, &topo, &ta, 1, flops, algo);
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        let miss = step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        let hit = step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        for c in [&miss, &hit] {
            assert_eq!(c.compute_s, cold.compute_s);
            assert_eq!(c.allreduce_s, cold.allreduce_s);
            assert_eq!(c.a2a_s, cold.a2a_s);
            assert_eq!(c.a2a, cold.a2a);
        }
        // a pattern within tolerance reuses the schedule but re-prices it
        // on the live bytes (the total moves with the scaled volume)
        let drifted = ta.scale(1.0 + 1e-4);
        let d = step_cost_cached(&shape, &topo, &drifted, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 2));
        assert!(d.a2a_s > cold.a2a_s, "repriced on live bytes");
        // direct plans have no synthesis step: the cache is bypassed
        step_cost_cached(&shape, &topo, &ta, 1, flops, A2aAlgo::Direct, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 2));
        // a disabled cache is the uncached baseline
        let mut off = PlanCache::disabled();
        let c = step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut off);
        assert_eq!((off.misses(), off.hits()), (0, 0));
        assert_eq!(c.a2a_s, cold.a2a_s);
    }

    #[test]
    fn plan_cache_invalidates_past_tolerance() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let flops = device_flops('C');
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        step_cost_cached(&shape, &topo, &even, 1, flops, algo, &mut cache);
        // even → TA target is far past any reasonable tolerance
        let warm = step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (2, 0));
        let cold = step_cost(&shape, &topo, &ta, 1, flops, algo);
        assert_eq!(warm.a2a_s, cold.a2a_s, "re-synthesis matches cold path");
        // uniform volume growth keeps the pattern *shape* but changes the
        // regime the schedule was refined for — it must miss, not hit
        step_cost_cached(&shape, &topo, &ta.scale(4.0), 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (3, 0));
        // a different link graph with the same P must miss too
        let topo_b = presets::cluster_b(2);
        step_cost_cached(&shape, &topo_b, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (4, 0));
    }

    #[test]
    fn plan_cache_shares_across_link_identical_topologies() {
        // the documented topo-identity rule: a `with_noise` clone perturbs
        // only the per-pair α/β matrices — the link graph is identical, so
        // a schedule synthesised on the clean topology may be reused
        let topo = presets::cluster_c(2);
        let noisy = topo.with_noise(0.2, 42);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let flops = device_flops('C');
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        step_cost_cached(&shape, &noisy, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 1), "noise clone must hit");
    }

    #[test]
    fn plan_cache_placement_epoch_invalidates() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let flops = device_flops('C');
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        // same epoch: idempotent, entries survive
        cache.set_epoch(0);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 2));
        // a migration bumped the epoch: every cached schedule is stale,
        // even though the byte matrix fingerprint is unchanged
        cache.set_epoch(1);
        assert_eq!(cache.epoch(), 1);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (2, 2), "epoch bump must miss");
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (2, 3), "then caching resumes");
    }

    #[test]
    fn plan_cache_topology_epoch_invalidates_schedules() {
        // per-pair-only α/β mutation leaves `topo_key` unchanged (the
        // `with_noise` sharing rule), so without the explicit topology
        // epoch a degraded network would keep serving stale schedules
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let flops = device_flops('C');
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        // same topo epoch: idempotent
        cache.set_topo_epoch(0);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (1, 2));
        // a topology mutation bumped the epoch: cached schedules are stale
        cache.set_topo_epoch(1);
        assert_eq!(cache.topo_epoch(), 1);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (2, 2), "topo epoch bump must miss");
    }

    #[test]
    fn plan_cache_topology_epoch_invalidates_tuned_k() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let bytes = ta.scale(shape.token_bytes());
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        cache.remember_k(&topo, &bytes, algo, 4);
        assert_eq!(cache.tuned_k(&topo, &bytes, algo), Some(4));
        cache.set_topo_epoch(3);
        assert_eq!(cache.tuned_k(&topo, &bytes, algo), None, "tuned-k memo must drop");
    }

    #[test]
    fn scale_link_misses_naturally_via_topo_key() {
        // link-table mutation changes `topo_key`, so even without an
        // epoch bump a degraded link never reuses the clean schedule
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let flops = device_flops('C');
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        step_cost_cached(&shape, &topo, &ta, 1, flops, algo, &mut cache);
        let mut degraded = topo.clone();
        degraded.scale_link(0, 3.0);
        step_cost_cached(&shape, &degraded, &ta, 1, flops, algo, &mut cache);
        assert_eq!((cache.misses(), cache.hits()), (2, 0), "mutated links must miss");
    }

    #[test]
    fn unit_slowdown_reproduces_profiled_price_exactly() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let flops = device_flops('C');
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let ones = vec![1.0; 16];
        for mode in [OverlapMode::Serial, OverlapMode::Fixed(4), OverlapMode::Auto] {
            let clean = step_cost_profiled(
                &shape, &topo, &ta, 1, flops, algo, mode,
                StepProfile::train(), None, None,
            );
            let slowed = step_cost_perturbed(
                &shape, &topo, &ta, 1, flops, algo, mode,
                StepProfile::train(), None, None, &ones,
            );
            assert_eq!(slowed.compute_s, clean.compute_s, "{mode}");
            assert_eq!(slowed.a2a_s, clean.a2a_s, "{mode}");
            assert_eq!(slowed.step_s(), clean.step_s(), "{mode}");
        }
    }

    #[test]
    fn straggler_slowdown_raises_compute_monotonically() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let flops = device_flops('C');
        let clean = step_cost(&shape, &topo, &ta, 1, flops, A2aAlgo::Direct);
        let mut prev = clean.compute_s;
        for factor in [1.5, 2.0, 4.0] {
            let mut s = vec![1.0; 16];
            s[3] = factor;
            let c = step_cost_perturbed(
                &shape, &topo, &ta, 1, flops, A2aAlgo::Direct, OverlapMode::Serial,
                StepProfile::train(), None, None, &s,
            );
            assert!(c.compute_s >= prev, "factor {factor}");
            assert_eq!(c.a2a_s, clean.a2a_s, "stragglers never touch the wire");
            prev = c.compute_s;
        }
        assert!(prev > clean.compute_s, "a 4× straggler must show up in compute");
    }

    #[test]
    fn identity_placement_reproduces_step_cost_exactly() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let flops = device_flops('C');
        for algo in [A2aAlgo::Direct, A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn)] {
            let canon = step_cost(&shape, &topo, &ta, 1, flops, algo);
            let ident = Placement::identity(16, 1);
            let placed = step_cost_placed(&shape, &topo, &ta, &ident, flops, algo, None);
            assert_eq!(placed.compute_s, canon.compute_s, "{algo}");
            assert_eq!(placed.a2a_s, canon.a2a_s, "{algo}");
            assert_eq!(placed.allreduce_s, canon.allreduce_s, "{algo}");
        }
    }

    #[test]
    fn placement_reroutes_bytes_and_compute() {
        // all senders crowd expert 15 (canonically on device 15): hosting
        // it elsewhere must change the a2a price, and the compute bound
        // must follow the hot expert's host, not its id
        let topo = presets::cluster_c(2);
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let flops = device_flops('C');
        let mut skew = Mat::filled(16, 16, 64.0);
        for i in 0..16 {
            skew.set(i, 15, 4096.0);
        }
        let canon = step_cost(&shape, &topo, &skew, 1, flops, A2aAlgo::Direct);
        let mut pl = Placement::identity(16, 1);
        pl.swap_experts(15, 0);
        let placed = step_cost_placed(&shape, &topo, &skew, &pl, flops, A2aAlgo::Direct, None);
        assert_ne!(placed.a2a_s, canon.a2a_s);
        // compute: max recv is the same set of column sums either way
        // (a permutation of devices), so the bound is unchanged
        assert_eq!(placed.compute_s, canon.compute_s);
    }

    #[test]
    fn overlapped_serial_mode_is_the_serial_clock() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let flops = device_flops('C');
        for algo in [A2aAlgo::Direct, A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn)] {
            let serial = step_cost(&shape, &topo, &ta, 1, flops, algo);
            assert_eq!(serial.step_s(), serial.serial_total(), "{algo}");
            assert_eq!(serial.chunks, 1, "{algo}");
            assert_eq!(serial.exposed_a2a_s, serial.a2a_s, "{algo}");
            assert_eq!(serial.overlap_efficiency(), 0.0, "{algo}");
            let ov = step_cost_overlapped(
                &shape,
                &topo,
                &ta,
                1,
                flops,
                algo,
                OverlapMode::Serial,
                None,
                None,
            );
            assert_eq!(ov.step_s(), serial.serial_total(), "{algo}");
            assert_eq!(ov.a2a_s, serial.a2a_s, "{algo}");
        }
    }

    #[test]
    fn overlapped_k1_reproduces_the_serial_price() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let flops = device_flops('C');
        for algo in [
            A2aAlgo::Direct,
            A2aAlgo::Hierarchical,
            A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn),
        ] {
            let serial = step_cost(&shape, &topo, &ta, 1, flops, algo);
            for cached in [false, true] {
                let mut cache = PlanCache::new(PLAN_CACHE_TOL);
                let c = step_cost_overlapped(
                    &shape,
                    &topo,
                    &ta,
                    1,
                    flops,
                    algo,
                    OverlapMode::Fixed(1),
                    if cached { Some(&mut cache) } else { None },
                    None,
                );
                let (got, want) = (c.step_s(), serial.serial_total());
                assert!(
                    (got - want).abs() <= 1e-12 * want,
                    "{algo} cached={cached}: {got} != {want}"
                );
                assert_eq!(c.chunks, 1);
            }
        }
    }

    #[test]
    fn overlapped_auto_never_exceeds_serial_and_memoises() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let flops = device_flops('C');
        let serial = step_cost(&shape, &topo, &ta, 1, flops, algo);
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        let auto = step_cost_overlapped(
            &shape,
            &topo,
            &ta,
            1,
            flops,
            algo,
            OverlapMode::Auto,
            Some(&mut cache),
            None,
        );
        // k = 1 is in the sweep, so auto can only improve on serial
        assert!(auto.step_s() <= serial.serial_total() * (1.0 + 1e-9));
        assert!(auto.chunks >= 1);
        assert!(auto.exposed_a2a_s <= auto.a2a_s * (1.0 + 1e-9));
        // the winner is memoised against the routed byte matrix
        // (e_per_dev = 1 ⇒ bytes = counts · token_bytes)
        let bytes = ta.scale(shape.token_bytes());
        assert_eq!(cache.tuned_k(&topo, &bytes, algo), Some(auto.chunks));
        let again = step_cost_overlapped(
            &shape,
            &topo,
            &ta,
            1,
            flops,
            algo,
            OverlapMode::Auto,
            Some(&mut cache),
            None,
        );
        assert_eq!(again.chunks, auto.chunks);
        assert_eq!(again.step_s(), auto.step_s());
        // a placement epoch bump drops the memo with the schedules
        cache.set_epoch(9);
        assert_eq!(cache.tuned_k(&topo, &bytes, algo), None);
    }

    #[test]
    fn chunk_breakdown_scales_like_the_plan() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let bytes = Mat::from_fn(16, 16, |i, j| ta.get(i, j) * shape.token_bytes());
        let algo = A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn);
        let mut cache = PlanCache::new(PLAN_CACHE_TOL);
        let full = cache.plan(&topo, &bytes, algo).breakdown;
        // k = 1 chunk is the full exchange, bit for bit
        assert_eq!(cache.chunk_breakdown(&topo, &bytes, algo, 1), full);
        // a 1/k chunk is cheaper than the full exchange but never cheaper
        // than 1/k of it (α terms do not shrink)
        for k in [2usize, 4, 8] {
            let c = cache.chunk_breakdown(&topo, &bytes, algo, k);
            assert!(c.total() < full.total(), "k={k}");
            assert!(c.total() >= full.total() / k as f64 * (1.0 - 1e-12), "k={k}");
        }
        // the disabled cache prices chunks from scratch: at k = 1 that is
        // exactly the planner's own price (synthesis decisions on a freshly
        // scaled chunk matrix may legitimately differ for k > 1)
        let cold = PlanCache::disabled();
        let c1 = cold.chunk_breakdown(&topo, &bytes, algo, 1);
        assert_eq!(c1, algo.plan(&topo, &bytes).breakdown);
        let c8 = cold.chunk_breakdown(&topo, &bytes, algo, 8);
        assert!(c8.total() > 0.0 && c8.total() < full.total());
    }

    #[test]
    fn gshard_moves_more_bytes_than_switch() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let s1 = ModelShape::gpt_medium(false, 6, 1024);
        let s2 = ModelShape { k: 2, ..s1 };
        let even1 = converged_counts(&FastMoeEven, &topo, &cfg);
        let even2 = even1.scale(2.0); // top-2 doubles dispatched tokens
        let c1 = step_cost(&s1, &topo, &even1, 1, device_flops('C'), A2aAlgo::Direct);
        let c2 = step_cost(&s2, &topo, &even2, 1, device_flops('C'), A2aAlgo::Direct);
        assert!(c2.a2a_s > c1.a2a_s * 1.5);
    }
}
