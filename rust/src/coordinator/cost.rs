//! Simulated step-time model: FLOPs + α-β communication on the cluster
//! clock.
//!
//! This is the clock behind every throughput/speedup figure (DESIGN.md §2):
//! compute comes from a FLOP count over the model shape divided by an
//! effective per-device rate, communication from the [`crate::comm`]
//! engine priced on the *actual* per-step dispatch counts `c_ie` (either
//! measured from a real training run or taken from
//! [`super::policy::converged_counts`] for paper-scale sweeps).
//!
//! Per training step we charge:
//! * forward + backward compute: 3× the forward FLOPs (standard estimate);
//! * per MoE layer: dispatch + combine all-to-all in forward and their
//!   mirror images in backward → 4 exchanges of the `c_ie` byte matrix;
//! * a ring allreduce of the dense (replicated) gradients.
//!
//! Expert compute is bottlenecked by the most-loaded device (the paper's
//! load-imbalance effect): `max_j Σ_{e on j} Σ_i c_ie`.

use crate::comm::{ring_allreduce_time, A2aAlgo, A2aBreakdown};
use crate::runtime::ModelCfg;
use crate::topology::Topology;
use crate::util::Mat;

/// Shape of the model whose step is being priced. Decoupled from the
/// compiled artifacts so paper-scale configs (GPT-Medium) can be priced on
/// the cost model while the trained artifacts stay CPU-sized.
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    pub layers: usize,
    pub d: usize,
    pub f: usize,
    pub vocab: usize,
    pub seq: usize,
    /// Tokens per device per step (S).
    pub tokens_per_dev: usize,
    pub k: usize,
    pub n_moe_layers: usize,
    /// Bytes per element on the wire (2 = fp16, 4 = fp32).
    pub elem_bytes: usize,
}

impl ModelShape {
    /// The paper's GPT-Medium MoE configs (Table 3).
    pub fn gpt_medium(gate_gshard: bool, batch: usize, seq: usize) -> ModelShape {
        ModelShape {
            layers: 12,
            d: 1024,
            f: if gate_gshard { 2048 } else { 4096 },
            vocab: 50_000,
            seq,
            tokens_per_dev: batch * seq,
            k: if gate_gshard { 2 } else { 1 },
            n_moe_layers: 6, // MoE every other layer
            elem_bytes: 2,   // FP16 on clusters A; B/C use 4 (see Table 3)
        }
    }

    /// From a compiled artifact's config (fp32 on this CPU testbed).
    pub fn from_cfg(cfg: &ModelCfg) -> ModelShape {
        ModelShape {
            layers: cfg.layers,
            d: cfg.d,
            f: cfg.f,
            vocab: cfg.vocab,
            seq: cfg.seq,
            tokens_per_dev: cfg.tokens_per_dev,
            k: cfg.k,
            n_moe_layers: cfg.n_moe_layers(),
            elem_bytes: 4,
        }
    }

    /// Forward FLOPs per token, dense portion (attention + embeddings +
    /// the dense FFN layers).
    pub fn dense_flops_per_token(&self) -> f64 {
        let d = self.d as f64;
        let f = self.f as f64;
        let t = self.seq as f64;
        let attn = 8.0 * d * d + 4.0 * t * d; // qkvo projections + scores/apply
        let dense_ffn = 4.0 * d * f; // the non-MoE layers
        let n_dense = (self.layers - self.n_moe_layers) as f64;
        let logits = 2.0 * self.vocab as f64 * d;
        self.layers as f64 * attn + n_dense * dense_ffn + logits
    }

    /// Forward FLOPs per *dispatched* token inside one expert.
    pub fn expert_flops_per_token(&self) -> f64 {
        4.0 * self.d as f64 * self.f as f64
    }

    /// Bytes of the replicated (dense) parameters, for the allreduce.
    pub fn dense_param_bytes(&self) -> f64 {
        let d = self.d as f64;
        let f = self.f as f64;
        let attn = 4.0 * d * d;
        let n_dense = (self.layers - self.n_moe_layers) as f64;
        let embed = self.vocab as f64 * d;
        (self.layers as f64 * attn + n_dense * 2.0 * d * f + embed) * self.elem_bytes as f64
    }
}

/// Effective sustained FLOP/s per device for the paper's clusters
/// (roofline × a realistic MFU for MoE training).
pub fn device_flops(cluster: char) -> f64 {
    match cluster.to_ascii_uppercase() {
        'A' => 120e12, // A100 fp16 (312 peak × ~0.38 MFU)
        _ => 45e12,    // V100 (125 peak fp16 × ~0.36; paper runs fp32 on B/C,
                       // absorbed into the same effective rate)
    }
}

/// Per-step cost breakdown on the simulated cluster clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCost {
    pub compute_s: f64,
    /// Total all-to-all time; equals `a2a.total()`.
    pub a2a_s: f64,
    pub allreduce_s: f64,
    /// Per-phase all-to-all split (local / intra-node / inter-node).
    pub a2a: A2aBreakdown,
}

impl StepCost {
    pub fn total(&self) -> f64 {
        self.compute_s + self.a2a_s + self.allreduce_s
    }
}

/// Price one training step.
///
/// `counts` is the per-MoE-layer dispatch matrix `c_ie` in tokens
/// (P×N). `a2a` selects how the dispatch/combine exchanges execute on
/// the wire (see [`A2aAlgo`]).
pub fn step_cost(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
) -> StepCost {
    let p = topo.p();
    assert_eq!(counts.rows(), p);
    let n = counts.cols();
    assert_eq!(n, p * e_per_dev);

    // --- compute: slowest device bounds the step ---------------------------
    let dense = shape.dense_flops_per_token() * shape.tokens_per_dev as f64;
    let max_recv: f64 = (0..p)
        .map(|j| {
            (0..e_per_dev)
                .map(|le| counts.col_sum(j * e_per_dev + le))
                .sum::<f64>()
        })
        .fold(0.0, f64::max);
    let expert = shape.expert_flops_per_token() * max_recv * shape.n_moe_layers as f64;
    let fwd_flops = dense + expert;
    let compute_s = 3.0 * fwd_flops / flops_per_dev; // fwd + bwd ≈ 3× fwd

    // --- all-to-all: 4 exchanges of the c_ie bytes per MoE layer -----------
    let bytes = Mat::from_fn(p, p, |i, j| {
        let mut tok = 0.0;
        for le in 0..e_per_dev {
            tok += counts.get(i, j * e_per_dev + le);
        }
        tok * (shape.d * shape.elem_bytes) as f64
    });
    let plan = a2a.plan(topo, &bytes);
    let breakdown = plan.breakdown.scale(4.0 * shape.n_moe_layers as f64);
    let a2a_s = breakdown.total();

    // --- dense gradient allreduce ------------------------------------------
    let allreduce_s = ring_allreduce_time(topo, shape.dense_param_bytes());

    StepCost { compute_s, a2a_s, allreduce_s, a2a: breakdown }
}

/// Throughput in tokens/s for a converged dispatch pattern.
pub fn throughput(
    shape: &ModelShape,
    topo: &Topology,
    counts: &Mat,
    e_per_dev: usize,
    flops_per_dev: f64,
    a2a: A2aAlgo,
) -> f64 {
    let cost = step_cost(shape, topo, counts, e_per_dev, flops_per_dev, a2a);
    topo.p() as f64 * shape.tokens_per_dev as f64 / cost.total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{converged_counts, FastMoeEven, TaMoe};
    use crate::dispatch::Norm;
    use crate::topology::presets;

    fn cfg16() -> ModelCfg {
        ModelCfg {
            p: 16,
            e_per_dev: 1,
            layers: 12,
            d: 1024,
            f: 4096,
            heads: 16,
            vocab: 50_000,
            batch: 6,
            seq: 1024,
            k: 1,
            cap_factor: 1.0,
            gate: "switch".into(),
            dispatch: "local".into(),
            n_experts: 16,
            capacity: 6 * 1024,
            tokens_per_dev: 6 * 1024,
            moe_layer_ids: (0..6).map(|i| i * 2 + 1).collect(),
        }
    }

    #[test]
    fn tamoe_throughput_beats_even_on_cluster_c() {
        // The fig4 headline direction, at GPT-Medium scale on 2 nodes.
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let t_even = throughput(&shape, &topo, &even, 1, device_flops('C'), A2aAlgo::Direct);
        let t_ta = throughput(&shape, &topo, &ta, 1, device_flops('C'), A2aAlgo::Direct);
        let speedup = t_ta / t_even;
        assert!(speedup > 1.02, "speedup {speedup}");
        assert!(speedup < 6.0, "speedup {speedup} implausibly large");
    }

    #[test]
    fn compute_dominates_on_single_node() {
        let topo = presets::cluster_a(1);
        let cfg = ModelCfg { p: 8, n_experts: 8, ..cfg16() };
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let c = step_cost(&shape, &topo, &even, 1, device_flops('A'), A2aAlgo::Direct);
        assert!(c.compute_s > c.a2a_s, "{c:?}");
    }

    #[test]
    fn imbalanced_experts_slow_compute() {
        let topo = presets::cluster_b(1);
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = Mat::filled(8, 8, 768.0);
        // all tokens crowd expert 0
        let mut skew = Mat::zeros(8, 8);
        for i in 0..8 {
            skew.set(i, 0, 6144.0);
        }
        let c_even = step_cost(&shape, &topo, &even, 1, device_flops('B'), A2aAlgo::Direct);
        let c_skew = step_cost(&shape, &topo, &skew, 1, device_flops('B'), A2aAlgo::Direct);
        assert!(c_skew.compute_s > c_even.compute_s * 2.0);
    }

    #[test]
    fn a2a_algo_changes_a2a_only() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let shape = ModelShape::gpt_medium(false, 6, 1024);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let dir = step_cost(&shape, &topo, &even, 1, device_flops('C'), A2aAlgo::Direct);
        for algo in [
            A2aAlgo::Hierarchical,
            A2aAlgo::Scheduled(crate::comm::ScheduleKind::Rotation),
            A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn),
        ] {
            let c = step_cost(&shape, &topo, &even, 1, device_flops('C'), algo);
            assert_eq!(dir.compute_s, c.compute_s, "{algo}");
            assert_eq!(dir.allreduce_s, c.allreduce_s, "{algo}");
            assert_ne!(dir.a2a_s, c.a2a_s, "{algo}");
            assert!((c.a2a.total() - c.a2a_s).abs() < 1e-15, "{algo}");
        }
    }

    #[test]
    fn gshard_moves_more_bytes_than_switch() {
        let topo = presets::cluster_c(2);
        let cfg = cfg16();
        let s1 = ModelShape::gpt_medium(false, 6, 1024);
        let s2 = ModelShape { k: 2, ..s1 };
        let even1 = converged_counts(&FastMoeEven, &topo, &cfg);
        let even2 = even1.scale(2.0); // top-2 doubles dispatched tokens
        let c1 = step_cost(&s1, &topo, &even1, 1, device_flops('C'), A2aAlgo::Direct);
        let c2 = step_cost(&s2, &topo, &even2, 1, device_flops('C'), A2aAlgo::Direct);
        assert!(c2.a2a_s > c1.a2a_s * 1.5);
    }
}
