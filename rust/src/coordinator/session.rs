//! [`Session`]: one training run = topology + policy + backend + data +
//! metrics, assembled by [`SessionBuilder`].
//!
//! The builder is the crate's front door (DESIGN.md §api). Everything is
//! optional except an execution backend (or an artifact name for
//! [`crate::runtime::open_backend`] to resolve):
//!
//! ```
//! use ta_moe::coordinator::SessionBuilder;
//! use ta_moe::runtime::{ModelCfg, SimBackend};
//!
//! let mut session = SessionBuilder::new()
//!     .backend(Box::new(SimBackend::new(ModelCfg::preset("tiny4").unwrap())))
//!     .cluster("C")
//!     .policy_named("ta-moe")
//!     .lr(2e-3)
//!     .build()
//!     .unwrap();
//! let log = session.run(5).unwrap();
//! assert_eq!(log.records.len(), 5);
//! ```
//!
//! Per step the session feeds the next batch to the backend, reads back
//! the gate statistics `c_ie`, and charges the step to the simulated
//! cluster clock via [`super::cost::step_cost_overlapped`] using the
//! *measured* dispatch counts — the simulated time axis therefore
//! reflects what the gate actually learned, not what the policy hoped
//! for. With the default [`OverlapMode::Serial`] the clock is the
//! historic serial phase sum; `--overlap k=<n>|auto` charges the chunked
//! pipeline's makespan instead (`sim_comm_s` then records the *exposed*
//! communication).

use super::cost::{ModelShape, PlanCache, StepProfile, PLAN_CACHE_TOL};
use super::policy::{DispatchPolicy, PolicyInputs, TaMoe};
use super::registry::parse_policy;
use super::workload::{trace_migration, Workload, WorkloadCore};
use crate::comm::A2aAlgo;
use crate::config::topology_for;
use crate::data::{Batcher, SyntheticCorpus};
use crate::metrics::{MigrationRecord, PerturbationRecord, RunLog, StepRecord};
use crate::overlap::OverlapMode;
use crate::perturb::ChaosSpec;
use crate::placement::{Placement, PlacementConfig};
use crate::runtime::{open_backend, Backend, BackendKind, HostTensor};
use crate::topology::Topology;
use crate::trace::{TraceLevel, Tracer};
use crate::util::Mat;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Scalar knobs of a session.
#[derive(Clone, Debug)]
pub struct SessionOptions {
    pub lr: f32,
    pub seed: i32,
    /// Effective device FLOP/s for the simulated clock.
    pub flops_per_dev: f64,
    /// Run a held-out eval every n steps inside [`Session::run`] (0 = off).
    pub eval_every: usize,
    /// Relative drift tolerance of the step-level [`PlanCache`]
    /// (≤ 0 disables caching: every step re-synthesises its a2a schedule).
    pub plan_cache_tol: f64,
    /// Topology- and load-aware expert placement with amortised live
    /// migration (`None` = canonical hosting forever).
    pub placement: Option<PlacementConfig>,
    /// How the step clock is priced: serially (the historic upper bound),
    /// as a fixed-`k` chunk pipeline, or chunk-count-autotuned
    /// (see [`crate::overlap`]).
    pub overlap: OverlapMode,
    /// Scripted fault stream (`off` = the clean run, bit-identical to a
    /// session without the engine; see [`crate::perturb`]).
    pub chaos: ChaosSpec,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            lr: 1e-3,
            seed: 0,
            flops_per_dev: 45e12,
            eval_every: 0,
            plan_cache_tol: PLAN_CACHE_TOL,
            placement: None,
            overlap: OverlapMode::Serial,
            chaos: ChaosSpec::off(),
        }
    }
}

/// Where the session's token stream comes from.
#[derive(Clone, Debug)]
pub enum DataSource {
    /// Deterministic Zipf/Markov corpus (the default; seeded).
    Synthetic { seed: u64 },
    /// UTF-8 text, byte-tokenised and tiled.
    Text(String),
    /// A pre-tokenised stream.
    Stream(Vec<i32>),
}

/// Builder for [`Session`]. Construction errors (unknown policy name,
/// missing artifact, world-size mismatch) surface in [`build`].
///
/// [`build`]: SessionBuilder::build
#[derive(Default)]
pub struct SessionBuilder {
    backend: Option<Box<dyn Backend>>,
    artifact: Option<(PathBuf, String)>,
    backend_kind: BackendKind,
    topo: Option<Topology>,
    cluster: Option<String>,
    policy: Option<Box<dyn DispatchPolicy>>,
    policy_spec: Option<String>,
    a2a: Option<A2aAlgo>,
    a2a_spec: Option<String>,
    overlap_spec: Option<String>,
    chaos_spec: Option<String>,
    trace_level: Option<TraceLevel>,
    data: Option<DataSource>,
    opts: SessionOptions,
}

impl SessionBuilder {
    pub fn new() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Use an explicit execution backend (overrides [`artifact`]).
    ///
    /// [`artifact`]: SessionBuilder::artifact
    pub fn backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Resolve the backend from an artifact name at build time via
    /// [`open_backend`] (respects [`backend_kind`]).
    ///
    /// [`backend_kind`]: SessionBuilder::backend_kind
    pub fn artifact(mut self, artifacts_dir: impl Into<PathBuf>, name: impl Into<String>) -> Self {
        self.artifact = Some((artifacts_dir.into(), name.into()));
        self
    }

    /// Which engine [`artifact`] resolution opens (default: `Auto`).
    ///
    /// [`artifact`]: SessionBuilder::artifact
    pub fn backend_kind(mut self, kind: BackendKind) -> Self {
        self.backend_kind = kind;
        self
    }

    /// Use an explicit topology (must match the model's world size).
    pub fn topology(mut self, topo: Topology) -> Self {
        self.topo = Some(topo);
        self
    }

    /// Use a cluster preset ("A" | "B" | "C" | "table1"), scaled to the
    /// model's world size at build time. Default: "C".
    pub fn cluster(mut self, preset: impl Into<String>) -> Self {
        self.cluster = Some(preset.into());
        self
    }

    /// Use an explicit dispatch policy (overrides [`policy_named`]).
    ///
    /// [`policy_named`]: SessionBuilder::policy_named
    pub fn policy(mut self, policy: Box<dyn DispatchPolicy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Look the policy up in the registry at build time
    /// (e.g. `"ta-moe:softmax:2"`). Default: `"ta-moe"`.
    pub fn policy_named(mut self, spec: impl Into<String>) -> Self {
        self.policy_spec = Some(spec.into());
        self
    }

    /// Execute (and price) the MoE all-to-all with this plan, overriding
    /// the policy's [`DispatchPolicy::preferred_a2a`].
    pub fn a2a(mut self, algo: A2aAlgo) -> Self {
        self.a2a = Some(algo);
        self
    }

    /// Parse the a2a plan from a spec at build time
    /// (`direct | hier | sched:xor | sched:rot | sched:bvn`).
    pub fn a2a_named(mut self, spec: impl Into<String>) -> Self {
        self.a2a_spec = Some(spec.into());
        self
    }

    /// Price the step clock on the chunked overlap timeline
    /// (see [`OverlapMode`]; the default is the serial clock).
    pub fn overlap(mut self, mode: OverlapMode) -> Self {
        self.opts.overlap = mode;
        self
    }

    /// Parse the overlap mode from a spec at build time
    /// (`off | serial | k=<n> | auto`).
    pub fn overlap_named(mut self, spec: impl Into<String>) -> Self {
        self.overlap_spec = Some(spec.into());
        self
    }

    /// Inject this scripted fault stream (see [`ChaosSpec`]).
    pub fn chaos(mut self, spec: ChaosSpec) -> Self {
        self.opts.chaos = spec;
        self
    }

    /// Parse the fault stream from a `--chaos` spec at build time
    /// (`off`, or `+`-joined `straggler:…`, `link:…`, `nodeloss:…`,
    /// `drift:…` events).
    pub fn chaos_named(mut self, spec: impl Into<String>) -> Self {
        self.chaos_spec = Some(spec.into());
        self
    }

    /// Attach the deterministic tracer at this level: the run records
    /// phase/link spans and counters on the simulated clock (see
    /// [`crate::trace`]). Default: no tracer, zero overhead.
    pub fn trace_level(mut self, level: TraceLevel) -> Self {
        self.trace_level = Some(level);
        self
    }

    /// Train on the deterministic synthetic corpus with this seed.
    pub fn data_synthetic(mut self, seed: u64) -> Self {
        self.data = Some(DataSource::Synthetic { seed });
        self
    }

    /// Train on byte-tokenised text (tiled if short).
    pub fn data_text(mut self, text: impl Into<String>) -> Self {
        self.data = Some(DataSource::Text(text.into()));
        self
    }

    /// Train on a pre-tokenised stream.
    pub fn data_stream(mut self, stream: Vec<i32>) -> Self {
        self.data = Some(DataSource::Stream(stream));
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.opts.lr = lr;
        self
    }

    pub fn seed(mut self, seed: i32) -> Self {
        self.opts.seed = seed;
        self
    }

    pub fn flops_per_dev(mut self, flops: f64) -> Self {
        self.opts.flops_per_dev = flops;
        self
    }

    /// Held-out eval cadence inside [`Session::run`] (0 = off).
    pub fn eval_every(mut self, every: usize) -> Self {
        self.opts.eval_every = every;
        self
    }

    /// Relative drift tolerance of the step-level plan cache; pass a value
    /// ≤ 0 to disable caching (every step re-synthesises its schedule).
    pub fn plan_cache_tol(mut self, tol: f64) -> Self {
        self.opts.plan_cache_tol = tol;
        self
    }

    /// Enable topology- and load-aware expert placement with this full
    /// configuration (see [`PlacementConfig`]).
    pub fn placement(mut self, cfg: PlacementConfig) -> Self {
        self.opts.placement = Some(cfg);
        self
    }

    /// Enable expert placement with default knobs, attempting a
    /// re-placement every `every` steps (0 disables placement entirely —
    /// the canonical hosting is kept for the whole run).
    pub fn placement_every(mut self, every: usize) -> Self {
        self.opts.placement = if every == 0 {
            None
        } else {
            Some(PlacementConfig { every, ..Default::default() })
        };
        self
    }

    pub fn options(mut self, opts: SessionOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Assemble the session: resolve backend and policy, check the
    /// topology against the model's world size, compute the policy's gate
    /// inputs, initialise the backend, and set up the data pipeline.
    pub fn build(self) -> Result<Session> {
        let mut label_model = None;
        let mut backend = match (self.backend, self.artifact) {
            (Some(b), _) => b,
            (None, Some((dir, name))) => {
                label_model = Some(name.clone());
                open_backend(self.backend_kind, &dir, &name)
                    .with_context(|| format!("opening backend for artifact {name:?}"))?
            }
            (None, None) => anyhow::bail!(
                "SessionBuilder needs .backend(...) or .artifact(dir, name)"
            ),
        };
        let cfg = backend.model_cfg().clone();

        let topo = match (self.topo, self.cluster) {
            (Some(t), _) => t,
            (None, Some(c)) => topology_for(&c, cfg.p),
            (None, None) => topology_for("C", cfg.p),
        };
        anyhow::ensure!(
            topo.p() == cfg.p,
            "topology has {} devices, model wants {}",
            topo.p(),
            cfg.p
        );

        let policy: Box<dyn DispatchPolicy> = match (self.policy, self.policy_spec) {
            (Some(p), _) => p,
            (None, Some(spec)) => parse_policy(&spec).map_err(anyhow::Error::msg)?,
            (None, None) => Box::new(TaMoe::default()),
        };

        let a2a = match (self.a2a, self.a2a_spec) {
            (Some(a), _) => a,
            (None, Some(spec)) => spec.parse::<A2aAlgo>().map_err(anyhow::Error::msg)?,
            (None, None) => policy.preferred_a2a(),
        };
        a2a.validate_for(topo.p()).map_err(anyhow::Error::msg)?;

        let mut opts = self.opts;
        if let Some(spec) = self.overlap_spec {
            opts.overlap = spec.parse::<OverlapMode>().map_err(anyhow::Error::msg)?;
        }
        if let Some(spec) = self.chaos_spec {
            opts.chaos = spec.parse::<ChaosSpec>().map_err(anyhow::Error::msg)?;
        }
        anyhow::ensure!(
            opts.overlap != OverlapMode::Fixed(0),
            "overlap chunk count must be >= 1"
        );

        let inputs = policy.runtime_inputs(&topo, &cfg);
        backend.init(opts.seed, &inputs.gate)?;

        // data pipeline: training stream + one held-out eval batch drawn
        // from the same distribution. Synthetic data gets a disjoint
        // corpus (different seed); for text/stream sources the first batch
        // becomes the eval batch and training starts from the second.
        let min_len = cfg.p * cfg.batch * (cfg.seq + 1);
        let data = self
            .data
            .unwrap_or(DataSource::Synthetic { seed: opts.seed as u64 });
        let (batcher, eval_batch) = match data {
            DataSource::Synthetic { seed } => {
                let stream = SyntheticCorpus::new(seed).tokens(min_len * 64);
                let eval_seed = seed.wrapping_add(7777);
                let eval_stream = SyntheticCorpus::new(eval_seed).tokens(min_len * 8);
                let eval = Batcher::new(eval_stream, cfg.p, cfg.batch, cfg.seq).next_batch();
                (Batcher::new(stream, cfg.p, cfg.batch, cfg.seq), eval)
            }
            DataSource::Text(text) => {
                let mut b = Batcher::from_text(&text, cfg.p, cfg.batch, cfg.seq);
                let eval = b.next_batch();
                (b, eval)
            }
            DataSource::Stream(stream) => {
                anyhow::ensure!(
                    stream.len() > min_len,
                    "data stream has {} tokens, one batch needs > {min_len}",
                    stream.len()
                );
                let mut b = Batcher::new(stream, cfg.p, cfg.batch, cfg.seq);
                let eval = b.next_batch();
                (b, eval)
            }
        };

        let label = format!(
            "{}/{}",
            label_model.unwrap_or_else(|| backend.name().to_string()),
            policy.name()
        );
        let shape = ModelShape::from_cfg(&cfg);
        let tokens_per_step = cfg.p * cfg.tokens_per_dev;
        // the shared pricing state: plan cache, placement engine, overlap
        // clock — one training step exchanges the c_ie byte matrix
        // 4 · n_moe times (dispatch + combine, forward + backward)
        let mut core = WorkloadCore::new(
            topo,
            shape,
            a2a,
            opts.overlap,
            opts.flops_per_dev,
            cfg.e_per_dev,
            StepProfile::train(),
            opts.plan_cache_tol,
            opts.placement.clone(),
        )
        .with_chaos(opts.chaos.clone())?;
        if let Some(level) = self.trace_level {
            core.attach_tracer(level);
        }
        Ok(Session {
            backend,
            policy,
            inputs,
            core,
            opts,
            batcher,
            eval_batch,
            log: RunLog::new(&label, tokens_per_step),
            last_counts: None,
        })
    }
}

/// A fully-assembled training run over one backend, one topology, and one
/// dispatch policy. Replaces the old `Trainer`. The pricing half
/// (topology, plan cache, placement engine, overlap clock) lives in a
/// [`WorkloadCore`] shared with the serving simulator.
pub struct Session {
    backend: Box<dyn Backend>,
    policy: Box<dyn DispatchPolicy>,
    inputs: PolicyInputs,
    core: WorkloadCore,
    opts: SessionOptions,
    batcher: Batcher,
    eval_batch: (Vec<i32>, Vec<i32>),
    log: RunLog,
    last_counts: Option<Mat>,
}

impl Session {
    /// Train `steps` steps on the session's data source, running the
    /// held-out eval every `eval_every` steps (if configured). Returns the
    /// accumulated log.
    pub fn run(&mut self, steps: usize) -> Result<&RunLog> {
        for i in 0..steps {
            self.step()?;
            if self.opts.eval_every > 0 && (i + 1) % self.opts.eval_every == 0 {
                self.eval_held_out()?;
            }
        }
        Ok(&self.log)
    }

    /// One training step on the next batch from the session's data source.
    pub fn step(&mut self) -> Result<StepRecord> {
        let (tok, tgt) = self.batcher.next_batch();
        self.train_step(&tok, &tgt)
    }

    /// One training step on caller-provided `[P, B, T]` token/target ids;
    /// prices the step on the simulated cluster clock and logs it.
    pub fn train_step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<StepRecord> {
        let (tok, tgt) = self.batch_tensors(tokens, targets)?;
        // Host wall-clock for the wall_s observability metric only: it never
        // feeds the simulated clock or any priced decision.
        #[allow(clippy::disallowed_methods)]
        // pallas-lint: allow(determinism) -- wall_s observability metric only; never priced
        let wall0 = std::time::Instant::now();
        let out = self.backend.train_step(&tok, &tgt, self.opts.lr)?;
        let wall_s = wall0.elapsed().as_secs_f64();

        // chaos: the fault stream fires first — topology mutations and
        // the elastic re-scale happen before the gate loads are observed,
        // so the EWMA, the migration gate, and the pricing all see the
        // perturbed world (exactly what a real job would measure). An
        // emergency evacuation is charged like an accepted migration.
        let mut counts = out.counts;
        let mut migration_s = 0.0;
        let mut rehosted = false;
        // step start on the tracer's simulated clock (migrations advance
        // it before pricing: the stall precedes this step's exchanges)
        let step_t0 = self.core.tracer().map(|t| t.clock_s());
        if let Some(report) = self.core.chaos_step(&mut counts) {
            for ev in &report.events {
                self.log.push_perturbation(PerturbationRecord {
                    step: self.log.records.len(),
                    event: ev.clone(),
                });
            }
            if let Some(tr) = self.core.tracer_mut() {
                let t = tr.clock_s();
                for ev in &report.events {
                    tr.instant("step", ev, "chaos", t, &[]);
                }
                tr.registry_mut().inc("perturbations_total", report.events.len() as u64);
            }
            if let Some(m) = &report.migration {
                migration_s += m.cost_s;
                rehosted = true;
                self.log.push_migration(MigrationRecord {
                    step: self.log.records.len(),
                    moved: m.moved.len(),
                    bytes: m.bytes,
                    cost_s: m.cost_s,
                    predicted_saving_s: m.predicted_saving_s,
                    realized_saving_s: m.realized_saving_s,
                });
                if let Some(tr) = self.core.tracer_mut() {
                    trace_migration(tr, m.bytes, m.cost_s);
                }
            }
        }

        // placement: fold the measured loads in and, at the engine's
        // cadence, migrate experts when the move amortises. Step-time
        // semantics: gating (which produced `counts`) precedes dispatch,
        // so a migration decided here happens *between* them — the step
        // stalls for the weight transfer (its cost is charged to this
        // step's clock) and this step's a2a exchanges then run under the
        // NEW placement. A migration additionally
        // (a) invalidates cached a2a schedules via the placement epoch,
        // (b) re-points the policy inputs (mask, and for topology-aware
        //     policies the target/penalty) at the new hosting — live,
        //     without resetting the backend's training state.
        self.core.observe(&counts);
        if let Some(m) = self.core.maybe_migrate(&counts) {
            migration_s += m.cost_s;
            rehosted = true;
            self.log.push_migration(MigrationRecord {
                step: self.log.records.len(),
                moved: m.moved.len(),
                bytes: m.bytes,
                cost_s: m.cost_s,
                predicted_saving_s: m.predicted_saving_s,
                realized_saving_s: m.realized_saving_s,
            });
            if let Some(tr) = self.core.tracer_mut() {
                trace_migration(tr, m.bytes, m.cost_s);
            }
        }
        if rehosted {
            let mcfg = self.backend.model_cfg().clone();
            let placement = self.core.placement().expect("migration implies placement");
            let new_inputs =
                self.policy.runtime_inputs_placed(self.core.topology(), &mcfg, placement);
            self.backend.update_gate(&new_inputs.gate)?;
            self.inputs = new_inputs;
        }

        let hits_before = self.core.plan_cache().hits();
        // one pricing path for every (placement × overlap) combination:
        // serial mode reproduces the historic clock exactly, overlap
        // modes charge the chunked timeline's makespan instead (the
        // exposed communication replaces the serial a2a + allreduce sum)
        let cost = self.core.price(&counts);
        let record = StepRecord {
            step: self.log.records.len(),
            loss: out.loss,
            ce: out.ce,
            aux: out.aux,
            dropped: out.dropped,
            sim_comm_s: cost.step_s() - cost.compute_s,
            sim_compute_s: cost.compute_s,
            sim_a2a_local_s: cost.a2a.local_s,
            sim_a2a_intra_s: cost.a2a.intra_s,
            sim_a2a_inter_s: cost.a2a.inter_s,
            sim_serial_s: cost.serial_total(),
            sim_a2a_exposed_s: cost.exposed_a2a_s,
            chunks: cost.chunks,
            plan_cached: self.core.plan_cache().hits() > hits_before,
            sim_migration_s: migration_s,
            wall_s,
            ..Default::default()
        };
        if let (Some(t0), Some(tr)) = (step_t0, self.core.tracer_mut()) {
            // migrations already advanced the clock past t0; the span
            // covers the whole step including those stalls
            let dur = (tr.clock_s() - t0) + cost.step_s();
            tr.span(
                "step",
                &format!("step {}", record.step),
                "step",
                t0,
                dur,
                &[("loss", record.loss)],
            );
            tr.advance(cost.step_s());
        }
        self.last_counts = Some(counts);
        self.log.plan_hits = self.core.plan_cache().hits();
        self.log.plan_misses = self.core.plan_cache().misses();
        self.log.push(record.clone());
        Ok(record)
    }

    /// Validation pass on a caller-provided batch; logs the loss against
    /// the number of completed training steps (0 = before any training,
    /// so a pre-train eval is never attributed to step 0's record) and
    /// returns (ce_loss, counts).
    pub fn eval(&mut self, tokens: &[i32], targets: &[i32]) -> Result<(f64, Mat)> {
        let (tok, tgt) = self.batch_tensors(tokens, targets)?;
        let out = self.backend.eval(&tok, &tgt)?;
        let steps_done = self.log.records.len();
        self.log.push_eval(steps_done, out.ce);
        Ok((out.ce, out.counts))
    }

    /// Validation pass on the session's held-out batch.
    pub fn eval_held_out(&mut self) -> Result<(f64, Mat)> {
        let (tok, tgt) = self.eval_batch.clone();
        self.eval(&tok, &tgt)
    }

    fn batch_tensors(&self, tokens: &[i32], targets: &[i32]) -> Result<(HostTensor, HostTensor)> {
        let cfg = self.backend.model_cfg();
        let shape = [cfg.p, cfg.batch, cfg.seq];
        let numel: usize = shape.iter().product();
        anyhow::ensure!(
            tokens.len() == numel && targets.len() == numel,
            "batch has {}/{} tokens, model wants {numel}",
            tokens.len(),
            targets.len()
        );
        Ok((
            HostTensor::i32(tokens.to_vec(), &shape),
            HostTensor::i32(targets.to_vec(), &shape),
        ))
    }

    // -- accessors ----------------------------------------------------------

    pub fn model_cfg(&self) -> &crate::runtime::ModelCfg {
        self.backend.model_cfg()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn policy(&self) -> &dyn DispatchPolicy {
        self.policy.as_ref()
    }

    /// The all-to-all plan the session's step-time model executes.
    pub fn a2a_algo(&self) -> A2aAlgo {
        self.core.a2a_algo()
    }

    /// How the session's step clock is priced (see [`OverlapMode`]).
    pub fn overlap_mode(&self) -> OverlapMode {
        self.core.overlap_mode()
    }

    /// The gate inputs + target the policy produced for this run.
    pub fn policy_inputs(&self) -> &PolicyInputs {
        &self.inputs
    }

    pub fn topology(&self) -> &Topology {
        self.core.topology()
    }

    pub fn log(&self) -> &RunLog {
        &self.log
    }

    /// Mean per-MoE-layer dispatch counts of the most recent step.
    pub fn last_counts(&self) -> Option<&Mat> {
        self.last_counts.as_ref()
    }

    /// The session's step-level a2a schedule cache (hit/miss counters).
    pub fn plan_cache(&self) -> &PlanCache {
        self.core.plan_cache()
    }

    /// The live expert→device map (None when placement is disabled).
    pub fn placement(&self) -> Option<&Placement> {
        self.core.placement()
    }

    /// Accepted migrations so far (0 when placement is disabled).
    pub fn placement_epoch(&self) -> u64 {
        self.core.placement_epoch()
    }

    /// The attached event sink, if the session was built with
    /// [`SessionBuilder::trace_level`].
    pub fn tracer(&self) -> Option<&Tracer> {
        self.core.tracer()
    }
}

impl Workload for Session {
    fn step(&mut self) -> Result<StepRecord> {
        Session::step(self)
    }

    fn log(&self) -> &RunLog {
        &self.log
    }

    fn core(&self) -> &WorkloadCore {
        &self.core
    }
}
