//! Dispatch strategies: the systems the paper compares (§5 Methodology).
//!
//! A strategy decides the four runtime inputs of the compiled model —
//! penalty matrix (which aux loss), capacity matrix, intra-node mask, and
//! the Hir remote fraction — plus which all-to-all schedule its timing
//! model uses. TA-MoE composes with either host system exactly as §4.3
//! describes: on FastMoE it swaps the loss, on DeepSpeed-MoE it also makes
//! the local capacities proportional to `ĉ`.

use crate::dispatch::{
    baseline_penalty_matrix, even_caps, proportional_caps, target_pattern,
    topo_penalty_matrix, DispatchProblem, Norm, TargetPattern,
};
use crate::runtime::ModelCfg;
use crate::topology::Topology;
use crate::util::Mat;

/// Which MoE system drives the gate/capacity inputs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// DeepSpeed-MoE: even local capacities `C/P`, load-balance loss,
    /// hierarchical all-to-all.
    DeepSpeedEven,
    /// FastMoE: global per-expert capacity with size exchange, load-balance
    /// loss, direct all-to-all.
    FastMoeEven,
    /// FasterMoE's Hir gate: compulsory intra-node ratio (1 − remote_frac).
    FasterMoeHir { remote_frac: f64 },
    /// TA-MoE (this paper): topology-aware loss, and on local-capacity
    /// hosts, `C_ie ∝ ĉ_ie`.
    TaMoe { norm: Norm },
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::DeepSpeedEven => "deepspeed".into(),
            Strategy::FastMoeEven => "fastmoe".into(),
            Strategy::FasterMoeHir { remote_frac } => format!("fastermoe-hir{remote_frac}"),
            Strategy::TaMoe { norm: Norm::L1 } => "ta-moe".into(),
            Strategy::TaMoe { norm: Norm::Softmax { temp } } => format!("ta-moe-sm{temp}"),
        }
    }

    /// Parse a CLI/config name: `deepspeed|fastmoe|fastermoe[:frac]|ta-moe[:softmax[:temp]]`.
    pub fn parse(s: &str) -> Result<Strategy, String> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "deepspeed" | "deepspeed-moe" => Ok(Strategy::DeepSpeedEven),
            "fastmoe" => Ok(Strategy::FastMoeEven),
            "fastermoe" | "fastermoe-hir" | "hir" => {
                let frac = parts
                    .get(1)
                    .map(|p| p.parse::<f64>().map_err(|e| e.to_string()))
                    .transpose()?
                    .unwrap_or(0.25);
                Ok(Strategy::FasterMoeHir { remote_frac: frac })
            }
            "ta-moe" | "tamoe" => {
                if parts.get(1) == Some(&"softmax") {
                    let temp = parts
                        .get(2)
                        .map(|p| p.parse::<f64>().map_err(|e| e.to_string()))
                        .transpose()?
                        .unwrap_or(2.0);
                    Ok(Strategy::TaMoe { norm: Norm::Softmax { temp } })
                } else {
                    Ok(Strategy::TaMoe { norm: Norm::L1 })
                }
            }
            other => Err(format!(
                "unknown strategy {other:?} (deepspeed|fastmoe|fastermoe[:frac]|ta-moe)"
            )),
        }
    }

    /// Does this strategy use the topology-aware loss?
    pub fn is_topology_aware(&self) -> bool {
        matches!(self, Strategy::TaMoe { .. })
    }

    /// Does its timing model use the hierarchical all-to-all?
    pub fn hierarchical_a2a(&self) -> bool {
        matches!(self, Strategy::DeepSpeedEven)
    }

    /// The Eq. 7 target pattern this strategy steers toward (TA-MoE only).
    pub fn target(&self, topo: &Topology, cfg: &ModelCfg) -> Option<TargetPattern> {
        if !self.is_topology_aware() {
            return None;
        }
        let prob = DispatchProblem {
            k: cfg.k,
            s: cfg.tokens_per_dev,
            e_per_dev: cfg.e_per_dev,
            elem_bytes: cfg.token_bytes(),
        };
        Some(target_pattern(topo, &prob))
    }

    /// Build the model's runtime inputs for this strategy on a topology.
    pub fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> StrategyInputs {
        assert_eq!(topo.p(), cfg.p, "topology/model world-size mismatch");
        let p = cfg.p;
        let n = cfg.n_experts;
        let local_mask = topo.local_mask(n, cfg.e_per_dev);
        match self {
            Strategy::DeepSpeedEven | Strategy::FastMoeEven => StrategyInputs {
                penalty: baseline_penalty_matrix(p, n),
                caps: even_caps(p, n, cfg.capacity),
                local_mask,
                hir_remote_frac: 1.0, // unused by switch/gshard gates
                target: None,
            },
            Strategy::FasterMoeHir { remote_frac } => StrategyInputs {
                penalty: baseline_penalty_matrix(p, n),
                caps: even_caps(p, n, cfg.capacity),
                local_mask,
                hir_remote_frac: *remote_frac as f32,
                target: None,
            },
            Strategy::TaMoe { norm } => {
                let tp = self.target(topo, cfg).expect("ta-moe target");
                let caps = if cfg.dispatch == "local" {
                    // §4.3: local capacities proportional to ĉ
                    proportional_caps(&tp.c, cfg.capacity)
                } else {
                    // FastMoE host: capacity untouched, only the loss changes
                    even_caps(p, n, cfg.capacity)
                };
                StrategyInputs {
                    penalty: topo_penalty_matrix(&tp.c, *norm),
                    caps,
                    local_mask,
                    hir_remote_frac: 1.0,
                    target: Some(tp),
                }
            }
        }
    }
}

/// The four runtime input matrices/scalars + the target (if any).
#[derive(Clone, Debug)]
pub struct StrategyInputs {
    pub penalty: Mat,
    pub caps: Mat,
    pub local_mask: Mat,
    pub hir_remote_frac: f32,
    pub target: Option<TargetPattern>,
}

/// The dispatch pattern a strategy converges to, used by the analytic
/// throughput model (fig4/fig6a/fig8) — validated against real training
/// in the fig3/fig7 runs:
///
/// * even strategies: the load-balance loss drives `c → k·S/N` uniform;
/// * TA-MoE: the topology loss drives `c → ĉ`;
/// * Hir: top-1 preference is ~uniform, but at most `remote_frac·S` tokens
///   leave the node; the remainder is folded back onto intra-node experts.
pub fn converged_counts(strategy: &Strategy, topo: &Topology, cfg: &ModelCfg) -> Mat {
    let p = cfg.p;
    let n = cfg.n_experts;
    let ks = (cfg.k * cfg.tokens_per_dev) as f64;
    match strategy {
        Strategy::DeepSpeedEven | Strategy::FastMoeEven => Mat::filled(p, n, ks / n as f64),
        Strategy::TaMoe { .. } => strategy.target(topo, cfg).expect("target").c,
        Strategy::FasterMoeHir { remote_frac } => {
            let mut m = Mat::zeros(p, n);
            for i in 0..p {
                let local: Vec<usize> = (0..n)
                    .filter(|&e| topo.same_node(i, e / cfg.e_per_dev))
                    .collect();
                let remote: Vec<usize> = (0..n)
                    .filter(|&e| !topo.same_node(i, e / cfg.e_per_dev))
                    .collect();
                if remote.is_empty() {
                    for &e in &local {
                        m.set(i, e, ks / local.len() as f64);
                    }
                    continue;
                }
                // uniform preference sends |remote|/n of the tokens out,
                // clipped at the compulsory budget
                let want_remote = ks * remote.len() as f64 / n as f64;
                let remote_total = want_remote.min(ks * remote_frac);
                let local_total = ks - remote_total;
                for &e in &remote {
                    m.set(i, e, remote_total / remote.len() as f64);
                }
                for &e in &local {
                    m.set(i, e, local_total / local.len() as f64);
                }
            }
            m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn cfg(p: usize, dispatch: &str) -> ModelCfg {
        ModelCfg {
            p,
            e_per_dev: 1,
            layers: 4,
            d: 128,
            f: 256,
            heads: 4,
            vocab: 256,
            batch: 2,
            seq: 32,
            k: 1,
            cap_factor: 1.25,
            gate: "switch".into(),
            dispatch: dispatch.into(),
            n_experts: p,
            capacity: 80,
            tokens_per_dev: 64,
            moe_layer_ids: vec![1, 3],
        }
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(Strategy::parse("deepspeed").unwrap(), Strategy::DeepSpeedEven);
        assert_eq!(Strategy::parse("fastmoe").unwrap(), Strategy::FastMoeEven);
        assert_eq!(
            Strategy::parse("fastermoe:0.3").unwrap(),
            Strategy::FasterMoeHir { remote_frac: 0.3 }
        );
        assert_eq!(
            Strategy::parse("ta-moe").unwrap(),
            Strategy::TaMoe { norm: Norm::L1 }
        );
        assert!(matches!(
            Strategy::parse("ta-moe:softmax:3").unwrap(),
            Strategy::TaMoe { norm: Norm::Softmax { .. } }
        ));
        assert!(Strategy::parse("whatever").is_err());
    }

    #[test]
    fn baseline_inputs_are_even() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "global");
        let si = Strategy::FastMoeEven.runtime_inputs(&topo, &c);
        assert_eq!(si.penalty.get(0, 0), 16.0);
        assert!((si.caps.get(0, 0) - 5.0).abs() < 1e-9); // 80/16
        assert!(si.target.is_none());
    }

    #[test]
    fn tamoe_local_caps_are_proportional() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "local");
        let si = Strategy::TaMoe { norm: Norm::L1 }.runtime_inputs(&topo, &c);
        let tp = si.target.as_ref().unwrap();
        // same-node expert gets more capacity than cross-node
        assert!(si.caps.get(0, 1) > si.caps.get(0, 8));
        // caps sum to capacity per expert
        for e in 0..16 {
            assert_eq!(si.caps.col_sum(e) as usize, c.capacity);
        }
        // penalty is anti-monotone in the target
        assert!(tp.c.get(0, 1) > tp.c.get(0, 8));
        assert!(si.penalty.get(0, 1) < si.penalty.get(0, 8));
    }

    #[test]
    fn converged_counts_conserve_tokens() {
        let topo = presets::cluster_c(2);
        let c = cfg(16, "global");
        for s in [
            Strategy::DeepSpeedEven,
            Strategy::FastMoeEven,
            Strategy::FasterMoeHir { remote_frac: 0.2 },
            Strategy::TaMoe { norm: Norm::L1 },
        ] {
            let m = converged_counts(&s, &topo, &c);
            for i in 0..16 {
                assert!(
                    (m.row_sum(i) - 64.0).abs() < 1e-6,
                    "{} row {i}: {}",
                    s.name(),
                    m.row_sum(i)
                );
            }
        }
    }

    #[test]
    fn hir_counts_respect_budget() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "global");
        let frac = 0.25;
        let m = converged_counts(&Strategy::FasterMoeHir { remote_frac: frac }, &topo, &c);
        for i in 0..16 {
            let remote: f64 = (0..16)
                .filter(|&e| !topo.same_node(i, e))
                .map(|e| m.get(i, e))
                .sum();
            assert!(remote <= 64.0 * frac + 1e-9);
        }
    }

    #[test]
    fn hir_single_node_goes_fully_local() {
        let topo = presets::cluster_b(1);
        let c = cfg(8, "global");
        let m = converged_counts(&Strategy::FasterMoeHir { remote_frac: 0.2 }, &topo, &c);
        for i in 0..8 {
            assert!((m.row_sum(i) - 64.0).abs() < 1e-9);
        }
    }
}
