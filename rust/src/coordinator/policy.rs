//! The [`DispatchPolicy`] trait: the routing-strategy seam of the public
//! API, replacing the old closed `Strategy` enum.
//!
//! A policy decides the four runtime inputs of the compiled model —
//! penalty matrix (which aux loss), capacity matrix, intra-node mask, and
//! the Hir remote fraction — plus which all-to-all schedule its timing
//! model uses, and the dispatch pattern it converges to (for the analytic
//! throughput sweeps). The four systems the paper compares (§5
//! Methodology) ship as structs implementing it; downstream crates add
//! their own via [`super::registry::register_policy`] without touching
//! this file.
//!
//! TA-MoE composes with either host system exactly as §4.3 describes: on
//! FastMoE it swaps the loss, on DeepSpeed-MoE it also makes the local
//! capacities proportional to `ĉ`.

use crate::comm::A2aAlgo;
use crate::dispatch::{
    baseline_penalty_matrix, even_caps, proportional_caps, target_pattern,
    target_pattern_placed, topo_penalty_matrix, DispatchProblem, Norm, TargetPattern,
};
use crate::placement::Placement;
use crate::runtime::{GateInputs, ModelCfg};
use crate::topology::Topology;
use crate::util::Mat;

/// A routing strategy: produces the model's gate inputs on a topology and
/// describes its timing/convergence behaviour. Implementations must be
/// `Debug` (property tests print failing cases) and thread-safe.
pub trait DispatchPolicy: std::fmt::Debug + Send + Sync {
    /// Canonical name. Must round-trip through the registry:
    /// `parse_policy(self.name())` yields an equivalent policy.
    fn name(&self) -> String;

    /// Does this policy use the topology-aware loss?
    fn is_topology_aware(&self) -> bool {
        false
    }

    /// The all-to-all execution plan this policy's host system uses by
    /// default (overridable per session via `SessionBuilder::a2a`).
    fn preferred_a2a(&self) -> A2aAlgo {
        A2aAlgo::Direct
    }

    /// The Eq. 7 target pattern this policy steers toward, if any.
    fn target(&self, topo: &Topology, cfg: &ModelCfg) -> Option<TargetPattern> {
        let _ = (topo, cfg);
        None
    }

    /// Build the model's runtime inputs for this policy on a topology.
    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs;

    /// [`runtime_inputs`] under an explicit expert placement (live
    /// migration moved experts off their canonical hosts). The default
    /// re-derives the intra-node mask from the placement and keeps
    /// everything else; topology-aware policies additionally re-solve
    /// their target for the new hosting (see [`TaMoe`]). With the
    /// identity placement this must agree with [`runtime_inputs`].
    ///
    /// [`runtime_inputs`]: DispatchPolicy::runtime_inputs
    fn runtime_inputs_placed(
        &self,
        topo: &Topology,
        cfg: &ModelCfg,
        placement: &Placement,
    ) -> PolicyInputs {
        let mut inputs = self.runtime_inputs(topo, cfg);
        inputs.gate.local_mask = placement.local_mask(topo);
        inputs
    }

    /// The dispatch pattern the gate converges to under this policy, used
    /// by the analytic throughput model (fig4/fig6a/fig8) — validated
    /// against real training in the fig3/fig7 runs.
    fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> Mat;
}

/// A policy's runtime inputs: the gate matrices the backend consumes plus
/// the target pattern (topology-aware policies only).
#[derive(Clone, Debug)]
pub struct PolicyInputs {
    pub gate: GateInputs,
    pub target: Option<TargetPattern>,
}

/// Free-function form of [`DispatchPolicy::converged_counts`], kept for
/// sweep/bench call-site ergonomics.
pub fn converged_counts(policy: &dyn DispatchPolicy, topo: &Topology, cfg: &ModelCfg) -> Mat {
    policy.converged_counts(topo, cfg)
}

/// The Eq. 7 problem instance for a model shape.
fn dispatch_problem(cfg: &ModelCfg) -> DispatchProblem {
    DispatchProblem {
        k: cfg.k,
        s: cfg.tokens_per_dev,
        e_per_dev: cfg.e_per_dev,
        elem_bytes: cfg.token_bytes(),
    }
}

/// Gate inputs shared by the even baselines: constant load-balance
/// penalty, even capacity slices.
fn even_gate(topo: &Topology, cfg: &ModelCfg, hir_remote_frac: f32) -> GateInputs {
    assert_eq!(topo.p(), cfg.p, "topology/model world-size mismatch");
    GateInputs {
        penalty: baseline_penalty_matrix(cfg.p, cfg.n_experts),
        caps: even_caps(cfg.p, cfg.n_experts, cfg.capacity),
        local_mask: topo.local_mask(cfg.n_experts, cfg.e_per_dev),
        hir_remote_frac,
    }
}

/// Uniform converged pattern `c_ie = k·S/N` (the load-balance loss target).
fn even_counts(cfg: &ModelCfg) -> Mat {
    let ks = (cfg.k * cfg.tokens_per_dev) as f64;
    Mat::filled(cfg.p, cfg.n_experts, ks / cfg.n_experts as f64)
}

// ---------------------------------------------------------------------------
// The four systems the paper compares
// ---------------------------------------------------------------------------

/// DeepSpeed-MoE: even local capacities `C/P`, load-balance loss,
/// hierarchical all-to-all.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct DeepSpeedEven;

impl DispatchPolicy for DeepSpeedEven {
    fn name(&self) -> String {
        "deepspeed".into()
    }

    fn preferred_a2a(&self) -> A2aAlgo {
        A2aAlgo::Hierarchical
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        PolicyInputs { gate: even_gate(topo, cfg, 1.0), target: None }
    }

    fn converged_counts(&self, _topo: &Topology, cfg: &ModelCfg) -> Mat {
        even_counts(cfg)
    }
}

/// FastMoE: global per-expert capacity with size exchange, load-balance
/// loss, direct all-to-all.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct FastMoeEven;

impl DispatchPolicy for FastMoeEven {
    fn name(&self) -> String {
        "fastmoe".into()
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        PolicyInputs { gate: even_gate(topo, cfg, 1.0), target: None }
    }

    fn converged_counts(&self, _topo: &Topology, cfg: &ModelCfg) -> Mat {
        even_counts(cfg)
    }
}

/// FasterMoE's Hir gate: compulsory intra-node ratio (1 − remote_frac).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FasterMoeHir {
    pub remote_frac: f64,
}

impl Default for FasterMoeHir {
    fn default() -> Self {
        FasterMoeHir { remote_frac: 0.25 }
    }
}

impl DispatchPolicy for FasterMoeHir {
    fn name(&self) -> String {
        format!("fastermoe:{}", self.remote_frac)
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        PolicyInputs {
            gate: even_gate(topo, cfg, self.remote_frac as f32),
            target: None,
        }
    }

    /// Top-1 preference is ~uniform, but at most `remote_frac·S` tokens
    /// leave the node; the remainder is folded back onto intra-node
    /// experts.
    fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> Mat {
        let (p, n) = (cfg.p, cfg.n_experts);
        let ks = (cfg.k * cfg.tokens_per_dev) as f64;
        let mut m = Mat::zeros(p, n);
        for i in 0..p {
            let local: Vec<usize> =
                (0..n).filter(|&e| topo.same_node(i, e / cfg.e_per_dev)).collect();
            let remote: Vec<usize> =
                (0..n).filter(|&e| !topo.same_node(i, e / cfg.e_per_dev)).collect();
            if remote.is_empty() {
                for &e in &local {
                    m.set(i, e, ks / local.len() as f64);
                }
                continue;
            }
            // uniform preference sends |remote|/n of the tokens out,
            // clipped at the compulsory budget
            let want_remote = ks * remote.len() as f64 / n as f64;
            let remote_total = want_remote.min(ks * self.remote_frac);
            let local_total = ks - remote_total;
            for &e in &remote {
                m.set(i, e, remote_total / remote.len() as f64);
            }
            for &e in &local {
                m.set(i, e, local_total / local.len() as f64);
            }
        }
        m
    }
}

/// TA-MoE (this paper): topology-aware loss, and on local-capacity hosts,
/// `C_ie ∝ ĉ_ie`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaMoe {
    pub norm: Norm,
}

impl Default for TaMoe {
    fn default() -> Self {
        TaMoe { norm: Norm::L1 }
    }
}

impl TaMoe {
    /// Penalty/caps/mask for a solved target pattern (shared by the
    /// canonical and placed input paths).
    fn inputs_for(
        &self,
        _topo: &Topology,
        cfg: &ModelCfg,
        tp: TargetPattern,
        local_mask: Mat,
    ) -> PolicyInputs {
        let caps = if cfg.dispatch == "local" {
            // §4.3: local capacities proportional to ĉ
            proportional_caps(&tp.c, cfg.capacity)
        } else {
            // FastMoE host: capacity untouched, only the loss changes
            even_caps(cfg.p, cfg.n_experts, cfg.capacity)
        };
        PolicyInputs {
            gate: GateInputs {
                penalty: topo_penalty_matrix(&tp.c, self.norm),
                caps,
                local_mask,
                hir_remote_frac: 1.0,
            },
            target: Some(tp),
        }
    }
}

impl DispatchPolicy for TaMoe {
    fn name(&self) -> String {
        match self.norm {
            Norm::L1 => "ta-moe".into(),
            Norm::Softmax { temp } => format!("ta-moe:softmax:{temp}"),
        }
    }

    fn is_topology_aware(&self) -> bool {
        true
    }

    fn target(&self, topo: &Topology, cfg: &ModelCfg) -> Option<TargetPattern> {
        Some(target_pattern(topo, &dispatch_problem(cfg)))
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        assert_eq!(topo.p(), cfg.p, "topology/model world-size mismatch");
        let tp = self.target(topo, cfg).expect("ta-moe target");
        self.inputs_for(topo, cfg, tp, topo.local_mask(cfg.n_experts, cfg.e_per_dev))
    }

    /// Topology-aware placement support: re-solve Eq. 7 for the experts'
    /// actual hosts, so the loss steers dispatch toward where the weights
    /// now live, and re-derive mask + capacities from the same solution.
    fn runtime_inputs_placed(
        &self,
        topo: &Topology,
        cfg: &ModelCfg,
        placement: &Placement,
    ) -> PolicyInputs {
        assert_eq!(topo.p(), cfg.p, "topology/model world-size mismatch");
        let tp = target_pattern_placed(topo, &dispatch_problem(cfg), placement);
        self.inputs_for(topo, cfg, tp, placement.local_mask(topo))
    }

    /// The topology loss drives `c → ĉ`.
    fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> Mat {
        self.target(topo, cfg).expect("target").c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn cfg(p: usize, dispatch: &str) -> ModelCfg {
        ModelCfg {
            p,
            e_per_dev: 1,
            layers: 4,
            d: 128,
            f: 256,
            heads: 4,
            vocab: 256,
            batch: 2,
            seq: 32,
            k: 1,
            cap_factor: 1.25,
            gate: "switch".into(),
            dispatch: dispatch.into(),
            n_experts: p,
            capacity: 80,
            tokens_per_dev: 64,
            moe_layer_ids: vec![1, 3],
        }
    }

    #[test]
    fn baseline_inputs_are_even() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "global");
        let pi = FastMoeEven.runtime_inputs(&topo, &c);
        assert_eq!(pi.gate.penalty.get(0, 0), 16.0);
        assert!((pi.gate.caps.get(0, 0) - 5.0).abs() < 1e-9); // 80/16
        assert!(pi.target.is_none());
    }

    #[test]
    fn tamoe_local_caps_are_proportional() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "local");
        let pi = TaMoe { norm: Norm::L1 }.runtime_inputs(&topo, &c);
        let tp = pi.target.as_ref().unwrap();
        // same-node expert gets more capacity than cross-node
        assert!(pi.gate.caps.get(0, 1) > pi.gate.caps.get(0, 8));
        // caps sum to capacity per expert
        for e in 0..16 {
            assert_eq!(pi.gate.caps.col_sum(e) as usize, c.capacity);
        }
        // penalty is anti-monotone in the target
        assert!(tp.c.get(0, 1) > tp.c.get(0, 8));
        assert!(pi.gate.penalty.get(0, 1) < pi.gate.penalty.get(0, 8));
    }

    #[test]
    fn converged_counts_conserve_tokens() {
        let topo = presets::cluster_c(2);
        let c = cfg(16, "global");
        let policies: Vec<Box<dyn DispatchPolicy>> = vec![
            Box::new(DeepSpeedEven),
            Box::new(FastMoeEven),
            Box::new(FasterMoeHir { remote_frac: 0.2 }),
            Box::new(TaMoe { norm: Norm::L1 }),
        ];
        for s in &policies {
            let m = converged_counts(s.as_ref(), &topo, &c);
            for i in 0..16 {
                assert!(
                    (m.row_sum(i) - 64.0).abs() < 1e-6,
                    "{} row {i}: {}",
                    s.name(),
                    m.row_sum(i)
                );
            }
        }
    }

    #[test]
    fn hir_counts_respect_budget() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "global");
        let frac = 0.25;
        let m = FasterMoeHir { remote_frac: frac }.converged_counts(&topo, &c);
        for i in 0..16 {
            let remote: f64 = (0..16)
                .filter(|&e| !topo.same_node(i, e))
                .map(|e| m.get(i, e))
                .sum();
            assert!(remote <= 64.0 * frac + 1e-9);
        }
    }

    #[test]
    fn hir_single_node_goes_fully_local() {
        let topo = presets::cluster_b(1);
        let c = cfg(8, "global");
        let m = FasterMoeHir { remote_frac: 0.2 }.converged_counts(&topo, &c);
        for i in 0..8 {
            assert!((m.row_sum(i) - 64.0).abs() < 1e-9);
        }
    }

    #[test]
    fn placed_inputs_agree_with_canonical_on_identity() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "local");
        let ident = Placement::identity(16, 1);
        for policy in [
            Box::new(TaMoe { norm: Norm::L1 }) as Box<dyn DispatchPolicy>,
            Box::new(FastMoeEven),
            Box::new(FasterMoeHir { remote_frac: 0.2 }),
        ] {
            let a = policy.runtime_inputs(&topo, &c);
            let b = policy.runtime_inputs_placed(&topo, &c, &ident);
            let name = policy.name();
            assert_eq!(a.gate.penalty.linf_dist(&b.gate.penalty), 0.0, "{name}");
            assert_eq!(a.gate.caps.linf_dist(&b.gate.caps), 0.0, "{name}");
            assert_eq!(a.gate.local_mask.linf_dist(&b.gate.local_mask), 0.0, "{name}");
        }
    }

    #[test]
    fn tamoe_placed_inputs_follow_the_migrated_expert() {
        let topo = presets::cluster_b(2);
        let c = cfg(16, "local");
        // expert 8 (canonically across the node boundary from device 0)
        // migrates onto device 0's node; expert 1 takes its place
        let mut pl = Placement::identity(16, 1);
        pl.swap_experts(1, 8);
        let pi = TaMoe { norm: Norm::L1 }.runtime_inputs_placed(&topo, &c, &pl);
        let tp = pi.target.as_ref().unwrap();
        // the re-solved target sends device 0 more to expert 8 (now
        // near) than to expert 1 (now far), inverting the canonical order
        assert!(tp.c.get(0, 8) > tp.c.get(0, 1));
        assert!(pi.gate.penalty.get(0, 8) < pi.gate.penalty.get(0, 1));
        // and the mask follows the hosts
        assert_eq!(pi.gate.local_mask.get(0, 8), 1.0);
        assert_eq!(pi.gate.local_mask.get(0, 1), 0.0);
        // the default (non-topology-aware) impl swaps only the mask
        let pe = FastMoeEven.runtime_inputs_placed(&topo, &c, &pl);
        assert_eq!(pe.gate.local_mask.get(0, 8), 1.0);
        assert_eq!(pe.gate.penalty.get(0, 0), 16.0, "penalty untouched");
    }

    #[test]
    fn only_deepspeed_prefers_hierarchical_a2a() {
        assert_eq!(DeepSpeedEven.preferred_a2a(), A2aAlgo::Hierarchical);
        assert_eq!(FastMoeEven.preferred_a2a(), A2aAlgo::Direct);
        assert_eq!(TaMoe::default().preferred_a2a(), A2aAlgo::Direct);
        assert_eq!(FasterMoeHir::default().preferred_a2a(), A2aAlgo::Direct);
        assert!(TaMoe::default().is_topology_aware());
        assert!(!DeepSpeedEven.is_topology_aware());
    }
}
