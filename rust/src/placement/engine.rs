//! The amortised live-migration controller.
//!
//! [`PlacementEngine`] sits inside a `Session`: every step it folds the
//! measured dispatch counts into its EWMA load estimate, and every
//! `every` steps it solves for a better placement and applies it **only
//! when the migration amortises** — predicted per-step a2a savings over
//! the configured horizon must exceed the one-off cost of moving the
//! re-placed experts' weights over the real links. Each accepted
//! migration bumps the *placement epoch*; the session forwards the epoch
//! to its `PlanCache`, whose schedules were synthesised for the old
//! routing and must not survive it.

use super::solver::{solve_placement, PlacementObjective};
use super::{GateLoadEwma, Placement};
use crate::comm::A2aAlgo;
use crate::topology::Topology;
use crate::util::Mat;

/// Knobs of the placement engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlacementConfig {
    /// Attempt a re-placement every this many steps (0 disables attempts;
    /// a disabled engine still tracks loads).
    pub every: usize,
    /// Steps over which a migration must pay for itself: accept only when
    /// `predicted_saving_per_step × horizon ≥ migration_cost`.
    pub horizon: f64,
    /// EWMA weight of the newest step's counts in the load estimate.
    pub ewma_alpha: f64,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig { every: 16, horizon: 50.0, ewma_alpha: 0.25 }
    }
}

impl PlacementConfig {
    /// Parse a `--placement` / `train.placement` spec:
    /// `off` → `None`, `on` → defaults, an integer → defaults with that
    /// attempt cadence.
    pub fn parse_spec(spec: &str) -> Result<Option<PlacementConfig>, String> {
        match spec.trim() {
            "" | "off" => Ok(None),
            "on" => Ok(Some(PlacementConfig::default())),
            s => match s.parse::<usize>() {
                Ok(0) => Ok(None),
                Ok(every) => Ok(Some(PlacementConfig { every, ..Default::default() })),
                Err(_) => Err(format!(
                    "unknown placement spec {s:?} (known: off, on, <every-steps>)"
                )),
            },
        }
    }
}

/// One accepted migration: what moved, what it cost, and the savings
/// accounting the amortisation decision was made on.
#[derive(Clone, Debug)]
pub struct Migration {
    /// 1-based count of training steps the engine had observed at the
    /// decision (the deciding step's counts are already folded in). Note
    /// this is NOT a `RunLog` record index — the session logs the
    /// deciding step's 0-based record index in `MigrationRecord::step`.
    pub step: u64,
    /// Experts whose host changed.
    pub moved: Vec<usize>,
    /// Total expert-weight bytes moved.
    pub bytes: f64,
    /// One-off migration time (weights priced over the real links),
    /// charged to the step clock.
    pub cost_s: f64,
    /// Predicted per-step a2a saving on the EWMA loads — what the
    /// amortisation gate multiplied by the horizon.
    pub predicted_saving_s: f64,
    /// Per-step saving re-priced on the live counts of the deciding step
    /// (the realised-vs-predicted comparison the run log reports).
    pub realized_saving_s: f64,
}

/// Load-tracking + solve + amortisation gate, owning the session's
/// current [`Placement`] and its epoch.
#[derive(Debug)]
pub struct PlacementEngine {
    cfg: PlacementConfig,
    placement: Placement,
    loads: GateLoadEwma,
    epoch: u64,
    /// Wire bytes of one dispatched token (d · elem).
    token_bytes: f64,
    /// Weight bytes of one expert (the migration payload).
    expert_bytes: f64,
    /// Priced exchanges of the dispatch matrix per training step
    /// (4 × MoE layers: dispatch + combine, forward + backward).
    exchanges_per_step: f64,
    /// The a2a plan the session's step clock actually executes — the
    /// accept/reject savings are priced under it, so a candidate that
    /// only wins under a plan the session doesn't run is never applied.
    a2a: A2aAlgo,
    steps: u64,
}

impl PlacementEngine {
    pub fn new(
        cfg: PlacementConfig,
        p: usize,
        e_per_dev: usize,
        token_bytes: f64,
        expert_bytes: f64,
        exchanges_per_step: f64,
        a2a: A2aAlgo,
    ) -> PlacementEngine {
        PlacementEngine {
            placement: Placement::identity(p, e_per_dev),
            loads: GateLoadEwma::new(p, p * e_per_dev, cfg.ewma_alpha),
            cfg,
            epoch: 0,
            token_bytes,
            expert_bytes,
            exchanges_per_step,
            a2a,
            steps: 0,
        }
    }

    /// The current expert→device map.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Monotone counter bumped by every accepted migration. Forward it to
    /// `PlanCache::set_epoch` — cached schedules do not survive a
    /// re-routing of the byte matrix.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The smoothed load estimate decisions are made on.
    pub fn loads(&self) -> &GateLoadEwma {
        &self.loads
    }

    /// Fold one step's measured dispatch counts (tokens, P×N) in.
    pub fn observe(&mut self, counts: &Mat) {
        self.loads.observe(counts);
        self.steps += 1;
    }

    /// At the configured cadence, solve for a better placement and apply
    /// it iff the migration amortises within the horizon. `live_counts`
    /// is the deciding step's measured dispatch matrix, used only for the
    /// realised-saving accounting. Returns the accepted migration, if any.
    pub fn maybe_replace(&mut self, topo: &Topology, live_counts: &Mat) -> Option<Migration> {
        if self.cfg.every == 0 || self.steps == 0 || self.steps % self.cfg.every as u64 != 0 {
            return None;
        }
        let candidate =
            solve_placement(topo, self.loads.loads(), &self.placement, self.token_bytes);
        if candidate == self.placement {
            return None;
        }
        // the swap descent searches on the cheap direct-contention proxy;
        // the accept/reject decision re-prices both placements under the
        // a2a plan the step clock actually runs, so a proxy-only win
        // (e.g. one that a hierarchical exchange would erase) is rejected
        let exchange = |pl: &Placement, counts: &Mat| {
            self.a2a.exchange_time(topo, &pl.bytes_matrix(counts, self.token_bytes))
        };
        let cur = exchange(&self.placement, self.loads.loads());
        let new = exchange(&candidate, self.loads.loads());
        let predicted_saving_s = (cur - new) * self.exchanges_per_step;
        let mut obj = PlacementObjective::new(topo, self.token_bytes);
        let cost_s = obj.migration_cost(&self.placement, &candidate, self.expert_bytes);
        if predicted_saving_s <= 0.0 || predicted_saving_s * self.cfg.horizon < cost_s {
            return None; // does not amortise — keep the current placement
        }
        let realized_saving_s = (exchange(&self.placement, live_counts)
            - exchange(&candidate, live_counts))
            * self.exchanges_per_step;
        let moved = self.placement.moved_experts(&candidate);
        let bytes = moved.len() as f64 * self.expert_bytes;
        self.placement = candidate;
        self.epoch += 1;
        Some(Migration {
            step: self.steps,
            moved,
            bytes,
            cost_s,
            predicted_saving_s,
            realized_saving_s,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    /// Node-0 senders crowd the canonical node-1 experts; node-1 senders
    /// stay uniform (same shape as the solver scenario tests).
    fn skewed_counts(topo: &Topology, sent: f64) -> Mat {
        let p = topo.p();
        Mat::from_fn(p, p, |i, e| {
            if topo.node_of(i) == 0 {
                if topo.node_of(e) == 1 {
                    0.45 * sent
                } else {
                    0.05 * sent
                }
            } else {
                sent / p as f64
            }
        })
    }

    fn engine(cfg: PlacementConfig) -> PlacementEngine {
        // tiny4-ish scales: d=32 fp32 tokens, 16 KiB expert weights,
        // 8 priced exchanges per step, direct a2a
        PlacementEngine::new(cfg, 4, 1, 128.0, 16384.0, 8.0, A2aAlgo::Direct)
    }

    #[test]
    fn parse_spec_round_trips() {
        assert_eq!(PlacementConfig::parse_spec("off").unwrap(), None);
        assert_eq!(PlacementConfig::parse_spec("").unwrap(), None);
        assert_eq!(PlacementConfig::parse_spec("0").unwrap(), None);
        assert_eq!(
            PlacementConfig::parse_spec("on").unwrap(),
            Some(PlacementConfig::default())
        );
        assert_eq!(PlacementConfig::parse_spec("4").unwrap().unwrap().every, 4);
        assert!(PlacementConfig::parse_spec("sometimes").is_err());
    }

    #[test]
    fn migrates_on_skewed_load_and_bumps_epoch() {
        let topo = presets::table1();
        let cfg = PlacementConfig { every: 4, horizon: 50.0, ewma_alpha: 0.5 };
        let mut eng = engine(cfg);
        let counts = skewed_counts(&topo, 32.0);
        let mut migration = None;
        for _ in 0..8 {
            eng.observe(&counts);
            if let Some(m) = eng.maybe_replace(&topo, &counts) {
                migration = Some(m);
                break;
            }
        }
        let m = migration.expect("skewed load must trigger a migration");
        assert_eq!(eng.epoch(), 1);
        assert!(!eng.placement().is_identity());
        assert!(!m.moved.is_empty());
        assert_eq!(m.bytes, m.moved.len() as f64 * 16384.0);
        assert!(m.cost_s > 0.0);
        assert!(m.predicted_saving_s > 0.0);
        // steady skew: the live counts equal the EWMA estimate, so the
        // realised saving matches the predicted one
        assert!((m.realized_saving_s - m.predicted_saving_s).abs() <= 1e-9);
        // the gate held: the accepted move amortises within the horizon
        assert!(m.predicted_saving_s * cfg.horizon >= m.cost_s);
    }

    #[test]
    fn uniform_load_never_migrates() {
        let topo = presets::table1();
        let mut eng = engine(PlacementConfig { every: 2, ..Default::default() });
        let counts = Mat::filled(4, 4, 8.0);
        for _ in 0..10 {
            eng.observe(&counts);
            assert!(eng.maybe_replace(&topo, &counts).is_none());
        }
        assert_eq!(eng.epoch(), 0);
        assert!(eng.placement().is_identity());
    }

    #[test]
    fn tiny_horizon_blocks_the_migration() {
        // same skew, but the migration may not amortise in ~0 steps
        let topo = presets::table1();
        let cfg = PlacementConfig { every: 2, horizon: 1e-9, ewma_alpha: 0.5 };
        let mut eng = engine(cfg);
        let counts = skewed_counts(&topo, 32.0);
        for _ in 0..8 {
            eng.observe(&counts);
            assert!(eng.maybe_replace(&topo, &counts).is_none());
        }
        assert_eq!(eng.epoch(), 0);
        assert!(eng.placement().is_identity());
    }

    #[test]
    fn cadence_gates_attempts() {
        let topo = presets::table1();
        let cfg = PlacementConfig { every: 5, horizon: 50.0, ewma_alpha: 0.5 };
        let mut eng = engine(cfg);
        let counts = skewed_counts(&topo, 32.0);
        for step in 1..=4u64 {
            eng.observe(&counts);
            assert!(
                eng.maybe_replace(&topo, &counts).is_none(),
                "no attempt before the cadence (step {step})"
            );
        }
        eng.observe(&counts);
        assert!(eng.maybe_replace(&topo, &counts).is_some(), "attempt at step 5");
    }
}
