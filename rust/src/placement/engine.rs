//! The amortised live-migration controller.
//!
//! [`PlacementEngine`] sits inside a `Session`: every step it folds the
//! measured dispatch counts into its EWMA load estimate, and every
//! `every` steps it solves for a better placement and applies it **only
//! when the migration amortises** — predicted per-step a2a savings over
//! the configured horizon must exceed the one-off cost of moving the
//! re-placed experts' weights over the real links. Each accepted
//! migration bumps the *placement epoch*; the session forwards the epoch
//! to its `PlanCache`, whose schedules were synthesised for the old
//! routing and must not survive it.

use super::solver::{solve_placement, PlacementObjective};
use super::{GateLoadEwma, Placement};
use crate::comm::{price_rounds, ring_allreduce_time, A2aAlgo};
use crate::overlap::{autotune_k, pipeline_cost, OverlapInputs, OverlapMode};
use crate::topology::Topology;
use crate::util::Mat;

/// Knobs of the placement engine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlacementConfig {
    /// Attempt a re-placement every this many steps (0 disables attempts;
    /// a disabled engine still tracks loads).
    pub every: usize,
    /// Steps over which a migration must pay for itself: accept only when
    /// `predicted_saving_per_step × horizon ≥ migration_cost`.
    pub horizon: f64,
    /// EWMA weight of the newest step's counts in the load estimate.
    pub ewma_alpha: f64,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig { every: 16, horizon: 50.0, ewma_alpha: 0.25 }
    }
}

impl PlacementConfig {
    /// Parse a `--placement` / `train.placement` spec:
    /// `off` → `None`, `on` → defaults, an integer → defaults with that
    /// attempt cadence.
    pub fn parse_spec(spec: &str) -> Result<Option<PlacementConfig>, String> {
        match spec.trim() {
            "" | "off" => Ok(None),
            "on" => Ok(Some(PlacementConfig::default())),
            s => match s.parse::<usize>() {
                Ok(0) => Ok(None),
                Ok(every) => Ok(Some(PlacementConfig { every, ..Default::default() })),
                Err(_) => Err(format!(
                    "unknown placement spec {s:?} (known: off, on, <every-steps>)"
                )),
            },
        }
    }
}

/// One accepted migration: what moved, what it cost, and the savings
/// accounting the amortisation decision was made on.
#[derive(Clone, Debug)]
pub struct Migration {
    /// 1-based count of training steps the engine had observed at the
    /// decision (the deciding step's counts are already folded in). Note
    /// this is NOT a `RunLog` record index — the session logs the
    /// deciding step's 0-based record index in `MigrationRecord::step`.
    pub step: u64,
    /// Experts whose host changed.
    pub moved: Vec<usize>,
    /// Total expert-weight bytes moved.
    pub bytes: f64,
    /// One-off migration time (weights priced over the real links),
    /// charged to the step clock.
    pub cost_s: f64,
    /// Predicted per-step a2a saving on the EWMA loads — what the
    /// amortisation gate multiplied by the horizon.
    pub predicted_saving_s: f64,
    /// Per-step saving re-priced on the live counts of the deciding step
    /// (the realised-vs-predicted comparison the run log reports).
    pub realized_saving_s: f64,
}

/// How the amortisation gate prices a step when the session's clock runs
/// on the chunked overlap timeline: candidate placements are compared on
/// full overlapped step makespans, so a2a bytes the pipeline hides under
/// compute produce no predicted saving and cannot trigger a migration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapPricing {
    /// The session's overlap mode (`Auto` re-tunes the chunk count for
    /// each candidate placement, exactly as the session would after the
    /// migration).
    pub mode: OverlapMode,
    /// Forward dense compute per step (see `overlap::OverlapInputs`).
    pub dense_fwd_s: f64,
    /// Backward dense compute per step (the allreduce's overlap window).
    pub dense_bwd_s: f64,
    /// Expert compute seconds per received token, totalled over all MoE
    /// layers, forward + backward.
    pub expert_s_per_token: f64,
    /// MoE layers in the model.
    pub n_moe: usize,
    /// Dense gradient bytes (per-bucket allreduce pricing).
    pub dense_param_bytes: f64,
}

impl OverlapPricing {
    /// Overlapped step time of `counts` routed through `pl` — the clock
    /// the session would charge for a step under this placement.
    fn step_s(
        &self,
        topo: &Topology,
        pl: &Placement,
        counts: &Mat,
        a2a: A2aAlgo,
        token_bytes: f64,
    ) -> f64 {
        let bytes = pl.bytes_matrix(counts, token_bytes);
        let inputs = OverlapInputs {
            dense_fwd_s: self.dense_fwd_s,
            dense_bwd_s: self.dense_bwd_s,
            expert_s_per_dev: pl
                .recv_per_device(counts)
                .into_iter()
                .map(|r| r * self.expert_s_per_token)
                .collect(),
            n_moe: self.n_moe,
        };
        // synthesise the round schedule once per candidate byte matrix
        // (an even 1/k split leaves the optimal structure unchanged), so
        // the autotune sweep re-prices rounds instead of re-running BvN
        // synthesis per chunk count
        let rounds = a2a.rounds(topo, &bytes);
        let chunk_of = |k: usize| {
            let chunk = bytes.scale(1.0 / k as f64);
            let breakdown = match &rounds {
                Some(r) => price_rounds(topo, &chunk, r),
                None => a2a.plan(topo, &chunk).breakdown,
            };
            (breakdown, ring_allreduce_time(topo, self.dense_param_bytes / k as f64))
        };
        match self.mode {
            OverlapMode::Auto => autotune_k(&inputs, chunk_of).1.makespan_s,
            // Serial prices as the k = 1 pipeline — one chain, the same
            // clock to fp precision
            mode => {
                let k = mode.fixed_k().unwrap_or(1);
                let (chunk, ar_chunk) = chunk_of(k);
                pipeline_cost(&inputs, &chunk, ar_chunk, k).makespan_s
            }
        }
    }
}

/// Load-tracking + solve + amortisation gate, owning the session's
/// current [`Placement`] and its epoch.
#[derive(Debug)]
pub struct PlacementEngine {
    cfg: PlacementConfig,
    placement: Placement,
    loads: GateLoadEwma,
    epoch: u64,
    /// Wire bytes of one dispatched token (d · elem).
    token_bytes: f64,
    /// Weight bytes of one expert (the migration payload).
    expert_bytes: f64,
    /// Priced exchanges of the dispatch matrix per training step
    /// (4 × MoE layers: dispatch + combine, forward + backward).
    exchanges_per_step: f64,
    /// The a2a plan the session's step clock actually executes — the
    /// accept/reject savings are priced under it, so a candidate that
    /// only wins under a plan the session doesn't run is never applied.
    a2a: A2aAlgo,
    /// When the session prices steps on the overlap timeline, savings are
    /// re-priced under the overlapped clock too ([`OverlapPricing`]) —
    /// the gate must not chase a2a time that was never exposed.
    overlap: Option<OverlapPricing>,
    steps: u64,
}

impl PlacementEngine {
    pub fn new(
        cfg: PlacementConfig,
        p: usize,
        e_per_dev: usize,
        token_bytes: f64,
        expert_bytes: f64,
        exchanges_per_step: f64,
        a2a: A2aAlgo,
    ) -> PlacementEngine {
        PlacementEngine {
            placement: Placement::identity(p, e_per_dev),
            loads: GateLoadEwma::new(p, p * e_per_dev, cfg.ewma_alpha),
            cfg,
            epoch: 0,
            token_bytes,
            expert_bytes,
            exchanges_per_step,
            a2a,
            overlap: None,
            steps: 0,
        }
    }

    /// Price migration savings under the chunked overlap clock instead of
    /// the serial a2a diff (use when the session runs with `--overlap`).
    pub fn with_overlap(mut self, pricing: OverlapPricing) -> PlacementEngine {
        self.overlap = Some(pricing);
        self
    }

    /// The overlapped-clock pricing the gate uses, if any.
    pub fn overlap_pricing(&self) -> Option<&OverlapPricing> {
        self.overlap.as_ref()
    }

    /// The current expert→device map.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Monotone counter bumped by every accepted migration. Forward it to
    /// `PlanCache::set_epoch` — cached schedules do not survive a
    /// re-routing of the byte matrix.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The smoothed load estimate decisions are made on.
    pub fn loads(&self) -> &GateLoadEwma {
        &self.loads
    }

    /// Fold one step's measured dispatch counts (tokens, P×N) in.
    pub fn observe(&mut self, counts: &Mat) {
        self.loads.observe(counts);
        self.steps += 1;
    }

    /// At the configured cadence, solve for a better placement and apply
    /// it iff the migration amortises within the horizon. `live_counts`
    /// is the deciding step's measured dispatch matrix, used only for the
    /// realised-saving accounting. Returns the accepted migration, if any.
    pub fn maybe_replace(&mut self, topo: &Topology, live_counts: &Mat) -> Option<Migration> {
        if self.cfg.every == 0 || self.steps == 0 || self.steps % self.cfg.every as u64 != 0 {
            return None;
        }
        let candidate =
            solve_placement(topo, self.loads.loads(), &self.placement, self.token_bytes);
        if candidate == self.placement {
            return None;
        }
        // the swap descent searches on the cheap direct-contention proxy;
        // the accept/reject decision re-prices both placements under the
        // clock the session actually runs: the a2a plan, and — when the
        // session prices steps on the overlap timeline — the overlapped
        // makespan, so a2a bytes hidden under compute yield no saving and
        // a proxy-only win is never applied
        let step_time = |pl: &Placement, counts: &Mat| match &self.overlap {
            None => {
                self.a2a.exchange_time(topo, &pl.bytes_matrix(counts, self.token_bytes))
                    * self.exchanges_per_step
            }
            Some(ov) => ov.step_s(topo, pl, counts, self.a2a, self.token_bytes),
        };
        let cur = step_time(&self.placement, self.loads.loads());
        let new = step_time(&candidate, self.loads.loads());
        let predicted_saving_s = cur - new;
        let mut obj = PlacementObjective::new(topo, self.token_bytes);
        let cost_s = obj.migration_cost(&self.placement, &candidate, self.expert_bytes);
        if predicted_saving_s <= 0.0 || predicted_saving_s * self.cfg.horizon < cost_s {
            return None; // does not amortise — keep the current placement
        }
        let realized_saving_s =
            step_time(&self.placement, live_counts) - step_time(&candidate, live_counts);
        let moved = self.placement.moved_experts(&candidate);
        let bytes = moved.len() as f64 * self.expert_bytes;
        self.placement = candidate;
        self.epoch += 1;
        Some(Migration {
            step: self.steps,
            moved,
            bytes,
            cost_s,
            predicted_saving_s,
            realized_saving_s,
        })
    }

    /// Emergency evacuation on node loss: swap every loaded expert hosted
    /// on `dead_dev` with the coldest expert hosted on a live device, so
    /// only (near-)dead load parks on the corpse. Unlike
    /// [`maybe_replace`](Self::maybe_replace) this bypasses the cadence
    /// and amortisation gates — there is no choice to amortise, the host
    /// is gone — but the migration is still priced over the real links
    /// and must be charged to the clock by the caller. The placement
    /// stays a full `e_per_dev`-slot permutation (the corpse keeps
    /// hosting its quota of cold experts; the chaos layer re-gates their
    /// traffic to live hosts), so the permutation invariant survives.
    /// Returns `None` when nothing on the corpse carries load worth
    /// moving (then no epoch bump either).
    pub fn evacuate(&mut self, topo: &Topology, dead_dev: usize) -> Option<Migration> {
        assert_eq!(topo.p(), self.placement.p(), "topology/placement world mismatch");
        assert!(dead_dev < self.placement.p(), "device {dead_dev} out of range");
        let mut candidate = self.placement.clone();
        let loads = self.loads.loads();
        // hottest evacuees first: if parking spots run out, the hottest
        // experts are guaranteed to have been rescued
        let mut evacuees = candidate.experts_on(dead_dev);
        evacuees.sort_by(|&a, &b| {
            loads.col_sum(b).total_cmp(&loads.col_sum(a)).then(a.cmp(&b))
        });
        for e in evacuees {
            if loads.col_sum(e) <= 0.0 {
                break; // remaining evacuees are cold — nothing to rescue
            }
            // coldest expert currently hosted on a live device (ties break
            // toward the lower expert id: deterministic)
            let cold = (0..candidate.n_experts())
                .filter(|&x| {
                    let d = candidate.device_of(x);
                    d != dead_dev && topo.is_alive(d)
                })
                .min_by(|&a, &b| {
                    loads.col_sum(a).total_cmp(&loads.col_sum(b)).then(a.cmp(&b))
                });
            let Some(cold) = cold else { break };
            if loads.col_sum(cold) >= loads.col_sum(e) {
                break; // swapping would park a hotter expert on the corpse
            }
            candidate.swap_experts(e, cold);
        }
        if candidate == self.placement {
            return None;
        }
        let mut obj = PlacementObjective::new(topo, self.token_bytes);
        let cost_s = obj.migration_cost(&self.placement, &candidate, self.expert_bytes);
        let moved = self.placement.moved_experts(&candidate);
        let bytes = moved.len() as f64 * self.expert_bytes;
        self.placement = candidate;
        self.epoch += 1;
        Some(Migration {
            step: self.steps,
            moved,
            bytes,
            cost_s,
            // an evacuation is not an optimisation: there is no "kept the
            // old placement" counterfactual to price a saving against
            predicted_saving_s: 0.0,
            realized_saving_s: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    /// Node-0 senders crowd the canonical node-1 experts; node-1 senders
    /// stay uniform (same shape as the solver scenario tests).
    fn skewed_counts(topo: &Topology, sent: f64) -> Mat {
        let p = topo.p();
        Mat::from_fn(p, p, |i, e| {
            if topo.node_of(i) == 0 {
                if topo.node_of(e) == 1 {
                    0.45 * sent
                } else {
                    0.05 * sent
                }
            } else {
                sent / p as f64
            }
        })
    }

    fn engine(cfg: PlacementConfig) -> PlacementEngine {
        // tiny4-ish scales: d=32 fp32 tokens, 16 KiB expert weights,
        // 8 priced exchanges per step, direct a2a
        PlacementEngine::new(cfg, 4, 1, 128.0, 16384.0, 8.0, A2aAlgo::Direct)
    }

    #[test]
    fn parse_spec_round_trips() {
        assert_eq!(PlacementConfig::parse_spec("off").unwrap(), None);
        assert_eq!(PlacementConfig::parse_spec("").unwrap(), None);
        assert_eq!(PlacementConfig::parse_spec("0").unwrap(), None);
        assert_eq!(
            PlacementConfig::parse_spec("on").unwrap(),
            Some(PlacementConfig::default())
        );
        assert_eq!(PlacementConfig::parse_spec("4").unwrap().unwrap().every, 4);
        assert!(PlacementConfig::parse_spec("sometimes").is_err());
    }

    #[test]
    fn migrates_on_skewed_load_and_bumps_epoch() {
        let topo = presets::table1();
        let cfg = PlacementConfig { every: 4, horizon: 50.0, ewma_alpha: 0.5 };
        let mut eng = engine(cfg);
        let counts = skewed_counts(&topo, 32.0);
        let mut migration = None;
        for _ in 0..8 {
            eng.observe(&counts);
            if let Some(m) = eng.maybe_replace(&topo, &counts) {
                migration = Some(m);
                break;
            }
        }
        let m = migration.expect("skewed load must trigger a migration");
        assert_eq!(eng.epoch(), 1);
        assert!(!eng.placement().is_identity());
        assert!(!m.moved.is_empty());
        assert_eq!(m.bytes, m.moved.len() as f64 * 16384.0);
        assert!(m.cost_s > 0.0);
        assert!(m.predicted_saving_s > 0.0);
        // steady skew: the live counts equal the EWMA estimate, so the
        // realised saving matches the predicted one
        assert!((m.realized_saving_s - m.predicted_saving_s).abs() <= 1e-9);
        // the gate held: the accepted move amortises within the horizon
        assert!(m.predicted_saving_s * cfg.horizon >= m.cost_s);
    }

    #[test]
    fn overlapped_gate_discounts_a2a_time_hidden_under_compute() {
        // the serial gate prices a migration's saving as the full a2a
        // diff; the overlapped gate prices full step makespans, so a2a
        // bytes pipelined under heavy expert compute contribute only
        // their exposed slivers (the pipe edges) — the predicted saving
        // must collapse relative to the serial gate's for the SAME skew.
        // (The received loads are a permutation across placements, so the
        // compute bound itself is placement-invariant here.)
        let topo = presets::table1();
        let cfg = PlacementConfig { every: 4, horizon: 1e9, ewma_alpha: 0.5 };
        // fat tokens so the uplink β term (which migration can shrink)
        // dominates the path α (which it cannot)
        let fat = || PlacementEngine::new(cfg, 4, 1, 4096.0, 16384.0, 8.0, A2aAlgo::Direct);
        let counts = skewed_counts(&topo, 32.0);
        let migrate = |mut eng: PlacementEngine| -> Migration {
            for _ in 0..8 {
                eng.observe(&counts);
                if let Some(m) = eng.maybe_replace(&topo, &counts) {
                    return m;
                }
            }
            panic!("skewed load must migrate under a 1e9-step horizon");
        };

        let serial = migrate(fat());
        let pricing = OverlapPricing {
            mode: crate::overlap::OverlapMode::Fixed(4),
            dense_fwd_s: 0.0,
            dense_bwd_s: 0.0,
            expert_s_per_token: 1.0, // seconds per token: compute dwarfs a2a
            n_moe: 2,
            dense_param_bytes: 0.0,
        };
        let eng = fat().with_overlap(pricing);
        assert_eq!(eng.overlap_pricing(), Some(&pricing));
        let hidden = migrate(eng);
        assert!(
            hidden.predicted_saving_s < serial.predicted_saving_s / 2.0,
            "hidden a2a must be discounted: overlapped {} vs serial {}",
            hidden.predicted_saving_s,
            serial.predicted_saving_s
        );
        // with compute stripped back out the overlapped gate still sees
        // (most of) the saving: the a2a really is exposed again
        let exposed = OverlapPricing { expert_s_per_token: 0.0, ..pricing };
        let m = migrate(fat().with_overlap(exposed));
        assert!(m.predicted_saving_s > hidden.predicted_saving_s * 2.0);
    }

    #[test]
    fn uniform_load_never_migrates() {
        let topo = presets::table1();
        let mut eng = engine(PlacementConfig { every: 2, ..Default::default() });
        let counts = Mat::filled(4, 4, 8.0);
        for _ in 0..10 {
            eng.observe(&counts);
            assert!(eng.maybe_replace(&topo, &counts).is_none());
        }
        assert_eq!(eng.epoch(), 0);
        assert!(eng.placement().is_identity());
    }

    #[test]
    fn tiny_horizon_blocks_the_migration() {
        // same skew, but the migration may not amortise in ~0 steps
        let topo = presets::table1();
        let cfg = PlacementConfig { every: 2, horizon: 1e-9, ewma_alpha: 0.5 };
        let mut eng = engine(cfg);
        let counts = skewed_counts(&topo, 32.0);
        for _ in 0..8 {
            eng.observe(&counts);
            assert!(eng.maybe_replace(&topo, &counts).is_none());
        }
        assert_eq!(eng.epoch(), 0);
        assert!(eng.placement().is_identity());
    }

    #[test]
    fn evacuate_rescues_hot_experts_and_keeps_the_permutation() {
        let mut topo = presets::table1();
        let mut eng = engine(PlacementConfig::default());
        // expert 3 (hosted on device 3) is the hottest column; expert 0 is
        // stone cold, so it becomes the parking spot on the corpse
        let counts = Mat::from_fn(4, 4, |_, e| match e {
            3 => 100.0,
            0 => 0.0,
            _ => 10.0,
        });
        eng.observe(&counts);
        topo.mark_dead(3);
        let m = eng.evacuate(&topo, 3).expect("hot expert must be rescued");
        assert_eq!(eng.epoch(), 1);
        assert!(m.moved.contains(&3), "expert 3 must move: {:?}", m.moved);
        assert!(m.cost_s > 0.0, "weights crossed real links");
        assert_eq!(m.predicted_saving_s, 0.0);
        assert_eq!(m.bytes, m.moved.len() as f64 * 16384.0);
        // still a valid e_per_dev-slot permutation…
        let pl = eng.placement().clone();
        Placement::from_device_of(pl.device_map().to_vec(), 4, 1).unwrap();
        // …with expert 3 off the corpse and the cold expert parked on it
        assert!(topo.is_alive(pl.device_of(3)));
        assert_eq!(pl.device_of(0), 3);
        // idempotent: the corpse now hosts only the coldest expert
        assert!(eng.evacuate(&topo, 3).is_none());
        assert_eq!(eng.epoch(), 1);
    }

    #[test]
    fn evacuate_is_a_noop_without_observed_load() {
        let mut topo = presets::table1();
        let mut eng = engine(PlacementConfig::default());
        topo.mark_dead(2);
        assert!(eng.evacuate(&topo, 2).is_none(), "zero loads: nothing to rescue");
        assert_eq!(eng.epoch(), 0);
        assert!(eng.placement().is_identity());
    }

    #[test]
    fn cadence_gates_attempts() {
        let topo = presets::table1();
        let cfg = PlacementConfig { every: 5, horizon: 50.0, ewma_alpha: 0.5 };
        let mut eng = engine(cfg);
        let counts = skewed_counts(&topo, 32.0);
        for step in 1..=4u64 {
            eng.observe(&counts);
            assert!(
                eng.maybe_replace(&topo, &counts).is_none(),
                "no attempt before the cadence (step {step})"
            );
        }
        eng.observe(&counts);
        assert!(eng.maybe_replace(&topo, &counts).is_some(), "attempt at step 5");
    }
}
