//! Placement objective + solvers.
//!
//! The objective prices what placement actually changes: the `P×P` byte
//! matrix of one dispatch exchange (loads routed onto devices through the
//! placement map) under the [`CostEngine`] contention model — the same
//! α-β machinery the step clock uses, so "better placement" means "this
//! exchange completes sooner on these links", not a proxy like inter-node
//! byte count.
//!
//! Two deterministic solvers:
//!
//! * [`greedy_placement`] — locality-aware initialiser: experts are
//!   assigned heaviest-first to the open device minimising the
//!   load-weighted α-β delivery cost from every sender (ties broken by
//!   device index, so the result is reproducible);
//! * [`local_search`] — first-improvement swap descent over expert pairs:
//!   a swap is kept only when the priced objective strictly drops, so the
//!   search is monotone non-increasing and terminates.
//!
//! [`solve_placement`] runs the search from both the current placement and
//! the greedy initialiser and returns the cheaper result, preferring the
//! current-seeded one on ties (fewer weights to move).

use super::Placement;
use crate::comm::CostEngine;
use crate::topology::Topology;
use crate::util::Mat;

/// Swap-descent sweeps bound (each sweep is O(N²) candidate swaps, each
/// re-priced from scratch — placement attempts run at the engine cadence,
/// not per step, so the simple full re-price stays well inside the
/// per-topology budget at the P this repo sweeps; an incremental census
/// delta à la `refine_rounds` is the upgrade path if P grows).
const SEARCH_SWEEPS: usize = 8;
/// Relative improvement a swap must clear to be accepted (guards against
/// fp-noise cycles; also the "strictly decreases" margin tests rely on).
const SEARCH_REL_TOL: f64 = 1e-12;

/// Prices placements on one topology: predicted per-exchange completion
/// time of the EWMA loads routed through a candidate map, and the cost of
/// moving expert weights over the real links.
pub struct PlacementObjective<'a> {
    engine: CostEngine<'a>,
    token_bytes: f64,
}

impl<'a> PlacementObjective<'a> {
    /// `token_bytes` is the wire size of one dispatched token (d · elem).
    pub fn new(topo: &'a Topology, token_bytes: f64) -> PlacementObjective<'a> {
        PlacementObjective { engine: CostEngine::contention(topo), token_bytes }
    }

    /// Completion time of one dispatch exchange of `loads` (tokens, P×N)
    /// under `placement`.
    pub fn cost(&mut self, loads: &Mat, placement: &Placement) -> f64 {
        self.engine.exchange_time(&placement.bytes_matrix(loads, self.token_bytes))
    }

    /// Time to move every re-placed expert's weights (`expert_bytes` each)
    /// from its old host to its new one, as one concurrent exchange over
    /// the real links. Zero when the placements agree.
    pub fn migration_cost(&mut self, from: &Placement, to: &Placement, expert_bytes: f64) -> f64 {
        let bytes = from.migration_bytes(to, expert_bytes);
        if bytes.sum() <= 0.0 {
            return 0.0;
        }
        self.engine.exchange_time(&bytes)
    }
}

/// Locality-aware greedy initial placement: experts heaviest-first, each
/// onto the open device minimising `Σ_i loads[i][e] · (α_id + β_id·tok)`
/// — the load-weighted isolated delivery cost of reaching that expert
/// there. Deterministic: ties break toward the lower expert id and lower
/// device id. The result always satisfies the `e_per_dev` slot invariant.
pub fn greedy_placement(
    topo: &Topology,
    loads: &Mat,
    e_per_dev: usize,
    token_bytes: f64,
) -> Placement {
    let p = topo.p();
    let n = p * e_per_dev;
    assert_eq!((loads.rows(), loads.cols()), (p, n), "loads shape");
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| loads.col_sum(b).total_cmp(&loads.col_sum(a)).then(a.cmp(&b)));
    let mut free = vec![e_per_dev; p];
    let mut device_of = vec![usize::MAX; n];
    for e in order {
        let mut best = usize::MAX;
        let mut best_cost = f64::INFINITY;
        for d in 0..p {
            if free[d] == 0 {
                continue;
            }
            let cost: f64 = (0..p)
                .map(|i| {
                    loads.get(i, e) * (topo.alpha(i, d) + topo.beta(i, d) * token_bytes)
                })
                .sum();
            if cost < best_cost {
                best_cost = cost;
                best = d;
            }
        }
        device_of[e] = best;
        free[best] -= 1;
    }
    Placement::from_device_of(device_of, p, e_per_dev).expect("greedy respects slots")
}

/// First-improvement swap descent from `init`: repeatedly try swapping
/// every expert pair hosted on different devices, keeping a swap only when
/// the priced objective strictly drops. Monotone non-increasing in the
/// objective; returns when a full sweep finds no improving swap (or at the
/// sweep bound).
pub fn local_search(
    obj: &mut PlacementObjective<'_>,
    loads: &Mat,
    init: Placement,
) -> Placement {
    let n = init.n_experts();
    let mut placement = init;
    let mut cost = obj.cost(loads, &placement);
    for _ in 0..SEARCH_SWEEPS {
        let mut improved = false;
        for a in 0..n {
            for b in (a + 1)..n {
                if placement.device_of(a) == placement.device_of(b) {
                    continue;
                }
                placement.swap_experts(a, b);
                let c = obj.cost(loads, &placement);
                if c < cost * (1.0 - SEARCH_REL_TOL) {
                    cost = c;
                    improved = true;
                } else {
                    placement.swap_experts(a, b); // revert
                }
            }
        }
        if !improved {
            break;
        }
    }
    placement
}

/// Solve for a placement of `loads` on `topo`: swap descent seeded from
/// both the current placement and the greedy initialiser; the cheaper
/// result wins, with ties (within fp tolerance) going to the
/// current-seeded solution so no-op decisions don't shuffle experts.
pub fn solve_placement(
    topo: &Topology,
    loads: &Mat,
    current: &Placement,
    token_bytes: f64,
) -> Placement {
    let mut obj = PlacementObjective::new(topo, token_bytes);
    let from_current = local_search(&mut obj, loads, current.clone());
    let greedy = greedy_placement(topo, loads, current.e_per_dev(), token_bytes);
    let from_greedy = local_search(&mut obj, loads, greedy);
    let c_cur = obj.cost(loads, &from_current);
    let c_grd = obj.cost(loads, &from_greedy);
    if c_grd < c_cur * (1.0 - SEARCH_REL_TOL) {
        from_greedy
    } else {
        from_current
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{presets, Link, Topology, TreeSpec};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_tree(rng: &mut Rng) -> Topology {
        let n_nodes = rng.range(2, 4);
        let per_node = rng.range(2, 4);
        let dev = Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0));
        let up = Link::from_gbps_us(rng.range_f64(4.0, 25.0), rng.range_f64(5.0, 30.0));
        Topology::tree(
            &TreeSpec::symmetric(&[n_nodes, per_node]),
            &[dev, up],
            presets::local_copy(),
        )
    }

    /// The skewed load of the scenario tests: node-0 devices crowd the
    /// experts canonically hosted on node 1, node-1 devices stay uniform.
    fn skewed_loads(topo: &Topology, sent: f64) -> Mat {
        let p = topo.p();
        Mat::from_fn(p, p, |i, e| {
            if topo.node_of(i) == 0 {
                let hot = topo.node_of(e) == 1;
                let n_hot = (0..p).filter(|&x| topo.node_of(x) == 1).count() as f64;
                let n_cold = p as f64 - n_hot;
                if hot {
                    0.9 * sent / n_hot
                } else {
                    0.1 * sent / n_cold
                }
            } else {
                sent / p as f64
            }
        })
    }

    #[test]
    fn prop_solvers_emit_valid_placements() {
        check(
            25,
            0x51AC,
            |rng| {
                let topo = random_tree(rng);
                let p = topo.p();
                let e_per_dev = 1 + rng.below(2);
                let loads = Mat::from_fn(p, p * e_per_dev, |_, _| rng.range_f64(0.0, 1000.0));
                (topo, loads, e_per_dev)
            },
            |(topo, loads, e_per_dev)| {
                let tok = 512.0;
                let greedy = greedy_placement(topo, loads, *e_per_dev, tok);
                Placement::from_device_of(
                    greedy.device_map().to_vec(),
                    topo.p(),
                    *e_per_dev,
                )
                .map_err(|e| format!("greedy: {e}"))?;
                let mut obj = PlacementObjective::new(topo, tok);
                let searched = local_search(&mut obj, loads, greedy);
                Placement::from_device_of(
                    searched.device_map().to_vec(),
                    topo.p(),
                    *e_per_dev,
                )
                .map_err(|e| format!("local_search: {e}"))?;
                let solved =
                    solve_placement(topo, loads, &Placement::identity(topo.p(), *e_per_dev), tok);
                Placement::from_device_of(
                    solved.device_map().to_vec(),
                    topo.p(),
                    *e_per_dev,
                )
                .map_err(|e| format!("solve: {e}"))?;
                Ok(())
            },
        );
    }

    #[test]
    fn prop_local_search_never_increases_the_objective() {
        check(
            25,
            0x51AD,
            |rng| {
                let topo = random_tree(rng);
                let p = topo.p();
                let loads = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 1000.0));
                // random valid start: a shuffled identity
                let mut device_of: Vec<usize> = (0..p).collect();
                rng.shuffle(&mut device_of);
                (topo, loads, device_of)
            },
            |(topo, loads, device_of)| {
                let tok = 512.0;
                let init = Placement::from_device_of(device_of.clone(), topo.p(), 1).unwrap();
                let mut obj = PlacementObjective::new(topo, tok);
                let before = obj.cost(loads, &init);
                let after_p = local_search(&mut obj, loads, init);
                let after = obj.cost(loads, &after_p);
                if after > before * (1.0 + 1e-9) {
                    return Err(format!("search increased cost {before} → {after}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn skewed_load_beats_canonical_on_2x2_tree() {
        // The scenario the subsystem exists for: node-0 senders crowd the
        // experts canonically hosted across the uplink. The solver must
        // pull the hot experts onto node 0 and strictly beat identity.
        let topo = presets::table1(); // the [2,2] tree
        let loads = skewed_loads(&topo, 1024.0);
        let ident = Placement::identity(4, 1);
        let tok = 2048.0;
        let mut obj = PlacementObjective::new(&topo, tok);
        let c_ident = obj.cost(&loads, &ident);
        let solved = solve_placement(&topo, &loads, &ident, tok);
        let c_solved = obj.cost(&loads, &solved);
        assert!(
            c_solved < c_ident * 0.9,
            "solved {c_solved} not clearly below canonical {c_ident}"
        );
        assert!(!solved.is_identity());
        // the hot experts (canonically on node 1) now live on node 0
        let hot_on_node0 = (0..4)
            .filter(|&e| topo.node_of(e) == 1 && topo.node_of(solved.device_of(e)) == 0)
            .count();
        assert!(hot_on_node0 >= 1, "no hot expert moved: {:?}", solved.device_map());
    }

    #[test]
    fn uniform_load_keeps_identity_competitive() {
        // On a symmetric tree with uniform load every placement prices the
        // same, so solve_placement must return the current (identity)
        // placement — the tie rule that prevents pointless migrations.
        let topo = presets::table1();
        let loads = Mat::filled(4, 4, 256.0);
        let ident = Placement::identity(4, 1);
        let solved = solve_placement(&topo, &loads, &ident, 2048.0);
        assert!(solved.is_identity(), "{:?}", solved.device_map());
    }

    #[test]
    fn greedy_pulls_hot_experts_toward_their_senders() {
        let topo = presets::table1();
        let loads = skewed_loads(&topo, 1024.0);
        let greedy = greedy_placement(&topo, &loads, 1, 2048.0);
        // the heaviest experts are the canonical node-1 residents; greedy
        // must host at least one of them on node 0 (where the load is)
        let pulled = (0..4)
            .filter(|&e| topo.node_of(e) == 1 && topo.node_of(greedy.device_of(e)) == 0)
            .count();
        assert!(pulled >= 1, "{:?}", greedy.device_map());
    }
}
