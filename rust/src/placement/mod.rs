//! Topology- and load-aware expert placement (the dual axis to §4.2).
//!
//! TA-MoE adapts the *dispatch pattern* to the topology; this module
//! adapts the *expert-to-device mapping* to the observed gate load — the
//! optimisation axis the related systems exploit (HetuMoE's hierarchical
//! dispatch presumes good expert locality, MoNTA co-optimises the parallel
//! layout with network traffic). A hot expert stranded behind a slow
//! inter-node link no longer stays there forever:
//!
//! * [`Placement`] — the expert→device map. The canonical (identity)
//!   mapping `expert e → device e / e_per_dev` is the default everywhere;
//!   any other map must still be a permutation of the expert slots that
//!   hosts exactly `e_per_dev` experts per device.
//! * [`GateLoadEwma`] — an exponentially-weighted accumulator over the
//!   per-step dispatch counts `c_ie`, the load estimate placement
//!   decisions are made on (one noisy step must not trigger a migration).
//! * [`solver`] — the placement objective (predicted per-exchange byte
//!   matrix priced through the [`crate::comm::CostEngine`] contention
//!   model) plus two deterministic solvers: a locality-aware greedy
//!   initialiser and a swap-based local search that never increases the
//!   priced objective.
//! * [`engine`] — the amortised live-migration controller: re-placement
//!   only triggers when the predicted per-step savings pay for moving the
//!   expert weights (priced over the real links) within a configurable
//!   horizon. Every accepted migration bumps a *placement epoch* that
//!   invalidates the step-level `PlanCache` (schedules were synthesised
//!   for the old routing).
//!
//! Placement changes where experts *live*, not what the gate *learns*:
//! the dispatch matrix `c_ie` stays in expert space, and only its routing
//! onto devices (byte matrices, per-device compute loads, the intra-node
//! mask) goes through the placement map.

pub mod engine;
pub mod solver;

pub use engine::{Migration, OverlapPricing, PlacementConfig, PlacementEngine};
pub use solver::{greedy_placement, local_search, solve_placement, PlacementObjective};

use crate::topology::Topology;
use crate::util::Mat;

/// An expert→device map: `device_of[e]` hosts expert `e`. Always a
/// permutation of the canonical layout — every device hosts exactly
/// `e_per_dev` experts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    device_of: Vec<usize>,
    p: usize,
    e_per_dev: usize,
}

impl Placement {
    /// The canonical mapping `expert e → device e / e_per_dev`.
    pub fn identity(p: usize, e_per_dev: usize) -> Placement {
        assert!(p >= 1 && e_per_dev >= 1);
        Placement {
            device_of: (0..p * e_per_dev).map(|e| e / e_per_dev).collect(),
            p,
            e_per_dev,
        }
    }

    /// Build from an explicit map, validating the `e_per_dev`-slot
    /// permutation invariant.
    pub fn from_device_of(
        device_of: Vec<usize>,
        p: usize,
        e_per_dev: usize,
    ) -> Result<Placement, String> {
        if device_of.len() != p * e_per_dev {
            return Err(format!(
                "placement maps {} experts, world has {}",
                device_of.len(),
                p * e_per_dev
            ));
        }
        let mut slots = vec![0usize; p];
        for (e, &d) in device_of.iter().enumerate() {
            if d >= p {
                return Err(format!("expert {e} placed on device {d} >= P={p}"));
            }
            slots[d] += 1;
        }
        if let Some(d) = (0..p).find(|&d| slots[d] != e_per_dev) {
            return Err(format!(
                "device {d} hosts {} experts, every device must host {e_per_dev}",
                slots[d]
            ));
        }
        Ok(Placement { device_of, p, e_per_dev })
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn e_per_dev(&self) -> usize {
        self.e_per_dev
    }

    pub fn n_experts(&self) -> usize {
        self.device_of.len()
    }

    /// Device hosting expert `e`.
    #[inline]
    pub fn device_of(&self, e: usize) -> usize {
        self.device_of[e]
    }

    pub fn device_map(&self) -> &[usize] {
        &self.device_of
    }

    /// Experts hosted on device `d`, in expert order.
    pub fn experts_on(&self, d: usize) -> Vec<usize> {
        (0..self.device_of.len()).filter(|&e| self.device_of[e] == d).collect()
    }

    /// Is this the canonical `e / e_per_dev` layout?
    pub fn is_identity(&self) -> bool {
        self.device_of.iter().enumerate().all(|(e, &d)| d == e / self.e_per_dev)
    }

    /// Swap the hosts of two experts (the local-search move). Keeps the
    /// slot invariant by construction.
    pub fn swap_experts(&mut self, a: usize, b: usize) {
        self.device_of.swap(a, b);
    }

    /// `[P, N]` mask: 1.0 where expert `e`'s host shares a node with
    /// device `i` — the placement-aware form of
    /// [`Topology::local_mask`].
    pub fn local_mask(&self, topo: &Topology) -> Mat {
        assert_eq!(topo.p(), self.p, "placement/topology world mismatch");
        Mat::from_fn(self.p, self.n_experts(), |i, e| {
            if topo.same_node(i, self.device_of[e]) {
                1.0
            } else {
                0.0
            }
        })
    }

    /// Route a `P×N` dispatch matrix (tokens) onto devices: the `P×P`
    /// byte matrix of one exchange under this placement
    /// (`bytes[i][j] = Σ_{e on j} counts[i][e] · token_bytes`).
    pub fn bytes_matrix(&self, counts: &Mat, token_bytes: f64) -> Mat {
        assert_eq!(counts.rows(), self.p, "counts rows");
        assert_eq!(counts.cols(), self.n_experts(), "counts cols");
        // accumulate tokens first, scale once: identical rounding to the
        // canonical sum-then-multiply bytes loop in `step_cost`, so the
        // identity placement reproduces it bit-for-bit
        let mut bytes = Mat::zeros(self.p, self.p);
        for i in 0..self.p {
            for e in 0..self.n_experts() {
                bytes.add_assign(i, self.device_of[e], counts.get(i, e));
            }
        }
        for v in bytes.data_mut() {
            *v *= token_bytes;
        }
        bytes
    }

    /// Tokens received per device under this placement (the expert-compute
    /// load the slowest device bounds).
    pub fn recv_per_device(&self, counts: &Mat) -> Vec<f64> {
        assert_eq!(counts.cols(), self.n_experts(), "counts cols");
        let mut recv = vec![0.0; self.p];
        for e in 0..self.n_experts() {
            recv[self.device_of[e]] += counts.col_sum(e);
        }
        recv
    }

    /// Experts hosted on a different device in `to` than here.
    pub fn moved_experts(&self, to: &Placement) -> Vec<usize> {
        assert_eq!(self.device_of.len(), to.device_of.len());
        (0..self.device_of.len()).filter(|&e| self.device_of[e] != to.device_of[e]).collect()
    }

    /// `P×P` byte matrix of migrating from this placement to `to`:
    /// `expert_bytes` flows from each moved expert's old host to its new
    /// host. Priced over the real links by the migration cost model.
    pub fn migration_bytes(&self, to: &Placement, expert_bytes: f64) -> Mat {
        let mut bytes = Mat::zeros(self.p, self.p);
        for e in self.moved_experts(to) {
            bytes.add_assign(self.device_of[e], to.device_of[e], expert_bytes);
        }
        bytes
    }
}

/// EWMA accumulator over per-step gate loads `c_ie` (tokens, P×N). The
/// placement engine decides on this smoothed estimate, never on a single
/// step's counts.
#[derive(Clone, Debug)]
pub struct GateLoadEwma {
    loads: Mat,
    alpha: f64,
    steps: u64,
}

impl GateLoadEwma {
    /// `alpha` is the weight of the newest observation (0 < alpha ≤ 1).
    pub fn new(p: usize, n_experts: usize, alpha: f64) -> GateLoadEwma {
        assert!(alpha > 0.0 && alpha <= 1.0, "ewma alpha {alpha} out of (0, 1]");
        GateLoadEwma { loads: Mat::zeros(p, n_experts), alpha, steps: 0 }
    }

    /// Fold one step's dispatch counts in. The first observation seeds the
    /// estimate directly (no decay toward the zero init).
    pub fn observe(&mut self, counts: &Mat) {
        assert_eq!(
            (counts.rows(), counts.cols()),
            (self.loads.rows(), self.loads.cols()),
            "counts shape"
        );
        if self.steps == 0 {
            self.loads = counts.clone();
        } else {
            let a = self.alpha;
            for (l, &c) in self.loads.data_mut().iter_mut().zip(counts.data()) {
                *l = (1.0 - a) * *l + a * c;
            }
        }
        self.steps += 1;
    }

    /// The smoothed per-step load estimate (tokens, P×N).
    pub fn loads(&self) -> &Mat {
        &self.loads
    }

    /// Observations folded in so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    #[test]
    fn identity_matches_canonical_hosting() {
        let pl = Placement::identity(4, 2);
        assert_eq!(pl.n_experts(), 8);
        assert!(pl.is_identity());
        for e in 0..8 {
            assert_eq!(pl.device_of(e), e / 2);
        }
        assert_eq!(pl.experts_on(1), vec![2, 3]);
    }

    #[test]
    fn from_device_of_validates_slots() {
        assert!(Placement::from_device_of(vec![0, 1, 2, 3], 4, 1).is_ok());
        assert!(Placement::from_device_of(vec![1, 0, 3, 2], 4, 1).is_ok());
        // device 0 hosts two experts, device 1 none
        assert!(Placement::from_device_of(vec![0, 0, 2, 3], 4, 1).is_err());
        // out of range
        assert!(Placement::from_device_of(vec![0, 1, 2, 4], 4, 1).is_err());
        // wrong length
        assert!(Placement::from_device_of(vec![0, 1], 4, 1).is_err());
    }

    #[test]
    fn swap_keeps_validity_and_breaks_identity() {
        let mut pl = Placement::identity(4, 1);
        pl.swap_experts(0, 2);
        assert!(!pl.is_identity());
        assert_eq!(pl.device_of(0), 2);
        assert_eq!(pl.device_of(2), 0);
        assert!(Placement::from_device_of(pl.device_map().to_vec(), 4, 1).is_ok());
    }

    #[test]
    fn local_mask_follows_the_placement_not_the_expert_id() {
        let topo = presets::table1(); // [2,2]: devices {0,1} node0, {2,3} node1
        let mut pl = Placement::identity(4, 1);
        pl.swap_experts(0, 2);
        let m = pl.local_mask(&topo);
        // expert 2 now lives on device 0 (node 0)
        assert_eq!(m.get(0, 2), 1.0);
        assert_eq!(m.get(3, 2), 0.0);
        // expert 0 moved to node 1
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(3, 0), 1.0);
        // canonical mask for untouched experts
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.get(3, 3), 1.0);
    }

    #[test]
    fn bytes_matrix_routes_through_the_placement() {
        let mut counts = Mat::zeros(2, 4); // P=2, e_per_dev=2
        counts.set(0, 2, 10.0);
        counts.set(0, 3, 5.0);
        let ident = Placement::identity(2, 2);
        let b = ident.bytes_matrix(&counts, 2.0);
        assert_eq!(b.get(0, 1), 30.0); // experts 2,3 on device 1
        let mut pl = Placement::identity(2, 2);
        pl.swap_experts(0, 2); // expert 2 → device 0, expert 0 → device 1
        let b = pl.bytes_matrix(&counts, 2.0);
        assert_eq!(b.get(0, 0), 20.0);
        assert_eq!(b.get(0, 1), 10.0);
    }

    #[test]
    fn recv_per_device_groups_by_host() {
        let counts = Mat::from_fn(2, 2, |i, e| (i * 2 + e) as f64 + 1.0);
        // col sums: e0 = 1 + 3 = 4, e1 = 2 + 4 = 6
        let ident = Placement::identity(2, 1);
        assert_eq!(ident.recv_per_device(&counts), vec![4.0, 6.0]);
        let swapped = Placement::from_device_of(vec![1, 0], 2, 1).unwrap();
        assert_eq!(swapped.recv_per_device(&counts), vec![6.0, 4.0]);
    }

    #[test]
    fn migration_bytes_covers_exactly_the_moved_experts() {
        let a = Placement::identity(4, 1);
        let mut b = Placement::identity(4, 1);
        b.swap_experts(1, 3);
        assert_eq!(a.moved_experts(&b), vec![1, 3]);
        let m = a.migration_bytes(&b, 100.0);
        assert_eq!(m.get(1, 3), 100.0); // expert 1: device 1 → 3
        assert_eq!(m.get(3, 1), 100.0); // expert 3: device 3 → 1
        assert_eq!(m.sum(), 200.0);
        assert!(a.migration_bytes(&a, 100.0).sum() == 0.0);
    }

    #[test]
    fn ewma_seeds_then_smooths() {
        let mut ew = GateLoadEwma::new(1, 2, 0.5);
        assert_eq!(ew.steps(), 0);
        ew.observe(&Mat::from_vec(1, 2, vec![4.0, 0.0]));
        assert_eq!(ew.loads().get(0, 0), 4.0, "first observation seeds");
        ew.observe(&Mat::from_vec(1, 2, vec![0.0, 4.0]));
        assert_eq!(ew.loads().get(0, 0), 2.0);
        assert_eq!(ew.loads().get(0, 1), 2.0);
        assert_eq!(ew.steps(), 2);
    }
}
