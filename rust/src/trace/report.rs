//! Post-run utilization report derived from retained trace spans.
//!
//! [`utilization`] folds a run's spans into per-resource busy totals
//! and the headline numbers every profiler report leads with: busy
//! fraction per link/device, straggler skew (max/mean device busy), and
//! the top-k hottest resources. The math is mirrored bit-exactly in
//! `python/mirrors/trace_utilization.py` (pallas-lint mirror registry,
//! subsystem `trace-utilization`).

use super::{TraceEvent, TracePh};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One resource row of the report.
#[derive(Clone, Debug, PartialEq)]
pub struct UtilizationRow {
    /// The track the spans ran on (`"dev:<i>"`, `"link:<slot>"`, …).
    pub track: String,
    /// Sum of span durations on the track.
    pub busy_s: f64,
    /// `busy_s / total_s`, zero when the run had no clock.
    pub busy_frac: f64,
    /// Number of positive-duration spans.
    pub spans: usize,
}

/// The folded report: rows sorted by track name, plus the headlines.
#[derive(Clone, Debug, PartialEq)]
pub struct UtilizationReport {
    pub rows: Vec<UtilizationRow>,
    /// Max/mean busy over `dev:` tracks; 1.0 for a skew-free (or
    /// device-free) run.
    pub straggler_skew: f64,
    /// Top-k tracks by busy time, busiest first (ties by name).
    pub hottest: Vec<String>,
    /// The run's simulated clock the fractions are against.
    pub total_s: f64,
}

/// Fold retained spans into the utilization report. Only positive
/// -duration [`TracePh::Span`] events count, and the aggregate `step`
/// track is excluded — it would otherwise dominate every headline while
/// saying nothing about *where* time went.
///
/// `dead_devs` lists devices the fault stream killed (`nodeloss:<dev>`
/// [`crate::metrics::PerturbationRecord`]s —
/// [`crate::metrics::RunLog::dead_devices`] derives the list). A corpse
/// contributes 0 busy seconds for the rest of the window, which would
/// deflate the device mean and inflate `straggler_skew` into reading
/// healthy devices as stragglers; dead devices keep their report rows
/// but are excluded from the skew's mean and max.
pub fn utilization(
    events: &[TraceEvent],
    total_s: f64,
    top_k: usize,
    dead_devs: &[usize],
) -> UtilizationReport {
    let mut busy: BTreeMap<&str, (f64, usize)> = BTreeMap::new();
    for e in events {
        if e.ph != TracePh::Span || e.dur_s <= 0.0 || e.track == "step" {
            continue;
        }
        let slot = busy.entry(&e.track).or_insert((0.0, 0));
        slot.0 += e.dur_s;
        slot.1 += 1;
    }
    let rows: Vec<UtilizationRow> = busy
        .iter()
        .map(|(track, (busy_s, spans))| UtilizationRow {
            track: track.to_string(),
            busy_s: *busy_s,
            busy_frac: if total_s > 0.0 { busy_s / total_s } else { 0.0 },
            spans: *spans,
        })
        .collect();

    let dev_busy: Vec<f64> = rows
        .iter()
        .filter(|r| r.track.starts_with("dev:") && !track_is_dead(&r.track, dead_devs))
        .map(|r| r.busy_s)
        .collect();
    let straggler_skew = if dev_busy.is_empty() {
        1.0
    } else {
        let mean = dev_busy.iter().sum::<f64>() / dev_busy.len() as f64;
        let max = dev_busy.iter().copied().fold(0.0, f64::max);
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    };

    let mut by_heat: Vec<(f64, &str)> = rows.iter().map(|r| (r.busy_s, r.track.as_str())).collect();
    // busiest first; ties resolve by track name so the report is total
    by_heat.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(b.1)));
    let hottest = by_heat.iter().take(top_k).map(|(_, t)| t.to_string()).collect();

    UtilizationReport { rows, straggler_skew, hottest, total_s }
}

/// Whether a `dev:<i>` track belongs to a whole-window-dead device.
fn track_is_dead(track: &str, dead_devs: &[usize]) -> bool {
    track
        .strip_prefix("dev:")
        .and_then(|d| d.parse::<usize>().ok())
        .is_some_and(|d| dead_devs.contains(&d))
}

/// The report as a `utilization.csv` body (header + one row per track).
pub fn utilization_csv(report: &UtilizationReport) -> String {
    let mut out = String::from("resource,busy_s,busy_frac,spans\n");
    for r in &report.rows {
        out.push_str(&format!("{},{},{},{}\n", r.track, r.busy_s, r.busy_frac, r.spans));
    }
    out
}

impl UtilizationReport {
    /// The report as the `utilization` subobject of summary JSON.
    pub fn to_json(&self) -> Json {
        let mut resources = BTreeMap::new();
        for r in &self.rows {
            let mut row = BTreeMap::new();
            row.insert("busy_s".to_string(), Json::Num(r.busy_s));
            row.insert("busy_frac".to_string(), Json::Num(r.busy_frac));
            row.insert("spans".to_string(), Json::Num(r.spans as f64));
            resources.insert(r.track.clone(), Json::Obj(row));
        }
        let mut obj = BTreeMap::new();
        obj.insert("resources".to_string(), Json::Obj(resources));
        obj.insert("straggler_skew".to_string(), Json::Num(self.straggler_skew));
        obj.insert(
            "hottest".to_string(),
            Json::Arr(self.hottest.iter().map(|t| Json::Str(t.clone())).collect()),
        );
        obj.insert("total_s".to_string(), Json::Num(self.total_s));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{TraceLevel, Tracer};

    fn spans() -> Vec<TraceEvent> {
        let mut t = Tracer::new(TraceLevel::Chunk);
        t.span("step", "step 0", "step", 0.0, 10.0, &[]);
        t.span("dev:0", "expert", "compute", 0.0, 4.0, &[]);
        t.span("dev:0", "expert", "compute", 5.0, 2.0, &[]);
        t.span("dev:1", "expert", "compute", 0.0, 2.0, &[]);
        t.span("link:3", "round", "a2a", 1.0, 5.0, &[]);
        t.instant("control", "migration", "placement", 2.0, &[]);
        t.span("chan:allreduce", "bucket", "allreduce", 6.0, 0.0, &[]);
        t.events().to_vec()
    }

    #[test]
    fn folds_busy_excluding_step_instants_and_zero_spans() {
        let rep = utilization(&spans(), 10.0, 2, &[]);
        let tracks: Vec<&str> = rep.rows.iter().map(|r| r.track.as_str()).collect();
        // sorted; no "step", no instant track, no zero-duration span
        assert_eq!(tracks, vec!["dev:0", "dev:1", "link:3"]);
        assert_eq!(rep.rows[0].busy_s, 6.0);
        assert_eq!(rep.rows[0].spans, 2);
        assert_eq!(rep.rows[0].busy_frac, 0.6);
        // skew: dev busy {6, 2}, mean 4, max 6
        assert!((rep.straggler_skew - 1.5).abs() < 1e-15);
        assert_eq!(rep.hottest, vec!["dev:0", "link:3"]);
        assert_eq!(rep.total_s, 10.0);
    }

    #[test]
    fn empty_run_yields_empty_report_without_nan() {
        let rep = utilization(&[], 0.0, 3, &[]);
        assert!(rep.rows.is_empty());
        assert_eq!(rep.straggler_skew, 1.0);
        assert!(rep.hottest.is_empty());
        // zero clock: fractions are 0, never NaN
        let one = utilization(&spans(), 0.0, 1, &[]);
        assert!(one.rows.iter().all(|r| r.busy_frac == 0.0));
    }

    #[test]
    fn csv_and_json_carry_the_rows() {
        let rep = utilization(&spans(), 10.0, 2, &[]);
        let csv = utilization_csv(&rep);
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("resource,busy_s,busy_frac,spans"));
        assert_eq!(lines.next(), Some("dev:0,6,0.6,2"));
        let j = rep.to_json();
        let r0 = j.req("resources").unwrap().req("dev:0").unwrap();
        assert_eq!(r0.req("busy_s").unwrap().as_f64(), Some(6.0));
        assert_eq!(j.req("straggler_skew").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.req("hottest").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn ties_in_heat_resolve_by_track_name() {
        let mut t = Tracer::new(TraceLevel::Chunk);
        t.span("link:9", "round", "a2a", 0.0, 1.0, &[]);
        t.span("link:1", "round", "a2a", 0.0, 1.0, &[]);
        let rep = utilization(t.events(), 1.0, 2, &[]);
        assert_eq!(rep.hottest, vec!["link:1", "link:9"]);
    }

    #[test]
    fn dead_devices_do_not_inflate_straggler_skew() {
        // dev:2 died just after the window opened: 1 busy second against
        // the survivors' 6 and 2. With the corpse in the mean the skew
        // reads 6/((6+2+1)/3) = 2.0 — a lie about the living. Excluded,
        // it is the honest 6/((6+2)/2) = 1.5.
        let mut t = Tracer::new(TraceLevel::Chunk);
        t.span("dev:0", "expert", "compute", 0.0, 6.0, &[]);
        t.span("dev:1", "expert", "compute", 0.0, 2.0, &[]);
        t.span("dev:2", "expert", "compute", 0.0, 1.0, &[]);
        let naive = utilization(t.events(), 10.0, 4, &[]);
        let fixed = utilization(t.events(), 10.0, 4, &[2]);
        assert!((naive.straggler_skew - 2.0).abs() < 1e-15);
        assert!((fixed.straggler_skew - 1.5).abs() < 1e-15);
        // the dead device keeps its report row — only the skew ignores it
        assert!(fixed.rows.iter().any(|r| r.track == "dev:2"));
        // all devices dead: mean of an empty set degrades to skew 1
        let all_dead = utilization(t.events(), 10.0, 4, &[0, 1, 2]);
        assert_eq!(all_dead.straggler_skew, 1.0);
    }
}
