//! The unified counters/gauges registry.
//!
//! Before this module every subsystem kept its own ad-hoc tallies (plan
//! hits/misses on the cache, migrations on the placement engine, fetches
//! on the expert cache, perturbations on the chaos engine) and every
//! consumer had to know where each lived. A [`MetricsRegistry`] names
//! them all in one sorted map with lint-enforced key grammar: counter
//! keys end in `_total`, gauge keys end in a canonical unit suffix
//! (`_s`, `_bytes`, …) — `pallas-lint`'s units rule checks every literal
//! key at `inc`/`gauge_add` call sites, so a misnamed metric fails CI
//! before it ever reaches a dashboard.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Named monotone counters (`u64`) and additive gauges (`f64`), sorted
/// by key for deterministic export. Cheap to clone and compare — tests
/// diff whole registries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to a counter, creating it at zero. Counter keys must end
    /// in `_total` (lint-enforced at literal call sites).
    pub fn inc(&mut self, key: &str, by: u64) {
        if let Some(v) = self.counters.get_mut(key) {
            *v += by;
        } else {
            self.counters.insert(key.to_string(), by);
        }
    }

    /// Current counter value — zero when never incremented.
    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Add `v` to an additive gauge, creating it at zero. Gauge keys must
    /// end in a canonical unit suffix (lint-enforced at literal sites).
    pub fn gauge_add(&mut self, key: &str, v: f64) {
        if let Some(g) = self.gauges.get_mut(key) {
            *g += v;
        } else {
            self.gauges.insert(key.to_string(), v);
        }
    }

    /// Current gauge value — zero when never touched.
    pub fn gauge(&self, key: &str) -> f64 {
        self.gauges.get(key).copied().unwrap_or(0.0)
    }

    /// True when no counter or gauge was ever touched.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty()
    }

    /// `{"counters": {...}, "gauges": {...}}`, keys sorted — the shape
    /// merged into summary JSON and the chrome trace's `otherData`.
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in &self.counters {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in &self.gauges {
            gauges.insert(k.clone(), Json::Num(*v));
        }
        let mut obj = BTreeMap::new();
        obj.insert("counters".to_string(), Json::Obj(counters));
        obj.insert("gauges".to_string(), Json::Obj(gauges));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_to_zero() {
        let mut r = MetricsRegistry::new();
        assert!(r.is_empty());
        assert_eq!(r.counter("plan_hits_total"), 0);
        r.inc("plan_hits_total", 1);
        r.inc("plan_hits_total", 2);
        assert_eq!(r.counter("plan_hits_total"), 3);
        assert!(!r.is_empty());
    }

    #[test]
    fn gauges_accumulate_additively() {
        let mut r = MetricsRegistry::new();
        r.gauge_add("migration_s", 0.5);
        r.gauge_add("migration_s", 0.25);
        assert_eq!(r.gauge("migration_s"), 0.75);
        assert_eq!(r.gauge("fetch_s"), 0.0);
    }

    #[test]
    fn json_export_is_sorted_and_round_trips() {
        let mut r = MetricsRegistry::new();
        r.inc("plan_misses_total", 4);
        r.inc("cache_hits_total", 7);
        r.gauge_add("migration_bytes", 1024.0);
        let j = r.to_json();
        let s = j.to_string_compact();
        // BTreeMap ordering: cache_hits before plan_misses
        assert!(s.find("cache_hits_total").unwrap() < s.find("plan_misses_total").unwrap());
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.req("counters").unwrap().get("cache_hits_total").unwrap().as_f64(), Some(7.0));
        assert_eq!(back.req("gauges").unwrap().get("migration_bytes").unwrap().as_f64(), Some(1024.0));
    }
}
