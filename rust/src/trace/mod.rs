//! Deterministic tracing & profiling on the simulated clock.
//!
//! Every number the simulator prices already lives on one simulated
//! clock, but until now only *aggregates* survived a run (CSV columns,
//! summary JSON) — the overlap [`crate::overlap::Timeline`] discarded
//! its events after computing busy totals, so "which link was the
//! bottleneck in step 412?" was unanswerable. This module retains the
//! structure:
//!
//! * [`Tracer`] — a span/event sink on the simulated clock. Sessions
//!   advance its clock by each step's priced makespan; the pricing path
//!   ([`crate::coordinator`]), placement engine, expert cache, and chaos
//!   engine emit spans (phases, per-link a2a rounds, pipeline events)
//!   and instants (migrations, fetches, plan hits/misses, faults)
//!   against it. No wall clock is ever read — the pallas-lint
//!   determinism bans apply to this directory.
//! * [`TraceLevel`] — how much detail to record: `step` (step spans +
//!   lifecycle instants), `phase` (adds serial phase spans), `chunk`
//!   (adds per-directed-link rounds and retained pipeline events).
//! * [`MetricsRegistry`] — named counters/gauges unifying the ad-hoc
//!   tallies, with lint-enforced key grammar.
//! * [`chrome_trace`] — Chrome-trace-event JSON (Perfetto-loadable).
//! * [`utilization`] — the post-run per-resource report (busy fraction,
//!   straggler skew, hottest resources), mirrored bit-exactly in
//!   `python/mirrors/trace_utilization.py`.
//!
//! The whole subsystem is opt-in: a session without a tracer attached
//! allocates nothing and prices byte-identically to one that never
//! heard of this module.

mod chrome;
mod registry;
mod report;

pub use chrome::chrome_trace;
pub use registry::MetricsRegistry;
pub use report::{utilization, utilization_csv, UtilizationReport, UtilizationRow};

use std::collections::BTreeMap;

/// How much detail the tracer records. Ordered: each level includes
/// everything below it (`Step < Phase < Chunk`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// One span per training/serve step plus lifecycle instants
    /// (migrations, fetches, faults, plan hits/misses) and the registry.
    Step,
    /// Adds serial phase spans: compute, a2a local/intra/inter,
    /// allreduce, laid back to back inside each step.
    Phase,
    /// Adds per-directed-link a2a round spans (serial steps) and the
    /// retained pipeline timeline (overlapped steps).
    Chunk,
}

impl std::fmt::Display for TraceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceLevel::Step => write!(f, "step"),
            TraceLevel::Phase => write!(f, "phase"),
            TraceLevel::Chunk => write!(f, "chunk"),
        }
    }
}

impl std::str::FromStr for TraceLevel {
    type Err = String;

    fn from_str(s: &str) -> Result<TraceLevel, String> {
        match s.trim() {
            "step" => Ok(TraceLevel::Step),
            "phase" => Ok(TraceLevel::Phase),
            "chunk" => Ok(TraceLevel::Chunk),
            other => Err(format!("unknown trace level {other:?} (known: step, phase, chunk)")),
        }
    }
}

/// Whether an event occupies time (a span) or marks a point (instant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TracePh {
    /// Chrome `"X"` — a complete span with a duration.
    Span,
    /// Chrome `"i"` — an instantaneous marker.
    Mark,
}

/// One recorded event. `track` names the resource row it renders on
/// (`"step"`, `"serial"`, `"dev:<i>"`, `"link:<slot>"`, `"chan:<name>"`,
/// `"control"`); times are simulated seconds from the run's origin.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub track: String,
    pub name: String,
    pub cat: String,
    pub ph: TracePh,
    pub start_s: f64,
    pub dur_s: f64,
    pub args: Vec<(String, f64)>,
}

/// The span/event sink. Owned by a `WorkloadCore` when tracing is on;
/// the session advances [`Tracer::advance`] by each step's priced total
/// so emitters only compute offsets within the current step.
#[derive(Clone, Debug)]
pub struct Tracer {
    level: TraceLevel,
    /// Simulated time at the start of the step being traced.
    clock_s: f64,
    events: Vec<TraceEvent>,
    registry: MetricsRegistry,
    /// Independent busy accounting per track, fed from
    /// `Timeline::busy()` (field accumulation in `schedule`), NOT from
    /// the retained event list — so the validator's span-sum
    /// reconciliation checks a real invariant, not a tautology.
    timeline_busy: BTreeMap<String, f64>,
}

impl Tracer {
    pub fn new(level: TraceLevel) -> Tracer {
        Tracer {
            level,
            clock_s: 0.0,
            events: Vec::new(),
            registry: MetricsRegistry::new(),
            timeline_busy: BTreeMap::new(),
        }
    }

    /// The configured detail level.
    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// True when events of `level` should be recorded (each level
    /// includes everything below it).
    pub fn enabled(&self, level: TraceLevel) -> bool {
        self.level >= level
    }

    /// Simulated time at the start of the current step.
    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// Advance the step origin by one step's priced total.
    pub fn advance(&mut self, dt_s: f64) {
        debug_assert!(dt_s >= 0.0, "clock must not run backwards ({dt_s})");
        self.clock_s += dt_s;
    }

    /// Record a complete span at an absolute simulated time.
    pub fn span(
        &mut self,
        track: &str,
        name: &str,
        cat: &str,
        start_s: f64,
        dur_s: f64,
        args: &[(&str, f64)],
    ) {
        debug_assert!(dur_s >= 0.0, "negative span duration {dur_s}");
        self.events.push(TraceEvent {
            track: track.to_string(),
            name: name.to_string(),
            cat: cat.to_string(),
            ph: TracePh::Span,
            start_s,
            dur_s,
            args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// Record an instantaneous marker at an absolute simulated time.
    pub fn instant(&mut self, track: &str, name: &str, cat: &str, at_s: f64, args: &[(&str, f64)]) {
        self.events.push(TraceEvent {
            track: track.to_string(),
            name: name.to_string(),
            cat: cat.to_string(),
            ph: TracePh::Mark,
            start_s: at_s,
            dur_s: 0.0,
            args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// Everything recorded so far, in emission order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// The unified counters/gauges registry.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut MetricsRegistry {
        &mut self.registry
    }

    /// Accumulate a track's busy time from `Timeline::busy()` — the
    /// independent accounting the validator reconciles span sums
    /// against.
    pub fn note_busy(&mut self, track: &str, busy_s: f64) {
        if let Some(b) = self.timeline_busy.get_mut(track) {
            *b += busy_s;
        } else {
            self.timeline_busy.insert(track.to_string(), busy_s);
        }
    }

    /// Per-track busy totals accumulated via [`Tracer::note_busy`].
    pub fn timeline_busy(&self) -> &BTreeMap<String, f64> {
        &self.timeline_busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_display_and_order() {
        for (s, l) in [("step", TraceLevel::Step), ("phase", TraceLevel::Phase), ("chunk", TraceLevel::Chunk)] {
            assert_eq!(s.parse::<TraceLevel>().unwrap(), l);
            assert_eq!(l.to_string(), s);
        }
        assert!(TraceLevel::Step < TraceLevel::Phase);
        assert!(TraceLevel::Phase < TraceLevel::Chunk);
        assert!("off".parse::<TraceLevel>().is_err());
        let t = Tracer::new(TraceLevel::Phase);
        assert!(t.enabled(TraceLevel::Step));
        assert!(t.enabled(TraceLevel::Phase));
        assert!(!t.enabled(TraceLevel::Chunk));
    }

    #[test]
    fn spans_instants_and_clock_accumulate() {
        let mut t = Tracer::new(TraceLevel::Chunk);
        assert_eq!(t.clock_s(), 0.0);
        t.span("step", "step 0", "step", 0.0, 1.5, &[("loss", 2.0)]);
        t.advance(1.5);
        t.instant("control", "migration", "placement", t.clock_s(), &[]);
        assert_eq!(t.clock_s(), 1.5);
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.events()[0].ph, TracePh::Span);
        assert_eq!(t.events()[0].args, vec![("loss".to_string(), 2.0)]);
        assert_eq!(t.events()[1].ph, TracePh::Mark);
        assert_eq!(t.events()[1].start_s, 1.5);
    }

    #[test]
    fn note_busy_accumulates_per_track() {
        let mut t = Tracer::new(TraceLevel::Chunk);
        t.note_busy("dev:0", 1.0);
        t.note_busy("dev:0", 0.5);
        t.note_busy("chan:allreduce", 2.0);
        assert_eq!(t.timeline_busy().get("dev:0"), Some(&1.5));
        assert_eq!(t.timeline_busy().get("chan:allreduce"), Some(&2.0));
        assert_eq!(t.timeline_busy().len(), 2);
    }

    #[test]
    fn registry_reachable_through_the_tracer() {
        let mut t = Tracer::new(TraceLevel::Step);
        t.registry_mut().inc("migrations_total", 1);
        assert_eq!(t.registry().counter("migrations_total"), 1);
    }
}
