//! Chrome-trace-event JSON exporter.
//!
//! [`chrome_trace`] serialises a [`Tracer`]'s events in the Chrome
//! trace-event format (the `{"traceEvents": [...]}` object form), which
//! Perfetto and `chrome://tracing` load directly. Tracks become
//! threads: each track gets a `tid` in first-use order plus a
//! `thread_name` metadata event, spans become `"X"` complete events and
//! markers `"i"` instants, with timestamps in microseconds of simulated
//! time. `otherData` carries the registry and the independent
//! `Timeline::busy()` totals the validator reconciles span sums
//! against. Output is deterministic: `util::json::Json` objects are
//! sorted maps and event order is emission order.

use super::{TracePh, Tracer};
use crate::util::json::Json;
use std::collections::BTreeMap;

const PID: f64 = 1.0;

/// Serialise the tracer's full state as a Chrome trace JSON value.
pub fn chrome_trace(tracer: &Tracer) -> Json {
    let mut tid_of: BTreeMap<&str, usize> = BTreeMap::new();
    let mut track_order: Vec<&str> = Vec::new();
    for e in tracer.events() {
        if !tid_of.contains_key(e.track.as_str()) {
            tid_of.insert(&e.track, track_order.len() + 1);
            track_order.push(&e.track);
        }
    }

    let mut trace_events: Vec<Json> = Vec::with_capacity(tracer.events().len() + track_order.len());
    for (i, track) in track_order.iter().enumerate() {
        let mut args = BTreeMap::new();
        args.insert("name".to_string(), Json::Str(track.to_string()));
        let mut ev = BTreeMap::new();
        ev.insert("ph".to_string(), Json::Str("M".to_string()));
        ev.insert("name".to_string(), Json::Str("thread_name".to_string()));
        ev.insert("pid".to_string(), Json::Num(PID));
        ev.insert("tid".to_string(), Json::Num((i + 1) as f64));
        ev.insert("args".to_string(), Json::Obj(args));
        trace_events.push(Json::Obj(ev));
    }

    for e in tracer.events() {
        let tid = tid_of[e.track.as_str()];
        let mut ev = BTreeMap::new();
        ev.insert("name".to_string(), Json::Str(e.name.clone()));
        ev.insert("cat".to_string(), Json::Str(e.cat.clone()));
        ev.insert("pid".to_string(), Json::Num(PID));
        ev.insert("tid".to_string(), Json::Num(tid as f64));
        ev.insert("ts".to_string(), Json::Num(e.start_s * 1e6));
        match e.ph {
            TracePh::Span => {
                ev.insert("ph".to_string(), Json::Str("X".to_string()));
                ev.insert("dur".to_string(), Json::Num(e.dur_s * 1e6));
            }
            TracePh::Mark => {
                ev.insert("ph".to_string(), Json::Str("i".to_string()));
                // thread-scoped instant (renders as a tick on the track)
                ev.insert("s".to_string(), Json::Str("t".to_string()));
            }
        }
        if !e.args.is_empty() {
            let mut args = BTreeMap::new();
            for (k, v) in &e.args {
                args.insert(k.clone(), Json::Num(*v));
            }
            ev.insert("args".to_string(), Json::Obj(args));
        }
        trace_events.push(Json::Obj(ev));
    }

    // otherData: the registry plus the independent busy accounting
    let mut other = match tracer.registry().to_json() {
        Json::Obj(m) => m,
        _ => BTreeMap::new(),
    };
    let mut busy = BTreeMap::new();
    for (track, b) in tracer.timeline_busy() {
        busy.insert(track.clone(), Json::Num(*b));
    }
    other.insert("timeline_busy_s".to_string(), Json::Obj(busy));

    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(trace_events));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    top.insert("otherData".to_string(), Json::Obj(other));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceLevel;

    #[test]
    fn exports_metadata_spans_and_instants() {
        let mut t = Tracer::new(TraceLevel::Chunk);
        t.span("step", "step 0", "step", 0.0, 1.5, &[("loss", 2.0)]);
        t.span("dev:0", "expert", "compute", 0.25, 0.5, &[]);
        t.instant("step", "migration", "placement", 1.0, &[]);
        t.note_busy("dev:0", 0.5);
        t.registry_mut().inc("migrations_total", 1);

        let j = chrome_trace(&t);
        let evs = j.req("traceEvents").unwrap().as_arr().unwrap();
        // 2 tracks -> 2 metadata events, then the 3 recorded events
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].req("ph").unwrap().as_str(), Some("M"));
        assert_eq!(evs[0].req("args").unwrap().req("name").unwrap().as_str(), Some("step"));
        assert_eq!(evs[1].req("args").unwrap().req("name").unwrap().as_str(), Some("dev:0"));
        // the step span: tid 1 (first use), ts 0, dur 1.5e6 us
        assert_eq!(evs[2].req("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[2].req("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(evs[2].req("dur").unwrap().as_f64(), Some(1.5e6));
        assert_eq!(evs[2].req("args").unwrap().req("loss").unwrap().as_f64(), Some(2.0));
        // the instant rides the step track with a scope
        assert_eq!(evs[4].req("ph").unwrap().as_str(), Some("i"));
        assert_eq!(evs[4].req("s").unwrap().as_str(), Some("t"));
        assert_eq!(evs[4].req("ts").unwrap().as_f64(), Some(1e6));
        // otherData: registry + busy accounting
        let other = j.req("otherData").unwrap();
        assert_eq!(
            other.req("counters").unwrap().req("migrations_total").unwrap().as_f64(),
            Some(1.0)
        );
        assert_eq!(
            other.req("timeline_busy_s").unwrap().req("dev:0").unwrap().as_f64(),
            Some(0.5)
        );
    }

    #[test]
    fn serialisation_is_deterministic() {
        let build = || {
            let mut t = Tracer::new(TraceLevel::Phase);
            t.span("serial", "a2a:inter", "a2a", 0.125, 0.75, &[]);
            t.instant("step", "plan:miss", "plan", 0.0, &[]);
            t.note_busy("serial", 0.75);
            chrome_trace(&t).to_string_compact()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn empty_tracer_exports_a_loadable_skeleton() {
        let t = Tracer::new(TraceLevel::Step);
        let j = chrome_trace(&t);
        assert_eq!(j.req("traceEvents").unwrap().as_arr().unwrap().len(), 0);
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(back, j);
    }
}
