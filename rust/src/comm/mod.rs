//! α-β communication cost engine (paper §4.1) and collective schedules.
//!
//! A global MoE exchange is P×P peer-to-peer deliveries; the engine prices
//! a byte matrix (`bytes[i][j]` from device i to j) under three models:
//!
//! * [`ExchangeModel::SlowestPair`] — `max_ij (α_ij + β_ij · bytes_ij)`,
//!   the Eq. 2 lower bound the paper optimises ("the slowest delivery, as
//!   a lower-bound, constrains the final communication performance");
//! * [`ExchangeModel::PerSenderSerial`] — each sender serialises its P
//!   sends (single-NIC behaviour); the exchange ends when the slowest
//!   sender finishes;
//! * [`ExchangeModel::Contention`] — each flow's β is inflated by the
//!   number of concurrent flows sharing each physical link (full-duplex,
//!   per direction). This is the model that reproduces Table 1: the
//!   inter-node uplink of a [2,2] tree carries 4 concurrent flows, which
//!   is exactly why 32 MB takes ~5.6 ms there and why uneven dispatch
//!   wins ~30%.
//!
//! [`hierarchical_a2a_time`] prices the DeepSpeed-MoE/HetuMoE hierarchical
//! all-to-all (intra-gather → inter-exchange → intra-scatter) for the
//! system-level comparison, and [`ring_allreduce_time`] prices the dense
//! gradient synchronisation in the coordinator's step-time model.
//!
//! All of these execution styles unify behind the [`A2aAlgo`] planner
//! (`direct | hier | sched:xor | sched:rot | sched:bvn`), the seam
//! `step_cost`, `Session`, and the `--a2a` CLI flag select on;
//! [`bvn_schedule`] is its byte-matrix-aware schedule synthesizer.

mod allreduce;
mod alltoall;
mod engine;
mod plan;
mod profile;
mod schedules;

pub use allreduce::ring_allreduce_time;
pub use alltoall::{hierarchical_a2a_time, HierBreakdown};
// census primitives, shared with the tracer's per-link round attribution
// (coordinator::cost) so traced link times match priced round times
pub(crate) use engine::{census_add, census_sub, contended_time};
pub use engine::{CostEngine, ExchangeModel};
pub use plan::{bvn_schedule, price_rounds, A2aAlgo, A2aBreakdown, CommPlan, ScheduleKind};
pub use profile::{profile_exchange, ExchangeProfile};
pub use schedules::{
    rotation_schedule, scheduled_a2a_time, validate_schedule, xor_schedule, Round,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;
    use crate::util::Mat;

    #[test]
    fn table1_motivation_reproduces() {
        // §3.3 / Table 1: on [[0,1],[0̂,1̂]] with 128 MB per rank, uneven
        // dispatch (¼,½,⅛,⅛) beats even (¼,¼,¼,¼) by roughly 30%.
        let topo = presets::table1();
        let total = 128.0 * 1024.0 * 1024.0;
        let even = Mat::filled(4, 4, total / 4.0);
        // rank r sends ¼ local, ½ to its node peer, ⅛ to each remote
        let peer = [1usize, 0, 3, 2];
        let uneven = Mat::from_fn(4, 4, |i, j| {
            if i == j {
                total / 4.0
            } else if j == peer[i] {
                total / 2.0
            } else {
                total / 8.0
            }
        });
        let mut eng = CostEngine::contention(&topo);
        let t_even = eng.exchange_time(&even);
        let t_uneven = eng.exchange_time(&uneven);
        let speedup = t_even / t_uneven;
        assert!(
            (1.2..2.2).contains(&speedup),
            "speedup {speedup} out of the paper's ballpark"
        );
    }

    #[test]
    fn models_are_ordered() {
        // serial ≥ contention ≥ slowest-pair on any dense exchange
        let topo = presets::cluster_c(2);
        let bytes = Mat::filled(16, 16, 1e6);
        let lb = CostEngine::slowest_pair(&topo).exchange_time(&bytes);
        let ct = CostEngine::contention(&topo).exchange_time(&bytes);
        let sr = CostEngine::per_sender(&topo).exchange_time(&bytes);
        assert!(lb <= ct + 1e-12, "{lb} {ct}");
        assert!(ct <= sr * (16.0) + 1e-12);
        assert!(lb > 0.0);
    }
}
