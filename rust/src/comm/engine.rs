//! The core cost engine: price a P×P byte matrix under an exchange model.

use crate::topology::Topology;
use crate::util::Mat;
use std::collections::HashMap;

/// How concurrent peer-to-peer deliveries interact (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeModel {
    SlowestPair,
    PerSenderSerial,
    Contention,
}

/// Prices exchanges on one topology. Cheap to construct; borrow-only.
pub struct CostEngine<'a> {
    topo: &'a Topology,
    model: ExchangeModel,
}

impl<'a> CostEngine<'a> {
    pub fn new(topo: &'a Topology, model: ExchangeModel) -> Self {
        CostEngine { topo, model }
    }

    pub fn slowest_pair(topo: &'a Topology) -> Self {
        Self::new(topo, ExchangeModel::SlowestPair)
    }

    pub fn per_sender(topo: &'a Topology) -> Self {
        Self::new(topo, ExchangeModel::PerSenderSerial)
    }

    pub fn contention(topo: &'a Topology) -> Self {
        Self::new(topo, ExchangeModel::Contention)
    }

    pub fn model(&self) -> ExchangeModel {
        self.model
    }

    /// Isolated pair delivery time: `α_ij + β_ij · bytes` (no contention).
    pub fn pair_time(&self, i: usize, j: usize, bytes: f64) -> f64 {
        self.topo.alpha(i, j) + self.topo.beta(i, j) * bytes
    }

    /// Per-pair delivery times for a full exchange under the engine's
    /// model. Zero-byte pairs cost 0 (no message sent).
    pub fn pair_times(&self, bytes: &Mat) -> Mat {
        let p = self.topo.p();
        assert_eq!((bytes.rows(), bytes.cols()), (p, p), "byte matrix shape");
        match self.model {
            ExchangeModel::SlowestPair | ExchangeModel::PerSenderSerial => {
                Mat::from_fn(p, p, |i, j| {
                    let b = bytes.get(i, j);
                    if b <= 0.0 {
                        0.0
                    } else {
                        self.pair_time(i, j, b)
                    }
                })
            }
            ExchangeModel::Contention => self.contention_pair_times(bytes),
        }
    }

    /// Completion time of the whole exchange under the engine's model.
    pub fn exchange_time(&self, bytes: &Mat) -> f64 {
        let times = self.pair_times(bytes);
        match self.model {
            ExchangeModel::SlowestPair | ExchangeModel::Contention => times.max().max(0.0),
            ExchangeModel::PerSenderSerial => (0..times.rows())
                .map(|i| times.row(i).iter().sum::<f64>())
                .fold(0.0, f64::max),
        }
    }

    /// Completion time of one synchronised round consisting of the given
    /// deliveries only. Zero-byte pairs cost nothing; self pairs are local
    /// copies that overlap with the network and never gate a round, so
    /// they are skipped here (callers price them separately). Returns 0.0
    /// for an effectively-empty round — an empty round costs nothing.
    pub fn round_time(&self, bytes: &Mat, round: &[(usize, usize)]) -> f64 {
        let p = self.topo.p();
        assert_eq!((bytes.rows(), bytes.cols()), (p, p), "byte matrix shape");
        let live = |&&(i, j): &&(usize, usize)| i != j && bytes.get(i, j) > 0.0;
        match self.model {
            ExchangeModel::SlowestPair => round
                .iter()
                .filter(live)
                .map(|&(i, j)| self.pair_time(i, j, bytes.get(i, j)))
                .fold(0.0, f64::max),
            ExchangeModel::PerSenderSerial => {
                let mut per_sender = vec![0.0; p];
                for &(i, j) in round.iter().filter(live) {
                    per_sender[i] += self.pair_time(i, j, bytes.get(i, j));
                }
                per_sender.into_iter().fold(0.0, f64::max)
            }
            ExchangeModel::Contention => {
                let load = self.link_load(round.iter().filter(live).copied());
                round
                    .iter()
                    .filter(live)
                    .map(|&(i, j)| self.contended_pair_time(&load, i, j, bytes.get(i, j)))
                    .fold(0.0, f64::max)
            }
        }
    }

    /// Flows per directed physical link across the given deliveries.
    fn link_load(
        &self,
        pairs: impl Iterator<Item = (usize, usize)>,
    ) -> HashMap<(usize, bool), usize> {
        let mut load = HashMap::new();
        for (i, j) in pairs {
            for dl in self.topo.path(i, j) {
                *load.entry((dl.edge, dl.up)).or_insert(0) += 1;
            }
        }
        load
    }

    /// One delivery's time under a flow census: α accumulates along the
    /// path, the slowest hop's β is inflated by its concurrent flows
    /// (non-blocking point-to-point links never contend).
    fn contended_pair_time(
        &self,
        load: &HashMap<(usize, bool), usize>,
        i: usize,
        j: usize,
        bytes: f64,
    ) -> f64 {
        let links = self.topo.links();
        let mut alpha = 0.0;
        let mut slow: f64 = 0.0;
        for dl in self.topo.path(i, j) {
            let flows = if self.topo.link_contended(dl.edge) {
                load[&(dl.edge, dl.up)] as f64
            } else {
                1.0
            };
            alpha += links[dl.edge].alpha;
            slow = slow.max(links[dl.edge].beta * flows);
        }
        alpha + slow * bytes
    }

    /// Contention pricing: each flow crosses its link path with β inflated
    /// by the number of concurrent flows using that (link, direction).
    fn contention_pair_times(&self, bytes: &Mat) -> Mat {
        let p = self.topo.p();
        let load = self.link_load(
            (0..p)
                .flat_map(|i| (0..p).map(move |j| (i, j)))
                .filter(|&(i, j)| i != j && bytes.get(i, j) > 0.0),
        );
        Mat::from_fn(p, p, |i, j| {
            let b = bytes.get(i, j);
            if b <= 0.0 {
                return 0.0;
            }
            if i == j {
                return self.pair_time(i, i, b);
            }
            self.contended_pair_time(&load, i, j, b)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{presets, Link, Topology, TreeSpec};

    fn tree22() -> Topology {
        Topology::tree(
            &TreeSpec::parse("[2,2]").unwrap(),
            &[Link::from_gbps_us(45.0, 2.0), Link::from_gbps_us(23.0, 10.0)],
            presets::local_copy(),
        )
    }

    #[test]
    fn slowest_pair_is_max_alpha_beta() {
        let t = tree22();
        let eng = CostEngine::slowest_pair(&t);
        let bytes = Mat::filled(4, 4, 1e6);
        let want = t.alpha(0, 2) + t.beta(0, 2) * 1e6;
        assert!((eng.exchange_time(&bytes) - want).abs() < 1e-12);
    }

    #[test]
    fn zero_bytes_cost_nothing() {
        let t = tree22();
        for eng in [
            CostEngine::slowest_pair(&t),
            CostEngine::per_sender(&t),
            CostEngine::contention(&t),
        ] {
            assert_eq!(eng.exchange_time(&Mat::zeros(4, 4)), 0.0);
        }
    }

    #[test]
    fn per_sender_serialises_row() {
        let t = tree22();
        let eng = CostEngine::per_sender(&t);
        let bytes = Mat::filled(4, 4, 1e6);
        let row: f64 = (0..4).map(|j| eng.pair_time(0, j, 1e6)).sum();
        assert!((eng.exchange_time(&bytes) - row).abs() / row < 1e-9);
    }

    #[test]
    fn contention_inflates_shared_uplinks() {
        let t = tree22();
        let eng = CostEngine::contention(&t);
        let full = Mat::filled(4, 4, 1e6);
        let times = eng.pair_times(&full);
        // cross-node flow shares the uplink with 3 other upward flows
        let isolated = eng.pair_time(0, 2, 1e6) - t.alpha(0, 2);
        let contended = times.get(0, 2) - t.alpha(0, 2);
        let ratio = contended / isolated;
        assert!((ratio - 4.0).abs() < 0.3, "ratio {ratio}");
        // intra-node flow unaffected by the uplink congestion
        let intra_iso = eng.pair_time(0, 1, 1e6);
        assert!((times.get(0, 1) - intra_iso).abs() / intra_iso < 1e-6);
    }

    #[test]
    fn removing_flows_reduces_contention() {
        let t = tree22();
        let eng = CostEngine::contention(&t);
        let full = Mat::filled(4, 4, 1e6);
        // only one cross-node flow: 0→2
        let mut sparse = Mat::zeros(4, 4);
        sparse.set(0, 2, 1e6);
        let t_full = eng.pair_times(&full).get(0, 2);
        let t_sparse = eng.pair_times(&sparse).get(0, 2);
        assert!(t_sparse < t_full * 0.5);
    }

    #[test]
    fn local_traffic_never_contends() {
        let t = tree22();
        let eng = CostEngine::contention(&t);
        let full = Mat::filled(4, 4, 1e6);
        let want = eng.pair_time(0, 0, 1e6);
        assert!((eng.pair_times(&full).get(0, 0) - want).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "byte matrix shape")]
    fn shape_mismatch_panics() {
        let t = tree22();
        CostEngine::slowest_pair(&t).pair_times(&Mat::zeros(3, 3));
    }

    #[test]
    fn round_time_prices_only_the_given_deliveries() {
        let t = tree22();
        let eng = CostEngine::contention(&t);
        let bytes = Mat::filled(4, 4, 1e6);
        // a single cross-node delivery is priced at its isolated time
        let single = eng.round_time(&bytes, &[(0, 2)]);
        assert!((single - eng.pair_time(0, 2, 1e6)).abs() < 1e-15);
        // two flows sharing the uplink contend with each other only
        let two = eng.round_time(&bytes, &[(0, 2), (1, 3)]);
        assert!(two > single);
        let full = eng.exchange_time(&bytes);
        assert!(two < full, "round of 2 must beat the 4-flow exchange");
        // empty rounds and self/zero pairs cost nothing
        assert_eq!(eng.round_time(&bytes, &[]), 0.0);
        assert_eq!(eng.round_time(&bytes, &[(1, 1)]), 0.0);
        assert_eq!(eng.round_time(&Mat::zeros(4, 4), &[(0, 2)]), 0.0);
    }
}
