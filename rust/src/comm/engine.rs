//! The core cost engine: price a P×P byte matrix under an exchange model.
//!
//! Pricing runs on the coordinator's per-step hot path (DESIGN.md §perf),
//! so the engine owns all of its scratch state: a dense directed-link flow
//! census indexed by the topology's flat incidence table (`2*edge + dir`
//! slots), a touched-slot list for O(flows) resets, and a reusable P×P
//! output matrix (sized on the first [`CostEngine::pair_times`] call, so
//! round-only pricing never pays for it). After construction plus that
//! one-time sizing, [`CostEngine::pair_times`],
//! [`CostEngine::exchange_time`], and [`CostEngine::round_time`] perform
//! no heap allocation. A naive `HashMap`-census oracle lives in
//! `rust/tests/prop_comm_oracle.rs` and pins these paths to 1e-12.
//!
//! Self pairs are local copies that overlap the network phase under every
//! model: only a copy slower than the network phase exposes its excess
//! (the same convention round-based pricing has always used, so
//! `exchange_time` and `round_time` now agree on who can gate).

use crate::topology::Topology;
use crate::util::Mat;

/// How concurrent peer-to-peer deliveries interact (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeModel {
    SlowestPair,
    PerSenderSerial,
    Contention,
}

/// Add one delivery's directed-link flows to a dense census.
#[inline]
pub(crate) fn census_add(topo: &Topology, census: &mut [u32], i: usize, j: usize) {
    for &s in topo.pair_slots(i, j) {
        census[s as usize] += 1;
    }
}

/// Remove one delivery's directed-link flows from a dense census.
#[inline]
pub(crate) fn census_sub(topo: &Topology, census: &mut [u32], i: usize, j: usize) {
    for &s in topo.pair_slots(i, j) {
        census[s as usize] -= 1;
    }
}

/// One delivery's time under a dense flow census: α accumulates along the
/// path, the slowest hop's β is inflated by its concurrent flows
/// (non-blocking point-to-point links never contend).
#[inline]
pub(crate) fn contended_time(
    topo: &Topology,
    census: &[u32],
    i: usize,
    j: usize,
    bytes: f64,
) -> f64 {
    let mut alpha = 0.0;
    let mut slow: f64 = 0.0;
    for &s in topo.pair_slots(i, j) {
        let s = s as usize;
        let flows = if topo.slot_contended[s] { census[s] as f64 } else { 1.0 };
        alpha += topo.slot_alpha[s];
        slow = slow.max(topo.slot_beta[s] * flows);
    }
    alpha + slow * bytes
}

/// Prices exchanges on one topology. Construction allocates the scratch
/// census/output buffers once; every pricing call after that is
/// allocation-free.
pub struct CostEngine<'a> {
    topo: &'a Topology,
    model: ExchangeModel,
    /// Dense flow census, indexed by directed-link slot.
    census: Vec<u32>,
    /// Slots with non-zero census, for O(flows) resets.
    touched: Vec<u32>,
    /// Reusable P×P output of [`CostEngine::pair_times`], sized lazily on
    /// first use so round-only pricing (`scheduled_phase_times`, the
    /// `PlanCache` hit path) never allocates it.
    times: Mat,
    /// Per-sender accumulator for the serial model's round pricing.
    per_sender: Vec<f64>,
}

impl<'a> CostEngine<'a> {
    pub fn new(topo: &'a Topology, model: ExchangeModel) -> Self {
        let p = topo.p();
        let n_slots = topo.n_slots();
        CostEngine {
            topo,
            model,
            census: vec![0; n_slots],
            touched: Vec::with_capacity(n_slots),
            times: Mat::zeros(0, 0),
            per_sender: vec![0.0; p],
        }
    }

    pub fn slowest_pair(topo: &'a Topology) -> Self {
        Self::new(topo, ExchangeModel::SlowestPair)
    }

    pub fn per_sender(topo: &'a Topology) -> Self {
        Self::new(topo, ExchangeModel::PerSenderSerial)
    }

    pub fn contention(topo: &'a Topology) -> Self {
        Self::new(topo, ExchangeModel::Contention)
    }

    pub fn model(&self) -> ExchangeModel {
        self.model
    }

    /// Isolated pair delivery time: `α_ij + β_ij · bytes` (no contention).
    pub fn pair_time(&self, i: usize, j: usize, bytes: f64) -> f64 {
        self.topo.alpha(i, j) + self.topo.beta(i, j) * bytes
    }

    /// Count `(i, j)`'s flows into the scratch census, tracking touched
    /// slots so the reset is O(flows), not O(links).
    #[inline]
    fn census_insert(&mut self, i: usize, j: usize) {
        let topo = self.topo;
        for &s in topo.pair_slots(i, j) {
            let s = s as usize;
            if self.census[s] == 0 {
                self.touched.push(s as u32);
            }
            self.census[s] += 1;
        }
    }

    #[inline]
    fn census_clear(&mut self) {
        for &s in &self.touched {
            self.census[s as usize] = 0;
        }
        self.touched.clear();
    }

    /// Per-pair delivery times for a full exchange under the engine's
    /// model, written into the engine's reusable output matrix. Zero-byte
    /// pairs cost 0 (no message sent).
    pub fn pair_times(&mut self, bytes: &Mat) -> &Mat {
        let p = self.topo.p();
        assert_eq!((bytes.rows(), bytes.cols()), (p, p), "byte matrix shape");
        if self.times.rows() != p {
            self.times = Mat::zeros(p, p); // first use only
        }
        match self.model {
            ExchangeModel::SlowestPair | ExchangeModel::PerSenderSerial => {
                for i in 0..p {
                    for j in 0..p {
                        let b = bytes.get(i, j);
                        let t = if b <= 0.0 { 0.0 } else { self.pair_time(i, j, b) };
                        self.times.set(i, j, t);
                    }
                }
            }
            ExchangeModel::Contention => {
                for i in 0..p {
                    for j in 0..p {
                        if i != j && bytes.get(i, j) > 0.0 {
                            self.census_insert(i, j);
                        }
                    }
                }
                for i in 0..p {
                    for j in 0..p {
                        let b = bytes.get(i, j);
                        let t = if b <= 0.0 {
                            0.0
                        } else if i == j {
                            self.pair_time(i, i, b)
                        } else {
                            contended_time(self.topo, &self.census, i, j, b)
                        };
                        self.times.set(i, j, t);
                    }
                }
                self.census_clear();
            }
        }
        &self.times
    }

    /// Completion time of the whole exchange under the engine's model.
    /// Self pairs are overlapped local copies: the network phase is gated
    /// by cross-device deliveries only, and a copy contributes just its
    /// excess over that phase (the round-time convention).
    pub fn exchange_time(&mut self, bytes: &Mat) -> f64 {
        let p = self.topo.p();
        self.pair_times(bytes);
        let mut net: f64 = 0.0;
        let mut copy: f64 = 0.0;
        match self.model {
            ExchangeModel::SlowestPair | ExchangeModel::Contention => {
                for i in 0..p {
                    for j in 0..p {
                        let t = self.times.get(i, j);
                        if i == j {
                            copy = copy.max(t);
                        } else {
                            net = net.max(t);
                        }
                    }
                }
            }
            ExchangeModel::PerSenderSerial => {
                for i in 0..p {
                    let mut row = 0.0;
                    for j in 0..p {
                        if i != j {
                            row += self.times.get(i, j);
                        }
                    }
                    net = net.max(row);
                    copy = copy.max(self.times.get(i, i));
                }
            }
        }
        net + (copy - net).max(0.0)
    }

    /// Completion time of one synchronised round consisting of the given
    /// deliveries only. Zero-byte pairs cost nothing; self pairs are local
    /// copies that overlap with the network and never gate a round, so
    /// they are skipped here (callers price them separately). Returns 0.0
    /// for an effectively-empty round — an empty round costs nothing.
    pub fn round_time(&mut self, bytes: &Mat, round: &[(usize, usize)]) -> f64 {
        let p = self.topo.p();
        assert_eq!((bytes.rows(), bytes.cols()), (p, p), "byte matrix shape");
        let live = |&&(i, j): &&(usize, usize)| i != j && bytes.get(i, j) > 0.0;
        match self.model {
            ExchangeModel::SlowestPair => round
                .iter()
                .filter(live)
                .map(|&(i, j)| self.pair_time(i, j, bytes.get(i, j)))
                .fold(0.0, f64::max),
            ExchangeModel::PerSenderSerial => {
                for v in &mut self.per_sender {
                    *v = 0.0;
                }
                for &(i, j) in round.iter().filter(live) {
                    let t = self.pair_time(i, j, bytes.get(i, j));
                    self.per_sender[i] += t;
                }
                self.per_sender.iter().copied().fold(0.0, f64::max)
            }
            ExchangeModel::Contention => {
                for &(i, j) in round.iter().filter(live) {
                    self.census_insert(i, j);
                }
                let mut t: f64 = 0.0;
                for &(i, j) in round.iter().filter(live) {
                    t = t.max(contended_time(self.topo, &self.census, i, j, bytes.get(i, j)));
                }
                self.census_clear();
                t
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{presets, Link, Topology, TreeSpec};

    fn tree22() -> Topology {
        Topology::tree(
            &TreeSpec::parse("[2,2]").unwrap(),
            &[Link::from_gbps_us(45.0, 2.0), Link::from_gbps_us(23.0, 10.0)],
            presets::local_copy(),
        )
    }

    #[test]
    fn slowest_pair_is_max_alpha_beta() {
        let t = tree22();
        let mut eng = CostEngine::slowest_pair(&t);
        let bytes = Mat::filled(4, 4, 1e6);
        let want = t.alpha(0, 2) + t.beta(0, 2) * 1e6;
        assert!((eng.exchange_time(&bytes) - want).abs() < 1e-12);
    }

    #[test]
    fn zero_bytes_cost_nothing() {
        let t = tree22();
        for mut eng in [
            CostEngine::slowest_pair(&t),
            CostEngine::per_sender(&t),
            CostEngine::contention(&t),
        ] {
            assert_eq!(eng.exchange_time(&Mat::zeros(4, 4)), 0.0);
        }
    }

    #[test]
    fn per_sender_serialises_row() {
        let t = tree22();
        let mut eng = CostEngine::per_sender(&t);
        let bytes = Mat::filled(4, 4, 1e6);
        // the serial phase is the cross-device sends; the local copy
        // overlaps it and is far faster here, so it exposes nothing
        let row: f64 = (1..4).map(|j| eng.pair_time(0, j, 1e6)).sum();
        assert!((eng.exchange_time(&bytes) - row).abs() / row < 1e-9);
    }

    #[test]
    fn contention_inflates_shared_uplinks() {
        let t = tree22();
        let mut eng = CostEngine::contention(&t);
        let full = Mat::filled(4, 4, 1e6);
        let times = eng.pair_times(&full).clone();
        // cross-node flow shares the uplink with 3 other upward flows
        let isolated = eng.pair_time(0, 2, 1e6) - t.alpha(0, 2);
        let contended = times.get(0, 2) - t.alpha(0, 2);
        let ratio = contended / isolated;
        assert!((ratio - 4.0).abs() < 0.3, "ratio {ratio}");
        // intra-node flow unaffected by the uplink congestion
        let intra_iso = eng.pair_time(0, 1, 1e6);
        assert!((times.get(0, 1) - intra_iso).abs() / intra_iso < 1e-6);
    }

    #[test]
    fn removing_flows_reduces_contention() {
        let t = tree22();
        let mut eng = CostEngine::contention(&t);
        let full = Mat::filled(4, 4, 1e6);
        // only one cross-node flow: 0→2
        let mut sparse = Mat::zeros(4, 4);
        sparse.set(0, 2, 1e6);
        let t_full = eng.pair_times(&full).get(0, 2);
        let t_sparse = eng.pair_times(&sparse).get(0, 2);
        assert!(t_sparse < t_full * 0.5);
    }

    #[test]
    fn local_traffic_never_contends() {
        let t = tree22();
        let mut eng = CostEngine::contention(&t);
        let full = Mat::filled(4, 4, 1e6);
        let want = eng.pair_time(0, 0, 1e6);
        assert!((eng.pair_times(&full).get(0, 0) - want).abs() < 1e-15);
    }

    #[test]
    fn repeated_calls_reuse_scratch_exactly() {
        // the census/touched scratch must reset fully between calls: a
        // dense exchange priced after a sparse one (and vice versa) must
        // match a fresh engine bit-for-bit
        let t = tree22();
        let full = Mat::filled(4, 4, 2e6);
        let mut sparse = Mat::zeros(4, 4);
        sparse.set(0, 2, 2e6);
        sparse.set(3, 1, 5e5);
        for model in [
            ExchangeModel::SlowestPair,
            ExchangeModel::PerSenderSerial,
            ExchangeModel::Contention,
        ] {
            let mut reused = CostEngine::new(&t, model);
            let warm = [
                reused.exchange_time(&full),
                reused.exchange_time(&sparse),
                reused.exchange_time(&full),
                reused.round_time(&full, &[(0, 2), (1, 3)]),
            ];
            let cold = [
                CostEngine::new(&t, model).exchange_time(&full),
                CostEngine::new(&t, model).exchange_time(&sparse),
                CostEngine::new(&t, model).exchange_time(&full),
                CostEngine::new(&t, model).round_time(&full, &[(0, 2), (1, 3)]),
            ];
            assert_eq!(warm, cold, "{model:?}");
        }
    }

    #[test]
    fn self_copies_overlap_the_network_phase() {
        // regression (self-pair convention): a slow local copy no longer
        // gates the whole exchange — under every model only its excess
        // over the network phase is exposed, exactly as round-based
        // pricing has always treated self pairs
        let t = tree22();
        let mut bytes = Mat::filled(4, 4, 1e6);
        bytes.set(0, 0, 1e11); // pathologically slow local copy
        let mut no_self = bytes.clone();
        for i in 0..4 {
            no_self.set(i, i, 0.0);
        }
        for model in [
            ExchangeModel::SlowestPair,
            ExchangeModel::PerSenderSerial,
            ExchangeModel::Contention,
        ] {
            let mut eng = CostEngine::new(&t, model);
            let copy = eng.pair_time(0, 0, 1e11);
            let net = eng.exchange_time(&no_self);
            let full = eng.exchange_time(&bytes);
            let want = net + (copy - net).max(0.0);
            assert!(
                (full - want).abs() <= 1e-12 * want,
                "{model:?}: {full} != {want}"
            );
            // here the copy dominates, so it is the exchange time …
            assert!(copy > net && (full - copy).abs() <= 1e-12 * copy, "{model:?}");
            // … but a fast copy exposes nothing
            let fast = eng.exchange_time(&Mat::filled(4, 4, 1e6));
            let net_only = eng.exchange_time(&no_self_of(&Mat::filled(4, 4, 1e6)));
            assert!((fast - net_only).abs() <= 1e-12 * fast, "{model:?}");
            // round_time still skips self pairs entirely
            assert_eq!(eng.round_time(&bytes, &[(0, 0)]), 0.0, "{model:?}");
        }
    }

    fn no_self_of(m: &Mat) -> Mat {
        let mut out = m.clone();
        for i in 0..m.rows() {
            out.set(i, i, 0.0);
        }
        out
    }

    #[test]
    #[should_panic(expected = "byte matrix shape")]
    fn shape_mismatch_panics() {
        let t = tree22();
        CostEngine::slowest_pair(&t).pair_times(&Mat::zeros(3, 3));
    }

    #[test]
    fn round_time_prices_only_the_given_deliveries() {
        let t = tree22();
        let mut eng = CostEngine::contention(&t);
        let bytes = Mat::filled(4, 4, 1e6);
        // a single cross-node delivery is priced at its isolated time
        let single = eng.round_time(&bytes, &[(0, 2)]);
        assert!((single - eng.pair_time(0, 2, 1e6)).abs() < 1e-15);
        // two flows sharing the uplink contend with each other only
        let two = eng.round_time(&bytes, &[(0, 2), (1, 3)]);
        assert!(two > single);
        let full = eng.exchange_time(&bytes);
        assert!(two < full, "round of 2 must beat the 4-flow exchange");
        // empty rounds and self/zero pairs cost nothing
        assert_eq!(eng.round_time(&bytes, &[]), 0.0);
        assert_eq!(eng.round_time(&bytes, &[(1, 1)]), 0.0);
        assert_eq!(eng.round_time(&Mat::zeros(4, 4), &[(0, 2)]), 0.0);
    }
}
