//! The unified all-to-all planner: one [`A2aAlgo`] selector for every way
//! this repo can execute (and therefore price) a MoE dispatch exchange.
//!
//! Before this module, three mutually-unaware code paths priced an
//! exchange (`CostEngine::exchange_time`, [`hierarchical_a2a_time`],
//! [`scheduled_a2a_time`]) and only a `hierarchical: bool` reached the
//! step-cost model. [`A2aAlgo`] unifies them:
//!
//! * [`A2aAlgo::Direct`] — fully-concurrent exchange under the contention
//!   engine (FastMoE-style peer-to-peer);
//! * [`A2aAlgo::Hierarchical`] — the DeepSpeed-MoE/HetuMoE 3-phase
//!   intra-gather → inter-exchange → intra-scatter;
//! * [`A2aAlgo::Scheduled`] — NCCL-like synchronised rounds over a
//!   1-factorisation: [`ScheduleKind::Xor`] (power-of-two P),
//!   [`ScheduleKind::Rotation`] (any P), or [`ScheduleKind::Bvn`] — the
//!   byte-matrix-aware schedule synthesised by [`bvn_schedule`].
//!
//! Specs parse with [`A2aAlgo::from_str`] (`direct | hier | sched:xor |
//! sched:rot | sched:bvn`) and round-trip through `Display`, mirroring the
//! policy registry's contract.
//!
//! # The BvN synthesizer
//!
//! [`bvn_schedule`] peels the P×P byte matrix into partial permutations,
//! Birkhoff–von-Neumann style, for **any** P (closing the xor schedule's
//! power-of-two gap):
//!
//! 1. self-traffic goes into round 0 (non-gating local copies);
//! 2. the remaining entries are peeled heaviest-pairs-first into maximal
//!    partial permutations, intra-node entries separately from uplink
//!    entries;
//! 3. a Kempe-style refinement repeatedly flips alternating components
//!    between the most expensive round and a cheaper one whenever the
//!    priced cost drops — this is where byte-awareness pays: heavy flows
//!    sharing a bottleneck link spread out, light flows pack under the
//!    gating delivery;
//! 4. the rotation 1-factorisation (the classic BvN decomposition of the
//!    uniform matrix) is refined as a second seed and the cheaper plan
//!    wins, so the synthesizer never regresses below `sched:rot`;
//! 5. rounds are ordered locality-first: intra-node rounds precede uplink
//!    rounds, so a real runtime can start local traffic while NICs drain.

use super::alltoall::hierarchical_a2a_time;
use super::engine::{census_add, census_sub, contended_time, CostEngine};
use super::schedules::{rotation_schedule, scheduled_a2a_time, xor_schedule, Round};
use crate::topology::Topology;
use crate::util::Mat;

/// Which 1-factorisation a [`A2aAlgo::Scheduled`] exchange runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// Round r pairs `i ↔ i ^ r`; P must be a power of two.
    Xor,
    /// Round r sends `i → (i + r) mod P`; any P.
    Rotation,
    /// Byte-matrix-aware greedy BvN decomposition ([`bvn_schedule`]); any P.
    Bvn,
}

/// How an all-to-all exchange is executed on the wire — the planner seam
/// threaded through `step_cost`, `DispatchPolicy::preferred_a2a`,
/// `SessionBuilder::a2a`, configs, and the `--a2a` CLI flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum A2aAlgo {
    /// Fully-concurrent P×P exchange under the contention engine.
    #[default]
    Direct,
    /// DeepSpeed-MoE/HetuMoE hierarchical 3-phase exchange.
    Hierarchical,
    /// Round-based execution of the given schedule.
    Scheduled(ScheduleKind),
}

impl A2aAlgo {
    /// All selectable algorithms, for sweeps and `--help` text.
    pub const ALL: [A2aAlgo; 5] = [
        A2aAlgo::Direct,
        A2aAlgo::Hierarchical,
        A2aAlgo::Scheduled(ScheduleKind::Xor),
        A2aAlgo::Scheduled(ScheduleKind::Rotation),
        A2aAlgo::Scheduled(ScheduleKind::Bvn),
    ];

    /// Canonical spec (round-trips through [`str::parse`]).
    pub fn name(&self) -> String {
        self.to_string()
    }

    /// Err when this algo cannot run at world size `p`
    /// (`sched:xor` needs a power of two).
    pub fn validate_for(&self, p: usize) -> Result<(), String> {
        match self {
            A2aAlgo::Scheduled(ScheduleKind::Xor) if !p.is_power_of_two() => Err(format!(
                "sched:xor needs a power-of-two world size, got P={p} \
                 (use sched:rot or sched:bvn)"
            )),
            _ => Ok(()),
        }
    }

    /// The rounds a scheduled algo executes (`None` for direct/hierarchical).
    pub fn rounds(&self, topo: &Topology, bytes: &Mat) -> Option<Vec<Round>> {
        match self {
            A2aAlgo::Direct | A2aAlgo::Hierarchical => None,
            A2aAlgo::Scheduled(ScheduleKind::Xor) => Some(xor_schedule(topo.p())),
            A2aAlgo::Scheduled(ScheduleKind::Rotation) => Some(rotation_schedule(topo.p())),
            A2aAlgo::Scheduled(ScheduleKind::Bvn) => Some(bvn_schedule(topo, bytes)),
        }
    }

    /// Price one exchange of `bytes` and attribute the time to phases.
    pub fn plan(&self, topo: &Topology, bytes: &Mat) -> CommPlan {
        let p = topo.p();
        assert_eq!((bytes.rows(), bytes.cols()), (p, p), "byte matrix shape");
        match self {
            A2aAlgo::Direct => {
                let mut eng = CostEngine::contention(topo);
                let times = eng.pair_times(bytes);
                // concurrent execution: the network phase takes as long as
                // its gating cross-device delivery, attributed to that
                // delivery's class; self-copies overlap the phase and only
                // their excess is exposed (the round-time convention)
                let (mut gi, mut gj, mut net) = (0, 0, 0.0);
                let mut copy: f64 = 0.0;
                for i in 0..p {
                    for j in 0..p {
                        let t = times.get(i, j);
                        if i == j {
                            copy = copy.max(t);
                        } else if t > net {
                            net = t;
                            (gi, gj) = (i, j);
                        }
                    }
                }
                let mut b = A2aBreakdown::default();
                if net > 0.0 {
                    if topo.same_node(gi, gj) {
                        b.intra_s = net;
                    } else {
                        b.inter_s = net;
                    }
                }
                b.local_s = (copy - net).max(0.0);
                CommPlan { algo: *self, rounds: None, breakdown: b }
            }
            A2aAlgo::Hierarchical => {
                let h = hierarchical_a2a_time(topo, bytes);
                // on a single node the "inter" phase is really a direct
                // intra-node exchange (the hierarchical fallback), so bill
                // it as such — nothing crosses a node boundary
                let breakdown = if topo.n_nodes() <= 1 {
                    A2aBreakdown { local_s: 0.0, intra_s: h.total(), inter_s: 0.0 }
                } else {
                    A2aBreakdown {
                        local_s: 0.0,
                        intra_s: h.intra_gather + h.intra_scatter,
                        inter_s: h.inter,
                    }
                };
                CommPlan { algo: *self, rounds: None, breakdown }
            }
            A2aAlgo::Scheduled(_) => {
                let rounds = self.rounds(topo, bytes).expect("scheduled rounds");
                let (local_s, intra_s, inter_s) =
                    super::schedules::scheduled_phase_times(topo, bytes, &rounds);
                CommPlan {
                    algo: *self,
                    rounds: Some(rounds),
                    breakdown: A2aBreakdown { local_s, intra_s, inter_s },
                }
            }
        }
    }

    /// Completion time of one exchange under this algo.
    pub fn exchange_time(&self, topo: &Topology, bytes: &Mat) -> f64 {
        self.plan(topo, bytes).total_s()
    }
}

impl std::fmt::Display for A2aAlgo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            A2aAlgo::Direct => write!(f, "direct"),
            A2aAlgo::Hierarchical => write!(f, "hier"),
            A2aAlgo::Scheduled(ScheduleKind::Xor) => write!(f, "sched:xor"),
            A2aAlgo::Scheduled(ScheduleKind::Rotation) => write!(f, "sched:rot"),
            A2aAlgo::Scheduled(ScheduleKind::Bvn) => write!(f, "sched:bvn"),
        }
    }
}

impl std::str::FromStr for A2aAlgo {
    type Err = String;

    fn from_str(s: &str) -> Result<A2aAlgo, String> {
        match s.trim() {
            "direct" => Ok(A2aAlgo::Direct),
            "hier" | "hierarchical" => Ok(A2aAlgo::Hierarchical),
            "sched:xor" => Ok(A2aAlgo::Scheduled(ScheduleKind::Xor)),
            "sched:rot" | "sched:rotation" => Ok(A2aAlgo::Scheduled(ScheduleKind::Rotation)),
            "sched:bvn" => Ok(A2aAlgo::Scheduled(ScheduleKind::Bvn)),
            other => Err(format!(
                "unknown a2a algo {other:?} (known: direct, hier, sched:xor, \
                 sched:rot, sched:bvn)"
            )),
        }
    }
}

/// Where an exchange's time goes: local copies, intra-node deliveries,
/// cross-node deliveries. Phases sum to the exchange completion time.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct A2aBreakdown {
    /// Exposed local-copy time (self-traffic not hidden under deliveries).
    pub local_s: f64,
    /// Time attributed to intra-node phases/rounds.
    pub intra_s: f64,
    /// Time attributed to phases/rounds crossing a node boundary.
    pub inter_s: f64,
}

impl A2aBreakdown {
    pub fn total(&self) -> f64 {
        self.local_s + self.intra_s + self.inter_s
    }

    pub fn scale(&self, f: f64) -> A2aBreakdown {
        A2aBreakdown {
            local_s: self.local_s * f,
            intra_s: self.intra_s * f,
            inter_s: self.inter_s * f,
        }
    }
}

/// A priced exchange: the algorithm, its rounds (for scheduled algos), and
/// the per-phase time attribution.
#[derive(Clone, Debug)]
pub struct CommPlan {
    pub algo: A2aAlgo,
    /// The synchronised rounds a scheduled algo executes.
    pub rounds: Option<Vec<Round>>,
    pub breakdown: A2aBreakdown,
}

impl CommPlan {
    /// Completion time of the planned exchange.
    pub fn total_s(&self) -> f64 {
        self.breakdown.total()
    }
}

/// Price an already-synthesised round schedule on (possibly different)
/// bytes. This is the `PlanCache` hit path: schedule *synthesis* is the
/// expensive part of [`bvn_schedule`], while pricing a given schedule is
/// cheap — so a cached plan's rounds are always re-priced on the live byte
/// matrix and never serve stale times.
pub fn price_rounds(topo: &Topology, bytes: &Mat, rounds: &[Round]) -> A2aBreakdown {
    let (local_s, intra_s, inter_s) =
        super::schedules::scheduled_phase_times(topo, bytes, rounds);
    A2aBreakdown { local_s, intra_s, inter_s }
}

// ---------------------------------------------------------------------------
// BvN schedule synthesis
// ---------------------------------------------------------------------------

/// Bounded number of Kempe-refinement flips per candidate schedule.
const REFINE_SWEEPS: usize = 12;

/// Synthesise a byte-matrix-aware round schedule for any P (see the module
/// docs for the algorithm). The result always passes
/// [`super::schedules::validate_schedule`] and never prices above the
/// rotation schedule under [`scheduled_a2a_time`].
pub fn bvn_schedule(topo: &Topology, bytes: &Mat) -> Vec<Round> {
    let p = topo.p();
    assert_eq!((bytes.rows(), bytes.cols()), (p, p), "byte matrix shape");
    let self_round: Round = (0..p).map(|i| (i, i)).collect();
    if p == 1 {
        return vec![self_round];
    }

    // candidate seeds: the heaviest-first locality peel, and the rotation
    // 1-factorisation (so refinement can only improve on sched:rot)
    let candidates = vec![peel_candidate(topo, bytes), rotation_candidate(p)];

    let mut best: Option<(f64, Vec<Round>)> = None;
    for cand in candidates {
        let refined = refine_rounds(topo, bytes, cand);
        let mut sched = vec![self_round.clone()];
        sched.extend(refined);
        let cost = scheduled_a2a_time(topo, bytes, &sched);
        match &best {
            Some((c, _)) if cost >= *c => {}
            _ => best = Some((cost, sched)),
        }
    }
    let (_, mut sched) = best.expect("at least one candidate");

    // locality-first ordering: intra-node rounds before uplink rounds
    // (stable sort; round order does not change the price)
    sched[1..].sort_by_key(|round| {
        round.iter().map(|&(i, j)| topo.level(i, j)).max().unwrap_or(0)
    });
    sched
}

/// Heaviest-first maximal partial permutations, intra-node entries peeled
/// separately from (and before) cross-node entries.
fn peel_candidate(topo: &Topology, bytes: &Mat) -> Vec<Round> {
    let p = topo.p();
    let mut intra = Vec::new();
    let mut inter = Vec::new();
    for i in 0..p {
        for j in 0..p {
            if i == j {
                continue;
            }
            let pair = (i, j, bytes.get(i, j));
            if topo.same_node(i, j) {
                intra.push(pair);
            } else {
                inter.push(pair);
            }
        }
    }
    let mut rounds = peel_rounds(intra, p);
    rounds.extend(peel_rounds(inter, p));
    rounds
}

/// Greedily peel `(src, dst, weight)` entries into maximal partial
/// permutations, heaviest first.
fn peel_rounds(mut pairs: Vec<(usize, usize, f64)>, p: usize) -> Vec<Round> {
    pairs.sort_by(|a, b| {
        b.2.total_cmp(&a.2).then_with(|| (a.0, a.1).cmp(&(b.0, b.1)))
    });
    let mut rounds = Vec::new();
    while !pairs.is_empty() {
        let mut send = vec![false; p];
        let mut recv = vec![false; p];
        let mut round = Vec::new();
        let mut rest = Vec::new();
        for (i, j, w) in pairs {
            if !send[i] && !recv[j] {
                send[i] = true;
                recv[j] = true;
                round.push((i, j));
            } else {
                rest.push((i, j, w));
            }
        }
        rounds.push(round);
        pairs = rest;
    }
    rounds
}

/// The rotation 1-factorisation without its self round.
fn rotation_candidate(p: usize) -> Vec<Round> {
    rotation_schedule(p).into_iter().skip(1).collect()
}

/// One alternating component of two rounds: flipping its deliveries
/// between the rounds keeps both partial permutations valid.
struct Component {
    from_a: Vec<(usize, usize)>,
    from_b: Vec<(usize, usize)>,
}

/// Alternating components of two partial permutations: components
/// partition the two rounds' send/receive slots (a device's send in `a`
/// and its send in `b` always land in the same component), so each
/// component's deliveries can swap rounds while every device keeps ≤1
/// send and ≤1 receive per round — and flips of distinct components
/// compose.
fn alternating_components(a: &Round, b: &Round, p: usize) -> Vec<Component> {
    const NONE: usize = usize::MAX;
    let mut out_a = vec![NONE; p];
    let mut in_a = vec![NONE; p];
    for (k, &(i, j)) in a.iter().enumerate() {
        out_a[i] = k;
        in_a[j] = k;
    }
    let mut out_b = vec![NONE; p];
    let mut in_b = vec![NONE; p];
    for (k, &(i, j)) in b.iter().enumerate() {
        out_b[i] = k;
        in_b[j] = k;
    }
    let mut seen_a = vec![false; a.len()];
    let mut seen_b = vec![false; b.len()];
    let mut comps = Vec::new();
    let starts = (0..a.len()).map(|k| (true, k)).chain((0..b.len()).map(|k| (false, k)));
    for start in starts {
        match start {
            (true, k) if seen_a[k] => continue,
            (false, k) if seen_b[k] => continue,
            _ => {}
        }
        let mut ca = Vec::new();
        let mut cb = Vec::new();
        let mut stack = vec![start];
        while let Some((is_a, k)) = stack.pop() {
            if is_a {
                if std::mem::replace(&mut seen_a[k], true) {
                    continue;
                }
                let (i, j) = a[k];
                ca.push((i, j));
                if out_b[i] != NONE {
                    stack.push((false, out_b[i]));
                }
                if in_b[j] != NONE {
                    stack.push((false, in_b[j]));
                }
            } else {
                if std::mem::replace(&mut seen_b[k], true) {
                    continue;
                }
                let (i, j) = b[k];
                cb.push((i, j));
                if out_a[i] != NONE {
                    stack.push((true, out_a[i]));
                }
                if in_a[j] != NONE {
                    stack.push((true, in_a[j]));
                }
            }
        }
        comps.push(Component { from_a: ca, from_b: cb });
    }
    comps
}

/// A round under refinement: its pairs, the dense directed-link census of
/// its live deliveries, and its current contention price. Maintaining the
/// census incrementally is what makes a candidate flip O(component +
/// round) instead of two from-scratch round re-pricings through a
/// `HashMap` link census.
struct RoundState {
    pairs: Round,
    census: Vec<u32>,
    cost: f64,
}

/// Max contended delivery time of `pairs` under `census`, with an early
/// exit: once the running max reaches `bound` the true cost can only be
/// ≥ `bound`, which is enough to reject a candidate flip against the
/// gating-delivery budget — the partial max is returned immediately.
fn round_cost(
    topo: &Topology,
    bytes: &Mat,
    census: &[u32],
    pairs: impl Iterator<Item = (usize, usize)>,
    bound: f64,
) -> f64 {
    let mut t: f64 = 0.0;
    for (i, j) in pairs {
        if i == j {
            continue;
        }
        let b = bytes.get(i, j);
        if b <= 0.0 {
            continue;
        }
        t = t.max(contended_time(topo, census, i, j, b));
        if t >= bound {
            return t;
        }
    }
    t
}

/// Disjoint mutable references to two slots of a slice.
fn two_mut<T>(v: &mut [T], a: usize, b: usize) -> (&mut T, &mut T) {
    debug_assert_ne!(a, b);
    if a < b {
        let (l, r) = v.split_at_mut(b);
        (&mut l[a], &mut r[0])
    } else {
        let (l, r) = v.split_at_mut(a);
        (&mut r[0], &mut l[b])
    }
}

/// Kempe-style local search: flip alternating components between the most
/// expensive round and a cheaper one whenever the priced cost drops.
/// Monotone non-increasing, so a rotation seed never gets worse.
///
/// The inner loop is incremental: each round keeps a live link census,
/// candidate flips apply the component's census delta, price the two new
/// rounds with an early-exit bound at the pair's combined budget, and
/// revert the delta on rejection — no per-candidate allocation and no
/// from-scratch re-pricing. Accept/reject decisions (and therefore the
/// emitted schedule) are identical to the from-scratch formulation.
fn refine_rounds(topo: &Topology, bytes: &Mat, mut rounds: Vec<Round>) -> Vec<Round> {
    let p = topo.p();
    rounds.retain(|r| r.iter().any(|&(i, j)| i != j));
    let n_slots = topo.n_slots();
    let live = |i: usize, j: usize| i != j && bytes.get(i, j) > 0.0;

    let mut states: Vec<RoundState> = rounds
        .into_iter()
        .map(|pairs| {
            let mut census = vec![0u32; n_slots];
            for &(i, j) in pairs.iter().filter(|&&(i, j)| live(i, j)) {
                census_add(topo, &mut census, i, j);
            }
            let cost =
                round_cost(topo, bytes, &census, pairs.iter().copied(), f64::INFINITY);
            RoundState { pairs, census, cost }
        })
        .collect();

    for _ in 0..REFINE_SWEEPS {
        let Some(a) =
            (0..states.len()).max_by(|&x, &y| states[x].cost.total_cmp(&states[y].cost))
        else {
            break;
        };
        if states[a].cost <= 0.0 {
            break;
        }
        let mut order: Vec<usize> = (0..states.len()).filter(|&k| k != a).collect();
        order.sort_by(|&x, &y| states[x].cost.total_cmp(&states[y].cost));
        let mut improved = false;
        for &b in &order {
            // components own their pairs, so earlier flips don't invalidate
            // later ones (distinct components are disjoint and compose)
            let comps = alternating_components(&states[a].pairs, &states[b].pairs, p);
            for comp in comps {
                let (ca, cb) = (&comp.from_a, &comp.from_b);
                if ca.is_empty() && cb.is_empty() {
                    continue;
                }
                let (sa, sb) = two_mut(&mut states, a, b);
                let budget = sa.cost + sb.cost;
                // apply the candidate flip's census delta
                for &(i, j) in ca.iter().filter(|&&(i, j)| live(i, j)) {
                    census_sub(topo, &mut sa.census, i, j);
                    census_add(topo, &mut sb.census, i, j);
                }
                for &(i, j) in cb.iter().filter(|&&(i, j)| live(i, j)) {
                    census_sub(topo, &mut sb.census, i, j);
                    census_add(topo, &mut sa.census, i, j);
                }
                let c_na = round_cost(
                    topo,
                    bytes,
                    &sa.census,
                    sa.pairs.iter().copied().filter(|pr| !ca.contains(pr)).chain(
                        cb.iter().copied(),
                    ),
                    budget,
                );
                let c_nb = if c_na < budget {
                    round_cost(
                        topo,
                        bytes,
                        &sb.census,
                        sb.pairs.iter().copied().filter(|pr| !cb.contains(pr)).chain(
                            ca.iter().copied(),
                        ),
                        budget - c_na,
                    )
                } else {
                    f64::INFINITY
                };
                if c_na + c_nb < budget * (1.0 - 1e-12) {
                    // commit: move the component's deliveries between rounds
                    sa.pairs.retain(|pr| !ca.contains(pr));
                    sa.pairs.extend(cb.iter().copied());
                    sb.pairs.retain(|pr| !cb.contains(pr));
                    sb.pairs.extend(ca.iter().copied());
                    sa.cost = c_na;
                    sb.cost = c_nb;
                    improved = true;
                } else {
                    // revert the census delta
                    for &(i, j) in ca.iter().filter(|&&(i, j)| live(i, j)) {
                        census_add(topo, &mut sa.census, i, j);
                        census_sub(topo, &mut sb.census, i, j);
                    }
                    for &(i, j) in cb.iter().filter(|&&(i, j)| live(i, j)) {
                        census_add(topo, &mut sb.census, i, j);
                        census_sub(topo, &mut sa.census, i, j);
                    }
                }
            }
            if improved {
                break;
            }
        }
        if !improved {
            break;
        }
    }
    states
        .into_iter()
        .map(|s| s.pairs)
        .filter(|r| !r.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::schedules::validate_schedule;
    use crate::dispatch::{target_pattern, DispatchProblem};
    use crate::topology::{presets, Link, TreeSpec};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_tree(rng: &mut Rng) -> Topology {
        // non-power-of-two and asymmetric shapes included
        let n_nodes = rng.range(2, 5);
        let per_node = rng.range(2, 5);
        let spec = if rng.below(3) == 0 && n_nodes >= 3 {
            let mut children = vec![TreeSpec::Switch(
                (0..n_nodes / 2).map(|_| TreeSpec::Devices(per_node)).collect(),
            )];
            for _ in n_nodes / 2..n_nodes {
                children.push(TreeSpec::Switch(vec![TreeSpec::Devices(per_node)]));
            }
            TreeSpec::Switch(children)
        } else {
            TreeSpec::symmetric(&[n_nodes, per_node])
        };
        let dev = Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0));
        let up = Link::from_gbps_us(rng.range_f64(4.0, 25.0), rng.range_f64(5.0, 30.0));
        let spine = Link::from_gbps_us(rng.range_f64(2.0, 20.0), rng.range_f64(10.0, 40.0));
        Topology::tree(&spec, &[dev, up, spine], presets::local_copy())
    }

    /// The fig4 cluster-C byte matrices: even dispatch and the Eq. 7
    /// TA-MoE target at GPT-Medium scale (d=1024, fp16).
    fn fig4_cluster_c_bytes(nodes: usize) -> (Topology, Vec<(&'static str, Mat)>) {
        let topo = presets::cluster_c(nodes);
        let p = topo.p();
        let per_tok = 2048.0;
        let even = Mat::filled(p, p, 6144.0 / p as f64 * per_tok);
        let prob = DispatchProblem { k: 1, s: 6144, e_per_dev: 1, elem_bytes: 2048 };
        let ta = target_pattern(&topo, &prob).bytes_matrix();
        (topo, vec![("even", even), ("ta", ta)])
    }

    #[test]
    fn specs_round_trip() {
        for algo in A2aAlgo::ALL {
            let spec = algo.name();
            let parsed: A2aAlgo = spec.parse().unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(parsed, algo, "{spec}");
        }
        assert_eq!("hierarchical".parse::<A2aAlgo>().unwrap(), A2aAlgo::Hierarchical);
        assert_eq!(
            "sched:rotation".parse::<A2aAlgo>().unwrap(),
            A2aAlgo::Scheduled(ScheduleKind::Rotation)
        );
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in ["", "sched", "sched:", "sched:bvn:2", "diagonal", "xor"] {
            assert!(bad.parse::<A2aAlgo>().is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn xor_validates_only_on_powers_of_two() {
        let xor = A2aAlgo::Scheduled(ScheduleKind::Xor);
        assert!(xor.validate_for(8).is_ok());
        assert!(xor.validate_for(6).is_err());
        for algo in [
            A2aAlgo::Direct,
            A2aAlgo::Hierarchical,
            A2aAlgo::Scheduled(ScheduleKind::Rotation),
            A2aAlgo::Scheduled(ScheduleKind::Bvn),
        ] {
            assert!(algo.validate_for(6).is_ok(), "{algo}");
        }
    }

    #[test]
    fn prop_bvn_is_valid_for_any_tree() {
        check(
            30,
            0xB1F0,
            |rng| {
                let topo = random_tree(rng);
                let p = topo.p();
                let bytes = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 64e6));
                (topo, bytes)
            },
            |(topo, bytes)| {
                let rounds = bvn_schedule(topo, bytes);
                validate_schedule(topo.p(), &rounds)
                    .map_err(|e| format!("P={}: {e}", topo.p()))
            },
        );
    }

    #[test]
    fn prop_every_algo_dominates_slowest_pair_bound() {
        // Eq. 2 lower-bounds any execution of the exchange: each delivery
        // happens somewhere, and no algo beats its isolated α-β time.
        check(
            20,
            0xA160,
            |rng| {
                let topo = random_tree(rng);
                let p = topo.p();
                let bytes = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 64e6));
                (topo, bytes)
            },
            |(topo, bytes)| {
                let lb = CostEngine::slowest_pair(topo).exchange_time(bytes);
                for algo in A2aAlgo::ALL {
                    if algo.validate_for(topo.p()).is_err() {
                        continue;
                    }
                    let t = algo.exchange_time(topo, bytes);
                    if t < lb * (1.0 - 1e-9) {
                        return Err(format!("{algo}: {t} below bound {lb}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bvn_never_prices_above_rotation_on_fig4_cluster_c() {
        // the planner acceptance bar: sched:bvn ≤ sched:rot on the fig4
        // cluster-C byte matrices (even + TA target), including the
        // 4-node asymmetric spine shape
        for nodes in [1usize, 2, 4] {
            let (topo, mats) = fig4_cluster_c_bytes(nodes);
            let p = topo.p();
            for (name, bytes) in &mats {
                let rot = scheduled_a2a_time(&topo, bytes, &rotation_schedule(p));
                let rounds = bvn_schedule(&topo, bytes);
                validate_schedule(p, &rounds).unwrap();
                let bvn = scheduled_a2a_time(&topo, bytes, &rounds);
                assert!(
                    bvn <= rot * (1.0 + 1e-9),
                    "{nodes} nodes / {name}: bvn {bvn} > rot {rot}"
                );
            }
        }
    }

    #[test]
    fn bvn_beats_rotation_on_two_node_cluster_c() {
        // where the byte-aware refinement actually wins, not just ties
        let (topo, mats) = fig4_cluster_c_bytes(2);
        let p = topo.p();
        for (name, bytes) in &mats {
            let rot = scheduled_a2a_time(&topo, bytes, &rotation_schedule(p));
            let bvn = scheduled_a2a_time(&topo, bytes, &bvn_schedule(&topo, bytes));
            assert!(bvn < rot, "{name}: bvn {bvn} !< rot {rot}");
        }
    }

    #[test]
    fn bvn_orders_intra_rounds_before_uplink_rounds() {
        let (topo, mats) = fig4_cluster_c_bytes(2);
        let rounds = bvn_schedule(&topo, &mats[0].1);
        let mut seen_cross = false;
        for round in &rounds[1..] {
            let cross = round.iter().any(|&(i, j)| !topo.same_node(i, j));
            assert!(
                !seen_cross || cross,
                "intra-node round after an uplink round"
            );
            seen_cross |= cross;
        }
        assert!(seen_cross, "multi-node schedule must have uplink rounds");
    }

    #[test]
    fn plan_breakdown_sums_to_exchange_time() {
        let (topo, mats) = fig4_cluster_c_bytes(2);
        for (_, bytes) in &mats {
            for algo in A2aAlgo::ALL {
                let plan = algo.plan(&topo, bytes);
                let b = plan.breakdown;
                assert!(
                    (b.total() - (b.local_s + b.intra_s + b.inter_s)).abs() < 1e-15
                );
                assert!(plan.total_s() > 0.0, "{algo}");
                match algo {
                    A2aAlgo::Scheduled(_) => {
                        let rounds = plan.rounds.as_ref().expect("rounds");
                        let want = scheduled_a2a_time(&topo, bytes, rounds);
                        assert!(
                            (plan.total_s() - want).abs() <= 1e-12 * want,
                            "{algo}: {} != {want}",
                            plan.total_s()
                        );
                    }
                    A2aAlgo::Hierarchical => {
                        let want = hierarchical_a2a_time(&topo, bytes).total();
                        assert!((plan.total_s() - want).abs() <= 1e-12 * want);
                        assert!(b.intra_s > 0.0 && b.inter_s > 0.0);
                    }
                    A2aAlgo::Direct => {
                        let want = CostEngine::contention(&topo).exchange_time(bytes);
                        assert!((plan.total_s() - want).abs() <= 1e-12 * want);
                        assert!(plan.rounds.is_none());
                    }
                }
            }
        }
    }

    #[test]
    fn direct_attributes_gating_delivery_class() {
        // all traffic intra-node ⇒ the direct plan bills intra, not inter
        let topo = presets::cluster_c(2);
        let p = topo.p();
        let bytes = Mat::from_fn(p, p, |i, j| {
            if i != j && topo.same_node(i, j) {
                1e6
            } else {
                0.0
            }
        });
        let plan = A2aAlgo::Direct.plan(&topo, &bytes);
        assert!(plan.breakdown.intra_s > 0.0);
        assert_eq!(plan.breakdown.inter_s, 0.0);
        assert_eq!(plan.breakdown.local_s, 0.0);
    }

    #[test]
    fn hierarchical_on_single_node_bills_intra_not_inter() {
        // the 1-node hierarchical fallback is a direct intra-node
        // exchange — nothing crosses a node boundary
        let topo = presets::cluster_c(1);
        let p = topo.p();
        let bytes = Mat::filled(p, p, 1e6);
        let plan = A2aAlgo::Hierarchical.plan(&topo, &bytes);
        assert_eq!(plan.breakdown.inter_s, 0.0);
        assert!(plan.breakdown.intra_s > 0.0);
        let want = hierarchical_a2a_time(&topo, &bytes).total();
        assert!((plan.total_s() - want).abs() <= 1e-12 * want);
    }

    #[test]
    fn bvn_single_device_is_self_round_only() {
        let topo = Topology::homogeneous(
            1,
            Link::from_gbps_us(100.0, 1.0),
            presets::local_copy(),
        );
        let rounds = bvn_schedule(&topo, &Mat::filled(1, 1, 1e6));
        assert_eq!(rounds, vec![vec![(0, 0)]]);
        validate_schedule(1, &rounds).unwrap();
    }
}
