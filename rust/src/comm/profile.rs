//! Table-1 style exchange profiling harness.
//!
//! Reproduces the paper's §3.3 motivation experiment: dispatch a fixed
//! volume per rank under a given ratio matrix and report the per-pair
//! delivery times of rank 0 plus two totals (slowest-pair completion and
//! the per-sender serial total that corresponds to the paper's "All"
//! column).

use super::engine::CostEngine;
use crate::topology::Topology;
use crate::util::Mat;

/// Result of profiling one dispatch pattern.
#[derive(Clone, Debug)]
pub struct ExchangeProfile {
    /// Delivery time (s) of rank 0 to every destination, under contention.
    pub rank0_times: Vec<f64>,
    /// Ratio row of rank 0 that produced them.
    pub rank0_ratios: Vec<f64>,
    /// Completion time under the contention model (slowest flow).
    pub completion: f64,
    /// Sum of rank 0's delivery times — the serialised "All" column.
    pub rank0_total: f64,
}

/// Profile an exchange where every rank sends `bytes_per_rank`, split
/// according to `ratios` (P×P, rows must sum to 1).
pub fn profile_exchange(topo: &Topology, bytes_per_rank: f64, ratios: &Mat) -> ExchangeProfile {
    let p = topo.p();
    assert_eq!((ratios.rows(), ratios.cols()), (p, p));
    for i in 0..p {
        let s = ratios.row_sum(i);
        assert!((s - 1.0).abs() < 1e-6, "ratio row {i} sums to {s}");
    }
    let bytes = ratios.scale(bytes_per_rank);
    let mut eng = CostEngine::contention(topo);
    let rank0_times: Vec<f64> = {
        let times = eng.pair_times(&bytes);
        (0..p).map(|j| times.get(0, j)).collect()
    };
    ExchangeProfile {
        rank0_total: rank0_times.iter().sum(),
        completion: eng.exchange_time(&bytes),
        rank0_times,
        rank0_ratios: ratios.row(0).to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn even(p: usize) -> Mat {
        Mat::filled(p, p, 1.0 / p as f64)
    }

    #[test]
    fn even_profile_on_table1_matches_paper_scale() {
        // Paper Table 1 (even): 144 µs local, 758 µs intra, ~5.6 ms inter.
        let topo = presets::table1();
        let prof = profile_exchange(&topo, 128.0 * 1024.0 * 1024.0, &even(4));
        let us: Vec<f64> = prof.rank0_times.iter().map(|t| t * 1e6).collect();
        assert!((us[0] - 144.0).abs() < 40.0, "local {us:?}");
        assert!((us[1] - 758.0).abs() < 200.0, "intra {us:?}");
        assert!(us[2] > 4000.0 && us[2] < 7500.0, "inter {us:?}");
    }

    #[test]
    fn uneven_improves_total() {
        let topo = presets::table1();
        let peer = [1usize, 0, 3, 2];
        let uneven = Mat::from_fn(4, 4, |i, j| {
            if i == j {
                0.25
            } else if j == peer[i] {
                0.5
            } else {
                0.125
            }
        });
        let b = 128.0 * 1024.0 * 1024.0;
        let pe = profile_exchange(&topo, b, &even(4));
        let pu = profile_exchange(&topo, b, &uneven);
        assert!(pu.rank0_total < pe.rank0_total * 0.85);
        assert!(pu.completion < pe.completion);
    }

    #[test]
    #[should_panic(expected = "ratio row")]
    fn rejects_nonstochastic_ratios() {
        let topo = presets::table1();
        profile_exchange(&topo, 1e6, &Mat::filled(4, 4, 0.3));
    }
}
