//! Round-based all-to-all schedules.
//!
//! The contention engine prices a fully-concurrent exchange; real
//! collectives serialise the P×P deliveries into *rounds* so each device
//! has one send and one receive in flight (NCCL's pairwise-exchange
//! behaviour). Two classic schedules:
//!
//! * [`xor_schedule`] — for power-of-two P, round r pairs `i ↔ i ^ r`
//!   (a perfect 1-factorisation of K_P);
//! * [`rotation_schedule`] — for any P, round r sends `i → (i + r) % P`
//!   (each device has exactly one send + one receive per round).
//!
//! [`scheduled_a2a_time`] prices an exchange as the sum of per-round
//! completion times under the contention engine — rounds are separated by
//! a synchronisation, so the slowest delivery of each round gates it
//! (empty rounds are free, and self-traffic is a non-gating local copy).
//! This sits between the optimistic slowest-pair bound (Eq. 2) and the
//! fully-serial model, and is the default ablation comparator in
//! `benches/ablation_design.rs`. The byte-matrix-aware schedule
//! synthesizer lives in [`super::plan`] ([`super::plan::bvn_schedule`]).

use super::engine::CostEngine;
use crate::topology::Topology;
use crate::util::Mat;

/// One round: disjoint (src, dst) pairs.
pub type Round = Vec<(usize, usize)>;

/// XOR pairwise-exchange schedule (P must be a power of two).
/// Round r ∈ 1..P pairs i with i^r; self-traffic is round 0.
pub fn xor_schedule(p: usize) -> Vec<Round> {
    assert!(p.is_power_of_two(), "xor schedule needs power-of-two P");
    let mut rounds = vec![vec![]; p];
    for r in 0..p {
        for i in 0..p {
            rounds[r].push((i, i ^ r));
        }
    }
    rounds
}

/// Rotation schedule: round r sends i → (i + r) mod P. Works for any P.
pub fn rotation_schedule(p: usize) -> Vec<Round> {
    (0..p)
        .map(|r| (0..p).map(|i| (i, (i + r) % p)).collect())
        .collect()
}

/// Validate that a schedule covers every (src, dst) pair exactly once and
/// each round is a partial permutation (≤1 send and ≤1 receive per device).
pub fn validate_schedule(p: usize, rounds: &[Round]) -> Result<(), String> {
    let mut seen = vec![false; p * p];
    for (r, round) in rounds.iter().enumerate() {
        let mut sends = vec![false; p];
        let mut recvs = vec![false; p];
        for &(i, j) in round {
            if i >= p || j >= p {
                return Err(format!("round {r}: out-of-range pair ({i},{j})"));
            }
            if std::mem::replace(&mut seen[i * p + j], true) {
                return Err(format!("pair ({i},{j}) scheduled twice"));
            }
            if std::mem::replace(&mut sends[i], true) {
                return Err(format!("round {r}: device {i} sends twice"));
            }
            if std::mem::replace(&mut recvs[j], true) {
                return Err(format!("round {r}: device {j} receives twice"));
            }
        }
    }
    if seen.iter().filter(|&&s| s).count() != p * p {
        return Err("schedule does not cover all pairs".into());
    }
    Ok(())
}

/// Price an exchange under a round-based schedule: rounds run back to
/// back, each gated by its slowest delivery (contention priced per round,
/// so only that round's flows share links).
///
/// Rounds that carry no positive cross-device bytes are skipped — an
/// empty round costs nothing, so padding a schedule with empty rounds
/// leaves the price unchanged. Self pairs are local copies that overlap
/// with the network rounds and never gate one; only a local copy slower
/// than the entire round sequence is exposed.
pub fn scheduled_a2a_time(topo: &Topology, bytes: &Mat, rounds: &[Round]) -> f64 {
    let (local, intra, inter) = scheduled_phase_times(topo, bytes, rounds);
    local + intra + inter
}

/// Per-class attribution of a round sequence's completion time:
/// `(exposed_local, intra_node, inter_node)`. A round's time goes to
/// `inter` when any of its positive deliveries crosses a node boundary,
/// else to `intra`; self-traffic is a non-gating local copy whose excess
/// over the round sequence is `exposed_local`. The sum is exactly
/// [`scheduled_a2a_time`]; the planner wraps this into an `A2aBreakdown`.
pub(super) fn scheduled_phase_times(
    topo: &Topology,
    bytes: &Mat,
    rounds: &[Round],
) -> (f64, f64, f64) {
    let p = topo.p();
    assert_eq!((bytes.rows(), bytes.cols()), (p, p));
    let mut eng = CostEngine::contention(topo);
    let mut intra = 0.0;
    let mut inter = 0.0;
    let mut local: f64 = 0.0;
    for round in rounds {
        let t = eng.round_time(bytes, round);
        let mut cross = false;
        for &(i, j) in round {
            if bytes.get(i, j) <= 0.0 {
                continue;
            }
            if i == j {
                local = local.max(eng.pair_time(i, i, bytes.get(i, i)));
            } else if !topo.same_node(i, j) {
                cross = true;
            }
        }
        if cross {
            inter += t;
        } else {
            intra += t;
        }
    }
    ((local - (intra + inter)).max(0.0), intra, inter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{presets, Link, Topology, TreeSpec};
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Random symmetric 2-level tree with arbitrary (non-power-of-two
    /// included) node/device counts.
    fn random_tree(rng: &mut Rng) -> Topology {
        let spec = TreeSpec::symmetric(&[rng.range(2, 5), rng.range(2, 5)]);
        let dev = Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0));
        let up = Link::from_gbps_us(rng.range_f64(4.0, 25.0), rng.range_f64(5.0, 30.0));
        Topology::tree(&spec, &[dev, up], presets::local_copy())
    }

    #[test]
    fn prop_rotation_schedule_valid_for_any_p() {
        // non-power-of-two world sizes included (the xor schedule's gap)
        check(
            40,
            0x5C4ED,
            |rng| rng.range(1, 34),
            |&p| {
                let s = rotation_schedule(p);
                if s.len() != p {
                    return Err(format!("{} rounds for P={p}", s.len()));
                }
                validate_schedule(p, &s).map_err(|e| format!("P={p}: {e}"))
            },
        );
    }

    #[test]
    fn prop_xor_schedule_valid_for_powers_of_two() {
        check(
            20,
            0xA0B1,
            |rng| 1usize << rng.below(6),
            |&p| {
                let s = xor_schedule(p);
                validate_schedule(p, &s).map_err(|e| format!("P={p}: {e}"))
            },
        );
    }

    #[test]
    fn prop_scheduled_time_dominates_slowest_pair_bound() {
        // Eq. 2 is a lower bound on any round-based execution: every pair
        // is delivered in some round, rounds serialise, and contention
        // only slows a delivery relative to its isolated α-β time.
        check(
            25,
            0xB0074,
            |rng| {
                let topo = random_tree(rng);
                let p = topo.p();
                let bytes = crate::util::Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 64e6));
                (topo, bytes)
            },
            |(topo, bytes)| {
                let p = topo.p();
                let lb = CostEngine::slowest_pair(topo).exchange_time(bytes);
                let mut schedules = vec![
                    rotation_schedule(p),
                    super::super::plan::bvn_schedule(topo, bytes),
                ];
                if p.is_power_of_two() {
                    schedules.push(xor_schedule(p));
                }
                for rounds in &schedules {
                    validate_schedule(p, rounds)?;
                    let t = scheduled_a2a_time(topo, bytes, rounds);
                    if t < lb * (1.0 - 1e-9) {
                        return Err(format!("scheduled {t} below lower bound {lb} (P={p})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn xor_schedule_is_valid() {
        for p in [2usize, 4, 8, 16] {
            let s = xor_schedule(p);
            validate_schedule(p, &s).unwrap();
            assert_eq!(s.len(), p);
        }
    }

    #[test]
    fn rotation_schedule_is_valid_any_p() {
        for p in [2usize, 3, 5, 8, 12] {
            let s = rotation_schedule(p);
            validate_schedule(p, &s).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn xor_rejects_odd_p() {
        xor_schedule(6);
    }

    #[test]
    fn validate_catches_double_send() {
        let bad = vec![vec![(0usize, 1usize), (0, 2)]];
        assert!(validate_schedule(3, &bad).unwrap_err().contains("sends twice"));
    }

    #[test]
    fn scheduled_between_bound_and_serial() {
        let topo = presets::table1();
        let bytes = Mat::filled(4, 4, 8e6);
        let lb = CostEngine::slowest_pair(&topo).exchange_time(&bytes);
        let serial = CostEngine::per_sender(&topo).exchange_time(&bytes);
        let sched = scheduled_a2a_time(&topo, &bytes, &xor_schedule(4));
        assert!(sched >= lb, "{sched} < lower bound {lb}");
        assert!(sched <= serial * 4.0, "{sched} > serial envelope");
    }

    #[test]
    fn schedule_reduces_contention_vs_concurrent() {
        // With only one cross-node flow per round, the uplink is never
        // shared, so per-delivery time matches the isolated pair time.
        let topo = presets::table1();
        let bytes = Mat::filled(4, 4, 32e6);
        let conc = CostEngine::contention(&topo).pair_times(&bytes).get(0, 2);
        let round: Round = vec![(0, 2), (1, 3)]; // wait: shares the uplink
        let single: Round = vec![(0, 2)];
        let mut eng = CostEngine::contention(&topo);
        let mut rb = Mat::zeros(4, 4);
        for &(i, j) in &single {
            rb.set(i, j, bytes.get(i, j));
        }
        let t_single = eng.exchange_time(&rb);
        let mut rb2 = Mat::zeros(4, 4);
        for &(i, j) in &round {
            rb2.set(i, j, bytes.get(i, j));
        }
        let t_pair = eng.exchange_time(&rb2);
        assert!(t_single < conc, "isolated round must beat concurrent");
        assert!(t_single <= t_pair);
    }

    #[test]
    fn padding_with_empty_rounds_leaves_price_unchanged() {
        let topo = presets::table1();
        let bytes = Mat::filled(4, 4, 8e6);
        let rounds = xor_schedule(4);
        let base = scheduled_a2a_time(&topo, &bytes, &rounds);
        let mut padded = vec![Vec::new(), rounds[0].clone(), Vec::new()];
        padded.extend(rounds[1..].iter().cloned());
        padded.push(Vec::new());
        assert_eq!(scheduled_a2a_time(&topo, &bytes, &padded), base);
        // rounds whose pairs all carry zero bytes are just as free
        let mut zeroed = bytes.clone();
        for &(i, j) in &rounds[2] {
            zeroed.set(i, j, 0.0);
        }
        let skipped: Vec<Round> =
            rounds.iter().cloned().filter(|r| r != &rounds[2]).collect();
        assert_eq!(
            scheduled_a2a_time(&topo, &zeroed, &rounds),
            scheduled_a2a_time(&topo, &zeroed, &skipped),
        );
    }

    #[test]
    fn self_traffic_is_a_non_gating_local_copy() {
        let topo = presets::table1();
        let rounds = xor_schedule(4);
        // pure self-traffic: the schedule costs exactly the slowest copy
        let mut self_only = Mat::zeros(4, 4);
        for i in 0..4 {
            self_only.set(i, i, 32e6);
        }
        let eng = CostEngine::contention(&topo); // pair_time only (&self)
        let want = (0..4)
            .map(|i| eng.pair_time(i, i, 32e6))
            .fold(0.0, f64::max);
        assert_eq!(scheduled_a2a_time(&topo, &self_only, &rounds), want);
        // with real cross traffic the copies hide under the rounds
        let full = Mat::filled(4, 4, 32e6);
        let mut no_self = full.clone();
        for i in 0..4 {
            no_self.set(i, i, 0.0);
        }
        let t_full = scheduled_a2a_time(&topo, &full, &rounds);
        let t_no_self = scheduled_a2a_time(&topo, &no_self, &rounds);
        assert_eq!(t_full, t_no_self, "hidden copies must not add cost");
        assert!(t_full > want);
    }

    #[test]
    fn xor_groups_intra_node_rounds_first() {
        // On [2,2], xor round 1 is entirely intra-node (i ^ 1 flips the
        // low bit), round 2/3 cross nodes — the locality property that
        // makes xor the natural hierarchical-friendly schedule.
        let topo = presets::table1();
        let s = xor_schedule(4);
        for &(i, j) in &s[1] {
            assert!(topo.same_node(i, j));
        }
        for &(i, j) in &s[2] {
            assert!(!topo.same_node(i, j));
        }
    }
}
