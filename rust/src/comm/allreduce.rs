//! Ring allreduce pricing for the dense (data-parallel) gradient sync.
//!
//! The coordinator's step-time model needs the cost of synchronising the
//! replicated (non-expert) parameters every step. We price the standard
//! ring allreduce: 2·(P−1) steps, each moving `bytes/P` between ring
//! neighbours; the slowest traversed pair bottlenecks every step (the ring
//! is laid out over device ids, so on a multi-node topology the node
//! boundary links dominate — as they do for NCCL rings in practice).

use crate::topology::Topology;

/// Time for a ring allreduce of `bytes` across all P devices.
pub fn ring_allreduce_time(topo: &Topology, bytes: f64) -> f64 {
    let p = topo.p();
    if p <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    // ring neighbours: i → (i+1) % p; bottleneck over the ring
    let mut alpha_max: f64 = 0.0;
    let mut beta_max: f64 = 0.0;
    for i in 0..p {
        let j = (i + 1) % p;
        alpha_max = alpha_max.max(topo.alpha(i, j));
        beta_max = beta_max.max(topo.beta(i, j));
    }
    let steps = 2.0 * (p as f64 - 1.0);
    let chunk = bytes / p as f64;
    steps * (alpha_max + beta_max * chunk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{presets, Link, Topology};

    #[test]
    fn single_device_is_free() {
        let t = Topology::homogeneous(1, Link::new(0.0, 1e-9), presets::local_copy());
        assert_eq!(ring_allreduce_time(&t, 1e9), 0.0);
    }

    #[test]
    fn bandwidth_term_matches_formula() {
        let t = Topology::homogeneous(4, Link::new(0.0, 1e-9), presets::local_copy());
        let got = ring_allreduce_time(&t, 4e6);
        let want = 2.0 * 3.0 * (1e-9 * 1e6);
        assert!((got - want).abs() / want < 1e-9);
    }

    #[test]
    fn multinode_bottlenecked_by_uplink() {
        let single = presets::cluster_b(1);
        let multi = presets::cluster_b(2);
        // per-device chunk shrinks with P, but the slow inter-node hop
        // dominates: same bytes must be slower on the multi-node ring
        let b = 64e6;
        assert!(ring_allreduce_time(&multi, b) > ring_allreduce_time(&single, b));
    }

    #[test]
    fn scales_linearly_in_bytes_when_alpha_zero() {
        let t = Topology::homogeneous(8, Link::new(0.0, 1e-9), presets::local_copy());
        let t1 = ring_allreduce_time(&t, 1e6);
        let t2 = ring_allreduce_time(&t, 2e6);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
