//! Hierarchical all-to-all (DeepSpeed-MoE / HetuMoE style).
//!
//! Instead of P×P direct deliveries, the hierarchical schedule does
//! (1) an intra-node exchange that re-groups data by destination *node*,
//! (2) one inter-node exchange between corresponding local ranks, and
//! (3) an intra-node exchange to the final destination rank. Fewer, larger
//! inter-node messages amortise α and avoid NIC oversubscription — the
//! system optimisation the paper's related-work section credits to
//! DeepSpeed-MoE/HetuMoE, priced here so benches can combine it with both
//! even and topology-aware dispatch patterns.

use super::engine::CostEngine;
use crate::topology::Topology;
use crate::util::Mat;

/// Per-phase times of a hierarchical all-to-all.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HierBreakdown {
    pub intra_gather: f64,
    pub inter: f64,
    pub intra_scatter: f64,
}

impl HierBreakdown {
    pub fn total(&self) -> f64 {
        self.intra_gather + self.inter + self.intra_scatter
    }
}

/// Price a hierarchical all-to-all of `bytes[i][j]` on `topo` under the
/// contention model. Falls back to a direct exchange when the topology has
/// a single node.
pub fn hierarchical_a2a_time(topo: &Topology, bytes: &Mat) -> HierBreakdown {
    let p = topo.p();
    assert_eq!((bytes.rows(), bytes.cols()), (p, p));
    let nodes = topo.nodes();
    let mut eng = CostEngine::contention(topo);
    if nodes.len() <= 1 {
        return HierBreakdown {
            intra_gather: 0.0,
            inter: eng.exchange_time(bytes),
            intra_scatter: 0.0,
        };
    }

    // Phase 1: within each node, device d hands the data destined for node
    // r to the local rank aligned with r (r-th device of the node, mod
    // node size). Build the intra byte matrix.
    let mut phase1 = Mat::zeros(p, p);
    for (src_node, devs) in nodes.iter().enumerate() {
        for &i in devs {
            for (dst_node, dst_devs) in nodes.iter().enumerate() {
                if dst_node == src_node {
                    continue; // local data goes direct in phase 3 pricing
                }
                let to_node: f64 = dst_devs.iter().map(|&j| bytes.get(i, j)).sum();
                let agent = devs[dst_node % devs.len()];
                phase1.add_assign(i, agent, to_node);
            }
        }
    }

    // Phase 2: aligned ranks exchange across nodes; agent for (src_node,
    // dst_node) sends everything its node is sending to dst_node.
    let mut phase2 = Mat::zeros(p, p);
    for (src_node, devs) in nodes.iter().enumerate() {
        for (dst_node, dst_devs) in nodes.iter().enumerate() {
            if dst_node == src_node {
                continue;
            }
            let total: f64 = devs
                .iter()
                .flat_map(|&i| dst_devs.iter().map(move |&j| bytes.get(i, j)))
                .sum();
            let send_agent = devs[dst_node % devs.len()];
            let recv_agent = dst_devs[src_node % dst_devs.len()];
            phase2.add_assign(send_agent, recv_agent, total);
        }
    }

    // Phase 3: deliver to the final rank inside the destination node, plus
    // the node-local portion of the original matrix.
    let mut phase3 = Mat::zeros(p, p);
    for (dst_node, dst_devs) in nodes.iter().enumerate() {
        for (src_node, devs) in nodes.iter().enumerate() {
            if src_node == dst_node {
                for &i in devs {
                    for &j in dst_devs {
                        phase3.add_assign(i, j, bytes.get(i, j));
                    }
                }
                continue;
            }
            let recv_agent = dst_devs[src_node % dst_devs.len()];
            for &j in dst_devs {
                let total: f64 = devs.iter().map(|&i| bytes.get(i, j)).sum();
                phase3.add_assign(recv_agent, j, total);
            }
        }
    }

    HierBreakdown {
        intra_gather: eng.exchange_time(&phase1),
        inter: eng.exchange_time(&phase2),
        intra_scatter: eng.exchange_time(&phase3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{presets, Link, Topology, TreeSpec};

    fn two_nodes() -> Topology {
        Topology::tree(
            &TreeSpec::parse("[4,4]").unwrap(),
            &[Link::from_gbps_us(45.0, 2.0), Link::from_gbps_us(12.5, 10.0)],
            presets::local_copy(),
        )
    }

    #[test]
    fn single_node_falls_back_to_direct() {
        let t = Topology::homogeneous(
            4,
            Link::from_gbps_us(100.0, 1.0),
            presets::local_copy(),
        );
        let b = Mat::filled(4, 4, 1e6);
        let h = hierarchical_a2a_time(&t, &b);
        assert_eq!(h.intra_gather, 0.0);
        assert_eq!(h.intra_scatter, 0.0);
        assert!(h.inter > 0.0);
    }

    #[test]
    fn phases_are_positive_on_multinode() {
        let t = two_nodes();
        let h = hierarchical_a2a_time(&t, &Mat::filled(8, 8, 1e6));
        assert!(h.intra_gather > 0.0);
        assert!(h.inter > 0.0);
        assert!(h.intra_scatter > 0.0);
    }

    #[test]
    fn hierarchical_beats_direct_on_small_messages() {
        // α-dominated regime: 8 devices × tiny messages — fewer inter-node
        // messages win.
        let t = two_nodes();
        let b = Mat::filled(8, 8, 2e4);
        let direct = CostEngine::per_sender(&t).exchange_time(&b);
        let hier = hierarchical_a2a_time(&t, &b).total();
        assert!(hier < direct, "hier {hier} direct {direct}");
    }

    #[test]
    fn conserves_total_bytes_inter_phase() {
        // the inter phase must carry exactly the cross-node bytes
        let t = two_nodes();
        let b = Mat::from_fn(8, 8, |i, j| (i * 8 + j) as f64);
        let nodes = t.nodes();
        let cross: f64 = (0..8)
            .flat_map(|i| (0..8).map(move |j| (i, j)))
            .filter(|&(i, j)| t.node_of(i) != t.node_of(j))
            .map(|(i, j)| b.get(i, j))
            .sum();
        // rebuild phase2 total via the public API: price with a zeroed
        // intra matrix and compare against manual accumulation
        let mut phase2_total = 0.0;
        for (sn, devs) in nodes.iter().enumerate() {
            for (dn, ddevs) in nodes.iter().enumerate() {
                if sn == dn {
                    continue;
                }
                for &i in devs {
                    for &j in ddevs {
                        phase2_total += b.get(i, j);
                    }
                }
            }
        }
        assert!((phase2_total - cross).abs() < 1e-9);
    }
}
