//! Execution backends behind the [`Backend`] trait.
//!
//! The runtime layer owns everything about *executing* the model:
//!
//! * [`backend`] — the [`Backend`] trait (`init`/`train_step`/`eval` over
//!   [`HostTensor`]s), the [`GateInputs`] a dispatch policy feeds a model,
//!   and [`open_backend`] for name-based construction (`sim`/`xla`/`auto`);
//! * [`SimBackend`] — pure-rust gate-statistics + loss-trajectory
//!   emulator; the default backend, needs no artifacts and no XLA;
//! * `XlaBackend` (feature `backend-xla`) — PJRT execution of the
//!   AOT-compiled JAX/Pallas artifacts (HLO text + manifest ABI emitted by
//!   `python/compile/aot.py`);
//! * [`Manifest`] / [`ModelCfg`] — the python↔rust ABI contract, parsed
//!   with the in-tree JSON reader (works without XLA);
//! * [`HostTensor`] — rust-side dense arrays, converted to/from
//!   `xla::Literal` only under the `backend-xla` feature.

mod backend;
mod manifest;
mod sim;
mod tensor;
#[cfg(feature = "backend-xla")]
mod xla;

pub use backend::{
    open_backend, resolve_model_cfg, Backend, BackendKind, EvalOutputs, GateInputs,
    StepOutputs,
};
pub use manifest::{Manifest, ModelCfg, ProgramDesc, TensorDesc};
pub use sim::SimBackend;
pub use tensor::{DType, HostTensor};
#[cfg(feature = "backend-xla")]
pub use xla::{Artifact, Program, Runtime, XlaBackend};
