//! PJRT runtime: load + execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` leaves per-config directories under `artifacts/`:
//! HLO **text** programs (`init`/`step`/`eval`) plus `manifest.json`
//! describing every input/output tensor in positional order (the ABI
//! contract with `python/compile/aot.py`). This module:
//!
//! * parses the manifest ([`Manifest`], [`TensorDesc`]);
//! * compiles the HLO text on the PJRT CPU client
//!   (`HloModuleProto::from_text_file → XlaComputation → compile`, the
//!   0.5.1-safe path from /opt/xla-example);
//! * wraps execution behind [`Program::run`] with tuple decomposition and
//!   shape checking;
//! * converts between [`HostTensor`] (rust-side dense arrays) and
//!   `xla::Literal`.
//!
//! Python never runs here — the binary is self-contained once artifacts
//! exist.

mod manifest;
mod tensor;

pub use manifest::{Manifest, ModelCfg, ProgramDesc, TensorDesc};
pub use tensor::{DType, HostTensor};

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client + executable cache root.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT runtime (the only backend in this image).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text program.
    pub fn load_program(&self, path: &Path, desc: ProgramDesc) -> Result<Program> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Program { exe, desc })
    }

    /// Load all three programs of an artifact directory.
    pub fn load_artifact(&self, dir: &Path) -> Result<Artifact> {
        let manifest = Manifest::load(dir)?;
        let init = self.load_program(&dir.join(&manifest.init.file), manifest.init.clone())?;
        let step = self.load_program(&dir.join(&manifest.step.file), manifest.step.clone())?;
        let eval = self.load_program(&dir.join(&manifest.eval.file), manifest.eval.clone())?;
        Ok(Artifact { manifest, init, step, eval })
    }
}

/// One compiled executable + its ABI description.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    desc: ProgramDesc,
}

impl Program {
    pub fn desc(&self) -> &ProgramDesc {
        &self.desc
    }

    /// Execute with positional literal inputs (borrowed or owned); returns
    /// the decomposed output tuple (aot.py lowers with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.desc.inputs.len(),
            "program {} expects {} inputs, got {}",
            self.desc.file,
            self.desc.inputs.len(),
            inputs.len()
        );
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.desc.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = tuple.to_tuple().context("decomposing output tuple")?;
        anyhow::ensure!(
            outs.len() == self.desc.outputs.len(),
            "program {} returned {} outputs, manifest says {}",
            self.desc.file,
            outs.len(),
            self.desc.outputs.len()
        );
        Ok(outs)
    }

    /// Convenience: run with host tensors, validating shapes against the
    /// manifest before dispatch.
    pub fn run_host(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        for (t, d) in inputs.iter().zip(&self.desc.inputs) {
            anyhow::ensure!(
                t.shape() == d.shape.as_slice() && t.dtype() == d.dtype,
                "input {:?}: got {:?}/{:?}, manifest wants {:?}/{:?}",
                d.name,
                t.shape(),
                t.dtype(),
                d.shape,
                d.dtype
            );
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.run(&lits)?;
        outs.iter()
            .zip(&self.desc.outputs)
            .map(|(l, d)| HostTensor::from_literal(l, &d.shape, d.dtype))
            .collect()
    }
}

/// A fully-loaded artifact: manifest + compiled init/step/eval.
pub struct Artifact {
    pub manifest: Manifest,
    pub init: Program,
    pub step: Program,
    pub eval: Program,
}
