//! The [`Backend`] trait: the execution-engine seam of the public API.
//!
//! A backend owns model + optimiser state and knows how to run one
//! training/eval step given host-side tensors. Everything above it (the
//! [`crate::coordinator::Session`], dispatch policies, the simulated
//! cluster clock) is backend-agnostic: the coordinator hands a backend the
//! gate's runtime matrices ([`GateInputs`]) once at init, then drives it
//! with `[P, B, T]` token batches and reads back scalars + the measured
//! dispatch counts `c_ie` ([`StepOutputs`]).
//!
//! Two implementations ship with the crate:
//!
//! * [`super::SimBackend`] — pure rust, zero external dependencies. It
//!   emulates the gate statistics and the loss trajectory, so training
//!   loops, benches, and CI run on any machine (the default feature set).
//! * `XlaBackend` (cargo feature `backend-xla`) — PJRT execution of the
//!   AOT-compiled JAX/Pallas artifacts, the full three-layer path.

use super::manifest::{Manifest, ModelCfg};
use super::tensor::HostTensor;
use crate::util::Mat;
use anyhow::Result;
use std::path::Path;

/// The gate's runtime inputs, produced by a
/// [`crate::coordinator::DispatchPolicy`] and fed to the model once per
/// session: the penalty matrix (which auxiliary loss), the capacity
/// matrix, the intra-node mask, and the FasterMoE-Hir compulsory remote
/// fraction (1.0 = unconstrained).
#[derive(Clone, Debug)]
pub struct GateInputs {
    pub penalty: Mat,
    pub caps: Mat,
    pub local_mask: Mat,
    pub hir_remote_frac: f32,
}

/// Observables of one training step.
#[derive(Clone, Debug)]
pub struct StepOutputs {
    pub loss: f64,
    pub ce: f64,
    pub aux: f64,
    /// Fraction of dispatched tokens dropped at full expert buffers.
    pub dropped: f64,
    /// Mean per-MoE-layer dispatch counts `c_ie` in tokens (P×N).
    pub counts: Mat,
}

/// Observables of one (pure) evaluation pass.
#[derive(Clone, Debug)]
pub struct EvalOutputs {
    pub ce: f64,
    pub counts: Mat,
}

/// An execution engine for one model: owns state, runs init/step/eval over
/// [`HostTensor`]s. Object-safe so sessions can hold `Box<dyn Backend>`.
pub trait Backend {
    /// Short engine name ("sim", "xla") for logs and labels.
    fn name(&self) -> &'static str;

    /// The model's static shape/structure.
    fn model_cfg(&self) -> &ModelCfg;

    /// (Re-)initialise model + optimiser state from `seed` under the given
    /// gate inputs. Must be called before `train_step`/`eval`; calling it
    /// again restarts training from scratch.
    fn init(&mut self, seed: i32, gate: &GateInputs) -> Result<()>;

    /// Replace the gate's runtime inputs **without** resetting model or
    /// optimiser state — the live-update seam expert migration uses: a
    /// re-placed expert changes the intra-node mask (and, for
    /// topology-aware policies, the penalty/capacity matrices), and the
    /// gate must steer toward the new hosting from wherever training
    /// currently is. Backends that cannot apply a live update may ignore
    /// it (the default is a no-op); callers must not assume the update
    /// took effect on such backends.
    fn update_gate(&mut self, gate: &GateInputs) -> Result<()> {
        let _ = gate;
        Ok(())
    }

    /// One optimisation step on a `[P, B, T]` i32 token/target batch.
    fn train_step(
        &mut self,
        tokens: &HostTensor,
        targets: &HostTensor,
        lr: f32,
    ) -> Result<StepOutputs>;

    /// A pure validation pass: must not mutate model state, and must be
    /// deterministic in (state, batch).
    fn eval(&mut self, tokens: &HostTensor, targets: &HostTensor) -> Result<EvalOutputs>;
}

/// Which execution engine to open (CLI `--backend`, config `train.backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// The pure-rust simulator; never needs artifacts or XLA.
    Sim,
    /// PJRT/XLA on compiled artifacts (requires the `backend-xla` feature).
    Xla,
    /// XLA when the feature is compiled in *and* the artifact directory
    /// exists; Sim otherwise.
    #[default]
    Auto,
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<BackendKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "sim" | "simulate" | "simulator" => Ok(BackendKind::Sim),
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            "auto" => Ok(BackendKind::Auto),
            other => Err(format!("unknown backend {other:?} (sim|xla|auto)")),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Sim => "sim",
            BackendKind::Xla => "xla",
            BackendKind::Auto => "auto",
        })
    }
}

/// Resolve a model shape by artifact name: from
/// `artifacts_dir/<artifact>/manifest.json` when present (the manifest
/// parser is pure rust), else from the built-in [`ModelCfg::preset`]
/// table. The single source of truth for name → shape used by both
/// [`open_backend`] and `ExperimentConfig`.
pub fn resolve_model_cfg(artifacts_dir: &Path, artifact: &str) -> Result<ModelCfg> {
    let dir = artifacts_dir.join(artifact);
    if dir.join("manifest.json").exists() {
        return Ok(Manifest::load(&dir)?.config);
    }
    ModelCfg::preset(artifact).ok_or_else(|| {
        anyhow::anyhow!(
            "no artifact at {dir:?} and no built-in preset named {artifact:?} \
             (presets: {})",
            ModelCfg::preset_names().join(", ")
        )
    })
}

/// Open a backend for the named artifact.
///
/// * `Sim` — model shape via [`resolve_model_cfg`]. Never touches XLA.
/// * `Xla` — loads + compiles the artifact's HLO programs; errors unless
///   the crate was built with `--features backend-xla`.
/// * `Auto` — `Xla` when available (feature + artifact dir), else `Sim`.
pub fn open_backend(
    kind: BackendKind,
    artifacts_dir: &Path,
    artifact: &str,
) -> Result<Box<dyn Backend>> {
    let dir = artifacts_dir.join(artifact);
    match kind {
        BackendKind::Sim => {
            let cfg = resolve_model_cfg(artifacts_dir, artifact)?;
            Ok(Box::new(super::SimBackend::new(cfg)))
        }
        BackendKind::Xla => {
            #[cfg(feature = "backend-xla")]
            {
                Ok(Box::new(super::XlaBackend::load(&dir)?))
            }
            #[cfg(not(feature = "backend-xla"))]
            {
                anyhow::bail!(
                    "backend `xla` requested but this binary was built without it; \
                     rebuild with `cargo build --features backend-xla` or use `--backend sim`"
                )
            }
        }
        BackendKind::Auto => {
            #[cfg(feature = "backend-xla")]
            {
                if dir.join("manifest.json").exists() {
                    return Ok(Box::new(super::XlaBackend::load(&dir)?));
                }
            }
            open_backend(BackendKind::Sim, artifacts_dir, artifact)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("sim".parse::<BackendKind>().unwrap(), BackendKind::Sim);
        assert_eq!("XLA".parse::<BackendKind>().unwrap(), BackendKind::Xla);
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert!("tpu".parse::<BackendKind>().is_err());
    }

    #[test]
    fn sim_backend_opens_from_preset_without_artifacts() {
        let b = open_backend(BackendKind::Sim, Path::new("definitely/missing"), "tiny4").unwrap();
        assert_eq!(b.name(), "sim");
        assert_eq!(b.model_cfg().p, 4);
    }

    #[test]
    fn unknown_artifact_without_preset_errors() {
        let err =
            open_backend(BackendKind::Sim, Path::new("definitely/missing"), "nope").unwrap_err();
        assert!(err.to_string().contains("preset"), "{err}");
    }

    #[cfg(not(feature = "backend-xla"))]
    #[test]
    fn xla_backend_errors_without_feature() {
        let err = open_backend(BackendKind::Xla, Path::new("artifacts"), "tiny4").unwrap_err();
        assert!(err.to_string().contains("backend-xla"), "{err}");
    }
}
