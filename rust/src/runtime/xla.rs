//! PJRT/XLA execution backend (cargo feature `backend-xla`).
//!
//! `make artifacts` leaves per-config directories under `artifacts/`:
//! HLO **text** programs (`init`/`step`/`eval`) plus `manifest.json`
//! describing every input/output tensor in positional order (the ABI
//! contract with `python/compile/aot.py`). This module:
//!
//! * compiles the HLO text on the PJRT CPU client
//!   (`HloModuleProto::from_text_file → XlaComputation → compile`, the
//!   0.5.1-safe path from /opt/xla-example);
//! * wraps execution behind [`Program::run`] with tuple decomposition and
//!   shape checking;
//! * implements the [`Backend`] trait over a loaded artifact
//!   ([`XlaBackend`]), holding model + Adam state as device literals
//!   between steps.
//!
//! Python never runs here — the binary is self-contained once artifacts
//! exist.

use super::backend::{Backend, EvalOutputs, GateInputs, StepOutputs};
use super::manifest::{Manifest, ModelCfg, ProgramDesc};
use super::tensor::{DType, HostTensor};
use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client + executable cache root.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT runtime (the only backend in this image).
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text program.
    pub fn load_program(&self, path: &Path, desc: ProgramDesc) -> Result<Program> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Program { exe, desc })
    }

    /// Load all three programs of an artifact directory.
    pub fn load_artifact(&self, dir: &Path) -> Result<Artifact> {
        let manifest = Manifest::load(dir)?;
        let init = self.load_program(&dir.join(&manifest.init.file), manifest.init.clone())?;
        let step = self.load_program(&dir.join(&manifest.step.file), manifest.step.clone())?;
        let eval = self.load_program(&dir.join(&manifest.eval.file), manifest.eval.clone())?;
        Ok(Artifact { manifest, init, step, eval })
    }
}

/// One compiled executable + its ABI description.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    desc: ProgramDesc,
}

impl Program {
    pub fn desc(&self) -> &ProgramDesc {
        &self.desc
    }

    /// Execute with positional literal inputs (borrowed or owned); returns
    /// the decomposed output tuple (aot.py lowers with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.desc.inputs.len(),
            "program {} expects {} inputs, got {}",
            self.desc.file,
            self.desc.inputs.len(),
            inputs.len()
        );
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.desc.file))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = tuple.to_tuple().context("decomposing output tuple")?;
        anyhow::ensure!(
            outs.len() == self.desc.outputs.len(),
            "program {} returned {} outputs, manifest says {}",
            self.desc.file,
            outs.len(),
            self.desc.outputs.len()
        );
        Ok(outs)
    }

    /// Convenience: run with host tensors, validating shapes against the
    /// manifest before dispatch.
    // pallas-lint: allow(structure) -- feature-gated PJRT entry point for embedders; no in-repo caller by design
    pub fn run_host(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        for (t, d) in inputs.iter().zip(&self.desc.inputs) {
            anyhow::ensure!(
                t.shape() == d.shape.as_slice() && t.dtype() == d.dtype,
                "input {:?}: got {:?}/{:?}, manifest wants {:?}/{:?}",
                d.name,
                t.shape(),
                t.dtype(),
                d.shape,
                d.dtype
            );
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.run(&lits)?;
        outs.iter()
            .zip(&self.desc.outputs)
            .map(|(l, d)| HostTensor::from_literal(l, &d.shape, d.dtype))
            .collect()
    }
}

/// A fully-loaded artifact: manifest + compiled init/step/eval.
pub struct Artifact {
    pub manifest: Manifest,
    pub init: Program,
    pub step: Program,
    pub eval: Program,
}

/// [`Backend`] over a compiled artifact: PJRT executes the cluster-step
/// program; this wrapper owns the parameter/optimiser literals, the gate
/// input literals, and the training-step counter `t`.
pub struct XlaBackend {
    #[allow(dead_code)]
    runtime: Runtime,
    artifact: Artifact,
    /// penalty, caps, local_mask, hir_frac as literals (set by `init`).
    input_lits: Vec<xla::Literal>,
    /// params ++ m ++ v (kept as XLA literals between steps).
    state: Vec<xla::Literal>,
    t: f32,
}

impl XlaBackend {
    /// Load + compile an artifact directory. Call [`Backend::init`] before
    /// stepping.
    pub fn load(artifact_dir: &Path) -> Result<XlaBackend> {
        let runtime = Runtime::cpu()?;
        let artifact = runtime.load_artifact(artifact_dir)?;
        Ok(XlaBackend { runtime, artifact, input_lits: Vec::new(), state: Vec::new(), t: 0.0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.artifact.manifest
    }

    fn batch_literals(
        &self,
        tokens: &HostTensor,
        targets: &HostTensor,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let cfg = &self.artifact.manifest.config;
        let shape = [cfg.p, cfg.batch, cfg.seq];
        anyhow::ensure!(
            tokens.shape() == shape && targets.shape() == shape,
            "batch is {:?}/{:?}, artifact {} wants {:?}",
            tokens.shape(),
            targets.shape(),
            self.artifact.manifest.name,
            shape
        );
        Ok((tokens.to_literal()?, targets.to_literal()?))
    }

    fn require_init(&self) -> Result<()> {
        anyhow::ensure!(!self.state.is_empty(), "XlaBackend: init() must run before step/eval");
        Ok(())
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn model_cfg(&self) -> &ModelCfg {
        &self.artifact.manifest.config
    }

    fn init(&mut self, seed: i32, gate: &GateInputs) -> Result<()> {
        self.input_lits = vec![
            HostTensor::from_mat(&gate.penalty).to_literal()?,
            HostTensor::from_mat(&gate.caps).to_literal()?,
            HostTensor::from_mat(&gate.local_mask).to_literal()?,
            HostTensor::scalar_f32(gate.hir_remote_frac).to_literal()?,
        ];

        // init: seed → params; Adam moments start at zero.
        let seed_lit = HostTensor::scalar_i32(seed).to_literal()?;
        let params = self
            .artifact
            .init
            .run(&[seed_lit])
            .context("running init program")?;
        let mut state = params;
        for desc in self
            .artifact
            .manifest
            .params
            .iter()
            .chain(&self.artifact.manifest.params)
        {
            state.push(HostTensor::f32(vec![0.0; desc.numel()], &desc.shape).to_literal()?);
        }
        self.state = state;
        self.t = 0.0;
        Ok(())
    }

    fn train_step(
        &mut self,
        tokens: &HostTensor,
        targets: &HostTensor,
        lr: f32,
    ) -> Result<StepOutputs> {
        self.require_init()?;
        let n = self.artifact.manifest.n_param_tensors;
        let (tok_lit, tgt_lit) = self.batch_literals(tokens, targets)?;
        let t_lit = HostTensor::scalar_f32(self.t).to_literal()?;
        let lr_lit = HostTensor::scalar_f32(lr).to_literal()?;

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 8);
        args.extend(self.state.iter());
        args.push(&t_lit);
        args.push(&lr_lit);
        args.push(&tok_lit);
        args.push(&tgt_lit);
        for lit in &self.input_lits {
            args.push(lit);
        }

        let mut outs = self.artifact.step.run(&args)?;

        // split outputs: 3n state, then t, loss, ce, aux, counts, dropped
        let tail = outs.split_off(3 * n);
        self.state = outs;
        let cfg = &self.artifact.manifest.config;
        let scalars: Vec<f64> = [0usize, 1, 2, 3, 5]
            .iter()
            .map(|&i| HostTensor::from_literal(&tail[i], &[], DType::F32).map(|t| t.item()))
            .collect::<Result<_>>()?;
        let counts =
            HostTensor::from_literal(&tail[4], &[cfg.p, cfg.n_experts], DType::F32)?.to_mat()?;
        self.t = scalars[0] as f32;

        Ok(StepOutputs {
            loss: scalars[1],
            ce: scalars[2],
            aux: scalars[3],
            dropped: scalars[4],
            counts,
        })
    }

    fn eval(&mut self, tokens: &HostTensor, targets: &HostTensor) -> Result<EvalOutputs> {
        self.require_init()?;
        let n = self.artifact.manifest.n_param_tensors;
        let (tok_lit, tgt_lit) = self.batch_literals(tokens, targets)?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(n + 6);
        args.extend(self.state.iter().take(n));
        args.push(&tok_lit);
        args.push(&tgt_lit);
        for lit in &self.input_lits {
            args.push(lit);
        }
        let outs = self.artifact.eval.run(&args)?;
        let cfg = &self.artifact.manifest.config;
        let ce = HostTensor::from_literal(&outs[1], &[], DType::F32)?.item();
        let counts =
            HostTensor::from_literal(&outs[3], &[cfg.p, cfg.n_experts], DType::F32)?.to_mat()?;
        Ok(EvalOutputs { ce, counts })
    }
}
