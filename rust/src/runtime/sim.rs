//! [`SimBackend`]: a pure-rust execution backend.
//!
//! The simulator does not run the transformer; it emulates the two things
//! the coordinator actually consumes (DESIGN.md §backends):
//!
//! * **gate statistics** — the per-step dispatch matrix `c_ie`. A freshly
//!   "initialised" gate dispatches near-uniformly (seeded jitter standing
//!   in for random gate weights); over training it relaxes toward the
//!   attractor implied by the penalty matrix it was given. Because the
//!   TA-MoE penalty is `Norm(1/ĉ)`, the row-normalised inverse penalty *is*
//!   the Eq. 7 target pattern, so a sim gate under the TA-MoE policy
//!   converges to `ĉ` exactly as the compiled gate does under the topology
//!   loss — and a load-balance penalty (constant rows) keeps it uniform.
//!   The FasterMoE-Hir compulsory ratio clips the remote mass of the
//!   attractor, reproducing the Hir budget behaviour.
//! * **loss trajectory** — a byte-level LM curve: cross-entropy decays
//!   exponentially from `ln(vocab)` toward a floor at a rate proportional
//!   to the learning rate, plus a small deterministic data-dependent
//!   ripple (a hash of the batch, not an RNG, so eval stays pure). A
//!   compulsory dispatch restriction converges to a worse floor — the
//!   paper's Fig. 5 observation, and the property the fig5 bench asserts.
//!
//! Everything is deterministic in `(seed, gate inputs, batches)`: two runs
//! with identical seeds produce byte-identical logs, matching the PJRT
//! backend's reproducibility contract.

use super::backend::{Backend, EvalOutputs, GateInputs, StepOutputs};
use super::manifest::ModelCfg;
use super::tensor::HostTensor;
use crate::util::rng::Rng;
use crate::util::Mat;
use anyhow::{Context, Result};

/// Steps for the gate to move ~63% of the way to its attractor.
const GATE_TAU_STEPS: f64 = 24.0;
/// CE decay rate per step per unit learning rate.
const LR_DECAY_SCALE: f64 = 30.0;
/// Irreducible byte-level CE floor for an unrestricted gate.
const CE_FLOOR: f64 = 1.9;
/// Extra converged CE per unit of compulsory (non-learnable) local ratio.
const COMPULSORY_HANDICAP: f64 = 0.35;
/// Amplitude of the data-dependent CE ripple (relative to ce − floor).
const NOISE_AMP: f64 = 0.01;
/// Train→valid CE generalisation gap emitted by `eval`.
const EVAL_GAP: f64 = 0.04;

/// Pure-rust backend emulating gate statistics and loss trajectory.
pub struct SimBackend {
    cfg: ModelCfg,
    /// Freshly-initialised gate frequencies (rows sum to 1).
    init_pref: Mat,
    /// Converged gate frequencies implied by the penalty (rows sum to 1).
    attractor: Mat,
    gate: Option<GateInputs>,
    step: usize,
    /// Noise-free cross-entropy state.
    ce: f64,
    /// Converged CE for this gate configuration.
    floor: f64,
}

impl SimBackend {
    pub fn new(cfg: ModelCfg) -> SimBackend {
        let (p, n) = (cfg.p, cfg.n_experts);
        SimBackend {
            cfg,
            init_pref: Mat::filled(p, n, 1.0 / n as f64),
            attractor: Mat::filled(p, n, 1.0 / n as f64),
            gate: None,
            step: 0,
            ce: 0.0,
            floor: CE_FLOOR,
        }
    }

    /// Gate dispatch frequencies at the current training step (rows sum
    /// to 1): initial preference relaxing toward the attractor.
    fn frequencies(&self) -> Mat {
        let lambda = 1.0 - (-(self.step as f64) / GATE_TAU_STEPS).exp();
        let (p, n) = (self.cfg.p, self.cfg.n_experts);
        Mat::from_fn(p, n, |i, e| {
            (1.0 - lambda) * self.init_pref.get(i, e) + lambda * self.attractor.get(i, e)
        })
    }

    fn counts(&self) -> Mat {
        let sent = (self.cfg.k * self.cfg.tokens_per_dev) as f64;
        self.frequencies().scale(sent)
    }

    fn require_init(&self) -> Result<&GateInputs> {
        self.gate.as_ref().context("SimBackend: init() must run before step/eval")
    }

    /// The unified auxiliary loss the compiled model evaluates:
    /// `mean_i Σ_e penalty_ie · f_ie²` over the current gate frequencies.
    fn aux(&self, freq: &Mat) -> f64 {
        let gate = self.gate.as_ref().expect("init checked by caller");
        let (p, n) = (freq.rows(), freq.cols());
        let mut total = 0.0;
        for i in 0..p {
            for e in 0..n {
                let f = freq.get(i, e);
                total += gate.penalty.get(i, e) * f * f;
            }
        }
        total / p as f64
    }

    /// Fraction of dispatched tokens exceeding per-expert buffer capacity.
    fn dropped(&self, counts: &Mat) -> f64 {
        let gate = self.gate.as_ref().expect("init checked by caller");
        let total = counts.sum().max(1e-12);
        let mut over = 0.0;
        for e in 0..counts.cols() {
            over += (counts.col_sum(e) - gate.caps.col_sum(e)).max(0.0);
        }
        over / total
    }
}

/// The penalty matrix's fixed point under the mask/budget: row-normalised
/// `1/penalty`, remote mass clipped to the compulsory budget. Shared by
/// `init` and `update_gate` so a live-migrated gate relaxes toward exactly
/// the fixed point a freshly-initialised one would.
fn attractor_of(p: usize, n: usize, gate: &GateInputs) -> Mat {
    let frac = gate.hir_remote_frac as f64;
    let mut attractor = Mat::from_fn(p, n, |i, e| 1.0 / gate.penalty.get(i, e).max(1e-12));
    for i in 0..p {
        normalise(attractor.row_mut(i));
        clip_remote(attractor.row_mut(i), gate.local_mask.row(i), frac);
    }
    attractor
}

/// Converged CE for a gate configuration: compulsory (non-learnable)
/// routing converges to a worse floor.
fn floor_of(gate: &GateInputs) -> f64 {
    let frac = gate.hir_remote_frac as f64;
    CE_FLOOR + if frac < 1.0 { COMPULSORY_HANDICAP * (1.0 - frac) } else { 0.0 }
}

/// Scale a non-negative row to sum to 1.
fn normalise(row: &mut [f64]) {
    let s: f64 = row.iter().sum();
    if s > 0.0 {
        for v in row.iter_mut() {
            *v /= s;
        }
    }
}

/// Clamp a normalised row's mass on non-local experts (`mask == 0`) to at
/// most `frac`, redistributing the surplus onto local experts.
fn clip_remote(row: &mut [f64], local_mask: &[f64], frac: f64) {
    if frac >= 1.0 {
        return;
    }
    let remote: f64 = row
        .iter()
        .zip(local_mask)
        .filter(|(_, &m)| m == 0.0)
        .map(|(v, _)| v)
        .sum();
    let local = 1.0 - remote;
    if remote > frac && local > 0.0 {
        let shrink = frac / remote;
        let grow = (1.0 - frac) / local;
        for (v, &m) in row.iter_mut().zip(local_mask) {
            *v *= if m == 0.0 { shrink } else { grow };
        }
    }
}

/// Deterministic data-dependent ripple in [-1, 1): FNV-1a over the batch
/// tokens and a salt. A pure function — no generator state — so repeated
/// eval on the same batch is bit-identical.
fn batch_ripple(tokens: &HostTensor, salt: u64) -> f64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ salt.wrapping_mul(0x0100_0000_01b3);
    if let Some(data) = tokens.as_i32() {
        for &t in data {
            h ^= t as u32 as u64;
            h = h.wrapping_mul(0x0100_0000_01b3);
        }
    }
    (h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn model_cfg(&self) -> &ModelCfg {
        &self.cfg
    }

    fn init(&mut self, seed: i32, gate: &GateInputs) -> Result<()> {
        let (p, n) = (self.cfg.p, self.cfg.n_experts);
        anyhow::ensure!(
            gate.penalty.rows() == p && gate.penalty.cols() == n,
            "penalty is {}x{}, model wants {p}x{n}",
            gate.penalty.rows(),
            gate.penalty.cols()
        );

        let frac = gate.hir_remote_frac as f64;

        // Fresh gate weights ⇒ near-uniform dispatch with seeded jitter.
        // The compulsory budget binds from step 0 (it is enforced by the
        // dispatcher, not learned), so both trajectory endpoints are
        // clipped — every convex mix between them then satisfies it too.
        let mut rng = Rng::seed_from_u64(seed as i64 as u64 ^ 0x51_4D_5F_67_41_54_45);
        let mut init_pref = Mat::from_fn(p, n, |_, _| (1.0 + 0.08 * rng.normal()).max(0.05));
        for i in 0..p {
            normalise(init_pref.row_mut(i));
            clip_remote(init_pref.row_mut(i), gate.local_mask.row(i), frac);
        }

        self.init_pref = init_pref;
        self.attractor = attractor_of(p, n, gate);
        self.gate = Some(gate.clone());
        self.step = 0;
        self.floor = floor_of(gate);
        self.ce = (self.cfg.vocab as f64).ln() + 0.02 * rng.f64();
        Ok(())
    }

    fn update_gate(&mut self, gate: &GateInputs) -> Result<()> {
        self.require_init()?;
        let (p, n) = (self.cfg.p, self.cfg.n_experts);
        anyhow::ensure!(
            gate.penalty.rows() == p && gate.penalty.cols() == n,
            "penalty is {}x{}, model wants {p}x{n}",
            gate.penalty.rows(),
            gate.penalty.cols()
        );
        // Re-point the attractor at the new penalty's fixed point under
        // the new mask/budget; training state (step, ce) is preserved —
        // the gate relaxes toward the new target from wherever it
        // currently is, exactly what a live loss-matrix swap does to the
        // compiled gate. The historic initial preference is re-clipped
        // against the new mask too: the compulsory budget is enforced by
        // the dispatcher, so BOTH trajectory endpoints must satisfy it —
        // every convex mix between them then does as well.
        let frac = gate.hir_remote_frac as f64;
        for i in 0..p {
            clip_remote(self.init_pref.row_mut(i), gate.local_mask.row(i), frac);
        }
        self.attractor = attractor_of(p, n, gate);
        self.floor = floor_of(gate);
        self.gate = Some(gate.clone());
        Ok(())
    }

    fn train_step(
        &mut self,
        tokens: &HostTensor,
        targets: &HostTensor,
        lr: f32,
    ) -> Result<StepOutputs> {
        self.require_init()?;
        let shape = [self.cfg.p, self.cfg.batch, self.cfg.seq];
        anyhow::ensure!(
            tokens.shape() == shape && targets.shape() == shape,
            "batch is {:?}/{:?}, model wants {:?}",
            tokens.shape(),
            targets.shape(),
            shape
        );

        self.step += 1;
        let rate = LR_DECAY_SCALE * lr.max(0.0) as f64;
        self.ce = self.floor + (self.ce - self.floor) * (-rate).exp();

        let freq = self.frequencies();
        let sent = (self.cfg.k * self.cfg.tokens_per_dev) as f64;
        let counts = freq.scale(sent);
        let aux = self.aux(&freq);
        let ripple = batch_ripple(tokens, self.step as u64);
        let ce = self.ce + NOISE_AMP * (self.ce - self.floor).abs() * ripple;
        let dropped = self.dropped(&counts);
        Ok(StepOutputs { loss: ce + 0.01 * aux, ce, aux, dropped, counts })
    }

    fn eval(&mut self, tokens: &HostTensor, _targets: &HostTensor) -> Result<EvalOutputs> {
        self.require_init()?;
        let ripple = batch_ripple(tokens, 0x45_56_41_4C);
        let ce = self.ce + EVAL_GAP + NOISE_AMP * (self.ce - self.floor).abs() * ripple;
        Ok(EvalOutputs { ce, counts: self.counts() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate_for(cfg: &ModelCfg, penalty: Mat, hir_remote_frac: f32) -> GateInputs {
        let (p, n) = (cfg.p, cfg.n_experts);
        // two "nodes": experts in the same half are local
        let local_mask = Mat::from_fn(p, n, |i, e| {
            if (i < p / 2) == (e < n / 2) {
                1.0
            } else {
                0.0
            }
        });
        GateInputs {
            penalty,
            caps: Mat::filled(p, n, cfg.capacity as f64 / p as f64),
            local_mask,
            hir_remote_frac,
        }
    }

    fn batch(cfg: &ModelCfg, fill: i32) -> (HostTensor, HostTensor) {
        let numel = cfg.p * cfg.batch * cfg.seq;
        let shape = [cfg.p, cfg.batch, cfg.seq];
        (
            HostTensor::i32(vec![fill; numel], &shape),
            HostTensor::i32(vec![fill; numel], &shape),
        )
    }

    #[test]
    fn uniform_penalty_keeps_dispatch_uniform() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let mut b = SimBackend::new(cfg.clone());
        let gate = gate_for(&cfg, Mat::filled(cfg.p, cfg.n_experts, cfg.n_experts as f64), 1.0);
        b.init(0, &gate).unwrap();
        let (tok, tgt) = batch(&cfg, 7);
        let mut out = None;
        for _ in 0..200 {
            out = Some(b.train_step(&tok, &tgt, 1e-3).unwrap());
        }
        let counts = out.unwrap().counts;
        let want = (cfg.k * cfg.tokens_per_dev) as f64 / cfg.n_experts as f64;
        for i in 0..cfg.p {
            for e in 0..cfg.n_experts {
                assert!((counts.get(i, e) - want).abs() < 0.05 * want, "c[{i}][{e}]");
            }
        }
    }

    #[test]
    fn skewed_penalty_attracts_dispatch() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        // heavily penalise the second half of the experts for everyone
        let penalty = Mat::from_fn(cfg.p, cfg.n_experts, |_, e| {
            if e < cfg.n_experts / 2 {
                1.0
            } else {
                100.0
            }
        });
        let mut b = SimBackend::new(cfg.clone());
        b.init(0, &gate_for(&cfg, penalty, 1.0)).unwrap();
        let (tok, tgt) = batch(&cfg, 3);
        let mut counts = None;
        for _ in 0..200 {
            counts = Some(b.train_step(&tok, &tgt, 1e-3).unwrap().counts);
        }
        let counts = counts.unwrap();
        assert!(counts.get(0, 0) > 30.0 * counts.get(0, cfg.n_experts - 1));
        // conservation survives the skew
        let want = (cfg.k * cfg.tokens_per_dev) as f64;
        for i in 0..cfg.p {
            assert!((counts.row_sum(i) - want).abs() < 1e-6 * want);
        }
    }

    #[test]
    fn loss_decays_toward_floor_and_depends_on_lr() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let gate = gate_for(&cfg, Mat::filled(cfg.p, cfg.n_experts, cfg.n_experts as f64), 1.0);
        let run = |lr: f32| {
            let mut b = SimBackend::new(cfg.clone());
            b.init(1, &gate).unwrap();
            let (tok, tgt) = batch(&cfg, 5);
            let mut last = f64::NAN;
            for _ in 0..50 {
                last = b.train_step(&tok, &tgt, lr).unwrap().ce;
            }
            last
        };
        let fast = run(5e-3);
        let slow = run(5e-4);
        assert!(fast < slow, "higher lr must reach lower ce: {fast} vs {slow}");
        assert!(fast > CE_FLOOR - 0.1);
    }

    #[test]
    fn hir_restriction_converges_worse() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let penalty = Mat::filled(cfg.p, cfg.n_experts, cfg.n_experts as f64);
        let run = |frac: f32| {
            let mut b = SimBackend::new(cfg.clone());
            b.init(2, &gate_for(&cfg, penalty.clone(), frac)).unwrap();
            let (tok, tgt) = batch(&cfg, 9);
            let mut last = f64::NAN;
            for _ in 0..400 {
                last = b.train_step(&tok, &tgt, 5e-3).unwrap().ce;
            }
            last
        };
        assert!(run(0.25) > run(1.0) + 0.1);
    }

    #[test]
    fn hir_budget_clips_remote_mass() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let penalty = Mat::filled(cfg.p, cfg.n_experts, cfg.n_experts as f64);
        let frac = 0.25f32;
        let mut b = SimBackend::new(cfg.clone());
        let gate = gate_for(&cfg, penalty, frac);
        b.init(3, &gate).unwrap();
        let (tok, tgt) = batch(&cfg, 11);
        let mut counts = None;
        for _ in 0..300 {
            counts = Some(b.train_step(&tok, &tgt, 1e-3).unwrap().counts);
        }
        let counts = counts.unwrap();
        let sent = (cfg.k * cfg.tokens_per_dev) as f64;
        for i in 0..cfg.p {
            let remote: f64 = (0..cfg.n_experts)
                .filter(|&e| gate.local_mask.get(i, e) == 0.0)
                .map(|e| counts.get(i, e))
                .sum();
            assert!(remote <= sent * frac as f64 + 1e-6, "rank {i} remote {remote}");
        }
    }

    #[test]
    fn eval_is_pure_and_deterministic() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let gate = gate_for(&cfg, Mat::filled(cfg.p, cfg.n_experts, cfg.n_experts as f64), 1.0);
        let mut b = SimBackend::new(cfg.clone());
        b.init(4, &gate).unwrap();
        let (tok, tgt) = batch(&cfg, 42);
        b.train_step(&tok, &tgt, 1e-3).unwrap();
        let a = b.eval(&tok, &tgt).unwrap();
        let c = b.eval(&tok, &tgt).unwrap();
        assert_eq!(a.ce, c.ce);
        assert_eq!(a.counts.linf_dist(&c.counts), 0.0);
        // eval ce sits above the training ce (generalisation gap)
        let train = b.train_step(&tok, &tgt, 0.0).unwrap();
        assert!(a.ce > train.ce - 0.2);
    }

    #[test]
    fn identical_seeds_identical_trajectories() {
        let cfg = ModelCfg::preset("small8_switch").unwrap();
        let gate = gate_for(&cfg, Mat::filled(cfg.p, cfg.n_experts, 8.0), 1.0);
        let run = |seed: i32| {
            let mut b = SimBackend::new(cfg.clone());
            b.init(seed, &gate).unwrap();
            let (tok, tgt) = batch(&cfg, 1);
            (0..10)
                .map(|_| b.train_step(&tok, &tgt, 1e-3).unwrap().loss)
                .collect::<Vec<f64>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn step_before_init_errors() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let mut b = SimBackend::new(cfg.clone());
        let (tok, tgt) = batch(&cfg, 0);
        assert!(b.train_step(&tok, &tgt, 1e-3).is_err());
    }

    #[test]
    fn update_gate_repoints_attractor_without_resetting_training() {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let mut b = SimBackend::new(cfg.clone());
        let n = cfg.n_experts;
        b.init(0, &gate_for(&cfg, Mat::filled(cfg.p, n, n as f64), 1.0)).unwrap();
        let (tok, tgt) = batch(&cfg, 13);
        let mut ce_before = f64::NAN;
        for _ in 0..100 {
            ce_before = b.train_step(&tok, &tgt, 2e-3).unwrap().ce;
        }
        // live-swap to a penalty that crowds the first expert
        let skew = Mat::from_fn(cfg.p, n, |_, e| if e == 0 { 1.0 } else { 50.0 });
        b.update_gate(&gate_for(&cfg, skew, 1.0)).unwrap();
        let out = b.train_step(&tok, &tgt, 2e-3).unwrap();
        // training state survived: the loss continues from where it was
        assert!(out.ce <= ce_before + 0.05, "ce jumped: {} → {}", ce_before, out.ce);
        // but the dispatch now tracks the new attractor
        assert!(out.counts.get(0, 0) > 10.0 * out.counts.get(0, n - 1));
        // update before init is an error
        let mut fresh = SimBackend::new(cfg.clone());
        let gate = gate_for(&cfg, Mat::filled(cfg.p, n, n as f64), 1.0);
        assert!(fresh.update_gate(&gate).is_err());
    }
}
