//! Artifact manifest parsing — the python↔rust ABI contract.

use crate::util::json::Json;
use crate::util::Mat;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

use super::tensor::DType;

/// One tensor in a program signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorDesc {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorDesc> {
        let name = j.req("name").map_err(anyhow::Error::msg)?
            .as_str().context("desc name")?.to_string();
        let shape = j.req("shape").map_err(anyhow::Error::msg)?
            .as_arr().context("desc shape")?
            .iter()
            .map(|v| v.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = match j.req("dtype").map_err(anyhow::Error::msg)?.as_str() {
            Some("f32") => DType::F32,
            Some("i32") => DType::I32,
            other => return Err(anyhow!("unsupported dtype {other:?}")),
        };
        Ok(TensorDesc { name, shape, dtype })
    }
}

/// Signature + file of one compiled program.
#[derive(Clone, Debug)]
pub struct ProgramDesc {
    pub file: String,
    pub inputs: Vec<TensorDesc>,
    pub outputs: Vec<TensorDesc>,
}

impl ProgramDesc {
    fn from_json(j: &Json) -> Result<ProgramDesc> {
        let descs = |key: &str| -> Result<Vec<TensorDesc>> {
            j.req(key)
                .map_err(anyhow::Error::msg)?
                .as_arr()
                .context("desc array")?
                .iter()
                .map(TensorDesc::from_json)
                .collect()
        };
        Ok(ProgramDesc {
            file: j.req("file").map_err(anyhow::Error::msg)?
                .as_str().context("file")?.to_string(),
            inputs: descs("inputs")?,
            outputs: descs("outputs")?,
        })
    }

    /// Index of a named input, if present.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|d| d.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|d| d.name == name)
    }
}

/// The model-structure block of the manifest (mirrors python configs.py).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub p: usize,
    pub e_per_dev: usize,
    pub layers: usize,
    pub d: usize,
    pub f: usize,
    pub heads: usize,
    pub vocab: usize,
    pub batch: usize,
    pub seq: usize,
    pub k: usize,
    pub cap_factor: f64,
    pub gate: String,
    pub dispatch: String,
    pub n_experts: usize,
    pub capacity: usize,
    pub tokens_per_dev: usize,
    pub moe_layer_ids: Vec<usize>,
}

impl ModelCfg {
    /// The names [`ModelCfg::preset`] knows (one per python artifact
    /// config in `python/compile/configs.py`).
    pub fn preset_names() -> Vec<&'static str> {
        vec!["tiny4", "small8_switch", "small8_gshard", "small8_hir", "wide16_switch"]
    }

    /// A built-in model shape mirroring the python artifact config of the
    /// same name, so backends that don't execute compiled programs (the
    /// simulator) run without `make artifacts`. Derived fields (capacity,
    /// MoE layer ids) follow the same formulas as `configs.py`.
    pub fn preset(name: &str) -> Option<ModelCfg> {
        #[allow(clippy::type_complexity)]
        let (p, layers, d, f, heads, batch, seq, k, cap_factor, gate, dispatch, moe_every): (
            usize, usize, usize, usize, usize, usize, usize, usize, f64, &str, &str, usize,
        ) = match name {
            "tiny4" => (4, 2, 32, 64, 2, 2, 16, 1, 1.5, "switch", "global", 1),
            "small8_switch" => (8, 4, 128, 256, 4, 2, 32, 1, 1.25, "switch", "global", 2),
            "small8_gshard" => (8, 4, 128, 256, 4, 2, 32, 2, 2.0, "gshard", "local", 2),
            "small8_hir" => (8, 4, 128, 256, 4, 2, 32, 1, 1.25, "hir", "global", 2),
            "wide16_switch" => (16, 2, 64, 128, 2, 2, 32, 1, 1.25, "switch", "global", 1),
            _ => return None,
        };
        let e_per_dev = 1;
        let n_experts = p * e_per_dev;
        let tokens_per_dev = batch * seq;
        // capacity: ceil(cap_factor·k·S·P/N), rounded up to a multiple of 8
        let raw = (cap_factor * (k * tokens_per_dev * p) as f64 / n_experts as f64).ceil();
        let capacity = (raw as usize).div_ceil(8) * 8;
        // MoE layers counted from the top so the last block is always MoE
        let moe_layer_ids =
            (0..layers).filter(|&l| (layers - 1 - l) % moe_every == 0).collect();
        Some(ModelCfg {
            p,
            e_per_dev,
            layers,
            d,
            f,
            heads,
            vocab: 256,
            batch,
            seq,
            k,
            cap_factor,
            gate: gate.into(),
            dispatch: dispatch.into(),
            n_experts,
            capacity,
            tokens_per_dev,
            moe_layer_ids,
        })
    }

    fn from_json(j: &Json) -> Result<ModelCfg> {
        let us = |k: &str| -> Result<usize> {
            j.req(k).map_err(anyhow::Error::msg)?.as_usize().context(k.to_string())
        };
        Ok(ModelCfg {
            p: us("p")?,
            e_per_dev: us("e_per_dev")?,
            layers: us("layers")?,
            d: us("d")?,
            f: us("f")?,
            heads: us("heads")?,
            vocab: us("vocab")?,
            batch: us("batch")?,
            seq: us("seq")?,
            k: us("k")?,
            cap_factor: j.req("cap_factor").map_err(anyhow::Error::msg)?
                .as_f64().context("cap_factor")?,
            gate: j.req("gate").map_err(anyhow::Error::msg)?
                .as_str().context("gate")?.to_string(),
            dispatch: j.req("dispatch").map_err(anyhow::Error::msg)?
                .as_str().context("dispatch")?.to_string(),
            n_experts: us("n_experts")?,
            capacity: us("capacity")?,
            tokens_per_dev: us("tokens_per_dev")?,
            moe_layer_ids: j.req("moe_layer_ids").map_err(anyhow::Error::msg)?
                .as_arr().context("moe_layer_ids")?
                .iter().map(|v| v.as_usize().context("layer id"))
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// Number of MoE layers in the model.
    pub fn n_moe_layers(&self) -> usize {
        self.moe_layer_ids.len()
    }

    /// Bytes of one dispatched token (f32 activations).
    pub fn token_bytes(&self) -> usize {
        self.d * 4
    }

    /// Convert a per-(device, expert) token-count matrix into a per-pair
    /// byte matrix for the comm engine (experts map to hosts by `e/E`).
    pub fn counts_to_bytes(&self, counts: &Mat) -> Mat {
        assert_eq!((counts.rows(), counts.cols()), (self.p, self.n_experts));
        Mat::from_fn(self.p, self.p, |i, j| {
            let mut tokens = 0.0;
            for le in 0..self.e_per_dev {
                tokens += counts.get(i, j * self.e_per_dev + le);
            }
            tokens * self.token_bytes() as f64
        })
    }
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub config: ModelCfg,
    pub n_param_tensors: usize,
    pub params: Vec<TensorDesc>,
    pub init: ProgramDesc,
    pub step: ProgramDesc,
    pub eval: ProgramDesc,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`?"))?;
        Self::parse(&text).with_context(|| format!("parsing {path:?}"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(anyhow::Error::msg)?;
        let params = j
            .req("params").map_err(anyhow::Error::msg)?
            .as_arr().context("params")?
            .iter()
            .map(TensorDesc::from_json)
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            name: j.req("name").map_err(anyhow::Error::msg)?
                .as_str().context("name")?.to_string(),
            config: ModelCfg::from_json(j.req("config").map_err(anyhow::Error::msg)?)?,
            n_param_tensors: j.req("n_param_tensors").map_err(anyhow::Error::msg)?
                .as_usize().context("n_param_tensors")?,
            params,
            init: ProgramDesc::from_json(j.req("init").map_err(anyhow::Error::msg)?)?,
            step: ProgramDesc::from_json(j.req("step").map_err(anyhow::Error::msg)?)?,
            eval: ProgramDesc::from_json(j.req("eval").map_err(anyhow::Error::msg)?)?,
        };
        // ABI sanity: the invariants the coordinator relies on.
        anyhow::ensure!(m.n_param_tensors == m.params.len(), "param count mismatch");
        anyhow::ensure!(
            m.step.inputs.len() == 3 * m.n_param_tensors + 8,
            "unexpected step input count"
        );
        anyhow::ensure!(
            m.step.outputs.len() == 3 * m.n_param_tensors + 6,
            "unexpected step output count"
        );
        anyhow::ensure!(m.eval.outputs.len() == 5, "unexpected eval output count");
        Ok(m)
    }

    /// Total parameter scalars (model size).
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|d| d.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "name": "t", "n_param_tensors": 1,
      "config": {"p":2,"e_per_dev":1,"layers":1,"d":4,"f":8,"heads":1,
                 "vocab":16,"batch":1,"seq":4,"k":1,"cap_factor":1.5,
                 "gate":"switch","dispatch":"global","moe_every":1,
                 "n_experts":2,"capacity":8,"tokens_per_dev":4,
                 "moe_layer_ids":[0],"name":"t"},
      "params": [{"name":"w","shape":[4,4],"dtype":"f32"}],
      "init": {"file":"init.hlo.txt",
               "inputs":[{"name":"seed","shape":[],"dtype":"i32"}],
               "outputs":[{"name":"w","shape":[4,4],"dtype":"f32"}]},
      "step": {"file":"step.hlo.txt",
               "inputs":[
                 {"name":"w","shape":[4,4],"dtype":"f32"},
                 {"name":"m.w","shape":[4,4],"dtype":"f32"},
                 {"name":"v.w","shape":[4,4],"dtype":"f32"},
                 {"name":"t","shape":[],"dtype":"f32"},
                 {"name":"lr","shape":[],"dtype":"f32"},
                 {"name":"tokens","shape":[2,1,4],"dtype":"i32"},
                 {"name":"targets","shape":[2,1,4],"dtype":"i32"},
                 {"name":"penalty","shape":[2,2],"dtype":"f32"},
                 {"name":"caps","shape":[2,2],"dtype":"f32"},
                 {"name":"local_mask","shape":[2,2],"dtype":"f32"},
                 {"name":"hir_remote_frac","shape":[],"dtype":"f32"}],
               "outputs":[
                 {"name":"w","shape":[4,4],"dtype":"f32"},
                 {"name":"m.w","shape":[4,4],"dtype":"f32"},
                 {"name":"v.w","shape":[4,4],"dtype":"f32"},
                 {"name":"t","shape":[],"dtype":"f32"},
                 {"name":"loss","shape":[],"dtype":"f32"},
                 {"name":"ce","shape":[],"dtype":"f32"},
                 {"name":"aux","shape":[],"dtype":"f32"},
                 {"name":"counts","shape":[2,2],"dtype":"f32"},
                 {"name":"dropped","shape":[],"dtype":"f32"}]},
      "eval": {"file":"eval.hlo.txt","inputs":[],
               "outputs":[
                 {"name":"loss","shape":[],"dtype":"f32"},
                 {"name":"ce","shape":[],"dtype":"f32"},
                 {"name":"aux","shape":[],"dtype":"f32"},
                 {"name":"counts","shape":[2,2],"dtype":"f32"},
                 {"name":"dropped","shape":[],"dtype":"f32"}]}
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.config.p, 2);
        assert_eq!(m.config.capacity, 8);
        assert_eq!(m.n_params(), 16);
        assert_eq!(m.step.input_index("lr"), Some(4));
        assert_eq!(m.step.output_index("counts"), Some(7));
    }

    #[test]
    fn rejects_inconsistent_step_abi() {
        let bad = MINI.replace(
            r#"{"name":"hir_remote_frac","shape":[],"dtype":"f32"}"#,
            r#"{"name":"hir_remote_frac","shape":[],"dtype":"f32"},
               {"name":"extra","shape":[],"dtype":"f32"}"#,
        );
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn counts_to_bytes_maps_experts_to_hosts() {
        let m = Manifest::parse(MINI).unwrap();
        let counts = Mat::from_vec(2, 2, vec![3.0, 1.0, 2.0, 2.0]);
        let b = m.config.counts_to_bytes(&counts);
        assert_eq!(b.get(0, 0), 3.0 * 16.0); // d=4 × 4 bytes
        assert_eq!(b.get(0, 1), 1.0 * 16.0);
    }

    #[test]
    fn presets_mirror_python_configs() {
        // spot-check the derived fields against configs.py
        let t = ModelCfg::preset("tiny4").unwrap();
        assert_eq!((t.p, t.n_experts, t.tokens_per_dev, t.capacity), (4, 4, 32, 48));
        assert_eq!(t.moe_layer_ids, vec![0, 1]);
        let s = ModelCfg::preset("small8_switch").unwrap();
        assert_eq!((s.p, s.tokens_per_dev, s.capacity), (8, 64, 80));
        assert_eq!(s.moe_layer_ids, vec![1, 3]);
        let g = ModelCfg::preset("small8_gshard").unwrap();
        assert_eq!((g.k, g.capacity, g.dispatch.as_str()), (2, 256, "local"));
        let w = ModelCfg::preset("wide16_switch").unwrap();
        assert_eq!((w.p, w.capacity), (16, 80));
        assert!(ModelCfg::preset("nope").is_none());
        for name in ModelCfg::preset_names() {
            assert!(ModelCfg::preset(name).is_some(), "{name}");
        }
    }

    #[test]
    fn loads_real_artifact_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny4");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.name, "tiny4");
            assert_eq!(m.config.p, 4);
            assert!(m.n_params() > 1000);
        }
    }
}
