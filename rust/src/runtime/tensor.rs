//! Host-side dense tensors; `xla::Literal` conversion is feature-gated.

use anyhow::{Context, Result};

/// Element types used by the model ABI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// A dense host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        HostTensor::I32(data, shape.to_vec())
    }

    /// From a crate matrix (f64 → f32).
    pub fn from_mat(m: &crate::util::Mat) -> HostTensor {
        HostTensor::F32(
            m.data().iter().map(|&v| v as f32).collect(),
            vec![m.rows(), m.cols()],
        )
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Some(d),
            _ => None,
        }
    }

    /// First element as f64 (for scalar outputs like loss).
    pub fn item(&self) -> f64 {
        match self {
            HostTensor::F32(d, _) => d[0] as f64,
            HostTensor::I32(d, _) => d[0] as f64,
        }
    }

    /// Convert to an `xla::Literal` with this tensor's shape.
    #[cfg(feature = "backend-xla")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(d, _) => xla::Literal::vec1(d),
            HostTensor::I32(d, _) => xla::Literal::vec1(d),
        };
        lit.reshape(&dims).context("reshaping literal")
    }

    /// Read a literal back into a host tensor of known shape/dtype.
    #[cfg(feature = "backend-xla")]
    pub fn from_literal(lit: &xla::Literal, shape: &[usize], dtype: DType) -> Result<HostTensor> {
        match dtype {
            DType::F32 => {
                let v = lit.to_vec::<f32>().context("literal→f32 vec")?;
                anyhow::ensure!(
                    v.len() == shape.iter().product::<usize>(),
                    "literal has {} elements, shape {:?}",
                    v.len(),
                    shape
                );
                Ok(HostTensor::F32(v, shape.to_vec()))
            }
            DType::I32 => {
                let v = lit.to_vec::<i32>().context("literal→i32 vec")?;
                anyhow::ensure!(v.len() == shape.iter().product::<usize>(), "shape mismatch");
                Ok(HostTensor::I32(v, shape.to_vec()))
            }
        }
    }

    /// View a `[rows, cols]` f32 tensor as a crate matrix.
    pub fn to_mat(&self) -> Result<crate::util::Mat> {
        let s = self.shape();
        anyhow::ensure!(s.len() == 2, "to_mat needs rank-2, got {s:?}");
        let d = self.as_f32().context("to_mat needs f32")?;
        Ok(crate::util::Mat::from_vec(
            s[0],
            s[1],
            d.iter().map(|&v| v as f64).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "backend-xla")]
    #[test]
    fn literal_round_trip_f32() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &[2, 3], DType::F32).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "backend-xla")]
    #[test]
    fn literal_round_trip_i32_scalar() {
        let t = HostTensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &[], DType::I32).unwrap();
        assert_eq!(back.item(), 42.0);
    }

    #[test]
    fn mat_round_trip() {
        let m = crate::util::Mat::from_fn(3, 2, |r, c| (r * 2 + c) as f64);
        let t = HostTensor::from_mat(&m);
        assert_eq!(t.shape(), &[3, 2]);
        let back = t.to_mat().unwrap();
        assert!(back.linf_dist(&m) < 1e-6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![1.0; 5], &[2, 3]);
    }
}
