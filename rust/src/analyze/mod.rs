//! Bottleneck attribution & what-if engine (DESIGN.md §analyze).
//!
//! PR 9's trace layer records *where time went*; this module interprets
//! it. Two instruments, surfaced together behind `--analyze`:
//!
//! * **Critical-path blame** — [`crate::coordinator::cost::step_cost_blamed`]
//!   re-prices one representative step with attribution enabled and
//!   returns per-resource seconds on the step's critical path. Unlike the
//!   busy fractions of [`crate::trace::utilization`] (which can sum to
//!   anything, because resources run in parallel), blame partitions the
//!   step clock: the fractions sum to 1. A resource with high *busy* but
//!   low *blame* is well overlapped; high blame is the thing to fix.
//! * **Counterfactual re-pricing** — a [`WhatIf`] spec family that clones
//!   the priced state, applies one perturbation through the existing
//!   seams ([`Topology::scale_link`], the per-device compute-slowdown
//!   vector, [`Topology::with_links_scaled`]), and re-prices the same
//!   step. The projection is *exactly* the clock a real run under the
//!   equivalent [`crate::perturb::ChaosSpec`] would charge (pinned by
//!   `tests/prop_analyze.rs`), so "2× this uplink buys 1.8×" is a
//!   statement about the simulator, not a heuristic.
//!
//! The decision math that ranks counterfactuals ([`rank_counterfactuals`])
//! and normalises blame ([`blame_fractions`]) is mirrored bit-exactly in
//! `python/mirrors/whatif_pricing.py` (pallas-lint mirror registry,
//! subsystem `whatif-pricing`).
//!
//! Everything here is read-only over the [`WorkloadCore`]: projections
//! price against a *clone* of the topology with the plan cache detached
//! (both the baseline and every counterfactual are priced cache-cold, so
//! the comparison is internally consistent), and a run without
//! `--analyze` never reaches this module.

use crate::coordinator::cost::{step_cost_perturbed, step_cost_profiled, StepCost};
use crate::coordinator::{step_cost_blamed, WorkloadCore};
use crate::metrics::RunLog;
use crate::topology::Topology;
use crate::util::bench::{fmt_time, Table};
use crate::util::json::Json;
use crate::util::Mat;
use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

/// One counterfactual perturbation of the priced state.
///
/// Spec grammar (round-trips through `FromStr`/`Display`):
///
/// | spelling          | meaning                                          |
/// |-------------------|--------------------------------------------------|
/// | `link:<edge>x<f>` | link `<edge>` made `<f>`× faster (α and β ÷ f)   |
/// | `dev:<i>x<f>`     | device `<i>` made `<f>`× faster                  |
/// | `alpha0`          | zero link latency, bandwidth unchanged           |
/// | `perfect-fabric`  | zero-cost links (compute-bound limit)            |
/// | `infinite-cache`  | every expert-weight fetch a hit (serving only)   |
///
/// Factors are *speedup* factors (`link:3x2` = twice as fast), the inverse
/// of the chaos grammar's slowdown multiplier: `link:3x2` here projects
/// the same clock a run under chaos `link:3x0.5@0` charges.
#[derive(Clone, Debug, PartialEq)]
pub enum WhatIf {
    /// `link:<edge>x<f>` — scale link `edge` to `f`× its speed.
    LinkScale { edge: usize, factor: f64 },
    /// `dev:<i>x<f>` — scale device `i`'s compute to `f`× its speed.
    DevScale { dev: usize, factor: f64 },
    /// `alpha0` — zero every link's latency term.
    Alpha0,
    /// `perfect-fabric` — zero every link's latency *and* byte cost.
    PerfectFabric,
    /// `infinite-cache` — expert-weight fetch time vanishes (serving).
    InfiniteCache,
}

impl WhatIf {
    /// Bounds-check the spec against a concrete fabric.
    pub fn validate(&self, p: usize, n_links: usize) -> Result<(), String> {
        match *self {
            WhatIf::LinkScale { edge, factor } => {
                if edge >= n_links {
                    return Err(format!("whatif link edge {edge} out of range (fabric has {n_links} links)"));
                }
                positive_factor(factor)
            }
            WhatIf::DevScale { dev, factor } => {
                if dev >= p {
                    return Err(format!("whatif dev {dev} out of range (fabric has {p} devices)"));
                }
                positive_factor(factor)
            }
            WhatIf::Alpha0 | WhatIf::PerfectFabric | WhatIf::InfiniteCache => Ok(()),
        }
    }
}

fn positive_factor(factor: f64) -> Result<(), String> {
    if factor > 0.0 && factor.is_finite() {
        Ok(())
    } else {
        Err(format!("whatif factor {factor} must be a positive finite speedup"))
    }
}

impl fmt::Display for WhatIf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WhatIf::LinkScale { edge, factor } => write!(f, "link:{edge}x{factor}"),
            WhatIf::DevScale { dev, factor } => write!(f, "dev:{dev}x{factor}"),
            WhatIf::Alpha0 => write!(f, "alpha0"),
            WhatIf::PerfectFabric => write!(f, "perfect-fabric"),
            WhatIf::InfiniteCache => write!(f, "infinite-cache"),
        }
    }
}

impl FromStr for WhatIf {
    type Err = String;

    fn from_str(s: &str) -> Result<WhatIf, String> {
        let s = s.trim();
        match s {
            "alpha0" => return Ok(WhatIf::Alpha0),
            "perfect-fabric" => return Ok(WhatIf::PerfectFabric),
            "infinite-cache" => return Ok(WhatIf::InfiniteCache),
            _ => {}
        }
        let parse_scaled = |body: &str, what: &str| -> Result<(usize, f64), String> {
            let (idx, factor) = body
                .split_once('x')
                .ok_or_else(|| format!("whatif {what} spec `{s}` missing `x<factor>`"))?;
            let idx: usize = idx
                .parse()
                .map_err(|_| format!("whatif {what} spec `{s}`: bad index `{idx}`"))?;
            let factor: f64 = factor
                .parse()
                .map_err(|_| format!("whatif {what} spec `{s}`: bad factor `{factor}`"))?;
            Ok((idx, factor))
        };
        if let Some(body) = s.strip_prefix("link:") {
            let (edge, factor) = parse_scaled(body, "link")?;
            positive_factor(factor)?;
            return Ok(WhatIf::LinkScale { edge, factor });
        }
        if let Some(body) = s.strip_prefix("dev:") {
            let (dev, factor) = parse_scaled(body, "dev")?;
            positive_factor(factor)?;
            return Ok(WhatIf::DevScale { dev, factor });
        }
        Err(format!(
            "unknown whatif spec `{s}` (expected link:<edge>x<f>, dev:<i>x<f>, \
             alpha0, perfect-fabric, or infinite-cache)"
        ))
    }
}

/// Parse a `+`-joined what-if list (`link:1x2+alpha0`); empty input and
/// blank segments are rejected so typos don't silently shrink the sweep.
pub fn parse_whatifs(s: &str) -> Result<Vec<WhatIf>, String> {
    let mut out = Vec::new();
    for part in s.split('+') {
        if part.trim().is_empty() {
            return Err(format!("empty segment in whatif list `{s}`"));
        }
        out.push(part.parse::<WhatIf>()?);
    }
    Ok(out)
}

/// One resource row of the blame table.
#[derive(Clone, Debug, PartialEq)]
pub struct BlameRow {
    /// The track blamed (`dev:<i>`, `link:<slot>`, `chan:<class>`).
    pub track: String,
    /// Critical-path seconds attributed to the track.
    pub blame_s: f64,
    /// `blame_s / step_s`; the rows' fractions sum to 1.
    pub blame_frac: f64,
    /// The track's busy fraction over the whole traced run, when a tracer
    /// was attached (`None` otherwise). Busy ≠ blame: a track can be busy
    /// the whole step yet never gate it.
    pub busy_frac: Option<f64>,
}

/// One counterfactual row of the what-if table.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterfactualRow {
    /// Canonical spec spelling ([`WhatIf`] `Display`).
    pub spec: String,
    /// The step clock as priced today.
    pub baseline_s: f64,
    /// The step clock under the counterfactual.
    pub projected_s: f64,
    /// `baseline_s / projected_s` (0 when the projection collapses to 0).
    pub speedup: f64,
}

/// The full analysis of one run: blame partition + ranked counterfactuals.
#[derive(Clone, Debug, PartialEq)]
pub struct BottleneckReport {
    /// The run kind the analysis rode on (`"train"` / `"serve"`).
    pub mode: String,
    /// The representative step clock the fractions are against.
    pub step_s: f64,
    /// Per-resource critical-path blame, most-blamed first.
    pub blame: Vec<BlameRow>,
    /// Counterfactual projections, best speedup first.
    pub counterfactuals: Vec<CounterfactualRow>,
}

/// Normalise raw `(track, blame_s)` rows against the step clock and sort
/// most-blamed first (ties by track name, so the report is total).
/// Mirrored bit-exactly in `python/mirrors/whatif_pricing.py`.
pub fn blame_fractions(rows: &[(String, f64)], step_s: f64) -> Vec<BlameRow> {
    let mut out: Vec<BlameRow> = rows
        .iter()
        .map(|(track, blame_s)| BlameRow {
            track: track.clone(),
            blame_s: *blame_s,
            blame_frac: if step_s > 0.0 { blame_s / step_s } else { 0.0 },
            busy_frac: None,
        })
        .collect();
    out.sort_by(|a, b| b.blame_s.total_cmp(&a.blame_s).then(a.track.cmp(&b.track)));
    out
}

/// Turn `(spec, baseline_s, projected_s)` triples into ranked rows: the
/// speedup is `baseline / projected` (0 when the projection collapses to
/// zero — "free" is reported as rank-worthless rather than infinite), and
/// rows sort by speedup descending with ties broken by spec so the
/// ranking is total. Mirrored bit-exactly in
/// `python/mirrors/whatif_pricing.py`.
pub fn rank_counterfactuals(rows: &[(String, f64, f64)]) -> Vec<CounterfactualRow> {
    let mut out: Vec<CounterfactualRow> = rows
        .iter()
        .map(|(spec, baseline_s, projected_s)| CounterfactualRow {
            spec: spec.clone(),
            baseline_s: *baseline_s,
            projected_s: *projected_s,
            speedup: if *projected_s > 0.0 { baseline_s / projected_s } else { 0.0 },
        })
        .collect();
    out.sort_by(|a, b| b.speedup.total_cmp(&a.speedup).then(a.spec.cmp(&b.spec)));
    out
}

/// The default what-if sweep when the user asks for `auto`: double the
/// top-blamed link, double the top-blamed device, and the two structural
/// limits (`alpha0`, `perfect-fabric`); serving runs add
/// `infinite-cache`. Bounded at 5 re-pricings so the analysis pass stays
/// inside the EXPERIMENTS.md ≤ 10% overhead budget.
pub fn default_whatifs(core: &WorkloadCore, blame: &[BlameRow]) -> Vec<WhatIf> {
    let topo = core.topology();
    // top-blamed link slot → its undirected edge; no link on the critical
    // path → the slowest (highest-β) edge, the natural suspect
    let edge = blame
        .iter()
        .find_map(|r| r.track.strip_prefix("link:"))
        .and_then(|slot| slot.parse::<usize>().ok())
        .map(|slot| slot / 2)
        .unwrap_or_else(|| slowest_edge(topo));
    let dev = blame
        .iter()
        .find_map(|r| r.track.strip_prefix("dev:"))
        .and_then(|d| d.parse::<usize>().ok())
        .unwrap_or(0);
    let mut out = vec![
        WhatIf::LinkScale { edge, factor: 2.0 },
        WhatIf::DevScale { dev, factor: 2.0 },
        WhatIf::Alpha0,
        WhatIf::PerfectFabric,
    ];
    if core.profile().is_forward_only() {
        out.push(WhatIf::InfiniteCache);
    }
    out
}

/// The highest-β (slowest-bandwidth) edge; 0 on a linkless fabric.
fn slowest_edge(topo: &Topology) -> usize {
    let mut best = 0usize;
    let mut best_beta = f64::NEG_INFINITY;
    for (e, l) in topo.links().iter().enumerate() {
        if l.beta > best_beta {
            best_beta = l.beta;
            best = e;
        }
    }
    best
}

/// Price one step of `core`'s workload on a (possibly perturbed) fabric,
/// cache-cold: the same path as [`step_cost_blamed`]'s baseline, so
/// baseline and projection differ *only* by the counterfactual.
fn price(
    core: &WorkloadCore,
    topo: &Topology,
    counts: &Mat,
    slowdown: Option<&[f64]>,
) -> StepCost {
    match slowdown {
        Some(s) => step_cost_perturbed(
            core.shape(),
            topo,
            counts,
            core.e_per_dev(),
            core.flops_per_dev(),
            core.a2a_algo(),
            core.overlap_mode(),
            core.profile(),
            None,
            core.placement(),
            s,
        ),
        None => step_cost_profiled(
            core.shape(),
            topo,
            counts,
            core.e_per_dev(),
            core.flops_per_dev(),
            core.a2a_algo(),
            core.overlap_mode(),
            core.profile(),
            None,
            core.placement(),
        ),
    }
}

/// Project the step clock under one counterfactual.
fn project(core: &WorkloadCore, counts: &Mat, baseline: &StepCost, log: &RunLog, w: &WhatIf) -> f64 {
    match *w {
        WhatIf::LinkScale { edge, factor } => {
            // the chaos grammar's factor is a slowdown multiplier; a
            // speedup of f is the equivalent chaos `link:<edge>x<1/f>`
            let mut topo = core.topology().clone();
            topo.scale_link(edge, 1.0 / factor);
            price(core, &topo, counts, core.slowdown()).step_s()
        }
        WhatIf::DevScale { dev, factor } => {
            let mut s = core
                .slowdown()
                .map(|s| s.to_vec())
                .unwrap_or_else(|| vec![1.0; core.topology().p()]);
            if let Some(slot) = s.get_mut(dev) {
                *slot /= factor;
            }
            price(core, core.topology(), counts, Some(&s)).step_s()
        }
        WhatIf::Alpha0 => {
            let topo = core.topology().with_links_scaled(0.0, 1.0);
            price(core, &topo, counts, core.slowdown()).step_s()
        }
        WhatIf::PerfectFabric => {
            let topo = core.topology().with_links_scaled(0.0, 0.0);
            price(core, &topo, counts, core.slowdown()).step_s()
        }
        WhatIf::InfiniteCache => {
            // fetch time is charged outside the priced step, so project
            // from the run log: the fetch share of the simulated clock
            let fetch: f64 = log.records.iter().map(|r| r.sim_fetch_s).sum();
            let total: f64 = log.records.iter().map(|r| r.sim_total_s()).sum();
            let frac = if total > 0.0 { fetch / total } else { 0.0 };
            baseline.step_s() * (1.0 - frac)
        }
    }
}

/// Run the full analysis over one workload: blame the baseline step, then
/// re-price it under every requested counterfactual. `counts` is the
/// representative step's dispatch matrix (the last priced step of the
/// run), `log` the accumulated run log (consulted only by
/// `infinite-cache`), `whatifs` the sweep to price (`None` =
/// [`default_whatifs`] derived from the blame table), `mode_label`
/// `"train"` or `"serve"`.
pub fn analyze_workload(
    core: &WorkloadCore,
    counts: &Mat,
    log: &RunLog,
    whatifs: Option<&[WhatIf]>,
    mode_label: &str,
) -> Result<BottleneckReport, String> {
    let topo = core.topology();
    let (baseline, raw_blame) = step_cost_blamed(
        core.shape(),
        topo,
        counts,
        core.e_per_dev(),
        core.flops_per_dev(),
        core.a2a_algo(),
        core.overlap_mode(),
        core.profile(),
        None,
        core.placement(),
        core.slowdown(),
    );
    let mut blame = blame_fractions(&raw_blame, baseline.step_s());
    // fold the traced busy fractions in beside blame when a tracer rode
    // the run — busy vs blame side by side is the report's whole point
    if let Some(tr) = core.tracer() {
        let clock = tr.clock_s();
        if clock > 0.0 {
            let busy: &BTreeMap<String, f64> = tr.timeline_busy();
            for row in &mut blame {
                row.busy_frac = busy.get(&row.track).map(|b| b / clock);
            }
        }
    }
    let whatifs: Vec<WhatIf> = match whatifs {
        Some(ws) => ws.to_vec(),
        None => default_whatifs(core, &blame),
    };
    for w in &whatifs {
        w.validate(topo.p(), topo.links().len())?;
    }
    let triples: Vec<(String, f64, f64)> = whatifs
        .iter()
        .map(|w| (w.to_string(), baseline.step_s(), project(core, counts, &baseline, log, w)))
        .collect();
    Ok(BottleneckReport {
        mode: mode_label.to_string(),
        step_s: baseline.step_s(),
        blame,
        counterfactuals: rank_counterfactuals(&triples),
    })
}

impl BottleneckReport {
    /// The report as the `<path>.bottleneck.json` document (and the
    /// `analyze` subobject of summary JSON).
    pub fn to_json(&self) -> Json {
        let blame: Vec<Json> = self
            .blame
            .iter()
            .map(|r| {
                let mut row = BTreeMap::new();
                row.insert("track".to_string(), Json::Str(r.track.clone()));
                row.insert("blame_s".to_string(), Json::Num(r.blame_s));
                row.insert("blame_frac".to_string(), Json::Num(r.blame_frac));
                if let Some(b) = r.busy_frac {
                    row.insert("busy_frac".to_string(), Json::Num(b));
                }
                Json::Obj(row)
            })
            .collect();
        let cf: Vec<Json> = self
            .counterfactuals
            .iter()
            .map(|r| {
                let mut row = BTreeMap::new();
                row.insert("spec".to_string(), Json::Str(r.spec.clone()));
                row.insert("baseline_s".to_string(), Json::Num(r.baseline_s));
                row.insert("projected_s".to_string(), Json::Num(r.projected_s));
                row.insert("speedup".to_string(), Json::Num(r.speedup));
                Json::Obj(row)
            })
            .collect();
        let mut obj = BTreeMap::new();
        obj.insert("mode".to_string(), Json::Str(self.mode.clone()));
        obj.insert("step_s".to_string(), Json::Num(self.step_s));
        obj.insert("blame".to_string(), Json::Arr(blame));
        obj.insert("counterfactuals".to_string(), Json::Arr(cf));
        Json::Obj(obj)
    }

    /// Print the ranked human-readable tables to stdout.
    pub fn print_tables(&self) {
        println!("bottleneck blame ({} step, {}):", self.mode, fmt_time(self.step_s));
        let mut t = Table::new(&["resource", "blame", "blame_frac", "busy_frac"]);
        for r in &self.blame {
            t.row(&[
                r.track.clone(),
                fmt_time(r.blame_s),
                format!("{:.4}", r.blame_frac),
                match r.busy_frac {
                    Some(b) => format!("{b:.4}"),
                    None => "-".to_string(),
                },
            ]);
        }
        t.print();
        println!("what-if projections:");
        let mut t = Table::new(&["what-if", "baseline", "projected", "speedup"]);
        for r in &self.counterfactuals {
            t.row(&[
                r.spec.clone(),
                fmt_time(r.baseline_s),
                fmt_time(r.projected_s),
                format!("{:.3}x", r.speedup),
            ]);
        }
        t.print();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whatif_specs_round_trip() {
        for s in ["link:3x2", "dev:1x4", "link:0x1.5", "alpha0", "perfect-fabric", "infinite-cache"]
        {
            let w: WhatIf = s.parse().unwrap();
            assert_eq!(w.to_string(), s, "round trip of `{s}`");
        }
    }

    #[test]
    fn whatif_rejects_malformed_specs() {
        for s in ["link:3", "dev:x2", "link:ax2", "dev:1x0", "link:1x-2", "turbo", "", "link:1xinf"]
        {
            assert!(s.parse::<WhatIf>().is_err(), "`{s}` should not parse");
        }
    }

    #[test]
    fn whatif_list_parses_and_rejects_blanks() {
        let ws = parse_whatifs("link:1x2+alpha0+dev:0x2").unwrap();
        assert_eq!(ws.len(), 3);
        assert_eq!(ws[0], WhatIf::LinkScale { edge: 1, factor: 2.0 });
        assert!(parse_whatifs("link:1x2++alpha0").is_err());
        assert!(parse_whatifs("").is_err());
    }

    #[test]
    fn whatif_validate_bounds_checks() {
        assert!(WhatIf::LinkScale { edge: 2, factor: 2.0 }.validate(4, 3).is_ok());
        assert!(WhatIf::LinkScale { edge: 3, factor: 2.0 }.validate(4, 3).is_err());
        assert!(WhatIf::DevScale { dev: 4, factor: 2.0 }.validate(4, 3).is_err());
        assert!(WhatIf::Alpha0.validate(0, 0).is_ok());
    }

    #[test]
    fn rank_orders_by_speedup_then_spec() {
        let rows = vec![
            ("alpha0".to_string(), 10.0, 5.0),
            ("link:1x2".to_string(), 10.0, 4.0),
            ("dev:0x2".to_string(), 10.0, 5.0),
            ("perfect-fabric".to_string(), 10.0, 0.0),
        ];
        let ranked = rank_counterfactuals(&rows);
        let specs: Vec<&str> = ranked.iter().map(|r| r.spec.as_str()).collect();
        // 2.5x first; the two 2.0x ties resolve alphabetically; the
        // zero-projection row ranks last with speedup 0, not inf
        assert_eq!(specs, vec!["link:1x2", "alpha0", "dev:0x2", "perfect-fabric"]);
        assert_eq!(ranked[0].speedup, 2.5);
        assert_eq!(ranked[3].speedup, 0.0);
    }

    #[test]
    fn blame_fractions_normalise_and_sort() {
        let rows = vec![
            ("dev:0".to_string(), 1.0),
            ("link:3".to_string(), 6.0),
            ("chan:allreduce".to_string(), 1.0),
        ];
        let blame = blame_fractions(&rows, 8.0);
        assert_eq!(blame[0].track, "link:3");
        assert_eq!(blame[0].blame_frac, 0.75);
        // ties by track name
        assert_eq!(blame[1].track, "chan:allreduce");
        assert_eq!(blame[2].track, "dev:0");
        let sum: f64 = blame.iter().map(|r| r.blame_frac).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        // zero clock: fractions 0, never NaN
        assert!(blame_fractions(&rows, 0.0).iter().all(|r| r.blame_frac == 0.0));
    }

    #[test]
    fn report_json_carries_rows_and_skips_absent_busy() {
        let rep = BottleneckReport {
            mode: "train".to_string(),
            step_s: 2.0,
            blame: vec![BlameRow {
                track: "dev:0".to_string(),
                blame_s: 2.0,
                blame_frac: 1.0,
                busy_frac: None,
            }],
            counterfactuals: rank_counterfactuals(&[("alpha0".to_string(), 2.0, 1.0)]),
        };
        let j = rep.to_json();
        assert_eq!(j.req("mode").unwrap().as_str(), Some("train"));
        let b0 = &j.req("blame").unwrap().as_arr().unwrap()[0];
        assert_eq!(b0.req("blame_frac").unwrap().as_f64(), Some(1.0));
        assert!(b0.get("busy_frac").is_none());
        let c0 = &j.req("counterfactuals").unwrap().as_arr().unwrap()[0];
        assert_eq!(c0.req("speedup").unwrap().as_f64(), Some(2.0));
    }
}
