//! Experiment configuration: TOML files + cluster presets.
//!
//! A config names an artifact, a cluster topology, a strategy, and the
//! training-loop parameters. Everything has a default, so `ta-moe train`
//! works with no file at all; `--config configs/fig3.toml` reproduces a
//! specific experiment. See `configs/*.toml` for the checked-in presets.

use crate::comm::A2aAlgo;
use crate::coordinator::{parse_policy, DispatchPolicy};
use crate::overlap::OverlapMode;
use crate::placement::PlacementConfig;
use crate::runtime::BackendKind;
use crate::topology::{presets, Topology};
use crate::trace::TraceLevel;
use crate::util::toml::TomlDoc;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Fully-resolved experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Artifact directory name under `artifacts/` (a python config name).
    pub artifact: String,
    /// Artifact root.
    pub artifacts_dir: PathBuf,
    /// Cluster preset: "A" | "B" | "C" | "table1".
    pub cluster: String,
    /// Nodes in the cluster (devices = nodes × 8 for A/B/C presets).
    pub nodes: usize,
    /// Dispatch-policy spec (see [`parse_policy`]).
    pub strategy: String,
    /// All-to-all plan: "auto" (the policy's preference) or an
    /// [`A2aAlgo`] spec (`direct | hier | sched:xor | sched:rot |
    /// sched:bvn`).
    pub a2a: String,
    /// Expert placement: "off" (canonical hosting), "on" (default
    /// cadence), or an integer attempt cadence in steps.
    pub placement: String,
    /// Step-clock overlap: "off"/"serial" (the serial phase sum),
    /// "k=<n>" (fixed chunk count), or "auto" (chunk-count autotuner).
    pub overlap: String,
    /// Execution backend: "sim" | "xla" | "auto".
    pub backend: String,
    /// Scripted fault stream: "off", or `+`-joined chaos events
    /// (`straggler:… | link:… | nodeloss:… | drift:…`; see
    /// [`crate::perturb::ChaosSpec`]). Applies to train and serve alike.
    pub chaos: String,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub eval_every: usize,
    pub log_every: usize,
    /// Output directory for CSV/JSON logs.
    pub out_dir: PathBuf,
    /// Use the synthetic Zipf corpus (true) or the builtin text (false).
    pub synthetic_data: bool,
    /// Serving-mode knobs (`ta-moe serve`; ignored by training).
    pub serve: ServeConfig,
    /// Tracing knobs (`--trace` / `--trace-level`; see [`crate::trace`]).
    pub trace: TraceSection,
    /// Bottleneck-analysis knobs (`--analyze`; see [`crate::analyze`]).
    pub analyze: AnalyzeSection,
}

/// The `[analyze]` section: where the bottleneck report goes and which
/// counterfactuals to price. `path = "off"` (the default) runs no
/// analysis at all — the run stays byte-identical to one on a build
/// without the analyze layer.
#[derive(Clone, Debug, PartialEq)]
pub struct AnalyzeSection {
    /// Output stem for `<path>.bottleneck.json`, or "off".
    pub path: String,
    /// What-if sweep: "auto" (the default sweep derived from the blame
    /// table) or a `+`-joined [`crate::analyze::WhatIf`] list
    /// (`link:<edge>x<f> | dev:<i>x<f> | alpha0 | perfect-fabric |
    /// infinite-cache`).
    pub whatifs: String,
}

impl Default for AnalyzeSection {
    fn default() -> Self {
        AnalyzeSection { path: "off".into(), whatifs: "auto".into() }
    }
}

impl AnalyzeSection {
    /// Whether the section turns analysis on at all.
    pub fn enabled(&self) -> bool {
        !self.path.trim().is_empty() && self.path.trim() != "off"
    }

    /// Resolve the what-if sweep: `None` means "auto" (derive the sweep
    /// from the blame table at analysis time).
    pub fn parsed_whatifs(&self) -> Result<Option<Vec<crate::analyze::WhatIf>>> {
        match self.whatifs.trim() {
            "" | "auto" => Ok(None),
            spec => crate::analyze::parse_whatifs(spec)
                .map(Some)
                .map_err(anyhow::Error::msg),
        }
    }
}

/// The `[trace]` section: where the Chrome trace goes and how much it
/// records. `path = "off"` (the default) attaches no tracer at all — the
/// run stays byte-identical to one on a build without the trace layer.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSection {
    /// Output path for the Chrome-trace JSON, or "off".
    pub path: String,
    /// Detail: "step" | "phase" | "chunk" (each includes the previous).
    pub level: String,
}

impl Default for TraceSection {
    fn default() -> Self {
        TraceSection { path: "off".into(), level: "chunk".into() }
    }
}

impl TraceSection {
    /// Resolve the section: `None` when tracing is off, else the level to
    /// attach (path validity is the writer's problem, not the parser's).
    pub fn parsed_level(&self) -> Result<Option<TraceLevel>> {
        if self.path.trim().is_empty() || self.path.trim() == "off" {
            return Ok(None);
        }
        self.level.parse::<TraceLevel>().map(Some).map_err(anyhow::Error::msg)
    }
}

/// The `[serve]` section: arrival trace + expert cache + SLO knobs for
/// the continuous-batching serving simulator (see [`crate::serve`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Arrival process: "poisson" | "bursty" | "diurnal".
    pub trace: String,
    /// Mean arrival rate, requests/second.
    pub rate_rps: f64,
    /// Requests in the trace.
    pub requests: usize,
    /// Mean prompt / output lengths in tokens.
    pub prompt_mean: usize,
    pub output_mean: usize,
    /// Resident experts per device (0 = unlimited, caching off).
    pub cache_cap: usize,
    /// Eviction policy: "lru" | "ewma".
    pub cache: String,
    /// TTFT deadline for goodput, seconds.
    pub slo_s: f64,
    /// Concurrent sequences per device (KV-cache slots).
    pub max_inflight: usize,
    /// Experts hosted per device (0 = keep the artifact's value).
    pub experts_per_dev: usize,
    /// Zipf exponent of the expert-popularity tilt.
    pub zipf: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            trace: "poisson".into(),
            rate_rps: 8.0,
            requests: 64,
            prompt_mean: 32,
            output_mean: 16,
            cache_cap: 0,
            cache: "lru".into(),
            slo_s: 0.2,
            max_inflight: 8,
            experts_per_dev: 0,
            zipf: 1.0,
        }
    }
}

impl ServeConfig {
    /// Resolve the trace spec.
    pub fn parsed_trace(&self) -> Result<crate::serve::TraceKind> {
        self.trace.parse().map_err(anyhow::Error::msg)
    }

    /// Resolve the cache-policy spec.
    pub fn parsed_cache(&self) -> Result<crate::serve::CachePolicy> {
        self.cache.parse().map_err(anyhow::Error::msg)
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            artifact: "small8_switch".into(),
            artifacts_dir: "artifacts".into(),
            cluster: "C".into(),
            nodes: 0, // 0 = derive from the artifact's world size
            strategy: "ta-moe".into(),
            a2a: "auto".into(),
            placement: "off".into(),
            overlap: "off".into(),
            backend: "auto".into(),
            chaos: "off".into(),
            steps: 100,
            lr: 1e-3,
            seed: 0,
            eval_every: 20,
            log_every: 10,
            out_dir: "target/runs".into(),
            synthetic_data: true,
            serve: ServeConfig::default(),
            trace: TraceSection::default(),
            analyze: AnalyzeSection::default(),
        }
    }
}

impl ExperimentConfig {
    /// Load a TOML config, falling back to defaults for missing keys.
    pub fn from_toml_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_toml(&text).with_context(|| format!("parsing config {path:?}"))
    }

    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = TomlDoc::parse(text).map_err(anyhow::Error::msg)?;
        let d = ExperimentConfig::default();
        Ok(ExperimentConfig {
            artifact: doc.str_or("model.artifact", &d.artifact).to_string(),
            artifacts_dir: doc.str_or("model.artifacts_dir", "artifacts").into(),
            cluster: doc.str_or("cluster.preset", &d.cluster).to_string(),
            nodes: doc.usize_or("cluster.nodes", d.nodes),
            strategy: doc.str_or("train.strategy", &d.strategy).to_string(),
            a2a: doc.str_or("train.a2a", &d.a2a).to_string(),
            // the spec is string-valued ("off" | "on" | "<n>") but the
            // cadence form reads naturally as a bare TOML integer —
            // accept both spellings
            placement: match doc.get("train.placement") {
                Some(v) => v
                    .as_str()
                    .map(str::to_string)
                    .or_else(|| v.as_usize().map(|n| n.to_string()))
                    .unwrap_or_else(|| d.placement.clone()),
                None => d.placement.clone(),
            },
            overlap: doc.str_or("train.overlap", &d.overlap).to_string(),
            backend: doc.str_or("train.backend", &d.backend).to_string(),
            chaos: doc.str_or("chaos.spec", &d.chaos).to_string(),
            steps: doc.usize_or("train.steps", d.steps),
            lr: doc.f64_or("train.lr", d.lr),
            seed: doc.usize_or("train.seed", d.seed as usize) as u64,
            eval_every: doc.usize_or("train.eval_every", d.eval_every),
            log_every: doc.usize_or("train.log_every", d.log_every),
            out_dir: doc.str_or("out.dir", "target/runs").into(),
            synthetic_data: doc.bool_or("train.synthetic_data", d.synthetic_data),
            serve: ServeConfig {
                trace: doc.str_or("serve.trace", &d.serve.trace).to_string(),
                rate_rps: doc.f64_or("serve.rate_rps", d.serve.rate_rps),
                requests: doc.usize_or("serve.requests", d.serve.requests),
                prompt_mean: doc.usize_or("serve.prompt_mean", d.serve.prompt_mean),
                output_mean: doc.usize_or("serve.output_mean", d.serve.output_mean),
                cache_cap: doc.usize_or("serve.cache_cap", d.serve.cache_cap),
                cache: doc.str_or("serve.cache", &d.serve.cache).to_string(),
                slo_s: doc.f64_or("serve.slo_s", d.serve.slo_s),
                max_inflight: doc.usize_or("serve.max_inflight", d.serve.max_inflight),
                experts_per_dev: doc
                    .usize_or("serve.experts_per_dev", d.serve.experts_per_dev),
                zipf: doc.f64_or("serve.zipf", d.serve.zipf),
            },
            trace: TraceSection {
                path: doc.str_or("trace.path", &d.trace.path).to_string(),
                level: doc.str_or("trace.level", &d.trace.level).to_string(),
            },
            analyze: AnalyzeSection {
                path: doc.str_or("analyze.path", &d.analyze.path).to_string(),
                whatifs: doc.str_or("analyze.whatifs", &d.analyze.whatifs).to_string(),
            },
        })
    }

    /// World size of the named artifact: from its manifest when compiled,
    /// else from the built-in preset of the same name (the same resolution
    /// [`crate::runtime::open_backend`] uses).
    pub fn artifact_world(&self) -> Result<usize> {
        Ok(crate::runtime::resolve_model_cfg(&self.artifacts_dir, &self.artifact)?.p)
    }

    /// Build the topology for this config, sized to the artifact's world.
    pub fn topology(&self) -> Result<Topology> {
        let p = self.artifact_world()?;
        Ok(topology_for(&self.cluster, p))
    }

    /// Resolve the policy spec through the registry.
    pub fn parsed_policy(&self) -> Result<Box<dyn DispatchPolicy>> {
        parse_policy(&self.strategy).map_err(anyhow::Error::msg)
    }

    /// Resolve the a2a spec: `None` means "auto" (defer to the policy's
    /// [`crate::coordinator::DispatchPolicy::preferred_a2a`]).
    pub fn parsed_a2a(&self) -> Result<Option<A2aAlgo>> {
        match self.a2a.trim() {
            "" | "auto" => Ok(None),
            spec => spec
                .parse::<A2aAlgo>()
                .map(Some)
                .map_err(anyhow::Error::msg),
        }
    }

    /// Resolve the backend selector.
    pub fn parsed_backend(&self) -> Result<BackendKind> {
        self.backend.parse().map_err(anyhow::Error::msg)
    }

    /// Resolve the placement spec: `None` means canonical hosting.
    pub fn parsed_placement(&self) -> Result<Option<PlacementConfig>> {
        PlacementConfig::parse_spec(&self.placement).map_err(anyhow::Error::msg)
    }

    /// Resolve the overlap spec (`off | serial | k=<n> | auto`).
    pub fn parsed_overlap(&self) -> Result<OverlapMode> {
        self.overlap.parse().map_err(anyhow::Error::msg)
    }

    /// Resolve the fault-stream spec (`off`, or `+`-joined chaos events).
    pub fn parsed_chaos(&self) -> Result<crate::perturb::ChaosSpec> {
        self.chaos.parse().map_err(anyhow::Error::msg)
    }
}

/// A cluster preset scaled (gpus-per-node shrunk if needed) to exactly `p`
/// devices. For the CPU-sized artifacts (p = 4..16) we keep the paper's
/// *structure* (nodes + uplinks) with fewer devices per node.
pub fn topology_for(cluster: &str, p: usize) -> Topology {
    use crate::topology::{Link, TreeSpec};
    if cluster.eq_ignore_ascii_case("table1") {
        return presets::table1();
    }
    // paper-scale: multiples of 8 with ≥2 nodes map onto the presets;
    // smaller worlds (the CPU-sized artifacts) use the scaled-down path so
    // they still exercise multi-node links — topology is the whole point.
    if p % 8 == 0 && p >= 16 {
        if let Some(t) = presets::by_name(cluster, p / 8) {
            return t;
        }
    }
    // scaled-down: 2 devices per node, same link hierarchy as the preset
    let nodes = (p / 2).max(1);
    let (dev, up, spine, symmetric) = match cluster.to_ascii_uppercase().as_str() {
        "A" => (
            Link::from_gbps_us(235.0, 2.0),
            Link::from_gbps_us(25.0, 10.0),
            Link::from_gbps_us(20.0, 15.0),
            false,
        ),
        "B" => (
            Link::from_gbps_us(45.0, 2.0),
            Link::from_gbps_us(12.5, 15.0),
            Link::from_gbps_us(12.5, 15.0),
            true,
        ),
        _ => (
            Link::from_gbps_us(45.0, 2.0),
            Link::from_gbps_us(12.5, 15.0),
            Link::from_gbps_us(8.0, 25.0),
            false,
        ),
    };
    let per_node = p / nodes;
    let spec = if nodes == 1 {
        TreeSpec::Devices(p)
    } else if symmetric || nodes == 2 {
        TreeSpec::Switch((0..nodes).map(|_| TreeSpec::Devices(per_node)).collect())
    } else {
        let pod = nodes / 2;
        let mut children = vec![TreeSpec::Switch(
            (0..pod).map(|_| TreeSpec::Devices(per_node)).collect(),
        )];
        for _ in pod..nodes {
            children.push(TreeSpec::Switch(vec![TreeSpec::Devices(per_node)]));
        }
        TreeSpec::Switch(children)
    };
    Topology::tree(&spec, &[dev, up, spine], presets::local_copy())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ExperimentConfig::default();
        assert_eq!(c.artifact, "small8_switch");
        assert!(c.steps > 0);
    }

    #[test]
    fn toml_overrides_defaults() {
        let c = ExperimentConfig::from_toml(
            r#"
[model]
artifact = "tiny4"

[cluster]
preset = "B"
nodes = 2

[train]
strategy = "fastmoe"
steps = 7
lr = 0.01
"#,
        )
        .unwrap();
        assert_eq!(c.artifact, "tiny4");
        assert_eq!(c.cluster, "B");
        assert_eq!(c.steps, 7);
        assert!((c.lr - 0.01).abs() < 1e-12);
        assert_eq!(c.strategy, "fastmoe");
        // default survives
        assert_eq!(c.eval_every, 20);
    }

    #[test]
    fn scaled_topology_has_requested_world() {
        for p in [4, 8, 16] {
            for cl in ["A", "B", "C"] {
                let t = topology_for(cl, p);
                assert_eq!(t.p(), p, "{cl} {p}");
            }
        }
    }

    #[test]
    fn paper_scale_uses_presets() {
        let t = topology_for("C", 32);
        assert_eq!(t.p(), 32);
        assert_eq!(t.n_nodes(), 4);
    }

    #[test]
    fn scaled_c_is_multinode_with_slow_spine() {
        let t = topology_for("C", 8); // 4 nodes × 2
        assert_eq!(t.n_nodes(), 4);
        assert!(t.beta(0, 7) > t.beta(0, 1));
    }

    #[test]
    fn a2a_defaults_to_auto_and_parses() {
        let c = ExperimentConfig::default();
        assert_eq!(c.a2a, "auto");
        assert!(c.parsed_a2a().unwrap().is_none());
        let c = ExperimentConfig::from_toml("[train]\na2a = \"sched:bvn\"\n").unwrap();
        assert_eq!(
            c.parsed_a2a().unwrap(),
            Some(A2aAlgo::Scheduled(crate::comm::ScheduleKind::Bvn))
        );
        let c = ExperimentConfig { a2a: "sched:diagonal".into(), ..Default::default() };
        assert!(c.parsed_a2a().is_err());
    }

    #[test]
    fn placement_defaults_to_off_and_parses() {
        let c = ExperimentConfig::default();
        assert_eq!(c.placement, "off");
        assert!(c.parsed_placement().unwrap().is_none());
        let c = ExperimentConfig::from_toml("[train]\nplacement = \"on\"\n").unwrap();
        assert_eq!(c.parsed_placement().unwrap(), Some(PlacementConfig::default()));
        let c = ExperimentConfig::from_toml("[train]\nplacement = \"12\"\n").unwrap();
        assert_eq!(c.parsed_placement().unwrap().unwrap().every, 12);
        // a bare integer cadence must work too, not silently fall to off
        let c = ExperimentConfig::from_toml("[train]\nplacement = 12\n").unwrap();
        assert_eq!(c.parsed_placement().unwrap().unwrap().every, 12);
        let c = ExperimentConfig { placement: "maybe".into(), ..Default::default() };
        assert!(c.parsed_placement().is_err());
    }

    #[test]
    fn overlap_defaults_to_off_and_parses() {
        let c = ExperimentConfig::default();
        assert_eq!(c.overlap, "off");
        assert_eq!(c.parsed_overlap().unwrap(), OverlapMode::Serial);
        let c = ExperimentConfig::from_toml("[train]\noverlap = \"auto\"\n").unwrap();
        assert_eq!(c.parsed_overlap().unwrap(), OverlapMode::Auto);
        let c = ExperimentConfig::from_toml("[train]\noverlap = \"k=8\"\n").unwrap();
        assert_eq!(c.parsed_overlap().unwrap(), OverlapMode::Fixed(8));
        let c = ExperimentConfig { overlap: "chunked".into(), ..Default::default() };
        assert!(c.parsed_overlap().is_err());
    }

    #[test]
    fn chaos_defaults_to_off_and_parses() {
        let c = ExperimentConfig::default();
        assert_eq!(c.chaos, "off");
        assert!(c.parsed_chaos().unwrap().is_off());
        let c = ExperimentConfig::from_toml(
            "[chaos]\nspec = \"straggler:0x2@10-20+nodeloss:3@40\"\n",
        )
        .unwrap();
        let spec = c.parsed_chaos().unwrap();
        assert!(!spec.is_off());
        assert_eq!(spec.to_string(), "straggler:0x2@10-20+nodeloss:3@40");
        let c = ExperimentConfig { chaos: "meteor:9@1".into(), ..Default::default() };
        assert!(c.parsed_chaos().is_err());
    }

    #[test]
    fn trace_defaults_to_off_and_parses() {
        let c = ExperimentConfig::default();
        assert_eq!(c.trace, TraceSection::default());
        assert!(c.trace.parsed_level().unwrap().is_none());
        let c = ExperimentConfig::from_toml(
            "[trace]\npath = \"target/run.trace.json\"\nlevel = \"phase\"\n",
        )
        .unwrap();
        assert_eq!(c.trace.path, "target/run.trace.json");
        assert_eq!(c.trace.parsed_level().unwrap(), Some(TraceLevel::Phase));
        // path without a level falls back to the default (chunk)
        let c = ExperimentConfig::from_toml("[trace]\npath = \"t.json\"\n").unwrap();
        assert_eq!(c.trace.parsed_level().unwrap(), Some(TraceLevel::Chunk));
        let bad = TraceSection { path: "t.json".into(), level: "verbose".into() };
        assert!(bad.parsed_level().is_err());
    }

    #[test]
    fn analyze_defaults_to_off_and_parses() {
        let c = ExperimentConfig::default();
        assert_eq!(c.analyze, AnalyzeSection::default());
        assert!(!c.analyze.enabled());
        assert!(c.analyze.parsed_whatifs().unwrap().is_none());
        let c = ExperimentConfig::from_toml(
            "[analyze]\npath = \"target/run\"\nwhatifs = \"link:1x2+alpha0\"\n",
        )
        .unwrap();
        assert!(c.analyze.enabled());
        let ws = c.analyze.parsed_whatifs().unwrap().unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].to_string(), "link:1x2");
        let bad = AnalyzeSection { path: "t".into(), whatifs: "turbo".into() };
        assert!(bad.parsed_whatifs().is_err());
    }

    #[test]
    fn bad_strategy_rejected() {
        let mut c = ExperimentConfig::default();
        c.strategy = "bogus".into();
        assert!(c.parsed_policy().is_err());
    }

    #[test]
    fn backend_defaults_to_auto_and_parses() {
        let c = ExperimentConfig::default();
        assert_eq!(c.parsed_backend().unwrap(), crate::runtime::BackendKind::Auto);
        let c = ExperimentConfig::from_toml("[train]\nbackend = \"sim\"\n").unwrap();
        assert_eq!(c.parsed_backend().unwrap(), crate::runtime::BackendKind::Sim);
        let mut c = ExperimentConfig::default();
        c.backend = "gpu".into();
        assert!(c.parsed_backend().is_err());
    }

    #[test]
    fn serve_section_defaults_and_overrides() {
        let c = ExperimentConfig::default();
        assert_eq!(c.serve, ServeConfig::default());
        assert_eq!(c.serve.parsed_trace().unwrap(), crate::serve::TraceKind::Poisson);
        assert_eq!(c.serve.parsed_cache().unwrap(), crate::serve::CachePolicy::Lru);
        let c = ExperimentConfig::from_toml(
            r#"
[serve]
trace = "bursty"
rate_rps = 12.5
requests = 128
cache_cap = 2
cache = "ewma"
slo_s = 0.15
max_inflight = 4
experts_per_dev = 4
zipf = 0.5
"#,
        )
        .unwrap();
        assert_eq!(c.serve.parsed_trace().unwrap(), crate::serve::TraceKind::Bursty);
        assert_eq!(
            c.serve.parsed_cache().unwrap(),
            crate::serve::CachePolicy::EwmaPrioritized
        );
        assert_eq!(c.serve.requests, 128);
        assert_eq!(c.serve.cache_cap, 2);
        assert_eq!(c.serve.experts_per_dev, 4);
        assert!((c.serve.rate_rps - 12.5).abs() < 1e-12);
        assert!((c.serve.slo_s - 0.15).abs() < 1e-12);
        // bad specs surface as errors, not defaults
        let mut bad = ExperimentConfig::default();
        bad.serve.trace = "weibull".into();
        assert!(bad.serve.parsed_trace().is_err());
        bad.serve.cache = "fifo".into();
        assert!(bad.serve.parsed_cache().is_err());
    }

    #[test]
    fn artifact_world_falls_back_to_preset() {
        let mut c = ExperimentConfig::default();
        c.artifacts_dir = "definitely/missing".into();
        c.artifact = "wide16_switch".into();
        assert_eq!(c.artifact_world().unwrap(), 16);
        c.artifact = "unknown_model".into();
        assert!(c.artifact_world().is_err());
    }
}
