//! Data pipeline: tokenizer, corpora, shard-aware batching.
//!
//! The paper trains on openwebtext2; this image has no internet, so the
//! pipeline offers (DESIGN.md §2 substitution table):
//!
//! * [`SyntheticCorpus`] — a deterministic Zipf-distributed word stream
//!   with Markov bigram structure. It has real learnable statistics (so
//!   loss curves fall and baselines can be compared on identical data)
//!   while being generable at any size from a seed.
//! * [`builtin_text`] — a small embedded natural-language corpus used by
//!   the quickstart and tests.
//!
//! Tokenization is byte-level (`vocab = 256`, matching the compiled
//! models' embedding table), so any UTF-8 text works without a trained
//! tokenizer artifact. [`Batcher`] cuts the token stream into the
//! `[P, B, T]` device-sharded batches the compiled step consumes, with
//! next-byte targets.

use crate::util::rng::Rng;

/// Byte-level tokenizer: text ↔ i32 token ids in [0, 256).
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .map(|&t| u8::try_from(t.clamp(0, 255)).unwrap())
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// A deterministic synthetic corpus: Zipf-weighted vocabulary with bigram
/// (Markov) transitions, emitted as space-separated "words" over a small
/// alphabet. Statistics are stable in the seed, so two training runs on
/// the same seed see byte-identical data.
pub struct SyntheticCorpus {
    words: Vec<String>,
    /// transition weights between word ids (row-stochastic up to scale)
    trans: Vec<Vec<f64>>,
    rng: Rng,
    cur: usize,
    pending: Vec<i32>,
}

impl SyntheticCorpus {
    pub fn new(seed: u64) -> SyntheticCorpus {
        let mut rng = Rng::seed_from_u64(seed);
        let n_words = 64;
        // word shapes: 2–7 lowercase letters
        let mut words = Vec::with_capacity(n_words);
        for _ in 0..n_words {
            let len = rng.range(2, 8);
            let w: String = (0..len)
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            words.push(w);
        }
        // Zipf base weights modulated by a random bigram affinity
        let trans: Vec<Vec<f64>> = (0..n_words)
            .map(|_| {
                (0..n_words)
                    .map(|j| (1.0 / (j + 1) as f64) * (0.25 + rng.f64()))
                    .collect()
            })
            .collect();
        SyntheticCorpus { words, trans, rng, cur: 0, pending: Vec::new() }
    }

    /// Next `n` byte-level tokens.
    pub fn tokens(&mut self, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            if self.pending.is_empty() {
                let next = self.rng.weighted(&self.trans[self.cur]);
                self.cur = next;
                let mut chunk = ByteTokenizer::encode(&self.words[next]);
                chunk.push(b' ' as i32);
                // occasional sentence structure
                if self.rng.below(12) == 0 {
                    chunk.pop();
                    chunk.extend(ByteTokenizer::encode(". "));
                }
                self.pending = chunk;
                self.pending.reverse(); // pop from the back
            }
            out.push(self.pending.pop().unwrap());
        }
        out
    }
}

/// A small embedded natural-language corpus (public-domain-style prose
/// written for this repo) for quickstarts and tests.
pub fn builtin_text() -> &'static str {
    concat!(
        "the network carries what the gate decides and the gate learns what ",
        "the network rewards. every expert waits at the end of a wire, and ",
        "every wire has a width. when the tokens crowd the narrow links the ",
        "whole machine slows to the pace of its weakest switch. so the loss ",
        "bends the routes toward the near and the wide, and the far experts ",
        "still see enough of the world to stay sharp. balance is not the ",
        "same as sameness: a fair schedule sends more where the road is ",
        "fast and less where the road is thin, and the model never notices ",
        "the difference because the difference was never about meaning. ",
        "topology is destiny for a packet. the scheduler reads the shape of ",
        "the cluster the way a river reads the valley, and the training run ",
        "flows downhill through the switches, filling the buffers it was ",
        "promised, dropping almost nothing, converging all the same. "
    )
}

/// Cuts a token stream into `[P, B, T]` sharded batches with next-byte
/// targets. Deterministic; wraps around the stream.
pub struct Batcher {
    stream: Vec<i32>,
    pos: usize,
    p: usize,
    b: usize,
    t: usize,
}

impl Batcher {
    pub fn new(stream: Vec<i32>, p: usize, b: usize, t: usize) -> Batcher {
        assert!(stream.len() > p * b * (t + 1), "stream too short for one batch");
        Batcher { stream, pos: 0, p, b, t }
    }

    pub fn from_text(text: &str, p: usize, b: usize, t: usize) -> Batcher {
        // tile short texts so at least a few batches exist
        let mut toks = ByteTokenizer::encode(text);
        let need = p * b * (t + 1) * 8;
        while toks.len() < need {
            let again = toks.clone();
            toks.extend(again);
        }
        Batcher::new(toks, p, b, t)
    }

    /// Tokens each device contributes per batch (S in the paper).
    pub fn tokens_per_dev(&self) -> usize {
        self.b * self.t
    }

    /// Next `(tokens, targets)`, both `[P, B, T]` row-major i32.
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let total = self.p * self.b;
        let mut tokens = Vec::with_capacity(total * self.t);
        let mut targets = Vec::with_capacity(total * self.t);
        for _ in 0..total {
            // wrap only when the (t+1)-token window would run off the end;
            // `pos + t + 1 == len` is still a valid final window
            if self.pos + self.t + 1 > self.stream.len() {
                self.pos = 0;
            }
            let seq = &self.stream[self.pos..self.pos + self.t + 1];
            tokens.extend_from_slice(&seq[..self.t]);
            targets.extend_from_slice(&seq[1..]);
            self.pos += self.t;
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_round_trips_ascii() {
        let s = "hello, MoE! 123";
        assert_eq!(ByteTokenizer::decode(&ByteTokenizer::encode(s)), s);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let mut c = SyntheticCorpus::new(0);
        for t in c.tokens(5_000) {
            assert!((0..256).contains(&t));
        }
    }

    #[test]
    fn synthetic_corpus_deterministic() {
        let mut a = SyntheticCorpus::new(9);
        let mut b = SyntheticCorpus::new(9);
        assert_eq!(a.tokens(1000), b.tokens(1000));
        let mut c = SyntheticCorpus::new(10);
        assert_ne!(a.tokens(1000), c.tokens(1000));
    }

    #[test]
    fn synthetic_corpus_has_skewed_unigrams() {
        // Zipf weights ⇒ some words far more frequent than others.
        let mut c = SyntheticCorpus::new(1);
        let toks = c.tokens(30_000);
        let text = ByteTokenizer::decode(&toks);
        // BTreeMap, not HashMap: HashMap's per-instance RandomState makes
        // even two identical maps iterate in different orders within one
        // process, so nothing derived from iteration may come from one.
        let mut counts = std::collections::BTreeMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w.trim_end_matches('.')).or_insert(0usize) += 1;
        }
        let max = *counts.values().max().unwrap();
        let min = *counts.values().min().unwrap();
        assert!(max > min * 5, "max {max} min {min}");
    }

    #[test]
    fn unigram_counts_are_reproducible_in_order() {
        // Regression: the counts map used to be a HashMap, whose iteration
        // order differs between two identical instances. The ordered map
        // must yield the exact same (word, count) sequence every build.
        let mut c = SyntheticCorpus::new(1);
        let toks = c.tokens(5_000);
        let text = ByteTokenizer::decode(&toks);
        let collect = || {
            let mut counts = std::collections::BTreeMap::new();
            for w in text.split_whitespace() {
                *counts.entry(w.trim_end_matches('.')).or_insert(0usize) += 1;
            }
            counts.into_iter().collect::<Vec<_>>()
        };
        let a = collect();
        assert_eq!(a, collect());
        assert!(a.windows(2).all(|w| w[0].0 < w[1].0), "sorted by word");
    }

    #[test]
    fn batcher_targets_are_shifted_tokens() {
        let stream: Vec<i32> = (0..1000).map(|i| i % 256).collect();
        let mut b = Batcher::new(stream, 2, 1, 8);
        let (tok, tgt) = b.next_batch();
        assert_eq!(tok.len(), 2 * 8);
        assert_eq!(tgt.len(), 2 * 8);
        // within each sequence the target is the next token
        for s in 0..2 {
            for i in 0..7 {
                assert_eq!(tgt[s * 8 + i], tok[s * 8 + i + 1]);
            }
        }
    }

    #[test]
    fn batcher_wraps_around() {
        let stream: Vec<i32> = (0..200).map(|i| i % 256).collect();
        let mut b = Batcher::new(stream, 2, 2, 8);
        for _ in 0..100 {
            let (tok, _) = b.next_batch();
            assert_eq!(tok.len(), 2 * 2 * 8);
        }
    }

    #[test]
    fn batcher_yields_final_window_before_wrapping() {
        // a stream of exactly p*b*(t+1)+1 tokens: after the first batch the
        // cursor sits at p*b*t, and [p*b*t, p*b*t + t + 1) is a valid final
        // window — the old `>=` wrap check silently skipped it forever.
        let (p, b, t) = (2usize, 2usize, 4usize);
        let n = p * b * (t + 1) + 1; // 21 tokens, window [16, 21) is valid
        let stream: Vec<i32> = (0..n as i32).collect();
        let mut batcher = Batcher::new(stream, p, b, t);
        let _ = batcher.next_batch(); // consumes windows at 0, 4, 8, 12
        let (tok, tgt) = batcher.next_batch();
        assert_eq!(&tok[..t], &[16, 17, 18, 19], "final window was skipped");
        assert_eq!(&tgt[..t], &[17, 18, 19, 20]);
        // and only then does the stream wrap to the head
        assert_eq!(&tok[t..2 * t], &[0, 1, 2, 3]);
    }

    #[test]
    fn from_text_tiles_short_text() {
        let b = Batcher::from_text("tiny", 4, 2, 16);
        assert!(b.stream.len() >= 4 * 2 * 17 * 8);
    }

    #[test]
    #[should_panic(expected = "stream too short")]
    fn too_short_stream_panics() {
        Batcher::new(vec![1, 2, 3], 2, 2, 8);
    }
}
