//! Randomised property-test helpers (proptest stand-in).
//!
//! `check(cases, seed, gen, prop)` runs `prop` on `cases` generated inputs
//! and panics with the reproducing case index + seed on the first failure.
//! No shrinking — generators here produce small cases by construction, and
//! the failing (seed, index) pair pins the exact input for a debugger.

use super::rng::Rng;

/// Run a property over generated cases. Panics on the first violation with
/// enough information to reproduce it deterministically.
pub fn check<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::seed_from_u64(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// A random stochastic matrix row (non-negative, sums to `total`).
pub fn random_row(rng: &mut Rng, n: usize, total: f64) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| rng.f64() + 1e-3).collect();
    let s: f64 = v.iter().sum();
    for x in &mut v {
        *x *= total / s;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            50,
            1,
            |rng| rng.below(100),
            |&x| if x < 100 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_case() {
        check(
            50,
            2,
            |rng| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err(format!("{x} >= 5")) },
        );
    }

    #[test]
    fn random_row_is_normalised() {
        let mut rng = Rng::seed_from_u64(3);
        let row = random_row(&mut rng, 7, 42.0);
        assert_eq!(row.len(), 7);
        assert!((row.iter().sum::<f64>() - 42.0).abs() < 1e-9);
        assert!(row.iter().all(|&x| x > 0.0));
    }
}
