//! Small self-contained substrates shared across the crate.
//!
//! This image has no crates.io access beyond the vendored xla set, so the
//! utilities that would normally be dependencies live here (DESIGN.md
//! §build-constraints): [`json`] (manifest/metrics I/O), [`rng`]
//! (deterministic xoshiro256**), [`toml`] (experiment config files),
//! [`bench`] (the criterion-less bench harness), and [`prop`] (randomised
//! property-test helpers standing in for proptest).
//!
//! This module itself holds the dense `Mat` type: the coordinator works
//! with `P×N` / `P×P` f64 matrices of at most a few thousand entries, so a
//! flat `Vec<f64>` with row-major indexing beats a linear-algebra crate.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod toml;

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f64) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn add_assign(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] += v;
    }

    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row_sum(&self, r: usize) -> f64 {
        self.row(r).iter().sum()
    }

    pub fn col_sum(&self, c: usize) -> f64 {
        (0..self.rows).map(|r| self.get(r, c)).sum()
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    pub fn max(&self) -> f64 {
        self.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.data.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        self.map(|v| v * s)
    }

    /// Max |a - b| over entries.
    pub fn linf_dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// `a ≈ b` within absolute tolerance.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol
}

/// Ceiling division for usize.
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_indexing_round_trips() {
        let mut m = Mat::zeros(3, 4);
        m.set(2, 3, 7.5);
        m.set(0, 0, -1.0);
        assert_eq!(m.get(2, 3), 7.5);
        assert_eq!(m.get(0, 0), -1.0);
        assert_eq!(m.get(1, 1), 0.0);
    }

    #[test]
    fn mat_from_fn_and_sums() {
        let m = Mat::from_fn(2, 3, |r, c| (r * 3 + c) as f64);
        assert_eq!(m.row_sum(0), 0.0 + 1.0 + 2.0);
        assert_eq!(m.col_sum(2), 2.0 + 5.0);
        assert_eq!(m.sum(), 15.0);
        assert_eq!(m.max(), 5.0);
        assert_eq!(m.min(), 0.0);
    }

    #[test]
    fn mat_rows_are_contiguous() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn linf_dist_zero_for_identical() {
        let m = Mat::filled(2, 2, 3.0);
        assert_eq!(m.linf_dist(&m), 0.0);
        let n = m.map(|v| v + 0.5);
        assert!(approx_eq(m.linf_dist(&n), 0.5, 1e-12));
    }

    #[test]
    fn ceil_div_edges() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
    }
}
