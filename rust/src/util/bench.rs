//! Criterion-less micro-bench harness + paper-style table printer.
//!
//! The `[[bench]]` targets are `harness = false` plain binaries; this
//! module gives them timing (warmup + N samples, mean/σ/min) and aligned
//! table output so each bench prints the same rows/series its paper table
//! or figure reports. Results are also dumped as JSON lines so
//! EXPERIMENTS.md numbers are regenerable by `cargo bench`.

use std::time::Instant;

/// Timing stats for one benchmarked closure.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

impl Sample {
    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Time `f`, auto-scaling the iteration count toward `target_s` total.
// Wall-clock timing is this module's whole purpose; the crate-wide
// clippy ban on `Instant::now` guards priced modules, not harnesses.
#[allow(clippy::disallowed_methods)]
pub fn time_it(mut f: impl FnMut(), warmup: usize, samples: usize) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples.max(1));
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / n;
    Sample {
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        iters: times.len(),
    }
}

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>().trim_end()
        );
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Append a JSON line to `target/bench-results.jsonl` for reproducibility.
pub fn record_jsonl(bench: &str, payload: &crate::util::json::Json) {
    use std::io::Write;
    let dir = std::path::Path::new("target");
    let _ = std::fs::create_dir_all(dir);
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join("bench-results.jsonl"))
    {
        let _ = writeln!(f, "{{\"bench\":\"{bench}\",\"data\":{}}}", payload.to_string_compact());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures_something() {
        let s = time_it(
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
            2,
            5,
        );
        assert!(s.mean_s >= 0.0);
        assert!(s.min_s <= s.mean_s + 1e-12);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5).ends_with('s'));
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5e-6).ends_with("us"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
