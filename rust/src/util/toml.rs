//! Minimal TOML-subset parser for experiment config files.
//!
//! Supports what `configs/*.toml` use: `[section]` / `[a.b]` tables,
//! `key = value` with string, integer, float, boolean and flat-array
//! values, `#` comments, and blank lines. Keys are exposed as dotted paths
//! (`"model.hidden"`). Unsupported TOML (multi-line strings, inline tables,
//! datetimes, arrays of tables) is rejected with a line-numbered error —
//! better a loud failure than a silently misread experiment config.

use std::collections::BTreeMap;

/// A TOML scalar or flat array.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed config: dotted-path → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut map = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated table header"))?
                    .trim();
                if name.is_empty() || name.starts_with('[') {
                    return Err(err(lineno, "unsupported table header"));
                }
                prefix = format!("{name}.");
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let key = format!("{prefix}{}", k.trim());
            let value = parse_value(v.trim()).map_err(|e| err(lineno, &e))?;
            if map.insert(key.clone(), value).is_some() {
                return Err(err(lineno, &format!("duplicate key {key:?}")));
            }
        }
        Ok(TomlDoc { map })
    }

    pub fn get(&self, dotted: &str) -> Option<&TomlValue> {
        self.map.get(dotted)
    }

    /// Typed getters with defaults — the config-system workhorses.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn err(lineno: usize, msg: &str) -> String {
    format!("line {}: {msg}", lineno + 1)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        if inner.contains('"') {
            return Err(format!("embedded quote in {s:?}"));
        }
        return Ok(TomlValue::Str(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array {s:?}"))?;
        let mut v = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                v.push(parse_value(p)?);
            }
        }
        return Ok(TomlValue::Arr(v));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split on commas that are not inside quotes (arrays are flat, so no
/// nested-bracket tracking is needed beyond rejecting them upstream).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
# experiment
name = "fig4"          # inline comment
steps = 1_200
lr = 3e-4
verbose = true

[cluster]
preset = "C"
nodes = 4

[model]
experts = [8, 16, 32]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "fig4");
        assert_eq!(doc.usize_or("steps", 0), 1200);
        assert!((doc.f64_or("lr", 0.0) - 3e-4).abs() < 1e-12);
        assert!(doc.bool_or("verbose", false));
        assert_eq!(doc.str_or("cluster.preset", ""), "C");
        assert_eq!(doc.usize_or("cluster.nodes", 0), 4);
        let arr = doc.get("model.experts").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_i64(), Some(32));
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = TomlDoc::parse("a = 1").unwrap();
        assert_eq!(doc.usize_or("missing", 7), 7);
        assert_eq!(doc.str_or("missing", "x"), "x");
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = TomlDoc::parse("i = 3\nf = 3.5").unwrap();
        assert_eq!(doc.get("i").unwrap().as_i64(), Some(3));
        assert_eq!(doc.get("i").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("f").unwrap().as_i64(), None);
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in ["[unclosed", "just a line", "k = ", "k = \"open", "a = 1\na = 2"] {
            assert!(TomlDoc::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn strings_may_contain_hash_and_commas() {
        let doc = TomlDoc::parse(r#"s = "a#b,c""#).unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b,c");
        let doc = TomlDoc::parse(r#"a = ["x,y", "z"]"#).unwrap();
        assert_eq!(doc.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
