//! Minimal JSON parser + writer (no serde in this image — DESIGN.md
//! §build-constraints).
//!
//! Covers the full JSON grammar minus exotic number forms; used to read the
//! artifact manifests emitted by `python/compile/aot.py` and to write
//! metrics/bench outputs. Strings support the standard escapes incl.
//! `\uXXXX` (BMP only — manifests are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` with a good error message.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialise (compact).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out);
        out
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let k = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    v => return Err(format!("object key must be string, got {v:?}")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let v = parse_value(b, pos)?;
                m.insert(k, v);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    other => return Err(format!("expected ',' or '}}', got {other:?}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    other => return Err(format!("expected ',' or ']', got {other:?}")),
                }
            }
        }
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b't') => lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => lit(b, pos, "null", Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut s = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(s);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        s.push(char::from_u32(cp).ok_or("bad \\u escape")?);
                        *pos += 4;
                    }
                    other => return Err(format!("bad escape {other:?}")),
                }
                *pos += 1;
            }
            Some(&c) => {
                // copy a UTF-8 run verbatim
                let start = *pos;
                let len = utf8_len(c);
                *pos += len;
                s.push_str(
                    std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?,
                );
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number {s:?} at byte {start}"))
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(v) => {
            out.push('[');
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Json::Str(k.clone()), out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {} }"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Obj(Default::default())));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"x", "{1: 2}", "[] []"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,true,null,"s\n"],"num":-3,"obj":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn parses_real_manifest() {
        // Smoke test against an actual manifest if artifacts were built.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny4/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).unwrap();
            assert_eq!(j.req("name").unwrap().as_str(), Some("tiny4"));
            assert!(j.req("n_param_tensors").unwrap().as_usize().unwrap() > 0);
        }
    }
}
