//! Deterministic PRNG (no external crates in this image — DESIGN.md
//! §build-constraints).
//!
//! SplitMix64 seeds a xoshiro256** generator; quality is far beyond what
//! the simulators/tests need and the implementation is ~40 lines. All
//! randomness in the crate flows through this, so every simulation and
//! property test is reproducible from a u64 seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Rng {
        // SplitMix64 expansion of the seed into 256 bits of state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // multiply-shift bounded sampling (Lemire); bias < 2^-64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(Rng::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn golden_vector_matches_the_python_mirror() {
        // the same constants are pinned in python/serve_mirror.py; both
        // sides must agree bit for bit or the mirror is lying
        let mut r = Rng::seed_from_u64(42);
        assert_eq!(
            [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()],
            [
                0x15780B2E0C2EC716,
                0x6104D9866D113A7E,
                0xAE17533239E499A1,
                0xECB8AD4703B360A1
            ]
        );
        let mut r = Rng::seed_from_u64(42);
        assert_eq!(r.f64().to_bits(), 0.08386297105988216f64.to_bits());
        let mut r = Rng::seed_from_u64(7);
        assert_eq!([r.below(10), r.below(10), r.below(10), r.below(10)], [7, 2, 8, 9]);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::seed_from_u64(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy_indices() {
        let mut r = Rng::seed_from_u64(4);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
