//! `ta-moe` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `train`        — train a model under a dispatch policy on a simulated
//!                    cluster, logging loss + simulated time. `--backend
//!                    sim` runs the pure-rust simulator (no artifacts, no
//!                    XLA); `--backend xla` the compiled artifacts
//!                    (requires `--features backend-xla`); default `auto`.
//! * `solve`        — print the Eq. 7 target dispatch pattern and Eq. 8
//!                    penalty weights for a cluster.
//! * `profile-topo` — show a topology's α/β matrices, levels, and the
//!                    Eq. 5 smoothed per-level parameters.
//! * `bench-comm`   — the Table-1 even-vs-uneven exchange micro-benchmark.
//! * `info`         — list compiled artifacts and their shapes.
//!
//! `--list-strategies` (any position) prints the dispatch-policy registry,
//! including policies registered by downstream code.
//!
//! Flags are `--key value`; `ta-moe <cmd> --help` lists them. (CLI parsing
//! is hand-rolled — this image has no clap; see DESIGN.md
//! §build-constraints.)

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use ta_moe::comm::profile_exchange;
use ta_moe::config::{topology_for, ExperimentConfig};
use ta_moe::coordinator::{device_flops, list_policies, SessionBuilder};
use ta_moe::dispatch::{penalty_weights, target_pattern, DispatchProblem, Norm};
use ta_moe::topology::smooth_levels;
use ta_moe::util::bench::Table;
use ta_moe::util::Mat;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let (cmd, flags) = parse_args(args)?;
    if flags.contains_key("list-strategies") {
        return cmd_list_strategies();
    }
    match cmd.as_deref() {
        Some("train") => cmd_train(&flags),
        Some("solve") => cmd_solve(&flags),
        Some("profile-topo") => cmd_profile_topo(&flags),
        Some("bench-comm") => cmd_bench_comm(&flags),
        Some("info") => cmd_info(&flags),
        Some("list-strategies") => cmd_list_strategies(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "ta-moe — Topology-Aware MoE training (NeurIPS 2022 reproduction)\n\n\
         USAGE: ta-moe <subcommand> [--key value ...]\n\n\
         SUBCOMMANDS\n\
           train         --artifact small8_switch --cluster C --strategy ta-moe\n\
                         --backend sim|xla|auto --steps 100 --lr 1e-3 --seed 0\n\
                         --a2a auto|direct|hier|sched:xor|sched:rot|sched:bvn\n\
                         --placement off|on|<every-steps> --overlap off|serial|k=<n>|auto\n\
                         --config file.toml\n\
           solve         --cluster C --nodes 2 [--tokens 1024] [--k 1]\n\
           profile-topo  --cluster table1 [--nodes 2] [--noise 0.2]\n\
           bench-comm    [--mb 128]\n\
           info          [--artifacts-dir artifacts]\n\
           list-strategies   (also available as a --list-strategies flag)\n\n\
         STRATEGIES: see `ta-moe --list-strategies` (registry-extensible)\n\
         CLUSTERS:   A | B | C | table1 (presets from the paper's Table 2)\n\
         BACKENDS:   sim (pure rust) | xla (compiled artifacts) | auto\n\
         A2A PLANS:  auto (policy preference) | direct | hier |\n\
                     sched:xor | sched:rot | sched:bvn (byte-aware BvN)\n\
         PLACEMENT:  off (canonical expert hosting) | on (amortised live\n\
                     migration, default cadence) | <every-steps>\n\
         OVERLAP:    off|serial (serial phase-sum clock) | k=<n> (fixed\n\
                     chunk pipeline) | auto (chunk-count autotuner)"
    );
}

type Flags = BTreeMap<String, String>;

/// Flags that take no value.
const BOOL_FLAGS: &[&str] = &["help", "list-strategies"];

fn parse_args(args: &[String]) -> Result<(Option<String>, Flags)> {
    let mut cmd = None;
    let mut flags = Flags::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            if BOOL_FLAGS.iter().any(|f| *f == key) {
                flags.insert(key.into(), "1".into());
                continue;
            }
            let val = it
                .next()
                .with_context(|| format!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
        } else if cmd.is_none() {
            cmd = Some(a.clone());
        } else {
            anyhow::bail!("unexpected positional argument {a:?}");
        }
    }
    Ok((cmd, flags))
}

fn flag<'a>(flags: &'a Flags, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn flag_parse<T: std::str::FromStr>(flags: &Flags, key: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        None => Ok(default),
        Some(s) => s
            .parse::<T>()
            .map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
    }
}

// ---------------------------------------------------------------------------
// list-strategies
// ---------------------------------------------------------------------------

fn cmd_list_strategies() -> Result<()> {
    let mut t = Table::new(&["policy", "description"]);
    for (names, help) in list_policies() {
        t.row(&[names, help]);
    }
    t.print();
    println!(
        "\nspec syntax: name[:arg...]  (e.g. fastermoe:0.3, ta-moe:softmax:2)\n\
         downstream code adds policies via ta_moe::coordinator::register_policy"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// train
// ---------------------------------------------------------------------------

fn cmd_train(flags: &Flags) -> Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    if let Some(c) = flags.get("cluster") {
        cfg.cluster = c.clone();
    }
    if let Some(s) = flags.get("strategy") {
        cfg.strategy = s.clone();
    }
    if let Some(a) = flags.get("a2a") {
        cfg.a2a = a.clone();
    }
    if let Some(p) = flags.get("placement") {
        cfg.placement = p.clone();
    }
    if let Some(o) = flags.get("overlap") {
        cfg.overlap = o.clone();
    }
    if let Some(b) = flags.get("backend") {
        cfg.backend = b.clone();
    }
    cfg.steps = flag_parse(flags, "steps", cfg.steps)?;
    cfg.lr = flag_parse(flags, "lr", cfg.lr)?;
    cfg.seed = flag_parse(flags, "seed", cfg.seed)?;

    let cluster_char = cfg.cluster.chars().next().unwrap_or('C');
    let mut builder = SessionBuilder::new()
        .artifact(cfg.artifacts_dir.clone(), cfg.artifact.clone())
        .backend_kind(cfg.parsed_backend()?)
        .cluster(cfg.cluster.clone())
        .policy(cfg.parsed_policy()?)
        .lr(cfg.lr as f32)
        .seed(cfg.seed as i32)
        .flops_per_dev(device_flops(cluster_char))
        .data_synthetic(cfg.seed);
    if let Some(algo) = cfg.parsed_a2a()? {
        builder = builder.a2a(algo);
    }
    let placement_cfg = cfg.parsed_placement()?;
    if let Some(pcfg) = placement_cfg {
        builder = builder.placement(pcfg);
    }
    let overlap_mode = cfg.parsed_overlap()?;
    builder = builder.overlap(overlap_mode);
    let mut session = builder.build()?;

    let topo = session.topology();
    println!(
        "train: artifact={} backend={} cluster={} (P={}, {} nodes) strategy={} a2a={} \
         placement={} overlap={} steps={}",
        cfg.artifact,
        session.backend_name(),
        cfg.cluster,
        topo.p(),
        topo.n_nodes(),
        session.policy().name(),
        session.a2a_algo(),
        match placement_cfg {
            Some(p) => format!("every {} steps", p.every),
            None => "off".into(),
        },
        overlap_mode,
        cfg.steps
    );

    for step in 0..cfg.steps {
        let rec = session.step()?;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            println!(
                "step {:>5}  loss {:.4}  ce {:.4}  aux {:.4}  drop {:.3}  sim {:.2}ms (comm {:.2}ms)  wall {:.0}ms",
                step,
                rec.loss,
                rec.ce,
                rec.aux,
                rec.dropped,
                rec.sim_total_s() * 1e3,
                rec.sim_comm_s * 1e3,
                rec.wall_s * 1e3
            );
        }
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let (vl, _) = session.eval_held_out()?;
            println!("  eval @ {:>5}: valid ce {:.4}  ppl {:.2}", step, vl, vl.exp());
        }
    }

    let out = cfg.out_dir.join(format!(
        "{}_{}_{}.csv",
        cfg.artifact,
        cfg.cluster,
        session.policy().name().replace(':', "-")
    ));
    session.log().write_csv(&out)?;
    let (local, intra, inter) = session.log().a2a_phase_totals();
    println!(
        "done: sim throughput {:.0} tokens/s; a2a phases local {:.1}ms / intra {:.1}ms / inter {:.1}ms; \
         plan cache {} hits / {} syntheses; log → {}",
        session.log().sim_throughput(),
        local * 1e3,
        intra * 1e3,
        inter * 1e3,
        session.log().plan_hits,
        session.log().plan_misses,
        out.display()
    );
    if overlap_mode != ta_moe::OverlapMode::Serial {
        let log = session.log();
        let charged: f64 =
            log.records.iter().map(|r| r.sim_comm_s + r.sim_compute_s).sum();
        let max_chunks = log.records.iter().map(|r| r.chunks).max().unwrap_or(1);
        println!(
            "overlap: {:.1}% of the serial clock hidden ({:.1}ms charged vs {:.1}ms serial); \
             a2a exposed {:.1}ms of {:.1}ms; chunk count up to {}",
            log.overlap_efficiency() * 100.0,
            charged * 1e3,
            log.sim_serial_total() * 1e3,
            log.a2a_exposed_total() * 1e3,
            {
                let (l, a, e) = log.a2a_phase_totals();
                (l + a + e) * 1e3
            },
            max_chunks
        );
    }
    if placement_cfg.is_some() {
        let log = session.log();
        let (pred, real) = log.migration_savings();
        println!(
            "placement: {} migrations, {:.0} KiB of expert weights moved; \
             per-step savings at decision time, summed over migrations: \
             predicted {:.3}ms vs realized {:.3}ms",
            log.migrations.len(),
            log.migration_bytes() / 1024.0,
            pred * 1e3,
            real * 1e3
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// solve
// ---------------------------------------------------------------------------

fn cmd_solve(flags: &Flags) -> Result<()> {
    let cluster = flag(flags, "cluster", "C");
    let nodes = flag_parse(flags, "nodes", 2usize)?;
    let tokens = flag_parse(flags, "tokens", 1024usize)?;
    let k = flag_parse(flags, "k", 1usize)?;
    let topo = if nodes == 0 {
        topology_for(cluster, 8)
    } else {
        ta_moe::topology::presets::by_name(cluster, nodes)
            .with_context(|| format!("unknown cluster {cluster:?}"))?
    };
    let prob = DispatchProblem { k, s: tokens, e_per_dev: 1, elem_bytes: 4096 };
    let tp = target_pattern(&topo, &prob);
    let pen = penalty_weights(&tp.c, Norm::L1);

    println!(
        "cluster {} × {} nodes: P={}, levels={}",
        cluster,
        topo.n_nodes(),
        topo.p(),
        topo.n_levels()
    );
    println!("\ntarget dispatch ĉ_0e (tokens from rank 0, Eq. 7):");
    print_row(tp.c.row(0));
    println!("penalty weights p_0e (Eq. 8):");
    print_row(pen.row(0));
    Ok(())
}

fn print_row(row: &[f64]) {
    let cells: Vec<String> = row.iter().map(|v| format!("{v:.2}")).collect();
    println!("  [{}]", cells.join(", "));
}

// ---------------------------------------------------------------------------
// profile-topo
// ---------------------------------------------------------------------------

fn cmd_profile_topo(flags: &Flags) -> Result<()> {
    let cluster = flag(flags, "cluster", "table1");
    let nodes = flag_parse(flags, "nodes", 2usize)?;
    let noise = flag_parse(flags, "noise", 0.0f64)?;
    let topo = ta_moe::topology::presets::by_name(cluster, nodes)
        .with_context(|| format!("unknown cluster {cluster:?}"))?;
    let topo = if noise > 0.0 { topo.with_noise(noise, 42) } else { topo };

    println!("cluster {cluster}: P={}, nodes={}", topo.p(), topo.n_nodes());
    let lp = smooth_levels(&topo);
    let mut t = Table::new(&["level", "pairs", "alpha (us)", "bw (GB/s)"]);
    for l in 0..lp.beta.len() {
        if lp.count[l] == 0 {
            continue;
        }
        t.row(&[
            l.to_string(),
            lp.count[l].to_string(),
            format!("{:.1}", lp.alpha[l] * 1e6),
            format!("{:.1}", 1.0 / lp.beta[l] / 1e9),
        ]);
    }
    t.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// bench-comm (Table 1)
// ---------------------------------------------------------------------------

fn cmd_bench_comm(flags: &Flags) -> Result<()> {
    let mb = flag_parse(flags, "mb", 128.0f64)?;
    let topo = ta_moe::topology::presets::table1();
    let bytes = mb * 1024.0 * 1024.0;
    let even = Mat::filled(4, 4, 0.25);
    let peer = [1usize, 0, 3, 2];
    let uneven = Mat::from_fn(4, 4, |i, j| {
        if i == j {
            0.25
        } else if j == peer[i] {
            0.5
        } else {
            0.125
        }
    });

    let mut t = Table::new(&["pattern", "0<->0", "0<->1", "0<->0'", "0<->1'", "All (us)"]);
    for (name, ratios) in [("even", &even), ("uneven", &uneven)] {
        let p = profile_exchange(&topo, bytes, ratios);
        let us: Vec<String> = p
            .rank0_times
            .iter()
            .map(|s| format!("{:.0}", s * 1e6))
            .collect();
        t.row(&[
            name.to_string(),
            us[0].clone(),
            us[1].clone(),
            us[2].clone(),
            us[3].clone(),
            format!("{:.0}", p.rank0_total * 1e6),
        ]);
    }
    t.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// info
// ---------------------------------------------------------------------------

fn cmd_info(flags: &Flags) -> Result<()> {
    let dir = PathBuf::from(flag(flags, "artifacts-dir", "artifacts"));
    let mut t = Table::new(&["artifact", "P", "N", "layers", "d", "gate", "dispatch", "params"]);
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .with_context(|| format!("listing {dir:?} — run `make artifacts`?"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.join("manifest.json").exists())
        .collect();
    entries.sort();
    for path in entries {
        let m = ta_moe::runtime::Manifest::load(&path)?;
        t.row(&[
            m.name.clone(),
            m.config.p.to_string(),
            m.config.n_experts.to_string(),
            m.config.layers.to_string(),
            m.config.d.to_string(),
            m.config.gate.clone(),
            m.config.dispatch.clone(),
            format!("{:.2}M", m.n_params() as f64 / 1e6),
        ]);
    }
    t.print();
    Ok(())
}
