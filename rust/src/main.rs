//! `ta-moe` — the launcher CLI.
//!
//! Subcommands:
//!
//! * `train`        — train a model under a dispatch policy on a simulated
//!                    cluster, logging loss + simulated time. `--backend
//!                    sim` runs the pure-rust simulator (no artifacts, no
//!                    XLA); `--backend xla` the compiled artifacts
//!                    (requires `--features backend-xla`); default `auto`.
//! * `serve`        — continuous-batching inference serving simulator:
//!                    seeded arrival traces, expert-weight caching, SLO
//!                    metrics (TTFT/TPOT percentiles, goodput) — pure
//!                    pricing, no backend or artifacts needed.
//! * `solve`        — print the Eq. 7 target dispatch pattern and Eq. 8
//!                    penalty weights for a cluster.
//! * `profile-topo` — show a topology's α/β matrices, levels, and the
//!                    Eq. 5 smoothed per-level parameters.
//! * `bench-comm`   — the Table-1 even-vs-uneven exchange micro-benchmark.
//! * `info`         — list compiled artifacts and their shapes.
//!
//! `--list-strategies` (any position) prints the dispatch-policy registry,
//! including policies registered by downstream code. `--list-modes`
//! enumerates every selectable mode spec — a2a plans, overlap modes,
//! placement specs, serve traces and cache policies.
//!
//! Flags are `--key value`; `ta-moe <cmd> --help` lists them. (CLI parsing
//! is hand-rolled — this image has no clap; see DESIGN.md
//! §build-constraints.)

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use ta_moe::analyze::analyze_workload;
use ta_moe::comm::{profile_exchange, A2aAlgo};
use ta_moe::config::{topology_for, AnalyzeSection, ExperimentConfig};
use ta_moe::coordinator::{device_flops, list_policies, SessionBuilder, Workload, WorkloadCore};
use ta_moe::dispatch::{penalty_weights, target_pattern, DispatchProblem, Norm};
use ta_moe::metrics::RunLog;
use ta_moe::serve::{CachePolicy, ServeBuilder, TraceConfig, TraceKind};
use ta_moe::topology::smooth_levels;
use ta_moe::trace::{chrome_trace, utilization, utilization_csv};
use ta_moe::util::bench::Table;
use ta_moe::util::json::Json;
use ta_moe::util::Mat;
use ta_moe::{BottleneckReport, Tracer};

/// Tracks listed under `hottest` in the utilization report (summary JSON
/// and `ta-moe` stdout alike).
const TRACE_TOP_K: usize = 8;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let (cmd, flags) = parse_args(args)?;
    if flags.contains_key("list-strategies") {
        return cmd_list_strategies();
    }
    if flags.contains_key("list-modes") {
        return cmd_list_modes();
    }
    match cmd.as_deref() {
        Some("train") => cmd_train(&flags),
        Some("serve") => cmd_serve(&flags),
        Some("solve") => cmd_solve(&flags),
        Some("profile-topo") => cmd_profile_topo(&flags),
        Some("bench-comm") => cmd_bench_comm(&flags),
        Some("info") => cmd_info(&flags),
        Some("list-strategies") => cmd_list_strategies(),
        Some("list-modes") => cmd_list_modes(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "ta-moe — Topology-Aware MoE training (NeurIPS 2022 reproduction)\n\n\
         USAGE: ta-moe <subcommand> [--key value ...]\n\n\
         SUBCOMMANDS\n\
           train         --artifact small8_switch --cluster C --strategy ta-moe\n\
                         --backend sim|xla|auto --steps 100 --lr 1e-3 --seed 0\n\
                         --a2a auto|direct|hier|sched:xor|sched:rot|sched:bvn\n\
                         --placement off|on|<every-steps> --overlap off|serial|k=<n>|auto\n\
                         --chaos off|<events> --trace off|<path.json>\n\
                         --trace-level step|phase|chunk --config file.toml\n\
                         --analyze off|<path> --whatifs auto|<specs>\n\
           serve         --artifact tiny4 --cluster table1 --strategy ta-moe\n\
                         --trace poisson|bursty|diurnal --rate 8 --requests 64\n\
                         --cache-cap <n> --cache lru|ewma --slo-s 0.2\n\
                         --experts-per-dev <n> --max-inflight 8 --zipf 1.0\n\
                         --a2a ... --placement ... --overlap ... --chaos ... --seed 0\n\
                         --analyze off|<path> --whatifs auto|<specs>\n\
                         (--trace also takes a <path.json> to record a\n\
                         Chrome trace; --trace-level as in train)\n\
           solve         --cluster C --nodes 2 [--tokens 1024] [--k 1]\n\
           profile-topo  --cluster table1 [--nodes 2] [--noise 0.2]\n\
           bench-comm    [--mb 128]\n\
           info          [--artifacts-dir artifacts]\n\
           list-strategies   (also available as a --list-strategies flag)\n\
           list-modes        every mode spec: a2a, overlap, placement,\n\
                             serve traces, cache policies\n\n\
         STRATEGIES: see `ta-moe --list-strategies` (registry-extensible)\n\
         CLUSTERS:   A | B | C | table1 (presets from the paper's Table 2)\n\
         BACKENDS:   sim (pure rust) | xla (compiled artifacts) | auto\n\
         A2A PLANS:  auto (policy preference) | direct | hier |\n\
                     sched:xor | sched:rot | sched:bvn (byte-aware BvN)\n\
         PLACEMENT:  off (canonical expert hosting) | on (amortised live\n\
                     migration, default cadence) | <every-steps>\n\
         OVERLAP:    off|serial (serial phase-sum clock) | k=<n> (fixed\n\
                     chunk pipeline) | auto (chunk-count autotuner)\n\
         TRACES:     poisson | bursty (2-state MMPP) | diurnal (thinned\n\
                     sinusoidal rate)\n\
         CACHE:      lru | ewma (gate-load-EWMA-prioritized eviction)\n\
         CHAOS:      off | `+`-joined scripted faults, e.g.\n\
                     straggler:0x2@10-20:flap=4 + link:1x3@30-60 +\n\
                     nodeloss:3@80 + drift:1@40-50 (see `ta-moe --list-modes`)\n\
         TRACING:    --trace <path.json> records a deterministic Chrome\n\
                     trace (load in Perfetto / chrome://tracing) plus a\n\
                     per-resource utilization CSV; levels step < phase <\n\
                     chunk; default off (zero overhead)\n\
         ANALYZE:    --analyze <path> writes <path>.bottleneck.json —\n\
                     per-resource critical-path blame plus what-if\n\
                     projections; --whatifs auto | `+`-joined specs\n\
                     (link:<edge>x<f> | dev:<i>x<f> | alpha0 |\n\
                     perfect-fabric | infinite-cache); default off"
    );
}

type Flags = BTreeMap<String, String>;

/// Flags that take no value.
const BOOL_FLAGS: &[&str] = &["help", "list-strategies", "list-modes"];

fn parse_args(args: &[String]) -> Result<(Option<String>, Flags)> {
    let mut cmd = None;
    let mut flags = Flags::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            if BOOL_FLAGS.iter().any(|f| *f == key) {
                flags.insert(key.into(), "1".into());
                continue;
            }
            let val = it
                .next()
                .with_context(|| format!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
        } else if cmd.is_none() {
            cmd = Some(a.clone());
        } else {
            anyhow::bail!("unexpected positional argument {a:?}");
        }
    }
    Ok((cmd, flags))
}

fn flag<'a>(flags: &'a Flags, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn flag_parse<T: std::str::FromStr>(flags: &Flags, key: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        None => Ok(default),
        Some(s) => s
            .parse::<T>()
            .map_err(|e| anyhow::anyhow!("--{key} {s:?}: {e}")),
    }
}

// ---------------------------------------------------------------------------
// list-strategies
// ---------------------------------------------------------------------------

fn cmd_list_strategies() -> Result<()> {
    let mut t = Table::new(&["policy", "description"]);
    for (names, help) in list_policies() {
        t.row(&[names, help]);
    }
    t.print();
    println!(
        "\nspec syntax: name[:arg...]  (e.g. fastermoe:0.3, ta-moe:softmax:2)\n\
         downstream code adds policies via ta_moe::coordinator::register_policy"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// train
// ---------------------------------------------------------------------------

fn cmd_train(flags: &Flags) -> Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    if let Some(c) = flags.get("cluster") {
        cfg.cluster = c.clone();
    }
    if let Some(s) = flags.get("strategy") {
        cfg.strategy = s.clone();
    }
    if let Some(a) = flags.get("a2a") {
        cfg.a2a = a.clone();
    }
    if let Some(p) = flags.get("placement") {
        cfg.placement = p.clone();
    }
    if let Some(o) = flags.get("overlap") {
        cfg.overlap = o.clone();
    }
    if let Some(b) = flags.get("backend") {
        cfg.backend = b.clone();
    }
    if let Some(c) = flags.get("chaos") {
        cfg.chaos = c.clone();
    }
    if let Some(t) = flags.get("trace") {
        cfg.trace.path = t.clone();
    }
    if let Some(l) = flags.get("trace-level") {
        cfg.trace.level = l.clone();
    }
    if let Some(a) = flags.get("analyze") {
        cfg.analyze.path = a.clone();
    }
    if let Some(w) = flags.get("whatifs") {
        cfg.analyze.whatifs = w.clone();
    }
    cfg.steps = flag_parse(flags, "steps", cfg.steps)?;
    cfg.lr = flag_parse(flags, "lr", cfg.lr)?;
    cfg.seed = flag_parse(flags, "seed", cfg.seed)?;

    let cluster_char = cfg.cluster.chars().next().unwrap_or('C');
    let mut builder = SessionBuilder::new()
        .artifact(cfg.artifacts_dir.clone(), cfg.artifact.clone())
        .backend_kind(cfg.parsed_backend()?)
        .cluster(cfg.cluster.clone())
        .policy(cfg.parsed_policy()?)
        .lr(cfg.lr as f32)
        .seed(cfg.seed as i32)
        .flops_per_dev(device_flops(cluster_char))
        .data_synthetic(cfg.seed);
    if let Some(algo) = cfg.parsed_a2a()? {
        builder = builder.a2a(algo);
    }
    let placement_cfg = cfg.parsed_placement()?;
    if let Some(pcfg) = placement_cfg {
        builder = builder.placement(pcfg);
    }
    let overlap_mode = cfg.parsed_overlap()?;
    builder = builder.overlap(overlap_mode);
    let chaos_spec = cfg.parsed_chaos()?;
    builder = builder.chaos(chaos_spec.clone());
    let trace_level = cfg.trace.parsed_level()?;
    if let Some(level) = trace_level {
        builder = builder.trace_level(level);
    }
    let mut session = builder.build()?;

    let topo = session.topology();
    println!(
        "train: artifact={} backend={} cluster={} (P={}, {} nodes) strategy={} a2a={} \
         placement={} overlap={} steps={}",
        cfg.artifact,
        session.backend_name(),
        cfg.cluster,
        topo.p(),
        topo.n_nodes(),
        session.policy().name(),
        session.a2a_algo(),
        match placement_cfg {
            Some(p) => format!("every {} steps", p.every),
            None => "off".into(),
        },
        overlap_mode,
        cfg.steps
    );
    if !chaos_spec.is_off() {
        println!("chaos: {chaos_spec}");
    }
    if let Some(level) = trace_level {
        println!("trace: level {level} → {}", cfg.trace.path);
    }

    for step in 0..cfg.steps {
        let rec = session.step()?;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            println!(
                "step {:>5}  loss {:.4}  ce {:.4}  aux {:.4}  drop {:.3}  sim {:.2}ms (comm {:.2}ms)  wall {:.0}ms",
                step,
                rec.loss,
                rec.ce,
                rec.aux,
                rec.dropped,
                rec.sim_total_s() * 1e3,
                rec.sim_comm_s * 1e3,
                rec.wall_s * 1e3
            );
        }
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let (vl, _) = session.eval_held_out()?;
            println!("  eval @ {:>5}: valid ce {:.4}  ppl {:.2}", step, vl, vl.exp());
        }
    }

    let out = cfg.out_dir.join(format!(
        "{}_{}_{}.csv",
        cfg.artifact,
        cfg.cluster,
        session.policy().name().replace(':', "-")
    ));
    session.log().write_csv(&out)?;
    let (local, intra, inter) = session.log().a2a_phase_totals();
    println!(
        "done: sim throughput {:.0} tokens/s; a2a phases local {:.1}ms / intra {:.1}ms / inter {:.1}ms; \
         plan cache {} hits / {} syntheses; log → {}",
        session.log().sim_throughput(),
        local * 1e3,
        intra * 1e3,
        inter * 1e3,
        session.log().plan_hits,
        session.log().plan_misses,
        out.display()
    );
    if overlap_mode != ta_moe::OverlapMode::Serial {
        let log = session.log();
        let charged: f64 =
            log.records.iter().map(|r| r.sim_comm_s + r.sim_compute_s).sum();
        let max_chunks = log.records.iter().map(|r| r.chunks).max().unwrap_or(1);
        println!(
            "overlap: {:.1}% of the serial clock hidden ({:.1}ms charged vs {:.1}ms serial); \
             a2a exposed {:.1}ms of {:.1}ms; chunk count up to {}",
            log.overlap_efficiency() * 100.0,
            charged * 1e3,
            log.sim_serial_total() * 1e3,
            log.a2a_exposed_total() * 1e3,
            {
                let (l, a, e) = log.a2a_phase_totals();
                (l + a + e) * 1e3
            },
            max_chunks
        );
    }
    if placement_cfg.is_some() {
        let log = session.log();
        let (pred, real) = log.migration_savings();
        println!(
            "placement: {} migrations, {:.0} KiB of expert weights moved; \
             per-step savings at decision time, summed over migrations: \
             predicted {:.3}ms vs realized {:.3}ms",
            log.migrations.len(),
            log.migration_bytes() / 1024.0,
            pred * 1e3,
            real * 1e3
        );
    }
    if !chaos_spec.is_off() {
        let log = session.log();
        let recovery = match log.recovery_steps() {
            Some(n) => format!("{n} steps"),
            None => "not within the run".into(),
        };
        println!(
            "chaos: {} events fired (first at step {}); step-clock recovery: {}",
            log.perturbations.len(),
            log.first_perturbation_step()
                .map_or_else(|| "-".into(), |s| s.to_string()),
            recovery
        );
    }
    let analyze_report = if cfg.analyze.enabled() {
        Some(run_analysis(
            session.core(),
            session.last_counts(),
            session.log(),
            &cfg.analyze,
            "train",
        )?)
    } else {
        None
    };
    if !chaos_spec.is_off() || session.tracer().is_some() || analyze_report.is_some() {
        // chaos, traced, and analyzed runs get the JSON summary
        // (recovery_steps, utilization, blame & co); clean bare runs keep
        // the historic CSV-only output byte for byte
        let json_path = out.with_extension("json");
        let mut summary = summary_with_trace(session.log(), session.tracer());
        if let (Some(rep), Json::Obj(m)) = (&analyze_report, &mut summary) {
            m.insert("analyze".into(), rep.to_json());
        }
        std::fs::write(&json_path, summary.to_string_compact())?;
        println!("summary → {}", json_path.display());
    }
    if let Some(tr) = session.tracer() {
        write_trace_outputs(tr, &cfg.trace.path, &session.log().dead_devices())?;
    }
    Ok(())
}

/// Run the bottleneck analysis over a finished workload and write
/// `<path>.bottleneck.json` beside printing the ranked tables.
fn run_analysis(
    core: &WorkloadCore,
    counts: Option<&Mat>,
    log: &RunLog,
    section: &AnalyzeSection,
    mode: &str,
) -> Result<BottleneckReport> {
    let counts = counts.context("--analyze needs at least one priced step")?;
    let whatifs = section.parsed_whatifs()?;
    let report = analyze_workload(core, counts, log, whatifs.as_deref(), mode)
        .map_err(anyhow::Error::msg)?;
    report.print_tables();
    let path = PathBuf::from(format!("{}.bottleneck.json", section.path));
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&path, report.to_json().to_string_compact())?;
    println!("analyze → {}", path.display());
    Ok(report)
}

/// The run-log summary, with the tracer's utilization report and counter
/// registry folded in when a tracer was attached (untraced summaries are
/// byte-identical to the historic ones).
fn summary_with_trace(log: &RunLog, tracer: Option<&Tracer>) -> Json {
    let mut summary = log.summary_json();
    if let (Some(tr), Json::Obj(m)) = (tracer, &mut summary) {
        let report = utilization(tr.events(), tr.clock_s(), TRACE_TOP_K, &log.dead_devices());
        m.insert("utilization".into(), report.to_json());
        m.insert("registry".into(), tr.registry().to_json());
    }
    summary
}

/// Write the Chrome-trace JSON (Perfetto-loadable) at `path_spec` and the
/// per-resource utilization CSV next to it. `dead_devs` as in
/// [`utilization`].
fn write_trace_outputs(tracer: &Tracer, path_spec: &str, dead_devs: &[usize]) -> Result<()> {
    let path = PathBuf::from(path_spec);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&path, chrome_trace(tracer).to_string_compact())?;
    let report = utilization(tracer.events(), tracer.clock_s(), TRACE_TOP_K, dead_devs);
    let csv_path = path.with_extension("utilization.csv");
    std::fs::write(&csv_path, utilization_csv(&report))?;
    if let Some(hot) = report.hottest.first() {
        let busy = report
            .rows
            .iter()
            .find(|r| &r.track == hot)
            .map_or(0.0, |r| r.busy_frac);
        println!(
            "trace: {} events on {} tracks; hottest {} at {:.1}% busy; \
             straggler skew {:.3}",
            tracer.events().len(),
            report.rows.len(),
            hot,
            busy * 100.0,
            report.straggler_skew
        );
    }
    println!("trace → {} (+ {})", path.display(), csv_path.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

fn cmd_serve(flags: &Flags) -> Result<()> {
    let mut cfg = match flags.get("config") {
        Some(path) => ExperimentConfig::from_toml_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(a) = flags.get("artifact") {
        cfg.artifact = a.clone();
    }
    if let Some(c) = flags.get("cluster") {
        cfg.cluster = c.clone();
    }
    if let Some(s) = flags.get("strategy") {
        cfg.strategy = s.clone();
    }
    if let Some(a) = flags.get("a2a") {
        cfg.a2a = a.clone();
    }
    if let Some(p) = flags.get("placement") {
        cfg.placement = p.clone();
    }
    if let Some(o) = flags.get("overlap") {
        cfg.overlap = o.clone();
    }
    if let Some(t) = flags.get("trace") {
        // `--trace` is overloaded on serve: an arrival-process kind
        // (poisson|bursty|diurnal) keeps its historic meaning; anything
        // else is a tracer output path ("off" disables the tracer)
        if t.parse::<TraceKind>().is_ok() {
            cfg.serve.trace = t.clone();
        } else {
            cfg.trace.path = t.clone();
        }
    }
    if let Some(l) = flags.get("trace-level") {
        cfg.trace.level = l.clone();
    }
    if let Some(c) = flags.get("cache") {
        cfg.serve.cache = c.clone();
    }
    if let Some(c) = flags.get("chaos") {
        cfg.chaos = c.clone();
    }
    if let Some(a) = flags.get("analyze") {
        cfg.analyze.path = a.clone();
    }
    if let Some(w) = flags.get("whatifs") {
        cfg.analyze.whatifs = w.clone();
    }
    cfg.seed = flag_parse(flags, "seed", cfg.seed)?;
    cfg.serve.rate_rps = flag_parse(flags, "rate", cfg.serve.rate_rps)?;
    cfg.serve.requests = flag_parse(flags, "requests", cfg.serve.requests)?;
    cfg.serve.cache_cap = flag_parse(flags, "cache-cap", cfg.serve.cache_cap)?;
    cfg.serve.slo_s = flag_parse(flags, "slo-s", cfg.serve.slo_s)?;
    cfg.serve.max_inflight = flag_parse(flags, "max-inflight", cfg.serve.max_inflight)?;
    cfg.serve.experts_per_dev =
        flag_parse(flags, "experts-per-dev", cfg.serve.experts_per_dev)?;
    cfg.serve.zipf = flag_parse(flags, "zipf", cfg.serve.zipf)?;
    cfg.serve.prompt_mean = flag_parse(flags, "prompt-mean", cfg.serve.prompt_mean)?;
    cfg.serve.output_mean = flag_parse(flags, "output-mean", cfg.serve.output_mean)?;
    let max_iters = flag_parse(flags, "max-iters", 1_000_000usize)?;

    // same model-shape resolution as training: compiled manifest when
    // present, built-in preset otherwise — serving needs no artifacts
    let model = ta_moe::runtime::resolve_model_cfg(&cfg.artifacts_dir, &cfg.artifact)?;
    let cluster_char = cfg.cluster.chars().next().unwrap_or('C');
    let mut builder = ServeBuilder::new()
        .model_cfg(model)
        .cluster(cfg.cluster.clone())
        .policy(cfg.parsed_policy()?)
        .flops_per_dev(device_flops(cluster_char))
        .trace(TraceConfig {
            kind: cfg.serve.parsed_trace()?,
            rate_rps: cfg.serve.rate_rps,
            n_requests: cfg.serve.requests,
            seed: cfg.seed,
            prompt_mean: cfg.serve.prompt_mean,
            output_mean: cfg.serve.output_mean,
        })
        .cache_cap(cfg.serve.cache_cap)
        .cache_policy(cfg.serve.parsed_cache()?)
        .slo_s(cfg.serve.slo_s)
        .max_inflight_per_dev(cfg.serve.max_inflight)
        .zipf_s(cfg.serve.zipf)
        .overlap(cfg.parsed_overlap()?)
        .placement(cfg.parsed_placement()?);
    let chaos_spec = cfg.parsed_chaos()?;
    builder = builder.chaos(chaos_spec.clone());
    let trace_level = cfg.trace.parsed_level()?;
    if let Some(level) = trace_level {
        builder = builder.trace_level(level);
    }
    if let Some(algo) = cfg.parsed_a2a()? {
        builder = builder.a2a(algo);
    }
    if cfg.serve.experts_per_dev > 0 {
        builder = builder.experts_per_dev(cfg.serve.experts_per_dev);
    }
    let mut sess = builder.build()?;

    println!(
        "serve: model={} cluster={} (P={}) strategy={} a2a={} trace={} rate={}rps \
         requests={} cache={}(cap={}) slo={}s",
        cfg.artifact,
        cfg.cluster,
        sess.model_cfg().p,
        cfg.strategy,
        sess.a2a_algo(),
        cfg.serve.trace,
        cfg.serve.rate_rps,
        cfg.serve.requests,
        cfg.serve.cache,
        cfg.serve.cache_cap,
        cfg.serve.slo_s
    );
    if !chaos_spec.is_off() {
        println!("chaos: {chaos_spec}");
    }
    if let Some(level) = trace_level {
        println!("trace: level {level} → {}", cfg.trace.path);
    }
    sess.run(max_iters)?;

    let log = sess.log();
    println!(
        "done: {} requests over {} iterations, {:.2}s simulated; goodput {:.1} tok/s \
         (TTFT SLO {:.0}ms)",
        log.requests.len(),
        log.records.len(),
        sess.now_s(),
        sess.goodput(),
        sess.slo_s() * 1e3
    );
    let (p50, p99) = (
        log.ttft_percentile(50.0).unwrap_or(0.0),
        log.ttft_percentile(99.0).unwrap_or(0.0),
    );
    println!(
        "latency: TTFT p50 {:.2}ms / p99 {:.2}ms; TPOT p50 {:.3}ms / p99 {:.3}ms; \
         cache {:.1}% hits ({} misses); {} migrations",
        p50 * 1e3,
        p99 * 1e3,
        log.tpot_percentile(50.0).unwrap_or(0.0) * 1e3,
        log.tpot_percentile(99.0).unwrap_or(0.0) * 1e3,
        log.cache_hit_rate() * 100.0,
        log.cache_misses,
        log.migrations.len()
    );
    if !chaos_spec.is_off() {
        let recovery = match log.recovery_steps() {
            Some(n) => format!("{n} iterations"),
            None => "not within the run".into(),
        };
        println!(
            "chaos: {} events fired (first at iteration {}); step-clock recovery: {}",
            log.perturbations.len(),
            log.first_perturbation_step()
                .map_or_else(|| "-".into(), |s| s.to_string()),
            recovery
        );
    }
    let stem = format!(
        "serve_{}_{}_{}_{}",
        cfg.artifact,
        cfg.cluster,
        cfg.strategy.replace(':', "-"),
        cfg.serve.trace
    );
    let csv = cfg.out_dir.join(format!("{stem}.csv"));
    log.write_csv(&csv)?;
    let analyze_report = if cfg.analyze.enabled() {
        Some(run_analysis(sess.core(), sess.last_counts(), log, &cfg.analyze, "serve")?)
    } else {
        None
    };
    let json_path = cfg.out_dir.join(format!("{stem}.json"));
    let mut summary = summary_with_trace(log, sess.tracer());
    if let (Some(rep), Json::Obj(m)) = (&analyze_report, &mut summary) {
        m.insert("analyze".into(), rep.to_json());
    }
    std::fs::write(&json_path, summary.to_string_compact())?;
    println!("log → {} / {}", csv.display(), json_path.display());
    if let Some(tr) = sess.tracer() {
        write_trace_outputs(tr, &cfg.trace.path, &log.dead_devices())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// list-modes
// ---------------------------------------------------------------------------

fn cmd_list_modes() -> Result<()> {
    let mut t = Table::new(&["kind", "spec", "description"]);
    for algo in A2aAlgo::ALL {
        t.row(&["a2a".into(), algo.to_string(), a2a_help(algo).into()]);
    }
    for (spec, help) in [
        ("off|serial", "serial phase-sum clock (a2a fully exposed)"),
        ("k=<n>", "fixed n-chunk dispatch-compute-combine pipeline"),
        ("auto", "per-step chunk-count autotuner"),
    ] {
        t.row(&["overlap".into(), spec.into(), help.into()]);
    }
    for (spec, help) in [
        ("off", "canonical expert hosting (expert e on device e/E)"),
        ("on", "amortised live migration, default cadence"),
        ("<n>", "live migration, re-solve attempted every n steps"),
    ] {
        t.row(&["placement".into(), spec.into(), help.into()]);
    }
    for kind in TraceKind::ALL {
        t.row(&["trace".into(), kind.to_string(), trace_help(kind).into()]);
    }
    for policy in CachePolicy::ALL {
        t.row(&["cache".into(), policy.to_string(), cache_help(policy).into()]);
    }
    for (spec, help) in TRACE_LEVEL_ROWS {
        t.row(&["trace-level".into(), (*spec).into(), (*help).into()]);
    }
    for (spec, help) in CHAOS_MODE_ROWS {
        t.row(&["chaos".into(), (*spec).into(), (*help).into()]);
    }
    for (spec, help) in WHATIF_MODE_ROWS {
        t.row(&["whatif".into(), (*spec).into(), (*help).into()]);
    }
    t.print();
    println!("\ndispatch policies: see `ta-moe --list-strategies`");
    Ok(())
}

fn a2a_help(algo: A2aAlgo) -> &'static str {
    use ta_moe::comm::ScheduleKind;
    match algo {
        A2aAlgo::Direct => "every pair exchanges at once (contention-priced)",
        A2aAlgo::Hierarchical => "intra-node gather, inter-node exchange, scatter",
        A2aAlgo::Scheduled(ScheduleKind::Xor) => "P contention-free rounds, XOR pairing",
        A2aAlgo::Scheduled(ScheduleKind::Rotation) => "P rounds, rotation pairing",
        A2aAlgo::Scheduled(ScheduleKind::Bvn) => "byte-matrix-aware BvN round synthesis",
    }
}

fn trace_help(kind: TraceKind) -> &'static str {
    match kind {
        TraceKind::Poisson => "exponential inter-arrivals at the mean rate",
        TraceKind::Bursty => "2-state MMPP (alias mmpp): ON/OFF bursts",
        TraceKind::Diurnal => "Poisson thinned against a sinusoidal day curve",
    }
}

fn cache_help(policy: CachePolicy) -> &'static str {
    match policy {
        CachePolicy::Lru => "evict the least-recently-touched expert",
        CachePolicy::EwmaPrioritized => "evict the lowest gate-load EWMA expert",
    }
}

/// The `--list-modes` tracer detail rows. Every spec is a parseable
/// [`ta_moe::TraceLevel`] in its canonical spelling (a test round-trips
/// each one); each level includes everything the previous one records.
const TRACE_LEVEL_ROWS: &[(&str, &str)] = &[
    ("step", "one span per step plus chaos/migration/fetch marks"),
    ("phase", "adds compute/a2a/allreduce phase spans and plan hit/miss"),
    ("chunk", "adds chunk-pipeline device/channel spans and per-link rounds"),
];

/// The `--list-modes` chaos rows. Every example is a *parseable* spec in
/// its canonical spelling (a test round-trips each one), joinable with
/// `+` into one `--chaos` argument.
const CHAOS_MODE_ROWS: &[(&str, &str)] = &[
    ("off", "no fault injection (bit-identical to a run without the engine)"),
    (
        "straggler:0x2@10-20:flap=4",
        "device 0 computes 2x slower over steps [10,20), flapping every 4 steps",
    ),
    ("straggler:1x1.5@25", "device 1 permanently 1.5x slower from step 25 on"),
    ("link:1x3@30-60", "link 1's alpha/beta scaled 3x over [30,60), restored after"),
    (
        "nodeloss:3@80",
        "device 3 dies at step 80: experts evacuated, in-flight work re-homed",
    ),
    ("drift:1@40-50", "gate regime shift: expert columns rotate by 1 over [40,50)"),
];

/// The `--list-modes` what-if rows (the `--whatifs` sweep of `--analyze`).
/// Every example is a *parseable* [`ta_moe::WhatIf`] in its canonical
/// spelling (a test round-trips each one), joinable with `+`.
const WHATIF_MODE_ROWS: &[(&str, &str)] = &[
    ("link:1x2", "project the step clock with link 1 twice as fast"),
    ("dev:0x2", "project with device 0 computing twice as fast"),
    ("alpha0", "project with zero link latency (bandwidth unchanged)"),
    ("perfect-fabric", "project with free links (the compute-bound limit)"),
    ("infinite-cache", "project with every expert-weight fetch a hit (serve)"),
];

// ---------------------------------------------------------------------------
// solve
// ---------------------------------------------------------------------------

fn cmd_solve(flags: &Flags) -> Result<()> {
    let cluster = flag(flags, "cluster", "C");
    let nodes = flag_parse(flags, "nodes", 2usize)?;
    let tokens = flag_parse(flags, "tokens", 1024usize)?;
    let k = flag_parse(flags, "k", 1usize)?;
    let topo = if nodes == 0 {
        topology_for(cluster, 8)
    } else {
        ta_moe::topology::presets::by_name(cluster, nodes)
            .with_context(|| format!("unknown cluster {cluster:?}"))?
    };
    let prob = DispatchProblem { k, s: tokens, e_per_dev: 1, elem_bytes: 4096 };
    let tp = target_pattern(&topo, &prob);
    let pen = penalty_weights(&tp.c, Norm::L1);

    println!(
        "cluster {} × {} nodes: P={}, levels={}",
        cluster,
        topo.n_nodes(),
        topo.p(),
        topo.n_levels()
    );
    println!("\ntarget dispatch ĉ_0e (tokens from rank 0, Eq. 7):");
    print_row(tp.c.row(0));
    println!("penalty weights p_0e (Eq. 8):");
    print_row(pen.row(0));
    Ok(())
}

fn print_row(row: &[f64]) {
    let cells: Vec<String> = row.iter().map(|v| format!("{v:.2}")).collect();
    println!("  [{}]", cells.join(", "));
}

// ---------------------------------------------------------------------------
// profile-topo
// ---------------------------------------------------------------------------

fn cmd_profile_topo(flags: &Flags) -> Result<()> {
    let cluster = flag(flags, "cluster", "table1");
    let nodes = flag_parse(flags, "nodes", 2usize)?;
    let noise = flag_parse(flags, "noise", 0.0f64)?;
    let topo = ta_moe::topology::presets::by_name(cluster, nodes)
        .with_context(|| format!("unknown cluster {cluster:?}"))?;
    let topo = if noise > 0.0 { topo.with_noise(noise, 42) } else { topo };

    println!("cluster {cluster}: P={}, nodes={}", topo.p(), topo.n_nodes());
    let lp = smooth_levels(&topo);
    let mut t = Table::new(&["level", "pairs", "alpha (us)", "bw (GB/s)"]);
    for l in 0..lp.beta.len() {
        if lp.count[l] == 0 {
            continue;
        }
        t.row(&[
            l.to_string(),
            lp.count[l].to_string(),
            format!("{:.1}", lp.alpha[l] * 1e6),
            format!("{:.1}", 1.0 / lp.beta[l] / 1e9),
        ]);
    }
    t.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// bench-comm (Table 1)
// ---------------------------------------------------------------------------

fn cmd_bench_comm(flags: &Flags) -> Result<()> {
    let mb = flag_parse(flags, "mb", 128.0f64)?;
    let topo = ta_moe::topology::presets::table1();
    let bytes = mb * 1024.0 * 1024.0;
    let even = Mat::filled(4, 4, 0.25);
    let peer = [1usize, 0, 3, 2];
    let uneven = Mat::from_fn(4, 4, |i, j| {
        if i == j {
            0.25
        } else if j == peer[i] {
            0.5
        } else {
            0.125
        }
    });

    let mut t = Table::new(&["pattern", "0<->0", "0<->1", "0<->0'", "0<->1'", "All (us)"]);
    for (name, ratios) in [("even", &even), ("uneven", &uneven)] {
        let p = profile_exchange(&topo, bytes, ratios);
        let us: Vec<String> = p
            .rank0_times
            .iter()
            .map(|s| format!("{:.0}", s * 1e6))
            .collect();
        t.row(&[
            name.to_string(),
            us[0].clone(),
            us[1].clone(),
            us[2].clone(),
            us[3].clone(),
            format!("{:.0}", p.rank0_total * 1e6),
        ]);
    }
    t.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// info
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::{CHAOS_MODE_ROWS, TRACE_LEVEL_ROWS, WHATIF_MODE_ROWS};
    use ta_moe::perturb::ChaosSpec;
    use ta_moe::{TraceLevel, WhatIf};

    #[test]
    fn listed_trace_levels_parse_and_round_trip() {
        for (spec, _) in TRACE_LEVEL_ROWS {
            let parsed: TraceLevel = spec.parse().unwrap();
            assert_eq!(parsed.to_string(), *spec, "canonical form drifted for {spec}");
        }
        assert!("verbose".parse::<TraceLevel>().is_err());
    }

    #[test]
    fn listed_chaos_examples_parse_and_round_trip() {
        for (spec, _) in CHAOS_MODE_ROWS {
            let parsed: ChaosSpec = spec.parse().unwrap();
            assert_eq!(parsed.to_string(), *spec, "canonical form drifted for {spec}");
        }
        // the composed spelling from the help text
        let joined = "straggler:0x2@10-20:flap=4+link:1x3@30-60+nodeloss:3@80+drift:1@40-50";
        let parsed: ChaosSpec = joined.parse().unwrap();
        assert_eq!(parsed.to_string(), joined);
    }

    #[test]
    fn listed_whatif_examples_parse_and_round_trip() {
        for (spec, _) in WHATIF_MODE_ROWS {
            let parsed: WhatIf = spec.parse().unwrap();
            assert_eq!(parsed.to_string(), *spec, "canonical form drifted for {spec}");
        }
        // the composed spelling from the help text
        let joined = "link:1x2+dev:0x2+alpha0+perfect-fabric+infinite-cache";
        let ws = ta_moe::analyze::parse_whatifs(joined).unwrap();
        assert_eq!(
            ws.iter().map(|w| w.to_string()).collect::<Vec<_>>().join("+"),
            joined
        );
    }
}

fn cmd_info(flags: &Flags) -> Result<()> {
    let dir = PathBuf::from(flag(flags, "artifacts-dir", "artifacts"));
    let mut t = Table::new(&["artifact", "P", "N", "layers", "d", "gate", "dispatch", "params"]);
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .with_context(|| format!("listing {dir:?} — run `make artifacts`?"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.join("manifest.json").exists())
        .collect();
    entries.sort();
    for path in entries {
        let m = ta_moe::runtime::Manifest::load(&path)?;
        t.row(&[
            m.name.clone(),
            m.config.p.to_string(),
            m.config.n_experts.to_string(),
            m.config.layers.to_string(),
            m.config.d.to_string(),
            m.config.gate.clone(),
            m.config.dispatch.clone(),
            format!("{:.2}M", m.n_params() as f64 / 1e6),
        ]);
    }
    t.print();
    Ok(())
}
