//! The event-driven multi-resource timeline.
//!
//! A [`Timeline`] owns a set of *resources* (per-device compute streams,
//! directional link channels, the allreduce channel — the caller decides
//! the mapping) and schedules *events* against them. An event occupies
//! exactly one resource for its duration and may depend on earlier
//! events; it starts at the later of its resource's free time and its
//! slowest dependency's completion (list scheduling in submission order,
//! which for the regular chunk DAGs built by [`super::chunk`] reproduces
//! the classic flow-shop recurrence `C(c,s) = max(C(c-1,s), C(c,s-1)) +
//! d_s`). The timeline tracks, besides the makespan:
//!
//! * per-resource *busy* time — the analytic lower bound of any schedule
//!   is the busiest single resource ([`Timeline::max_busy`]);
//! * per-class activity intervals, from which [`Timeline::exposed`]
//!   measures how much of one class of work is *not* hidden under
//!   another (e.g. a2a time with no compute in flight — the "exposed
//!   communication" every overlap paper reports).

/// Index of a scheduled event, used to declare dependencies.
pub type EventId = usize;

/// One retained scheduled event: where it ran, what it was, and when.
/// Only recorded when the timeline was built with [`Timeline::recording`]
/// (the tracing path); the default constructor keeps scheduling
/// allocation-free beyond the per-resource vectors.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimelineEvent {
    pub resource: usize,
    pub class: EventClass,
    pub start_s: f64,
    pub end_s: f64,
}

/// What kind of work an event represents, for exposure accounting.
/// (Resources say *where* an event runs; the class says *what* it is.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventClass {
    Compute,
    A2a,
    Allreduce,
}

/// An event-driven schedule under construction. See the module docs.
#[derive(Debug)]
pub struct Timeline {
    /// Earliest free time per resource.
    free_at: Vec<f64>,
    /// Accumulated occupied time per resource.
    busy: Vec<f64>,
    /// Completion time per event, indexed by [`EventId`].
    end_of: Vec<f64>,
    /// `(class, start, end)` of every positive-duration event.
    intervals: Vec<(EventClass, f64, f64)>,
    makespan: f64,
    /// Event-retention mode: when true, every positive-duration event is
    /// also kept with its resource in [`Timeline::events`] (the tracer's
    /// feed). Off by default — [`Timeline::new`] stays zero-cost.
    retain: bool,
    /// Retained events, in schedule order (empty unless `retain`).
    events: Vec<TimelineEvent>,
}

impl Timeline {
    pub fn new(n_resources: usize) -> Timeline {
        Timeline {
            free_at: vec![0.0; n_resources],
            busy: vec![0.0; n_resources],
            end_of: Vec::new(),
            intervals: Vec::new(),
            makespan: 0.0,
            retain: false,
            events: Vec::new(),
        }
    }

    /// A timeline that retains per-resource events for tracing. The
    /// schedule it computes is bit-identical to [`Timeline::new`]'s —
    /// retention only copies what `schedule` already decided.
    pub fn recording(n_resources: usize) -> Timeline {
        Timeline { retain: true, ..Timeline::new(n_resources) }
    }

    /// Schedule one event on `resource` with the given dependencies.
    /// Returns its id for later `deps` lists. Zero-duration events are
    /// legal — they carry dependencies without occupying time.
    pub fn schedule(
        &mut self,
        resource: usize,
        class: EventClass,
        duration: f64,
        deps: &[EventId],
    ) -> EventId {
        debug_assert!(duration >= 0.0, "negative event duration {duration}");
        let mut start = self.free_at[resource];
        for &d in deps {
            start = start.max(self.end_of[d]);
        }
        let end = start + duration;
        self.free_at[resource] = end;
        self.busy[resource] += duration;
        if duration > 0.0 {
            self.intervals.push((class, start, end));
            if self.retain {
                self.events.push(TimelineEvent { resource, class, start_s: start, end_s: end });
            }
        }
        self.makespan = self.makespan.max(end);
        self.end_of.push(end);
        self.end_of.len() - 1
    }

    /// Retained events in schedule order (empty unless built with
    /// [`Timeline::recording`]).
    pub fn events(&self) -> &[TimelineEvent] {
        &self.events
    }

    /// Completion time of the whole schedule.
    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    /// Completion time of one event.
    pub fn end_of(&self, id: EventId) -> f64 {
        self.end_of[id]
    }

    /// Accumulated occupied time per resource.
    pub fn busy(&self) -> &[f64] {
        &self.busy
    }

    /// The busiest single resource — the analytic lower bound on the
    /// makespan of *any* schedule of these events.
    pub fn max_busy(&self) -> f64 {
        self.busy.iter().copied().fold(0.0, f64::max)
    }

    /// Sum of every event duration — the serial execution of the same
    /// events, and (for list scheduling) an upper bound on the makespan.
    pub fn serial_sum(&self) -> f64 {
        self.busy.iter().sum()
    }

    /// Per-resource critical-path blame: walk the retained event DAG
    /// backwards from the makespan, charging each critical segment to
    /// the resource that ran it. Unlike busy time, blame *partitions*
    /// the makespan — the returned per-resource seconds sum to
    /// [`Timeline::makespan`] (to fp addition error), so blame
    /// fractions answer "which resource gates the step" directly.
    ///
    /// Dependency edges are not retained, but `schedule` copies the
    /// binding constraint's completion time bit-exactly into the next
    /// event's start, so an event's predecessor on the critical path is
    /// recoverable as any retained event with `end_s == start_s`
    /// (resource-occupancy and dependency constraints both leave this
    /// signature; zero-duration barriers forward it unchanged). Ties
    /// are broken deterministically (earliest start, then lowest
    /// resource). If a start is unexplained by any retained event —
    /// possible only when the binding chain was entirely zero-duration
    /// back to the origin — the residual prefix is charged to the
    /// current resource so blame still covers the whole makespan.
    ///
    /// Requires retention ([`Timeline::recording`]); an empty event
    /// list yields all-zero blame.
    pub fn critical_blame(&self) -> Vec<f64> {
        let mut blame = vec![0.0; self.free_at.len()];
        // terminal event: latest end; ties → earliest start, lowest resource
        let last = self
            .events
            .iter()
            .max_by(|a, b| {
                a.end_s
                    .total_cmp(&b.end_s)
                    .then(b.start_s.total_cmp(&a.start_s))
                    .then(b.resource.cmp(&a.resource))
            })
            .copied();
        let mut cur = match last {
            Some(e) => e,
            None => return blame,
        };
        loop {
            blame[cur.resource] += cur.end_s - cur.start_s;
            let t = cur.start_s;
            if t <= 0.0 {
                break;
            }
            let prev = self
                .events
                .iter()
                .filter(|e| e.end_s == t)
                .min_by(|a, b| {
                    a.start_s.total_cmp(&b.start_s).then(a.resource.cmp(&b.resource))
                })
                .copied();
            match prev {
                Some(e) => cur = e,
                None => {
                    blame[cur.resource] += t;
                    break;
                }
            }
        }
        blame
    }

    /// Measure of the times where an event of `class` is running and no
    /// event of any class in `hidden_by` is — the exposed portion of that
    /// class of work.
    pub fn exposed(&self, class: EventClass, hidden_by: &[EventClass]) -> f64 {
        let target = union_of(
            self.intervals
                .iter()
                .filter(|(c, _, _)| *c == class)
                .map(|&(_, s, e)| (s, e))
                .collect(),
        );
        let hide = union_of(
            self.intervals
                .iter()
                .filter(|(c, _, _)| hidden_by.contains(c))
                .map(|&(_, s, e)| (s, e))
                .collect(),
        );
        measure_minus(&target, &hide)
    }
}

/// Sort + merge a set of intervals into a disjoint union.
fn union_of(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (s, e) in iv {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// `measure(a \ b)` for two disjoint, sorted interval unions.
fn measure_minus(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let mut total = 0.0;
    let mut bi = 0;
    for &(s, e) in a {
        let mut cur = s;
        while bi < b.len() && b[bi].1 <= cur {
            bi += 1;
        }
        let mut k = bi;
        while cur < e {
            if k >= b.len() || b[k].0 >= e {
                total += e - cur;
                break;
            }
            if b[k].0 > cur {
                total += b[k].0 - cur;
            }
            cur = cur.max(b[k].1);
            k += 1;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_of_dependencies_serialises() {
        let mut t = Timeline::new(2);
        let a = t.schedule(0, EventClass::Compute, 1.0, &[]);
        let b = t.schedule(1, EventClass::A2a, 2.0, &[a]);
        let c = t.schedule(0, EventClass::Compute, 0.5, &[b]);
        assert_eq!(t.end_of(a), 1.0);
        assert_eq!(t.end_of(b), 3.0);
        assert_eq!(t.end_of(c), 3.5);
        assert_eq!(t.makespan(), 3.5);
        assert_eq!(t.busy(), &[1.5, 2.0]);
        assert_eq!(t.serial_sum(), 3.5);
        assert_eq!(t.max_busy(), 2.0);
    }

    #[test]
    fn resource_occupancy_serialises_independent_events() {
        let mut t = Timeline::new(1);
        t.schedule(0, EventClass::A2a, 1.0, &[]);
        t.schedule(0, EventClass::A2a, 1.0, &[]); // no dep, same resource
        assert_eq!(t.makespan(), 2.0);
    }

    #[test]
    fn independent_resources_overlap() {
        let mut t = Timeline::new(2);
        t.schedule(0, EventClass::Compute, 3.0, &[]);
        t.schedule(1, EventClass::A2a, 2.0, &[]);
        assert_eq!(t.makespan(), 3.0);
        assert_eq!(t.serial_sum(), 5.0);
        // the a2a runs entirely under the compute: nothing exposed
        assert_eq!(t.exposed(EventClass::A2a, &[EventClass::Compute]), 0.0);
        // the compute's tail is not hidden by the shorter a2a
        assert_eq!(t.exposed(EventClass::Compute, &[EventClass::A2a]), 1.0);
    }

    #[test]
    fn exposed_measures_partial_overlap() {
        let mut t = Timeline::new(3);
        // compute [0, 2); a2a [1, 4) on its own channel; exposed = [2, 4)
        let c = t.schedule(0, EventClass::Compute, 2.0, &[]);
        let gate = t.schedule(2, EventClass::Compute, 1.0, &[]);
        let _ = c;
        let a = t.schedule(1, EventClass::A2a, 3.0, &[gate]);
        assert_eq!(t.end_of(a), 4.0);
        assert_eq!(t.exposed(EventClass::A2a, &[EventClass::Compute]), 2.0);
        // against nothing, the full a2a interval is exposed
        assert_eq!(t.exposed(EventClass::A2a, &[]), 3.0);
    }

    #[test]
    fn zero_duration_events_carry_deps_without_time() {
        let mut t = Timeline::new(1);
        let a = t.schedule(0, EventClass::Compute, 1.0, &[]);
        let barrier = t.schedule(0, EventClass::Compute, 0.0, &[a]);
        let b = t.schedule(0, EventClass::Compute, 1.0, &[barrier]);
        assert_eq!(t.end_of(b), 2.0);
        assert_eq!(t.makespan(), 2.0);
        // the barrier adds no interval
        assert_eq!(t.exposed(EventClass::Compute, &[]), 2.0);
    }

    #[test]
    fn recording_retains_events_without_changing_the_schedule() {
        let build = |mut t: Timeline| {
            let a = t.schedule(0, EventClass::Compute, 1.0, &[]);
            let barrier = t.schedule(0, EventClass::Compute, 0.0, &[a]);
            t.schedule(1, EventClass::A2a, 2.0, &[barrier]);
            t
        };
        let plain = build(Timeline::new(2));
        let rec = build(Timeline::recording(2));
        // same schedule, bit for bit
        assert_eq!(plain.makespan(), rec.makespan());
        assert_eq!(plain.busy(), rec.busy());
        // plain retains nothing; recording keeps positive-duration events
        assert!(plain.events().is_empty());
        assert_eq!(
            rec.events(),
            &[
                TimelineEvent {
                    resource: 0,
                    class: EventClass::Compute,
                    start_s: 0.0,
                    end_s: 1.0
                },
                TimelineEvent { resource: 1, class: EventClass::A2a, start_s: 1.0, end_s: 3.0 },
            ]
        );
        // retained durations reconcile with the busy accounting exactly
        for (r, &b) in rec.busy().iter().enumerate() {
            let sum: f64 = rec
                .events()
                .iter()
                .filter(|e| e.resource == r)
                .map(|e| e.end_s - e.start_s)
                .sum();
            assert_eq!(sum, b, "resource {r}");
        }
    }

    #[test]
    fn critical_blame_partitions_the_makespan() {
        // diamond: compute 1s on dev 0, then parallel a2a 2s (res 1) and
        // compute 0.5s (res 0), then a joining compute 1s on res 2. The
        // critical path is res0(1) → res1(2) → res2(1); res 0's short
        // second event never gates anything.
        let mut t = Timeline::recording(3);
        let a = t.schedule(0, EventClass::Compute, 1.0, &[]);
        let b = t.schedule(1, EventClass::A2a, 2.0, &[a]);
        let c = t.schedule(0, EventClass::Compute, 0.5, &[a]);
        t.schedule(2, EventClass::Compute, 1.0, &[b, c]);
        let blame = t.critical_blame();
        assert_eq!(blame, vec![1.0, 2.0, 1.0]);
        let total: f64 = blame.iter().sum();
        assert!((total - t.makespan()).abs() < 1e-12);
    }

    #[test]
    fn critical_blame_spans_zero_duration_barriers() {
        // a → barrier(0s) → b: the barrier is not retained, but the
        // back-walk recovers a through the bit-exact end==start match.
        let mut t = Timeline::recording(2);
        let a = t.schedule(0, EventClass::Compute, 1.5, &[]);
        let barrier = t.schedule(0, EventClass::Compute, 0.0, &[a]);
        t.schedule(1, EventClass::A2a, 2.5, &[barrier]);
        let blame = t.critical_blame();
        assert_eq!(blame, vec![1.5, 2.5]);
        assert_eq!(blame.iter().sum::<f64>(), t.makespan());
    }

    #[test]
    fn critical_blame_without_retention_is_zero() {
        let mut t = Timeline::new(2);
        t.schedule(0, EventClass::Compute, 1.0, &[]);
        assert_eq!(t.critical_blame(), vec![0.0, 0.0]);
        let empty = Timeline::recording(2);
        assert_eq!(empty.critical_blame(), vec![0.0, 0.0]);
    }

    #[test]
    fn interval_helpers_merge_and_subtract() {
        let u = union_of(vec![(3.0, 4.0), (0.0, 1.0), (0.5, 2.0)]);
        assert_eq!(u, vec![(0.0, 2.0), (3.0, 4.0)]);
        // [0,2)∪[3,4) minus [1,3.5) = [0,1) + [3.5,4)
        let m = measure_minus(&u, &[(1.0, 3.5)]);
        assert!((m - 1.5).abs() < 1e-15);
        assert_eq!(measure_minus(&u, &[]), 3.0);
        assert_eq!(measure_minus(&[], &u), 0.0);
    }
}
