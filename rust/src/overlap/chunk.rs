//! The chunked dispatch–compute–combine pipeline.
//!
//! [`pipeline_cost`] prices one training step as a dependency DAG of
//! chunk-granular events on a [`Timeline`] instead of a serial phase sum.
//! The step is modelled as `2 · n_moe` *MoE blocks* (each MoE layer's
//! forward pass and its backward mirror), every block being one
//! dispatch → expert-compute → combine sequence over the step's dispatch
//! byte matrix, split into `k` equal token chunks:
//!
//! * each chunk's dispatch exchange runs as an intra-node event followed
//!   by an inter-node event on the *dispatch* channels (locality-first,
//!   the BvN round ordering); its combine mirrors that on the *combine*
//!   channels. Dispatch and combine channels are distinct because the two
//!   exchanges traverse the links in opposite directions (the topology's
//!   directed `2·edge + dir` slots), so combine of chunk `c` overlaps
//!   dispatch of chunk `c+1` — the MoNTA/Parallel-Folding overlap;
//! * each chunk's expert compute runs per device on that device's
//!   compute stream (the most-loaded device gates, as in the serial
//!   model), between its dispatch and its combine;
//! * forward dense compute precedes each forward block's dispatch (the
//!   gate needs the layer input); all backward dense compute is folded
//!   into a *tail* after the last MoE block — lower layers and
//!   embedding/logit grads dominate backward FLOPs — which is the
//!   allreduce's legal overlap window: the gradient allreduce is split
//!   into `k` buckets, bucket `c` firing after tail slice `c`.
//!
//! With `k = 1` every edge of the DAG is on one chain, so the makespan is
//! *exactly* the serial phase sum; as `k` grows the schedule approaches
//! the busiest-resource bound while re-paying per-chunk latency (each
//! chunk exchange is priced on `bytes/k`, so α terms do not shrink) —
//! the tradeoff [`super::autotune_k`] sweeps.

use super::timeline::{EventClass, EventId, Timeline};
use crate::comm::A2aBreakdown;

/// Chunk counts the autotuner sweeps (and benches/tests grid over).
pub const CHUNK_SWEEP: [usize; 5] = [1, 2, 4, 8, 16];

/// Everything the pipeline needs to know about one step, independent of
/// how the exchanges are priced (the caller supplies per-chunk a2a
/// breakdowns separately, typically via the plan cache).
#[derive(Clone, Debug)]
pub struct OverlapInputs {
    /// Forward dense compute (attention, dense FFN, logits), split evenly
    /// across the forward blocks' pre-dispatch slices.
    pub dense_fwd_s: f64,
    /// Backward dense compute, folded into the post-block tail the
    /// allreduce buckets overlap.
    pub dense_bwd_s: f64,
    /// Total expert compute per device over all MoE layers, forward +
    /// backward (length P). The slowest device gates each chunk.
    pub expert_s_per_dev: Vec<f64>,
    /// MoE layers in the model; the pipeline runs `2 · n_moe` blocks.
    pub n_moe: usize,
}

/// The priced pipeline: the overlapped clock plus the analytic envelope
/// and exposure accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipelineCost {
    /// Completion time of the chunked step on the event timeline.
    pub makespan_s: f64,
    /// Sum of every event duration — executing this chunking serially.
    /// Upper-bounds the makespan; at `k = 1` it *is* the makespan.
    pub serial_sum_s: f64,
    /// Busiest single resource — the lower bound on the makespan.
    pub bound_s: f64,
    /// A2a time with no compute in flight (the exposed communication).
    pub exposed_a2a_s: f64,
    /// Allreduce time hidden under neither compute nor a2a.
    pub exposed_allreduce_s: f64,
    /// Token chunks the step was split into.
    pub chunks: usize,
}

impl PipelineCost {
    /// All communication not hidden under compute.
    pub fn exposed_comm_s(&self) -> f64 {
        self.exposed_a2a_s + self.exposed_allreduce_s
    }
}

/// Price one step as a `k`-chunk pipeline. `chunk` is the priced
/// breakdown of ONE exchange of `bytes/k` (same breakdown for dispatch
/// and combine, mirroring the serial model's convention of pricing all
/// `4 · n_moe` exchanges identically); `allreduce_chunk_s` the ring time
/// of one `1/k` gradient bucket.
pub fn pipeline_cost(
    inp: &OverlapInputs,
    chunk: &A2aBreakdown,
    allreduce_chunk_s: f64,
    k: usize,
) -> PipelineCost {
    pipeline_cost_retained(inp, chunk, allreduce_chunk_s, k, false).0
}

/// [`pipeline_cost`] plus the timeline it scheduled. With `retain` the
/// timeline keeps every event (the tracer's chunk-level feed); without,
/// this is exactly `pipeline_cost` with the timeline's busy accounting
/// still readable. The returned cost is bit-identical either way.
pub fn pipeline_cost_retained(
    inp: &OverlapInputs,
    chunk: &A2aBreakdown,
    allreduce_chunk_s: f64,
    k: usize,
    retain: bool,
) -> (PipelineCost, Timeline) {
    assert!(k >= 1, "chunk count must be >= 1");
    let p = inp.expert_s_per_dev.len();
    assert!(p >= 1, "pipeline needs at least one device");

    // resource map: P compute streams, 4 directional link channels, the
    // allreduce channel
    let disp_intra = p;
    let disp_inter = p + 1;
    let comb_intra = p + 2;
    let comb_inter = p + 3;
    let ar_chan = p + 4;
    let mut tl = if retain { Timeline::recording(p + 5) } else { Timeline::new(p + 5) };

    // exposed local copies ride the intra event (they are serial with the
    // network phase in the breakdown's convention)
    let intra_s = chunk.local_s + chunk.intra_s;
    let inter_s = chunk.inter_s;
    let kf = k as f64;

    let n_blocks = 2 * inp.n_moe;
    let dense_slice = if inp.n_moe > 0 { inp.dense_fwd_s / inp.n_moe as f64 } else { 0.0 };
    // the last events of the previous block every device must wait for
    let mut join: Vec<EventId> = Vec::new();
    let mut scratch: Vec<EventId> = Vec::with_capacity(p);
    for b in 0..n_blocks {
        let is_bwd = b >= inp.n_moe;
        // forward blocks carry their dense slice (the gate needs the
        // layer input); backward dense is all in the tail
        let slice = if is_bwd { 0.0 } else { dense_slice };
        scratch.clear();
        for dev in 0..p {
            scratch.push(tl.schedule(dev, EventClass::Compute, slice, &join));
        }
        let dense_ev = scratch.clone();
        join.clear();
        for _c in 0..k {
            let di = tl.schedule(disp_intra, EventClass::A2a, intra_s, &dense_ev);
            let dx = tl.schedule(disp_inter, EventClass::A2a, inter_s, &[di]);
            scratch.clear();
            for dev in 0..p {
                // fwd/bwd expert split: backward is 2x forward
                let e = inp.expert_s_per_dev[dev] / 3.0
                    * if is_bwd { 2.0 } else { 1.0 }
                    / inp.n_moe as f64
                    / kf;
                scratch.push(tl.schedule(dev, EventClass::Compute, e, &[dx]));
            }
            let ci = tl.schedule(comb_intra, EventClass::A2a, intra_s, &scratch);
            let cx = tl.schedule(comb_inter, EventClass::A2a, inter_s, &[ci]);
            join.push(cx);
        }
    }

    // backward dense tail in k slices, each releasing one gradient bucket
    // (a MoE-free model has no blocks, so its forward dense lands here too
    // rather than silently vanishing from the clock)
    let tail = inp.dense_bwd_s + if n_blocks == 0 { inp.dense_fwd_s } else { 0.0 };
    let tail_slice = tail / kf;
    for _c in 0..k {
        scratch.clear();
        for dev in 0..p {
            scratch.push(tl.schedule(dev, EventClass::Compute, tail_slice, &join));
        }
        join = scratch.clone();
        tl.schedule(ar_chan, EventClass::Allreduce, allreduce_chunk_s, &join);
    }

    let cost = PipelineCost {
        makespan_s: tl.makespan(),
        serial_sum_s: tl.serial_sum(),
        bound_s: tl.max_busy(),
        exposed_a2a_s: tl.exposed(EventClass::A2a, &[EventClass::Compute]),
        exposed_allreduce_s: tl
            .exposed(EventClass::Allreduce, &[EventClass::Compute, EventClass::A2a]),
        chunks: k,
    };
    (cost, tl)
}

/// Price one **forward-only** pass (an inference decode iteration) as a
/// `k`-chunk pipeline: `n_moe` blocks of dispatch → expert → combine with
/// no backward mirror, no tail, and no allreduce. `inp.expert_s_per_dev`
/// is the *forward* expert total per device (no 3× fwd/bwd folding —
/// build it via `ModelShape::overlap_inputs_profiled` with a forward-only
/// profile) and `inp.dense_bwd_s` is ignored. As in [`pipeline_cost`],
/// `chunk` prices ONE exchange of `bytes/k` and `k = 1` is exactly the
/// serial phase sum.
pub fn pipeline_cost_forward(inp: &OverlapInputs, chunk: &A2aBreakdown, k: usize) -> PipelineCost {
    pipeline_cost_forward_retained(inp, chunk, k, false).0
}

/// [`pipeline_cost_forward`] plus the timeline it scheduled; see
/// [`pipeline_cost_retained`] for the retention contract.
pub fn pipeline_cost_forward_retained(
    inp: &OverlapInputs,
    chunk: &A2aBreakdown,
    k: usize,
    retain: bool,
) -> (PipelineCost, Timeline) {
    assert!(k >= 1, "chunk count must be >= 1");
    let p = inp.expert_s_per_dev.len();
    assert!(p >= 1, "pipeline needs at least one device");

    let disp_intra = p;
    let disp_inter = p + 1;
    let comb_intra = p + 2;
    let comb_inter = p + 3;
    let mut tl = if retain { Timeline::recording(p + 4) } else { Timeline::new(p + 4) };

    let intra_s = chunk.local_s + chunk.intra_s;
    let inter_s = chunk.inter_s;
    let kf = k as f64;

    let dense_slice = if inp.n_moe > 0 { inp.dense_fwd_s / inp.n_moe as f64 } else { 0.0 };
    let mut join: Vec<EventId> = Vec::new();
    let mut scratch: Vec<EventId> = Vec::with_capacity(p);
    for _b in 0..inp.n_moe {
        scratch.clear();
        for dev in 0..p {
            scratch.push(tl.schedule(dev, EventClass::Compute, dense_slice, &join));
        }
        let dense_ev = scratch.clone();
        join.clear();
        for _c in 0..k {
            let di = tl.schedule(disp_intra, EventClass::A2a, intra_s, &dense_ev);
            let dx = tl.schedule(disp_inter, EventClass::A2a, inter_s, &[di]);
            scratch.clear();
            for dev in 0..p {
                let e = inp.expert_s_per_dev[dev] / inp.n_moe as f64 / kf;
                scratch.push(tl.schedule(dev, EventClass::Compute, e, &[dx]));
            }
            let ci = tl.schedule(comb_intra, EventClass::A2a, intra_s, &scratch);
            let cx = tl.schedule(comb_inter, EventClass::A2a, inter_s, &[ci]);
            join.push(cx);
        }
    }
    // a MoE-free model is a pure dense forward pass
    if inp.n_moe == 0 {
        for dev in 0..p {
            tl.schedule(dev, EventClass::Compute, inp.dense_fwd_s, &[]);
        }
    }

    let cost = PipelineCost {
        makespan_s: tl.makespan(),
        serial_sum_s: tl.serial_sum(),
        bound_s: tl.max_busy(),
        exposed_a2a_s: tl.exposed(EventClass::A2a, &[EventClass::Compute]),
        exposed_allreduce_s: 0.0,
        chunks: k,
    };
    (cost, tl)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(p: usize) -> OverlapInputs {
        OverlapInputs {
            dense_fwd_s: 3.0,
            dense_bwd_s: 6.0,
            expert_s_per_dev: (0..p).map(|d| 3.0 + d as f64).collect(),
            n_moe: 3,
        }
    }

    /// Serial allreduce time the tests bucket into `k` chunks.
    const AR: f64 = 4.0;

    fn chunk(intra: f64, inter: f64, k: usize) -> A2aBreakdown {
        A2aBreakdown { local_s: 0.0, intra_s: intra / k as f64, inter_s: inter / k as f64 }
    }

    #[test]
    fn k1_is_the_serial_phase_sum() {
        let inp = inputs(4);
        let (intra, inter) = (0.5, 2.0);
        let c = pipeline_cost(&inp, &chunk(intra, inter, 1), AR, 1);
        // 2·n_moe blocks × 2 exchanges × (intra + inter) + dense + slowest
        // expert + allreduce, all on one chain
        let a2a = 4.0 * inp.n_moe as f64 * (intra + inter);
        let want = inp.dense_fwd_s + inp.dense_bwd_s + 6.0 + a2a + AR;
        assert!(
            (c.makespan_s - want).abs() <= 1e-12 * want,
            "{} != {want}",
            c.makespan_s
        );
        assert!((c.serial_sum_s - c.makespan_s).abs() <= 1e-12 * want);
        assert_eq!(c.chunks, 1);
        // nothing overlaps at k = 1: the full a2a and allreduce are exposed
        assert!((c.exposed_a2a_s - a2a).abs() <= 1e-12 * a2a);
        assert!((c.exposed_allreduce_s - AR).abs() <= 1e-12);
    }

    #[test]
    fn bounds_sandwich_the_makespan_for_all_k() {
        let inp = inputs(5);
        for k in CHUNK_SWEEP {
            let c = pipeline_cost(&inp, &chunk(1.0, 4.0, k), AR / k as f64, k);
            assert!(c.bound_s <= c.makespan_s * (1.0 + 1e-12), "k={k}");
            assert!(c.makespan_s <= c.serial_sum_s * (1.0 + 1e-12), "k={k}");
            assert!(c.exposed_a2a_s >= 0.0 && c.exposed_allreduce_s >= 0.0);
            assert!(c.exposed_comm_s() <= c.makespan_s * (1.0 + 1e-12));
        }
    }

    #[test]
    fn fluid_chunking_is_monotone_and_approaches_the_bound() {
        // with per-chunk durations = phase/k (no latency re-pay, the
        // α = 0 regime) finer chunking can only help
        let inp = inputs(4);
        let mut prev = f64::INFINITY;
        let mut last = 0.0;
        for k in CHUNK_SWEEP {
            let c = pipeline_cost(&inp, &chunk(1.0, 4.0, k), AR / k as f64, k);
            assert!(
                c.makespan_s <= prev * (1.0 + 1e-12),
                "k={k}: {} > previous {prev}",
                c.makespan_s
            );
            prev = c.makespan_s;
            last = c.makespan_s;
        }
        let k1 = pipeline_cost(&inp, &chunk(1.0, 4.0, 1), AR, 1).makespan_s;
        assert!(last < k1, "chunking must strictly beat serial here");
    }

    #[test]
    fn combine_overlaps_next_chunks_dispatch() {
        // comm-only pipeline (no compute): one block, dispatch T + combine
        // T serially; chunked, combine chunk c rides under dispatch chunk
        // c+1, so the block tends to T as k grows
        let inp = OverlapInputs {
            dense_fwd_s: 0.0,
            dense_bwd_s: 0.0,
            expert_s_per_dev: vec![0.0; 4],
            n_moe: 1,
        };
        let t = 8.0;
        let serial = pipeline_cost(&inp, &chunk(0.0, t, 1), 0.0, 1).makespan_s;
        assert!((serial - 2.0 * 2.0 * t).abs() < 1e-12); // 2 blocks × (disp + comb)
        let k = 8;
        let c = pipeline_cost(&inp, &chunk(0.0, t, k), 0.0, k).makespan_s;
        // flow shop: per block ≈ t + t/k
        let want = 2.0 * (t + t / k as f64);
        assert!((c - want).abs() <= 1e-9 * want, "{c} != {want}");
    }

    #[test]
    fn allreduce_hides_under_the_backward_tail() {
        let inp = OverlapInputs {
            dense_fwd_s: 0.0,
            dense_bwd_s: 10.0,
            expert_s_per_dev: vec![0.0; 2],
            n_moe: 1,
        };
        let zero = A2aBreakdown::default();
        // k = 1: the bucket waits for the whole tail — fully exposed
        let s = pipeline_cost(&inp, &zero, 4.0, 1);
        assert!((s.exposed_allreduce_s - 4.0).abs() < 1e-12);
        // k = 4: buckets fire after each tail slice; only the last bucket
        // (1s) sticks out past the tail
        let c = pipeline_cost(&inp, &zero, 1.0, 4);
        assert!((c.exposed_allreduce_s - 1.0).abs() < 1e-12, "{:?}", c);
        assert!((c.makespan_s - 11.0).abs() < 1e-12);
    }

    #[test]
    fn forward_k1_is_the_serial_phase_sum() {
        // forward-only expert totals: no 3× folding in the inputs
        let inp = OverlapInputs {
            dense_fwd_s: 2.0,
            dense_bwd_s: 99.0, // ignored by the forward pipeline
            expert_s_per_dev: vec![1.0, 4.0, 2.0],
            n_moe: 2,
        };
        let (intra, inter) = (0.5, 1.5);
        let c = pipeline_cost_forward(&inp, &chunk(intra, inter, 1), 1);
        // n_moe blocks × 2 exchanges × (intra + inter) + dense fwd + slowest
        let a2a = 2.0 * inp.n_moe as f64 * (intra + inter);
        let want = inp.dense_fwd_s + 4.0 + a2a;
        assert!((c.makespan_s - want).abs() <= 1e-12 * want, "{} != {want}", c.makespan_s);
        assert!((c.exposed_a2a_s - a2a).abs() <= 1e-12 * a2a);
        assert_eq!(c.exposed_allreduce_s, 0.0);
    }

    #[test]
    fn forward_bounds_sandwich_for_all_k() {
        let inp = OverlapInputs {
            dense_fwd_s: 1.0,
            dense_bwd_s: 0.0,
            expert_s_per_dev: vec![2.0; 4],
            n_moe: 3,
        };
        for k in CHUNK_SWEEP {
            let c = pipeline_cost_forward(&inp, &chunk(1.0, 4.0, k), k);
            assert!(c.bound_s <= c.makespan_s * (1.0 + 1e-12), "k={k}");
            assert!(c.makespan_s <= c.serial_sum_s * (1.0 + 1e-12), "k={k}");
        }
    }

    #[test]
    fn forward_chunking_overlaps_in_the_fluid_regime() {
        let inp = OverlapInputs {
            dense_fwd_s: 1.0,
            dense_bwd_s: 0.0,
            expert_s_per_dev: vec![4.0; 4],
            n_moe: 2,
        };
        let k1 = pipeline_cost_forward(&inp, &chunk(1.0, 4.0, 1), 1).makespan_s;
        let k8 = pipeline_cost_forward(&inp, &chunk(1.0, 4.0, 8), 8).makespan_s;
        assert!(k8 < k1, "fluid forward chunking must beat serial: {k8} vs {k1}");
    }

    #[test]
    fn forward_moe_free_model_is_pure_dense() {
        let inp = OverlapInputs {
            dense_fwd_s: 5.0,
            dense_bwd_s: 0.0,
            expert_s_per_dev: vec![0.0; 2],
            n_moe: 0,
        };
        let c = pipeline_cost_forward(&inp, &A2aBreakdown::default(), 4);
        assert!((c.makespan_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn retained_variants_price_identically_and_keep_events() {
        let inp = inputs(4);
        let c = chunk(1.0, 4.0, 4);
        let plain = pipeline_cost(&inp, &c, AR / 4.0, 4);
        let (rec, tl) = pipeline_cost_retained(&inp, &c, AR / 4.0, 4, true);
        assert_eq!(plain.makespan_s, rec.makespan_s);
        assert_eq!(plain.serial_sum_s, rec.serial_sum_s);
        assert_eq!(plain.exposed_a2a_s, rec.exposed_a2a_s);
        assert!(!tl.events().is_empty());
        // retained durations reconcile with the busy accounting exactly
        for (r, &b) in tl.busy().iter().enumerate() {
            let sum: f64 = tl
                .events()
                .iter()
                .filter(|e| e.resource == r)
                .map(|e| e.end_s - e.start_s)
                .sum();
            assert!((sum - b).abs() <= 1e-12 * b.max(1.0), "resource {r}: {sum} != {b}");
        }
        // without retain, the returned timeline keeps its busy accounting
        // but no events
        let (rec2, tl2) = pipeline_cost_retained(&inp, &c, AR / 4.0, 4, false);
        assert_eq!(rec2.makespan_s, plain.makespan_s);
        assert!(tl2.events().is_empty());
        assert_eq!(tl2.busy(), tl.busy());

        let fwd = pipeline_cost_forward(&inp, &c, 4);
        let (fwd_rec, ftl) = pipeline_cost_forward_retained(&inp, &c, 4, true);
        assert_eq!(fwd.makespan_s, fwd_rec.makespan_s);
        assert!(!ftl.events().is_empty());
    }

    #[test]
    fn slowest_device_gates_expert_compute() {
        let mut inp = inputs(3);
        inp.expert_s_per_dev = vec![1.0, 1.0, 9.0];
        let c = pipeline_cost(&inp, &A2aBreakdown::default(), AR, 1);
        let want = inp.dense_fwd_s + inp.dense_bwd_s + 9.0 + AR;
        assert!((c.makespan_s - want).abs() <= 1e-12 * want);
    }
}
