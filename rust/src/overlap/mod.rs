//! Chunked dispatch–compute–combine overlap engine.
//!
//! The serial step-cost model (`StepCost::serial_total`) charges
//! `compute + a2a + allreduce` back to back, which overstates the value
//! of shrinking inter-node bytes: real MoE runtimes (FasterMoE's smart
//! scheduling, MoNTA, MoE Parallel Folding) pipeline token chunks through
//! dispatch → expert → combine and hide the gradient allreduce under the
//! backward pass, so the *slowest resource*, not the sum of phases,
//! bounds the step. This module prices that regime:
//!
//! * [`Timeline`] — an event-driven multi-resource scheduler with typed
//!   resources (per-device compute streams, intra-/inter-node link
//!   channels per transfer direction, the allreduce channel), returning
//!   the makespan plus per-resource busy and per-class exposure
//!   accounting;
//! * [`pipeline_cost`] — the chunk DAG: the dispatch byte matrix and
//!   expert FLOPs split into `k` token chunks, dispatch(c) → expert(c) →
//!   combine(c) per chunk with combine of chunk `c` overlapping dispatch
//!   of chunk `c+1`, and the allreduce bucketed over the backward tail.
//!   Per-chunk exchanges are priced on `bytes/k` through the same
//!   contention engine as the serial model (α terms re-paid per chunk);
//! * [`autotune_k`] — sweeps `k ∈ {1, 2, 4, 8, 16}` and keeps the
//!   cheapest pipeline (never above the serial clock, since `k = 1` *is*
//!   the serial clock to fp precision).
//!
//! [`OverlapMode`] is the user-facing selector threaded through
//! `SessionBuilder::overlap`, the `train.overlap` config key, and the
//! `--overlap` CLI flag; `coordinator::cost::step_cost_overlapped` wires
//! the engine into the step clock and memoises the tuned `k` through the
//! epoch-aware `PlanCache`.

mod autotune;
mod chunk;
mod timeline;

pub use autotune::{autotune_k, autotune_k_forward};
pub use chunk::{
    pipeline_cost, pipeline_cost_forward, pipeline_cost_forward_retained, pipeline_cost_retained,
    OverlapInputs, PipelineCost, CHUNK_SWEEP,
};
pub use timeline::{EventClass, EventId, Timeline, TimelineEvent};

/// How a session prices its step clock: serially (the historic model), as
/// a fixed-`k` chunk pipeline, or autotuned per dispatch pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// Phases back to back — the serial upper bound (`off` in specs).
    #[default]
    Serial,
    /// Chunked pipeline with exactly this many token chunks (`k=<n>`).
    Fixed(usize),
    /// Sweep the chunk counts per (topology, plan) and keep the winner.
    Auto,
}

impl OverlapMode {
    /// The chunk count this mode pins, if any (`Auto` resolves per step).
    pub fn fixed_k(&self) -> Option<usize> {
        match self {
            OverlapMode::Fixed(k) => Some(*k),
            _ => None,
        }
    }
}

impl std::fmt::Display for OverlapMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OverlapMode::Serial => write!(f, "serial"),
            OverlapMode::Fixed(k) => write!(f, "k={k}"),
            OverlapMode::Auto => write!(f, "auto"),
        }
    }
}

impl std::str::FromStr for OverlapMode {
    type Err = String;

    fn from_str(s: &str) -> Result<OverlapMode, String> {
        match s.trim() {
            "off" | "serial" => Ok(OverlapMode::Serial),
            "auto" => Ok(OverlapMode::Auto),
            other => match other.strip_prefix("k=") {
                Some(n) => match n.parse::<usize>() {
                    Ok(k) if k >= 1 => Ok(OverlapMode::Fixed(k)),
                    Ok(_) => Err("overlap chunk count must be >= 1".into()),
                    Err(e) => Err(format!("bad overlap chunk count {n:?}: {e}")),
                },
                None => Err(format!(
                    "unknown overlap mode {other:?} (known: off, serial, k=<n>, auto)"
                )),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_round_trip() {
        for mode in [OverlapMode::Serial, OverlapMode::Fixed(4), OverlapMode::Auto] {
            let spec = mode.to_string();
            assert_eq!(spec.parse::<OverlapMode>().unwrap(), mode, "{spec}");
        }
        // `off` is an accepted alias of the serial clock
        assert_eq!("off".parse::<OverlapMode>().unwrap(), OverlapMode::Serial);
        assert_eq!("k=16".parse::<OverlapMode>().unwrap(), OverlapMode::Fixed(16));
        assert_eq!(OverlapMode::Fixed(8).fixed_k(), Some(8));
        assert_eq!(OverlapMode::Auto.fixed_k(), None);
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in ["", "k=", "k=0", "k=two", "chunks:4", "maybe"] {
            assert!(bad.parse::<OverlapMode>().is_err(), "{bad:?} should not parse");
        }
    }
}
