//! The chunk-count autotuner.
//!
//! Chunking trades per-chunk latency (every chunk exchange re-pays the
//! path α, every gradient bucket re-pays the ring latency) against
//! pipeline overlap, so the best `k` depends on the topology, the byte
//! matrix, and the a2a plan. [`autotune_k`] sweeps
//! [`CHUNK_SWEEP`](super::CHUNK_SWEEP) with caller-supplied per-chunk
//! pricing and returns the cheapest pipeline; since `k = 1` is in the
//! sweep, the winner never prices above the serial clock. The per-step
//! memoisation of the winner (keyed on the byte-matrix fingerprint,
//! invalidated by topology changes and placement epochs) lives in
//! `coordinator::cost::PlanCache`.

use super::chunk::{pipeline_cost, pipeline_cost_forward, OverlapInputs, PipelineCost, CHUNK_SWEEP};
use crate::comm::A2aBreakdown;

/// Sweep the chunk counts and return `(k, cost)` of the cheapest
/// pipeline. `chunk_of(k)` must return the priced breakdown of one
/// exchange of `bytes/k` and the ring time of one `1/k` gradient bucket.
/// Near-ties (within 1e-9 relative) keep the smaller `k` — less
/// launch/synchronisation overhead for the same clock.
pub fn autotune_k(
    inp: &OverlapInputs,
    mut chunk_of: impl FnMut(usize) -> (A2aBreakdown, f64),
) -> (usize, PipelineCost) {
    let mut best: Option<(usize, PipelineCost)> = None;
    for k in CHUNK_SWEEP {
        let (chunk, ar_chunk) = chunk_of(k);
        let cost = pipeline_cost(inp, &chunk, ar_chunk, k);
        let better = match &best {
            None => true,
            Some((_, b)) => cost.makespan_s < b.makespan_s * (1.0 - 1e-9),
        };
        if better {
            best = Some((k, cost));
        }
    }
    best.expect("CHUNK_SWEEP is non-empty")
}

/// [`autotune_k`] over the forward-only pipeline
/// ([`pipeline_cost_forward`]) — the decode-iteration variant the serving
/// simulator tunes. `chunk_of(k)`'s allreduce component is ignored
/// (forward passes run none).
pub fn autotune_k_forward(
    inp: &OverlapInputs,
    mut chunk_of: impl FnMut(usize) -> (A2aBreakdown, f64),
) -> (usize, PipelineCost) {
    let mut best: Option<(usize, PipelineCost)> = None;
    for k in CHUNK_SWEEP {
        let (chunk, _ar) = chunk_of(k);
        let cost = pipeline_cost_forward(inp, &chunk, k);
        let better = match &best {
            None => true,
            Some((_, b)) => cost.makespan_s < b.makespan_s * (1.0 - 1e-9),
        };
        if better {
            best = Some((k, cost));
        }
    }
    best.expect("CHUNK_SWEEP is non-empty")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inp(expert: f64) -> OverlapInputs {
        OverlapInputs {
            dense_fwd_s: 0.1,
            dense_bwd_s: 0.2,
            expert_s_per_dev: vec![expert; 4],
            n_moe: 2,
        }
    }

    /// α-β per-chunk pricing: each chunk exchange costs `alpha + beta/k`.
    fn pricer(
        alpha: f64,
        inter: f64,
        ar: f64,
    ) -> impl FnMut(usize) -> (A2aBreakdown, f64) {
        move |k| {
            let kf = k as f64;
            (
                A2aBreakdown {
                    local_s: 0.0,
                    intra_s: 0.0,
                    inter_s: alpha + inter / kf,
                },
                ar / kf,
            )
        }
    }

    #[test]
    fn alpha_dominated_steps_stay_serial() {
        // chunking only re-pays latency here: the winner must be k = 1
        let (k, cost) = autotune_k(&inp(0.01), &mut pricer(1.0, 0.01, 0.5));
        assert_eq!(k, 1);
        assert_eq!(cost.chunks, 1);
    }

    #[test]
    fn bandwidth_dominated_steps_chunk() {
        // big payloads, tiny α: pipelining wins and the winner beats serial
        let mut price = pricer(1e-3, 4.0, 0.5);
        let (k, cost) = autotune_k(&inp(2.0), &mut price);
        assert!(k > 1, "expected chunking to win, got k={k}");
        let (c1, ar1) = price(1);
        let serial = pipeline_cost(&inp(2.0), &c1, ar1, 1);
        assert!(cost.makespan_s < serial.makespan_s);
    }

    #[test]
    fn winner_never_prices_above_serial() {
        // k = 1 is in the sweep, so the tuned clock is ≤ the serial clock
        for (alpha, inter) in [(0.5, 0.1), (1e-3, 8.0), (0.1, 0.1)] {
            let mut price = pricer(alpha, inter, 0.5);
            let (_, cost) = autotune_k(&inp(1.0), &mut price);
            let (c1, ar1) = price(1);
            let serial = pipeline_cost(&inp(1.0), &c1, ar1, 1);
            assert!(
                cost.makespan_s <= serial.makespan_s * (1.0 + 1e-9),
                "alpha={alpha} inter={inter}"
            );
        }
    }
}
