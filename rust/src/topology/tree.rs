//! Hierarchical tree topologies (paper §3.2, Figure 2 c/d).
//!
//! The paper writes trees as nested lists: `[2,2]` is a 2-layer symmetric
//! tree (a root switch with 2 leaf switches of 2 devices each);
//! `[[2,2],[2]]` is the 3-layer asymmetric example of Figure 2(d).
//! [`TreeSpec`] parses exactly that notation.
//!
//! The builder elaborates a spec into the explicit link graph:
//! every device hangs off its leaf switch via a device link
//! (`level_links[0]`, e.g. NVLink/NVSwitch), every non-root switch hangs
//! off its parent via an uplink whose parameters come from the child's
//! height (`level_links[h]`, e.g. the RoCE NIC at h = 1). End-to-end α is
//! the sum over traversed links, end-to-end β the max (slowest hop
//! dominates, §3.2).

use super::{DirLink, Link, Topology, TopologyKind};
use crate::util::Mat;

/// Nested-list tree specification in the paper's notation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TreeSpec {
    /// A leaf switch with `n` devices directly attached (`2` in the paper's
    /// notation).
    Devices(usize),
    /// An internal switch with child sub-trees (`[...]`).
    Switch(Vec<TreeSpec>),
}

impl TreeSpec {
    /// Parse the paper's nested-list notation, e.g. `"[[2,2],[2]]"`.
    pub fn parse(s: &str) -> Result<TreeSpec, String> {
        let mut chars = s.chars().filter(|c| !c.is_whitespace()).peekable();
        let spec = Self::parse_node(&mut chars)?;
        if chars.next().is_some() {
            return Err(format!("trailing characters in tree spec {s:?}"));
        }
        Ok(spec)
    }

    fn parse_node(
        it: &mut std::iter::Peekable<impl Iterator<Item = char>>,
    ) -> Result<TreeSpec, String> {
        match it.peek() {
            Some('[') => {
                it.next();
                let mut children = Vec::new();
                loop {
                    match it.peek() {
                        Some(']') => {
                            it.next();
                            break;
                        }
                        Some(',') => {
                            it.next();
                        }
                        Some(_) => children.push(Self::parse_node(it)?),
                        None => return Err("unterminated '['".into()),
                    }
                }
                if children.is_empty() {
                    return Err("empty switch '[]'".into());
                }
                // A list of plain integers like `[2,2]` means "switch whose
                // children are leaf switches with that many devices".
                Ok(TreeSpec::Switch(children))
            }
            Some(c) if c.is_ascii_digit() => {
                let mut n = 0usize;
                while let Some(c) = it.peek() {
                    if let Some(d) = c.to_digit(10) {
                        n = n * 10 + d as usize;
                        it.next();
                    } else {
                        break;
                    }
                }
                if n == 0 {
                    return Err("zero-device leaf".into());
                }
                Ok(TreeSpec::Devices(n))
            }
            other => Err(format!("unexpected {other:?} in tree spec")),
        }
    }

    /// Symmetric n-layer tree from per-level child counts, paper's
    /// `[L_0, L_1, ...]` with `L_last` devices per leaf switch. E.g.
    /// `symmetric(&[2, 2])` == `parse("[2,2]")`.
    pub fn symmetric(levels: &[usize]) -> TreeSpec {
        assert!(!levels.is_empty());
        if levels.len() == 1 {
            TreeSpec::Devices(levels[0])
        } else {
            TreeSpec::Switch(
                (0..levels[0])
                    .map(|_| TreeSpec::symmetric(&levels[1..]))
                    .collect(),
            )
        }
    }

    /// Total devices under this (sub-)tree.
    pub fn n_devices(&self) -> usize {
        match self {
            TreeSpec::Devices(n) => *n,
            TreeSpec::Switch(cs) => cs.iter().map(|c| c.n_devices()).sum(),
        }
    }

    /// Height: a leaf switch has height 1.
    pub fn height(&self) -> usize {
        match self {
            TreeSpec::Devices(_) => 1,
            TreeSpec::Switch(cs) => 1 + cs.iter().map(|c| c.height()).max().unwrap(),
        }
    }

    /// Is the tree symmetric (all siblings identical at every level)?
    pub fn is_symmetric(&self) -> bool {
        match self {
            TreeSpec::Devices(_) => true,
            TreeSpec::Switch(cs) => {
                cs.iter().all(|c| c == &cs[0]) && cs[0].is_symmetric()
            }
        }
    }

    /// Device-group sizes of the leaf switches, left to right.
    pub fn leaf_groups(&self) -> Vec<usize> {
        match self {
            TreeSpec::Devices(n) => vec![*n],
            TreeSpec::Switch(cs) => cs.iter().flat_map(|c| c.leaf_groups()).collect(),
        }
    }

    /// The paper's §4.2 asymmetric→symmetric transformation: "merge the
    /// separate nodes into the close symmetric sub-trees". All leaf device
    /// groups are re-attached directly under a single root, e.g.
    /// `[[2,2],[2]] → [[2,2,2]]` (Figure 2(d) example).
    pub fn merge_to_symmetric(&self) -> TreeSpec {
        if self.is_symmetric() {
            return self.clone();
        }
        TreeSpec::Switch(self.leaf_groups().into_iter().map(TreeSpec::Devices).collect())
    }
}

impl std::fmt::Display for TreeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TreeSpec::Devices(n) => write!(f, "{n}"),
            TreeSpec::Switch(cs) => {
                write!(f, "[")?;
                for (i, c) in cs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{c}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Elaborated switch graph used during construction.
struct Builder {
    /// parent switch of each switch (root: usize::MAX)
    parent: Vec<usize>,
    /// height of each switch (leaf switch = 1)
    height: Vec<usize>,
    /// uplink edge id of each switch (to its parent; root: usize::MAX)
    uplink: Vec<usize>,
    /// leaf switch of each device
    dev_switch: Vec<usize>,
    /// device link edge id of each device
    dev_edge: Vec<usize>,
    links: Vec<Link>,
    /// device links are non-blocking point-to-point (false); switch
    /// uplinks are shared media (true)
    contended: Vec<bool>,
}

impl Builder {
    fn link_at(&self, level_links: &[Link], h: usize) -> Link {
        *level_links
            .get(h.min(level_links.len() - 1))
            .expect("level_links non-empty")
    }

    fn add(&mut self, spec: &TreeSpec, level_links: &[Link]) -> usize {
        match spec {
            TreeSpec::Devices(n) => {
                let sw = self.parent.len();
                self.parent.push(usize::MAX);
                self.height.push(1);
                self.uplink.push(usize::MAX);
                for _ in 0..*n {
                    let e = self.links.len();
                    self.links.push(self.link_at(level_links, 0));
                    self.contended.push(false);
                    self.dev_switch.push(sw);
                    self.dev_edge.push(e);
                }
                sw
            }
            TreeSpec::Switch(cs) => {
                let children: Vec<usize> =
                    cs.iter().map(|c| self.add(c, level_links)).collect();
                let sw = self.parent.len();
                let h = 1 + children.iter().map(|&c| self.height[c]).max().unwrap();
                self.parent.push(usize::MAX);
                self.height.push(h);
                self.uplink.push(usize::MAX);
                for &c in &children {
                    let e = self.links.len();
                    self.links.push(self.link_at(level_links, self.height[c]));
                    self.contended.push(true);
                    self.parent[c] = sw;
                    self.uplink[c] = e;
                }
                sw
            }
        }
    }

    /// Chain of switches from a device's leaf switch up to the root.
    fn chain(&self, dev: usize) -> Vec<usize> {
        let mut v = vec![self.dev_switch[dev]];
        while self.parent[*v.last().unwrap()] != usize::MAX {
            v.push(self.parent[*v.last().unwrap()]);
        }
        v
    }
}

pub(super) fn build(spec: &TreeSpec, level_links: &[Link], local: Link) -> Topology {
    assert!(!level_links.is_empty(), "need at least the device link level");
    let mut b = Builder {
        parent: Vec::new(),
        height: Vec::new(),
        uplink: Vec::new(),
        dev_switch: Vec::new(),
        dev_edge: Vec::new(),
        links: Vec::new(),
        contended: Vec::new(),
    };
    b.add(spec, level_links);
    let p = b.dev_switch.len();
    assert!(p >= 1, "tree has no devices");

    let mut alpha = Mat::zeros(p, p);
    let mut beta = Mat::zeros(p, p);
    let mut level = vec![0usize; p * p];
    let mut paths = vec![Vec::new(); p * p];

    // node ids: compact leaf-switch ids in first-seen order (BTreeMap for
    // the crate-wide ordered-collections rule; assignment is first-seen via
    // `entry`, so the ids are deterministic by construction)
    let mut node_ids = std::collections::BTreeMap::new();
    let node_of: Vec<usize> = (0..p)
        .map(|d| {
            let sw = b.dev_switch[d];
            let next = node_ids.len();
            *node_ids.entry(sw).or_insert(next)
        })
        .collect();

    for i in 0..p {
        let ci = b.chain(i);
        for j in 0..p {
            if i == j {
                alpha.set(i, j, local.alpha);
                beta.set(i, j, local.beta);
                continue;
            }
            let cj = b.chain(j);
            // lowest common ancestor: first switch of ci present in cj
            let (mut ai, mut aj) = (0usize, 0usize);
            'outer: for (xi, sw) in ci.iter().enumerate() {
                for (xj, sw2) in cj.iter().enumerate() {
                    if sw == sw2 {
                        ai = xi;
                        aj = xj;
                        break 'outer;
                    }
                }
            }
            // path: device link up, uplinks up to (not incl.) LCA, then down
            let mut path = vec![DirLink { edge: b.dev_edge[i], up: true }];
            for &sw in &ci[..ai] {
                path.push(DirLink { edge: b.uplink[sw], up: true });
            }
            for &sw in cj[..aj].iter().rev() {
                path.push(DirLink { edge: b.uplink[sw], up: false });
            }
            path.push(DirLink { edge: b.dev_edge[j], up: false });

            let a_sum: f64 = path.iter().map(|dl| b.links[dl.edge].alpha).sum();
            let b_max: f64 = path
                .iter()
                .map(|dl| b.links[dl.edge].beta)
                .fold(0.0, f64::max);
            alpha.set(i, j, a_sum);
            beta.set(i, j, b_max);
            // pair level: 1 = same leaf switch; +1 per level the path climbs
            level[i * p + j] = 1 + ai.max(aj);
            paths[i * p + j] = path;
        }
    }

    Topology {
        p,
        kind: TopologyKind::Tree { spec: spec.clone(), symmetric: spec.is_symmetric() },
        alpha,
        beta,
        level,
        node_of,
        links: b.links,
        link_contended: b.contended,
        paths,
        path_off: Vec::new(),
        path_slots: Vec::new(),
        slot_alpha: Vec::new(),
        slot_beta: Vec::new(),
        slot_contended: Vec::new(),
        alive: vec![true; p],
    }
    .with_incidence()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn links() -> Vec<Link> {
        vec![
            Link::new(1e-6, 1e-11),  // device link: 100 GB/s
            Link::new(5e-6, 1e-10),  // switch uplink: 10 GB/s
            Link::new(1e-5, 1e-9),   // higher level: 1 GB/s
        ]
    }

    #[test]
    fn parse_round_trips() {
        for s in ["[2,2]", "[[2,2],[2]]", "[8,8,8]", "[[4],[4],[4],[4]]"] {
            let spec = TreeSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(TreeSpec::parse("[]").is_err());
        assert!(TreeSpec::parse("[2,").is_err());
        assert!(TreeSpec::parse("2]").is_err());
        assert!(TreeSpec::parse("[0]").is_err());
        assert!(TreeSpec::parse("abc").is_err());
    }

    #[test]
    fn symmetric_builder_matches_notation() {
        assert_eq!(TreeSpec::symmetric(&[2, 2]), TreeSpec::parse("[2,2]").unwrap());
        assert!(TreeSpec::parse("[2,2]").unwrap().is_symmetric());
        assert!(!TreeSpec::parse("[[2,2],[2]]").unwrap().is_symmetric());
    }

    #[test]
    fn figure2d_merges_to_figure2c_shape() {
        // The paper's example: [[2,2],[2]] merges into [[2,2,2]] ≡ [3·2].
        let spec = TreeSpec::parse("[[2,2],[2]]").unwrap();
        let merged = spec.merge_to_symmetric();
        assert_eq!(merged, TreeSpec::parse("[2,2,2]").unwrap());
        assert!(merged.is_symmetric());
        assert_eq!(merged.n_devices(), spec.n_devices());
    }

    #[test]
    fn two_level_tree_betas() {
        // [2,2]: intra-node pairs see the device link, inter-node pairs the
        // slow uplink.
        let spec = TreeSpec::parse("[2,2]").unwrap();
        let t = Topology::tree(&spec, &links(), Link::new(0.0, 1e-12));
        assert_eq!(t.p(), 4);
        assert_eq!(t.beta(0, 1), 1e-11);
        assert_eq!(t.beta(0, 2), 1e-10);
        assert_eq!(t.beta(2, 3), 1e-11);
        assert_eq!(t.level(0, 1), 1);
        assert_eq!(t.level(0, 2), 2);
        assert_eq!(t.node_of(0), t.node_of(1));
        assert_ne!(t.node_of(0), t.node_of(2));
    }

    #[test]
    fn leaf_node_ids_are_first_seen_and_reproducible() {
        // Regression: leaf-switch ids were assigned through a HashMap;
        // first-seen assignment via `entry` was already deterministic, but
        // the ordered map pins the invariant mechanically. Ids must be
        // compact, start at 0, and be identical across rebuilds.
        let spec = TreeSpec::parse("[2,3,2]").unwrap();
        let t1 = Topology::tree(&spec, &links(), Link::new(0.0, 1e-12));
        let t2 = Topology::tree(&spec, &links(), Link::new(0.0, 1e-12));
        let ids1: Vec<usize> = (0..t1.p()).map(|d| t1.node_of(d)).collect();
        let ids2: Vec<usize> = (0..t2.p()).map(|d| t2.node_of(d)).collect();
        assert_eq!(ids1, ids2);
        assert_eq!(ids1[0], 0, "first device maps to node 0");
        for w in ids1.windows(2) {
            // first-seen order over contiguous leaf groups: ids never skip
            assert!(w[1] == w[0] || w[1] == w[0] + 1, "ids {ids1:?}");
        }
    }

    #[test]
    fn alpha_accumulates_over_hops() {
        let spec = TreeSpec::parse("[2,2]").unwrap();
        let t = Topology::tree(&spec, &links(), Link::new(0.0, 1e-12));
        // intra-node: two device links
        assert!((t.alpha(0, 1) - 2e-6).abs() < 1e-12);
        // inter-node: two device links + two uplinks
        assert!((t.alpha(0, 2) - (2e-6 + 2.0 * 5e-6)).abs() < 1e-12);
    }

    #[test]
    fn paths_share_uplink_edges() {
        // Both 0→2 and 1→3 cross the same two uplink edges in [2,2] — the
        // contention the comm engine models.
        let spec = TreeSpec::parse("[2,2]").unwrap();
        let t = Topology::tree(&spec, &links(), Link::new(0.0, 1e-12));
        let p02: Vec<usize> = t.path(0, 2).iter().map(|d| d.edge).collect();
        let p13: Vec<usize> = t.path(1, 3).iter().map(|d| d.edge).collect();
        let shared: Vec<_> = p02.iter().filter(|e| p13.contains(e)).collect();
        assert_eq!(shared.len(), 2);
    }

    #[test]
    fn asymmetric_tree_levels() {
        // [[2,2],[2]]: devices 0..3 under the deep branch, 4..5 shallow.
        let spec = TreeSpec::parse("[[2,2],[2]]").unwrap();
        let t = Topology::tree(&spec, &links(), Link::new(0.0, 1e-12));
        assert_eq!(t.p(), 6);
        assert_eq!(t.level(0, 1), 1); // same leaf
        assert_eq!(t.level(0, 2), 2); // across the [2,2] sub-root
        assert_eq!(t.level(0, 4), 3); // across the global root
        assert_eq!(t.level(4, 5), 1);
        assert_eq!(t.n_levels(), 3);
        assert_eq!(t.n_nodes(), 3);
    }

    #[test]
    fn device_count_matches_spec() {
        for s in ["[2,2]", "[[2,2],[2]]", "[4,2]", "[2,2,2]"] {
            let spec = TreeSpec::parse(s).unwrap();
            let t = Topology::tree(&spec, &links(), Link::new(0.0, 1e-12));
            assert_eq!(t.p(), spec.n_devices());
        }
    }
}
