//! Topology presets for the paper's testbeds (Table 2) and the Table 1
//! motivation experiment.
//!
//! Bandwidth/latency numbers come from public hardware specs and are tuned
//! so the Table-1 micro-benchmark reproduces the paper's measurements under
//! the contention exchange model (see `benches/table1_uneven.rs`):
//!
//! * local copy      ≈ 222 GB/s (the paper's 0↔0 at 128/4 MB in 144 µs)
//! * NVLink pair     ≈ 45 GB/s  (0↔1: 32 MB in 758 µs)
//! * NVSwitch (A100) ≈ 235 GB/s per pair
//! * node uplink     ≈ 23 GB/s on the Table-1 cluster (0↔0̂: 32 MB in
//!   5609 µs with 4 flows sharing each uplink), 25 GB/s on cluster A
//!   (100 Gb/s RoCE / 4 GPUs × 2 NICs), 12.5 GB/s on clusters B/C
//!   (100 Gb/s / 8 GPUs).
//!
//! The paper's clusters: A = 8×A100/node, NVSwitch, asymmetric multi-switch;
//! B = 8×V100/node, NVLink, all nodes on one switch (symmetric); C =
//! 8×V100/node, many switches (asymmetric, the contention-heavy testbed).

use super::{Link, Topology, TreeSpec};

/// Local (same-device) copy: no network, just HBM bandwidth.
pub fn local_copy() -> Link {
    Link::new(2e-6, 1.0 / 222e9)
}

/// The [[0,1],[0̂,1̂]] topology of Table 1.
pub fn table1() -> Topology {
    let spec = TreeSpec::parse("[2,2]").unwrap();
    Topology::tree(
        &spec,
        &[
            Link::from_gbps_us(45.0, 2.0),  // NVLink device link
            Link::from_gbps_us(23.0, 10.0), // node uplink
        ],
        local_copy(),
    )
}

/// Cluster A: 8 × A100 per node, NVSwitch intra-node, asymmetric
/// inter-node switching. `n_nodes` ∈ 1..=8 (paper runs 8–64 experts).
pub fn cluster_a(n_nodes: usize) -> Topology {
    cluster(
        n_nodes,
        8,
        Link::from_gbps_us(235.0, 2.0), // NVSwitch
        Link::from_gbps_us(25.0, 10.0), // 100 Gb/s RoCE per 4 GPUs (2 NICs)
        Link::from_gbps_us(20.0, 15.0), // second-level switch
        /*symmetric=*/ false,
    )
}

/// Cluster B: 8 × V100 per node, NVLink intra-node, **all nodes on the
/// same switch** (symmetric 2-level tree).
pub fn cluster_b(n_nodes: usize) -> Topology {
    cluster(
        n_nodes,
        8,
        Link::from_gbps_us(45.0, 2.0),  // NVLink
        Link::from_gbps_us(12.5, 15.0), // 100 Gb/s RoCE / 8 GPUs
        Link::from_gbps_us(12.5, 15.0),
        /*symmetric=*/ true,
    )
}

/// Cluster C: like B but across many switches with a slower spine —
/// the paper's contention-heavy testbed where TA-MoE gains most.
pub fn cluster_c(n_nodes: usize) -> Topology {
    cluster(
        n_nodes,
        8,
        Link::from_gbps_us(45.0, 2.0),
        Link::from_gbps_us(12.5, 15.0),
        Link::from_gbps_us(8.0, 25.0), // congested spine
        /*symmetric=*/ false,
    )
}

/// Look up a preset by name ("A"/"B"/"C" or "table1").
pub fn by_name(name: &str, n_nodes: usize) -> Option<Topology> {
    match name.to_ascii_uppercase().as_str() {
        "A" => Some(cluster_a(n_nodes)),
        "B" => Some(cluster_b(n_nodes)),
        "C" => Some(cluster_c(n_nodes)),
        "TABLE1" => Some(table1()),
        _ => None,
    }
}

fn cluster(
    n_nodes: usize,
    gpus: usize,
    dev: Link,
    uplink: Link,
    spine: Link,
    symmetric: bool,
) -> Topology {
    assert!(n_nodes >= 1);
    let spec = if n_nodes == 1 {
        TreeSpec::Devices(gpus)
    } else if symmetric || n_nodes == 2 {
        // all leaf switches under one spine switch
        TreeSpec::Switch((0..n_nodes).map(|_| TreeSpec::Devices(gpus)).collect())
    } else {
        // asymmetric: first half of the nodes share a pod switch, the rest
        // hang off the spine directly — e.g. 4 nodes → [[8,8],[8],[8]]
        // (the Figure 2(d) shape at cluster scale).
        let pod = n_nodes / 2;
        let mut children = vec![TreeSpec::Switch(
            (0..pod).map(|_| TreeSpec::Devices(gpus)).collect(),
        )];
        for _ in pod..n_nodes {
            children.push(TreeSpec::Switch(vec![TreeSpec::Devices(gpus)]));
        }
        TreeSpec::Switch(children)
    };
    Topology::tree(&spec, &[dev, uplink, spine], local_copy())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_link_speeds() {
        let t = table1();
        assert_eq!(t.p(), 4);
        // intra-pair raw time for 32 MB ≈ 713 µs + α (paper: 758 µs —
        // the difference is send/recv overhead, absorbed into α here).
        let bytes = 32.0 * 1024.0 * 1024.0 * 4.0 / 4.0; // placeholder math kept simple
        let _ = bytes;
        let t01 = t.alpha(0, 1) + t.beta(0, 1) * 32e6;
        assert!(t01 > 6e-4 && t01 < 8e-4, "{t01}");
        // local copy ≈ 144 µs for 32 MB
        let t00 = t.alpha(0, 0) + t.beta(0, 0) * 32e6;
        assert!(t00 > 1.2e-4 && t00 < 1.7e-4, "{t00}");
    }

    #[test]
    fn cluster_b_is_symmetric_tree() {
        let t = cluster_b(4);
        assert_eq!(t.p(), 32);
        assert_eq!(t.n_nodes(), 4);
        match t.kind() {
            super::super::TopologyKind::Tree { symmetric, .. } => assert!(symmetric),
            k => panic!("unexpected kind {k:?}"),
        }
        assert_eq!(t.n_levels(), 2);
    }

    #[test]
    fn cluster_c_is_asymmetric_with_spine_level() {
        let t = cluster_c(4);
        assert_eq!(t.p(), 32);
        assert_eq!(t.n_nodes(), 4);
        match t.kind() {
            super::super::TopologyKind::Tree { symmetric, .. } => assert!(!symmetric),
            k => panic!("unexpected kind {k:?}"),
        }
        // cross-pod traffic is slower than intra-pod inter-node traffic
        assert!(t.beta(0, 31) > t.beta(0, 15));
    }

    #[test]
    fn single_node_has_no_uplink_level() {
        for t in [cluster_a(1), cluster_b(1), cluster_c(1)] {
            assert_eq!(t.p(), 8);
            assert_eq!(t.n_levels(), 1);
            assert_eq!(t.n_nodes(), 1);
        }
    }

    #[test]
    fn by_name_resolves() {
        assert_eq!(by_name("a", 2).unwrap().p(), 16);
        assert_eq!(by_name("B", 2).unwrap().p(), 16);
        assert_eq!(by_name("table1", 0).unwrap().p(), 4);
        assert!(by_name("nope", 1).is_none());
    }

    #[test]
    fn inter_node_slower_than_intra() {
        for t in [cluster_a(2), cluster_b(2), cluster_c(2)] {
            assert!(t.beta(0, 8) > t.beta(0, 1) * 1.5);
        }
    }
}
