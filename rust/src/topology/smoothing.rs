//! Eq. 5 hierarchical smoothing of profiled link parameters.
//!
//! Raw profiled `α_ij`, `β_ij` matrices are noisy and over-parameterised; on
//! (near-)hierarchical topologies every pair at the same level `t` shares the
//! same physical bottleneck, so the paper collapses them to per-level values
//!
//! ```text
//! α_l = Σ_{i<j} 1(j ∈ G_l^i) α_ij / #pairs(l)      (and likewise β_l)
//! ```
//!
//! and re-expands them to hierarchical matrices `α̂_ij = α_level(i,j)`
//! (Eq. 5). This "precisely characterises the underlying topology and
//! eliminates the noise of profiling" — demonstrated by
//! `tests::smoothing_removes_profiler_noise` below.

use super::Topology;

/// Per-level α/β (index = pair level; level 0 = local copy).
#[derive(Clone, Debug, PartialEq)]
pub struct LevelParams {
    pub alpha: Vec<f64>,
    pub beta: Vec<f64>,
    /// Number of ordered pairs contributing to each level.
    pub count: Vec<usize>,
}

/// Compute per-level averages of the topology's α/β matrices (Eq. 5).
pub fn smooth_levels(topo: &Topology) -> LevelParams {
    let n = topo.n_levels() + 1;
    let mut alpha = vec![0.0; n];
    let mut beta = vec![0.0; n];
    let mut count = vec![0usize; n];
    for i in 0..topo.p() {
        for j in 0..topo.p() {
            let l = topo.level(i, j);
            alpha[l] += topo.alpha(i, j);
            beta[l] += topo.beta(i, j);
            count[l] += 1;
        }
    }
    for l in 0..n {
        if count[l] > 0 {
            alpha[l] /= count[l] as f64;
            beta[l] /= count[l] as f64;
        }
    }
    LevelParams { alpha, beta, count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Link, Topology, TreeSpec};

    fn tree22() -> Topology {
        let spec = TreeSpec::parse("[2,2]").unwrap();
        Topology::tree(
            &spec,
            &[Link::new(1e-6, 1e-11), Link::new(5e-6, 1e-10)],
            Link::new(0.0, 1e-12),
        )
    }

    #[test]
    fn clean_tree_levels_are_exact() {
        let lp = smooth_levels(&tree22());
        assert_eq!(lp.beta.len(), 3);
        assert!((lp.beta[0] - 1e-12).abs() < 1e-18); // local
        assert!((lp.beta[1] - 1e-11).abs() < 1e-17); // intra-node
        assert!((lp.beta[2] - 1e-10).abs() < 1e-16); // inter-node
        assert_eq!(lp.count[1], 4); // 2 ordered pairs per node × 2 nodes
        assert_eq!(lp.count[2], 8); // 4 cross pairs × 2 directions
    }

    #[test]
    fn smoothing_removes_profiler_noise() {
        // Perturb per-pair values by ±20% and check the level averages land
        // much closer to truth than the worst single measurement — Eq. 5's
        // purpose.
        let clean = tree22();
        let noisy = clean.with_noise(0.2, 7);
        let lp = smooth_levels(&noisy);
        let rel = |a: f64, b: f64| (a - b).abs() / b;
        let worst_pair_err = (0..4)
            .flat_map(|i| (0..4).map(move |j| (i, j)))
            .filter(|&(i, j)| i != j)
            .map(|(i, j)| rel(noisy.beta(i, j), clean.beta(i, j)))
            .fold(0.0, f64::max);
        assert!(rel(lp.beta[2], 1e-10) < worst_pair_err);
        assert!(rel(lp.beta[2], 1e-10) < 0.15);
    }

    #[test]
    fn smoothed_topology_is_level_constant() {
        let noisy = tree22().with_noise(0.3, 11);
        let s = noisy.smoothed();
        // all pairs at the same level share identical α̂/β̂
        assert_eq!(s.beta(0, 2), s.beta(1, 3));
        assert_eq!(s.beta(0, 1), s.beta(2, 3));
        assert_eq!(s.alpha(0, 2), s.alpha(2, 0));
    }

    #[test]
    fn homogeneous_smoothing_is_identity_without_noise() {
        let t = Topology::homogeneous(4, Link::new(1e-6, 1e-9), Link::new(0.0, 1e-12));
        let s = t.smoothed();
        assert!(t.beta_mat().linf_dist(s.beta_mat()) < 1e-18);
    }
}
