//! Ring topologies (paper §3.2, Figure 2(b): NVLink rings).
//!
//! Devices sit on a cycle; `links[i]` joins device `i` and `(i+1) % p`.
//! Adjacent-link bandwidths may differ (different NVLink lane counts).
//! Non-adjacent traffic hops through intermediate devices, so the slowest
//! traversed link bottlenecks β while latencies accumulate — exactly the
//! "communication of nonadjacent devices has to hop through intermediate
//! devices and the slowest link may become the bottleneck" behaviour the
//! paper describes. The pair level used by Eq. 5 smoothing is the hop
//! distance (the ring's "hierarchical characteristic", §4.2).

use super::{DirLink, Link, Topology, TopologyKind};
use crate::util::Mat;

pub(super) fn build(links_ring: Vec<Link>, local: Link) -> Topology {
    let p = links_ring.len();
    assert!(p >= 2, "a ring needs at least 2 devices");

    let mut alpha = Mat::zeros(p, p);
    let mut beta = Mat::zeros(p, p);
    let mut level = vec![0usize; p * p];
    let mut paths = vec![Vec::new(); p * p];

    for i in 0..p {
        for j in 0..p {
            if i == j {
                alpha.set(i, j, local.alpha);
                beta.set(i, j, local.beta);
                continue;
            }
            // choose the cheaper arc: fewer hops, tie-break on β sum
            let cw = arc(i, j, p, true);
            let ccw = arc(i, j, p, false);
            let cost = |path: &Vec<usize>| {
                let bsum: f64 = path.iter().map(|&e| links_ring[e].beta).sum();
                (path.len(), (bsum * 1e15) as u64)
            };
            let path_edges = if cost(&cw) <= cost(&ccw) { cw } else { ccw };
            let a_sum: f64 = path_edges.iter().map(|&e| links_ring[e].alpha).sum();
            let b_max: f64 = path_edges
                .iter()
                .map(|&e| links_ring[e].beta)
                .fold(0.0, f64::max);
            alpha.set(i, j, a_sum);
            beta.set(i, j, b_max);
            level[i * p + j] = path_edges.len();
            // direction flag: `up` = clockwise traversal of the edge
            let clockwise = path_edges
                .first()
                .map(|&e| e == i) // clockwise first edge is link i
                .unwrap_or(true);
            paths[i * p + j] = path_edges
                .into_iter()
                .map(|e| DirLink { edge: e, up: clockwise })
                .collect();
        }
    }

    Topology {
        p,
        kind: TopologyKind::Ring,
        alpha,
        beta,
        level,
        node_of: vec![0; p], // a ring is an intra-node fabric
        link_contended: vec![true; links_ring.len()],
        links: links_ring,
        paths,
        path_off: Vec::new(),
        path_slots: Vec::new(),
        slot_alpha: Vec::new(),
        slot_beta: Vec::new(),
        slot_contended: Vec::new(),
        alive: vec![true; p],
    }
    .with_incidence()
}

/// Edge ids along the arc from i to j. Clockwise: i → i+1 → … → j uses
/// edges i, i+1, …, j-1 (mod p); counter-clockwise uses i-1, …, j (mod p).
fn arc(i: usize, j: usize, p: usize, clockwise: bool) -> Vec<usize> {
    let mut edges = Vec::new();
    let mut cur = i;
    while cur != j {
        if clockwise {
            edges.push(cur);
            cur = (cur + 1) % p;
        } else {
            cur = (cur + p - 1) % p;
            edges.push(cur);
        }
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_ring(p: usize, beta: f64) -> Topology {
        Topology::ring(vec![Link::new(1e-6, beta); p], Link::new(0.0, 1e-12))
    }

    #[test]
    fn adjacent_single_hop() {
        let t = uniform_ring(4, 1e-10);
        assert_eq!(t.level(0, 1), 1);
        assert_eq!(t.level(1, 0), 1);
        assert_eq!(t.path(0, 1).len(), 1);
        assert_eq!(t.beta(0, 1), 1e-10);
    }

    #[test]
    fn opposite_takes_half_ring() {
        let t = uniform_ring(4, 1e-10);
        assert_eq!(t.level(0, 2), 2);
        assert_eq!(t.path(0, 2).len(), 2);
        // α accumulates over 2 hops
        assert!((t.alpha(0, 2) - 2e-6).abs() < 1e-12);
    }

    #[test]
    fn slowest_link_dominates_beta() {
        // Link 1 (between devices 1 and 2) is 10× slower.
        let mut links = vec![Link::new(1e-6, 1e-10); 4];
        links[1] = Link::new(1e-6, 1e-9);
        let t = Topology::ring(links, Link::new(0.0, 1e-12));
        // 0→2 clockwise crosses edges 0,1 → bottleneck 1e-9; ccw crosses
        // 3,2 → 1e-10 with same hop count, so the cheaper arc is chosen.
        assert_eq!(t.beta(0, 2), 1e-10);
        // 1→2 must use edge 1 (single hop) → sees the slow link.
        assert_eq!(t.beta(1, 2), 1e-9);
    }

    #[test]
    fn ring_is_single_node() {
        let t = uniform_ring(6, 1e-10);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.n_levels(), 3); // hop distances 1, 2, 3
    }

    #[test]
    fn levels_symmetric_in_hops() {
        let t = uniform_ring(6, 1e-10);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(t.level(i, j), t.level(j, i));
            }
        }
    }
}
