//! Network topology substrate (paper §3.2).
//!
//! Models the four topology families the paper studies — homogeneous
//! (NVSwitch-like), ring (NVLink ring), symmetric tree, and asymmetric tree
//! — as an explicit graph of physical links, from which we derive:
//!
//! * per-pair end-to-end `α_ij` / `β_ij` matrices (latency seconds /
//!   inverse bandwidth seconds-per-byte): α sums over hops, β is the
//!   slowest traversed link ("the most limited bandwidth in the hops
//!   dominates the final bandwidth", §3.2);
//! * the level decomposition `G_t^i` (devices grouped by how far up the
//!   tree their path to `i` goes) used by the Eq. 5 smoothing;
//! * explicit per-pair link paths, so the [`crate::comm`] engine can model
//!   *contention* — multiple flows sharing a switch uplink — which is what
//!   actually produces the Table-1 slowdowns on inter-node links;
//! * node (server) membership, from which the coordinator builds the
//!   intra-node expert mask used by the FasterMoE-Hir gate.

mod ring;
mod smoothing;
mod tree;

pub mod presets;

pub use smoothing::{smooth_levels, LevelParams};
pub use tree::TreeSpec;

use crate::util::{rng::Rng, Mat};

/// One physical link: fixed latency `alpha` (s) + inverse bandwidth `beta`
/// (s/byte). The α-β model of §4.1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Link {
    pub alpha: f64,
    pub beta: f64,
}

impl Link {
    pub fn new(alpha: f64, beta: f64) -> Self {
        Link { alpha, beta }
    }

    /// Convenience: a link described by bandwidth in GB/s and latency in µs.
    pub fn from_gbps_us(gb_per_s: f64, alpha_us: f64) -> Self {
        Link { alpha: alpha_us * 1e-6, beta: 1.0 / (gb_per_s * 1e9) }
    }

    /// Time to move `bytes` over this link alone.
    pub fn time(&self, bytes: f64) -> f64 {
        self.alpha + self.beta * bytes
    }
}

/// A directed traversal of a physical link (`up` = toward the root).
/// Contention is counted per `(edge, direction)` — links are full duplex.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DirLink {
    pub edge: usize,
    pub up: bool,
}

/// Which family a topology was built from (kept for reporting/serialization).
#[derive(Clone, Debug, PartialEq)]
pub enum TopologyKind {
    Homogeneous,
    Ring,
    Tree { spec: TreeSpec, symmetric: bool },
}

/// A fully-elaborated topology: `P` devices + the link graph between them.
#[derive(Clone, Debug)]
pub struct Topology {
    pub(crate) p: usize,
    pub(crate) kind: TopologyKind,
    /// Per-pair end-to-end latency (s); `alpha[i][i]` is the local-copy cost.
    pub(crate) alpha: Mat,
    /// Per-pair end-to-end inverse bandwidth (s/byte).
    pub(crate) beta: Mat,
    /// Level of the pair for Eq.5 grouping: 0 = same device, 1 = same leaf
    /// switch/adjacent, t = path peaks t-1 levels above the leaf switches.
    pub(crate) level: Vec<usize>,
    /// Leaf switch (server/node) id per device.
    pub(crate) node_of: Vec<usize>,
    /// Physical links; index = edge id.
    pub(crate) links: Vec<Link>,
    /// Whether a link is a shared medium (switch uplink / ring segment)
    /// that concurrent flows contend on. Device-to-leaf-switch links
    /// (NVLink/NVSwitch lanes) are non-blocking point-to-point fabric and
    /// do not contend.
    pub(crate) link_contended: Vec<bool>,
    /// Per-pair directed link path (empty for i == j).
    pub(crate) paths: Vec<Vec<DirLink>>,
    /// Flat link-incidence table (see [`Topology::with_incidence`]): CSR
    /// offsets into [`Topology::path_slots`], one entry per (i, j) pair in
    /// row-major order, `p*p + 1` entries total.
    pub(crate) path_off: Vec<u32>,
    /// Concatenated per-pair directed-link *slot* lists. A slot is
    /// `2*edge + dir` (`dir` = 1 toward the root), so a flow census over a
    /// set of deliveries is a dense `Vec` indexed by slot — no hashing on
    /// the per-step pricing path.
    pub(crate) path_slots: Vec<u32>,
    /// Per-slot link latency (duplicated across both directions).
    pub(crate) slot_alpha: Vec<f64>,
    /// Per-slot link inverse bandwidth.
    pub(crate) slot_beta: Vec<f64>,
    /// Per-slot contention flag (mirrors [`Topology::link_contended`]).
    pub(crate) slot_contended: Vec<bool>,
    /// Per-device liveness mask (all true at construction). A dead device
    /// stays in the link graph — its pair entries still price — but the
    /// perturbation layer routes no tokens to or from it and the serving
    /// batcher admits nothing onto it.
    pub(crate) alive: Vec<bool>,
}

impl Topology {
    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    /// Homogeneous all-to-all fabric (e.g. one NVSwitch): every pair gets a
    /// dedicated link with identical parameters.
    pub fn homogeneous(p: usize, link: Link, local: Link) -> Topology {
        assert!(p >= 1);
        let mut links = Vec::new();
        let mut paths = vec![Vec::new(); p * p];
        let mut edge_of = vec![usize::MAX; p * p];
        for i in 0..p {
            for j in (i + 1)..p {
                let id = links.len();
                links.push(link);
                edge_of[i * p + j] = id;
                edge_of[j * p + i] = id;
            }
        }
        let mut alpha = Mat::zeros(p, p);
        let mut beta = Mat::zeros(p, p);
        let mut level = vec![0usize; p * p];
        for i in 0..p {
            for j in 0..p {
                if i == j {
                    alpha.set(i, j, local.alpha);
                    beta.set(i, j, local.beta);
                } else {
                    let e = edge_of[i * p + j];
                    paths[i * p + j] = vec![DirLink { edge: e, up: i < j }];
                    alpha.set(i, j, link.alpha);
                    beta.set(i, j, link.beta);
                    level[i * p + j] = 1;
                }
            }
        }
        let n_links = links.len();
        Topology {
            p,
            kind: TopologyKind::Homogeneous,
            alpha,
            beta,
            level,
            node_of: vec![0; p],
            links,
            link_contended: vec![true; n_links],
            paths,
            path_off: Vec::new(),
            path_slots: Vec::new(),
            slot_alpha: Vec::new(),
            slot_beta: Vec::new(),
            slot_contended: Vec::new(),
            alive: vec![true; p],
        }
        .with_incidence()
    }

    /// Ring of `links.len()` devices; `links[i]` connects device `i` to
    /// `(i+1) % p`. Non-adjacent pairs hop through intermediate devices:
    /// the slowest traversed link dominates β, latencies accumulate (§3.2).
    pub fn ring(links_ring: Vec<Link>, local: Link) -> Topology {
        ring::build(links_ring, local)
    }

    /// Hierarchical tree from a nested-list spec (paper notation:
    /// `[[2,2],[2]]`). `level_links[0]` is the device↔leaf-switch link,
    /// `level_links[h]` the switch uplink at height `h`; the last entry is
    /// reused for deeper levels.
    pub fn tree(spec: &TreeSpec, level_links: &[Link], local: Link) -> Topology {
        tree::build(spec, level_links, local)
    }

    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn kind(&self) -> &TopologyKind {
        &self.kind
    }

    pub fn alpha(&self, i: usize, j: usize) -> f64 {
        self.alpha.get(i, j)
    }

    pub fn beta(&self, i: usize, j: usize) -> f64 {
        self.beta.get(i, j)
    }

    pub fn alpha_mat(&self) -> &Mat {
        &self.alpha
    }

    pub fn beta_mat(&self) -> &Mat {
        &self.beta
    }

    /// Pair level for Eq. 5 grouping (0 ⇔ i == j).
    pub fn level(&self, i: usize, j: usize) -> usize {
        self.level[i * self.p + j]
    }

    /// Number of distinct non-zero levels (`n` in the paper's n-layer tree).
    pub fn n_levels(&self) -> usize {
        self.level.iter().copied().max().unwrap_or(0)
    }

    /// Server/node id of a device (devices under the same leaf switch).
    pub fn node_of(&self, dev: usize) -> usize {
        self.node_of[dev]
    }

    pub fn same_node(&self, i: usize, j: usize) -> bool {
        self.node_of[i] == self.node_of[j]
    }

    pub fn n_nodes(&self) -> usize {
        self.node_of.iter().copied().max().unwrap_or(0) + 1
    }

    /// Devices grouped by node, in device order.
    pub fn nodes(&self) -> Vec<Vec<usize>> {
        let mut groups = vec![Vec::new(); self.n_nodes()];
        for d in 0..self.p {
            groups[self.node_of[d]].push(d);
        }
        groups
    }

    /// `[P, N]` mask: 1.0 where expert `e` (hosted on device `e / e_per_dev`)
    /// is on the same node as device `i`. Feeds the Hir gate input.
    pub fn local_mask(&self, n_experts: usize, e_per_dev: usize) -> Mat {
        Mat::from_fn(self.p, n_experts, |i, e| {
            let host = e / e_per_dev;
            if self.same_node(i, host) {
                1.0
            } else {
                0.0
            }
        })
    }

    /// Directed link path of a pair (empty for i == j: local copy).
    pub fn path(&self, i: usize, j: usize) -> &[DirLink] {
        &self.paths[i * self.p + j]
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Does this link contend (shared medium) under concurrent flows?
    pub fn link_contended(&self, edge: usize) -> bool {
        self.link_contended[edge]
    }

    /// Number of directed-link slots (`2 × links`); the length of any
    /// flow-census vector over this topology.
    #[inline]
    pub(crate) fn n_slots(&self) -> usize {
        self.slot_beta.len()
    }

    /// Directed-link slot ids of a pair's path (`2*edge + dir`; empty for
    /// i == j). The flat-incidence mirror of [`Topology::path`].
    #[inline]
    pub(crate) fn pair_slots(&self, i: usize, j: usize) -> &[u32] {
        let k = i * self.p + j;
        &self.path_slots[self.path_off[k] as usize..self.path_off[k + 1] as usize]
    }

    /// Fill the flat link-incidence table from `links` + `paths`. Every
    /// constructor (homogeneous/ring/tree) must finish with this; the
    /// table is derived state, so `with_noise`/`smoothed` clones stay
    /// valid (they perturb the per-pair α/β matrices, never the links).
    fn with_incidence(mut self) -> Topology {
        let n_slots = 2 * self.links.len();
        self.slot_alpha = vec![0.0; n_slots];
        self.slot_beta = vec![0.0; n_slots];
        self.slot_contended = vec![false; n_slots];
        for (e, l) in self.links.iter().enumerate() {
            for d in 0..2 {
                self.slot_alpha[2 * e + d] = l.alpha;
                self.slot_beta[2 * e + d] = l.beta;
                self.slot_contended[2 * e + d] = self.link_contended[e];
            }
        }
        let mut off = Vec::with_capacity(self.p * self.p + 1);
        off.push(0u32);
        let mut slots = Vec::new();
        for path in &self.paths {
            for dl in path {
                slots.push((2 * dl.edge + dl.up as usize) as u32);
            }
            off.push(slots.len() as u32);
        }
        self.path_off = off;
        self.path_slots = slots;
        self
    }

    /// The paper's `G_t^i`: devices whose pair level with `i` equals `t`.
    pub fn group(&self, i: usize, t: usize) -> Vec<usize> {
        (0..self.p).filter(|&j| self.level(i, j) == t).collect()
    }

    // ------------------------------------------------------------------
    // Runtime mutation (perturbation layer)
    // ------------------------------------------------------------------

    /// Whether a device is live (true unless [`Topology::mark_dead`] ran).
    pub fn is_alive(&self, dev: usize) -> bool {
        self.alive[dev]
    }

    /// Number of live devices.
    pub fn n_alive(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Drop a device from the cluster (node loss). The link graph and
    /// per-pair matrices are untouched — a dead device can still be
    /// priced against — but at least one device must stay alive.
    pub fn mark_dead(&mut self, dev: usize) {
        assert!(dev < self.p, "device {dev} out of range");
        self.alive[dev] = false;
        assert!(self.n_alive() > 0, "cannot kill the last live device");
    }

    /// Degrade (or restore) one physical link in place: α and β of
    /// `links[edge]` are multiplied by `factor`, both directed slots
    /// follow, and every per-pair entry whose path crosses the edge is
    /// re-derived from the link graph (α = hop sum, β = slowest hop,
    /// §3.2 — the same derivation every constructor uses). Anything that
    /// caches plans priced off this topology is stale afterwards; the
    /// caller must bump its topology epoch (`PlanCache::set_topo_epoch`).
    pub fn scale_link(&mut self, edge: usize, factor: f64) {
        assert!(edge < self.links.len(), "link {edge} out of range");
        assert!(factor > 0.0, "non-positive link scale {factor}");
        self.links[edge].alpha *= factor;
        self.links[edge].beta *= factor;
        for dir in 0..2 {
            self.slot_alpha[2 * edge + dir] = self.links[edge].alpha;
            self.slot_beta[2 * edge + dir] = self.links[edge].beta;
        }
        for i in 0..self.p {
            for j in 0..self.p {
                if i == j {
                    continue;
                }
                let path = &self.paths[i * self.p + j];
                if path.iter().any(|dl| dl.edge == edge) {
                    let a_sum: f64 =
                        path.iter().map(|dl| self.links[dl.edge].alpha).sum();
                    let b_max: f64 = path
                        .iter()
                        .map(|dl| self.links[dl.edge].beta)
                        .fold(0.0, f64::max);
                    self.alpha.set(i, j, a_sum);
                    self.beta.set(i, j, b_max);
                }
            }
        }
    }

    /// Counterfactual seam ([`crate::analyze`]): a clone with *every*
    /// physical link's α multiplied by `alpha_f` and β by `beta_f`
    /// (zero allowed — `alpha0` keeps bandwidth but kills latency,
    /// `perfect-fabric` zeroes both), per-pair matrices re-derived from
    /// the link graph exactly as the constructors do (α = hop sum,
    /// β = slowest hop, §3.2). Local copies (the diagonal) are
    /// untouched: a perfect fabric still pays the memory copy. Any
    /// profiling noise baked into the per-pair matrices is discarded —
    /// counterfactuals price the true fabric.
    pub fn with_links_scaled(&self, alpha_f: f64, beta_f: f64) -> Topology {
        assert!(alpha_f >= 0.0, "negative link alpha scale {alpha_f}");
        assert!(beta_f >= 0.0, "negative link beta scale {beta_f}");
        let mut t = self.clone();
        for l in &mut t.links {
            l.alpha *= alpha_f;
            l.beta *= beta_f;
        }
        for (e, l) in t.links.iter().enumerate() {
            for dir in 0..2 {
                t.slot_alpha[2 * e + dir] = l.alpha;
                t.slot_beta[2 * e + dir] = l.beta;
            }
        }
        for i in 0..t.p {
            for j in 0..t.p {
                if i == j {
                    continue;
                }
                let path = &t.paths[i * t.p + j];
                let a_sum: f64 = path.iter().map(|dl| t.links[dl.edge].alpha).sum();
                let b_max: f64 = path
                    .iter()
                    .map(|dl| t.links[dl.edge].beta)
                    .fold(0.0, f64::max);
                t.alpha.set(i, j, a_sum);
                t.beta.set(i, j, b_max);
            }
        }
        t
    }

    /// Perturb cross-device per-pair α/β with relative log-normal-ish
    /// noise — the "profiling noise" that Eq. 5 smoothing is designed to
    /// remove. Self pairs (i == j) are local memory copies no profiler
    /// mismeasures, so the diagonal stays exact; the link graph is left
    /// untouched (contention still uses true links).
    pub fn with_noise(&self, rel_sigma: f64, seed: u64) -> Topology {
        let mut rng = Rng::seed_from_u64(seed);
        let mut t = self.clone();
        let p = self.p;
        for i in 0..p {
            for j in 0..p {
                let fa: f64 = 1.0 + rel_sigma * (rng.f64() * 2.0 - 1.0);
                let fb: f64 = 1.0 + rel_sigma * (rng.f64() * 2.0 - 1.0);
                if i == j {
                    continue; // draws still consumed: off-diagonal noise
                              // stays seed-stable across this fix
                }
                t.alpha.set(i, j, self.alpha.get(i, j) * fa.max(0.05));
                t.beta.set(i, j, self.beta.get(i, j) * fb.max(0.05));
            }
        }
        t
    }

    /// Replace the per-pair α/β with their Eq. 5 level-smoothed versions.
    pub fn smoothed(&self) -> Topology {
        let params = smoothing::smooth_levels(self);
        let mut t = self.clone();
        for i in 0..self.p {
            for j in 0..self.p {
                let l = self.level(i, j);
                t.alpha.set(i, j, params.alpha[l]);
                t.beta.set(i, j, params.beta[l]);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l(beta: f64) -> Link {
        Link::new(1e-6, beta)
    }

    #[test]
    fn homogeneous_is_uniform() {
        let t = Topology::homogeneous(4, l(1e-9), Link::new(0.0, 1e-11));
        assert_eq!(t.p(), 4);
        for i in 0..4 {
            for j in 0..4 {
                if i == j {
                    assert_eq!(t.beta(i, j), 1e-11);
                    assert_eq!(t.level(i, j), 0);
                } else {
                    assert_eq!(t.beta(i, j), 1e-9);
                    assert_eq!(t.level(i, j), 1);
                    assert_eq!(t.path(i, j).len(), 1);
                }
            }
        }
        assert_eq!(t.n_levels(), 1);
        assert_eq!(t.n_nodes(), 1);
    }

    #[test]
    fn homogeneous_pairs_have_distinct_links() {
        let t = Topology::homogeneous(3, l(1e-9), Link::new(0.0, 1e-11));
        // 3 unordered pairs → 3 physical links, no sharing (no contention).
        assert_eq!(t.links().len(), 3);
        assert_ne!(t.path(0, 1)[0].edge, t.path(0, 2)[0].edge);
    }

    #[test]
    fn local_mask_marks_same_node() {
        let spec = TreeSpec::parse("[[2],[2]]").unwrap();
        let t = Topology::tree(&spec, &[l(1e-10), l(1e-8)], Link::new(0.0, 1e-11));
        let m = t.local_mask(4, 1);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.get(0, 2), 0.0);
        assert_eq!(m.get(3, 3), 1.0);
        assert_eq!(m.get(3, 0), 0.0);
    }

    #[test]
    fn groups_partition_devices() {
        let spec = TreeSpec::parse("[[2],[2]]").unwrap();
        let t = Topology::tree(&spec, &[l(1e-10), l(1e-8)], Link::new(0.0, 1e-11));
        for i in 0..4 {
            let mut all: Vec<usize> = Vec::new();
            for t_ in 0..=t.n_levels() {
                all.extend(t.group(i, t_));
            }
            all.sort();
            assert_eq!(all, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn incidence_table_mirrors_paths() {
        let spec = TreeSpec::parse("[[2,2],[2]]").unwrap();
        let trees = [
            Topology::tree(&spec, &[l(1e-10), l(1e-8), l(1e-7)], Link::new(0.0, 1e-11)),
            Topology::homogeneous(4, l(1e-9), Link::new(0.0, 1e-11)),
            Topology::ring(vec![l(1e-9); 5], Link::new(0.0, 1e-11)),
        ];
        for t in &trees {
            assert_eq!(t.n_slots(), 2 * t.links().len());
            for i in 0..t.p() {
                for j in 0..t.p() {
                    let slots = t.pair_slots(i, j);
                    let path = t.path(i, j);
                    assert_eq!(slots.len(), path.len());
                    for (s, dl) in slots.iter().zip(path) {
                        assert_eq!(*s as usize, 2 * dl.edge + dl.up as usize);
                        let e = *s as usize / 2;
                        assert_eq!(t.slot_alpha[*s as usize], t.links()[e].alpha);
                        assert_eq!(t.slot_beta[*s as usize], t.links()[e].beta);
                        assert_eq!(t.slot_contended[*s as usize], t.link_contended(e));
                    }
                }
            }
        }
    }

    #[test]
    fn links_scaled_rederives_pairs_and_allows_zero() {
        let spec = TreeSpec::parse("[2,2]").unwrap();
        let t = Topology::tree(&spec, &[l(1e-10), l(1e-8)], Link::new(2e-7, 1e-11));
        // alpha0: latency gone, bandwidth kept, diagonal untouched
        let a0 = t.with_links_scaled(0.0, 1.0);
        for i in 0..t.p() {
            for j in 0..t.p() {
                if i == j {
                    assert_eq!(a0.alpha(i, i), t.alpha(i, i));
                    assert_eq!(a0.beta(i, i), t.beta(i, i));
                } else {
                    assert_eq!(a0.alpha(i, j), 0.0, "alpha {i}->{j}");
                    assert_eq!(a0.beta(i, j), t.beta(i, j), "beta {i}->{j}");
                }
            }
        }
        // perfect fabric: both zero on every cross-device pair and slot
        let pf = t.with_links_scaled(0.0, 0.0);
        assert!(pf.links().iter().all(|l| l.alpha == 0.0 && l.beta == 0.0));
        assert_eq!(pf.beta(0, 2), 0.0);
        assert!(pf.beta(0, 0) > 0.0);
        // a uniform scale matches per-edge scale_link over all edges
        let mut per_edge = t.clone();
        for e in 0..t.links().len() {
            per_edge.scale_link(e, 2.0);
        }
        let uniform = t.with_links_scaled(2.0, 2.0);
        assert_eq!(uniform.alpha_mat(), per_edge.alpha_mat());
        assert_eq!(uniform.beta_mat(), per_edge.beta_mat());
    }

    #[test]
    fn noise_preserves_links_and_is_deterministic() {
        let t = Topology::homogeneous(4, l(1e-9), Link::new(0.0, 1e-11));
        let n1 = t.with_noise(0.2, 42);
        let n2 = t.with_noise(0.2, 42);
        assert_eq!(n1.alpha_mat(), n2.alpha_mat());
        assert_eq!(n1.beta_mat(), n2.beta_mat());
        assert_eq!(n1.links(), t.links());
        assert!(n1.beta_mat().linf_dist(t.beta_mat()) > 0.0);
    }

    #[test]
    fn scale_link_degrades_crossing_pairs_only() {
        // [2,2]: degrade the first switch uplink 4×. Pairs crossing it
        // slow down by exactly the link-graph re-derivation; intra-node
        // pairs on the other side are untouched.
        let spec = TreeSpec::parse("[2,2]").unwrap();
        let mut t = Topology::tree(&spec, &[l(1e-10), l(1e-8)], Link::new(0.0, 1e-11));
        let clean = t.clone();
        // find the uplink on device 0's inter-node path (slowest hop)
        let up_edge = t
            .path(0, 2)
            .iter()
            .map(|dl| dl.edge)
            .max_by(|&a, &b| t.links()[a].beta.total_cmp(&t.links()[b].beta))
            .unwrap();
        t.scale_link(up_edge, 4.0);
        assert_eq!(t.links()[up_edge].beta, 4.0 * clean.links()[up_edge].beta);
        for dir in 0..2 {
            assert_eq!(t.slot_beta[2 * up_edge + dir], t.links()[up_edge].beta);
        }
        // crossing pair: β is the degraded uplink, α re-accumulated
        assert!(t.beta(0, 2) >= clean.beta(0, 2));
        assert_eq!(t.beta(0, 2), 4.0 * 1e-8);
        // non-crossing intra-node pair (2, 3): bit-identical
        assert_eq!(t.beta(2, 3), clean.beta(2, 3));
        assert_eq!(t.alpha(2, 3), clean.alpha(2, 3));
        // diagonal (local copies) untouched
        for i in 0..t.p() {
            assert_eq!(t.beta(i, i), clean.beta(i, i));
        }
    }

    #[test]
    fn liveness_mask_defaults_true_and_marks_dead() {
        let mut t = Topology::homogeneous(4, l(1e-9), Link::new(0.0, 1e-11));
        assert_eq!(t.n_alive(), 4);
        assert!((0..4).all(|d| t.is_alive(d)));
        t.mark_dead(2);
        assert!(!t.is_alive(2));
        assert_eq!(t.n_alive(), 3);
        // pricing state is untouched by death
        assert_eq!(t.beta(2, 0), 1e-9);
    }

    #[test]
    fn noise_leaves_local_copies_exact() {
        // regression: profiling noise used to perturb the diagonal too,
        // distorting the local-copy (i == j) α/β that no profiler measures
        // over a link
        let spec = TreeSpec::parse("[[2,2],[2]]").unwrap();
        let t = Topology::tree(&spec, &[l(1e-10), l(1e-8)], Link::new(3e-7, 1e-11));
        let n = t.with_noise(0.3, 7);
        for i in 0..t.p() {
            assert_eq!(n.alpha(i, i), t.alpha(i, i), "alpha diag {i}");
            assert_eq!(n.beta(i, i), t.beta(i, i), "beta diag {i}");
        }
        // off-diagonal entries are still perturbed
        let mut moved = 0;
        for i in 0..t.p() {
            for j in 0..t.p() {
                if i != j && n.beta(i, j) != t.beta(i, j) {
                    moved += 1;
                }
            }
        }
        assert!(moved > 0, "noise must still perturb cross-device pairs");
    }
}
