//! Continuous batcher: the iteration-level scheduler of the serving
//! simulator.
//!
//! Orca-style continuous batching — sequences join and leave the running
//! batch at *iteration* granularity instead of waiting for a whole batch
//! to drain. Each simulated iteration:
//!
//! 1. [`ContinuousBatcher::admit`] pulls every request that has arrived
//!    by `now` into the in-flight set, least-loaded device first, capped
//!    at `max_inflight_per_dev` sequences per device (the KV-cache slot
//!    budget);
//! 2. [`ContinuousBatcher::tokens_per_device`] reports the iteration's
//!    token bill: a sequence in its prefill iteration contributes its
//!    whole prompt, a decoding sequence contributes one token;
//! 3. after the step is priced, [`ContinuousBatcher::advance`] stamps
//!    prefilling sequences' first-token time (TTFT), emits one output
//!    token per sequence, and retires finished sequences as
//!    [`RequestRecord`]s for the run log.
//!
//! The batcher owns queueing and lifetime only — routing and pricing live
//! in [`super::ServeSession`].

use super::trace::Request;
use crate::metrics::RequestRecord;

/// One in-flight sequence.
#[derive(Clone, Debug)]
struct Sequence {
    id: usize,
    arrival_s: f64,
    prompt_tokens: usize,
    output_tokens: usize,
    /// Output tokens emitted so far; 0 means the prefill iteration is
    /// still pending.
    emitted: usize,
    /// Device whose batch the sequence joined (its KV cache lives there).
    device: usize,
    first_token_s: Option<f64>,
}

/// Iteration-granular admission + retirement over a fixed arrival trace.
#[derive(Clone, Debug)]
pub struct ContinuousBatcher {
    trace: Vec<Request>,
    /// Next unadmitted trace index.
    next: usize,
    inflight: Vec<Sequence>,
    per_dev: Vec<usize>,
    max_inflight_per_dev: usize,
    /// Devices lost to a node failure: closed to admission forever (see
    /// [`Self::fail_device`]).
    dead: Vec<bool>,
}

impl ContinuousBatcher {
    pub fn new(trace: Vec<Request>, p: usize, max_inflight_per_dev: usize) -> ContinuousBatcher {
        assert!(p > 0 && max_inflight_per_dev > 0);
        ContinuousBatcher {
            trace,
            next: 0,
            inflight: Vec::new(),
            per_dev: vec![0; p],
            max_inflight_per_dev,
            dead: vec![false; p],
        }
    }

    /// Admit every request arrived by `now`, least-loaded device first
    /// (ties to the lowest device id), until per-device slots run out.
    /// Returns how many were admitted.
    pub fn admit(&mut self, now: f64) -> usize {
        let mut admitted = 0;
        while self.next < self.trace.len() && self.trace[self.next].arrival_s <= now {
            let Some(dev) = self.least_loaded_open_device() else { break };
            let r = self.trace[self.next];
            self.inflight.push(Sequence {
                id: self.next,
                arrival_s: r.arrival_s,
                prompt_tokens: r.prompt_tokens,
                output_tokens: r.output_tokens.max(1),
                emitted: 0,
                device: dev,
                first_token_s: None,
            });
            self.per_dev[dev] += 1;
            self.next += 1;
            admitted += 1;
        }
        admitted
    }

    fn least_loaded_open_device(&self) -> Option<usize> {
        let (dev, &load) = self
            .per_dev
            .iter()
            .enumerate()
            .filter(|&(d, _)| !self.dead[d])
            .min_by_key(|&(d, &load)| (load, d))?;
        (load < self.max_inflight_per_dev).then_some(dev)
    }

    /// Device `dev` dies: close it to admission forever and re-home its
    /// in-flight sequences (in id order) onto the least-loaded surviving
    /// devices. Emergency re-admission deliberately ignores the
    /// per-device slot cap — dropping accepted work is worse than
    /// transiently oversubscribing a survivor's KV budget; admission of
    /// *new* requests still honours the cap, so the overshoot drains as
    /// sequences finish. No request is ever lost (the conservation
    /// invariant the node-loss acceptance test pins). Returns how many
    /// sequences were re-homed; idempotent on an already-dead device.
    pub fn fail_device(&mut self, dev: usize) -> usize {
        assert!(dev < self.per_dev.len(), "device {dev} out of range");
        if self.dead[dev] {
            return 0;
        }
        self.dead[dev] = true;
        assert!(self.dead.iter().any(|d| !d), "cannot fail the last device");
        let mut stranded: Vec<usize> = (0..self.inflight.len())
            .filter(|&i| self.inflight[i].device == dev)
            .collect();
        stranded.sort_by_key(|&i| self.inflight[i].id);
        let rehomed = stranded.len();
        for i in stranded {
            let new_dev = self
                .per_dev
                .iter()
                .enumerate()
                .filter(|&(d, _)| !self.dead[d])
                .min_by_key(|&(d, &load)| (load, d))
                .map(|(d, _)| d)
                .expect("a live device exists");
            self.per_dev[dev] -= 1;
            self.per_dev[new_dev] += 1;
            self.inflight[i].device = new_dev;
        }
        rehomed
    }

    /// Is `dev` closed to admission after a node failure?
    pub fn is_dead(&self, dev: usize) -> bool {
        self.dead[dev]
    }

    /// This iteration's token bill per device: prompt length for
    /// sequences still prefilling, one decode token otherwise.
    pub fn tokens_per_device(&self) -> Vec<usize> {
        let mut t = vec![0usize; self.per_dev.len()];
        for s in &self.inflight {
            t[s.device] += if s.emitted == 0 { s.prompt_tokens } else { 1 };
        }
        t
    }

    /// Close the iteration that ended at `now_end`: every in-flight
    /// sequence emits one token (prefills emit their first and stamp
    /// TTFT); finished sequences retire as records, in id order.
    pub fn advance(&mut self, now_end: f64) -> Vec<RequestRecord> {
        let mut done = Vec::new();
        let mut keep = Vec::with_capacity(self.inflight.len());
        for mut s in self.inflight.drain(..) {
            if s.emitted == 0 {
                s.first_token_s = Some(now_end);
            }
            s.emitted += 1;
            if s.emitted >= s.output_tokens {
                self.per_dev[s.device] -= 1;
                done.push(RequestRecord {
                    id: s.id,
                    arrival_s: s.arrival_s,
                    first_token_s: s.first_token_s.unwrap_or(now_end),
                    finish_s: now_end,
                    prompt_tokens: s.prompt_tokens,
                    output_tokens: s.output_tokens,
                });
            } else {
                keep.push(s);
            }
        }
        self.inflight = keep;
        done.sort_by_key(|r| r.id);
        done
    }

    /// Arrival time of the next unadmitted request (for idle-skip).
    pub fn next_arrival(&self) -> Option<f64> {
        self.trace.get(self.next).map(|r| r.arrival_s)
    }

    pub fn inflight_len(&self) -> usize {
        self.inflight.len()
    }

    /// True once every trace request has been admitted and retired.
    pub fn done(&self) -> bool {
        self.next >= self.trace.len() && self.inflight.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(arrival_s: f64, prompt: usize, output: usize) -> Request {
        Request { arrival_s, prompt_tokens: prompt, output_tokens: output }
    }

    #[test]
    fn admits_in_arrival_order_to_least_loaded_device() {
        let trace = vec![req(0.0, 8, 2), req(0.0, 8, 2), req(0.5, 8, 2)];
        let mut b = ContinuousBatcher::new(trace, 2, 4);
        assert_eq!(b.admit(0.0), 2); // req 2 not yet arrived
        assert_eq!(b.tokens_per_device(), vec![8, 8]); // spread across devs
        assert_eq!(b.admit(1.0), 1);
        assert_eq!(b.inflight_len(), 3);
    }

    #[test]
    fn per_device_slot_cap_defers_admission() {
        let trace = vec![req(0.0, 4, 3); 5];
        let mut b = ContinuousBatcher::new(trace, 2, 2);
        assert_eq!(b.admit(0.0), 4); // 2 devices × 2 slots
        assert_eq!(b.admit(0.0), 0); // full
        // finish everyone: 3 output tokens each → 3 iterations
        b.advance(1.0);
        b.advance(2.0);
        let done = b.advance(3.0);
        assert_eq!(done.len(), 4);
        assert_eq!(b.admit(3.0), 1); // slot freed, straggler admitted
        assert!(!b.done());
    }

    #[test]
    fn prefill_then_decode_token_accounting() {
        let mut b = ContinuousBatcher::new(vec![req(0.0, 10, 3)], 1, 8);
        b.admit(0.0);
        assert_eq!(b.tokens_per_device(), vec![10]); // prefill
        assert!(b.advance(0.25).is_empty()); // first token out
        assert_eq!(b.tokens_per_device(), vec![1]); // decode
        assert!(b.advance(0.5).is_empty());
        let done = b.advance(0.75);
        assert_eq!(done.len(), 1);
        let r = &done[0];
        assert_eq!(r.ttft_s(), 0.25);
        assert_eq!(r.finish_s, 0.75);
        // 2 post-first tokens over 0.5 s
        assert!((r.tpot_s() - 0.25).abs() < 1e-12);
        assert!(b.done());
    }

    #[test]
    fn single_token_requests_finish_in_their_prefill_iteration() {
        let mut b = ContinuousBatcher::new(vec![req(0.0, 6, 1)], 1, 8);
        b.admit(0.0);
        let done = b.advance(0.1);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].first_token_s, done[0].finish_s);
        assert_eq!(done[0].tpot_s(), 0.0);
        assert!(b.done());
    }

    #[test]
    fn fail_device_rehomes_inflight_and_closes_admission() {
        let mut trace = vec![req(0.0, 4, 5); 6];
        trace.extend(vec![req(0.5, 4, 5); 2]);
        let mut b = ContinuousBatcher::new(trace, 3, 4);
        assert_eq!(b.admit(0.0), 6); // 2 per device, late pair not arrived
        assert_eq!(b.fail_device(1), 2);
        assert!(b.is_dead(1));
        assert_eq!(b.fail_device(1), 0); // idempotent
        // nobody lost, nobody left on the corpse, 3 on each survivor
        assert_eq!(b.inflight_len(), 6);
        let t = b.tokens_per_device();
        assert_eq!(t[1], 0);
        assert_eq!(t[0] + t[2], 6 * 4);
        // the late arrivals only ever land on survivors
        assert_eq!(b.admit(1.0), 2);
        assert_eq!(b.tokens_per_device()[1], 0);
        assert_eq!(b.inflight_len(), 8);
    }

    #[test]
    fn fail_device_conserves_every_request_to_retirement() {
        let trace = vec![req(0.0, 4, 3); 4];
        let mut b = ContinuousBatcher::new(trace, 2, 4);
        b.admit(0.0);
        b.fail_device(0);
        let mut done = Vec::new();
        for i in 1..=3 {
            done.extend(b.advance(i as f64));
        }
        assert_eq!(done.len(), 4);
        assert!(b.done());
    }

    #[test]
    #[should_panic(expected = "cannot fail the last device")]
    fn failing_every_device_panics() {
        let mut b = ContinuousBatcher::new(vec![req(0.0, 4, 1)], 2, 2);
        b.fail_device(0);
        b.fail_device(1);
    }

    #[test]
    fn next_arrival_supports_idle_skip() {
        let mut b = ContinuousBatcher::new(vec![req(0.0, 4, 1), req(9.0, 4, 1)], 1, 8);
        assert_eq!(b.next_arrival(), Some(0.0));
        b.admit(0.0);
        b.advance(0.2);
        assert_eq!(b.inflight_len(), 0);
        assert_eq!(b.next_arrival(), Some(9.0)); // clock can jump to 9.0
        b.admit(9.0);
        b.advance(9.3);
        assert!(b.done());
        assert_eq!(b.next_arrival(), None);
    }
}
