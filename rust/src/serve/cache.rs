//! Expert-weight device cache with capacity eviction.
//!
//! At serving batch sizes the expert weights, not the activations, are
//! the memory bill: a device hosting `e_per_dev` experts may only have
//! HBM for `cap` of them resident. Every decode iteration touches the
//! hosted experts the gate routed tokens to; a touched expert that is not
//! resident is a **miss** and its weights stream in from the expert's
//! canonical home device (the parameter-server copy) — priced as real
//! bytes over the real links by the caller, through the same contention
//! [`crate::comm::CostEngine`] that prices migrations.
//!
//! Retention is priority-based and cache-oblivious: the access stream
//! (which experts the gate picks) does not depend on cache contents, so a
//! device's residents are always the top-`cap` hosted experts under the
//! policy's priority order. That makes the hit rate provably monotone in
//! capacity for **both** policies (the priority order is
//! capacity-independent, and top-`cap` prefixes are nested), and makes
//! `cap ≥ e_per_dev` purely compulsory-miss (zero misses after warmup) —
//! the invariants `rust/tests/prop_serve.rs` checks.
//!
//! * [`CachePolicy::Lru`] — priority = recency of last touch;
//! * [`CachePolicy::EwmaPrioritized`] — priority = the expert's gate-load
//!   EWMA (the serving twin of the placement engine's
//!   [`crate::placement::GateLoadEwma`]), recency as tie-break: a
//!   one-burst cold expert cannot evict a consistently hot one.

use crate::placement::Placement;
use crate::util::Mat;

/// Which eviction priority the expert cache uses (CLI `--cache`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CachePolicy {
    #[default]
    Lru,
    EwmaPrioritized,
}

impl CachePolicy {
    /// All selectable policies, for `--list-modes` and sweeps.
    pub const ALL: [CachePolicy; 2] = [CachePolicy::Lru, CachePolicy::EwmaPrioritized];
}

impl std::fmt::Display for CachePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CachePolicy::Lru => "lru",
            CachePolicy::EwmaPrioritized => "ewma",
        })
    }
}

impl std::str::FromStr for CachePolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<CachePolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "lru" => Ok(CachePolicy::Lru),
            "ewma" | "ewma-prioritized" => Ok(CachePolicy::EwmaPrioritized),
            other => Err(format!("unknown cache policy {other:?} (lru|ewma)")),
        }
    }
}

/// One iteration's cache outcome: the hit/miss counts and the fetch byte
/// matrix (`bytes[home][host]`, canonical home → current host) the caller
/// prices through the contention engine.
#[derive(Clone, Debug)]
pub struct CacheAccess {
    pub hits: usize,
    pub misses: usize,
    pub fetch_bytes: Mat,
}

/// Per-device expert-weight cache over the experts each device currently
/// hosts. `cap` is the resident-expert capacity per device; `cap = 0`
/// disables caching entirely (every expert always resident — the
/// infinite-HBM baseline).
#[derive(Clone, Debug)]
pub struct ExpertCache {
    p: usize,
    e_per_dev: usize,
    cap: usize,
    policy: CachePolicy,
    alpha: f64,
    /// resident[e]: whether expert e is resident on its current host.
    resident: Vec<bool>,
    /// Last-touch stamp per expert (iteration counter; 0 = never).
    stamp: Vec<u64>,
    /// Gate-load EWMA per expert.
    ewma: Vec<f64>,
    tick: u64,
    total_hits: u64,
    total_misses: u64,
}

impl ExpertCache {
    pub fn new(p: usize, e_per_dev: usize, cap: usize, policy: CachePolicy) -> ExpertCache {
        Self::with_alpha(p, e_per_dev, cap, policy, 0.25)
    }

    pub fn with_alpha(
        p: usize,
        e_per_dev: usize,
        cap: usize,
        policy: CachePolicy,
        alpha: f64,
    ) -> ExpertCache {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA alpha must be in (0, 1]");
        let n = p * e_per_dev;
        ExpertCache {
            p,
            e_per_dev,
            cap,
            policy,
            alpha,
            resident: vec![cap == 0; n],
            stamp: vec![0; n],
            ewma: vec![0.0; n],
            tick: 0,
            total_hits: 0,
            total_misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn total_hits(&self) -> u64 {
        self.total_hits
    }

    pub fn total_misses(&self) -> u64 {
        self.total_misses
    }

    /// One iteration: `counts` is the P×N dispatch matrix (tokens),
    /// `placement` the active expert→device map, `expert_bytes` one
    /// expert's weight payload. Touched experts (column sum > 0) hit if
    /// resident, otherwise miss and fetch `expert_bytes` from their
    /// canonical home into their current host; residency is then
    /// re-settled to the top-`cap` priority experts per device.
    pub fn access(
        &mut self,
        counts: &Mat,
        placement: &Placement,
        expert_bytes: f64,
    ) -> CacheAccess {
        let n = self.p * self.e_per_dev;
        assert_eq!(counts.cols(), n, "counts shape");
        assert_eq!((placement.p(), placement.e_per_dev()), (self.p, self.e_per_dev));
        self.tick += 1;

        let mut hits = 0;
        let mut misses = 0;
        let mut fetch = Mat::zeros(self.p, self.p);
        for e in 0..n {
            let load = counts.col_sum(e);
            // gate-load EWMA over every expert, touched or not
            self.ewma[e] = (1.0 - self.alpha) * self.ewma[e] + self.alpha * load;
            if load <= 0.0 {
                continue;
            }
            if self.resident[e] {
                hits += 1;
            } else {
                misses += 1;
                let home = e / self.e_per_dev;
                let host = placement.device_of(e);
                fetch.add_assign(home, host, expert_bytes);
            }
            self.stamp[e] = self.tick;
            self.resident[e] = true;
        }
        if self.cap > 0 {
            self.settle(placement);
        }
        self.total_hits += hits as u64;
        self.total_misses += misses as u64;
        CacheAccess { hits, misses, fetch_bytes: fetch }
    }

    /// After a live migration, moved experts' weights travelled with the
    /// migration (already priced by the placement engine): they arrive
    /// resident on their new host, and the old host's copy is dropped.
    /// Residency is re-settled per device under the new hosting.
    pub fn apply_migration(&mut self, moved: &[usize], placement: &Placement) {
        for &e in moved {
            self.resident[e] = true;
            self.stamp[e] = self.tick;
        }
        if self.cap > 0 {
            self.settle(placement);
        }
    }

    /// Whether expert `e` is currently resident on its host.
    pub fn is_resident(&self, e: usize) -> bool {
        self.resident[e]
    }

    /// Keep only the top-`cap` priority resident experts per device.
    fn settle(&mut self, placement: &Placement) {
        for dev in 0..self.p {
            let mut resident_here: Vec<usize> = placement
                .experts_on(dev)
                .into_iter()
                .filter(|&e| self.resident[e])
                .collect();
            if resident_here.len() <= self.cap {
                continue;
            }
            // highest priority first; evict the tail
            resident_here.sort_by(|&a, &b| self.priority(b).total_cmp(&self.priority(a)));
            for &e in &resident_here[self.cap..] {
                self.resident[e] = false;
            }
        }
    }

    /// Retention priority (higher = keep). Strictly positive stamps make
    /// the recency tie-break well-ordered; the index term breaks exact
    /// ties deterministically.
    fn priority(&self, e: usize) -> f64 {
        let recency = self.stamp[e] as f64 - e as f64 / (self.p * self.e_per_dev) as f64;
        match self.policy {
            CachePolicy::Lru => recency,
            // EWMA dominates; recency only breaks near-exact load ties
            CachePolicy::EwmaPrioritized => self.ewma[e] * 1e9 + recency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts_for(p: usize, e_per_dev: usize, touched: &[(usize, f64)]) -> Mat {
        let mut m = Mat::zeros(p, p * e_per_dev);
        for &(e, tok) in touched {
            m.set(0, e, tok);
        }
        m
    }

    #[test]
    fn policies_round_trip() {
        for pol in CachePolicy::ALL {
            let spec = pol.to_string();
            assert_eq!(spec.parse::<CachePolicy>().unwrap(), pol, "{spec}");
        }
        assert!("fifo".parse::<CachePolicy>().is_err());
    }

    #[test]
    fn first_touch_misses_then_hits_within_capacity() {
        let pl = Placement::identity(2, 2);
        let mut c = ExpertCache::new(2, 2, 2, CachePolicy::Lru);
        let counts = counts_for(2, 2, &[(0, 4.0), (1, 2.0)]);
        let a = c.access(&counts, &pl, 100.0);
        assert_eq!((a.hits, a.misses), (0, 2)); // compulsory
        assert_eq!(a.fetch_bytes.get(0, 0), 200.0); // both home = host = 0
        let a = c.access(&counts, &pl, 100.0);
        assert_eq!((a.hits, a.misses), (2, 0));
        assert_eq!(a.fetch_bytes.sum(), 0.0);
    }

    #[test]
    fn lru_evicts_the_coldest_expert() {
        // device 0 hosts experts 0..4, cap 2
        let pl = Placement::identity(1, 4);
        let mut c = ExpertCache::new(1, 4, 2, CachePolicy::Lru);
        c.access(&counts_for(1, 4, &[(0, 1.0)]), &pl, 1.0);
        c.access(&counts_for(1, 4, &[(1, 1.0)]), &pl, 1.0);
        c.access(&counts_for(1, 4, &[(2, 1.0)]), &pl, 1.0); // evicts 0 (oldest)
        assert!(!c.is_resident(0) && c.is_resident(1) && c.is_resident(2));
        let a = c.access(&counts_for(1, 4, &[(0, 1.0)]), &pl, 1.0);
        assert_eq!(a.misses, 1);
    }

    #[test]
    fn ewma_keeps_the_hot_expert_through_a_burst() {
        let pl = Placement::identity(1, 4);
        let mut lru = ExpertCache::new(1, 4, 1, CachePolicy::Lru);
        let mut ewma = ExpertCache::new(1, 4, 1, CachePolicy::EwmaPrioritized);
        // expert 0 is consistently hot; expert 3 gets one cold burst
        for _ in 0..10 {
            lru.access(&counts_for(1, 4, &[(0, 10.0)]), &pl, 1.0);
            ewma.access(&counts_for(1, 4, &[(0, 10.0)]), &pl, 1.0);
        }
        lru.access(&counts_for(1, 4, &[(3, 1.0)]), &pl, 1.0);
        ewma.access(&counts_for(1, 4, &[(3, 1.0)]), &pl, 1.0);
        // LRU dropped the hot expert for the burst; EWMA kept it
        assert!(!lru.is_resident(0) && lru.is_resident(3));
        assert!(ewma.is_resident(0) && !ewma.is_resident(3));
        let a = ewma.access(&counts_for(1, 4, &[(0, 10.0)]), &pl, 1.0);
        assert_eq!(a.hits, 1);
        let a = lru.access(&counts_for(1, 4, &[(0, 10.0)]), &pl, 1.0);
        assert_eq!(a.misses, 1);
    }

    #[test]
    fn misses_fetch_from_canonical_home_to_current_host() {
        // expert 0's home is device 0; swap it to device 1
        let mut pl = Placement::identity(2, 1);
        pl.swap_experts(0, 1);
        let mut c = ExpertCache::new(2, 1, 1, CachePolicy::Lru);
        let mut counts = Mat::zeros(2, 2);
        counts.set(0, 0, 3.0);
        let a = c.access(&counts, &pl, 64.0);
        assert_eq!(a.misses, 1);
        assert_eq!(a.fetch_bytes.get(0, 1), 64.0); // home 0 → host 1
    }

    #[test]
    fn cap_zero_disables_caching() {
        let pl = Placement::identity(1, 4);
        let mut c = ExpertCache::new(1, 4, 0, CachePolicy::Lru);
        for _ in 0..3 {
            let a = c.access(&counts_for(1, 4, &[(0, 1.0), (3, 1.0)]), &pl, 1.0);
            assert_eq!(a.misses, 0);
        }
    }

    #[test]
    fn migrated_expert_arrives_resident_on_new_host() {
        let mut pl = Placement::identity(2, 2);
        let mut c = ExpertCache::new(2, 2, 2, CachePolicy::Lru);
        let mut counts = Mat::zeros(2, 4);
        counts.set(0, 0, 1.0);
        c.access(&counts, &pl, 1.0);
        pl.swap_experts(0, 2);
        c.apply_migration(&[0, 2], &pl);
        let a = c.access(&counts, &pl, 1.0); // expert 0 now hosted on dev 1
        assert_eq!((a.hits, a.misses), (1, 0));
    }
}
