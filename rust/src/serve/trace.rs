//! Request-arrival trace generators for the serving simulator.
//!
//! Three processes, all seeded through [`crate::util::rng::Rng`] so a
//! trace is a pure function of its [`TraceConfig`] (the python mirror
//! `python/serve_mirror.py` reproduces them bit-for-bit):
//!
//! * [`TraceKind::Poisson`] — exponential inter-arrivals at `rate_rps`;
//! * [`TraceKind::Bursty`] — a 2-state Markov-modulated Poisson process:
//!   an ON state arriving at `BURST_HIGH_X · rate` and an OFF state at
//!   `rate / BURST_LOW_DIV`, toggling with probability `BURST_SWITCH_P`
//!   after each arrival (geometric sojourns). This is the trace the
//!   acceptance scenario stresses caches with: bursts pile sequences up
//!   and quiet spells let them drain;
//! * [`TraceKind::Diurnal`] — a replayed diurnal curve: a Poisson process
//!   thinned against `rate · (1 + DIURNAL_AMPL · sin(2πt / DIURNAL_PERIOD_S))`,
//!   compressing a day's load shape into a simulable period.
//!
//! Per request the generator draws, in this fixed order: the
//! inter-arrival gap (plus the thinning/state draws its process needs),
//! the prompt length, then the output length — both uniform in
//! `[mean/2, 3·mean/2)` (mirrorable with one `below` draw each).

use crate::util::rng::Rng;

/// Burst state multiplier / divisor / toggle probability of the MMPP.
pub const BURST_HIGH_X: f64 = 4.0;
pub const BURST_LOW_DIV: f64 = 4.0;
pub const BURST_SWITCH_P: f64 = 0.08;
/// Compressed "day" of the diurnal trace, and its modulation depth.
pub const DIURNAL_PERIOD_S: f64 = 120.0;
pub const DIURNAL_AMPL: f64 = 0.8;

/// One inference request: when it arrives and how much work it carries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Arrival time on the simulated clock, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt tokens to prefill in the request's first iteration.
    pub prompt_tokens: usize,
    /// Output tokens to decode (≥ 1; the first is emitted by prefill).
    pub output_tokens: usize,
}

/// Which arrival process generates the trace (CLI `--trace`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TraceKind {
    #[default]
    Poisson,
    Bursty,
    Diurnal,
}

impl TraceKind {
    /// All selectable traces, for `--list-modes` and sweeps.
    pub const ALL: [TraceKind; 3] = [TraceKind::Poisson, TraceKind::Bursty, TraceKind::Diurnal];
}

impl std::fmt::Display for TraceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TraceKind::Poisson => "poisson",
            TraceKind::Bursty => "bursty",
            TraceKind::Diurnal => "diurnal",
        })
    }
}

impl std::str::FromStr for TraceKind {
    type Err = String;

    fn from_str(s: &str) -> Result<TraceKind, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "poisson" => Ok(TraceKind::Poisson),
            "bursty" | "mmpp" => Ok(TraceKind::Bursty),
            "diurnal" => Ok(TraceKind::Diurnal),
            other => Err(format!("unknown trace {other:?} (poisson|bursty|diurnal)")),
        }
    }
}

/// Everything a trace is a function of.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub kind: TraceKind,
    /// Mean arrival rate in requests/second (of the unmodulated process).
    pub rate_rps: f64,
    /// Requests to generate.
    pub n_requests: usize,
    pub seed: u64,
    /// Mean prompt length in tokens (lengths uniform in [m/2, 3m/2)).
    pub prompt_mean: usize,
    /// Mean output length in tokens (same distribution; min 1).
    pub output_mean: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            kind: TraceKind::Poisson,
            rate_rps: 8.0,
            n_requests: 64,
            seed: 0,
            prompt_mean: 32,
            output_mean: 16,
        }
    }
}

/// Generate the trace: `n_requests` requests in arrival order.
pub fn generate(cfg: &TraceConfig) -> Vec<Request> {
    assert!(cfg.rate_rps > 0.0, "arrival rate must be positive");
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut t = 0.0;
    let mut burst_on = false;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        match cfg.kind {
            TraceKind::Poisson => {
                t += exp_gap(&mut rng, cfg.rate_rps);
            }
            TraceKind::Bursty => {
                let rate = if burst_on {
                    cfg.rate_rps * BURST_HIGH_X
                } else {
                    cfg.rate_rps / BURST_LOW_DIV
                };
                t += exp_gap(&mut rng, rate);
                if rng.f64() < BURST_SWITCH_P {
                    burst_on = !burst_on;
                }
            }
            TraceKind::Diurnal => {
                // thinning against the sinusoidal envelope: propose at the
                // peak rate, accept with rate(t)/peak
                let peak = cfg.rate_rps * (1.0 + DIURNAL_AMPL);
                loop {
                    t += exp_gap(&mut rng, peak);
                    let rate_t = cfg.rate_rps
                        * (1.0
                            + DIURNAL_AMPL
                                * (2.0 * std::f64::consts::PI * t / DIURNAL_PERIOD_S).sin());
                    if rng.f64() * peak < rate_t {
                        break;
                    }
                }
            }
        }
        let prompt = span_sample(&mut rng, cfg.prompt_mean);
        let output = span_sample(&mut rng, cfg.output_mean);
        out.push(Request { arrival_s: t, prompt_tokens: prompt, output_tokens: output });
    }
    out
}

/// Exponential inter-arrival gap at `rate` (one `f64` draw).
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    -rng.f64().max(1e-300).ln() / rate
}

/// Uniform length in `[mean/2, 3·mean/2)`, at least 1 (one `below` draw).
fn span_sample(rng: &mut Rng, mean: usize) -> usize {
    let lo = (mean / 2).max(1);
    let hi = (3 * mean).div_ceil(2).max(lo + 1);
    rng.range(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_round_trip_and_reject_garbage() {
        for kind in TraceKind::ALL {
            let spec = kind.to_string();
            assert_eq!(spec.parse::<TraceKind>().unwrap(), kind, "{spec}");
        }
        assert!("weibull".parse::<TraceKind>().is_err());
    }

    #[test]
    fn traces_are_deterministic_in_seed() {
        for kind in TraceKind::ALL {
            let cfg = TraceConfig { kind, seed: 42, ..Default::default() };
            assert_eq!(generate(&cfg), generate(&cfg), "{kind}");
            let other = TraceConfig { seed: 43, ..cfg };
            assert_ne!(generate(&cfg), generate(&other), "{kind}");
        }
    }

    #[test]
    fn arrivals_are_sorted_and_lengths_in_band() {
        for kind in TraceKind::ALL {
            let cfg = TraceConfig { kind, n_requests: 200, seed: 7, ..Default::default() };
            let trace = generate(&cfg);
            assert_eq!(trace.len(), 200);
            for w in trace.windows(2) {
                assert!(w[1].arrival_s >= w[0].arrival_s, "{kind}");
            }
            for r in &trace {
                assert!(r.prompt_tokens >= cfg.prompt_mean / 2, "{kind}");
                assert!(r.prompt_tokens < 3 * cfg.prompt_mean, "{kind}");
                assert!(r.output_tokens >= 1, "{kind}");
            }
        }
    }

    #[test]
    fn golden_first_request_matches_the_python_mirror() {
        // pinned in python/serve_mirror.py: same seed, same draw order,
        // same IEEE-754 arithmetic
        let cfg = TraceConfig {
            kind: TraceKind::Poisson,
            rate_rps: 20.0,
            n_requests: 1,
            seed: 42,
            prompt_mean: 32,
            output_mean: 16,
        };
        let r = generate(&cfg)[0];
        assert_eq!(r.arrival_s.to_bits(), 0.1239285554529295f64.to_bits());
        assert_eq!((r.prompt_tokens, r.output_tokens), (28, 18));
    }

    #[test]
    fn poisson_mean_rate_is_close() {
        let cfg = TraceConfig {
            kind: TraceKind::Poisson,
            rate_rps: 10.0,
            n_requests: 2000,
            seed: 3,
            ..Default::default()
        };
        let trace = generate(&cfg);
        let span = trace.last().unwrap().arrival_s;
        let measured = trace.len() as f64 / span;
        assert!((measured - 10.0).abs() < 1.0, "rate {measured}");
    }

    #[test]
    fn bursty_has_heavier_gap_tail_than_poisson() {
        // same mean-ish rate, but the MMPP mixes short ON gaps with long
        // OFF gaps → higher gap variance
        let n = 2000;
        let var = |kind| {
            let cfg =
                TraceConfig { kind, rate_rps: 8.0, n_requests: n, seed: 11, ..Default::default() };
            let tr = generate(&cfg);
            let gaps: Vec<f64> =
                tr.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
                / gaps.len() as f64
                / (mean * mean) // squared coefficient of variation
        };
        assert!(var(TraceKind::Bursty) > var(TraceKind::Poisson) * 1.5);
    }
}
