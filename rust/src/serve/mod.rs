//! Inference serving simulator: continuous batching + expert-weight
//! caching + SLO metrics on the same priced cluster as training.
//!
//! Training answered "how fast does a step go"; serving asks "how fast
//! does a *token* come back". This module reuses the whole pricing stack
//! — topology, contention-aware a2a plans, the epoch-aware
//! [`PlanCache`](crate::coordinator::PlanCache), live placement, the
//! chunked overlap clock — through the [`Workload`] seam, and adds the
//! three things a decode loop has that a training loop does not:
//!
//! * a [`batcher`] admitting and retiring sequences at iteration
//!   granularity against a seeded arrival [`trace`];
//! * a [`cache`] holding only part of each device's expert weights, whose
//!   misses are priced as real byte transfers over the real links;
//! * SLO accounting (TTFT/TPOT percentiles, goodput under a deadline)
//!   accumulated in the shared [`RunLog`].
//!
//! Each simulated iteration prices one decode/prefill step under
//! [`StepProfile::decode`] — forward-only, dispatch+combine once per MoE
//! layer, no gradient allreduce — with `tokens_per_dev` set to the live
//! batch's largest per-device token bill, then advances the request clock
//! by `step + fetch + migration` seconds. Routing draws each token's
//! top-k experts from the policy's converged dispatch pattern tilted by a
//! Zipf popularity over each device's canonical experts, so gate skew is
//! present without running a real gate network: the point is pricing the
//! *system*, not the model. There is no [`crate::runtime`] backend in the
//! loop — `python/serve_mirror.py` reproduces the decision math instead.
//!
//! ```no_run
//! use ta_moe::serve::{ServeBuilder, TraceKind};
//! let mut sess = ServeBuilder::new()
//!     .preset("tiny4")
//!     .experts_per_dev(4)
//!     .cluster("table1")
//!     .policy_named("ta-moe")
//!     .trace_kind(TraceKind::Bursty)
//!     .cache_cap(2)
//!     .build()
//!     .unwrap();
//! sess.run(10_000).unwrap();
//! println!("goodput {:.1} tok/s", sess.goodput());
//! ```

pub mod batcher;
pub mod cache;
pub mod trace;

pub use batcher::ContinuousBatcher;
pub use cache::{CacheAccess, CachePolicy, ExpertCache};
pub use trace::{Request, TraceConfig, TraceKind};

use crate::comm::{A2aAlgo, CostEngine};
use crate::coordinator::workload::trace_migration;
use crate::coordinator::{
    converged_counts, parse_policy, DispatchPolicy, ModelShape, PolicyInputs, StepProfile,
    TaMoe, Workload, WorkloadCore, PLAN_CACHE_TOL,
};
use crate::metrics::{MigrationRecord, PerturbationRecord, RequestRecord, RunLog, StepRecord};
use crate::overlap::OverlapMode;
use crate::perturb::ChaosSpec;
use crate::placement::{Placement, PlacementConfig};
use crate::runtime::ModelCfg;
use crate::topology::Topology;
use crate::trace::{TraceLevel, Tracer};
use crate::util::{rng::Rng, Mat};
use anyhow::Result;

/// Seed salt separating the routing RNG stream from the trace RNG (the
/// python mirror uses the same constant).
pub const ROUTE_SEED_SALT: u64 = 0x5345_5256_45; // "SERVE"

/// Builder for a [`ServeSession`] — same shape as
/// [`crate::coordinator::SessionBuilder`], minus the backend (serving is
/// pure pricing) plus the serve knobs: trace, cache, SLO, admission.
pub struct ServeBuilder {
    cfg: ModelCfg,
    /// Unknown preset name, surfaced as an error at [`ServeBuilder::build`]
    /// so the chain stays infallible.
    preset_err: Option<String>,
    experts_per_dev: Option<usize>,
    topo: Option<Topology>,
    cluster: Option<String>,
    policy: Option<Box<dyn DispatchPolicy>>,
    policy_spec: Option<String>,
    a2a: Option<A2aAlgo>,
    a2a_spec: Option<String>,
    overlap: OverlapMode,
    overlap_spec: Option<String>,
    placement: Option<PlacementConfig>,
    plan_cache_tol: f64,
    flops_per_dev: f64,
    trace: TraceConfig,
    cache_cap: usize,
    cache_policy: CachePolicy,
    slo_s: f64,
    max_inflight_per_dev: usize,
    zipf_s: f64,
    chaos: ChaosSpec,
    chaos_spec: Option<String>,
    trace_level: Option<TraceLevel>,
    label: Option<String>,
}

impl Default for ServeBuilder {
    fn default() -> Self {
        ServeBuilder {
            cfg: ModelCfg::preset("tiny4").expect("tiny4 preset"),
            preset_err: None,
            experts_per_dev: None,
            topo: None,
            cluster: None,
            policy: None,
            policy_spec: None,
            a2a: None,
            a2a_spec: None,
            overlap: OverlapMode::Serial,
            overlap_spec: None,
            placement: None,
            plan_cache_tol: PLAN_CACHE_TOL,
            flops_per_dev: 45e12,
            trace: TraceConfig::default(),
            cache_cap: 0,
            cache_policy: CachePolicy::Lru,
            slo_s: 0.2,
            max_inflight_per_dev: 8,
            zipf_s: 1.0,
            chaos: ChaosSpec::off(),
            chaos_spec: None,
            trace_level: None,
            label: None,
        }
    }
}

impl ServeBuilder {
    pub fn new() -> ServeBuilder {
        ServeBuilder::default()
    }

    /// Model shape by preset name (see [`ModelCfg::preset_names`]).
    pub fn preset(mut self, name: &str) -> Self {
        match ModelCfg::preset(name) {
            Some(cfg) => self.cfg = cfg,
            None => self.preset_err = Some(name.to_string()),
        }
        self
    }

    /// Explicit model config (tests; sweeping shapes without presets).
    pub fn model_cfg(mut self, cfg: ModelCfg) -> Self {
        self.cfg = cfg;
        self
    }

    /// Override experts hosted per device (the serving knob that creates
    /// cache pressure; presets all ship `e_per_dev = 1`). Rewrites the
    /// derived fields the same way `configs.py` does.
    pub fn experts_per_dev(mut self, n: usize) -> Self {
        self.experts_per_dev = Some(n);
        self
    }

    /// Cluster preset name ("A" | "B" | "C" | "table1"), scaled to the
    /// model's world size.
    pub fn cluster(mut self, name: impl Into<String>) -> Self {
        self.cluster = Some(name.into());
        self
    }

    pub fn topology(mut self, topo: Topology) -> Self {
        self.topo = Some(topo);
        self
    }

    pub fn policy(mut self, policy: Box<dyn DispatchPolicy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Policy by registry name ("ta-moe" | "deepspeed" | ...).
    pub fn policy_named(mut self, spec: impl Into<String>) -> Self {
        self.policy_spec = Some(spec.into());
        self
    }

    pub fn a2a(mut self, algo: A2aAlgo) -> Self {
        self.a2a = Some(algo);
        self
    }

    pub fn a2a_named(mut self, spec: impl Into<String>) -> Self {
        self.a2a_spec = Some(spec.into());
        self
    }

    pub fn overlap(mut self, mode: OverlapMode) -> Self {
        self.overlap = mode;
        self
    }

    pub fn overlap_named(mut self, spec: impl Into<String>) -> Self {
        self.overlap_spec = Some(spec.into());
        self
    }

    /// Enable the live placement engine (None = canonical hosting).
    pub fn placement(mut self, cfg: Option<PlacementConfig>) -> Self {
        self.placement = cfg;
        self
    }

    /// Placement with the default config at an attempt cadence.
    pub fn placement_every(mut self, every: usize) -> Self {
        self.placement = Some(PlacementConfig { every, ..Default::default() });
        self
    }

    pub fn plan_cache_tol(mut self, tol: f64) -> Self {
        self.plan_cache_tol = tol;
        self
    }

    pub fn flops_per_dev(mut self, flops: f64) -> Self {
        self.flops_per_dev = flops;
        self
    }

    /// Full arrival-trace config (kind + rate + length + seed + shapes).
    pub fn trace(mut self, cfg: TraceConfig) -> Self {
        self.trace = cfg;
        self
    }

    pub fn trace_kind(mut self, kind: TraceKind) -> Self {
        self.trace.kind = kind;
        self
    }

    pub fn rate_rps(mut self, rate: f64) -> Self {
        self.trace.rate_rps = rate;
        self
    }

    pub fn requests(mut self, n: usize) -> Self {
        self.trace.n_requests = n;
        self
    }

    /// Seed for both the trace and the routing draws (the routing stream
    /// is salted with [`ROUTE_SEED_SALT`]).
    pub fn seed(mut self, seed: u64) -> Self {
        self.trace.seed = seed;
        self
    }

    /// Resident experts per device (0 = unlimited, caching disabled).
    pub fn cache_cap(mut self, cap: usize) -> Self {
        self.cache_cap = cap;
        self
    }

    pub fn cache_policy(mut self, policy: CachePolicy) -> Self {
        self.cache_policy = policy;
        self
    }

    /// TTFT deadline for [`ServeSession::goodput`], in seconds.
    pub fn slo_s(mut self, s: f64) -> Self {
        self.slo_s = s;
        self
    }

    /// KV-cache slot budget: concurrent sequences per device.
    pub fn max_inflight_per_dev(mut self, n: usize) -> Self {
        self.max_inflight_per_dev = n;
        self
    }

    /// Zipf exponent of the per-device expert popularity tilt (0 = the
    /// policy's converged pattern unmodified).
    pub fn zipf_s(mut self, s: f64) -> Self {
        self.zipf_s = s;
        self
    }

    /// Inject this scripted fault stream (see [`ChaosSpec`]).
    pub fn chaos(mut self, spec: ChaosSpec) -> Self {
        self.chaos = spec;
        self
    }

    /// Parse the fault stream from a `--chaos` spec at build time
    /// (`off`, or `+`-joined `straggler:…`, `link:…`, `nodeloss:…`,
    /// `drift:…` events).
    pub fn chaos_named(mut self, spec: impl Into<String>) -> Self {
        self.chaos_spec = Some(spec.into());
        self
    }

    /// Attach the deterministic tracer at this level (see
    /// [`crate::trace`]; not to be confused with [`ServeBuilder::trace`],
    /// which configures the *arrival* trace). Default: no tracer.
    pub fn trace_level(mut self, level: TraceLevel) -> Self {
        self.trace_level = Some(level);
        self
    }

    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Assemble the session: resolve topology/policy/a2a exactly like the
    /// training builder, generate the trace, derive the routing matrix,
    /// and wire the batcher + cache into a [`WorkloadCore`] running the
    /// decode [`StepProfile`].
    pub fn build(self) -> Result<ServeSession> {
        if let Some(name) = self.preset_err {
            anyhow::bail!(
                "unknown model preset {name:?} (known: {:?})",
                ModelCfg::preset_names()
            );
        }
        let mut cfg = self.cfg;
        if let Some(e) = self.experts_per_dev {
            anyhow::ensure!(e > 0, "experts_per_dev must be >= 1");
            cfg.e_per_dev = e;
            cfg.n_experts = cfg.p * e;
            // same formula as configs.py / ModelCfg::preset
            let raw = (cfg.cap_factor * (cfg.k * cfg.tokens_per_dev * cfg.p) as f64
                / cfg.n_experts as f64)
                .ceil();
            cfg.capacity = (raw as usize).div_ceil(8) * 8;
        }

        let topo = match (self.topo, self.cluster) {
            (Some(t), _) => t,
            (None, Some(c)) => crate::config::topology_for(&c, cfg.p),
            (None, None) => crate::config::topology_for("C", cfg.p),
        };
        anyhow::ensure!(
            topo.p() == cfg.p,
            "topology has {} devices, model wants {}",
            topo.p(),
            cfg.p
        );

        let policy: Box<dyn DispatchPolicy> = match (self.policy, self.policy_spec) {
            (Some(p), _) => p,
            (None, Some(spec)) => parse_policy(&spec).map_err(anyhow::Error::msg)?,
            (None, None) => Box::new(TaMoe::default()),
        };
        let a2a = match (self.a2a, self.a2a_spec) {
            (Some(a), _) => a,
            (None, Some(spec)) => spec.parse::<A2aAlgo>().map_err(anyhow::Error::msg)?,
            (None, None) => policy.preferred_a2a(),
        };
        a2a.validate_for(topo.p()).map_err(anyhow::Error::msg)?;
        let overlap = match self.overlap_spec {
            Some(spec) => spec.parse::<OverlapMode>().map_err(anyhow::Error::msg)?,
            None => self.overlap,
        };
        anyhow::ensure!(overlap != OverlapMode::Fixed(0), "overlap chunk count must be >= 1");
        anyhow::ensure!(self.trace.n_requests > 0, "trace must carry at least one request");
        anyhow::ensure!(self.slo_s > 0.0, "SLO must be positive");
        let chaos = match self.chaos_spec {
            Some(spec) => spec.parse::<ChaosSpec>().map_err(anyhow::Error::msg)?,
            None => self.chaos,
        };

        let inputs = policy.runtime_inputs(&topo, &cfg);
        let route = route_matrix(&inputs, policy.as_ref(), &topo, &cfg, self.zipf_s);
        let requests =
            trace::generate(&self.trace);
        let batcher = ContinuousBatcher::new(requests, cfg.p, self.max_inflight_per_dev);
        let cache =
            ExpertCache::new(cfg.p, cfg.e_per_dev, self.cache_cap, self.cache_policy);
        let label = self.label.unwrap_or_else(|| {
            format!("serve-{}/{}", self.trace.kind, policy.name())
        });
        let shape = ModelShape::from_cfg(&cfg);
        let mut core = WorkloadCore::new(
            topo,
            shape,
            a2a,
            overlap,
            self.flops_per_dev,
            cfg.e_per_dev,
            StepProfile::decode(),
            self.plan_cache_tol,
            self.placement,
        )
        .with_chaos(chaos)?;
        if let Some(level) = self.trace_level {
            core.attach_tracer(level);
        }
        let identity = Placement::identity(cfg.p, cfg.e_per_dev);
        let rng = Rng::seed_from_u64(self.trace.seed ^ ROUTE_SEED_SALT);
        Ok(ServeSession {
            core,
            policy,
            cfg,
            route,
            cache,
            batcher,
            rng,
            identity,
            log: RunLog::new(&label, 0),
            now_s: 0.0,
            slo_s: self.slo_s,
            zipf_s: self.zipf_s,
            last_counts: None,
        })
    }
}

/// Routing matrix: the policy's converged dispatch preference (the
/// TA-MoE Eq. 7 target when the policy has one) tilted per source device
/// by a Zipf popularity over each device's canonical expert block, rows
/// normalised to draw weights. Skew is intrinsic to the canonical expert
/// id, so migrating a hot expert moves its load with it.
fn route_matrix(
    inputs: &PolicyInputs,
    policy: &dyn DispatchPolicy,
    topo: &Topology,
    cfg: &ModelCfg,
    zipf_s: f64,
) -> Mat {
    let base = match &inputs.target {
        Some(t) => t.c.clone(),
        None => converged_counts(policy, topo, cfg),
    };
    let (p, n) = (cfg.p, cfg.n_experts);
    let mut route = Mat::zeros(p, n);
    for i in 0..p {
        let row: Vec<f64> = (0..n)
            .map(|e| {
                let pop = (1.0 + (e % cfg.e_per_dev) as f64).powf(-zipf_s);
                base.get(i, e).max(0.0) * pop
            })
            .collect();
        let sum: f64 = row.iter().sum();
        if sum > 0.0 {
            for e in 0..n {
                route.set(i, e, row[e] / sum);
            }
        } else {
            for e in 0..n {
                route.set(i, e, 1.0 / n as f64);
            }
        }
    }
    route
}

/// A continuous-batching serving run over one topology, one dispatch
/// policy, and one arrival trace — the inference twin of
/// [`crate::coordinator::Session`], priced on the same cluster clock.
pub struct ServeSession {
    core: WorkloadCore,
    policy: Box<dyn DispatchPolicy>,
    cfg: ModelCfg,
    /// P×N per-device expert draw weights (rows sum to 1).
    route: Mat,
    cache: ExpertCache,
    batcher: ContinuousBatcher,
    rng: Rng,
    /// Canonical hosting, used whenever the placement engine is off.
    identity: Placement,
    log: RunLog,
    /// The simulated request clock (includes idle gaps between arrivals —
    /// unlike the busy-time axis in [`RunLog::sim_time_axis`]).
    now_s: f64,
    slo_s: f64,
    zipf_s: f64,
    /// The dispatch counts of the last priced iteration — the
    /// representative step `--analyze` re-prices counterfactually.
    last_counts: Option<Mat>,
}

impl ServeSession {
    /// One serving iteration: admit arrivals, sample the batch's routed
    /// counts, let placement observe/migrate, charge cache misses, price
    /// the decode step, advance the clock, retire finished requests.
    pub fn step(&mut self) -> Result<StepRecord> {
        anyhow::ensure!(!self.batcher.done(), "serve step on an exhausted trace");
        // idle-skip: nothing in flight → jump the clock to the next
        // arrival instead of simulating empty iterations
        if self.batcher.inflight_len() == 0 {
            if let Some(t) = self.batcher.next_arrival() {
                self.now_s = self.now_s.max(t);
            }
        }
        // tracer: follow the request clock across idle gaps, then mark
        // the iteration start (migration/fetch stalls advance from here)
        let step_t0 = if let Some(tr) = self.core.tracer_mut() {
            let gap = self.now_s - tr.clock_s();
            if gap > 0.0 {
                tr.advance(gap);
            }
            Some(tr.clock_s())
        } else {
            None
        };
        let admitted = self.batcher.admit(self.now_s);
        let inflight = self.batcher.inflight_len();
        let mut tokens = self.batcher.tokens_per_device();
        let mut counts = self.sample_counts(&tokens);

        // chaos: the fault stream fires before loads are observed, so the
        // EWMA, the migration gate, and the pricing all see the perturbed
        // world. A node death drains its in-flight sequences onto the
        // survivors and evacuates its experts, charged like an accepted
        // migration (the death iteration prices the surviving work; the
        // re-homed sequences bill from their new devices next iteration).
        let mut migration_s = 0.0;
        if let Some(report) = self.core.chaos_step(&mut counts) {
            for ev in &report.events {
                self.log.push_perturbation(PerturbationRecord {
                    step: self.log.records.len(),
                    event: ev.clone(),
                });
            }
            if let Some(tr) = self.core.tracer_mut() {
                let t = tr.clock_s();
                for ev in &report.events {
                    tr.instant("step", ev, "chaos", t, &[]);
                }
                tr.registry_mut().inc("perturbations_total", report.events.len() as u64);
            }
            for &dev in &report.dead_devices {
                self.batcher.fail_device(dev);
            }
            if !report.dead_devices.is_empty() {
                tokens = self.batcher.tokens_per_device();
            }
            if let Some(m) = report.migration {
                migration_s += m.cost_s;
                let placement =
                    self.core.placement().expect("evacuation implies placement");
                let inputs = self
                    .policy
                    .runtime_inputs_placed(self.core.topology(), &self.cfg, placement);
                self.route = route_matrix(
                    &inputs,
                    self.policy.as_ref(),
                    self.core.topology(),
                    &self.cfg,
                    self.zipf_s,
                );
                self.cache.apply_migration(&m.moved, placement);
                self.log.push_migration(MigrationRecord {
                    step: self.log.records.len(),
                    moved: m.moved.len(),
                    bytes: m.bytes,
                    cost_s: m.cost_s,
                    predicted_saving_s: m.predicted_saving_s,
                    realized_saving_s: m.realized_saving_s,
                });
                if let Some(tr) = self.core.tracer_mut() {
                    trace_migration(tr, m.bytes, m.cost_s);
                }
            }
        }

        // placement: fold loads, maybe migrate — on acceptance re-derive
        // the routing for the new hosting and move cached weights with
        // their experts
        self.core.observe(&counts);
        if let Some(m) = self.core.maybe_migrate(&counts) {
            migration_s += m.cost_s;
            let placement = self.core.placement().expect("migration implies placement");
            let inputs =
                self.policy.runtime_inputs_placed(self.core.topology(), &self.cfg, placement);
            self.route =
                route_matrix(&inputs, self.policy.as_ref(), self.core.topology(), &self.cfg, self.zipf_s);
            self.cache.apply_migration(&m.moved, placement);
            self.log.push_migration(MigrationRecord {
                step: self.log.records.len(),
                moved: m.moved.len(),
                bytes: m.bytes,
                cost_s: m.cost_s,
                predicted_saving_s: m.predicted_saving_s,
                realized_saving_s: m.realized_saving_s,
            });
            if let Some(tr) = self.core.tracer_mut() {
                trace_migration(tr, m.bytes, m.cost_s);
            }
        }

        // expert-weight cache: misses stream weights home → host over the
        // real links, priced by the same contention engine as migrations
        let expert_bytes = self.core.shape().expert_param_bytes();
        let access = {
            let placement = self.core.placement().unwrap_or(&self.identity);
            self.cache.access(&counts, placement, expert_bytes)
        };
        let fetch_s = if access.fetch_bytes.sum() > 0.0 {
            CostEngine::contention(self.core.topology()).exchange_time(&access.fetch_bytes)
        } else {
            0.0
        };
        if let Some(tr) = self.core.tracer_mut() {
            tr.registry_mut().inc("cache_hits_total", access.hits as u64);
            tr.registry_mut().inc("cache_misses_total", access.misses as u64);
            if fetch_s > 0.0 {
                let t = tr.clock_s();
                tr.span(
                    "fetch",
                    "expert fetch",
                    "cache",
                    t,
                    fetch_s,
                    &[("misses", access.misses as f64)],
                );
                tr.registry_mut().gauge_add("fetch_s", fetch_s);
                tr.advance(fetch_s);
            }
        }

        // price the iteration under the decode profile, with the token
        // dimension set to the live batch's largest per-device bill
        let mut shape = *self.core.shape();
        shape.tokens_per_dev = tokens.iter().copied().max().unwrap_or(0).max(1);
        let hits_before = self.core.plan_cache().hits();
        let cost = self.core.price_with_shape(&shape, &counts);
        self.last_counts = Some(counts);

        self.now_s += cost.step_s() + fetch_s + migration_s;
        let finished = self.batcher.advance(self.now_s);
        for r in &finished {
            self.log.push_request(r.clone());
        }
        self.log.cache_hits += access.hits as u64;
        self.log.cache_misses += access.misses as u64;

        let record = StepRecord {
            step: self.log.records.len(),
            sim_comm_s: cost.step_s() - cost.compute_s,
            sim_compute_s: cost.compute_s,
            sim_a2a_local_s: cost.a2a.local_s,
            sim_a2a_intra_s: cost.a2a.intra_s,
            sim_a2a_inter_s: cost.a2a.inter_s,
            sim_serial_s: cost.serial_total(),
            sim_a2a_exposed_s: cost.exposed_a2a_s,
            chunks: cost.chunks,
            plan_cached: self.core.plan_cache().hits() > hits_before,
            sim_migration_s: migration_s,
            sim_fetch_s: fetch_s,
            inflight,
            admitted,
            finished: finished.len(),
            cache_hits: access.hits,
            cache_misses: access.misses,
            ..Default::default()
        };
        if let (Some(t0), Some(tr)) = (step_t0, self.core.tracer_mut()) {
            // migration/fetch stalls already advanced the clock past t0
            let dur = (tr.clock_s() - t0) + cost.step_s();
            tr.span(
                "step",
                &format!("step {}", record.step),
                "step",
                t0,
                dur,
                &[("inflight", inflight as f64)],
            );
            tr.advance(cost.step_s());
        }
        self.log.plan_hits = self.core.plan_cache().hits();
        self.log.plan_misses = self.core.plan_cache().misses();
        self.log.push(record.clone());
        Ok(record)
    }

    /// Each of device `i`'s tokens draws `k` experts from the routing
    /// row, in fixed (device, token, draw) order — `python/serve_mirror.py`
    /// replays the same stream.
    fn sample_counts(&mut self, tokens: &[usize]) -> Mat {
        let n = self.cfg.n_experts;
        let mut counts = Mat::zeros(self.cfg.p, n);
        for (dev, &t) in tokens.iter().enumerate() {
            if t == 0 {
                continue;
            }
            let row: Vec<f64> = (0..n).map(|e| self.route.get(dev, e)).collect();
            for _ in 0..t {
                for _ in 0..self.cfg.k {
                    let e = self.rng.weighted(&row);
                    counts.add_assign(dev, e, 1.0);
                }
            }
        }
        counts
    }

    /// Drive iterations until the trace is fully served (or `max_iters`
    /// as a runaway stop).
    pub fn run(&mut self, max_iters: usize) -> Result<()> {
        let mut iters = 0;
        while !self.batcher.done() {
            anyhow::ensure!(iters < max_iters, "serve run exceeded {max_iters} iterations");
            self.step()?;
            iters += 1;
        }
        Ok(())
    }

    pub fn log(&self) -> &RunLog {
        &self.log
    }

    /// Output tokens per busy-second from requests meeting the TTFT SLO.
    pub fn goodput(&self) -> f64 {
        self.log.goodput(self.slo_s)
    }

    pub fn slo_s(&self) -> f64 {
        self.slo_s
    }

    pub fn a2a_algo(&self) -> A2aAlgo {
        self.core.a2a_algo()
    }

    pub fn overlap_mode(&self) -> OverlapMode {
        self.core.overlap_mode()
    }

    pub fn topology(&self) -> &Topology {
        self.core.topology()
    }

    /// The simulated request clock (arrival time axis).
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    pub fn cache(&self) -> &ExpertCache {
        &self.cache
    }

    pub fn model_cfg(&self) -> &ModelCfg {
        &self.cfg
    }

    /// The live routing matrix (tests; the mirror checks its rows).
    pub fn route(&self) -> &Mat {
        &self.route
    }

    /// The attached event sink, if the session was built with
    /// [`ServeBuilder::trace_level`].
    pub fn tracer(&self) -> Option<&Tracer> {
        self.core.tracer()
    }

    /// The dispatch counts of the last priced iteration (`None` before
    /// the first step) — the representative step `--analyze` re-prices.
    pub fn last_counts(&self) -> Option<&Mat> {
        self.last_counts.as_ref()
    }

    pub fn done(&self) -> bool {
        self.batcher.done()
    }
}

impl Workload for ServeSession {
    fn step(&mut self) -> Result<StepRecord> {
        ServeSession::step(self)
    }

    fn log(&self) -> &RunLog {
        &self.log
    }

    fn core(&self) -> &WorkloadCore {
        &self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_builder() -> ServeBuilder {
        ServeBuilder::new()
            .preset("tiny4")
            .cluster("table1")
            .trace_kind(TraceKind::Poisson)
            .requests(24)
            .seed(5)
    }

    #[test]
    fn serves_a_whole_trace_deterministically() {
        let mut a = quick_builder().build().unwrap();
        let mut b = quick_builder().build().unwrap();
        a.run(100_000).unwrap();
        b.run(100_000).unwrap();
        assert!(a.done());
        assert_eq!(a.log().requests.len(), 24);
        assert_eq!(b.log().requests.len(), 24);
        for (x, y) in a.log().requests.iter().zip(&b.log().requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish_s, y.finish_s);
        }
        // every request finishes after it arrives, first token before last
        for r in &a.log().requests {
            assert!(r.first_token_s > r.arrival_s);
            assert!(r.finish_s >= r.first_token_s);
        }
    }

    #[test]
    fn routing_rows_are_normalised_draw_weights() {
        let sess = quick_builder().experts_per_dev(4).zipf_s(1.0).build().unwrap();
        let route = sess.route();
        assert_eq!((route.rows(), route.cols()), (4, 16));
        for i in 0..4 {
            let sum: f64 = (0..16).map(|e| route.get(i, e)).sum();
            assert!((sum - 1.0).abs() < 1e-9, "row {i} sums to {sum}");
            // Zipf tilt: within a device's canonical block, expert 0
            // outweighs expert 3
            assert!(route.get(i, 4 * i) > route.get(i, 4 * i + 3));
        }
    }

    #[test]
    fn constrained_cache_misses_cost_time() {
        let run = |cap| {
            let mut s = quick_builder()
                .experts_per_dev(4)
                .cache_cap(cap)
                .build()
                .unwrap();
            s.run(100_000).unwrap();
            let fetch: f64 = s.log().records.iter().map(|r| r.sim_fetch_s).sum();
            (s.log().cache_hit_rate(), fetch)
        };
        let (rate_tight, fetch_tight) = run(1);
        let (rate_loose, fetch_loose) = run(4);
        assert!(rate_tight < rate_loose);
        assert!(fetch_tight > fetch_loose);
        // cap = e_per_dev → compulsory misses only, all local copies
        let (rate_full, _) = run(4);
        assert!(rate_full > 0.9, "hit rate {rate_full}");
    }

    #[test]
    fn builder_rejects_nonsense() {
        assert!(ServeBuilder::new().preset("gpt5_huge").build().is_err());
        assert!(quick_builder().requests(0).build().is_err());
        assert!(quick_builder().slo_s(-1.0).build().is_err());
        assert!(quick_builder().policy_named("nope").build().is_err());
    }

    #[test]
    fn chaos_off_serve_is_bit_identical() {
        let mut a = quick_builder().build().unwrap();
        let mut b = quick_builder().chaos_named("off").build().unwrap();
        a.run(100_000).unwrap();
        b.run(100_000).unwrap();
        assert_eq!(a.log().requests.len(), b.log().requests.len());
        for (x, y) in a.log().requests.iter().zip(&b.log().requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish_s, y.finish_s);
        }
        assert!(b.log().perturbations.is_empty());
    }

    #[test]
    fn node_loss_serve_conserves_requests() {
        let mut s = quick_builder()
            .experts_per_dev(2)
            .placement_every(4)
            .chaos_named("nodeloss:1@3")
            .build()
            .unwrap();
        s.run(100_000).unwrap();
        // the corpse is dead, admission routed around it, and every
        // request still retires — conservation under elastic re-scale
        assert_eq!(s.log().requests.len(), 24);
        assert!(!s.topology().is_alive(1));
        assert_eq!(s.topology().n_alive(), 3);
        assert!(s.log().perturbations.iter().any(|p| p.event.contains("nodeloss:1")));
        let json = s.log().summary_json().to_string_compact();
        assert!(json.contains("perturbations"), "chaos keys missing in {json}");
    }

    #[test]
    fn serve_summary_surfaces_slo_metrics() {
        let mut s = quick_builder().experts_per_dev(2).cache_cap(1).build().unwrap();
        s.run(100_000).unwrap();
        let json = s.log().summary_json().to_string_compact();
        for key in ["ttft_p99_s", "tpot_p50_s", "cache_hit_rate", "requests"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(s.goodput() >= 0.0);
        assert!(s.log().ttft_percentile(99.0).unwrap() >= s.log().ttft_percentile(50.0).unwrap());
    }
}
