//! # TA-MoE: Topology-Aware Large Scale Mixture-of-Expert Training
//!
//! Rust + JAX + Pallas reproduction of *TA-MoE* (Chen et al., NeurIPS 2022).
//!
//! The crate is the **Layer-3 coordinator** of the three-layer architecture
//! (see `DESIGN.md`):
//!
//! * [`topology`] — network topology descriptions (homogeneous, ring,
//!   symmetric/asymmetric trees), per-pair α-β link matrices, the level
//!   decomposition `G_t^i` and the Eq. 5 hierarchical smoothing.
//! * [`dispatch`] — the paper's §4.2 optimisation: the closed-form target
//!   dispatch pattern `ĉ_ie` (Eq. 7), an iterative min-max refiner used to
//!   verify it, and the Eq. 8 penalty weights `p_i = Norm(1/ĉ_i)`.
//! * [`comm`] — the α-β communication cost engine: slowest-pair (the
//!   paper's lower bound, Eq. 2), per-sender-serial and link-contention
//!   exchange models, hierarchical all-to-all, ring allreduce, the
//!   Table-1 profiling harness, and the unified [`comm::A2aAlgo`]
//!   planner (direct / hierarchical / scheduled rounds, including the
//!   byte-matrix-aware BvN schedule synthesizer).
//! * [`runtime`] — execution backends behind the [`runtime::Backend`]
//!   trait: the pure-rust [`runtime::SimBackend`] (default) and PJRT
//!   execution of the AOT-compiled JAX/Pallas artifacts (HLO text +
//!   manifest ABI emitted by `python/compile/aot.py`, cargo feature
//!   `backend-xla`).
//! * [`coordinator`] — the training orchestrator: the open
//!   [`coordinator::DispatchPolicy`] trait with the four paper systems
//!   (even/DeepSpeed, FastMoE, FasterMoE-Hir, TA-MoE) and a registry for
//!   third-party policies, composed with a backend + topology + data into
//!   a [`coordinator::Session`], with simulated-time accounting and
//!   metrics.
//! * [`overlap`] — the chunked dispatch–compute–combine overlap engine:
//!   an event-driven multi-resource [`overlap::Timeline`], the chunk
//!   pipeline DAG with combine(c) ∥ dispatch(c+1) and bucketed-allreduce
//!   overlap, and the chunk-count autotuner behind
//!   [`overlap::OverlapMode`] / `--overlap`.
//! * [`placement`] — the topology- and load-aware expert placement
//!   engine: an expert→device [`placement::Placement`] map (identity by
//!   default), EWMA gate-load tracking, greedy + swap-descent solvers
//!   priced through the comm engine, and amortised live migration of
//!   expert weights wired into the [`coordinator::Session`] step loop.
//! * [`perturb`] — the scripted fault-injection engine: seeded
//!   step-granular [`perturb::Perturbation`] streams (stragglers,
//!   degraded links, node loss with elastic re-scale, gate-load regime
//!   shifts) replayed through the [`coordinator::Workload`] seam so
//!   training and serving face the same fault model, plus the
//!   recovery-time metric ([`perturb::recovery_steps`]).
//! * [`serve`] — the inference serving simulator: continuous batching
//!   over seeded arrival traces (Poisson / bursty MMPP / diurnal), an
//!   expert-weight device cache (LRU / gate-load-EWMA) whose misses are
//!   priced as real transfers, and SLO accounting (TTFT/TPOT percentiles,
//!   goodput under a deadline) — all sharing the training pricing stack
//!   through the [`coordinator::Workload`] seam.
//! * [`trace`] — the deterministic tracing & profiling layer: a
//!   [`trace::Tracer`] span/event sink on the simulated clock fed by the
//!   pricing path, a Chrome-trace-event exporter
//!   ([`trace::chrome_trace`], Perfetto-loadable), the post-run
//!   utilization report ([`trace::utilization`]) and the unified
//!   [`trace::MetricsRegistry`] of named counters/gauges — all behind
//!   `--trace`, zero-cost when off.
//! * [`analyze`] — the bottleneck attribution & what-if engine:
//!   critical-path blame over the overlap [`overlap::Timeline`] (per
//!   -resource seconds that sum to the step clock, unlike busy
//!   fractions) and the [`analyze::WhatIf`] counterfactual re-pricer
//!   (`link:<edge>x<f>`, `dev:<i>x<f>`, `alpha0`, `perfect-fabric`,
//!   `infinite-cache`) — all behind `--analyze`, zero-cost when off.
//! * [`data`] — byte-level tokenizer, bundled tiny corpus and a synthetic
//!   Zipf corpus generator, shard-aware batching.
//! * [`config`] — TOML experiment configs and the cluster A/B/C presets
//!   from the paper's Table 2.
//! * [`metrics`] — throughput/latency accumulators and CSV/JSON emitters
//!   used by the benches that regenerate every paper table and figure.
//!
//! With `--features backend-xla`, python never runs after `make
//! artifacts`: the binary loads HLO text via the `xla` crate's PJRT CPU
//! client and drives everything from rust. On the default feature set the
//! simulator stands in for the compiled model, so the whole crate —
//! training loops, benches, tier-1 tests — needs no XLA at all.

// The simulator prices clusters it never touches: everything is plain
// safe rust, and the crate keeps it that way mechanically.
#![forbid(unsafe_code)]

pub mod analyze;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dispatch;
pub mod metrics;
pub mod overlap;
pub mod perturb;
pub mod placement;
pub mod runtime;
pub mod serve;
pub mod topology;
pub mod trace;
pub mod util;

pub use analyze::{analyze_workload, BottleneckReport, WhatIf};
pub use config::ExperimentConfig;
pub use coordinator::{DispatchPolicy, Session, SessionBuilder, Workload};
pub use overlap::OverlapMode;
pub use perturb::{ChaosEngine, ChaosSpec};
pub use placement::{Placement, PlacementConfig, PlacementEngine};
pub use runtime::{Backend, SimBackend};
pub use serve::{CachePolicy, ServeBuilder, ServeSession, TraceConfig, TraceKind};
pub use topology::Topology;
pub use trace::{MetricsRegistry, TraceLevel, Tracer};
