//! Eq. 7: the closed-form target dispatch pattern.
//!
//! On the Eq. 5-smoothed topology the min-max problem of Eq. 6 admits the
//! closed form
//!
//! ```text
//! ĉ_ie = k·S / (E · Σ_j 1/β̂_ij) · 1/β̂_{i, ⌊e/E⌋}
//! ```
//!
//! — dispatch volume proportional to link bandwidth ("higher bandwidth
//! links should bear more loads"). The per-sender conservation constraint
//! (Eq. 3) holds by construction; the per-expert balance constraint (Eq. 4)
//! holds exactly on symmetric topologies and is restored by a Sinkhorn
//! repair pass otherwise (asymmetric trees are additionally *merged*,
//! §4.2: all levels ≥ 2 collapse into one inter-node class, the paper's
//! `[[2,2],[2]] → [[2,2,2]]` transformation, realised here on the smoothed
//! level parameters instead of by rebuilding the graph).

use super::refine::sinkhorn_repair;
use crate::placement::Placement;
use crate::topology::{smooth_levels, Topology, TopologyKind};
use crate::util::Mat;

/// Shape of one dispatch decision (per MoE layer, per step).
#[derive(Clone, Copy, Debug)]
pub struct DispatchProblem {
    /// Gate top-k.
    pub k: usize,
    /// Tokens per device per step (S in the paper).
    pub s: usize,
    /// Experts per device (E in the paper).
    pub e_per_dev: usize,
    /// Bytes per dispatched token (d · b in the paper: hidden × elem size).
    pub elem_bytes: usize,
}

impl DispatchProblem {
    /// Total tokens sent by one device (k·S).
    pub fn sent_per_dev(&self) -> f64 {
        (self.k * self.s) as f64
    }

    /// Balanced tokens received per expert (k·S/E, Eq. 4).
    pub fn recv_per_expert(&self) -> f64 {
        (self.k * self.s) as f64 / self.e_per_dev as f64
    }
}

/// The solved target pattern ĉ (tokens, P×N) plus the β̂ used to derive it.
#[derive(Clone, Debug)]
pub struct TargetPattern {
    /// ĉ_ie in tokens, P rows × N experts.
    pub c: Mat,
    /// The smoothed (and possibly merged) per-pair β̂ the solution used.
    pub beta_hat: Mat,
    pub problem: DispatchProblem,
}

impl TargetPattern {
    /// Panic unless Eq. 3 (row sums = k·S) and Eq. 4 (col sums = k·S/E)
    /// hold within `tol` (relative).
    pub fn assert_feasible(&self, tol: f64) {
        let p = self.c.rows();
        let n = self.c.cols();
        let want_row = self.problem.sent_per_dev();
        // Eq. 4: c has one column per expert (N = P·E), so the per-column
        // target is exactly the balanced receive per expert.
        let want_col = self.problem.recv_per_expert();
        for i in 0..p {
            let r = self.c.row_sum(i);
            assert!(
                (r - want_row).abs() <= tol * want_row,
                "row {i} sum {r} != {want_row}"
            );
        }
        for e in 0..n {
            let c = self.c.col_sum(e);
            assert!(
                (c - want_col).abs() <= tol * want_col,
                "col {e} sum {c} != {want_col}"
            );
        }
        assert!(self.c.min() >= 0.0, "negative dispatch volume");
    }

    /// Per-pair byte matrix (P×P): bytes device i sends to device j under
    /// the canonical expert hosting (`e → e / e_per_dev`).
    pub fn bytes_matrix(&self) -> Mat {
        let p = self.c.rows();
        self.bytes_matrix_placed(&Placement::identity(p, self.problem.e_per_dev))
    }

    /// [`bytes_matrix`] routed through an explicit expert placement:
    /// tokens for expert `e` land on `placement.device_of(e)`, wherever
    /// migration put it.
    ///
    /// [`bytes_matrix`]: TargetPattern::bytes_matrix
    pub fn bytes_matrix_placed(&self, placement: &Placement) -> Mat {
        placement.bytes_matrix(&self.c, self.problem.elem_bytes as f64)
    }
}

/// Smoothed per-pair β̂ with the asymmetric→symmetric merge applied.
pub(crate) fn beta_hat(topo: &Topology) -> Mat {
    let params = smooth_levels(topo);
    let symmetric = match topo.kind() {
        TopologyKind::Tree { symmetric, .. } => *symmetric,
        _ => true,
    };
    let (alpha, beta) = if symmetric {
        (params.alpha.clone(), params.beta.clone())
    } else {
        // Merge: collapse every level ≥ 2 into a single inter-node class
        // (count-weighted mean) — the matrix-level equivalent of merging
        // the spec into one symmetric layer of leaf groups.
        let mut a2 = 0.0;
        let mut b2 = 0.0;
        let mut cnt = 0usize;
        for l in 2..params.beta.len() {
            a2 += params.alpha[l] * params.count[l] as f64;
            b2 += params.beta[l] * params.count[l] as f64;
            cnt += params.count[l];
        }
        let mut alpha = params.alpha.clone();
        let mut beta = params.beta.clone();
        if cnt > 0 {
            for l in 2..beta.len() {
                alpha[l] = a2 / cnt as f64;
                beta[l] = b2 / cnt as f64;
            }
        }
        (alpha, beta)
    };
    let _ = alpha; // α is dropped by the closed form ("omit the small latency term")
    let p = topo.p();
    Mat::from_fn(p, p, |i, j| beta[topo.level(i, j)])
}

/// Solve Eq. 6 for the target pattern ĉ (Eq. 7) on a topology, under the
/// canonical expert hosting.
pub fn target_pattern(topo: &Topology, prob: &DispatchProblem) -> TargetPattern {
    target_pattern_placed(topo, prob, &Placement::identity(topo.p(), prob.e_per_dev))
}

/// [`target_pattern`] under an explicit expert placement: the closed form
/// reads `β̂_{i, host(e)}` with `host(e) = placement.device_of(e)`, so
/// after a migration the topology-aware loss steers dispatch toward the
/// experts' *actual* hosts.
pub fn target_pattern_placed(
    topo: &Topology,
    prob: &DispatchProblem,
    placement: &Placement,
) -> TargetPattern {
    let p = topo.p();
    let e = prob.e_per_dev;
    let n = p * e;
    assert_eq!(placement.p(), p, "placement/topology world mismatch");
    assert_eq!(placement.n_experts(), n, "placement expert count");
    let bh = beta_hat(topo);

    let ks = prob.sent_per_dev();
    let mut c = Mat::zeros(p, n);
    for i in 0..p {
        let denom: f64 = (0..p).map(|j| 1.0 / bh.get(i, j)).sum();
        for ei in 0..n {
            let host = placement.device_of(ei);
            c.set(i, ei, ks / (e as f64 * denom) * (1.0 / bh.get(i, host)));
        }
    }

    // Eq. 4 repair (exact on symmetric topologies, a no-op there).
    let row_t = vec![ks; p];
    let col_t = vec![ks * p as f64 / n as f64; n];
    let c = sinkhorn_repair(&c, &row_t, &col_t, 200, 1e-10);

    TargetPattern { c, beta_hat: bh, problem: *prob }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{presets, Link, Topology, TreeSpec};

    fn prob() -> DispatchProblem {
        DispatchProblem { k: 1, s: 1000, e_per_dev: 1, elem_bytes: 512 }
    }

    fn tree22() -> Topology {
        Topology::tree(
            &TreeSpec::parse("[2,2]").unwrap(),
            &[Link::from_gbps_us(45.0, 2.0), Link::from_gbps_us(12.5, 10.0)],
            presets::local_copy(),
        )
    }

    #[test]
    fn homogeneous_target_is_even() {
        let topo = Topology::homogeneous(
            4,
            Link::from_gbps_us(100.0, 1.0),
            Link::from_gbps_us(100.0, 0.0), // same local speed → fully even
        );
        let tp = target_pattern(&topo, &prob());
        for i in 0..4 {
            for e in 0..4 {
                assert!((tp.c.get(i, e) - 250.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn volumes_scale_with_bandwidth() {
        // Eq. 7: ĉ linear in 1/β̂ — local > intra-node > inter-node.
        let tp = target_pattern(&tree22(), &prob());
        let local = tp.c.get(0, 0);
        let intra = tp.c.get(0, 1);
        let inter = tp.c.get(0, 2);
        assert!(local > intra && intra > inter, "{local} {intra} {inter}");
        let b = &tp.beta_hat;
        // ratio check: ĉ_01/ĉ_02 == β̂_02/β̂_01
        let want = b.get(0, 2) / b.get(0, 1);
        let got = intra / inter;
        assert!((got - want).abs() / want < 1e-6);
    }

    #[test]
    fn constraints_hold_on_symmetric() {
        let tp = target_pattern(&tree22(), &prob());
        tp.assert_feasible(1e-9);
    }

    #[test]
    fn constraints_hold_after_merge_on_asymmetric() {
        let topo = Topology::tree(
            &TreeSpec::parse("[[2,2],[2]]").unwrap(),
            &[Link::from_gbps_us(45.0, 2.0), Link::from_gbps_us(12.5, 10.0)],
            presets::local_copy(),
        );
        let tp = target_pattern(&topo, &prob());
        tp.assert_feasible(1e-6);
        // merged: all inter-node pairs share one β̂ class → no expert
        // starves (the paper's "expert isolation" guard).
        let min_cross = (0..6)
            .flat_map(|i| (0..6).map(move |e| (i, e)))
            .filter(|&(i, e)| !topo.same_node(i, e))
            .map(|(i, e)| tp.c.get(i, e))
            .fold(f64::INFINITY, f64::min);
        assert!(min_cross > 0.0);
        let cross: Vec<f64> = (0..6)
            .flat_map(|i| (0..6).map(move |e| (i, e)))
            .filter(|&(i, e)| !topo.same_node(i, e))
            .map(|(i, e)| tp.c.get(i, e))
            .collect();
        let max_cross = cross.iter().cloned().fold(0.0, f64::max);
        assert!(max_cross / min_cross < 1.5, "isolation: {min_cross}..{max_cross}");
    }

    #[test]
    fn e_per_dev_splits_within_host() {
        let p = DispatchProblem { k: 1, s: 1000, e_per_dev: 2, elem_bytes: 512 };
        let tp = target_pattern(&tree22(), &p);
        assert_eq!(tp.c.cols(), 8);
        // experts co-hosted on one device receive identical volumes
        for i in 0..4 {
            for host in 0..4 {
                let a = tp.c.get(i, host * 2);
                let b = tp.c.get(i, host * 2 + 1);
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn bytes_matrix_aggregates_experts() {
        let p = DispatchProblem { k: 1, s: 1000, e_per_dev: 2, elem_bytes: 100 };
        let tp = target_pattern(&tree22(), &p);
        let bm = tp.bytes_matrix();
        assert_eq!(bm.rows(), 4);
        let want = (tp.c.get(0, 2) + tp.c.get(0, 3)) * 100.0;
        assert!((bm.get(0, 1) - want).abs() < 1e-9);
    }

    #[test]
    fn placed_target_follows_the_experts_host() {
        let topo = tree22();
        let p = prob();
        // swap experts 0 and 2 across the node boundary
        let mut pl = Placement::identity(4, 1);
        pl.swap_experts(0, 2);
        let tp = target_pattern_placed(&topo, &p, &pl);
        tp.assert_feasible(1e-9);
        // from device 0's view, expert 2 is now local (its host is device
        // 0) and expert 0 is across the uplink: Eq. 7 volumes follow the
        // host, not the expert id
        assert!(tp.c.get(0, 2) > tp.c.get(0, 1), "local beats intra");
        assert!(tp.c.get(0, 1) > tp.c.get(0, 0), "intra beats inter");
        // identity placement reproduces the canonical solution exactly
        let canon = target_pattern(&topo, &p);
        let ident = target_pattern_placed(&topo, &p, &Placement::identity(4, 1));
        assert_eq!(canon.c.linf_dist(&ident.c), 0.0);
    }

    #[test]
    fn placed_bytes_matrix_routes_through_the_permutation() {
        let p = DispatchProblem { k: 1, s: 1000, e_per_dev: 1, elem_bytes: 100 };
        let tp = target_pattern(&tree22(), &p);
        let mut pl = Placement::identity(4, 1);
        pl.swap_experts(1, 3);
        let bm = tp.bytes_matrix_placed(&pl);
        // expert 1's tokens now land on device 3, expert 3's on device 1
        assert!((bm.get(0, 3) - tp.c.get(0, 1) * 100.0).abs() < 1e-9);
        assert!((bm.get(0, 1) - tp.c.get(0, 3) * 100.0).abs() < 1e-9);
        // the identity route matches the canonical bytes matrix
        let ident = tp.bytes_matrix_placed(&Placement::identity(4, 1));
        assert_eq!(ident.linf_dist(&tp.bytes_matrix()), 0.0);
    }
}
