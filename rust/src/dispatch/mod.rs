//! The paper's §4.2 dispatch optimisation and §4.3 routing-strategy inputs.
//!
//! * [`target`] — the closed-form target pattern `ĉ_ie` of Eq. 7 on the
//!   Eq. 5-smoothed topology, with the asymmetric→symmetric merge.
//! * [`refine`] — Sinkhorn-style constraint repair (Eqs. 3–4) and a local
//!   perturbation verifier used by tests to confirm the closed form is a
//!   (local) minimiser of the Eq. 6 min-max objective.
//! * [`penalty`] — Eq. 8 penalty weights `p_i = Norm(1/ĉ_i)`, the topology
//!   loss coefficients `N·P·p_ie`, and the capacity matrices (even /
//!   proportional) the coordinator feeds the compiled model.

mod penalty;
mod refine;
mod target;

pub use penalty::{
    baseline_penalty_matrix, even_caps, penalty_weights, proportional_caps,
    topo_penalty_matrix, Norm,
};
pub use refine::{is_locally_optimal, sinkhorn_repair};
pub use target::{target_pattern, target_pattern_placed, DispatchProblem, TargetPattern};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CostEngine;
    use crate::topology::{presets, Link, Topology, TreeSpec};

    fn prob() -> DispatchProblem {
        DispatchProblem { k: 1, s: 1024, e_per_dev: 1, elem_bytes: 2048 }
    }

    #[test]
    fn closed_form_beats_even_on_table1() {
        // The headline §3.3 motivation: on [2,2] the topology-aware target
        // strictly reduces the slowest-pair exchange time vs even dispatch.
        let topo = presets::table1();
        let p = prob();
        let tp = target_pattern(&topo, &p);
        let mut engine = CostEngine::slowest_pair(&topo);
        let even = crate::util::Mat::filled(
            topo.p(),
            topo.p(),
            p.k as f64 * p.s as f64 / topo.p() as f64,
        );
        let t_even = engine.exchange_time(&even.scale(p.elem_bytes as f64));
        let t_ta = engine.exchange_time(&tp.c.scale(p.elem_bytes as f64));
        assert!(
            t_ta < t_even * 0.8,
            "target {t_ta} not clearly better than even {t_even}"
        );
    }

    #[test]
    fn target_is_locally_optimal_on_symmetric_tree() {
        let spec = TreeSpec::parse("[2,2]").unwrap();
        let topo = Topology::tree(
            &spec,
            &[Link::from_gbps_us(45.0, 2.0), Link::from_gbps_us(12.5, 10.0)],
            presets::local_copy(),
        );
        let p = prob();
        let tp = target_pattern(&topo, &p);
        assert!(is_locally_optimal(&topo, &tp.c, &p, 500, 0.02, 1e-9));
    }

    #[test]
    fn asymmetric_target_satisfies_constraints() {
        let topo = presets::cluster_c(3); // asymmetric, 24 devices
        let p = prob();
        let tp = target_pattern(&topo, &p);
        tp.assert_feasible(1e-6);
    }
}
