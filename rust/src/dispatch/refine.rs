//! Constraint repair + local-optimality verification for dispatch patterns.
//!
//! [`sinkhorn_repair`] alternately rescales rows and columns of a positive
//! pattern until the Eq. 3 / Eq. 4 marginals hold — the classic iterative
//! proportional fitting procedure, which converges for strictly positive
//! matrices and preserves the *ratios* the closed form encodes.
//!
//! [`is_locally_optimal`] is the verifier used by the test-suite (and the
//! ablation bench) to confirm Eq. 7 actually minimises the Eq. 6 min-max
//! objective: it samples random feasible 2×2 perturbations (move δ tokens
//! between two experts on one sender, compensate on another sender so both
//! marginals stay fixed) and checks none reduces the slowest-pair exchange
//! time.

use super::target::DispatchProblem;
use crate::comm::CostEngine;
use crate::topology::Topology;
use crate::util::{rng::Rng, Mat};

/// Iterative proportional fitting toward the given row/column sums.
///
/// Zero entries stay zero; the input must have at least one positive entry
/// in every row and column with a positive target.
pub fn sinkhorn_repair(
    c: &Mat,
    row_targets: &[f64],
    col_targets: &[f64],
    max_iters: usize,
    tol: f64,
) -> Mat {
    assert_eq!(c.rows(), row_targets.len());
    assert_eq!(c.cols(), col_targets.len());
    let mut m = c.clone();
    for _ in 0..max_iters {
        let mut worst: f64 = 0.0;
        for r in 0..m.rows() {
            let s = m.row_sum(r);
            if s > 0.0 && row_targets[r] > 0.0 {
                let f = row_targets[r] / s;
                worst = worst.max((f - 1.0).abs());
                for x in m.row_mut(r) {
                    *x *= f;
                }
            }
        }
        for cidx in 0..m.cols() {
            let s = m.col_sum(cidx);
            if s > 0.0 && col_targets[cidx] > 0.0 {
                let f = col_targets[cidx] / s;
                worst = worst.max((f - 1.0).abs());
                for r in 0..m.rows() {
                    m.set(r, cidx, m.get(r, cidx) * f);
                }
            }
        }
        if worst < tol {
            break;
        }
    }
    m
}

/// Randomised local-optimality check of a pattern w.r.t. the Eq. 6
/// objective under the slowest-pair model.
///
/// Samples `trials` feasible perturbations of relative size `rel_delta`;
/// returns false iff some perturbation improves the objective by more than
/// `tol` (absolute seconds).
pub fn is_locally_optimal(
    topo: &Topology,
    c: &Mat,
    prob: &DispatchProblem,
    trials: usize,
    rel_delta: f64,
    tol: f64,
) -> bool {
    let mut engine = CostEngine::slowest_pair(topo);
    let eb = prob.elem_bytes as f64;
    let e = prob.e_per_dev;
    // aggregate expert columns onto their host devices for pricing
    let to_bytes = |c: &Mat| {
        Mat::from_fn(c.rows(), c.rows(), |i, j| {
            (0..e).map(|le| c.get(i, j * e + le)).sum::<f64>() * eb
        })
    };
    let base = engine.exchange_time(&to_bytes(c));
    let p = c.rows();
    let n = c.cols();
    let mut rng = Rng::seed_from_u64(0xD15_BA7C4);
    let scale = c.sum() / (p * n) as f64 * rel_delta;

    for _ in 0..trials {
        // pick two senders and two experts; move δ along a 2×2 cycle so
        // both row and column sums are unchanged
        let i0 = rng.below(p);
        let i1 = rng.below(p);
        let e0 = rng.below(n);
        let e1 = rng.below(n);
        if i0 == i1 || e0 == e1 {
            continue;
        }
        let delta = scale.min(c.get(i0, e0)).min(c.get(i1, e1));
        if delta <= 0.0 {
            continue;
        }
        let mut m = c.clone();
        m.add_assign(i0, e0, -delta);
        m.add_assign(i0, e1, delta);
        m.add_assign(i1, e1, -delta);
        m.add_assign(i1, e0, delta);
        let t = engine.exchange_time(&to_bytes(&m));
        if t < base - tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sinkhorn_hits_marginals() {
        let c = Mat::from_vec(2, 2, vec![3.0, 1.0, 1.0, 3.0]);
        let out = sinkhorn_repair(&c, &[10.0, 10.0], &[10.0, 10.0], 100, 1e-12);
        for r in 0..2 {
            assert!((out.row_sum(r) - 10.0).abs() < 1e-9);
            assert!((out.col_sum(r) - 10.0).abs() < 1e-9);
        }
        // ratios preserved: diagonal still dominates
        assert!(out.get(0, 0) > out.get(0, 1));
    }

    #[test]
    fn sinkhorn_identity_when_already_feasible() {
        let c = Mat::filled(3, 3, 2.0);
        let out = sinkhorn_repair(&c, &[6.0; 3], &[6.0; 3], 50, 1e-12);
        assert!(out.linf_dist(&c) < 1e-12);
    }

    #[test]
    fn sinkhorn_preserves_zeros() {
        let c = Mat::from_vec(2, 2, vec![0.0, 4.0, 4.0, 4.0]);
        let out = sinkhorn_repair(&c, &[4.0, 8.0], &[4.0, 8.0], 200, 1e-12);
        assert_eq!(out.get(0, 0), 0.0);
        assert!((out.row_sum(0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn uneven_marginals_supported() {
        let c = Mat::filled(2, 3, 1.0);
        let out = sinkhorn_repair(&c, &[9.0, 3.0], &[4.0, 4.0, 4.0], 200, 1e-12);
        assert!((out.row_sum(0) - 9.0).abs() < 1e-8);
        assert!((out.col_sum(2) - 4.0).abs() < 1e-8);
    }
}
