//! Eq. 8: penalty weights and the runtime matrices fed to the model.
//!
//! The topology loss of §4.3 is
//! `l_topo^i = N·P·Σ_e p_ie · m_ie · c_ie/S` with `p_i = Norm(1/ĉ_i)`.
//! The compiled model (python/compile/model.py) evaluates the *unified*
//! loss `Σ_e penalty_ie · m_ie · c_ie/S`, so this module produces the
//! penalty matrix for each strategy:
//!
//! * baseline (Eq. 1 load-balance): `penalty_ie = N` — the GShard/Switch
//!   auxiliary loss;
//! * TA-MoE (Eq. 8): `penalty_ie = N·P·p_ie`.
//!
//! It also produces the capacity matrices `C_ie`: even `C/P` slices
//! (DeepSpeed-MoE) or proportional to `ĉ_ie` (TA-MoE on DeepSpeed-MoE,
//! §4.3 "one can modify the local capacity sizes to be consistent with the
//! proposed dispatch pattern").

use crate::util::Mat;

/// Normalisation for `p_i = Norm(1/ĉ_i)` (Eq. 8). The paper uses plain
/// normalisation and notes softmax-like variants "that enlarge the penalty
/// of the low-bandwidth transfer are also preferable".
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Norm {
    /// p_ie = (1/ĉ_ie) / Σ_e (1/ĉ_ie)
    L1,
    /// p_ie = softmax(temp · z_ie) with z the L1-normalised 1/ĉ row —
    /// sharper penalties on the slowest links.
    Softmax { temp: f64 },
}

/// Per-row penalty weights `p_i = Norm(1/ĉ_i)`, rows summing to 1.
pub fn penalty_weights(target: &Mat, norm: Norm) -> Mat {
    let (p, n) = (target.rows(), target.cols());
    let mut w = Mat::zeros(p, n);
    for i in 0..p {
        let inv: Vec<f64> = target.row(i).iter().map(|&c| 1.0 / c.max(1e-12)).collect();
        let s: f64 = inv.iter().sum();
        let z: Vec<f64> = inv.iter().map(|v| v / s).collect();
        match norm {
            Norm::L1 => {
                for (e, v) in z.iter().enumerate() {
                    w.set(i, e, *v);
                }
            }
            Norm::Softmax { temp } => {
                let mx = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let ex: Vec<f64> = z.iter().map(|v| ((v - mx) * temp * n as f64).exp()).collect();
                let es: f64 = ex.iter().sum();
                for (e, v) in ex.iter().enumerate() {
                    w.set(i, e, v / es);
                }
            }
        }
    }
    w
}

/// TA-MoE penalty matrix: `N·P·p_ie` (Eq. 8's magnitude-preserving scale).
pub fn topo_penalty_matrix(target: &Mat, norm: Norm) -> Mat {
    let (p, n) = (target.rows(), target.cols());
    penalty_weights(target, norm).scale(n as f64 * p as f64)
}

/// Baseline load-balance penalty (Eq. 1): a constant `N`.
pub fn baseline_penalty_matrix(p: usize, n: usize) -> Mat {
    Mat::filled(p, n, n as f64)
}

/// DeepSpeed-MoE even local capacities: `C_ie = C/P`.
pub fn even_caps(p: usize, n: usize, capacity: usize) -> Mat {
    Mat::filled(p, n, capacity as f64 / p as f64)
}

/// TA-MoE local capacities proportional to the target pattern, scaled so
/// every expert's total capacity is exactly `capacity` slots (floored to
/// integers, remainder given to the largest shares).
pub fn proportional_caps(target: &Mat, capacity: usize) -> Mat {
    let (p, n) = (target.rows(), target.cols());
    let mut caps = Mat::zeros(p, n);
    for e in 0..n {
        let col_sum = target.col_sum(e).max(1e-12);
        // largest-remainder rounding of capacity · ĉ_ie / Σ_i ĉ_ie
        let shares: Vec<f64> = (0..p)
            .map(|i| capacity as f64 * target.get(i, e) / col_sum)
            .collect();
        let mut floors: Vec<usize> = shares.iter().map(|&s| s.floor() as usize).collect();
        let mut used: usize = floors.iter().sum();
        let mut order: Vec<usize> = (0..p).collect();
        order.sort_by(|&a, &b| {
            (shares[b] - shares[b].floor())
                .partial_cmp(&(shares[a] - shares[a].floor()))
                .unwrap()
        });
        let mut oi = 0;
        while used < capacity {
            floors[order[oi % p]] += 1;
            used += 1;
            oi += 1;
        }
        for i in 0..p {
            caps.set(i, e, floors[i] as f64);
        }
    }
    caps
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_target() -> Mat {
        // device 0 should send a lot to expert 0 (fast) and little to 3
        Mat::from_vec(
            2,
            4,
            vec![
                8.0, 4.0, 2.0, 2.0, //
                2.0, 2.0, 4.0, 8.0,
            ],
        )
    }

    #[test]
    fn weights_are_normalised_and_inverse_ordered() {
        let w = penalty_weights(&skewed_target(), Norm::L1);
        for i in 0..2 {
            let s: f64 = w.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
        // larger target ⇒ smaller penalty
        assert!(w.get(0, 0) < w.get(0, 3));
        assert!(w.get(1, 3) < w.get(1, 0));
    }

    #[test]
    fn softmax_sharpens_the_penalty() {
        let t = skewed_target();
        let l1 = penalty_weights(&t, Norm::L1);
        let sm = penalty_weights(&t, Norm::Softmax { temp: 4.0 });
        // softmax puts relatively more mass on the most-penalised expert
        let ratio_l1 = l1.get(0, 3) / l1.get(0, 0);
        let ratio_sm = sm.get(0, 3) / sm.get(0, 0);
        assert!(ratio_sm > ratio_l1);
        let s: f64 = sm.row(0).iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn topo_matrix_scale_matches_paper() {
        // uniform target ⇒ p_ie = 1/N ⇒ penalty = N·P/N = P everywhere
        let t = Mat::filled(4, 8, 5.0);
        let m = topo_penalty_matrix(&t, Norm::L1);
        for v in m.data() {
            assert!((v - 4.0).abs() < 1e-9);
        }
    }

    #[test]
    fn baseline_matrix_is_constant_n() {
        let m = baseline_penalty_matrix(4, 8);
        assert_eq!(m.get(3, 7), 8.0);
    }

    #[test]
    fn even_caps_sum_to_capacity() {
        let caps = even_caps(4, 8, 64);
        for e in 0..8 {
            assert!((caps.col_sum(e) - 64.0).abs() < 1e-9);
        }
    }

    #[test]
    fn proportional_caps_integral_and_exact() {
        let t = skewed_target();
        let caps = proportional_caps(&t, 33);
        for e in 0..4 {
            assert_eq!(caps.col_sum(e) as usize, 33);
        }
        for v in caps.data() {
            assert_eq!(v.fract(), 0.0);
            assert!(*v >= 0.0);
        }
        // proportionality: device 0 gets most of expert 0
        assert!(caps.get(0, 0) > caps.get(1, 0));
        assert!(caps.get(1, 3) > caps.get(0, 3));
    }

    #[test]
    fn proportional_caps_handle_zero_columns() {
        let t = Mat::from_vec(2, 2, vec![1.0, 0.0, 1.0, 0.0]);
        let caps = proportional_caps(&t, 10);
        assert_eq!(caps.col_sum(1) as usize, 10); // still allocates capacity
    }
}
