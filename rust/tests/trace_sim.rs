//! Acceptance tests for the deterministic tracing & profiling layer:
//! byte-identical pricing with and without a tracer attached, byte
//! -identical Chrome exports across reruns of the same seed, the serial
//! phase-span skeleton on the Table-1 tree, per-track span/busy
//! reconciliation on overlapped steps (the validator's invariant,
//! asserted in-repo too), and the serve-side trace seam.

use ta_moe::coordinator::{DispatchPolicy, PolicyInputs, Session, SessionBuilder};
use ta_moe::dispatch::even_caps;
use ta_moe::overlap::OverlapMode;
use ta_moe::runtime::{GateInputs, ModelCfg, SimBackend};
use ta_moe::serve::{CachePolicy, ServeBuilder, ServeSession, TraceConfig, TraceKind};
use ta_moe::topology::{presets, Link, Topology, TreeSpec};
use ta_moe::trace::{chrome_trace, utilization, utilization_csv, TraceEvent, TraceLevel, TracePh};

/// The tiny4 [2,2]-tree scenario from ISSUE-9's acceptance bar.
fn table1_session(trace: Option<TraceLevel>, overlap: &str, seed: i32) -> Session {
    let cfg = ModelCfg::preset("tiny4").expect("builtin preset");
    let mut b = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(presets::table1())
        .a2a_named("sched:rot")
        .overlap_named(overlap)
        .seed(seed);
    if let Some(level) = trace {
        b = b.trace_level(level);
    }
    b.build().unwrap()
}

/// A 2×2 tree with a bottlenecked uplink so `--overlap auto` really
/// chunks (same shape as the overlap acceptance tests).
fn bottleneck22() -> Topology {
    Topology::tree(
        &TreeSpec::parse("[2,2]").unwrap(),
        &[Link::from_gbps_us(45.0, 1.0), Link::from_gbps_us(0.01, 1.0)],
        presets::local_copy(),
    )
}

/// Spans (track, start, end) grouped per track, in emission order.
fn spans_by_track(events: &[TraceEvent]) -> Vec<(String, Vec<(f64, f64)>)> {
    let mut out: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for e in events {
        if e.ph != TracePh::Span {
            continue;
        }
        let span = (e.start_s, e.start_s + e.dur_s);
        match out.iter_mut().find(|(t, _)| *t == e.track) {
            Some((_, v)) => v.push(span),
            None => out.push((e.track.clone(), vec![span])),
        }
    }
    out
}

#[test]
fn tracing_never_perturbs_the_priced_run() {
    // the zero-cost contract: a session with a tracer attached prices
    // byte-identically to one that never heard of the trace module —
    // same losses, same clock, same summary JSON, same CSV bytes
    let run = |trace: Option<TraceLevel>| {
        let mut s = table1_session(trace, "auto", 7);
        s.run(25).unwrap();
        s
    };
    let off = run(None);
    let on = run(Some(TraceLevel::Chunk));
    assert!(off.tracer().is_none(), "no --trace, no tracer");
    assert!(on.tracer().is_some());

    for (a, b) in off.log().records.iter().zip(&on.log().records) {
        assert_eq!(a.loss, b.loss, "step {}", a.step);
        assert_eq!(a.sim_total_s(), b.sim_total_s(), "step {}", a.step);
        assert_eq!(a.chunks, b.chunks, "step {}", a.step);
    }
    assert_eq!(
        off.log().summary_json().to_string_compact(),
        on.log().summary_json().to_string_compact()
    );
    let csv = |s: &Session, tag: &str| {
        let path = std::env::temp_dir().join(format!("ta_moe_trace_identity_{tag}.csv"));
        s.log().write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        text
    };
    assert_eq!(csv(&off, "off"), csv(&on, "on"));
}

#[test]
fn identical_seeds_export_byte_identical_chrome_traces() {
    let export = |seed: i32| {
        let mut s = table1_session(Some(TraceLevel::Chunk), "auto", seed);
        s.run(12).unwrap();
        chrome_trace(s.tracer().unwrap()).to_string_compact()
    };
    let a = export(3);
    assert_eq!(a, export(3), "same config+seed must re-export byte-identically");
    assert_ne!(a, export(4), "the trace must reflect the run, not a constant");
}

#[test]
fn serial_phase_spans_tile_each_step_exactly() {
    // the golden skeleton: on a serial clock the phase spans (compute,
    // a2a:local/intra/inter, allreduce) laid back to back ARE the step's
    // attribution — per step they sum to the step span's duration, and
    // the last one ends where the next step begins
    let mut s = table1_session(Some(TraceLevel::Phase), "serial", 11);
    s.run(8).unwrap();
    let tr = s.tracer().unwrap();
    let events = tr.events();

    let steps: Vec<&TraceEvent> =
        events.iter().filter(|e| e.track == "step" && e.ph == TracePh::Span).collect();
    assert_eq!(steps.len(), 8);
    for (k, e) in steps.iter().enumerate() {
        assert_eq!(e.name, format!("step {k}"));
        assert_eq!(e.cat, "step");
        let rec = &s.log().records[k];
        assert_eq!(e.args, vec![("loss".to_string(), rec.loss)]);
        assert!((e.dur_s - rec.sim_total_s()).abs() <= 1e-12 * rec.sim_total_s());
        // phase spans inside [start, start+dur] tile it exactly
        let inside: Vec<&TraceEvent> = events
            .iter()
            .filter(|p| {
                p.track == "serial"
                    && p.start_s >= e.start_s - 1e-12
                    && p.start_s + p.dur_s <= e.start_s + e.dur_s + 1e-12
            })
            .collect();
        assert_eq!(inside.len(), 5, "compute + 3 a2a phases + allreduce");
        assert_eq!(inside[0].name, "compute");
        assert_eq!(inside[4].name, "allreduce");
        let tiled: f64 = inside.iter().map(|p| p.dur_s).sum();
        assert!((tiled - e.dur_s).abs() <= 1e-9, "step {k}: {tiled} vs {}", e.dur_s);
        let mut cur = e.start_s;
        for p in &inside {
            assert!((p.start_s - cur).abs() <= 1e-9, "phase {} must abut", p.name);
            cur += p.dur_s;
        }
    }
    // the tracer's clock ends on the simulated time axis
    let end = s.log().sim_time_axis().last().copied().unwrap();
    assert!((tr.clock_s() - end).abs() <= 1e-9 * end.max(1.0));

    // every scheduled step either hit or missed the plan cache, and the
    // Phase level records the instants for it
    let reg = tr.registry();
    assert_eq!(reg.counter("plan_hits_total") + reg.counter("plan_misses_total"), 8);
    assert!(events.iter().any(|e| e.name == "plan:miss" && e.ph == TracePh::Mark));

    // Phase level stops short of link rounds; Chunk adds them
    assert!(events.iter().all(|e| !e.track.starts_with("link:")));
    let mut c = table1_session(Some(TraceLevel::Chunk), "serial", 11);
    c.run(2).unwrap();
    let link_spans = c
        .tracer()
        .unwrap()
        .events()
        .iter()
        .filter(|e| e.track.starts_with("link:") && e.cat == "a2a")
        .count();
    assert!(link_spans > 0, "sched:rot serial steps must attribute per-link rounds");
}

#[test]
fn span_sums_reconcile_with_timeline_busy_and_never_overlap() {
    // overlapped steps: the retained pipeline spans per dev:/chan: track
    // must sum to the independently accumulated `Timeline::busy()` totals
    // (within 1e-9 — the trace_validator.py invariant), and no track may
    // ever have two spans occupying the same simulated instant
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut s = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(bottleneck22())
        .policy_named("fastmoe")
        .overlap_named("auto")
        .seed(33)
        .trace_level(TraceLevel::Chunk)
        .build()
        .unwrap();
    s.run(20).unwrap();
    assert!(s.log().records.iter().any(|r| r.chunks > 1), "auto must chunk here");

    let tr = s.tracer().unwrap();
    let per_track = spans_by_track(tr.events());
    assert!(!tr.timeline_busy().is_empty());
    for (track, busy) in tr.timeline_busy() {
        let spans = per_track.iter().find(|(t, _)| t == track);
        let sum: f64 = match spans {
            Some((_, v)) => v.iter().map(|(a, b)| b - a).sum(),
            None => 0.0,
        };
        assert!(
            (sum - busy).abs() <= 1e-9,
            "{track}: span sum {sum} vs timeline busy {busy}"
        );
    }
    // devices both compute, so the report sees them; utilization folds
    // the same spans the reconciliation just checked
    let rep = utilization(tr.events(), tr.clock_s(), 4, &[]);
    assert!(rep.rows.iter().any(|r| r.track.starts_with("dev:")));
    assert!(rep.rows.iter().all(|r| r.busy_frac >= 0.0 && r.busy_frac <= 1.0 + 1e-12));
    assert!(rep.straggler_skew >= 1.0);
    assert_eq!(rep.hottest.len(), 4.min(rep.rows.len()));
    let csv = utilization_csv(&rep);
    assert_eq!(csv.lines().count(), rep.rows.len() + 1);

    for (track, mut spans) in per_track {
        spans.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        for w in spans.windows(2) {
            assert!(
                w[1].0 >= w[0].1 - 1e-9,
                "{track}: span [{}, {}] overlaps [{}, {}]",
                w[1].0,
                w[1].1,
                w[0].0,
                w[0].1
            );
        }
    }
}

#[test]
fn serve_traces_cache_and_steps_on_the_arrival_clock() {
    let build = |trace: bool| {
        let mut b = ServeBuilder::new()
            .preset("tiny4")
            .experts_per_dev(4)
            .cluster("table1")
            .policy_named("ta-moe")
            .trace(TraceConfig {
                kind: TraceKind::Bursty,
                rate_rps: 50.0,
                n_requests: 32,
                seed: 9,
                prompt_mean: 32,
                output_mean: 16,
            })
            .cache_cap(2)
            .cache_policy(CachePolicy::Lru)
            .slo_s(0.2)
            .overlap(OverlapMode::Serial);
        if trace {
            b = b.trace_level(TraceLevel::Chunk);
        }
        let mut s: ServeSession = b.build().unwrap();
        s.run(100_000).unwrap();
        s
    };
    let s = build(true);
    let tr = s.tracer().unwrap();

    // the registry's cache tallies are the log's, counted independently
    let reg = tr.registry();
    assert!(reg.counter("cache_misses_total") > 0);
    assert_eq!(reg.counter("cache_hits_total"), s.log().cache_hits);
    assert_eq!(reg.counter("cache_misses_total"), s.log().cache_misses);
    // misses cost time on a dedicated fetch track
    let fetch: f64 = tr
        .events()
        .iter()
        .filter(|e| e.track == "fetch" && e.ph == TracePh::Span)
        .map(|e| e.dur_s)
        .sum();
    let logged: f64 = s.log().records.iter().map(|r| r.sim_fetch_s).sum();
    assert!(fetch > 0.0);
    assert!((fetch - logged).abs() <= 1e-9);

    // one step span per priced step, riding the arrival clock: spans on
    // the step track are ordered and gap only while the queue was idle
    let steps: Vec<(f64, f64)> = spans_by_track(tr.events())
        .into_iter()
        .find(|(t, _)| t == "step")
        .map(|(_, v)| v)
        .unwrap();
    assert_eq!(steps.len(), s.log().records.len());
    for w in steps.windows(2) {
        assert!(w[1].0 >= w[0].1 - 1e-9, "serve step spans must not overlap");
    }
    assert!(tr.clock_s() <= s.now_s() + 1e-9);

    // the export round-trips through the JSON parser
    let j = chrome_trace(tr);
    let text = j.to_string_compact();
    let back = ta_moe::util::json::Json::parse(&text).unwrap();
    assert_eq!(back, j);

    // tracing must not perturb serving either
    let off = build(false);
    assert!(off.tracer().is_none());
    assert_eq!(
        off.log().summary_json().to_string_compact(),
        s.log().summary_json().to_string_compact()
    );
}

#[test]
fn nodeloss_corpses_do_not_inflate_traced_straggler_skew() {
    // regression: a device dead from step 10 of 40 contributes ~1/4 of a
    // living device's busy seconds, deflating the dev mean and inflating
    // max/mean — the report must read the *living* fleet's skew
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut s = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(presets::table1())
        .policy_named("fastmoe")
        .seed(17)
        .chaos_named("nodeloss:3@10")
        .trace_level(TraceLevel::Chunk)
        .build()
        .unwrap();
    s.run(40).unwrap();
    assert_eq!(s.log().dead_devices(), vec![3]);
    let tr = s.tracer().unwrap();
    let naive = utilization(tr.events(), tr.clock_s(), 4, &[]);
    let fixed = utilization(tr.events(), tr.clock_s(), 4, &s.log().dead_devices());
    assert!(
        naive.straggler_skew > fixed.straggler_skew,
        "corpse must have inflated the naive skew ({} vs {})",
        naive.straggler_skew,
        fixed.straggler_skew
    );
    // the dead device still gets its report row — only the skew mean
    // excludes it — and the living fleet reads near-even
    assert!(fixed.rows.iter().any(|r| r.track == "dev:3"));
    assert!(fixed.straggler_skew < 1.2, "living skew {}", fixed.straggler_skew);
}

/// The session_sim skew scenario, restated: node-0 devices crowd the
/// experts canonically hosted on node 1 hard enough that the placement
/// engine is guaranteed to migrate on the [2,2] tree.
#[derive(Debug)]
struct CrossNodeSkew;

impl DispatchPolicy for CrossNodeSkew {
    fn name(&self) -> String {
        "cross-node-skew".into()
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        let penalty = ta_moe::util::Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
            if topo.node_of(i) == 0 && topo.node_of(e / cfg.e_per_dev) == 0 {
                9.0
            } else {
                1.0
            }
        });
        PolicyInputs {
            gate: GateInputs {
                penalty,
                caps: even_caps(cfg.p, cfg.n_experts, cfg.capacity),
                local_mask: topo.local_mask(cfg.n_experts, cfg.e_per_dev),
                hir_remote_frac: 1.0,
            },
            target: None,
        }
    }

    fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> ta_moe::util::Mat {
        let inputs = self.runtime_inputs(topo, cfg);
        let sent = (cfg.k * cfg.tokens_per_dev) as f64;
        ta_moe::util::Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
            let w = 1.0 / inputs.gate.penalty.get(i, e);
            let row: f64 =
                (0..cfg.n_experts).map(|x| 1.0 / inputs.gate.penalty.get(i, x)).sum();
            sent * w / row
        })
    }
}

#[test]
fn migrations_land_on_their_own_track_with_registry_totals() {
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut s = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(presets::table1())
        .policy(Box::new(CrossNodeSkew))
        .seed(21)
        .placement_every(8)
        .trace_level(TraceLevel::Step)
        .build()
        .unwrap();
    s.run(80).unwrap();
    let tr = s.tracer().unwrap();

    let migrations = &s.log().migrations;
    assert!(!migrations.is_empty(), "the placement engine must act on cross-node skew");
    let spans: Vec<&TraceEvent> = tr
        .events()
        .iter()
        .filter(|e| e.track == "migrate" && e.ph == TracePh::Span)
        .collect();
    assert_eq!(spans.len(), migrations.len());
    for (sp, m) in spans.iter().zip(migrations) {
        assert_eq!(sp.cat, "placement");
        assert_eq!(sp.dur_s, m.cost_s);
        assert_eq!(sp.args, vec![("bytes".to_string(), m.bytes)]);
    }
    let reg = tr.registry();
    assert_eq!(reg.counter("migrations_total"), migrations.len() as u64);
    let bytes: f64 = migrations.iter().map(|m| m.bytes).sum();
    assert!((reg.gauge("migration_bytes") - bytes).abs() <= 1e-9 * bytes.max(1.0));
    // Step level keeps the lifecycle without the per-phase detail
    assert!(tr.events().iter().all(|e| e.track != "serial" && !e.track.starts_with("dev:")));
}
