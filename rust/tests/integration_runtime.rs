//! Integration: the full python-AOT → rust-PJRT path on the tiny artifact,
//! driven through the `Session`/`XlaBackend` API.
//!
//! Only built under `--features backend-xla`; these tests additionally
//! need `make artifacts` to have run, and are skipped (not failed) when
//! artifacts are missing so `cargo test` works on a fresh clone. The
//! backend-agnostic session behaviour is covered on the simulator in
//! `session_sim.rs`.
#![cfg(feature = "backend-xla")]

use std::path::{Path, PathBuf};
use ta_moe::config::topology_for;
use ta_moe::coordinator::{DispatchPolicy, FastMoeEven, Session, SessionBuilder, TaMoe};
use ta_moe::data::builtin_text;
use ta_moe::dispatch::Norm;
use ta_moe::runtime::{HostTensor, Runtime, XlaBackend};

fn tiny_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny4");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match tiny_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn session_on(
    dir: &Path,
    cluster: &str,
    policy: Box<dyn DispatchPolicy>,
    lr: f32,
    seed: i32,
) -> Session {
    SessionBuilder::new()
        .backend(Box::new(XlaBackend::load(dir).unwrap()))
        .topology(topology_for(cluster, 4))
        .policy(policy)
        .lr(lr)
        .seed(seed)
        .data_text(builtin_text())
        .build()
        .unwrap()
}

#[test]
fn init_is_deterministic_in_seed() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let seed = HostTensor::scalar_i32(7).to_literal().unwrap();
    let a = art.init.run(&[&seed]).unwrap();
    let b = art.init.run(&[&seed]).unwrap();
    let seed2 = HostTensor::scalar_i32(8).to_literal().unwrap();
    let c = art.init.run(&[&seed2]).unwrap();
    let va = a[0].to_vec::<f32>().unwrap();
    let vb = b[0].to_vec::<f32>().unwrap();
    let vc = c[0].to_vec::<f32>().unwrap();
    assert_eq!(va, vb);
    assert_ne!(va, vc);
    // embed shape matches the manifest
    assert_eq!(va.len(), art.manifest.params[0].numel());
}

#[test]
fn step_rejects_wrong_arity() {
    let dir = require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let art = rt.load_artifact(&dir).unwrap();
    let seed = HostTensor::scalar_i32(0).to_literal().unwrap();
    let err = art.step.run(&[&seed]).err().expect("arity error");
    assert!(err.to_string().contains("expects"), "{err}");
}

#[test]
fn training_decreases_loss_and_conserves_tokens() {
    let dir = require_artifacts!();
    let mut session = session_on(&dir, "C", Box::new(TaMoe { norm: Norm::L1 }), 2e-3, 0);
    let cfg = session.model_cfg().clone();
    let mut losses = Vec::new();
    for _ in 0..12 {
        let rec = session.step().unwrap();
        losses.push(rec.loss);
        // conservation: every (device, k-slot) pair chose an expert
        let counts = session.last_counts().unwrap();
        for i in 0..cfg.p {
            let sum = counts.row_sum(i);
            let want = (cfg.k * cfg.tokens_per_dev) as f64;
            assert!((sum - want).abs() < 1e-3, "row {i}: {sum} != {want}");
        }
        assert!(rec.sim_comm_s > 0.0, "a2a must cost something");
        assert!(rec.loss.is_finite());
    }
    assert!(
        losses.last().unwrap() < &losses[0],
        "loss should decrease: {losses:?}"
    );
}

#[test]
fn eval_is_pure_and_deterministic() {
    let dir = require_artifacts!();
    let mut session = session_on(&dir, "B", Box::new(FastMoeEven), 1e-3, 0);
    let (l1, c1) = session.eval_held_out().unwrap();
    let (l2, c2) = session.eval_held_out().unwrap();
    assert_eq!(l1, l2);
    assert!(c1.linf_dist(&c2) == 0.0);
    // eval must not change the parameters: a train-free re-eval matches
    let (l3, _) = session.eval_held_out().unwrap();
    assert_eq!(l1, l3);
}

#[test]
fn identical_seeds_give_identical_runs() {
    let dir = require_artifacts!();
    let run = || {
        let mut s = session_on(&dir, "C", Box::new(TaMoe { norm: Norm::L1 }), 1e-3, 3);
        let mut out = Vec::new();
        for _ in 0..5 {
            out.push(s.step().unwrap().loss);
        }
        out
    };
    assert_eq!(run(), run());
}

#[test]
fn strategies_share_the_same_artifact() {
    // The same compiled program must serve every policy (the runtime
    // inputs are the only difference) — core to the §4.3 design.
    let dir = require_artifacts!();
    let policies: Vec<Box<dyn DispatchPolicy>> = vec![
        Box::new(FastMoeEven),
        Box::new(TaMoe { norm: Norm::L1 }),
        Box::new(TaMoe { norm: Norm::Softmax { temp: 2.0 } }),
    ];
    for policy in policies {
        let name = policy.name();
        let mut s = session_on(&dir, "C", policy, 1e-3, 0);
        let rec = s.step().unwrap();
        assert!(rec.loss.is_finite(), "{name}");
    }
}

#[test]
fn tamoe_and_baseline_differ_only_via_inputs() {
    // Same seed + data, different penalty/caps ⇒ different aux, same
    // *initial* CE (the first forward pass sees identical params/data and
    // the CE path does not read the penalty).
    let dir = require_artifacts!();
    let mut first_ce = Vec::new();
    let policies: Vec<Box<dyn DispatchPolicy>> =
        vec![Box::new(FastMoeEven), Box::new(TaMoe { norm: Norm::L1 })];
    for policy in policies {
        let mut s = session_on(&dir, "C", policy, 1e-3, 11);
        let rec = s.step().unwrap();
        first_ce.push((rec.ce, rec.aux));
    }
    assert!((first_ce[0].0 - first_ce[1].0).abs() < 1e-5, "{first_ce:?}");
    assert!((first_ce[0].1 - first_ce[1].1).abs() > 1e-6, "aux should differ");
}
