//! Property tests pinning the overlap timeline to its analytic bounds
//! (ISSUE 5 satellite): for every topology × a2a algo × chunk count, the
//! busiest single resource lower-bounds the makespan and the serial
//! execution of the same chunked events upper-bounds it; `k = 1` *is* the
//! serial step price to 1e-12; the autotuned clock never exceeds the
//! serial clock; and on contention-free zero-latency fabrics the makespan
//! is monotone non-increasing in `k`.

use ta_moe::comm::{ring_allreduce_time, A2aAlgo};
use ta_moe::coordinator::{step_cost, step_cost_overlapped, ModelShape};
use ta_moe::overlap::{pipeline_cost, OverlapInputs, OverlapMode, CHUNK_SWEEP};
use ta_moe::topology::{presets, Link, Topology, TreeSpec};
use ta_moe::util::prop::check;
use ta_moe::util::rng::Rng;
use ta_moe::util::Mat;

fn random_tree(rng: &mut Rng) -> Topology {
    let spec = TreeSpec::symmetric(&[rng.range(2, 5), rng.range(2, 5)]);
    let dev = Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0));
    let up = Link::from_gbps_us(rng.range_f64(4.0, 25.0), rng.range_f64(5.0, 30.0));
    Topology::tree(&spec, &[dev, up], presets::local_copy())
}

fn shape() -> ModelShape {
    ModelShape {
        layers: 4,
        d: 64,
        f: 128,
        vocab: 1000,
        seq: 64,
        tokens_per_dev: 64,
        k: 1,
        n_moe_layers: 2,
        elem_bytes: 4,
    }
}

fn algos_for(p: usize) -> Vec<A2aAlgo> {
    A2aAlgo::ALL
        .into_iter()
        .filter(|a| a.validate_for(p).is_ok())
        .collect()
}

/// The same `OverlapInputs` that `step_cost_overlapped` derives
/// (via `ModelShape::overlap_inputs`, the shared derivation), so the
/// pipeline-level envelope can be checked with full visibility.
fn inputs_for(sh: &ModelShape, topo: &Topology, counts: &Mat, flops: f64) -> OverlapInputs {
    let recv: Vec<f64> = (0..topo.p()).map(|j| counts.col_sum(j)).collect();
    sh.overlap_inputs(flops, &recv)
}

const FLOPS: f64 = 45e12;

#[test]
fn prop_timeline_stays_inside_its_analytic_envelope() {
    // max(phase) ≤ overlapped ≤ serial sum, for every (topology × algo × k):
    // the phases are the per-resource busy totals of the chunked events,
    // and their back-to-back execution is the serial sum
    check(
        10,
        0x0E41A,
        |rng| {
            let topo = random_tree(rng);
            let p = topo.p();
            let counts = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 256.0));
            (topo, counts)
        },
        |(topo, counts)| {
            let sh = shape();
            let inp = inputs_for(&sh, topo, counts, FLOPS);
            let bytes = counts.scale(sh.token_bytes());
            for algo in algos_for(topo.p()) {
                for k in CHUNK_SWEEP {
                    let chunk = algo.plan(topo, &bytes.scale(1.0 / k as f64)).breakdown;
                    let ar = ring_allreduce_time(topo, sh.dense_param_bytes() / k as f64);
                    let c = pipeline_cost(&inp, &chunk, ar, k);
                    if c.bound_s > c.makespan_s * (1.0 + 1e-9) {
                        return Err(format!(
                            "{algo} k={k}: busiest resource {} above makespan {}",
                            c.bound_s, c.makespan_s
                        ));
                    }
                    if c.makespan_s > c.serial_sum_s * (1.0 + 1e-9) {
                        return Err(format!(
                            "{algo} k={k}: makespan {} above serial sum {}",
                            c.makespan_s, c.serial_sum_s
                        ));
                    }
                    if c.exposed_comm_s() > c.makespan_s * (1.0 + 1e-9) {
                        return Err(format!("{algo} k={k}: exposure above makespan"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_k1_equals_the_serial_step_price() {
    check(
        10,
        0x0E41B,
        |rng| {
            let topo = random_tree(rng);
            let p = topo.p();
            let counts = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 256.0));
            (topo, counts)
        },
        |(topo, counts)| {
            let sh = shape();
            for algo in algos_for(topo.p()) {
                let serial = step_cost(&sh, topo, counts, 1, FLOPS, algo);
                let k1 = step_cost_overlapped(
                    &sh,
                    topo,
                    counts,
                    1,
                    FLOPS,
                    algo,
                    OverlapMode::Fixed(1),
                    None,
                    None,
                );
                let (got, want) = (k1.step_s(), serial.serial_total());
                if (got - want).abs() > 1e-12 * want {
                    return Err(format!("{algo}: k=1 clock {got} != serial {want}"));
                }
                // phase lower bounds visible from outside the engine: all
                // compute serialises on the slowest stream, the whole
                // allreduce on its channel
                for k in CHUNK_SWEEP {
                    let c = step_cost_overlapped(
                        &sh,
                        topo,
                        counts,
                        1,
                        FLOPS,
                        algo,
                        OverlapMode::Fixed(k),
                        None,
                        None,
                    );
                    let floor = serial.compute_s.max(serial.allreduce_s);
                    if c.step_s() < floor * (1.0 - 1e-9) {
                        return Err(format!(
                            "{algo} k={k}: clock {} below phase floor {floor}",
                            c.step_s()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_autotuned_clock_never_exceeds_serial() {
    check(
        10,
        0x0E41C,
        |rng| {
            let topo = random_tree(rng);
            let p = topo.p();
            let counts = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 256.0));
            (topo, counts)
        },
        |(topo, counts)| {
            let sh = shape();
            for algo in algos_for(topo.p()) {
                let serial = step_cost(&sh, topo, counts, 1, FLOPS, algo);
                let auto = step_cost_overlapped(
                    &sh,
                    topo,
                    counts,
                    1,
                    FLOPS,
                    algo,
                    OverlapMode::Auto,
                    None,
                    None,
                );
                if auto.step_s() > serial.serial_total() * (1.0 + 1e-9) {
                    return Err(format!(
                        "{algo}: auto clock {} above serial {}",
                        auto.step_s(),
                        serial.serial_total()
                    ));
                }
                if auto.exposed_a2a_s > auto.step_s() * (1.0 + 1e-9) {
                    return Err(format!("{algo}: exposed a2a above the step clock"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn makespan_monotone_in_k_on_contention_free_fabric() {
    // zero-latency dedicated per-pair links: chunk pricing is exactly
    // fluid (t(bytes/k) = t(bytes)/k), so finer chunking can only help
    let local = Link::new(0.0, 1e-12);
    for p in [4usize, 6, 8] {
        let topo = Topology::homogeneous(p, Link::new(0.0, 1e-9), local);
        let mut rng = Rng::seed_from_u64(p as u64);
        let counts = Mat::from_fn(p, p, |_, _| rng.range_f64(1.0, 256.0));
        let sh = shape();
        for algo in algos_for(p) {
            let mut prev = f64::INFINITY;
            for k in CHUNK_SWEEP {
                let c = step_cost_overlapped(
                    &sh,
                    &topo,
                    &counts,
                    1,
                    FLOPS,
                    algo,
                    OverlapMode::Fixed(k),
                    None,
                    None,
                );
                assert!(
                    c.step_s() <= prev * (1.0 + 1e-9),
                    "P={p} {algo}: k={k} clock {} above k-smaller {prev}",
                    c.step_s()
                );
                prev = c.step_s();
            }
            // and the auto mode lands on the finest sweep point here
            let auto = step_cost_overlapped(
                &sh,
                &topo,
                &counts,
                1,
                FLOPS,
                algo,
                OverlapMode::Auto,
                None,
                None,
            );
            assert!(auto.step_s() <= prev * (1.0 + 1e-9), "P={p} {algo}");
        }
    }
}
