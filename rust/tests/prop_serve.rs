//! Property tests for the serving subsystem: the quickselect percentile
//! against a full-sort oracle, trace-generator determinism, expert-cache
//! eviction invariants (hit rate monotone in capacity; a full-size cache
//! takes only compulsory misses), and parse/Display round-trips for every
//! user-facing mode spec.

use std::str::FromStr;

use ta_moe::comm::A2aAlgo;
use ta_moe::metrics::percentile;
use ta_moe::overlap::OverlapMode;
use ta_moe::placement::Placement;
use ta_moe::serve::{trace, CachePolicy, ExpertCache, TraceConfig, TraceKind};
use ta_moe::util::rng::Rng;
use ta_moe::util::Mat;

// ---------------------------------------------------------------- percentile

#[test]
fn percentile_matches_the_sort_oracle() {
    let mut rng = Rng::seed_from_u64(0xC0FFEE);
    for trial in 0..200 {
        let n = 1 + rng.below(97);
        let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 1e3 - 500.0).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(f64::total_cmp);
        for q in [0.0, 1.0, 25.0, 50.0, 90.0, 99.0, 100.0, rng.f64() * 100.0] {
            let rank = ((q / 100.0 * n as f64).ceil() as usize).clamp(1, n);
            let oracle = sorted[rank - 1];
            assert_eq!(
                percentile(&xs, q),
                Some(oracle),
                "trial {trial}: n={n} q={q}"
            );
        }
    }
}

#[test]
fn percentile_edge_cases() {
    assert_eq!(percentile(&[], 50.0), None);
    assert_eq!(percentile(&[7.0], 0.0), Some(7.0));
    assert_eq!(percentile(&[7.0], 100.0), Some(7.0));
    // out-of-range q clamps rather than panics
    assert_eq!(percentile(&[1.0, 2.0], -5.0), Some(1.0));
    assert_eq!(percentile(&[1.0, 2.0], 250.0), Some(2.0));
    // duplicates are fine for the nearest-rank definition
    assert_eq!(percentile(&[3.0, 3.0, 3.0], 50.0), Some(3.0));
}

// ------------------------------------------------------------------- traces

fn trace_cfg(kind: TraceKind, seed: u64) -> TraceConfig {
    TraceConfig {
        kind,
        rate_rps: 20.0,
        n_requests: 64,
        seed,
        prompt_mean: 32,
        output_mean: 16,
    }
}

#[test]
fn traces_are_seed_deterministic_and_seed_sensitive() {
    for kind in TraceKind::ALL {
        let a = trace::generate(&trace_cfg(kind, 7));
        let b = trace::generate(&trace_cfg(kind, 7));
        assert_eq!(a.len(), 64, "{kind}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits(), "{kind}");
            assert_eq!(x.prompt_tokens, y.prompt_tokens, "{kind}");
            assert_eq!(x.output_tokens, y.output_tokens, "{kind}");
        }
        let c = trace::generate(&trace_cfg(kind, 8));
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.arrival_s != y.arrival_s),
            "{kind}: different seeds must give different arrivals"
        );
    }
}

#[test]
fn traces_are_well_formed() {
    for kind in TraceKind::ALL {
        let reqs = trace::generate(&trace_cfg(kind, 3));
        let mut prev = 0.0;
        for r in &reqs {
            assert!(r.arrival_s >= prev, "{kind}: arrivals must be sorted");
            assert!(r.arrival_s.is_finite());
            prev = r.arrival_s;
            assert!(r.prompt_tokens >= 1);
            assert!(r.output_tokens >= 1);
            // spans are uniform in [mean/2, 3·mean/2)
            assert!(r.prompt_tokens >= 16 && r.prompt_tokens < 48, "{kind}");
            assert!(r.output_tokens >= 8 && r.output_tokens < 24, "{kind}");
        }
    }
}

/// Coefficient of variation of the inter-arrival gaps.
fn gap_cv(arrivals: &[f64]) -> f64 {
    let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
    let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
    let var =
        gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
    var.sqrt() / mean
}

#[test]
fn bursty_traces_are_burstier_than_poisson() {
    // average the dispersion over several seeds so the test is not hostage
    // to one draw; MMPP inter-arrival CV strictly exceeds the exponential's
    let mut cv_poisson = 0.0;
    let mut cv_bursty = 0.0;
    for seed in 0..8 {
        let mut cfg = trace_cfg(TraceKind::Poisson, seed);
        cfg.n_requests = 256;
        let arr: Vec<f64> =
            trace::generate(&cfg).iter().map(|r| r.arrival_s).collect();
        cv_poisson += gap_cv(&arr);
        cfg.kind = TraceKind::Bursty;
        let arr: Vec<f64> =
            trace::generate(&cfg).iter().map(|r| r.arrival_s).collect();
        cv_bursty += gap_cv(&arr);
    }
    assert!(
        cv_bursty > cv_poisson,
        "bursty CV {:.3} must exceed poisson CV {:.3}",
        cv_bursty / 8.0,
        cv_poisson / 8.0
    );
}

// -------------------------------------------------------------------- cache

/// Replay one random access stream against a cache of the given capacity
/// and return (hits, misses, distinct experts touched). The stream itself
/// is capacity-independent.
fn replay(
    policy: CachePolicy,
    cap: usize,
    seed: u64,
    p: usize,
    e: usize,
) -> (u64, u64, u64) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut cache = ExpertCache::new(p, e, cap, policy);
    let pl = Placement::identity(p, e);
    let mut touched = vec![false; p * e];
    for _ in 0..60 {
        let mut counts = Mat::zeros(p, p * e);
        for d in 0..p {
            for _ in 0..3 {
                // zipf-flavoured stream: low expert ids run hot
                let x = rng.below(p * e * (p * e + 1) / 2);
                let mut acc = 0;
                let mut pick = 0;
                for cand in 0..p * e {
                    acc += p * e - cand;
                    if x < acc {
                        pick = cand;
                        break;
                    }
                }
                counts.add_assign(d, pick, 1.0);
                touched[pick] = true;
            }
        }
        cache.access(&counts, &pl, 1.0);
    }
    let distinct = touched.iter().filter(|&&t| t).count() as u64;
    (cache.total_hits(), cache.total_misses(), distinct)
}

#[test]
fn cache_hit_rate_is_monotone_in_capacity_for_both_policies() {
    let (p, e) = (4, 6);
    for policy in CachePolicy::ALL {
        for seed in [1, 42, 1234] {
            let mut prev_hits = 0;
            let mut accesses = None;
            for cap in 1..=e {
                let (hits, misses, _) = replay(policy, cap, seed, p, e);
                // the access stream is cache-oblivious, so totals agree
                match accesses {
                    None => accesses = Some(hits + misses),
                    Some(t) => assert_eq!(t, hits + misses, "{policy} cap={cap}"),
                }
                assert!(
                    hits >= prev_hits,
                    "{policy} seed={seed}: hits fell {prev_hits}->{hits} at cap={cap}"
                );
                prev_hits = hits;
            }
        }
    }
}

#[test]
fn full_capacity_takes_only_compulsory_misses() {
    let (p, e) = (4, 6);
    for policy in CachePolicy::ALL {
        let (_, misses, touched) = replay(policy, e, 99, p, e);
        // no expert is ever evicted at full capacity, so misses are
        // exactly the compulsory first touches
        assert_eq!(misses, touched, "{policy}");
        assert!(touched > 0);
        // and an over-provisioned cache changes nothing
        let (_, misses_over, _) = replay(policy, e + 3, 99, p, e);
        assert_eq!(misses, misses_over, "{policy}");
    }
}

#[test]
fn cap_zero_is_an_uncached_tier_with_no_misses() {
    for policy in CachePolicy::ALL {
        let (hits, misses, _) = replay(policy, 0, 5, 4, 6);
        assert_eq!(misses, 0, "{policy}");
        assert!(hits > 0, "{policy}");
    }
}

#[test]
fn eviction_respects_capacity_per_device() {
    let (p, e, cap) = (2, 4, 2);
    for policy in CachePolicy::ALL {
        let mut cache = ExpertCache::new(p, e, cap, policy);
        let pl = Placement::identity(p, e);
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..40 {
            let mut counts = Mat::zeros(p, p * e);
            for d in 0..p {
                counts.add_assign(d, rng.below(p * e), 1.0);
            }
            cache.access(&counts, &pl, 1.0);
            for dev in 0..p {
                let resident = (0..p * e)
                    .filter(|&x| pl.device_of(x) == dev && cache.is_resident(x))
                    .count();
                assert!(
                    resident <= cap,
                    "{policy}: device {dev} holds {resident} > cap {cap}"
                );
            }
        }
    }
}

// -------------------------------------------------------- spec round-trips

#[test]
fn a2a_specs_round_trip() {
    for algo in A2aAlgo::ALL {
        let spec = algo.to_string();
        assert_eq!(A2aAlgo::from_str(&spec), Ok(algo), "{spec}");
    }
    assert!(A2aAlgo::from_str("carrier-pigeon").is_err());
}

#[test]
fn overlap_specs_round_trip() {
    for mode in [OverlapMode::Serial, OverlapMode::Fixed(1), OverlapMode::Fixed(7), OverlapMode::Auto] {
        let spec = mode.to_string();
        assert_eq!(OverlapMode::from_str(&spec), Ok(mode), "{spec}");
    }
    // "off" is the documented alias for the serial clock
    assert_eq!(OverlapMode::from_str("off"), Ok(OverlapMode::Serial));
    assert!(OverlapMode::from_str("k=0").is_err());
    assert!(OverlapMode::from_str("sideways").is_err());
}

#[test]
fn trace_specs_round_trip() {
    for kind in TraceKind::ALL {
        let spec = kind.to_string();
        assert_eq!(TraceKind::from_str(&spec), Ok(kind), "{spec}");
    }
    // the queueing-theory name for the bursty generator is accepted too
    assert_eq!(TraceKind::from_str("mmpp"), Ok(TraceKind::Bursty));
    assert!(TraceKind::from_str("weibull").is_err());
}

#[test]
fn cache_specs_round_trip() {
    for policy in CachePolicy::ALL {
        let spec = policy.to_string();
        assert_eq!(CachePolicy::from_str(&spec), Ok(policy), "{spec}");
    }
    assert_eq!(
        CachePolicy::from_str("ewma-prioritized"),
        Ok(CachePolicy::EwmaPrioritized)
    );
    assert!(CachePolicy::from_str("fifo").is_err());
}
