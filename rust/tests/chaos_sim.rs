//! Perturbation-engine acceptance tests (the ISSUE-8 criterion): under
//! each scripted fault class the *adaptive* stack — live placement +
//! epoch-aware plan cache + chunk-autotuned overlap — must strictly beat
//! the *static* stack (canonical hosting, cache disabled, serial clock)
//! on the total simulated clock, with the fault visible in the run log
//! and the step clock recovering after bounded windows close.
//!
//! All train scenarios run on a 2×2 tree whose inter-node uplink is a
//! bandwidth bottleneck (the same fabric as the overlap acceptance test),
//! so the adaptive stack has real communication time to hide while the
//! fault stream stresses it. The serve scenario kills a device mid-trace
//! and checks request conservation end to end.

use ta_moe::comm::{A2aAlgo, ScheduleKind};
use ta_moe::coordinator::{Session, SessionBuilder};
use ta_moe::runtime::{ModelCfg, SimBackend};
use ta_moe::serve::{ServeBuilder, TraceKind};
use ta_moe::topology::{Link, Topology, TreeSpec};

/// A [2,2] tree with a deliberately slow uplink: plenty of exposed a2a
/// for the adaptive stack to hide, and a meaningful link to degrade.
fn bottleneck22() -> Topology {
    Topology::tree(
        &TreeSpec::parse("[2,2]").unwrap(),
        &[Link::from_gbps_us(45.0, 1.0), Link::from_gbps_us(0.01, 1.0)],
        ta_moe::topology::presets::local_copy(),
    )
}

fn run_chaos(chaos: &str, adaptive: bool, steps: usize) -> Session {
    let cfg = ModelCfg::preset("tiny4").unwrap(); // P = 4, matches [2,2]
    let mut b = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(bottleneck22())
        .policy_named("fastmoe") // even dispatch keeps the uplink loaded
        .a2a(A2aAlgo::Scheduled(ScheduleKind::Bvn))
        .seed(17)
        .chaos_named(chaos);
    b = if adaptive {
        b.placement_every(8).overlap_named("auto")
    } else {
        b.overlap_named("serial").plan_cache_tol(0.0)
    };
    let mut s = b.build().unwrap();
    s.run(steps).unwrap();
    s
}

fn total_s(s: &Session) -> f64 {
    s.log().sim_time_axis().last().copied().unwrap()
}

/// The shared acceptance bar: adaptive strictly faster, fault on the log.
fn assert_adaptive_wins(spec: &str, steps: usize) -> (Session, Session) {
    let adaptive = run_chaos(spec, true, steps);
    let static_ = run_chaos(spec, false, steps);
    let (ta, ts) = (total_s(&adaptive), total_s(&static_));
    assert!(
        ta < ts,
        "{spec}: adaptive clock {ta} must strictly beat static {ts}"
    );
    assert!(
        !adaptive.log().perturbations.is_empty(),
        "{spec}: the fault stream must be visible in the run log"
    );
    (adaptive, static_)
}

#[test]
fn adaptive_beats_static_under_flapping_straggler() {
    let spec = "straggler:1x3@10-18:flap=4";
    let (adaptive, _) = assert_adaptive_wins(spec, 40);
    let log = adaptive.log();
    assert_eq!(log.first_perturbation_step(), Some(10));
    // the fault bites the clock: same counts stream, strictly more
    // compute on the slowed device ⇒ a strictly slower run than the
    // clean twin of the same seed
    let clean = run_chaos("off", true, 40);
    assert!(
        total_s(&adaptive) > total_s(&clean),
        "a 3x straggler must cost simulated time"
    );
    assert!(clean.log().perturbations.is_empty());
    // bounded window ⇒ finite recovery, surfaced in the summary
    let rec = log.recovery_steps().expect("flapping straggler must recover");
    assert!(rec <= 30, "recovery {rec}");
    let json = log.summary_json().to_string_compact();
    assert!(json.contains(&format!("\"recovery_steps\":{rec}")), "{json}");
}

#[test]
fn adaptive_beats_static_under_link_degradation() {
    // edge 4 is the [2,2] tree's uplink (4 leaf links first)
    let spec = "link:4x4@12-24";
    let (adaptive, _) = assert_adaptive_wins(spec, 40);
    let log = adaptive.log();
    // degrade + restore both fire
    assert_eq!(log.perturbations.len(), 2);
    assert_eq!(log.perturbations[0].step, 12);
    assert_eq!(log.perturbations[1].step, 24);
    // the degraded fabric prices a slower exchange while the window holds
    let step_s: Vec<f64> = log.records.iter().map(|r| r.sim_total_s()).collect();
    assert!(step_s[12] > step_s[11] * 1.5, "degraded uplink must bite");
    // restore ⇒ finite recovery, and not before the window closes (the
    // degraded steps sit far outside the 5% recovery band)
    let rec = log.recovery_steps().expect("restored link must recover");
    assert!(rec >= 12 && rec <= 30, "recovery {rec}");
    // the plan cache noticed both fabric changes: schedules synthesised
    // for the old topology are unusable, so the run re-synthesises
    assert!(
        log.plan_misses >= 3,
        "topology epoch bumps must force re-synthesis, got {} misses",
        log.plan_misses
    );
}

#[test]
fn adaptive_beats_static_under_node_loss() {
    let spec = "nodeloss:2@20";
    let (adaptive, _) = assert_adaptive_wins(spec, 40);
    let log = adaptive.log();
    assert_eq!(log.first_perturbation_step(), Some(20));
    // the world shrank and stayed shrunk
    assert!(!adaptive.topology().is_alive(2));
    assert_eq!(adaptive.topology().n_alive(), 3);
    // the corpse sends nothing once dead: its dispatch row is zeroed
    let counts = adaptive.last_counts().unwrap();
    assert_eq!(counts.row_sum(2), 0.0);
    // every live row still dispatches a full batch (elastic re-scale
    // conserves the survivors' token budget)
    for i in [0usize, 1, 3] {
        assert!(counts.row_sum(i) > 0.0, "live row {i} must keep dispatching");
    }
    // with the sender gone the fabric is less loaded: the clock recovers
    let rec = log.recovery_steps().expect("post-loss clock must settle");
    assert!(rec <= 10, "recovery {rec}");
}

#[test]
fn adaptive_beats_static_under_gate_drift() {
    let spec = "drift:1@10-22";
    let (adaptive, _) = assert_adaptive_wins(spec, 40);
    let log = adaptive.log();
    assert_eq!(log.first_perturbation_step(), Some(10));
    // bounded regime shift ⇒ finite recovery
    let rec = log.recovery_steps().expect("drift window must recover");
    assert!(rec <= 30, "recovery {rec}");
}

#[test]
fn clean_chaos_spec_is_bit_identical_to_no_chaos() {
    // `--chaos off` must leave the whole priced run untouched — the CSV
    // row stream and the summary JSON, byte for byte
    let run = |chaos: Option<&str>| {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let mut b = SessionBuilder::new()
            .backend(Box::new(SimBackend::new(cfg)))
            .topology(bottleneck22())
            .policy_named("ta-moe")
            .seed(9)
            .placement_every(8)
            .overlap_named("auto");
        if let Some(spec) = chaos {
            b = b.chaos_named(spec);
        }
        let mut s = b.build().unwrap();
        s.run(30).unwrap();
        let dir = std::env::temp_dir();
        let tag = chaos.map_or("none", |_| "off");
        let path = dir.join(format!("ta_moe_chaos_bitident_{tag}.csv"));
        s.log().write_csv(&path).unwrap();
        let csv = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        (csv, s.log().summary_json().to_string_compact())
    };
    let (csv_none, json_none) = run(None);
    let (csv_off, json_off) = run(Some("off"));
    assert_eq!(csv_none, csv_off, "--chaos off must not perturb the CSV");
    assert_eq!(json_none, json_off, "--chaos off must not perturb the summary");
    assert!(!json_off.contains("perturbations"));
}

// ---------------------------------------------------------------------------
// serve: node loss with elastic re-scale
// ---------------------------------------------------------------------------

#[test]
fn serve_node_loss_conserves_requests_and_beats_static_admission() {
    let run = |chaos: &str| {
        let mut s = ServeBuilder::new()
            .preset("tiny4")
            .cluster("table1")
            .experts_per_dev(2)
            .policy_named("ta-moe")
            .trace_kind(TraceKind::Poisson)
            .requests(32)
            .seed(11)
            .placement_every(4)
            .chaos_named(chaos)
            .build()
            .unwrap();
        s.run(100_000).unwrap();
        s
    };
    let clean = run("off");
    let lossy = run("nodeloss:3@4");

    // conservation: every request admitted, served, and retired exactly
    // once despite the mid-trace death — nothing dropped, nothing doubled
    assert_eq!(clean.log().requests.len(), 32);
    assert_eq!(lossy.log().requests.len(), 32);
    let mut ids: Vec<usize> = lossy.log().requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 32, "each request retires exactly once");

    // the dead device is out of the batch from the death iteration on
    assert!(!lossy.topology().is_alive(3));
    assert!(lossy
        .log()
        .perturbations
        .iter()
        .any(|p| p.event.contains("nodeloss:3")));

    // three devices do four devices' work: the lossy run cannot be faster
    assert!(lossy.now_s() >= clean.now_s());

    // SLO accounting stays coherent under the fault
    assert!(lossy.goodput() >= 0.0);
    assert!(
        lossy.log().ttft_percentile(99.0).unwrap()
            >= lossy.log().ttft_percentile(50.0).unwrap()
    );
}
