//! Integration tests for the redesigned public API on the pure-rust
//! simulator: `SessionBuilder` → `Session` over `SimBackend`, the
//! `DispatchPolicy` registry, and third-party policy registration — all
//! with zero XLA/PJRT and zero compiled artifacts (the acceptance bar for
//! the default feature set).

use ta_moe::comm::{A2aAlgo, ScheduleKind};
use ta_moe::coordinator::{
    converged_counts, device_flops, parse_policy, register_policy, DeepSpeedEven,
    DispatchPolicy, FasterMoeHir, PolicyInputs, Session, SessionBuilder,
    SessionOptions, TaMoe,
};
use ta_moe::dispatch::{even_caps, Norm};
use ta_moe::runtime::{BackendKind, GateInputs, ModelCfg, SimBackend};
use ta_moe::topology::Topology;
use ta_moe::util::Mat;

fn sim_session(preset: &str, policy: Box<dyn DispatchPolicy>, seed: i32) -> Session {
    let cfg = ModelCfg::preset(preset).expect("builtin preset");
    SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .cluster("C")
        .policy(policy)
        .lr(2e-3)
        .seed(seed)
        .flops_per_dev(device_flops('C'))
        .build()
        .unwrap()
}

#[test]
fn options_bundle_matches_individual_setters() {
    // `SessionBuilder::options` installs a whole SessionOptions at once;
    // it must be bit-identical to the equivalent chain of setters.
    let cfg = ModelCfg::preset("tiny4").expect("builtin preset");
    let mut via_setters = sim_session("tiny4", Box::new(TaMoe { norm: Norm::L1 }), 3);
    let mut via_options = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .cluster("C")
        .policy(Box::new(TaMoe { norm: Norm::L1 }))
        .options(SessionOptions {
            lr: 2e-3,
            seed: 3,
            flops_per_dev: device_flops('C'),
            ..SessionOptions::default()
        })
        .build()
        .unwrap();
    for _ in 0..5 {
        let a = via_setters.step().unwrap();
        let b = via_options.step().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.sim_comm_s, b.sim_comm_s);
    }
}

#[test]
fn sim_session_trains_end_to_end() {
    let mut s = sim_session("tiny4", Box::new(TaMoe { norm: Norm::L1 }), 0);
    let cfg = s.model_cfg().clone();
    let mut losses = Vec::new();
    for _ in 0..30 {
        let rec = s.step().unwrap();
        losses.push(rec.loss);
        assert!(rec.loss.is_finite());
        assert!(rec.sim_comm_s > 0.0, "a2a must cost something");
        let counts = s.last_counts().unwrap();
        let want = (cfg.k * cfg.tokens_per_dev) as f64;
        for i in 0..cfg.p {
            let sum = counts.row_sum(i);
            assert!((sum - want).abs() < 1e-3, "row {i}: {sum} != {want}");
        }
    }
    assert!(
        losses.last().unwrap() < &losses[0],
        "loss should decrease: first {} last {}",
        losses[0],
        losses.last().unwrap()
    );
    assert_eq!(s.log().records.len(), 30);
    assert!(s.log().sim_throughput() > 0.0);
}

#[test]
fn sim_gate_converges_to_tamoe_target() {
    let mut s = sim_session("wide16_switch", Box::new(TaMoe { norm: Norm::L1 }), 1);
    s.run(150).unwrap();
    let target = s.policy_inputs().target.as_ref().unwrap().c.clone();
    let counts = s.last_counts().unwrap().clone();
    // after many steps the measured dispatch tracks ĉ row-wise
    let sent = target.row_sum(0);
    for i in 0..counts.rows() {
        for e in 0..counts.cols() {
            let got = counts.get(i, e) / sent;
            let want = target.get(i, e) / sent;
            assert!(
                (got - want).abs() < 0.02,
                "c[{i}][{e}] {got:.4} vs target {want:.4}"
            );
        }
    }
}

#[test]
fn sim_run_handles_eval_cadence() {
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut s = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .policy_named("fastmoe")
        .eval_every(5)
        .build()
        .unwrap();
    let log = s.run(20).unwrap();
    assert_eq!(log.records.len(), 20);
    assert_eq!(log.evals.len(), 4);
    // evals are attributed to the number of completed steps
    let steps: Vec<usize> = log.evals.iter().map(|e| e.0).collect();
    assert_eq!(steps, vec![5, 10, 15, 20]);
    // eval ce sits near the train ce (an emulated generalisation gap)
    let (_, vl) = *log.evals.last().unwrap();
    assert!((vl - log.records[19].ce).abs() < 0.5);
}

#[test]
fn eval_before_training_is_not_attributed_to_step_zero() {
    // regression: an eval before the first training step used to be logged
    // against step 0 as if training had already happened
    let mut s = sim_session("tiny4", Box::new(TaMoe { norm: Norm::L1 }), 5);
    s.eval_held_out().unwrap();
    assert_eq!(s.log().evals, vec![(0, s.log().evals[0].1)]);
    s.run(3).unwrap();
    s.eval_held_out().unwrap();
    let steps: Vec<usize> = s.log().evals.iter().map(|e| e.0).collect();
    assert_eq!(steps, vec![0, 3], "eval-after-step-k must log k completed steps");
    // the pre-train eval crosses any reachable loss target at t = 0
    let first_loss = s.log().evals[0].1;
    assert_eq!(s.log().sim_time_to_loss(first_loss + 1e-9), Some(0.0));
}

#[test]
fn identical_seeds_identical_runs_across_sessions() {
    let run = |seed: i32| {
        let mut s = sim_session("small8_switch", Box::new(TaMoe { norm: Norm::L1 }), seed);
        (0..10).map(|_| s.step().unwrap().loss).collect::<Vec<f64>>()
    };
    assert_eq!(run(3), run(3));
    assert_ne!(run(3), run(4));
}

#[test]
fn hir_converges_worse_than_tamoe_on_sim() {
    // the fig5 mechanism: the compulsory ratio cannot be learned away
    let run = |policy: Box<dyn DispatchPolicy>| {
        let mut s = sim_session("small8_switch", policy, 42);
        s.run(200).unwrap();
        s.log().tail_loss(5)
    };
    let ta = run(Box::new(TaMoe { norm: Norm::L1 }));
    let hir = run(Box::new(FasterMoeHir { remote_frac: 0.25 }));
    assert!(hir > ta + 0.05, "hir {hir} should converge worse than ta-moe {ta}");
}

#[test]
fn builder_resolves_a2a_from_policy_preference() {
    let s = sim_session("tiny4", Box::new(TaMoe { norm: Norm::L1 }), 0);
    assert_eq!(s.a2a_algo(), A2aAlgo::Direct);
    let s = sim_session("tiny4", Box::new(DeepSpeedEven), 0);
    assert_eq!(s.a2a_algo(), A2aAlgo::Hierarchical);
}

#[test]
fn a2a_override_changes_the_priced_step_and_its_breakdown() {
    let run = |algo: Option<A2aAlgo>| {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let mut b = SessionBuilder::new()
            .backend(Box::new(SimBackend::new(cfg)))
            .cluster("C")
            .policy(Box::new(TaMoe { norm: Norm::L1 }))
            .seed(11);
        if let Some(a) = algo {
            b = b.a2a(a);
        }
        let mut s = b.build().unwrap();
        let rec = s.step().unwrap();
        (s.a2a_algo(), rec)
    };
    let (algo_d, direct) = run(None);
    assert_eq!(algo_d, A2aAlgo::Direct);
    let (algo_b, bvn) = run(Some(A2aAlgo::Scheduled(ScheduleKind::Bvn)));
    assert_eq!(algo_b, A2aAlgo::Scheduled(ScheduleKind::Bvn));
    // same model + data, different wire plan → same loss, different clock
    assert_eq!(direct.loss, bvn.loss);
    assert_ne!(direct.sim_comm_s, bvn.sim_comm_s);
    // the per-phase split adds up to a positive a2a share of comm time
    for rec in [&direct, &bvn] {
        let phases = rec.sim_a2a_local_s + rec.sim_a2a_intra_s + rec.sim_a2a_inter_s;
        assert!(phases > 0.0);
        assert!(phases <= rec.sim_comm_s + 1e-15);
    }
}

#[test]
fn plan_cache_bounds_syntheses_without_distorting_the_clock() {
    // the perf acceptance bar: a 200-step sched:bvn session re-synthesises
    // its schedule only while the gate's dispatch pattern is still moving
    // (≤ ~10 times total, τ ≈ 24 steps), and the cached run's simulated
    // clock matches an uncached run of the same seed — prices are always
    // computed from the live byte matrix, only the schedule is reused
    let run = |cache_tol: f64| {
        let cfg = ModelCfg::preset("tiny4").unwrap();
        let mut s = SessionBuilder::new()
            .backend(Box::new(SimBackend::new(cfg)))
            .cluster("C")
            .policy(Box::new(TaMoe { norm: Norm::L1 }))
            .a2a(A2aAlgo::Scheduled(ScheduleKind::Bvn))
            .seed(7)
            .plan_cache_tol(cache_tol)
            .build()
            .unwrap();
        s.run(200).unwrap();
        let totals: Vec<f64> = s.log().records.iter().map(|r| r.sim_total_s()).collect();
        (s.log().plan_hits, s.log().plan_misses, totals)
    };
    let (hits, misses, cached) = run(ta_moe::coordinator::PLAN_CACHE_TOL);
    let (hits0, misses0, uncached) = run(0.0); // disabled cache = cold every step
    assert_eq!((hits0, misses0), (0, 0), "disabled cache must not count");
    assert!(
        misses <= 10,
        "a converged 200-step run must synthesise ≤ ~10 schedules, got {misses}"
    );
    assert_eq!(hits + misses, 200, "every step either hits or synthesises");
    assert!(hits >= 190);
    // identical clock: per-step totals track the uncached run everywhere
    // (a hit re-prices the cached schedule on the live bytes; within the
    // drift tolerance the synthesized schedule is structurally stable, so
    // any residual difference is refinement noise on near-equal rounds),
    // and once the gate has converged the two runs agree to fp precision
    assert_eq!(cached.len(), uncached.len());
    let mut max_rel = 0.0f64;
    for (a, b) in cached.iter().zip(&uncached) {
        max_rel = max_rel.max((a - b).abs() / b.max(1e-30));
    }
    assert!(max_rel <= 0.02, "per-step drift {max_rel} vs uncached");
    let (sa, sb): (f64, f64) = (cached.iter().sum(), uncached.iter().sum());
    assert!(
        (sa - sb).abs() <= 1e-2 * sb,
        "run totals must match: cached {sa} uncached {sb}"
    );
    for (a, b) in cached.iter().rev().zip(uncached.iter().rev()).take(50) {
        assert!(
            (a - b).abs() <= 2e-3 * b,
            "converged tail must agree: {a} vs {b}"
        );
    }
}

#[test]
fn builder_parses_and_validates_a2a_specs() {
    let build = |spec: &str| {
        SessionBuilder::new()
            .backend(Box::new(SimBackend::new(ModelCfg::preset("tiny4").unwrap())))
            .a2a_named(spec)
            .build()
    };
    assert_eq!(build("sched:rot").unwrap().a2a_algo().name(), "sched:rot");
    // tiny4 has P=4 (a power of two), so sched:xor is accepted
    assert!(build("sched:xor").is_ok());
    let err = build("sched:diagonal").unwrap_err();
    assert!(err.to_string().contains("unknown a2a algo"), "{err}");
}

#[test]
fn builder_rejects_world_size_mismatch() {
    let cfg = ModelCfg::preset("tiny4").unwrap(); // p = 4
    let err = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(ta_moe::topology::presets::cluster_c(2)) // p = 16
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("devices"), "{err}");
}

#[test]
fn builder_resolves_artifact_names_on_sim() {
    let mut s = SessionBuilder::new()
        .artifact("definitely/missing", "small8_gshard")
        .backend_kind(BackendKind::Sim)
        .policy_named("deepspeed")
        .build()
        .unwrap();
    assert_eq!(s.backend_name(), "sim");
    assert_eq!(s.model_cfg().k, 2);
    assert_eq!(s.policy().name(), "deepspeed");
    let rec = s.step().unwrap();
    assert!(rec.loss.is_finite());
}

// ---------------------------------------------------------------------------
// topology- and load-aware expert placement (the PR-4 acceptance criterion)
// ---------------------------------------------------------------------------

/// A skewed synthetic gate load: node-0 devices crowd the experts
/// canonically hosted on node 1 (45% each on a [2,2] tree), node-1
/// devices dispatch uniformly. The penalty is keyed to the *canonical*
/// host on purpose — the load lives in expert space and does not follow a
/// migration, so placement alone must win the comparison.
#[derive(Debug)]
struct SkewedLoad;

impl DispatchPolicy for SkewedLoad {
    fn name(&self) -> String {
        "skewed-load".into()
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        // sim-gate attractor = row-normalised 1/penalty: rows become
        // (0.05, 0.05, 0.45, 0.45) for node-0 devices, uniform for node 1
        let penalty = Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
            if topo.node_of(i) == 0 && topo.node_of(e / cfg.e_per_dev) == 0 {
                9.0
            } else {
                1.0
            }
        });
        PolicyInputs {
            gate: GateInputs {
                penalty,
                caps: even_caps(cfg.p, cfg.n_experts, cfg.capacity),
                local_mask: topo.local_mask(cfg.n_experts, cfg.e_per_dev),
                hir_remote_frac: 1.0,
            },
            target: None,
        }
    }

    fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> Mat {
        let inputs = self.runtime_inputs(topo, cfg);
        let sent = (cfg.k * cfg.tokens_per_dev) as f64;
        Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
            let w = 1.0 / inputs.gate.penalty.get(i, e);
            let row: f64 =
                (0..cfg.n_experts).map(|x| 1.0 / inputs.gate.penalty.get(i, x)).sum();
            sent * w / row
        })
    }
}

#[test]
fn placement_beats_canonical_on_skewed_load_over_2x2_tree() {
    let run = |placement_every: usize| {
        let cfg = ModelCfg::preset("tiny4").unwrap(); // P = 4, matches [2,2]
        let mut s = SessionBuilder::new()
            .backend(Box::new(SimBackend::new(cfg)))
            .topology(ta_moe::topology::presets::table1()) // the [2,2] tree preset
            .policy(Box::new(SkewedLoad))
            .seed(21)
            .placement_every(placement_every) // 0 = canonical hosting forever
            .build()
            .unwrap();
        s.run(80).unwrap();
        s
    };
    let on = run(8);
    let off = run(0);

    // identical model/data/policy: the placement axis must not touch what
    // the gate learns, only where its traffic lands
    assert_eq!(
        on.log().records.last().unwrap().loss,
        off.log().records.last().unwrap().loss
    );

    // canonical run: no engine, no migrations, identity forever
    assert!(off.placement().is_none());
    assert!(off.log().migrations.is_empty());

    // placement run: at least one amortisation-gated migration happened,
    // with full savings accounting
    let log = on.log();
    assert!(
        !log.migrations.is_empty(),
        "skewed load over the [2,2] tree must trigger a migration"
    );
    assert!(on.placement().is_some_and(|p| !p.is_identity()));
    assert!(on.placement_epoch() >= 1);
    for m in &log.migrations {
        assert!(m.moved > 0);
        assert!(m.bytes > 0.0, "weight bytes moved must be recorded");
        assert!(m.cost_s > 0.0, "migration time must be priced");
        assert!(m.predicted_saving_s > 0.0, "gate only accepts predicted wins");
        assert!(m.realized_saving_s.is_finite());
        // the migration's cost is charged to that step's clock
        let rec = &log.records[m.step];
        assert_eq!(rec.sim_migration_s, m.cost_s);
        assert!(rec.sim_total_s() >= rec.sim_comm_s + rec.sim_compute_s + m.cost_s - 1e-15);
    }
    assert!(log.migration_bytes() > 0.0);

    // the acceptance bar: strictly lower total a2a sim time than the
    // canonical placement...
    let a2a_total = |s: &Session| {
        let (l, a, e) = s.log().a2a_phase_totals();
        l + a + e
    };
    let (t_on, t_off) = (a2a_total(&on), a2a_total(&off));
    assert!(
        t_on < t_off,
        "placement-on a2a {t_on} must beat canonical {t_off}"
    );
    // ...and the migration pays for itself within the run even with its
    // cost charged to the clock
    let total = |s: &Session| s.log().sim_time_axis().last().copied().unwrap();
    assert!(
        total(&on) < total(&off),
        "placement-on total {} must beat canonical {}",
        total(&on),
        total(&off)
    );
}

// ---------------------------------------------------------------------------
// chunked overlap engine (the ISSUE-5 acceptance criterion)
// ---------------------------------------------------------------------------

/// A 2×2 tree whose inter-node uplink is a bandwidth bottleneck (β-term
/// far above the path α), so pipelining token chunks through
/// dispatch → expert → combine has real time to hide.
fn bottleneck22() -> Topology {
    use ta_moe::topology::{Link, TreeSpec};
    Topology::tree(
        &TreeSpec::parse("[2,2]").unwrap(),
        &[Link::from_gbps_us(45.0, 1.0), Link::from_gbps_us(0.01, 1.0)],
        ta_moe::topology::presets::local_copy(),
    )
}

fn overlap_session(spec: &str, seed: i32) -> Session {
    let cfg = ModelCfg::preset("tiny4").unwrap(); // P = 4, matches [2,2]
    SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(bottleneck22())
        .policy_named("fastmoe") // even dispatch keeps the uplink loaded
        .a2a(A2aAlgo::Direct)
        .seed(seed)
        .overlap_named(spec)
        .build()
        .unwrap()
}

#[test]
fn overlap_auto_beats_serial_on_bottleneck_2x2_tree() {
    let run = |spec: &str| {
        let mut s = overlap_session(spec, 33);
        s.run(40).unwrap();
        s
    };
    let serial = run("serial");
    let k1 = run("k=1");
    let auto = run("auto");

    // the clock axis must not touch what the model learns
    let last_loss = |s: &Session| s.log().records.last().unwrap().loss;
    assert_eq!(last_loss(&serial), last_loss(&k1));
    assert_eq!(last_loss(&serial), last_loss(&auto));

    // `--overlap k=1` reproduces the serial clock exactly (per step)
    assert_eq!(serial.overlap_mode(), ta_moe::OverlapMode::Serial);
    assert_eq!(k1.overlap_mode(), ta_moe::OverlapMode::Fixed(1));
    for (a, b) in serial.log().records.iter().zip(&k1.log().records) {
        let (ts, tk) = (a.sim_total_s(), b.sim_total_s());
        assert!((ts - tk).abs() <= 1e-12 * ts, "step {}: {ts} != {tk}", a.step);
        assert_eq!(b.chunks, 1);
        // serial-mode bookkeeping: the serial bound IS the charged clock
        assert!((a.sim_serial_s - ts).abs() <= 1e-12 * ts);
    }

    // `--overlap auto` picks k > 1 on the bottlenecked tree and charges a
    // strictly lower simulated clock under the same seed
    assert_eq!(auto.overlap_mode(), ta_moe::OverlapMode::Auto);
    let max_chunks = auto.log().records.iter().map(|r| r.chunks).max().unwrap();
    assert!(max_chunks > 1, "auto must chunk here, got k={max_chunks}");
    let total = |s: &Session| s.log().sim_time_axis().last().copied().unwrap();
    let (t_auto, t_serial) = (total(&auto), total(&serial));
    assert!(
        t_auto < t_serial * 0.99,
        "auto clock {t_auto} must strictly beat serial {t_serial}"
    );

    // the logging/summary paths report the overlapped clock (ISSUE-5
    // satellite regression): per-step records charge ≤ their own serial
    // bound, the run-level efficiency is positive, and the summary/CSV
    // carry the new columns
    for r in &auto.log().records {
        let charged = r.sim_comm_s + r.sim_compute_s;
        assert!(charged <= r.sim_serial_s * (1.0 + 1e-9), "step {}", r.step);
        assert!(r.chunks >= 1);
        assert!(r.sim_a2a_exposed_s >= 0.0);
    }
    let serial_bound: f64 = auto.log().records.iter().map(|r| r.sim_serial_s).sum();
    assert!(t_auto < serial_bound);
    assert!(auto.log().overlap_efficiency() > 0.005);
    assert!(serial.log().overlap_efficiency().abs() < 1e-9);
    let json = auto.log().summary_json().to_string_compact();
    assert!(json.contains("\"overlap_efficiency\":"), "{json}");
    assert!(json.contains(&format!("\"chunks_max\":{max_chunks}")), "{json}");
    let path = std::env::temp_dir().join("ta_moe_overlap_acceptance.csv");
    auto.log().write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let header = text.lines().next().unwrap();
    let chunks_col = header.split(',').position(|c| c == "chunks").unwrap();
    assert!(text
        .lines()
        .skip(1)
        .any(|l| l.split(',').nth(chunks_col).unwrap().parse::<usize>().unwrap() > 1));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn builder_parses_and_validates_overlap_specs() {
    let build = |spec: &str| {
        SessionBuilder::new()
            .backend(Box::new(SimBackend::new(ModelCfg::preset("tiny4").unwrap())))
            .overlap_named(spec)
            .build()
    };
    assert_eq!(
        build("k=4").unwrap().overlap_mode(),
        ta_moe::OverlapMode::Fixed(4)
    );
    assert_eq!(build("off").unwrap().overlap_mode(), ta_moe::OverlapMode::Serial);
    let err = build("sometimes").unwrap_err();
    assert!(err.to_string().contains("unknown overlap mode"), "{err}");
    // the typed setter is validated at build time too, not at step time
    let err = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(ModelCfg::preset("tiny4").unwrap())))
        .overlap(ta_moe::OverlapMode::Fixed(0))
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("chunk count"), "{err}");
}

// ---------------------------------------------------------------------------
// third-party policy registration (the open-API acceptance criterion)
// ---------------------------------------------------------------------------

/// A policy no builtin knows: everything stays strictly on-node. Lives in
/// this (downstream) test crate and is registered at runtime — no edits to
/// `coordinator/` needed.
#[derive(Debug)]
struct LocalOnly;

impl DispatchPolicy for LocalOnly {
    fn name(&self) -> String {
        "local-only".into()
    }

    fn runtime_inputs(&self, topo: &Topology, cfg: &ModelCfg) -> PolicyInputs {
        let local_mask = topo.local_mask(cfg.n_experts, cfg.e_per_dev);
        // effectively infinite penalty off-node ⇒ the gate goes local
        let penalty = Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
            if local_mask.get(i, e) > 0.0 {
                1.0
            } else {
                1e9
            }
        });
        PolicyInputs {
            gate: GateInputs {
                penalty,
                caps: even_caps(cfg.p, cfg.n_experts, cfg.capacity),
                local_mask,
                hir_remote_frac: 1.0,
            },
            target: None,
        }
    }

    fn converged_counts(&self, topo: &Topology, cfg: &ModelCfg) -> Mat {
        let ks = (cfg.k * cfg.tokens_per_dev) as f64;
        let mut m = Mat::zeros(cfg.p, cfg.n_experts);
        for i in 0..cfg.p {
            let local: Vec<usize> = (0..cfg.n_experts)
                .filter(|&e| topo.same_node(i, e / cfg.e_per_dev))
                .collect();
            for &e in &local {
                m.set(i, e, ks / local.len() as f64);
            }
        }
        m
    }
}

fn make_local_only(args: &[&str]) -> Result<Box<dyn DispatchPolicy>, String> {
    if !args.is_empty() {
        return Err(format!("local-only takes no arguments, got {:?}", args.join(":")));
    }
    Ok(Box::new(LocalOnly))
}

#[test]
fn third_party_policy_registers_and_trains() {
    register_policy(&["local-only"], "test-only: strictly intra-node dispatch", make_local_only);

    // selectable by name through the same registry the CLI/config uses
    let policy = parse_policy("local-only").unwrap();
    assert_eq!(policy.name(), "local-only");
    assert_eq!(parse_policy(&policy.name()).unwrap().name(), "local-only");
    assert!(parse_policy("local-only:junk").is_err(), "strict arg parsing applies");

    // and it drives a session end-to-end on the simulator
    let mut s = sim_session("wide16_switch", policy, 9);
    s.run(120).unwrap();
    let counts = s.last_counts().unwrap().clone();
    let topo = s.topology();
    for i in 0..counts.rows() {
        let on: f64 = (0..counts.cols())
            .filter(|&e| topo.same_node(i, e))
            .map(|e| counts.get(i, e))
            .sum();
        let frac = on / counts.row_sum(i);
        assert!(frac > 0.95, "rank {i} on-node fraction {frac}");
    }

    // the analytic sweep path works for it too
    let cc = converged_counts(&LocalOnly, topo, s.model_cfg());
    for i in 0..cc.rows() {
        assert!((cc.row_sum(i) - counts.row_sum(i)).abs() < 1e-6);
    }
}
