//! Property tests for the perturbation engine: the clean path is
//! bit-identical, fault streams replay deterministically, link
//! degradation can only cost priced time, and elastic re-scale never
//! corrupts the expert-hosting permutation.

use ta_moe::comm::A2aAlgo;
use ta_moe::coordinator::{
    step_cost_profiled, ModelShape, Session, SessionBuilder, StepProfile,
};
use ta_moe::overlap::OverlapMode;
use ta_moe::perturb::ChaosSpec;
use ta_moe::runtime::{ModelCfg, SimBackend};
use ta_moe::util::Mat;

fn session(chaos: Option<&str>, seed: i32) -> Session {
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut b = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(ta_moe::topology::presets::table1())
        .policy_named("ta-moe")
        .seed(seed)
        .placement_every(4);
    if let Some(spec) = chaos {
        b = b.chaos_named(spec);
    }
    b.build().unwrap()
}

#[test]
fn empty_fault_stream_is_bit_identical() {
    // an explicit `off` spec (typed or parsed) attaches no engine at all:
    // every priced step matches a session built without chaos, exactly
    let mut none = session(None, 7);
    let mut off_named = session(Some("off"), 7);
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut off_typed = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(ta_moe::topology::presets::table1())
        .policy_named("ta-moe")
        .seed(7)
        .placement_every(4)
        .chaos(ChaosSpec::off())
        .build()
        .unwrap();
    for _ in 0..15 {
        let a = none.step().unwrap();
        let b = off_named.step().unwrap();
        let c = off_typed.step().unwrap();
        for x in [&b, &c] {
            assert_eq!(a.loss, x.loss);
            assert_eq!(a.sim_comm_s, x.sim_comm_s);
            assert_eq!(a.sim_compute_s, x.sim_compute_s);
            assert_eq!(a.sim_migration_s, x.sim_migration_s);
        }
    }
    assert!(none.log().perturbations.is_empty());
    assert!(off_named.log().perturbations.is_empty());
}

#[test]
fn fault_streams_replay_deterministically() {
    let spec = "straggler:0x2@3-9:flap=2+link:4x3@5-12+drift:1@8-14+nodeloss:2@16";
    let run = |seed: i32| {
        let mut s = session(Some(spec), seed);
        s.run(25).unwrap();
        let totals: Vec<f64> =
            s.log().records.iter().map(|r| r.sim_total_s()).collect();
        let events: Vec<(usize, String)> = s
            .log()
            .perturbations
            .iter()
            .map(|p| (p.step, p.event.clone()))
            .collect();
        (totals, events)
    };
    let (t1, e1) = run(13);
    let (t2, e2) = run(13);
    assert_eq!(t1, t2, "same seed + same spec must replay bit-identically");
    assert_eq!(e1, e2);
    assert!(!e1.is_empty());
    // the schedule itself is seed-independent: the same faults fire at
    // the same steps regardless of what the gate draws
    let (_, e3) = run(14);
    assert_eq!(
        e1.iter().map(|(s, e)| (*s, e.clone())).collect::<Vec<_>>(),
        e3
    );
}

#[test]
fn link_degradation_never_lowers_the_priced_exchange() {
    // pure pricing property: scaling any link's alpha/beta by a factor
    // >= 1 can only hold or raise the priced step, for every link and a
    // range of factors, under both a2a plans
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let shape = ModelShape::from_cfg(&cfg);
    let counts = Mat::from_fn(cfg.p, cfg.n_experts, |i, e| {
        64.0 + ((i * 7 + e * 3) % 5) as f64 * 16.0 // uneven, all pairs loaded
    });
    let price = |topo: &ta_moe::topology::Topology, a2a: A2aAlgo| {
        step_cost_profiled(
            &shape,
            topo,
            &counts,
            cfg.e_per_dev,
            45e12,
            a2a,
            OverlapMode::Serial,
            StepProfile::train(),
            None,
            None,
        )
        .step_s()
    };
    let clean = ta_moe::topology::presets::table1();
    for a2a in [A2aAlgo::Direct, A2aAlgo::Hierarchical] {
        let base = price(&clean, a2a);
        for edge in 0..clean.links().len() {
            for factor in [1.0, 1.5, 2.0, 4.0, 16.0] {
                let mut degraded = clean.clone();
                degraded.scale_link(edge, factor);
                let cost = price(&degraded, a2a);
                assert!(
                    cost >= base - 1e-15,
                    "{a2a} edge {edge} x{factor}: {cost} < clean {base}"
                );
                if factor > 1.0 {
                    // monotone in the factor too
                    let mut worse = clean.clone();
                    worse.scale_link(edge, factor * 2.0);
                    assert!(price(&worse, a2a) >= cost - 1e-15);
                }
            }
        }
    }
}

#[test]
fn node_loss_rehosting_preserves_the_permutation() {
    let mut s = session(Some("nodeloss:1@6"), 3);
    s.run(20).unwrap();
    assert!(!s.topology().is_alive(1));
    // whatever evacuation did, the hosting is still a permutation onto
    // e_per_dev slots per device — including the corpse, which parks the
    // coldest experts
    let placement = s.placement().expect("placement engine is on");
    let cfg = s.model_cfg();
    let mut seen = vec![false; cfg.n_experts];
    for e in 0..cfg.n_experts {
        let d = placement.device_of(e);
        assert!(d < cfg.p);
        assert!(!seen[e], "expert {e} hosted twice");
        seen[e] = true;
    }
    for d in 0..cfg.p {
        assert_eq!(
            placement.experts_on(d).len(),
            cfg.e_per_dev,
            "device {d} must host exactly {} experts",
            cfg.e_per_dev
        );
    }
    // the dead sender dispatches nothing once the loss fires
    assert_eq!(s.last_counts().unwrap().row_sum(1), 0.0);
}
