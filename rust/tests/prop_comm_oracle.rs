//! The naive pricing oracle (test-only, per DESIGN.md §perf): a from-first-
//! principles reimplementation of the α-β engine using a `HashMap` link
//! census over `Topology::path` — exactly the formulation the optimized
//! engine replaced with the flat incidence table and scratch census. The
//! property tests pin the zero-alloc hot paths (`pair_times`,
//! `exchange_time`, `round_time`) to this oracle to 1e-12 across tree,
//! asymmetric-tree, and ring topologies, and check that `PlanCache` hits
//! reproduce the cold-path `StepCost` exactly.

// The whole point of this file is the naive HashMap formulation the
// engine replaced (see module doc): the one sanctioned use of the
// unordered type banned crate-wide by clippy.toml and pallas-lint.
// pallas-lint: allow(determinism) -- documented naive oracle; results are
// reduced order-independently (sums/maxima), never iterated for decisions.
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use ta_moe::comm::{rotation_schedule, A2aAlgo, CostEngine, ExchangeModel, ScheduleKind};
use ta_moe::coordinator::{
    device_flops, step_cost, step_cost_cached, ModelShape, PlanCache, PLAN_CACHE_TOL,
};
use ta_moe::topology::{presets, Link, Topology, TreeSpec};
use ta_moe::util::prop::check;
use ta_moe::util::rng::Rng;
use ta_moe::util::Mat;

// ---------------------------------------------------------------------------
// the naive oracle
// ---------------------------------------------------------------------------

/// Flows per directed physical link across the given deliveries.
fn naive_link_load(
    topo: &Topology,
    pairs: &[(usize, usize)],
) -> HashMap<(usize, bool), usize> {
    let mut load = HashMap::new();
    for &(i, j) in pairs {
        for dl in topo.path(i, j) {
            *load.entry((dl.edge, dl.up)).or_insert(0) += 1;
        }
    }
    load
}

/// One delivery's time under a flow census: α accumulates along the path,
/// the slowest hop's β is inflated by its concurrent flows.
fn naive_contended_time(
    topo: &Topology,
    load: &HashMap<(usize, bool), usize>,
    i: usize,
    j: usize,
    bytes: f64,
) -> f64 {
    let links = topo.links();
    let mut alpha = 0.0;
    let mut slow: f64 = 0.0;
    for dl in topo.path(i, j) {
        let flows = if topo.link_contended(dl.edge) {
            load[&(dl.edge, dl.up)] as f64
        } else {
            1.0
        };
        alpha += links[dl.edge].alpha;
        slow = slow.max(links[dl.edge].beta * flows);
    }
    alpha + slow * bytes
}

fn pair_time(topo: &Topology, i: usize, j: usize, bytes: f64) -> f64 {
    topo.alpha(i, j) + topo.beta(i, j) * bytes
}

/// Oracle mirror of `CostEngine::pair_times`.
fn naive_pair_times(topo: &Topology, model: ExchangeModel, bytes: &Mat) -> Mat {
    let p = topo.p();
    match model {
        ExchangeModel::SlowestPair | ExchangeModel::PerSenderSerial => {
            Mat::from_fn(p, p, |i, j| {
                let b = bytes.get(i, j);
                if b <= 0.0 {
                    0.0
                } else {
                    pair_time(topo, i, j, b)
                }
            })
        }
        ExchangeModel::Contention => {
            let live: Vec<(usize, usize)> = (0..p)
                .flat_map(|i| (0..p).map(move |j| (i, j)))
                .filter(|&(i, j)| i != j && bytes.get(i, j) > 0.0)
                .collect();
            let load = naive_link_load(topo, &live);
            Mat::from_fn(p, p, |i, j| {
                let b = bytes.get(i, j);
                if b <= 0.0 {
                    0.0
                } else if i == j {
                    pair_time(topo, i, i, b)
                } else {
                    naive_contended_time(topo, &load, i, j, b)
                }
            })
        }
    }
}

/// Oracle mirror of `CostEngine::exchange_time` (self copies overlap the
/// network phase; only their excess is exposed).
fn naive_exchange_time(topo: &Topology, model: ExchangeModel, bytes: &Mat) -> f64 {
    let p = topo.p();
    let times = naive_pair_times(topo, model, bytes);
    let copy = (0..p).map(|i| times.get(i, i)).fold(0.0, f64::max);
    let net = match model {
        ExchangeModel::SlowestPair | ExchangeModel::Contention => (0..p)
            .flat_map(|i| (0..p).map(move |j| (i, j)))
            .filter(|&(i, j)| i != j)
            .map(|(i, j)| times.get(i, j))
            .fold(0.0, f64::max),
        ExchangeModel::PerSenderSerial => (0..p)
            .map(|i| (0..p).filter(|&j| j != i).map(|j| times.get(i, j)).sum::<f64>())
            .fold(0.0, f64::max),
    };
    net + (copy - net).max(0.0)
}

/// Oracle mirror of `CostEngine::round_time`.
fn naive_round_time(
    topo: &Topology,
    model: ExchangeModel,
    bytes: &Mat,
    round: &[(usize, usize)],
) -> f64 {
    let live: Vec<(usize, usize)> = round
        .iter()
        .copied()
        .filter(|&(i, j)| i != j && bytes.get(i, j) > 0.0)
        .collect();
    match model {
        ExchangeModel::SlowestPair => live
            .iter()
            .map(|&(i, j)| pair_time(topo, i, j, bytes.get(i, j)))
            .fold(0.0, f64::max),
        ExchangeModel::PerSenderSerial => {
            let mut per_sender = vec![0.0; topo.p()];
            for &(i, j) in &live {
                per_sender[i] += pair_time(topo, i, j, bytes.get(i, j));
            }
            per_sender.into_iter().fold(0.0, f64::max)
        }
        ExchangeModel::Contention => {
            let load = naive_link_load(topo, &live);
            live.iter()
                .map(|&(i, j)| naive_contended_time(topo, &load, i, j, bytes.get(i, j)))
                .fold(0.0, f64::max)
        }
    }
}

// ---------------------------------------------------------------------------
// generators
// ---------------------------------------------------------------------------

const MODELS: [ExchangeModel; 3] = [
    ExchangeModel::SlowestPair,
    ExchangeModel::PerSenderSerial,
    ExchangeModel::Contention,
];

/// Random topology: symmetric tree, asymmetric tree, or ring.
fn random_topology(rng: &mut Rng) -> Topology {
    let dev = Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0));
    let up = Link::from_gbps_us(rng.range_f64(4.0, 25.0), rng.range_f64(5.0, 30.0));
    let spine = Link::from_gbps_us(rng.range_f64(2.0, 20.0), rng.range_f64(10.0, 40.0));
    match rng.below(3) {
        0 => {
            let spec = TreeSpec::symmetric(&[rng.range(2, 5), rng.range(2, 5)]);
            Topology::tree(&spec, &[dev, up], presets::local_copy())
        }
        1 => {
            // asymmetric: a deep pod next to shallow nodes
            let per = rng.range(2, 4);
            let spec = TreeSpec::Switch(vec![
                TreeSpec::Switch(vec![TreeSpec::Devices(per), TreeSpec::Devices(per)]),
                TreeSpec::Switch(vec![TreeSpec::Devices(per)]),
            ]);
            Topology::tree(&spec, &[dev, up, spine], presets::local_copy())
        }
        _ => {
            let p = rng.range(3, 9);
            let links = (0..p)
                .map(|_| {
                    Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0))
                })
                .collect();
            Topology::ring(links, presets::local_copy())
        }
    }
}

/// Random byte matrix with zeros sprinkled in (exercises the live filter).
fn random_bytes(rng: &mut Rng, p: usize) -> Mat {
    Mat::from_fn(p, p, |_, _| {
        if rng.below(5) == 0 {
            0.0
        } else {
            rng.range_f64(0.0, 64e6)
        }
    })
}

// ---------------------------------------------------------------------------
// properties
// ---------------------------------------------------------------------------

#[test]
fn prop_optimized_engine_matches_naive_oracle() {
    check(
        40,
        0x0AC1E,
        |rng| {
            let topo = random_topology(rng);
            let bytes = random_bytes(rng, topo.p());
            (topo, bytes)
        },
        |(topo, bytes)| {
            let p = topo.p();
            for model in MODELS {
                let mut eng = CostEngine::new(topo, model);
                // pair_times (twice: scratch reuse must not leak state)
                for _ in 0..2 {
                    let want = naive_pair_times(topo, model, bytes);
                    let got = eng.pair_times(bytes).clone();
                    let d = got.linf_dist(&want);
                    if d > 1e-12 {
                        return Err(format!("{model:?} pair_times off by {d}"));
                    }
                }
                // exchange_time
                let (got, want) =
                    (eng.exchange_time(bytes), naive_exchange_time(topo, model, bytes));
                if (got - want).abs() > 1e-12 * want.max(1.0) {
                    return Err(format!("{model:?} exchange {got} != {want}"));
                }
                // round_time over a full 1-factorisation (self round incl.)
                for round in rotation_schedule(p) {
                    let got = eng.round_time(bytes, &round);
                    let want = naive_round_time(topo, model, bytes, &round);
                    if (got - want).abs() > 1e-12 * want.max(1.0) {
                        return Err(format!("{model:?} round {got} != {want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduled_and_planned_prices_match_oracle_round_sums() {
    // the planner's scheduled price is exactly the oracle's per-round sum
    // plus the exposed-local-copy excess — pins scheduled_a2a_time (and
    // therefore bvn refinement's accounting) to the naive formulation
    check(
        15,
        0x5EED5,
        |rng| {
            let topo = random_topology(rng);
            let bytes = random_bytes(rng, topo.p());
            (topo, bytes)
        },
        |(topo, bytes)| {
            let p = topo.p();
            let rounds = rotation_schedule(p);
            let net: f64 = rounds
                .iter()
                .map(|r| naive_round_time(topo, ExchangeModel::Contention, bytes, r))
                .sum();
            let copy = (0..p)
                .map(|i| {
                    if bytes.get(i, i) > 0.0 {
                        pair_time(topo, i, i, bytes.get(i, i))
                    } else {
                        0.0
                    }
                })
                .fold(0.0, f64::max);
            let want = net + (copy - net).max(0.0);
            let got = ta_moe::comm::scheduled_a2a_time(topo, bytes, &rounds);
            if (got - want).abs() > 1e-12 * want.max(1.0) {
                return Err(format!("scheduled {got} != oracle {want}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_cache_hits_reproduce_cold_step_cost_exactly() {
    let shape = ModelShape::gpt_medium(false, 6, 1024);
    check(
        10,
        0xCAC4E,
        |rng| {
            let nodes = rng.range(2, 5);
            let topo = presets::cluster_c(nodes);
            let p = topo.p();
            let sent = 6144.0;
            // a random row-stochastic-ish dispatch: positive counts
            let counts = Mat::from_fn(p, p, |_, _| rng.range_f64(1.0, sent / p as f64));
            (topo, counts)
        },
        |(topo, counts)| {
            for kind in [ScheduleKind::Rotation, ScheduleKind::Bvn] {
                let algo = A2aAlgo::Scheduled(kind);
                let cold = step_cost(&shape, topo, counts, 1, device_flops('C'), algo);
                let mut cache = PlanCache::new(PLAN_CACHE_TOL);
                let miss = step_cost_cached(
                    &shape, topo, counts, 1, device_flops('C'), algo, &mut cache,
                );
                let hit = step_cost_cached(
                    &shape, topo, counts, 1, device_flops('C'), algo, &mut cache,
                );
                if (cache.misses(), cache.hits()) != (1, 1) {
                    return Err(format!(
                        "{algo}: counters {:?}", (cache.misses(), cache.hits())
                    ));
                }
                for (name, c) in [("miss", &miss), ("hit", &hit)] {
                    if c.a2a_s != cold.a2a_s
                        || c.compute_s != cold.compute_s
                        || c.allreduce_s != cold.allreduce_s
                        || c.a2a != cold.a2a
                    {
                        return Err(format!("{algo} {name}: {c:?} != cold {cold:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}
