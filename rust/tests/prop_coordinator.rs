//! Randomised property tests on coordinator invariants (proptest stand-in
//! — see `util::prop`): routing conservation, capacity feasibility,
//! penalty normalisation, cost-model monotonicity, and solver optimality
//! across randomly generated topologies and problem shapes.

use ta_moe::comm::{A2aAlgo, CostEngine};
use ta_moe::coordinator::{
    converged_counts, step_cost, DeepSpeedEven, DispatchPolicy, FastMoeEven, FasterMoeHir,
    ModelShape, TaMoe,
};
use ta_moe::dispatch::{
    is_locally_optimal, penalty_weights, proportional_caps, sinkhorn_repair,
    target_pattern, DispatchProblem, Norm,
};
use ta_moe::runtime::ModelCfg;
use ta_moe::topology::{presets, Link, Topology, TreeSpec};
use ta_moe::util::prop::check;
use ta_moe::util::rng::Rng;
use ta_moe::util::Mat;

/// Random 2-level (sometimes asymmetric 3-level) tree topology.
fn random_topology(rng: &mut Rng) -> Topology {
    let n_nodes = rng.range(2, 5);
    let per_node = rng.range(2, 5);
    let asym = rng.below(3) == 0 && n_nodes >= 3;
    let spec = if asym {
        let mut children = vec![TreeSpec::Switch(
            (0..n_nodes / 2).map(|_| TreeSpec::Devices(per_node)).collect(),
        )];
        for _ in n_nodes / 2..n_nodes {
            children.push(TreeSpec::Switch(vec![TreeSpec::Devices(per_node)]));
        }
        TreeSpec::Switch(children)
    } else {
        TreeSpec::Switch((0..n_nodes).map(|_| TreeSpec::Devices(per_node)).collect())
    };
    let dev = Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0));
    let up = Link::from_gbps_us(rng.range_f64(4.0, 25.0), rng.range_f64(5.0, 30.0));
    let spine = Link::from_gbps_us(rng.range_f64(2.0, 20.0), rng.range_f64(10.0, 40.0));
    Topology::tree(&spec, &[dev, up, spine], presets::local_copy())
}

fn random_problem(rng: &mut Rng) -> DispatchProblem {
    DispatchProblem {
        k: rng.range(1, 3),
        s: rng.range(64, 4096),
        e_per_dev: rng.range(1, 3),
        elem_bytes: 4 << rng.below(10),
    }
}

fn cfg_for(topo: &Topology, prob: &DispatchProblem) -> ModelCfg {
    let p = topo.p();
    ModelCfg {
        p,
        e_per_dev: prob.e_per_dev,
        layers: 4,
        d: 64,
        f: 128,
        heads: 2,
        vocab: 256,
        batch: 1,
        seq: prob.s,
        k: prob.k,
        cap_factor: 1.25,
        gate: "switch".into(),
        dispatch: "local".into(),
        n_experts: p * prob.e_per_dev,
        capacity: 2 * prob.k * prob.s,
        tokens_per_dev: prob.s,
        moe_layer_ids: vec![1, 3],
    }
}

#[test]
fn prop_target_pattern_feasible_on_random_topologies() {
    check(
        40,
        0xA11CE,
        |rng| (random_topology(rng), random_problem(rng)),
        |(topo, prob)| {
            let tp = target_pattern(topo, prob);
            let want_row = prob.sent_per_dev();
            let want_col = want_row * topo.p() as f64 / tp.c.cols() as f64;
            for i in 0..tp.c.rows() {
                let r = tp.c.row_sum(i);
                if (r - want_row).abs() > 1e-5 * want_row {
                    return Err(format!("row {i}: {r} != {want_row}"));
                }
            }
            for e in 0..tp.c.cols() {
                let c = tp.c.col_sum(e);
                if (c - want_col).abs() > 1e-4 * want_col {
                    return Err(format!("col {e}: {c} != {want_col}"));
                }
            }
            if tp.c.min() < 0.0 {
                return Err("negative volume".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_target_never_slower_than_even_on_lower_bound() {
    check(
        30,
        0xBEEF,
        |rng| (random_topology(rng), random_problem(rng)),
        |(topo, prob)| {
            let tp = target_pattern(topo, prob);
            let mut eng = CostEngine::slowest_pair(topo);
            let even = Mat::filled(
                topo.p(),
                tp.c.cols(),
                prob.sent_per_dev() / tp.c.cols() as f64,
            );
            let to_bytes = |c: &Mat| {
                Mat::from_fn(topo.p(), topo.p(), |i, j| {
                    (0..prob.e_per_dev)
                        .map(|le| c.get(i, j * prob.e_per_dev + le))
                        .sum::<f64>()
                        * prob.elem_bytes as f64
                })
            };
            let t_even = eng.exchange_time(&to_bytes(&even));
            let t_target = eng.exchange_time(&to_bytes(&tp.c));
            // β̂ smoothing can cost a whisker vs raw-β even dispatch, so
            // allow 5%; anything more means the solver regressed.
            if t_target > t_even * 1.05 {
                return Err(format!("target {t_target} worse than even {t_even}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_target_locally_optimal_on_symmetric_trees() {
    check(
        10,
        0xCAFE,
        |rng| {
            let n_nodes = rng.range(2, 4);
            let per_node = rng.range(2, 4);
            let spec = TreeSpec::symmetric(&[n_nodes, per_node]);
            let dev = Link::from_gbps_us(rng.range_f64(40.0, 250.0), 2.0);
            let up = Link::from_gbps_us(rng.range_f64(5.0, 25.0), 10.0);
            let topo = Topology::tree(&spec, &[dev, up], presets::local_copy());
            let prob = random_problem(rng);
            (topo, prob)
        },
        |(topo, prob)| {
            let tp = target_pattern(topo, prob);
            if is_locally_optimal(topo, &tp.c, prob, 200, 0.02, 1e-9) {
                Ok(())
            } else {
                Err("a feasible perturbation improved the min-max objective".into())
            }
        },
    );
}

#[test]
fn prop_converged_counts_conserve_for_all_strategies() {
    check(
        30,
        0xD00D,
        |rng| {
            let topo = random_topology(rng);
            let prob = random_problem(rng);
            let strat: Box<dyn DispatchPolicy> = match rng.below(4) {
                0 => Box::new(DeepSpeedEven),
                1 => Box::new(FastMoeEven),
                2 => Box::new(FasterMoeHir { remote_frac: rng.range_f64(0.0, 1.0) }),
                _ => Box::new(TaMoe { norm: Norm::L1 }),
            };
            (topo, prob, strat)
        },
        |(topo, prob, strat)| {
            let cfg = cfg_for(topo, prob);
            let m = converged_counts(strat.as_ref(), topo, &cfg);
            let want = (prob.k * prob.s) as f64;
            for i in 0..topo.p() {
                let r = m.row_sum(i);
                if (r - want).abs() > 1e-5 * want {
                    return Err(format!("{}: row {i} {r} != {want}", strat.name()));
                }
            }
            if m.min() < -1e-12 {
                return Err("negative counts".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_proportional_caps_exact_and_integral() {
    check(
        50,
        0xFACE,
        |rng| {
            let p = rng.range(2, 9);
            let n = rng.range(2, 9);
            let cap = rng.range(1, 500);
            let m = Mat::from_fn(p, n, |_, _| rng.range_f64(0.01, 10.0));
            (m, cap)
        },
        |(m, cap)| {
            let caps = proportional_caps(m, *cap);
            for e in 0..m.cols() {
                let s = caps.col_sum(e);
                if s as usize != *cap {
                    return Err(format!("col {e} sums to {s}, want {cap}"));
                }
            }
            for v in caps.data() {
                if v.fract() != 0.0 || *v < 0.0 {
                    return Err(format!("non-integral cap {v}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_penalty_rows_normalised_and_anti_monotone() {
    check(
        50,
        0x5EED,
        |rng| {
            let p = rng.range(2, 8);
            let n = rng.range(2, 8);
            Mat::from_fn(p, n, |_, _| rng.range_f64(0.1, 50.0))
        },
        |m| {
            for norm in [Norm::L1, Norm::Softmax { temp: 2.0 }] {
                let w = penalty_weights(m, norm);
                for i in 0..m.rows() {
                    let s: f64 = w.row(i).iter().sum();
                    if (s - 1.0).abs() > 1e-9 {
                        return Err(format!("row {i} sums to {s}"));
                    }
                    // anti-monotone: the argmax target gets the min penalty
                    let (amax, _) = m
                        .row(i)
                        .iter()
                        .enumerate()
                        .fold((0, f64::MIN), |a, (j, &v)| if v > a.1 { (j, v) } else { a });
                    let (amin_w, _) = w
                        .row(i)
                        .iter()
                        .enumerate()
                        .fold((0, f64::MAX), |a, (j, &v)| if v < a.1 { (j, v) } else { a });
                    if m.row(i)[amin_w] < m.row(i)[amax] - 1e-9 {
                        return Err(format!(
                            "row {i}: smallest penalty not on the largest target"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sinkhorn_reaches_marginals() {
    check(
        50,
        0xFEED,
        |rng| {
            let p = rng.range(2, 7);
            let n = rng.range(2, 7);
            let m = Mat::from_fn(p, n, |_, _| rng.range_f64(0.05, 5.0));
            let total = rng.range_f64(10.0, 1000.0);
            (m, total)
        },
        |(m, total)| {
            let rows = vec![total / m.rows() as f64 * 1.0; m.rows()];
            let cols = vec![total / m.cols() as f64; m.cols()];
            let out = sinkhorn_repair(m, &rows, &cols, 500, 1e-12);
            for i in 0..m.rows() {
                if (out.row_sum(i) - rows[i]).abs() > 1e-6 * rows[i] {
                    return Err(format!("row {i}"));
                }
            }
            for e in 0..m.cols() {
                if (out.col_sum(e) - cols[e]).abs() > 1e-6 * cols[e] {
                    return Err(format!("col {e}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_step_cost_monotone_in_remote_traffic() {
    // moving tokens from a local expert to a remote one can never make the
    // simulated exchange cheaper
    check(
        30,
        0xAB1E,
        |rng| {
            let topo = random_topology(rng);
            let prob = DispatchProblem { k: 1, s: 1024, e_per_dev: 1, elem_bytes: 4096 };
            let frac = rng.range_f64(0.0, 0.4);
            (topo, prob, frac)
        },
        |(topo, prob, frac)| {
            let cfg = cfg_for(topo, prob);
            let shape = ModelShape::gpt_medium(false, 1, 1024);
            let base = converged_counts(&TaMoe { norm: Norm::L1 }, topo, &cfg);
            // shift `frac` of rank 0's local volume to the farthest rank
            let mut shifted = base.clone();
            let far = topo.p() - 1;
            let moved = shifted.get(0, 0) * frac;
            shifted.add_assign(0, 0, -moved);
            shifted.add_assign(0, far, moved);
            let c0 = step_cost(&shape, topo, &base, 1, 45e12, A2aAlgo::Direct);
            let c1 = step_cost(&shape, topo, &shifted, 1, 45e12, A2aAlgo::Direct);
            if c1.a2a_s + 1e-12 < c0.a2a_s {
                return Err(format!("remote shift got cheaper: {} < {}", c1.a2a_s, c0.a2a_s));
            }
            Ok(())
        },
    );
}
