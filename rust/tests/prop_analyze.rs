//! Property and acceptance tests for the bottleneck-attribution &
//! what-if engine (ISSUE 10): blame partitions the step clock (rows
//! non-negative, fractions summing to 1) across topology × algo ×
//! overlap mode; the blamed pricing is bit-identical to the blame-free
//! entry point (`--analyze` can never change what a run charges); traced
//! busy fractions stay ≤ 1 per track; on the bottlenecked [2,2] tree the
//! top-blamed resource is the slow uplink; and a `link:<e>x<f>` what-if
//! projection equals the clock of a *real* run under the equivalent
//! chaos spec to 1e-9 relative — the projection is a statement about the
//! simulator, not a heuristic.

use ta_moe::analyze::{analyze_workload, blame_fractions, WhatIf};
use ta_moe::comm::{A2aAlgo, ScheduleKind};
use ta_moe::coordinator::{
    step_cost_blamed, step_cost_profiled, ModelShape, Session, SessionBuilder, StepProfile,
    Workload,
};
use ta_moe::overlap::OverlapMode;
use ta_moe::runtime::{ModelCfg, SimBackend};
use ta_moe::topology::{presets, Link, Topology, TreeSpec};
use ta_moe::trace::TraceLevel;
use ta_moe::util::prop::check;
use ta_moe::util::rng::Rng;
use ta_moe::util::Mat;

fn random_tree(rng: &mut Rng) -> Topology {
    let spec = TreeSpec::symmetric(&[rng.range(2, 5), rng.range(2, 5)]);
    let dev = Link::from_gbps_us(rng.range_f64(20.0, 300.0), rng.range_f64(1.0, 5.0));
    let up = Link::from_gbps_us(rng.range_f64(4.0, 25.0), rng.range_f64(5.0, 30.0));
    Topology::tree(&spec, &[dev, up], presets::local_copy())
}

fn shape() -> ModelShape {
    ModelShape {
        layers: 4,
        d: 64,
        f: 128,
        vocab: 1000,
        seq: 64,
        tokens_per_dev: 64,
        k: 1,
        n_moe_layers: 2,
        elem_bytes: 4,
    }
}

fn algos_for(p: usize) -> Vec<A2aAlgo> {
    A2aAlgo::ALL
        .into_iter()
        .filter(|a| a.validate_for(p).is_ok())
        .collect()
}

const FLOPS: f64 = 45e12;

const MODES: [OverlapMode; 4] = [
    OverlapMode::Serial,
    OverlapMode::Fixed(2),
    OverlapMode::Fixed(8),
    OverlapMode::Auto,
];

#[test]
fn prop_blame_partitions_the_step_clock() {
    // for every (topology × algo × overlap mode), with and without a
    // straggler: blame rows are non-negative and sum to the step clock,
    // so the normalised fractions sum to exactly 1
    check(
        10,
        0x0A7A1,
        |rng| {
            let topo = random_tree(rng);
            let p = topo.p();
            let counts = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 256.0));
            (topo, counts)
        },
        |(topo, counts)| {
            let sh = shape();
            let slow: Vec<f64> =
                (0..topo.p()).map(|i| if i == 1 { 2.0 } else { 1.0 }).collect();
            for algo in algos_for(topo.p()) {
                for mode in MODES {
                    for slowdown in [None, Some(slow.as_slice())] {
                        let (cost, rows) = step_cost_blamed(
                            &sh,
                            topo,
                            counts,
                            1,
                            FLOPS,
                            algo,
                            mode,
                            StepProfile::train(),
                            None,
                            None,
                            slowdown,
                        );
                        if rows.is_empty() {
                            return Err(format!("{algo} {mode}: no blame rows"));
                        }
                        if let Some((t, b)) = rows.iter().find(|(_, b)| *b < 0.0) {
                            return Err(format!("{algo} {mode}: negative blame {t}={b}"));
                        }
                        let sum: f64 = rows.iter().map(|(_, b)| b).sum();
                        if (sum - cost.step_s()).abs() > 1e-9 * cost.step_s() {
                            return Err(format!(
                                "{algo} {mode}: blame sum {sum} != step clock {}",
                                cost.step_s()
                            ));
                        }
                        let blame = blame_fractions(&rows, cost.step_s());
                        let frac_sum: f64 = blame.iter().map(|r| r.blame_frac).sum();
                        if (frac_sum - 1.0).abs() > 1e-9 {
                            return Err(format!(
                                "{algo} {mode}: blame fractions sum to {frac_sum}"
                            ));
                        }
                        if blame.iter().any(|r| r.blame_frac < 0.0) {
                            return Err(format!("{algo} {mode}: negative blame fraction"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blamed_pricing_is_bit_identical_to_profiled() {
    // attribution must be a pure observer: the StepCost that comes back
    // with blame attached is, field for field, the one the blame-free
    // entry point prices — `--analyze` can never change a run's clock
    check(
        10,
        0x0A7A2,
        |rng| {
            let topo = random_tree(rng);
            let p = topo.p();
            let counts = Mat::from_fn(p, p, |_, _| rng.range_f64(0.0, 256.0));
            (topo, counts)
        },
        |(topo, counts)| {
            let sh = shape();
            for algo in algos_for(topo.p()) {
                for mode in MODES {
                    let plain = step_cost_profiled(
                        &sh,
                        topo,
                        counts,
                        1,
                        FLOPS,
                        algo,
                        mode,
                        StepProfile::train(),
                        None,
                        None,
                    );
                    let (blamed, _) = step_cost_blamed(
                        &sh,
                        topo,
                        counts,
                        1,
                        FLOPS,
                        algo,
                        mode,
                        StepProfile::train(),
                        None,
                        None,
                        None,
                    );
                    let same = plain.compute_s == blamed.compute_s
                        && plain.a2a_s == blamed.a2a_s
                        && plain.allreduce_s == blamed.allreduce_s
                        && plain.overlapped_s == blamed.overlapped_s
                        && plain.exposed_a2a_s == blamed.exposed_a2a_s
                        && plain.chunks == blamed.chunks;
                    if !same {
                        return Err(format!(
                            "{algo} {mode}: blamed cost {blamed:?} != profiled {plain:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// acceptance scenarios on the bottlenecked [2,2] tree
// ---------------------------------------------------------------------------

/// A [2,2] tree with a deliberately slow uplink (the shared acceptance
/// fabric): 4 leaf links first, so the uplink is edge 4.
fn bottleneck22() -> Topology {
    Topology::tree(
        &TreeSpec::parse("[2,2]").unwrap(),
        &[Link::from_gbps_us(45.0, 1.0), Link::from_gbps_us(0.01, 1.0)],
        presets::local_copy(),
    )
}

const UPLINK: usize = 4;

/// A deterministic bottleneck22 run. `fastmoe` keeps the dispatch counts
/// independent of the fabric, so a chaos twin of the same seed prices the
/// *same* counts on a scaled topology; `plan_cache_tol 0.0` keeps cached
/// schedules exact-match-only, identical to the analyzer's cache-cold
/// re-pricing.
fn run22(overlap: &str, chaos: Option<&str>, trace: Option<TraceLevel>, steps: usize) -> Session {
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let mut b = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(bottleneck22())
        .policy_named("fastmoe")
        .a2a(A2aAlgo::Scheduled(ScheduleKind::Bvn))
        .overlap_named(overlap)
        .plan_cache_tol(0.0)
        .seed(17);
    if let Some(spec) = chaos {
        b = b.chaos_named(spec);
    }
    if let Some(level) = trace {
        b = b.trace_level(level);
    }
    let mut s = b.build().unwrap();
    s.run(steps).unwrap();
    s
}

#[test]
fn top_blame_on_the_bottlenecked_tree_is_the_uplink() {
    let s = run22("serial", None, None, 8);
    let rep =
        analyze_workload(s.core(), s.last_counts().unwrap(), s.log(), None, "train").unwrap();
    let top = &rep.blame[0];
    let slot: usize = top
        .track
        .strip_prefix("link:")
        .unwrap_or_else(|| panic!("top blame must be a link, got {}", top.track))
        .parse()
        .unwrap();
    assert_eq!(slot / 2, UPLINK, "top blame {} is not the uplink", top.track);
    // the uplink's two directed slots together gate most of the step on
    // a fabric whose leaf links are 4500x faster
    let uplink_frac: f64 = rep
        .blame
        .iter()
        .filter(|r| {
            r.track
                .strip_prefix("link:")
                .and_then(|s| s.parse::<usize>().ok())
                .is_some_and(|s| s / 2 == UPLINK)
        })
        .map(|r| r.blame_frac)
        .sum();
    assert!(uplink_frac > 0.5, "uplink blame {uplink_frac} should dominate");
    let frac_sum: f64 = rep.blame.iter().map(|r| r.blame_frac).sum();
    assert!((frac_sum - 1.0).abs() < 1e-9, "fractions sum to {frac_sum}");
    // the auto sweep chases the blame: its link counterfactual targets
    // the blamed uplink and relieving it projects a real speedup; the
    // ranking itself is non-increasing (train run: no infinite-cache)
    assert_eq!(rep.counterfactuals.len(), 4);
    let link_cf = rep
        .counterfactuals
        .iter()
        .find(|c| c.spec == format!("link:{UPLINK}x2"))
        .expect("auto sweep must target the blamed uplink");
    assert!(link_cf.speedup > 1.0, "2x uplink speedup {}", link_cf.speedup);
    for pair in rep.counterfactuals.windows(2) {
        assert!(pair[0].speedup >= pair[1].speedup, "ranking must be sorted");
    }
}

#[test]
fn whatif_projection_equals_the_equivalent_chaos_run() {
    // the engine's core invariant: projecting `link:4x4` (uplink 4×
    // faster) must equal the clock of a real run under chaos
    // `link:4x0.25@0` (the reciprocal slowdown, applied from step 0) —
    // on both the serial and the autotuned overlapped clock
    for overlap in ["serial", "auto"] {
        let base = run22(overlap, None, None, 12);
        let whatifs = [WhatIf::LinkScale { edge: UPLINK, factor: 4.0 }];
        let rep = analyze_workload(
            base.core(),
            base.last_counts().unwrap(),
            base.log(),
            Some(&whatifs),
            "train",
        )
        .unwrap();
        assert_eq!(rep.counterfactuals.len(), 1);
        let cf = &rep.counterfactuals[0];
        assert_eq!(cf.spec, format!("link:{UPLINK}x4"));

        // the baseline is the real unperturbed step clock
        let base_step = base.log().records.last().unwrap().sim_total_s();
        assert!(
            (cf.baseline_s - base_step).abs() <= 1e-9 * base_step,
            "{overlap}: baseline {} != run clock {base_step}",
            cf.baseline_s
        );

        let real = run22(overlap, Some("link:4x0.25@0"), None, 12);
        let real_step = real.log().records.last().unwrap().sim_total_s();
        assert!(
            (cf.projected_s - real_step).abs() <= 1e-9 * real_step,
            "{overlap}: projected {} != chaos-run clock {real_step}",
            cf.projected_s
        );
        assert!(
            cf.speedup > 1.0,
            "{overlap}: a 4x-faster uplink must project a speedup, got {}",
            cf.speedup
        );
    }
}

#[test]
fn traced_busy_fractions_never_exceed_one() {
    let s = run22("auto", None, Some(TraceLevel::Chunk), 10);
    let tr = s.tracer().unwrap();
    let clock = tr.clock_s();
    assert!(clock > 0.0);
    assert!(!tr.timeline_busy().is_empty());
    for (track, busy) in tr.timeline_busy() {
        let frac = busy / clock;
        assert!(frac <= 1.0 + 1e-9, "{track}: busy_frac {frac} above 1");
        assert!(frac >= 0.0, "{track}: negative busy_frac {frac}");
    }
    // and the analyzer folds those fractions in beside the blame rows
    let rep =
        analyze_workload(s.core(), s.last_counts().unwrap(), s.log(), None, "train").unwrap();
    for r in &rep.blame {
        if let Some(b) = r.busy_frac {
            assert!(b <= 1.0 + 1e-9, "{}: folded busy_frac {b} above 1", r.track);
        }
    }
}
