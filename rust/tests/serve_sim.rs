//! End-to-end acceptance tests for the serving subsystem: a full
//! continuous-batching run on the Table-1 2×2 tree under a bursty trace
//! with a constrained expert-weight cache, comparing the adaptive stack
//! (ta-moe dispatch + live placement + overlap autotuner + EWMA cache)
//! against the static baseline (even dispatch, canonical hosting, serial
//! clock, LRU) — all pure pricing, zero backends, zero artifacts.

use ta_moe::coordinator::{StepProfile, Workload};
use ta_moe::metrics::percentile;
use ta_moe::overlap::OverlapMode;
use ta_moe::runtime::{ModelCfg, SimBackend};
use ta_moe::serve::{CachePolicy, ServeBuilder, ServeSession, TraceConfig, TraceKind};
use ta_moe::SessionBuilder;

/// The acceptance scenario: tiny4 shape rehosted at 4 experts/device on
/// the paper's Table-1 tree (2 nodes × 2 GPUs), a bursty arrival trace,
/// and a cache that only holds half of each device's experts.
fn scenario(
    policy: &str,
    placement: bool,
    overlap: OverlapMode,
    cache: CachePolicy,
) -> ServeSession {
    let mut b = ServeBuilder::new()
        .preset("tiny4")
        .experts_per_dev(4)
        .cluster("table1")
        .policy_named(policy)
        .trace(TraceConfig {
            kind: TraceKind::Bursty,
            rate_rps: 50.0,
            n_requests: 48,
            seed: 9,
            prompt_mean: 32,
            output_mean: 16,
        })
        .cache_cap(2)
        .cache_policy(cache)
        .slo_s(0.2)
        .overlap(overlap);
    if placement {
        b = b.placement_every(8);
    }
    b.build().unwrap()
}

#[test]
fn adaptive_stack_beats_static_baseline_on_goodput_and_tail_latency() {
    let mut baseline =
        scenario("fastmoe", false, OverlapMode::Serial, CachePolicy::Lru);
    let mut adaptive =
        scenario("ta-moe", true, OverlapMode::Auto, CachePolicy::EwmaPrioritized);
    baseline.run(100_000).unwrap();
    adaptive.run(100_000).unwrap();

    assert_eq!(baseline.log().requests.len(), 48);
    assert_eq!(adaptive.log().requests.len(), 48);

    let (g_base, g_adapt) = (baseline.goodput(), adaptive.goodput());
    let p99_base = baseline.log().ttft_percentile(99.0).unwrap();
    let p99_adapt = adaptive.log().ttft_percentile(99.0).unwrap();
    assert!(
        g_adapt > g_base,
        "adaptive goodput {g_adapt:.1} must beat baseline {g_base:.1} tok/s"
    );
    assert!(
        p99_adapt < p99_base,
        "adaptive p99 TTFT {:.3}ms must beat baseline {:.3}ms",
        p99_adapt * 1e3,
        p99_base * 1e3
    );
    // the topology-aware route also touches fewer remote experts, so the
    // constrained cache serves it strictly better
    assert!(
        adaptive.log().cache_hit_rate() > baseline.log().cache_hit_rate(),
        "adaptive hit rate {:.3} vs baseline {:.3}",
        adaptive.log().cache_hit_rate(),
        baseline.log().cache_hit_rate()
    );
}

#[test]
fn serve_metrics_surface_in_csv_and_summary() {
    let mut s = scenario("ta-moe", false, OverlapMode::Serial, CachePolicy::Lru);
    s.run(100_000).unwrap();
    let log = s.log();

    let json = log.summary_json().to_string_compact();
    for key in [
        "requests",
        "ttft_p50_s",
        "ttft_p99_s",
        "tpot_p50_s",
        "tpot_p99_s",
        "cache_hits",
        "cache_misses",
        "cache_hit_rate",
        "fetch_s",
    ] {
        assert!(json.contains(&format!("\"{key}\":")), "{key} missing: {json}");
    }

    let path = std::env::temp_dir().join("ta_moe_serve_sim_acceptance.csv");
    log.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let header = text.lines().next().unwrap();
    for col in ["inflight", "admitted", "finished", "cache_hits", "cache_misses", "fetch_s"] {
        assert!(header.split(',').any(|c| c == col), "column {col} missing: {header}");
    }
    assert_eq!(text.lines().count(), log.records.len() + 1);
    std::fs::remove_file(&path).ok();

    // a constrained cache must actually miss, and misses must cost time
    assert!(log.cache_misses > 0);
    assert!(log.records.iter().map(|r| r.sim_fetch_s).sum::<f64>() > 0.0);
    // decode pricing carries no gradient allreduce: on the serial clock
    // the serial bound is exactly comm + compute
    for r in &log.records {
        assert!(
            (r.sim_serial_s - (r.sim_comm_s + r.sim_compute_s)).abs() <= 1e-12,
            "step {}: decode profile must not charge an allreduce",
            r.step
        );
    }
}

#[test]
fn request_accounting_is_conserved() {
    let mut s = scenario("ta-moe", false, OverlapMode::Serial, CachePolicy::Lru);
    s.run(100_000).unwrap();
    let log = s.log();
    // every admitted sequence retires exactly once
    let admitted: usize = log.records.iter().map(|r| r.admitted).sum();
    let finished: usize = log.records.iter().map(|r| r.finished).sum();
    assert_eq!(admitted, 48);
    assert_eq!(finished, 48);
    // lifecycle ordering per request, and the last finish is on the clock
    let mut ids: Vec<usize> = log.requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..48).collect::<Vec<_>>());
    for r in &log.requests {
        assert!(r.arrival_s < r.first_token_s);
        assert!(r.first_token_s <= r.finish_s);
        assert!(r.finish_s <= s.now_s() + 1e-12);
    }
    // percentiles agree with a full-sort oracle on the realised TTFTs
    let mut ttfts: Vec<f64> = log.requests.iter().map(|r| r.ttft_s()).collect();
    ttfts.sort_by(f64::total_cmp);
    let oracle = ttfts[((0.99 * 48.0_f64).ceil() as usize).clamp(1, 48) - 1];
    assert_eq!(log.ttft_percentile(99.0), Some(oracle));
    assert_eq!(percentile(&ttfts, 99.0), Some(oracle));
}

#[test]
fn workload_seam_drives_training_and_serving_alike() {
    // the tentpole seam: one trait object loop prices a training session
    // and a serving session identically
    let serve = scenario("ta-moe", false, OverlapMode::Serial, CachePolicy::Lru);
    let cfg = ModelCfg::preset("tiny4").unwrap();
    let train = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .cluster("table1")
        .build()
        .unwrap();
    let mut workloads: Vec<Box<dyn Workload>> = vec![Box::new(serve), Box::new(train)];
    for w in &mut workloads {
        w.run_steps(4).unwrap();
        assert_eq!(w.log().records.len(), 4);
        assert!(w.log().records.iter().all(|r| r.sim_compute_s > 0.0));
    }
    // profiles differ by workload: decode is forward-only, train is not
    assert!(workloads[0].core().profile().is_forward_only());
    assert!(!workloads[1].core().profile().is_forward_only());
    assert_eq!(workloads[1].core().profile(), StepProfile::train());
}
