//! Communication sweep: even vs uneven vs solved-target dispatch across
//! topologies and message sizes (a generalised Table 1).
//!
//! ```bash
//! cargo run --release --example comm_sweep
//! ```

use ta_moe::comm::CostEngine;
use ta_moe::dispatch::{target_pattern, DispatchProblem};
use ta_moe::topology::{presets, Topology};
use ta_moe::util::bench::{fmt_time, Table};
use ta_moe::util::Mat;

fn ratios_to_bytes(ratios: &Mat, bytes_per_rank: f64) -> Mat {
    ratios.scale(bytes_per_rank)
}

fn even_ratios(p: usize) -> Mat {
    Mat::filled(p, p, 1.0 / p as f64)
}

/// The solved Eq. 7 pattern as a ratio matrix.
fn target_ratios(topo: &Topology) -> Mat {
    let prob = DispatchProblem { k: 1, s: 1_000_000, e_per_dev: 1, elem_bytes: 1 };
    let tp = target_pattern(topo, &prob);
    let p = topo.p();
    Mat::from_fn(p, p, |i, j| tp.c.get(i, j) / 1_000_000.0)
}

fn main() {
    let topologies: Vec<(&str, Topology)> = vec![
        ("table1 [2,2]", presets::table1()),
        ("cluster B ×2 nodes", presets::cluster_b(2)),
        ("cluster C ×2 nodes", presets::cluster_c(2)),
        ("cluster C ×4 nodes", presets::cluster_c(4)),
        ("cluster A ×4 nodes", presets::cluster_a(4)),
    ];

    for (name, topo) in &topologies {
        println!("\n== {name}: P={}, nodes={} ==", topo.p(), topo.n_nodes());
        let mut eng = CostEngine::contention(topo);
        let mut t = Table::new(&["MB/rank", "even", "target (Eq.7)", "speedup"]);
        for mb in [1.0, 8.0, 32.0, 128.0] {
            let bytes = mb * 1024.0 * 1024.0;
            let t_even = eng.exchange_time(&ratios_to_bytes(&even_ratios(topo.p()), bytes));
            let t_tgt = eng.exchange_time(&ratios_to_bytes(&target_ratios(topo), bytes));
            t.row(&[
                format!("{mb:.0}"),
                fmt_time(t_even),
                fmt_time(t_tgt),
                format!("{:.2}x", t_even / t_tgt),
            ]);
        }
        t.print();
    }
    println!(
        "\nShape check (paper §3.3): topology-aware dispatch wins most where slow\n\
         switches see contention (cluster C), and wins nothing on flat fabrics."
    );
}
