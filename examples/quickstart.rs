//! Quickstart: solve a topology, inspect the TA-MoE inputs, train a few
//! steps — all on the pure-rust [`SimBackend`], so this runs on a fresh
//! clone with no artifacts and no XLA:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The session below exercises the full builder surface the CLI exposes:
//! an explicit a2a plan (`--a2a sched:bvn`), amortised expert placement
//! (`--placement 8`), and the chunk-overlap autotuner (`--overlap auto`).
//! With compiled artifacts (`make artifacts`) and `--features backend-xla`
//! the same `Session` drives the real compiled model instead — swap the
//! `.backend(...)` line for `.artifact("artifacts", "tiny4")`.

use anyhow::Result;
use ta_moe::config::topology_for;
use ta_moe::coordinator::{device_flops, SessionBuilder, TaMoe};
use ta_moe::data::builtin_text;
use ta_moe::dispatch::Norm;
use ta_moe::runtime::{ModelCfg, SimBackend};

fn main() -> Result<()> {
    // 1. A model shape and a topology: the tiny 4-device config on
    //    cluster C shrunk to 2 nodes × 2 GPUs with a slow inter-node
    //    switch.
    let cfg = ModelCfg::preset("tiny4").expect("builtin preset");
    let topo = topology_for("C", cfg.p);
    println!(
        "topology: P={} devices on {} nodes, {} levels",
        topo.p(),
        topo.n_nodes(),
        topo.n_levels()
    );

    // 2. Compose backend + topology + policy into a session. The TA-MoE
    //    policy computes the Eq. 7 target pattern and the Eq. 8 penalty
    //    matrix from the topology; the byte-aware BvN schedule executes
    //    the exchanges, expert placement may migrate hot experts, and the
    //    overlap autotuner picks how many token chunks to pipeline.
    let mut session = SessionBuilder::new()
        .backend(Box::new(SimBackend::new(cfg)))
        .topology(topo)
        .policy(Box::new(TaMoe { norm: Norm::L1 }))
        .a2a_named("sched:bvn")
        .placement_every(8)
        .overlap_named("auto")
        .lr(2e-3)
        .seed(0)
        .flops_per_dev(device_flops('C'))
        .data_text(builtin_text())
        .build()?;
    println!(
        "session: a2a={} placement=every-8-steps overlap={}",
        session.a2a_algo(),
        session.overlap_mode()
    );

    let inputs = session.policy_inputs();
    let target = inputs.target.as_ref().expect("ta-moe target");
    println!("\ntarget dispatch from rank 0 (tokens/step, Eq. 7):");
    println!(
        "  {:?}",
        target.c.row(0).iter().map(|v| (*v * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
    println!("penalty row 0 (Eq. 8 coefficients fed to the loss):");
    println!(
        "  {:?}",
        inputs.gate.penalty.row(0).iter().map(|v| (*v * 100.0).round() / 100.0).collect::<Vec<_>>()
    );

    // 3. Train a few steps on the builtin corpus.
    println!("\ntraining on the {} backend for 20 steps:", session.backend_name());
    for step in 0..20 {
        let rec = session.step()?;
        if step % 5 == 0 || step == 19 {
            println!(
                "  step {:>2}: loss {:.4} (ce {:.4}, aux {:.4}), {:.1}% dropped, sim step {:.2} ms",
                step,
                rec.loss,
                rec.ce,
                rec.aux,
                rec.dropped * 100.0,
                rec.sim_total_s() * 1e3,
            );
        }
    }
    println!(
        "\nsimulated throughput: {:.0} tokens/s on the cluster clock",
        session.log().sim_throughput()
    );
    let log = session.log();
    let max_chunks = log.records.iter().map(|r| r.chunks).max().unwrap_or(1);
    println!(
        "overlap: {:.1}% of the serial clock hidden (chunk count up to {}); \
         placement: {} migration(s), epoch {}",
        log.overlap_efficiency() * 100.0,
        max_chunks,
        log.migrations.len(),
        session.placement_epoch()
    );

    // 4. Where did the gate actually send tokens?
    if let Some(counts) = session.last_counts() {
        println!("\nmeasured dispatch from rank 0 after 20 steps (c_0e):");
        println!(
            "  {:?}",
            counts.row(0).iter().map(|v| (*v * 10.0).round() / 10.0).collect::<Vec<_>>()
        );
        println!("(compare with the Eq. 7 target above — the topology loss pulls c → ĉ)");
    }
    Ok(())
}
