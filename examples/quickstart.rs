//! Quickstart: solve a topology, inspect the TA-MoE inputs, train a few
//! steps of the tiny compiled model.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use ta_moe::config::topology_for;
use ta_moe::coordinator::{device_flops, Strategy, Trainer, TrainerOptions};
use ta_moe::data::{builtin_text, Batcher};
use ta_moe::dispatch::Norm;
use std::path::Path;

fn main() -> Result<()> {
    // 1. A topology: cluster C shrunk to the tiny artifact's 4 devices
    //    (2 nodes × 2 GPUs with a slow inter-node switch).
    let topo = topology_for("C", 4);
    println!(
        "topology: P={} devices on {} nodes, {} levels",
        topo.p(),
        topo.n_nodes(),
        topo.n_levels()
    );

    // 2. The TA-MoE strategy computes the Eq. 7 target pattern and the
    //    Eq. 8 penalty matrix from that topology.
    let strategy = Strategy::TaMoe { norm: Norm::L1 };
    let mut trainer = Trainer::new(
        Path::new("artifacts/tiny4"),
        topo,
        strategy,
        TrainerOptions { lr: 2e-3, seed: 0, flops_per_dev: device_flops('C') },
    )?;
    let inputs = trainer.strategy_inputs();
    let target = inputs.target.as_ref().expect("ta-moe target");
    println!("\ntarget dispatch from rank 0 (tokens/step, Eq. 7):");
    println!(
        "  {:?}",
        target.c.row(0).iter().map(|v| (*v * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
    println!("penalty row 0 (Eq. 8 coefficients fed to the loss):");
    println!(
        "  {:?}",
        inputs.penalty.row(0).iter().map(|v| (*v * 100.0).round() / 100.0).collect::<Vec<_>>()
    );

    // 3. Train a few steps on the builtin corpus.
    let cfg = trainer.manifest().config.clone();
    let mut batcher = Batcher::from_text(builtin_text(), cfg.p, cfg.batch, cfg.seq);
    println!("\ntraining {} params for 20 steps:", trainer.manifest().n_params());
    for step in 0..20 {
        let (tok, tgt) = batcher.next_batch();
        let rec = trainer.train_step(&tok, &tgt)?;
        if step % 5 == 0 || step == 19 {
            println!(
                "  step {:>2}: loss {:.4} (ce {:.4}, aux {:.4}), {:.1}% dropped, sim step {:.2} ms",
                step,
                rec.loss,
                rec.ce,
                rec.aux,
                rec.dropped * 100.0,
                rec.sim_total_s() * 1e3,
            );
        }
    }
    println!(
        "\nsimulated throughput: {:.0} tokens/s on the cluster clock",
        trainer.log().sim_throughput()
    );

    // 4. Where did the gate actually send tokens?
    if let Some(counts) = trainer.last_counts() {
        println!("\nmeasured dispatch from rank 0 after 20 steps (c_0e):");
        println!(
            "  {:?}",
            counts.row(0).iter().map(|v| (*v * 10.0).round() / 10.0).collect::<Vec<_>>()
        );
        println!("(compare with the Eq. 7 target above — the topology loss pulls c → ĉ)");
    }
    Ok(())
}
