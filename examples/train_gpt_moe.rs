//! End-to-end validation driver (DESIGN.md §deliverables): train the MoE
//! transformer under TA-MoE *and* the FastMoE baseline on identical data,
//! log both loss curves, and report the dispatch patterns.
//!
//! The backend resolves automatically: with `--features backend-xla` and
//! compiled artifacts this proves all three layers (Pallas kernels → JAX
//! step program → rust coordinator) compose on a real workload; on the
//! default feature set the simulator stands in and the same driver runs
//! anywhere.
//!
//! ```bash
//! cargo run --release --example train_gpt_moe            # default 150 steps
//! TA_MOE_STEPS=400 cargo run --release --example train_gpt_moe
//! TA_MOE_ARTIFACT=small8_gshard cargo run --release --example train_gpt_moe
//! TA_MOE_BACKEND=sim cargo run --release --example train_gpt_moe
//! # the full session surface: wire plan, expert placement, chunk overlap
//! TA_MOE_A2A=sched:bvn TA_MOE_PLACEMENT=16 TA_MOE_OVERLAP=auto \
//!     cargo run --release --example train_gpt_moe
//! ```
//!
//! Outputs: `target/runs/e2e_<artifact>_<strategy>.csv` per arm and a
//! summary table. Recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;
use std::path::Path;
use ta_moe::coordinator::{device_flops, parse_policy, SessionBuilder};
use ta_moe::runtime::BackendKind;
use ta_moe::util::bench::Table;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let steps = env_usize("TA_MOE_STEPS", 150);
    let artifact =
        std::env::var("TA_MOE_ARTIFACT").unwrap_or_else(|_| "small8_switch".into());
    let backend: BackendKind = std::env::var("TA_MOE_BACKEND")
        .unwrap_or_else(|_| "auto".into())
        .parse()
        .map_err(anyhow::Error::msg)?;
    let eval_every = 10;
    let seed = 42u64;
    // the rest of the builder surface, env-tunable like the CLI flags
    let a2a = std::env::var("TA_MOE_A2A").unwrap_or_else(|_| "auto".into());
    let placement = std::env::var("TA_MOE_PLACEMENT").unwrap_or_else(|_| "off".into());
    let overlap = std::env::var("TA_MOE_OVERLAP").unwrap_or_else(|_| "off".into());

    let arms = ["fastmoe", "ta-moe"];

    let mut summaries = Vec::new();
    for name in arms {
        println!(
            "=== arm: {name} ({artifact}, cluster C, {steps} steps, a2a={a2a}, \
             placement={placement}, overlap={overlap}) ==="
        );
        let mut builder = SessionBuilder::new()
            .artifact("artifacts", artifact.clone())
            .backend_kind(backend)
            .cluster("C")
            .policy(parse_policy(name).map_err(anyhow::Error::msg)?)
            .overlap_named(overlap.clone())
            .lr(1e-3)
            .seed(seed as i32)
            .flops_per_dev(device_flops('C'))
            // identical data across arms: same seed → byte-identical stream
            .data_synthetic(seed);
        if a2a != "auto" {
            builder = builder.a2a_named(a2a.clone());
        }
        if let Some(pcfg) =
            ta_moe::PlacementConfig::parse_spec(&placement).map_err(anyhow::Error::msg)?
        {
            builder = builder.placement(pcfg);
        }
        let mut session = builder.build()?;
        let cfg = session.model_cfg().clone();

        for step in 0..steps {
            let rec = session.step()?;
            if step % 25 == 0 || step + 1 == steps {
                println!(
                    "  step {:>4}: loss {:.4} ce {:.4} drop {:.2}%  sim {:.2} ms",
                    step,
                    rec.loss,
                    rec.ce,
                    rec.dropped * 100.0,
                    rec.sim_total_s() * 1e3
                );
            }
            if (step + 1) % eval_every == 0 {
                session.eval_held_out()?;
            }
        }
        let (vloss, counts) = session.eval_held_out()?;
        let csv = format!("target/runs/e2e_{artifact}_{name}.csv");
        session.log().write_csv(Path::new(&csv))?;

        // dispatch locality: fraction of rank-0 tokens staying on-node
        let topo = session.topology();
        let local_frac: f64 = {
            let row = counts.row(0);
            let local: f64 = row
                .iter()
                .enumerate()
                .filter(|(e, _)| topo.same_node(0, *e / cfg.e_per_dev))
                .map(|(_, v)| v)
                .sum();
            local / row.iter().sum::<f64>()
        };
        println!(
            "  final: valid ce {:.4} (ppl {:.1}); rank-0 keeps {:.0}% of tokens on-node; log → {csv}",
            vloss,
            vloss.exp(),
            local_frac * 100.0
        );
        summaries.push((
            name,
            vloss,
            session.log().sim_throughput(),
            local_frac,
            session.log().overlap_efficiency(),
            session.log().migrations.len(),
        ));
    }

    println!();
    let mut t = Table::new(&[
        "arm", "valid ce", "valid ppl", "sim tokens/s", "rank0 on-node %", "overlap hidden %",
        "migrations",
    ]);
    for (name, vloss, thr, lf, eff, migs) in &summaries {
        t.row(&[
            name.to_string(),
            format!("{vloss:.4}"),
            format!("{:.1}", vloss.exp()),
            format!("{thr:.0}"),
            format!("{:.0}", lf * 100.0),
            format!("{:.1}", eff * 100.0),
            migs.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Fig. 3 + Fig. 6b): the two valid losses match within noise\n\
         while TA-MoE's throughput is higher and its dispatch is node-local-heavy."
    );
    Ok(())
}
