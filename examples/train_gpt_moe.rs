//! End-to-end validation driver (DESIGN.md §deliverables): train the MoE
//! transformer under TA-MoE *and* the FastMoE baseline on identical data,
//! log both loss curves, and report the dispatch patterns — proving all
//! three layers (Pallas kernels → JAX step program → rust coordinator)
//! compose on a real workload.
//!
//! ```bash
//! cargo run --release --example train_gpt_moe            # default 150 steps
//! TA_MOE_STEPS=400 cargo run --release --example train_gpt_moe
//! TA_MOE_ARTIFACT=small8_gshard cargo run --release --example train_gpt_moe
//! ```
//!
//! Outputs: `target/runs/e2e_<artifact>_<strategy>.csv` per arm and a
//! summary table. Recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;
use std::path::Path;
use ta_moe::config::topology_for;
use ta_moe::coordinator::{device_flops, Strategy, Trainer, TrainerOptions};
use ta_moe::data::{Batcher, SyntheticCorpus};
use ta_moe::dispatch::Norm;
use ta_moe::util::bench::Table;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let steps = env_usize("TA_MOE_STEPS", 150);
    let artifact =
        std::env::var("TA_MOE_ARTIFACT").unwrap_or_else(|_| "small8_switch".into());
    let eval_every = 10;
    let seed = 42u64;

    let arms = [
        ("fastmoe", Strategy::FastMoeEven),
        ("ta-moe", Strategy::TaMoe { norm: Norm::L1 }),
    ];

    let mut summaries = Vec::new();
    for (name, strategy) in arms {
        println!("=== arm: {name} ({artifact}, cluster C, {steps} steps) ===");
        let dir = format!("artifacts/{artifact}");
        let manifest = ta_moe::runtime::Manifest::load(Path::new(&dir))?;
        let topo = topology_for("C", manifest.config.p);
        let mut trainer = Trainer::new(
            Path::new(&dir),
            topo,
            strategy,
            TrainerOptions { lr: 1e-3, seed: seed as i32, flops_per_dev: device_flops('C') },
        )?;
        let cfg = trainer.manifest().config.clone();

        // identical data across arms: same seed → byte-identical stream
        let mut corpus = SyntheticCorpus::new(seed);
        let stream = corpus.tokens(cfg.p * cfg.batch * (cfg.seq + 1) * 128);
        let mut batcher = Batcher::new(stream, cfg.p, cfg.batch, cfg.seq);
        let mut vcorpus = SyntheticCorpus::new(seed + 999);
        let vstream = vcorpus.tokens(cfg.p * cfg.batch * (cfg.seq + 1) * 8);
        let (vtok, vtgt) = Batcher::new(vstream, cfg.p, cfg.batch, cfg.seq).next_batch();

        for step in 0..steps {
            let (tok, tgt) = batcher.next_batch();
            let rec = trainer.train_step(&tok, &tgt)?;
            if step % 25 == 0 || step + 1 == steps {
                println!(
                    "  step {:>4}: loss {:.4} ce {:.4} drop {:.2}%  sim {:.2} ms",
                    step,
                    rec.loss,
                    rec.ce,
                    rec.dropped * 100.0,
                    rec.sim_total_s() * 1e3
                );
            }
            if (step + 1) % eval_every == 0 {
                trainer.eval(&vtok, &vtgt)?;
            }
        }
        let (vloss, counts) = trainer.eval(&vtok, &vtgt)?;
        let csv = format!("target/runs/e2e_{artifact}_{name}.csv");
        trainer.log().write_csv(Path::new(&csv))?;

        // dispatch locality: fraction of rank-0 tokens staying on-node
        let topo = trainer.topology();
        let local_frac: f64 = {
            let row = counts.row(0);
            let local: f64 = row
                .iter()
                .enumerate()
                .filter(|(e, _)| topo.same_node(0, *e / cfg.e_per_dev))
                .map(|(_, v)| v)
                .sum();
            local / row.iter().sum::<f64>()
        };
        println!(
            "  final: valid ce {:.4} (ppl {:.1}); rank-0 keeps {:.0}% of tokens on-node; log → {csv}",
            vloss,
            vloss.exp(),
            local_frac * 100.0
        );
        summaries.push((
            name,
            vloss,
            trainer.log().sim_throughput(),
            local_frac,
        ));
    }

    println!();
    let mut t = Table::new(&["arm", "valid ce", "valid ppl", "sim tokens/s", "rank0 on-node %"]);
    for (name, vloss, thr, lf) in &summaries {
        t.row(&[
            name.to_string(),
            format!("{vloss:.4}"),
            format!("{:.1}", vloss.exp()),
            format!("{thr:.0}"),
            format!("{:.0}", lf * 100.0),
        ]);
    }
    t.print();
    println!(
        "\nExpected shape (paper Fig. 3 + Fig. 6b): the two valid losses match within noise\n\
         while TA-MoE's throughput is higher and its dispatch is node-local-heavy."
    );
    Ok(())
}
