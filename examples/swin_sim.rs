//! Vision workload (paper §A.3 / Figure 8): a Swin-Transformer-shaped MoE
//! priced on cluster A at 16 and 32 GPUs, TA-MoE vs FastMoE, plus a short
//! *real* training run of the wide16 artifact on a patch-like token
//! stream to validate the dispatch shift.
//!
//! ```bash
//! cargo run --release --example swin_sim
//! TA_MOE_STEPS=80 cargo run --release --example swin_sim
//! ```

use anyhow::Result;
use ta_moe::comm::A2aAlgo;
use ta_moe::coordinator::{
    converged_counts, device_flops, throughput, FastMoeEven, ModelShape, SessionBuilder,
    TaMoe,
};
use ta_moe::dispatch::Norm;
use ta_moe::topology::presets;
use ta_moe::util::bench::Table;
use ta_moe::util::rng::Rng;

/// Swin-v1-ish MoE shape (Table 5): 12 layers, GShard gate, windows of
/// 7×7 patches; stage-3 dominates compute so we price its dims.
fn swin_shape(tokens_per_dev: usize) -> ModelShape {
    ModelShape {
        layers: 12,
        d: 384,        // stage-3 width
        f: 1536,
        vocab: 1000,   // classifier head
        seq: 49,       // 7×7 window
        tokens_per_dev,
        k: 2,          // GShard gate
        n_moe_layers: 6,
        elem_bytes: 2,
    }
}

fn main() -> Result<()> {
    // --- Figure 8: priced speedup on cluster A, 16 and 32 GPUs ------------
    println!("== Figure 8 analogue: Swin-MoE on cluster A ==");
    let mut t = Table::new(&["GPUs", "topology", "FastMoE tok/s", "TA-MoE tok/s", "speedup"]);
    for nodes in [2usize, 4] {
        let topo = presets::cluster_a(nodes);
        let p = topo.p();
        let shape = swin_shape(2 * 49 * 32); // 32 windows × 2 images per device
        let cfg = fake_cfg(p, shape.tokens_per_dev, 2);
        let even = converged_counts(&FastMoeEven, &topo, &cfg);
        let ta = converged_counts(&TaMoe { norm: Norm::L1 }, &topo, &cfg);
        let t_even = throughput(&shape, &topo, &even, 1, device_flops('A'), A2aAlgo::Direct);
        let t_ta = throughput(&shape, &topo, &ta, 1, device_flops('A'), A2aAlgo::Direct);
        t.row(&[
            p.to_string(),
            if nodes == 2 { "symmetric".into() } else { "asymmetric".to_string() },
            format!("{t_even:.0}"),
            format!("{t_ta:.0}"),
            format!("{:.2}x", t_ta / t_even),
        ]);
    }
    t.print();
    println!("(paper: 1.18x @16 GPUs, 1.20x @32 GPUs)");

    // --- real training on a patch-like stream -----------------------------
    let steps: usize = std::env::var("TA_MOE_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);
    println!("\n== wide16 model on a synthetic patch stream ({steps} steps) ==");
    // "patches": smooth byte field with spatial structure, row-major scan;
    // 64 batches at the wide16 shape.
    let wide16 = ta_moe::runtime::ModelCfg::preset("wide16_switch").expect("builtin preset");
    let mut rng = Rng::seed_from_u64(11);
    let mut stream = Vec::new();
    let mut v = 128i32;
    while stream.len() < wide16.p * wide16.batch * (wide16.seq + 1) * 64 {
        v = (v + rng.range(0, 9) as i32 - 4).clamp(0, 255);
        stream.push(v);
    }
    let mut session = SessionBuilder::new()
        .artifact("artifacts", "wide16_switch")
        .cluster("A")
        .policy(Box::new(TaMoe { norm: Norm::L1 }))
        .lr(1.5e-3)
        .seed(7)
        .flops_per_dev(device_flops('A'))
        .data_stream(stream)
        .build()?;
    for step in 0..steps {
        let rec = session.step()?;
        if step % 10 == 0 || step + 1 == steps {
            println!("  step {:>3}: loss {:.4} drop {:.2}%", step, rec.loss, rec.dropped * 100.0);
        }
    }
    if let Some(counts) = session.last_counts() {
        let topo = session.topology();
        let row = counts.row(0);
        let local: f64 = row
            .iter()
            .enumerate()
            .filter(|(e, _)| topo.same_node(0, *e))
            .map(|(_, v)| v)
            .sum();
        println!(
            "  rank-0 on-node dispatch fraction: {:.0}% (uniform would be {:.0}%)",
            100.0 * local / row.iter().sum::<f64>(),
            100.0 / topo.n_nodes() as f64
        );
    }
    Ok(())
}

/// A minimal ModelCfg for the analytic path (only the fields
/// converged_counts touches matter).
fn fake_cfg(p: usize, tokens_per_dev: usize, k: usize) -> ta_moe::runtime::ModelCfg {
    ta_moe::runtime::ModelCfg {
        p,
        e_per_dev: 1,
        layers: 12,
        d: 384,
        f: 1536,
        heads: 12,
        vocab: 1000,
        batch: 2,
        seq: tokens_per_dev / 2,
        k,
        cap_factor: 1.2,
        gate: "gshard".into(),
        dispatch: "local".into(),
        n_experts: p,
        capacity: tokens_per_dev * 2,
        tokens_per_dev,
        moe_layer_ids: (0..6).map(|i| 2 * i + 1).collect(),
    }
}
